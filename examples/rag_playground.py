"""RAG Playground (paper §2.2) — end-to-end on-device RAG:

  1. index a document corpus (hashed-ngram embedder + HNSW),
  2. take user queries, retrieve top-k docs,
  3. fill the {{user}}/{{context}} prompt template,
  4. generate with a small in-framework LM served through the
     continuous-batching engine.

    PYTHONPATH=src python examples/rag_playground.py \
        [--interactive] [--index {flat,ivf,hnsw,tiered}]

The retriever is any ``VectorIndex`` backend; documents can also be
retracted live (``del <key>`` in interactive mode) — the tombstone is
honored by every later retrieval.
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.data.corpus import BUILTIN_CORPUS
from repro.models import transformer as tf
from repro.serve.engine import ServeEngine
from repro.serve.rag import RAGPipeline, lm_generate_fn

QUERIES = [
    "how does mememo use IndexedDB for vector storage?",
    "what controls recall at query time in HNSW?",
    "why does on device retrieval protect privacy?",
]


def main(interactive: bool = False, index: str = "hnsw"):
    cfg = get_smoke_config("llama3-8b")
    params = tf.init_lm(jax.random.PRNGKey(0), cfg)
    engine = ServeEngine(params, cfg, slots=2, max_len=128, dtype=jnp.float32)

    rag = RAGPipeline(index_kind=index,
                      generate_fn=lm_generate_fn(engine, cfg.vocab, 96))
    rag.add_documents(BUILTIN_CORPUS)
    print(f"indexed {rag.index.size} documents "
          f"(backend={index}, {type(rag.index).__name__})\n")

    def ask(q: str):
        out = rag.answer(q, k=3)
        print(f"Q: {q}")
        for d in out["docs"]:
            print(f"   [{d.key}] d={d.distance:.3f}  {d.text[:70]}...")
        print(f"   prompt: {len(out['prompt'])} chars; "
              f"LM (untrained demo) -> {out['response'][:60]}\n")

    for q in QUERIES:
        ask(q)
    ask(QUERIES[0])                    # repeat: served from the LRU cache
    s = rag.retriever.stats.as_dict()
    print(f"retrieval: {s['searches']} device dispatches for "
          f"{s['requests']} queries, cache hit rate {s['hit_rate']:.2f} "
          f"(DESIGN.md §6)\n")

    if interactive:
        while True:
            q = input("query> ").strip()
            if not q:
                break
            if q.startswith("del "):             # retract a document live
                key = q[4:].strip()
                try:
                    rag.delete_document(key)
                    print(f"   deleted {key!r} "
                          f"({rag.index.size} docs remain)\n")
                except KeyError:
                    print(f"   no such key {key!r}\n")
                continue
            ask(q)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--interactive", action="store_true")
    ap.add_argument("--index", default="hnsw",
                    choices=("flat", "ivf", "hnsw", "tiered"))
    main(**vars(ap.parse_args()))
