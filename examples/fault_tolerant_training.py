"""Fault-tolerant training demo: injected failures, checkpoint restart,
straggler detection, and exact-replay determinism.

    PYTHONPATH=src python examples/fault_tolerant_training.py

What it shows (the 1000-node operating model, at smoke scale):
  1. a supervised run with TWO injected mid-run failures restores from the
     newest checkpoint and continues;
  2. the (seed, step)-deterministic data pipeline makes the recovered run
     bit-match a failure-free run;
  3. the straggler watchdog flags slow steps against a rolling p95.
"""
import tempfile

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.data.synthetic import lm_batches
from repro.models import transformer as tf
from repro.train.checkpoint import CheckpointManager
from repro.train.fault_tolerance import StragglerWatchdog, run_resilient
from repro.train.optimizer import AdamWConfig, warmup_cosine
from repro.train.train_loop import make_train_step
from repro.utils import logger


def main():
    cfg = get_smoke_config("llama3-8b")
    opt = AdamWConfig(lr=warmup_cosine(1e-3, 5, 40))
    loss_fn = lambda p, tokens, labels: tf.lm_loss(p, cfg, tokens, labels,
                                                   dtype=jnp.float32)
    step = make_train_step(loss_fn, opt, donate=False)

    def batch_fn(s):                      # deterministic in (seed, step)
        return next(lm_batches(cfg.vocab, 8, 33, seed=0, start_step=s))

    with tempfile.TemporaryDirectory() as td:
        logger.info("=== run 1: failures injected at steps 9 and 17 ===")
        wd = StragglerWatchdog(min_samples=5, factor=4.0)
        p1 = tf.init_lm(jax.random.PRNGKey(0), cfg)
        _, _, info1 = run_resilient(
            p1, step, batch_fn, steps=24,
            ckpt=CheckpointManager(td + "/a", keep=3, async_save=True),
            ckpt_every=8, watchdog=wd, fail_at=[9, 17])
        logger.info(f"restarts={info1['restarts']} "
                    f"stragglers={len(info1['stragglers'])} "
                    f"final loss={info1['losses'][23]:.5f}")

        logger.info("=== run 2: failure-free reference ===")
        p2 = tf.init_lm(jax.random.PRNGKey(0), cfg)
        _, _, info2 = run_resilient(
            p2, step, batch_fn, steps=24,
            ckpt=CheckpointManager(td + "/b", keep=3), ckpt_every=8)
        logger.info(f"final loss={info2['losses'][23]:.5f}")

        diff = abs(info1["losses"][23] - info2["losses"][23])
        logger.info(f"|recovered - reference| = {diff:.2e} "
                    f"({'EXACT replay' if diff < 2e-3 else 'MISMATCH'})")
        assert diff < 2e-3


if __name__ == "__main__":
    main()
