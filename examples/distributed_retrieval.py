"""Distributed retrieval demo: the corpus sharded over a (pod, data, model)
mesh, per-shard top-k + hierarchical merge — the pod-scale version of the
paper's on-device search. Uses 8 fake host devices.

    PYTHONPATH=src python examples/distributed_retrieval.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax                                    # noqa: E402
import jax.numpy as jnp                       # noqa: E402
import numpy as np                            # noqa: E402

from repro.core.distributed import sharded_flat_topk   # noqa: E402
from repro.data.synthetic import make_corpus            # noqa: E402
from repro.kernels import ref                           # noqa: E402


def main():
    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    n, dim, b, k = 64_000, 64, 8, 10
    db = jnp.asarray(make_corpus(n, dim, seed=0))
    db = db / jnp.linalg.norm(db, axis=1, keepdims=True)
    q = db[:b] + 0.01

    fn = jax.jit(lambda db, q: sharded_flat_topk(mesh, db, q, k))
    d, i = fn(db, q)
    d_exp, i_exp = ref.distance_topk_ref(db, q, k)
    match = (np.sort(np.asarray(i)) == np.sort(np.asarray(i_exp))).mean()
    print(f"mesh {dict(mesh.shape)}  db {n}x{dim} sharded over "
          f"{np.prod(list(mesh.shape.values()))} devices")
    print(f"top-{k} ids match exact search: {match:.1%}")
    print("first query ->", np.asarray(i[0])[:5], np.round(np.asarray(d[0])[:5], 4))

    lowered = jax.jit(lambda db, q: sharded_flat_topk(mesh, db, q, k)).lower(db, q)
    txt = lowered.compile().as_text()
    n_ag = txt.count("all-gather")
    print(f"compiled collective ops: {n_ag} all-gathers "
          f"(log-depth hierarchical merge over 3 axes)")


if __name__ == "__main__":
    main()
