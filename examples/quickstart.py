"""Quickstart: the MeMemo API (paper §2.1, Code 1 parity) plus the unified
mutable ``VectorIndex`` layer (full CRUD across flat/ivf/hnsw/tiered).

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import tempfile

import numpy as np

from repro.core import make_index
from repro.core.interface import HNSW
from repro.core.tiered import auto_prefetch_p, simulate_search_traffic
from repro.data.synthetic import make_corpus


def main():
    # --- Code 1: create an index, bulk-insert, query ------------------------
    n, dim = 2000, 64
    values = make_corpus(n, dim, seed=0)
    keys = [f"doc-{i}" for i in range(n)]

    index = HNSW(distance_function="cosine", M=16, ef_construction=100)
    index.bulk_insert(keys, values)                      # await index.bulkInsert(...)

    query = values[123] + 0.05 * np.random.default_rng(1).normal(size=dim)
    found_keys, distances = index.query(query, k=5)      # await index.query(...)
    print("query ->", list(zip(found_keys, np.round(distances, 4))))
    assert found_keys[0] == "doc-123"

    # --- full CRUD: update + delete (the privacy operation) -----------------
    index.update("doc-124", values[123])                 # re-embed in place
    index.delete("doc-123")                              # retract: tombstoned
    k2, _ = index.query(query, k=5)
    print("after delete/update ->", k2)
    assert "doc-123" not in k2 and k2[0] == "doc-124"
    assert index.size == n - 1

    # --- exact oracle comparison (recall) -----------------------------------
    exact_keys, _ = index.exact_query(query, k=5)
    print("exact keys:", exact_keys[:5])
    assert "doc-123" not in exact_keys                   # oracle honors deletes

    # --- export / load (persistent index incl. tombstones, §2.1) ------------
    with tempfile.TemporaryDirectory() as td:
        path = os.path.join(td, "index.npz")
        index.export_index(path)
        loaded = HNSW.load_index(path)
        k3, _ = loaded.query(query, k=5)
        assert k3 == k2
        print(f"export/load roundtrip OK ({os.path.getsize(path)/1e6:.1f} MB)")

    # --- one protocol, four backends ----------------------------------------
    for kind in ("flat", "ivf", "hnsw", "tiered"):
        idx = make_index(kind, dim=dim, metric="cosine", M=8,
                         ef_construction=60)
        idx.bulk_insert(keys[:500], values[:500])
        got, _ = idx.query(values[42], k=1)
        assert got[0] == "doc-42", (kind, got)
        print(f"make_index({kind!r:>9}) -> top-1 self-query OK")

    # --- the two-tier memory story (§3.2) ------------------------------------
    g = index._graph or index._builder.graph()
    queries = make_corpus(50, dim, seed=2)
    p = auto_prefetch_p(dim)
    with_pref = simulate_search_traffic(g, queries, ef=32, cache_rows=256,
                                        prefetch_p=16)
    without = simulate_search_traffic(g, queries, ef=32, cache_rows=256,
                                      prefetch_p=1, use_graph_prefetch=False)
    print(f"auto prefetch p for dim={dim}: {p}")
    print(f"slow-tier transactions  with prefetch: {with_pref.transactions}  "
          f"without: {without.transactions}  "
          f"({without.transactions / max(with_pref.transactions, 1):.2f}x saved)")


if __name__ == "__main__":
    main()
