#!/usr/bin/env bash
# Tier-1 verify: the one command CI and contributors run.
#   scripts/run_tests.sh [extra pytest args]
#   scripts/run_tests.sh --smoke   # tiny bench_query/bench_serve canary:
#                                  # catches perf-path breakage (shape
#                                  # regressions, lost batching, cache
#                                  # misses) without a full benchmark run
set -euo pipefail
cd "$(dirname "$0")/.."
if [[ "${1:-}" == "--smoke" ]]; then
  shift
  PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    exec python -m benchmarks.run --only query,serve --smoke "$@"
fi
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} exec python -m pytest -x -q "$@"
