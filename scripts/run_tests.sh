#!/usr/bin/env bash
# Tier-1 verify: the one command CI and contributors run.
#   scripts/run_tests.sh [extra pytest args]
#   scripts/run_tests.sh --smoke   # tiny bench_build/query/serve/store/...
#                                  # canary: catches perf-path breakage
#                                  # (shape regressions, lost batching,
#                                  # broken save/restore) without a full
#                                  # benchmark run
#
# --smoke always writes its machine-readable rows to a STABLE path
# ($SMOKE_JSON, default bench-results/BENCH_smoke.json) so CI can upload
# it as a workflow artifact and the perf trajectory accumulates per-PR.
set -euo pipefail
cd "$(dirname "$0")/.."
if [[ "${1:-}" == "--smoke" ]]; then
  shift
  out="${SMOKE_JSON:-bench-results/BENCH_smoke.json}"
  PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    exec python -m benchmarks.run --only build,query,serve,store,shard,memory,tenant,rag \
      --smoke --json "$out" "$@"
fi
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} exec python -m pytest -x -q "$@"
