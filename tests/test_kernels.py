"""Pallas kernels (interpret mode) vs pure-jnp oracles: shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.distance_topk import distance_topk_pallas
from repro.kernels.embedding_bag import embedding_bag_pallas
from repro.kernels.flash_decode import flash_decode_pallas
from repro.kernels.gather_distance import gather_distance_pallas


@pytest.mark.parametrize("n,d,b,k,dtype", [
    (128, 32, 8, 6, jnp.float32),
    (256, 64, 16, 10, jnp.float32),
    (64, 16, 4, 3, jnp.bfloat16),
    (512, 128, 8, 16, jnp.float32),
])
@pytest.mark.parametrize("metric", ["cosine", "l2"])
def test_gather_distance(n, d, b, k, dtype, metric):
    db = jax.random.normal(jax.random.PRNGKey(0), (n, d), dtype)
    q = jax.random.normal(jax.random.PRNGKey(1), (b, d), dtype)
    ids = jax.random.randint(jax.random.PRNGKey(2), (b, k), 0, n)
    out = gather_distance_pallas(db, q, ids, metric=metric, interpret=True)
    exp = ref.gather_distance_ref(db, q, ids, metric=metric)
    tol = 1e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("n,d,b,k,bq,bn", [
    (256, 32, 8, 5, 8, 64),
    (512, 64, 16, 10, 8, 128),
    (100, 16, 4, 4, 4, 25),       # non-pow2 tiling
])
@pytest.mark.parametrize("metric", ["cosine", "l2"])
def test_distance_topk(n, d, b, k, bq, bn, metric):
    db = jax.random.normal(jax.random.PRNGKey(0), (n, d))
    q = jax.random.normal(jax.random.PRNGKey(1), (b, d))
    pd, pi = distance_topk_pallas(db, q, k, metric=metric, block_q=bq,
                                  block_n=bn, interpret=True)
    neg, j = jax.lax.top_k(-pd, k)
    got_d = -neg
    got_i = jnp.take_along_axis(pi, j, axis=1)
    exp_d, exp_i = ref.distance_topk_ref(db, q, k, metric=metric)
    np.testing.assert_allclose(np.sort(np.asarray(got_d)),
                               np.sort(np.asarray(exp_d)),
                               rtol=1e-4, atol=1e-4)
    assert (np.sort(np.asarray(got_i)) == np.sort(np.asarray(exp_i))).all()


@pytest.mark.parametrize("r,e,b,l,dtype", [
    (100, 32, 12, 6, jnp.float32),
    (1000, 64, 8, 4, jnp.float32),
    (50, 16, 6, 3, jnp.bfloat16),
])
@pytest.mark.parametrize("combine", ["sum", "mean"])
def test_embedding_bag(r, e, b, l, dtype, combine):
    table = jax.random.normal(jax.random.PRNGKey(0), (r, e), dtype)
    ids = jax.random.randint(jax.random.PRNGKey(1), (b, l), 0, r)
    w = jax.random.uniform(jax.random.PRNGKey(2), (b, l))
    out = embedding_bag_pallas(table, ids, w, combine=combine, interpret=True)
    exp = ref.embedding_bag_ref(table, ids, w, combine=combine)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("b,h,kvh,dh,s,bs,cur", [
    (2, 8, 2, 32, 128, 32, 100),
    (3, 4, 4, 16, 64, 16, 64),    # MHA
    (1, 8, 1, 64, 256, 64, 7),    # MQA, short valid prefix
])
def test_flash_decode(b, h, kvh, dh, s, bs, cur):
    q = jax.random.normal(jax.random.PRNGKey(0), (b, h, dh))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, s, kvh, dh))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, s, kvh, dh))
    out = flash_decode_pallas(q, k, v, jnp.asarray(cur), block_s=bs,
                              interpret=True)
    exp = ref.flash_decode_ref(q, k, v, jnp.asarray(cur))
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=1e-5, atol=1e-5)


def test_flash_decode_per_sequence_cur_len():
    """Continuous-batching shape (DESIGN.md §11): cur_len is a [B] vector
    — each slot attends over its OWN live prefix. cur=1 is the floor the
    engine can pass (a parked slot decodes with n_valid=1, never 0).
    Must match per-row masking and the scalar fast path."""
    b, h, kvh, dh, s, bs = 4, 8, 2, 32, 128, 32
    q = jax.random.normal(jax.random.PRNGKey(0), (b, h, dh))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, s, kvh, dh))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, s, kvh, dh))
    cur = jnp.asarray([100, 7, 1, 128], jnp.int32)
    out = flash_decode_pallas(q, k, v, cur, block_s=bs, interpret=True)
    exp = ref.flash_decode_ref(q, k, v, cur)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=1e-5, atol=1e-5)
    # each row equals the scalar-cur_len result for that row alone
    for i in range(b):
        solo = flash_decode_pallas(q[i:i + 1], k[i:i + 1], v[i:i + 1],
                                   jnp.asarray(int(cur[i])), block_s=bs,
                                   interpret=True)
        np.testing.assert_allclose(np.asarray(out[i:i + 1]),
                                   np.asarray(solo), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("metric", ["cosine", "l2"])
def test_distance_topk_prime_shapes(metric):
    """Regression (DESIGN.md §9 satellite): B or N prime used to collapse
    the block-shaving loop to 1-row blocks (a B×N program grid). The
    kernel now PADS to the tile multiple and masks the padded db rows —
    results must match the oracle and never leak a padded row id."""
    n, b, k = 997, 7, 5
    db = jax.random.normal(jax.random.PRNGKey(0), (n, 32))
    q = jax.random.normal(jax.random.PRNGKey(1), (b, 32))
    pd, pi = distance_topk_pallas(db, q, k, metric=metric, block_q=4,
                                  block_n=64, interpret=True)
    assert ((np.asarray(pi) >= 0) & (np.asarray(pi) < n)).all()
    neg, j = jax.lax.top_k(-pd, k)
    got_d = -neg
    got_i = jnp.take_along_axis(pi, j, axis=1)
    exp_d, exp_i = ref.distance_topk_ref(db, q, k, metric=metric)
    np.testing.assert_allclose(np.sort(np.asarray(got_d)),
                               np.sort(np.asarray(exp_d)),
                               rtol=1e-4, atol=1e-4)
    assert (np.sort(np.asarray(got_i)) == np.sort(np.asarray(exp_i))).all()


@pytest.mark.parametrize("metric", ["cosine", "l2"])
def test_distance_topk_scales(metric):
    """Codec-encoded db + fused per-row decode (DESIGN.md §9): the int8
    kernel must equal the oracle on the decoded rows."""
    from repro.core.codec import get_codec

    rng = np.random.default_rng(0)
    x = rng.normal(size=(256, 32)).astype(np.float32)
    q = jnp.asarray(rng.normal(size=(8, 32)).astype(np.float32))
    enc, scales = get_codec("int8").encode(x)
    dec = get_codec("int8").decode(enc, scales)
    pd, pi = distance_topk_pallas(jnp.asarray(enc), q, 6, metric=metric,
                                  scales=jnp.asarray(scales), block_q=4,
                                  block_n=64, interpret=True)
    neg, j = jax.lax.top_k(-pd, 6)
    exp_d, exp_i = ref.distance_topk_ref(jnp.asarray(dec), q, 6,
                                         metric=metric)
    np.testing.assert_allclose(np.sort(np.asarray(-neg)),
                               np.sort(np.asarray(exp_d)),
                               rtol=1e-4, atol=1e-4)
    got_i = jnp.take_along_axis(pi, j, axis=1)
    assert (np.sort(np.asarray(got_i)) == np.sort(np.asarray(exp_i))).all()


@pytest.mark.parametrize("metric", ["cosine", "l2"])
def test_gather_distance_scales(metric):
    """int8 rows + per-row scale DMA: fused decode inside the wave loop
    must equal the oracle's take+decode+dot (DESIGN.md §9)."""
    from repro.core.codec import get_codec

    rng = np.random.default_rng(1)
    x = rng.normal(size=(200, 24)).astype(np.float32)
    enc, scales = get_codec("int8").encode(x)
    q = jnp.asarray(rng.normal(size=(6, 24)).astype(np.float32))
    ids = jnp.asarray(rng.integers(0, 200, size=(6, 9)).astype(np.int32))
    out = gather_distance_pallas(jnp.asarray(enc), q, ids, metric=metric,
                                 scales=jnp.asarray(scales), interpret=True)
    exp = ref.gather_distance_ref(jnp.asarray(enc), q, ids, metric=metric,
                                  scales=jnp.asarray(scales))
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=1e-4, atol=1e-4)


def test_resolve_interpret_platform_aware(monkeypatch):
    """interpret=None resolves per-platform (interpret only off-TPU) and
    honors the REPRO_PALLAS_INTERPRET env override."""
    from repro.kernels import resolve_interpret

    monkeypatch.delenv("REPRO_PALLAS_INTERPRET", raising=False)
    on_tpu = jax.default_backend() == "tpu"
    assert resolve_interpret(None) == (not on_tpu)
    assert resolve_interpret(True) is True
    assert resolve_interpret(False) is False
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "0")
    assert resolve_interpret(None) is False
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
    assert resolve_interpret(None) is True
    assert resolve_interpret(False) is False     # explicit arg still wins


def test_flat_topk_scales_dispatch(monkeypatch):
    """ops.flat_topk with scales: interpret == ref, like the f32 path."""
    from repro.core.codec import get_codec
    from repro.kernels import ops

    rng = np.random.default_rng(2)
    enc, scales = get_codec("int8").encode(
        rng.normal(size=(128, 32)).astype(np.float32))
    q = jnp.asarray(rng.normal(size=(4, 32)).astype(np.float32))
    monkeypatch.setenv("REPRO_PALLAS", "off")
    d0, i0 = ops.flat_topk(jnp.asarray(enc), q, 5,
                           scales=jnp.asarray(scales))
    monkeypatch.setenv("REPRO_PALLAS", "interpret")
    d1, i1 = ops.flat_topk(jnp.asarray(enc), q, 5,
                           scales=jnp.asarray(scales))
    np.testing.assert_allclose(np.asarray(d0), np.asarray(d1), rtol=1e-5)
    assert (np.asarray(i0) == np.asarray(i1)).all()


def test_ops_dispatch_matches_ref(monkeypatch):
    """ops.* under REPRO_PALLAS=interpret must equal REPRO_PALLAS=off."""
    from repro.kernels import ops
    db = jax.random.normal(jax.random.PRNGKey(0), (128, 32))
    q = jax.random.normal(jax.random.PRNGKey(1), (4, 32))
    monkeypatch.setenv("REPRO_PALLAS", "off")
    d0, i0 = ops.flat_topk(db, q, 5)
    monkeypatch.setenv("REPRO_PALLAS", "interpret")
    d1, i1 = ops.flat_topk(db, q, 5)
    np.testing.assert_allclose(np.asarray(d0), np.asarray(d1), rtol=1e-5)
    assert (np.asarray(i0) == np.asarray(i1)).all()
