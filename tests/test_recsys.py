"""RecSys models: FM identity (hypothesis), lookups, MIND routing."""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.configs import get_smoke_config
from repro.models import recsys as rs


@given(b=st.integers(1, 6), f=st.integers(2, 6), k=st.integers(1, 8))
@settings(max_examples=12, deadline=None)
def test_fm_sum_square_trick_equals_pairwise(b, f, k):
    """0.5*((Σv)² − Σv²) == Σ_{i<j} <v_i, v_j> — Rendle's O(nk) identity."""
    rng = np.random.default_rng(b * 100 + f * 10 + k)
    v = rng.normal(size=(b, f, k)).astype(np.float32)
    s = v.sum(axis=1)
    s2 = (v ** 2).sum(axis=1)
    trick = 0.5 * ((s ** 2) - s2).sum(-1)
    explicit = np.zeros(b, np.float32)
    for i in range(f):
        for j in range(i + 1, f):
            explicit += (v[:, i] * v[:, j]).sum(-1)
    np.testing.assert_allclose(trick, explicit, rtol=1e-4, atol=1e-4)


def test_fm_forward_matches_manual():
    cfg = get_smoke_config("fm")
    p = rs.init_fm(jax.random.PRNGKey(0), cfg)
    ids = jax.random.randint(jax.random.PRNGKey(1), (4, cfg.n_sparse), 0,
                             cfg.rows_per_field)
    dense = jax.random.normal(jax.random.PRNGKey(2), (4, cfg.n_dense))
    got = rs.fm_forward(p, cfg, ids, dense)
    # manual: embeddings + dense-scaled factors, explicit pairwise
    emb = np.stack([np.asarray(p["table"])[j, np.asarray(ids)[:, j]]
                    for j in range(cfg.n_sparse)], axis=1)
    vd = np.asarray(p["v_dense"])[None] * np.asarray(dense)[..., None]
    vx = np.concatenate([emb, vd], axis=1)
    pair = np.zeros(4, np.float32)
    F = vx.shape[1]
    for i in range(F):
        for j in range(i + 1, F):
            pair += (vx[:, i] * vx[:, j]).sum(-1)
    lin = sum(np.asarray(p["w_sparse"])[j, np.asarray(ids)[:, j]]
              for j in range(cfg.n_sparse))
    lin = lin + (np.asarray(dense) @ np.asarray(p["w_dense"]))[:, 0]
    np.testing.assert_allclose(np.asarray(got), lin + pair,
                               rtol=1e-3, atol=1e-3)


def test_lookup_gathers_correct_rows():
    table = jnp.arange(3 * 5 * 2, dtype=jnp.float32).reshape(3, 5, 2)
    ids = jnp.asarray([[0, 4, 2], [1, 0, 3]], jnp.int32)
    out = rs.lookup(table, ids)
    assert out.shape == (2, 3, 2)
    np.testing.assert_allclose(np.asarray(out[0, 1]),
                               np.asarray(table[1, 4]))
    np.testing.assert_allclose(np.asarray(out[1, 2]),
                               np.asarray(table[2, 3]))


def test_bert4rec_masked_loss_matches_full_loss_on_masked_positions():
    cfg = get_smoke_config("bert4rec")
    p = rs.init_bert4rec(jax.random.PRNGKey(0), cfg)
    B = 4
    seq = jax.random.randint(jax.random.PRNGKey(1), (B, cfg.seq_len), 0,
                             cfg.n_items)
    mpos = jnp.stack([jnp.asarray([1, 5, 9])] * B)
    labels = jax.random.randint(jax.random.PRNGKey(2), (B, 3), 0, cfg.n_items)
    got = rs.bert4rec_masked_loss(p, cfg, seq, mpos, labels)
    # oracle via the full-logits path + mask
    full_labels = jnp.zeros((B, cfg.seq_len), jnp.int32)
    mask = jnp.zeros((B, cfg.seq_len), jnp.float32)
    for j, pos in enumerate([1, 5, 9]):
        full_labels = full_labels.at[:, pos].set(labels[:, j])
        mask = mask.at[:, pos].set(1.0)
    want = rs.bert4rec_loss(p, cfg, seq, full_labels, mask)
    np.testing.assert_allclose(float(got), float(want), rtol=1e-4)


def test_mind_interests_mask_sensitivity():
    """Masked-out behavior items must not affect the interests."""
    cfg = get_smoke_config("mind")
    p = rs.init_mind(jax.random.PRNGKey(0), cfg)
    B, S = 3, cfg.seq_len
    beh = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.n_items)
    mask = jnp.ones((B, S)).at[:, S // 2:].set(0.0)
    i1 = rs.mind_interests(p, cfg, beh, mask)
    beh2 = beh.at[:, S // 2:].set((beh[:, S // 2:] + 7) % cfg.n_items)
    i2 = rs.mind_interests(p, cfg, beh2, mask)
    np.testing.assert_allclose(np.asarray(i1), np.asarray(i2), atol=1e-5)


def test_retrieval_cand_routes_through_flat_index():
    """The retrieval_cand cell is the paper's workload: top-k over items."""
    from repro.core.flat import FlatIndex
    cfg = get_smoke_config("mind")
    p = rs.init_mind(jax.random.PRNGKey(0), cfg)
    items = np.asarray(p["items"])
    idx = FlatIndex.build(items, metric="ip")
    beh = jax.random.randint(jax.random.PRNGKey(1), (1, cfg.seq_len), 0,
                             cfg.n_items)
    interests = rs.mind_user_embedding(p, cfg, beh,
                                       jnp.ones((1, cfg.seq_len)))
    d, i = idx.query(np.asarray(interests[0]), k=5)
    assert i.shape == (cfg.n_interests, 5)
    assert np.isfinite(np.asarray(d)).all()
