"""VectorCodec layer (DESIGN.md §9): encode/decode bounds, fp32 parity,
int8/bf16 recall, encoded persistence (bit-for-bit restore, secure-delete
byte absence, cross-dtype rejection), serving transparency, and the
sharded codec paths (subprocess: the fan-out needs a multi-device mesh —
see tests/test_sharded.py for the pattern)."""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import make_index
from repro.core.codec import (CODEC_NAMES, effective_rerank, get_codec,
                              rerank_exact)
from repro.data.synthetic import make_corpus

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

BACKENDS = [("flat", {"dim": 32}),
            ("ivf", {"dim": 32, "nlist": 8, "nprobe": 8}),
            ("hnsw", {"M": 8, "ef_construction": 40, "ef_search": 32}),
            ("tiered", {"M": 8, "ef_construction": 40, "ef_search": 32})]


def mutate(idx, data, extra):
    """The shared CRUD sequence: every mutator the WAL knows."""
    idx.bulk_insert([f"d{i}" for i in range(len(data))], data)
    for j in range(3):
        idx.insert(f"x{j}", extra[j])
    idx.update("d5", extra[4])
    idx.delete("d7")
    idx.delete("x0")


def run_sub(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=480)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


# ---------------------------------------------------------------------------
# codec primitives
# ---------------------------------------------------------------------------
def test_roundtrip_error_bounds(rng):
    x = rng.normal(size=(64, 48)).astype(np.float32)
    # fp32: identity, no side arrays
    c = get_codec("fp32")
    enc, scales = c.encode(x)
    assert scales is None and enc.dtype == np.float32
    assert (c.decode(enc) == x).all()
    # bf16: 8-bit mantissa -> relative error <= 2^-8
    c = get_codec("bf16")
    enc, scales = c.encode(x)
    assert scales is None and enc.dtype.itemsize == 2
    err = np.abs(c.decode(enc) - x)
    assert (err <= np.abs(x) * 2.0 ** -8 + 1e-9).all()
    # int8: per-row scale -> abs error <= scale/2 = max|row|/254
    c = get_codec("int8")
    enc, scales = c.encode(x)
    assert enc.dtype == np.int8 and scales.shape == (64,)
    bound = np.max(np.abs(x), axis=1) / 254.0 + 1e-9
    assert (np.abs(c.decode(enc, scales) - x) <= bound[:, None]).all()
    # all-zero rows: scale 1.0, exact zeros back
    z = np.zeros((2, 8), np.float32)
    enc, scales = c.encode(z)
    assert (scales == 1.0).all() and (c.decode(enc, scales) == 0).all()


def test_codec_registry_and_sizes():
    assert set(CODEC_NAMES) == {"fp32", "bf16", "int8"}
    assert get_codec("fp32") is get_codec("FP32")       # shared instances
    assert get_codec("fp32").bytes_per_vector(128) == 512
    assert get_codec("bf16").bytes_per_vector(128) == 256
    assert get_codec("int8").bytes_per_vector(128) == 128 + 4
    with pytest.raises(ValueError, match="unknown storage dtype"):
        get_codec("fp16")
    # rerank policy: lossless never reranks; int8 over-fetches by default
    assert effective_rerank(get_codec("fp32"), 8) == 1
    assert effective_rerank(get_codec("int8"), None) == 4
    assert effective_rerank(get_codec("int8"), 2) == 2
    assert effective_rerank(get_codec("bf16"), None) == 1


def test_rerank_exact_contract(rng):
    vecs = rng.normal(size=(20, 8)).astype(np.float32)
    vecs /= np.linalg.norm(vecs, axis=1, keepdims=True)
    q = rng.normal(size=(2, 8)).astype(np.float32)
    ids = np.array([[3, 7, 1, -1, 7], [0, -1, -1, -1, -1]])
    d, out = rerank_exact(vecs, q, ids, 3, metric="cosine")
    assert out.shape == (2, 3) and d.shape == (2, 3)
    assert set(out[0]) <= {1, 3, 7}                  # dups collapse
    assert list(out[1][1:]) == [-1, -1]              # short rows pad
    assert (np.diff(d[0]) >= 0).all()                # ascending


# ---------------------------------------------------------------------------
# fp32 parity + lossy recall
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("kind,cfg", BACKENDS)
def test_fp32_codec_is_bitwise_default(kind, cfg, rng):
    """dtype='fp32' must be THE historical path — same results, same
    state bytes — on every backend (the pre-codec suite is the oracle
    for the default; this pins the explicit spelling to it)."""
    data = make_corpus(150, 32, seed=0)
    extra = make_corpus(8, 32, seed=1)
    q = make_corpus(4, 32, seed=2)
    a = make_index(kind, metric="cosine", **cfg)
    b = make_index(kind, metric="cosine", dtype="fp32", **cfg)
    mutate(a, data, extra)
    mutate(b, data, extra)
    ka, da = a.query_batch(q, 8)
    kb, db = b.query_batch(q, 8)
    assert ka == kb
    assert (np.asarray(da) == np.asarray(db)).all()
    aa, ma = a.state_dict()
    ab, mb = b.state_dict()
    assert set(aa) == set(ab)
    for name in aa:
        assert (np.asarray(aa[name]) == np.asarray(ab[name])).all(), name
    assert a.mutation_epoch == b.mutation_epoch


@pytest.mark.parametrize("dtype", ["bf16", "int8"])
@pytest.mark.parametrize("kind,cfg", BACKENDS)
def test_lossy_recall_vs_fp32(kind, cfg, dtype, rng):
    """Acceptance bar: recall@10 >= 0.95 vs the fp32 index on the
    synthetic corpus, for every backend."""
    data = make_corpus(600, 32, seed=3)
    q = make_corpus(8, 32, seed=4)
    keys = [f"d{i}" for i in range(len(data))]
    ref = make_index(kind, metric="cosine", **cfg)
    ref.bulk_insert(keys, data)
    rk, _ = ref.exact_query(q, 10)
    idx = make_index(kind, metric="cosine", dtype=dtype, **cfg)
    idx.bulk_insert(keys, data)
    fk, _ = idx.query_batch(q, 10)
    recall = (sum(len(set(a) & set(b)) for a, b in zip(rk, fk))
              / (len(q) * 10))
    assert recall >= 0.95, (kind, dtype, recall)


def test_int8_device_blocks_shrink(rng):
    data = make_corpus(500, 64, seed=5)
    keys = [f"d{i}" for i in range(500)]
    sizes = {}
    for dtype in ("fp32", "int8"):
        idx = make_index("flat", dim=64, metric="cosine", dtype=dtype)
        idx.bulk_insert(keys, data)
        idx.query(data[0], 1)            # force the pack
        sizes[dtype] = idx._rows.device_block_bytes()
    assert sizes["fp32"] / sizes["int8"] >= 3.5


def test_rerank_factor_config_roundtrips():
    idx = make_index("flat", dim=8, metric="cosine", dtype="int8",
                     rerank_factor=2)
    assert idx.config_dict()["rerank_factor"] == 2
    assert idx.config_dict()["dtype"] == "int8"
    assert idx.storage_dtype == "int8"
    assert make_index("flat", **idx.config_dict()).rerank_factor == 2


# ---------------------------------------------------------------------------
# encoded persistence
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", ["bf16", "int8"])
@pytest.mark.parametrize("kind,cfg", BACKENDS)
def test_store_restore_bitforbit_per_codec(kind, cfg, dtype, tmp_path, rng):
    """snapshot + WAL-tail restore == the live index, byte for byte, for
    encoded state too (the encoded array is canonical — never re-derived
    — which is what makes this hold; DESIGN.md §9). fp32 is covered by
    tests/test_store.py."""
    from repro.store import IndexStore

    data = make_corpus(120, 32, seed=6)
    extra = make_corpus(8, 32, seed=7)
    q = make_corpus(4, 32, seed=8)
    idx = make_index(kind, store=IndexStore(str(tmp_path / "s")),
                     metric="cosine", dtype=dtype, **cfg)
    mutate(idx, data, extra)
    idx.query_batch(q, 5)                # pack / train derived state
    idx._store.snapshot(idx)
    idx.insert("late", extra[5])         # WAL tail past the snapshot
    idx.delete("d9")
    restored = make_index(kind, store=IndexStore(str(tmp_path / "s")))
    assert restored.storage_dtype == dtype
    a1, m1 = idx.state_dict()
    a2, m2 = restored.state_dict()
    assert m1 == m2
    assert set(a1) == set(a2)
    for name in a1:
        assert (np.asarray(a1[name]) == np.asarray(a2[name])).all(), name
    assert restored.mutation_epoch == idx.mutation_epoch
    k1, d1 = idx.query_batch(q, 5)
    k2, d2 = restored.query_batch(q, 5)
    assert k1 == k2
    assert (np.asarray(d1) == np.asarray(d2)).all()


def test_int8_snapshot_bytes_shrink(tmp_path, rng):
    from repro.store import IndexStore

    data = make_corpus(400, 64, seed=9)
    keys = [f"d{i}" for i in range(400)]
    sizes = {}
    for dtype in ("fp32", "int8"):
        root = tmp_path / dtype
        idx = make_index("flat", dim=64, metric="cosine", dtype=dtype,
                         store=IndexStore(str(root)))
        idx.bulk_insert(keys, data)
        idx._store.snapshot(idx)         # also truncates the WAL
        sizes[dtype] = sum(
            os.path.getsize(os.path.join(dp, fn))
            for dp, _, fns in os.walk(root) for fn in fns)
    assert sizes["fp32"] / sizes["int8"] >= 3.0


def test_secure_delete_erases_encoded_and_fp32_bytes(tmp_path, rng):
    """The §9 extension of the §7 byte-absence contract: after
    compaction, a deleted row's int8-encoded bytes AND its fp32 decode
    AND its raw WAL insert payload exist in no file under the store and
    in no host array."""
    from repro.store import IndexStore

    def dir_blob(root):
        blob = b""
        for dp, _, fns in os.walk(root):
            for fn in sorted(fns):
                with open(os.path.join(dp, fn), "rb") as f:
                    blob += f.read()
        return blob

    data = make_corpus(60, 32, seed=10)
    secret = (make_corpus(1, 32, seed=11)[0] * 7.7).astype(np.float32)
    idx = make_index("flat", dim=32, metric="cosine", dtype="int8",
                     store=IndexStore(str(tmp_path / "s")))
    idx.bulk_insert([f"d{i}" for i in range(60)], data)
    idx.insert("secret", secret)
    idx._store.snapshot(idx)
    row = idx._rows.key2row["secret"]
    enc_bytes = idx._rows.encoded[row].tobytes()
    fp32_bytes = idx._rows.vectors[row].tobytes()
    assert enc_bytes in dir_blob(tmp_path)     # sanity: durable pre-delete
    idx.delete("secret")
    blob = dir_blob(tmp_path)                  # tombstoned, NOT yet erased
    assert enc_bytes in blob
    idx._store.compact(idx)
    blob = dir_blob(tmp_path)
    assert enc_bytes not in blob
    assert fp32_bytes not in blob
    assert secret.tobytes() not in blob        # the WAL insert payload
    assert enc_bytes not in idx._rows.encoded.tobytes()
    assert fp32_bytes not in idx._rows.vectors.tobytes()
    assert "secret" not in blob.decode("latin1")


def test_cross_dtype_restore_rejection(tmp_path, rng):
    from repro.store import IndexStore

    data = make_corpus(40, 16, seed=12)
    idx = make_index("flat", dim=16, metric="cosine", dtype="int8",
                     store=IndexStore(str(tmp_path / "s")))
    idx.bulk_insert([f"d{i}" for i in range(40)], data)
    idx._store.snapshot(idx)
    with pytest.raises(ValueError, match="cannot restore.*transcoded"):
        make_index("flat", store=IndexStore(str(tmp_path / "s")),
                   dim=16, dtype="fp32")
    with pytest.raises(ValueError, match="cannot restore.*transcoded"):
        make_index("flat", store=IndexStore(str(tmp_path / "s")),
                   dim=16, dtype="bf16")
    # omitting dtype keeps the stored codec
    restored = make_index("flat", store=IndexStore(str(tmp_path / "s")),
                          dim=16)
    assert restored.storage_dtype == "int8"
    # a mismatched restore_state (e.g. hand-fed arrays) also fails loudly
    arrays, meta = idx.state_dict()
    fresh = make_index("flat", dim=16, metric="cosine", dtype="fp32")
    with pytest.raises(ValueError, match="encoded rows"):
        fresh.restore_state(arrays, meta)


# ---------------------------------------------------------------------------
# serving + tiers stay codec-transparent
# ---------------------------------------------------------------------------
def test_engine_epoch_invalidation_is_codec_transparent(rng):
    from repro.serve.retrieval import RetrievalEngine

    data = make_corpus(80, 16, seed=13)
    idx = make_index("flat", dim=16, metric="cosine", dtype="int8")
    idx.bulk_insert([f"d{i}" for i in range(80)], data)
    eng = RetrievalEngine(idx, max_batch=8, cache_size=64)
    assert eng.index_dtype == "int8"
    q = data[3]
    r1 = eng.retrieve_one(q, k=5)
    r2 = eng.retrieve_one(q, k=5)
    assert r2.from_cache and r2.keys == r1.keys
    victim = r1.keys[0]
    idx.delete(victim)                       # privacy op bumps the epoch
    r3 = eng.retrieve_one(q, k=5)
    assert not r3.from_cache
    assert victim not in r3.keys
    assert eng.stats.invalidations == 1


def test_tiered_slow_tier_is_encoded(rng):
    from repro.core.tiered import auto_prefetch_p

    data = make_corpus(200, 32, seed=14)
    keys = [f"d{i}" for i in range(200)]
    stores = {}
    for dtype in ("fp32", "int8"):
        idx = make_index("tiered", metric="cosine", M=8,
                         ef_construction=40, cache_rows=64, dtype=dtype)
        idx.bulk_insert(keys, data)
        idx.query(data[0], 5)
        g, store = idx._tiers()
        stores[dtype] = store
        assert idx.stats.transactions > 0    # accounting still runs
    assert (stores["fp32"].slow_tier_bytes
            / stores["int8"].slow_tier_bytes) >= 3.5
    # bytes-budgeted prefetch: an int8 slow tier prefetches ~4x more
    # rows per transaction (the paper's bytes-per-transaction economics)
    assert stores["int8"].p == auto_prefetch_p(32, 1)
    assert stores["int8"].p == 4 * stores["fp32"].p


def test_hnsw_incremental_sync_matches_full_rebuild_int8(rng):
    """Mutating after the first query drives the codec variant of the
    dirty-row scatter; its resident graph must equal a from-scratch
    conversion of the same host state."""
    data = make_corpus(100, 16, seed=15)
    idx = make_index("hnsw", metric="cosine", M=8, ef_construction=40,
                     dtype="int8")
    idx.bulk_insert([f"d{i}" for i in range(100)], data)
    q = make_corpus(3, 16, seed=16)
    idx.query_batch(q, 5)                    # resident device graph
    idx.insert("new", make_corpus(1, 16, seed=17)[0])
    idx.delete("d3")
    k_inc, d_inc = idx.query_batch(q, 5)     # incremental scatter path
    dg = idx._device_graph
    idx._device_graph = None                 # force the full conversion
    k_full, d_full = idx.query_batch(q, 5)
    assert k_inc == k_full
    assert (np.asarray(d_inc) == np.asarray(d_full)).all()
    assert (np.asarray(dg.vectors) ==
            np.asarray(idx._device_graph.vectors)).all()
    assert (np.asarray(dg.scales) ==
            np.asarray(idx._device_graph.scales)).all()


# ---------------------------------------------------------------------------
# sharded codec paths (multi-device mesh via subprocess)
# ---------------------------------------------------------------------------
def test_sharded_codec_parity_bitforbit():
    """8-shard vs 1-shard int8/bf16 flat+ivf: same keys, same distances
    (the rerank re-scores both against the same canonical host rows),
    BIT-identical state_dict — and the hnsw exact phase stays
    shard-count independent under int8."""
    run_sub("""
        import numpy as np
        from repro.core import make_index
        from repro.data.synthetic import make_corpus
        data = make_corpus(300, 32, seed=0)
        extra = make_corpus(8, 32, seed=1)
        q = make_corpus(6, 32, seed=2)
        def mutate(idx):
            idx.bulk_insert([f"d{i}" for i in range(len(data))], data)
            for j in range(4):
                idx.insert(f"x{j}", extra[j])
            idx.update("d5", extra[4])
            idx.delete("d7"); idx.delete("x0")
        for dt in ("int8", "bf16"):
            for kind, cfg in (("flat", {"dim": 32}),
                              ("ivf", {"dim": 32, "nlist": 16,
                                       "nprobe": 4})):
                i1 = make_index(kind, metric="cosine", n_shards=1,
                                dtype=dt, **cfg)
                i8 = make_index(kind, metric="cosine", n_shards=8,
                                dtype=dt, **cfg)
                mutate(i1); mutate(i8)
                k1, d1 = i1.query_batch(q, 10)
                k8, d8 = i8.query_batch(q, 10)
                assert k1 == k8, (kind, dt)
                np.testing.assert_allclose(np.asarray(d1), np.asarray(d8),
                                           rtol=1e-6, atol=0)
                a1, m1 = i1.state_dict(); a8, m8 = i8.state_dict()
                assert m1 == m8 and set(a1) == set(a8)
                for name in a1:
                    assert (np.asarray(a1[name])
                            == np.asarray(a8[name])).all(), (kind, dt, name)
                assert i1.mutation_epoch == i8.mutation_epoch
        h1 = make_index("hnsw", metric="cosine", M=8, ef_construction=40,
                        n_shards=1, dtype="int8")
        h8 = make_index("hnsw", metric="cosine", M=8, ef_construction=40,
                        n_shards=8, dtype="int8")
        mutate(h1); mutate(h8)
        ek1, ed1 = h1.exact_query(q, 10)
        ek8, ed8 = h8.exact_query(q, 10)
        assert ek1 == ek8
        np.testing.assert_allclose(np.asarray(ed1), np.asarray(ed8),
                                   rtol=1e-5, atol=1e-6)
        print("OK")
    """)


def test_sharded_codec_store_and_secure_delete():
    """int8 8-shard: warm restore bit-for-bit, 8->1 reshard-on-restore,
    and the secure-delete byte-absence of encoded bytes, sharded."""
    run_sub("""
        import numpy as np, os, tempfile
        from repro.core import make_index
        from repro.data.synthetic import make_corpus
        from repro.store import IndexStore
        def dir_blob(root):
            blob = b""
            for dp, _, fns in os.walk(root):
                for fn in sorted(fns):
                    blob += open(os.path.join(dp, fn), "rb").read()
            return blob
        data = make_corpus(200, 32, seed=0)
        q = make_corpus(4, 32, seed=2)
        root = tempfile.mkdtemp()
        idx = make_index("flat", dim=32, metric="cosine", n_shards=8,
                         dtype="int8", store=IndexStore(os.path.join(root, "s")))
        idx.bulk_insert([f"d{i}" for i in range(200)], data)
        secret = (make_corpus(1, 32, seed=9)[0] * 7.7).astype(np.float32)
        idx.insert("secret", secret)
        idx._store.snapshot(idx)
        row = idx._rows.key2row["secret"]
        enc_bytes = idx._rows.encoded[row].tobytes()
        # same-shard warm restore: bit-for-bit
        r8 = make_index("flat", store=IndexStore(os.path.join(root, "s")))
        assert r8.shard_count == 8 and r8.storage_dtype == "int8"
        a1, m1 = idx.state_dict(); a2, m2 = r8.state_dict()
        assert m1 == m2
        for name in a1:
            assert (np.asarray(a1[name]) == np.asarray(a2[name])).all()
        # reshard on restore: 8 -> 1, same results
        r1 = make_index("flat", store=IndexStore(os.path.join(root, "s")),
                        n_shards=1)
        k8, d8 = r8.query_batch(q, 5)
        k1, d1 = r1.query_batch(q, 5)
        assert k8 == k1
        np.testing.assert_allclose(np.asarray(d8), np.asarray(d1),
                                   rtol=1e-6, atol=0)
        # sharded secure delete: encoded + fp32 bytes physically gone
        fp32_bytes = idx._rows.vectors[row].tobytes()
        idx.delete("secret")
        idx._store.compact(idx)
        blob = dir_blob(root)
        assert enc_bytes not in blob and fp32_bytes not in blob
        assert secret.tobytes() not in blob
        # hnsw int8 reshard keeps the CANONICAL encodings: the replay
        # adopts each recorded row's encoded bytes + scale instead of
        # re-quantizing (re-encode is not ulp-stable) — graphs are
        # rebuilt, row payloads are the original bytes
        h8 = make_index("hnsw", metric="cosine", M=8, ef_construction=40,
                        n_shards=8, dtype="int8",
                        store=IndexStore(os.path.join(root, "h")))
        h8.bulk_insert([f"d{i}" for i in range(120)], data[:120])
        h8._store.snapshot(h8)
        h1 = make_index("hnsw", store=IndexStore(os.path.join(root, "h")),
                        n_shards=1)
        orig = {}
        for child in h8._shards:
            for key, node in child._key2id.items():
                orig[key] = (child._enc[node].tobytes(),
                             child._scales[node])
        for key, node in h1._key2id.items():
            assert h1._enc[node].tobytes() == orig[key][0], key
            assert h1._scales[node] == orig[key][1], key
        print("OK")
    """)
