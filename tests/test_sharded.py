"""Mesh-sharded VectorIndex conformance (DESIGN.md §8): shard parity,
sharded durability (reshard-on-restore + secure delete), and the serving
layer's epoch invalidation under shard-routed mutations.

Sharded paths need a multi-device mesh, so every test spawns a
subprocess that sets the fake-device XLA flag BEFORE importing jax (the
main pytest process must keep 1 CPU device — see conftest.py). Each
subprocess builds BOTH the 8-shard and the 1-shard index and compares.

Parity contract asserted here (and what it deliberately does not say):
  * flat / ivf — fully sharded: ``query_batch`` returns the same keys in
    the same order at any shard count, distances to <= 1 ulp (the CPU
    dot kernel may differ in summation order at tiny batch shapes), and
    ``state_dict`` is BIT-identical (canonical arrays, derived
    placement);
  * hnsw / tiered — per-shard graphs (a navigable small-world graph
    cannot be row-partitioned without changing results): the exact/flat
    phase is shard-count independent, the canonical key set / order /
    epoch match, and ANN recall vs the exact oracle holds at both shard
    counts. The per-shard graphs themselves legitimately differ.
"""
import os
import subprocess
import sys
import textwrap

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_sub(code: str, devices: int = 8, prelude: str = "") -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run(
        [sys.executable, "-c", prelude + textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=480)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


# the shared CRUD sequence: bulk insert + singles + updates + deletes,
# exercising every mutator the WAL knows
MUTATE = """
def mutate(idx, data, extra):
    idx.bulk_insert([f"d{i}" for i in range(len(data))], data)
    for j in range(4):
        idx.insert(f"x{j}", extra[j])
    idx.update("d5", extra[4])
    idx.update("x1", extra[5])
    idx.delete("d7"); idx.delete("x0"); idx.delete("d63")
"""


def test_flat_ivf_shard_parity_bitforbit():
    """8-shard vs 1-shard after the same mutation sequence: same keys,
    <=1-ulp distances, BIT-identical state_dict (epoch included)."""
    out = run_sub(prelude=MUTATE, code="""
        import numpy as np
        from repro.core import make_index
        from repro.data.synthetic import make_corpus
        data = make_corpus(300, 32, seed=0)
        extra = make_corpus(8, 32, seed=1)
        q = make_corpus(6, 32, seed=2)
        for kind, cfg in (("flat", {}), ("ivf", {"nlist": 16, "nprobe": 4})):
            i1 = make_index(kind, dim=32, metric="cosine", n_shards=1, **cfg)
            i8 = make_index(kind, dim=32, metric="cosine", n_shards=8, **cfg)
            mutate(i1, data, extra); mutate(i8, data, extra)
            k1, d1 = i1.query_batch(q, 10)
            k8, d8 = i8.query_batch(q, 10)
            assert k1 == k8, (kind, "keys diverge")
            np.testing.assert_allclose(np.asarray(d1), np.asarray(d8),
                                       rtol=1e-6, atol=0)
            # exact phase: nprobe=nlist / full scan, same contract
            ek1, ed1 = i1.exact_query(q, 12)
            ek8, ed8 = i8.exact_query(q, 12)
            assert ek1 == ek8
            np.testing.assert_allclose(np.asarray(ed1), np.asarray(ed8),
                                       rtol=1e-6, atol=1e-7)
            # k > live: None-padding identical
            kk1, _ = i1.query_batch(q[:1], 400)
            kk8, _ = i8.query_batch(q[:1], 400)
            assert kk1 == kk8
            # canonical state: BIT-identical at any shard count
            a1, m1 = i1.state_dict(); a8, m8 = i8.state_dict()
            assert m1 == m8, (kind, "meta diverges")
            assert set(a1) == set(a8)
            for name in a1:
                assert a1[name].dtype == a8[name].dtype
                assert a1[name].tobytes() == a8[name].tobytes(), (kind, name)
            assert i1.mutation_epoch == i8.mutation_epoch
        print("OK")
    """)
    assert "OK" in out


def test_hnsw_tiered_shard_parity():
    """Per-shard-graph backends: exact phase + canonical key set / order /
    epoch are shard-count independent; ANN recall holds at both counts."""
    out = run_sub(prelude=MUTATE, code="""
        import numpy as np
        from repro.core import make_index
        from repro.data.synthetic import make_corpus
        data = make_corpus(200, 16, seed=0)
        extra = make_corpus(8, 16, seed=1)
        q = make_corpus(5, 16, seed=2)
        for kind in ("hnsw", "tiered"):
            i1 = make_index(kind, metric="cosine", M=8, ef_construction=60,
                            ef_search=48, n_shards=1)
            i8 = make_index(kind, metric="cosine", M=8, ef_construction=60,
                            ef_search=48, n_shards=8)
            mutate(i1, data, extra); mutate(i8, data, extra)
            assert i1.size == i8.size == 201
            assert i1.keys() == i8.keys()          # canonical order (seq)
            assert i1.mutation_epoch == i8.mutation_epoch
            ek1, ed1 = i1.exact_query(q, 10)
            ek8, ed8 = i8.exact_query(q, 10)
            assert ek1 == ek8, (kind, "exact phase diverges across shards")
            np.testing.assert_allclose(np.asarray(ed1), np.asarray(ed8),
                                       rtol=1e-6, atol=1e-7)
            for idx in (i1, i8):
                hits = tot = 0
                kq, _ = idx.query_batch(q, 5)
                for b in range(len(q)):
                    ex, _ = idx.exact_query(q[b], 5)
                    hits += len({x for x in kq[b] if x} & set(ex))
                    tot += 5
                assert hits / tot >= 0.8, (kind, idx.shard_count, hits / tot)
            # deleted keys are gone from every shard's results
            kq, _ = i8.query_batch(data[7][None], 10)
            assert "d7" not in kq[0]
            # epoch parity survives compact() too (empty shards must not
            # add spurious bumps; the outer delta is one per live row)
            i1.compact(); i8.compact()
            assert i1.mutation_epoch == i8.mutation_epoch
            assert i1.keys() == i8.keys()
        print("OK")
    """)
    assert "OK" in out


def test_sharded_state_roundtrip_same_count():
    """S=8 state_dict -> restore_state on a fresh S=8 instance reproduces
    queries exactly (per-shard graphs ride the namespaced sub-states)."""
    out = run_sub(prelude=MUTATE, code="""
        import numpy as np
        from repro.core import make_index
        from repro.data.synthetic import make_corpus
        data = make_corpus(150, 16, seed=0)
        extra = make_corpus(8, 16, seed=1)
        q = make_corpus(4, 16, seed=2)
        for kind in ("flat", "ivf", "hnsw", "tiered"):
            idx = make_index(kind, dim=16, metric="cosine", M=8,
                             ef_construction=60, n_shards=8)
            mutate(idx, data, extra)
            idx.query_batch(q, 5)                  # train/pack derived state
            a, m = idx.state_dict()
            idx2 = make_index(kind, dim=16, metric="cosine", M=8,
                              ef_construction=60, n_shards=8)
            idx2.restore_state(a, m)
            k1, d1 = idx.query_batch(q, 5)
            k2, d2 = idx2.query_batch(q, 5)
            assert k1 == k2, kind
            np.testing.assert_array_equal(np.asarray(d1), np.asarray(d2))
            assert idx2.mutation_epoch == idx.mutation_epoch
            assert idx2.keys() == idx.keys()
        print("OK")
    """)
    assert "OK" in out


def test_sharded_durability_reshard_restore():
    """Snapshot at 8 shards -> restore at 1, and 1 -> 8 (store-level,
    snapshot + WAL replay): query parity across the reshard."""
    out = run_sub(prelude=MUTATE, code="""
        import numpy as np, tempfile, os
        from repro.core import make_index
        from repro.data.synthetic import make_corpus
        from repro.store import IndexStore
        data = make_corpus(120, 16, seed=0)
        extra = make_corpus(8, 16, seed=1)
        q = make_corpus(4, 16, seed=2)
        for kind in ("flat", "ivf", "hnsw"):
            with tempfile.TemporaryDirectory() as td:
                s8 = IndexStore(os.path.join(td, "s8"))
                i8 = make_index(kind, dim=16, metric="cosine", M=8,
                                ef_construction=60, n_shards=8, store=s8)
                mutate(i8, data, extra)
                i8.query_batch(q, 5)               # IVF trains centroids
                s8.snapshot(i8)
                i8.insert("late", extra[6])        # rides the WAL only
                # 8 -> 1: explicit override reshards on restore
                r1 = make_index(kind, dim=16, metric="cosine", M=8,
                                ef_construction=60, n_shards=1,
                                store=IndexStore(os.path.join(td, "s8")))
                assert r1.shard_count == 1
                assert r1.size == i8.size and "late" in r1
                assert r1.mutation_epoch == i8.mutation_epoch
                assert r1.keys() == i8.keys()
                ek8, ed8 = i8.exact_query(q, 8)
                ek1, ed1 = r1.exact_query(q, 8)
                assert ek8 == ek1, kind
                np.testing.assert_allclose(np.asarray(ed8), np.asarray(ed1),
                                           rtol=1e-6, atol=1e-7)
                if kind in ("flat", "ivf"):        # fully sharded: ANN too
                    k8, _ = i8.query_batch(q, 5)
                    k1, _ = r1.query_batch(q, 5)
                    assert k8 == k1
            with tempfile.TemporaryDirectory() as td:
                s1 = IndexStore(os.path.join(td, "s1"))
                i1 = make_index(kind, dim=16, metric="cosine", M=8,
                                ef_construction=60, n_shards=1, store=s1)
                mutate(i1, data, extra)
                i1.query_batch(q, 5)
                s1.snapshot(i1)
                # 1 -> 8
                r8 = make_index(kind, dim=16, metric="cosine", M=8,
                                ef_construction=60, n_shards=8,
                                store=IndexStore(os.path.join(td, "s1")))
                assert r8.shard_count == 8
                assert r8.size == i1.size
                assert r8.keys() == i1.keys()
                ek1, _ = i1.exact_query(q, 8)
                ek8, _ = r8.exact_query(q, 8)
                assert ek1 == ek8, kind
        # bulk-build epoch parity across the reshard: the 1-shard
        # use_bulk_build path bumps ONCE per batch, so WAL replay at a
        # different shard count must see the same per-record epoch deltas
        # — or the delete record after the bulk would be skipped as stale
        # and the retracted doc would resurrect
        with tempfile.TemporaryDirectory() as td:
            s1 = IndexStore(os.path.join(td, "bb"))
            i1 = make_index("hnsw", metric="cosine", M=8, ef_construction=60,
                            use_bulk_build=True, n_shards=1, store=s1)
            i1.bulk_insert([f"d{i}" for i in range(120)], data)
            i1.delete("d7")                    # WAL: bulk@0, delete@1
            r8 = make_index("hnsw", metric="cosine", M=8, ef_construction=60,
                            use_bulk_build=True, n_shards=8,
                            store=IndexStore(os.path.join(td, "bb")))
            assert r8.size == 119 and "d7" not in r8
            assert r8.mutation_epoch == i1.mutation_epoch == 2
        print("OK")
    """)
    assert "OK" in out


def test_sharded_secure_delete_compaction():
    """Secure-delete contract on a SHARDED index: after store.compact(),
    a deleted vector's bytes (and its key) appear in no file under the
    store — no per-shard page, no WAL, no manifest."""
    out = run_sub("""
        import numpy as np, tempfile, os
        from repro.core import make_index
        from repro.store import IndexStore
        rng = np.random.default_rng(0)
        data = rng.normal(size=(60, 16)).astype(np.float32)
        with tempfile.TemporaryDirectory() as td:
            store = IndexStore(td)
            idx = make_index("flat", dim=16, metric="cosine", n_shards=8,
                             store=store)
            idx.bulk_insert([f"doc-{i}" for i in range(60)], data)
            secret = np.asarray(idx.state_dict()[0]["vectors"][13],
                                np.float32).tobytes()
            idx.delete("doc-13")
            store.compact(idx)
            idx.query_batch(data[:2], 5)           # still serves after compact
            hits = []
            for root, _, files in os.walk(td):
                for f in files:
                    blob = open(os.path.join(root, f), "rb").read()
                    if secret in blob or b"doc-13" in blob:
                        hits.append(os.path.join(root, f))
            assert not hits, hits
            # live neighbours survived, in every shard
            k, _ = idx.query_batch(data[14][None], 3)
            assert k[0][0] == "doc-14"
            assert sum(s["live"] for s in idx.shard_stats()) == 59
        print("OK")
    """)
    assert "OK" in out


def test_engine_epoch_invalidation_under_shard_routed_mutations():
    """RetrievalEngine over a sharded index: a delete that lands on ONE
    shard still invalidates the whole LRU (global epoch), so a retracted
    key is never served from cache (DESIGN.md §6/§8)."""
    out = run_sub("""
        import numpy as np
        from repro.core import make_index
        from repro.data.synthetic import make_corpus
        from repro.serve.retrieval import RetrievalEngine
        data = make_corpus(100, 16, seed=0)
        idx = make_index("flat", dim=16, metric="cosine", n_shards=8)
        idx.bulk_insert([f"d{i}" for i in range(100)], data)
        eng = RetrievalEngine(idx, max_batch=16)
        assert eng.shards == 8
        q = data[7]
        r1 = eng.retrieve_one(q, k=3)
        assert r1.keys[0] == "d7" and not r1.from_cache
        r2 = eng.retrieve_one(q, k=3)
        assert r2.from_cache and eng.stats.cache_hits == 1
        idx.delete("d7")                           # routes to one shard...
        r3 = eng.retrieve_one(q, k=3)              # ...but flushes the LRU
        assert not r3.from_cache
        assert "d7" not in r3.keys
        assert eng.stats.invalidations == 1
        print("OK")
    """)
    assert "OK" in out


def test_shard_sweep_latency_and_capacity():
    """The bench_shard acceptance shape in miniature: per-shard work
    (rows per device) drops as 1/S while the key->shard routing keeps
    shards balanced; results stay exact at every S."""
    out = run_sub("""
        import numpy as np
        from repro.core import make_index
        from repro.data.synthetic import make_corpus
        data = make_corpus(4000, 16, seed=0)
        keys = [f"d{i}" for i in range(4000)]
        q = make_corpus(4, 16, seed=1)
        ref = None
        for s in (1, 2, 4, 8):
            idx = make_index("flat", dim=16, metric="cosine", n_shards=s)
            idx.bulk_insert(keys, data)
            k, _ = idx.query_batch(q, 10)
            if ref is None:
                ref = k
            assert k == ref, s                     # exact at every S
            stats = idx.shard_stats()
            assert len(stats) == s
            live = [st["live"] for st in stats]
            assert sum(live) == 4000
            assert max(live) <= (4000 // s) * 1.2  # hash keeps it balanced
        print("OK")
    """)
    assert "OK" in out


def test_stacked_fanout_matches_loop_bitwise():
    """The one-dispatch stacked fan-out (core/stacked.py) against the
    per-child Python loop it replaced, at S in {2, 3, 8} on both
    graph-backed kinds: same keys in the same order (the loop's stable
    shard-major tie order equals the stacked merge's two-key gid
    order), distances to <= 1 ulp (the capacity-padded stacked dot may
    differ from the per-child shape in summation order — the same
    allowance the flat/ivf parity contract documents above), and
    EXACTLY one device dispatch per ``query_batch`` regardless of
    shard count — the ISSUE 6 acceptance assert."""
    out = run_sub("""
        import numpy as np
        from repro.core import make_index, stacked
        from repro.data.synthetic import make_corpus
        data = make_corpus(250, 16, seed=0)
        keys = [f"d{i}" for i in range(250)]
        q = make_corpus(6, 16, seed=2)
        for kind in ("hnsw", "tiered"):
            for s in (2, 3, 8):
                idx = make_index(kind, metric="cosine", M=8,
                                 ef_construction=60, ef_search=48,
                                 n_shards=s)
                idx.bulk_insert(keys, data)
                idx.delete("d11")        # tombstones flow into the stack
                before = stacked.DISPATCH_COUNT
                kq, dq = idx.query_batch(q, 5)
                assert stacked.DISPATCH_COUNT == before + 1, (kind, s)
                kl, dl = idx._query_batch_sharded_loop(q, 5, 48)
                assert kq == kl, (kind, s)
                np.testing.assert_allclose(np.asarray(dq),
                                           np.asarray(dl),
                                           rtol=0, atol=2.5e-7)
                assert all("d11" not in row for row in kq)
                # warm path: still exactly one dispatch, nothing rebuilt
                before = stacked.DISPATCH_COUNT
                idx.query_batch(q, 5)
                assert stacked.DISPATCH_COUNT == before + 1, (kind, s)
        print("OK")
    """)
    assert "OK" in out


def test_exact_block_cache_invalidation():
    """Epoch-keyed exact-phase blocks: built once, reused with ZERO
    per-query block uploads on the steady state, and invalidated by
    every mutation class (delete / insert / compact) — a stale cache
    must never serve a retracted row. Also pins the compiled-fn cache:
    churning epochs must not grow ``_fanout_topk_fn``'s lru_cache."""
    out = run_sub("""
        import numpy as np
        from repro.core import make_index, sharded
        from repro.data.synthetic import make_corpus
        data = make_corpus(120, 16, seed=0)
        idx = make_index("hnsw", metric="cosine", M=8, ef_construction=60,
                         ef_search=48, n_shards=4)
        idx.bulk_insert([f"d{i}" for i in range(120)], data)
        q = data[7][None] + 0.001
        p0 = sharded.PLACE_COUNT
        ek, _ = idx.exact_query(q, 5)
        assert ek[0][0] == "d7"
        assert sharded.PLACE_COUNT == p0 + 1       # one build, one upload
        for _ in range(5):                          # steady state...
            idx.exact_query(q, 5)
            idx.query_batch(q, 5)
        assert sharded.PLACE_COUNT == p0 + 1        # ...zero re-uploads
        idx.delete("d7")
        ek2, _ = idx.exact_query(q, 5)
        assert "d7" not in ek2[0], "stale block cache served retracted row"
        assert sharded.PLACE_COUNT == p0 + 2        # delete rebuilt blocks
        idx.insert("z0", data[7])
        ek3, _ = idx.exact_query(q, 5)
        assert ek3[0][0] == "z0"                    # insert visible at once
        idx.compact()
        ek4, _ = idx.exact_query(q, 5)
        assert ek4[0][0] == "z0" and "d7" not in ek4[0]
        info = sharded._fanout_topk_fn.cache_info()
        assert info.currsize <= 8, info             # no churn across epochs
        print("OK")
    """)
    assert "OK" in out


def test_quantize_slack_bounded():
    """The compiled fan-out's cache key quantizes the dead-slot bound to
    a power of two: O(log R) distinct values over any corpus growth, and
    never below the true bound (under-fetch would drop candidates)."""
    from repro.core.sharded import _quantize_slack
    assert _quantize_slack(0) == 0
    assert all(_quantize_slack(r) >= r for r in range(5000))
    assert len({_quantize_slack(r) for r in range(5000)}) <= 15
