"""Multi-tenant IndexPool isolation contract (DESIGN.md §10).

Isolation is a *tested property*, not a convention. Asserted here:

  * parity — a pooled tenant behaves exactly like a dedicated flat
    index: same keys, same distance bits, same epoch schedule, and the
    canonical per-tenant state (``tenant_rows``) is bit-identical to the
    dedicated index's ``state_dict``;
  * byte-absence, per tenant — after one tenant's retract + compact,
    the deleted vectors' bytes (raw fp32, normalized fp32, AND codec-
    encoded) appear in no arena host array, no packed device block, no
    snapshot page, and no WAL — while the *other* tenants sharing the
    arena are untouched (epochs do not move, caches stay valid);
  * evict → restore round-trips are bit-for-bit vs a never-evicted
    oracle (LRU paging is invisible to correctness);
  * slab reuse never leaks — a slab freed by tenant A's eviction and
    re-admitted to tenant B exposes none of A's rows or bytes, even
    before any compaction;
  * a randomized interleaved workload over ~20 tenants matches a
    per-tenant single-index oracle in results, epochs, and store bytes.

Sharded (S=8) variants run in subprocesses that set the fake-device XLA
flag before importing jax (same idiom as test_sharded.py).
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from _hypothesis_compat import HealthCheck, given, settings, st
from repro.core import IndexPool, make_index
from repro.core.hnsw_build import normalize_rows
from repro.data.synthetic import make_corpus
from repro.serve.retrieval import RetrievalEngine

DIM = 16
CODECS = ("fp32", "bf16", "int8")
DATA = make_corpus(40, DIM, seed=0)
EXTRA = make_corpus(16, DIM, seed=1)
SECRET = make_corpus(8, DIM, seed=7)

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_sub(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=480)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def walk_bytes(root):
    for dp, _, fns in os.walk(root):
        for fn in fns:
            p = os.path.join(dp, fn)
            with open(p, "rb") as f:
                yield p, f.read()


def oracle_for(codec, store=None, n_shards=1):
    return make_index("flat", store=store, dim=DIM, metric="cosine",
                      dtype=codec, n_shards=n_shards)


def assert_tenant_bit_for_bit(pool, tid, oracle):
    """The pooled tenant's canonical state must be what the dedicated
    index would persist: same keys (insertion order, tombstones
    included), same array bytes, same epoch — and identical queries."""
    pool.admit(tid)
    keys, vecs, alive, enc, scales = pool._arena.tenant_rows(tid)
    oa, om = oracle.state_dict()
    assert keys == om["keys"]
    assert pool.epoch(tid) == oracle.mutation_epoch == om["epoch"]
    assert alive.tobytes() == np.asarray(oa["alive"]).tobytes()
    if "vectors" in oa:
        assert vecs.tobytes() == np.asarray(oa["vectors"]).tobytes()
    else:
        dec = oracle._codec.from_storage(np.asarray(oa["vectors_enc"]))
        assert enc.tobytes() == np.asarray(dec).tobytes()
        if scales is not None:
            assert scales.tobytes() == np.asarray(oa["scales"]).tobytes()
    if oracle.size == 0:                    # everything retracted: both
        assert pool.size(tid) == 0          # sides refuse queries alike
        return
    q = DATA[:5]
    pk, pd = pool.query_batch(tid, q, k=6)
    ok, od = oracle.query_batch(q, k=6)
    assert pk == ok
    assert np.asarray(pd).tobytes() == np.asarray(od).tobytes()


def device_haystacks(pool):
    """Every device-visible buffer the arena publishes: packed blocks,
    gid maps, codec scale tables."""
    _, blocks, gids, scales = pool._arena.pack_arena()
    bufs = []
    for b in (blocks if isinstance(blocks, (list, tuple)) else [blocks]):
        bufs.append(np.asarray(b).tobytes())
    bufs.append(np.asarray(gids).tobytes())
    if scales is not None:
        bufs.append(np.asarray(scales).tobytes())
    return bufs


def secret_needles(vecs, enc=None):
    """Byte patterns that must vanish: raw fp32 rows, the normalized
    rows the fp32 pack publishes, and the codec-encoded rows."""
    needles = {}
    for i, v in enumerate(np.asarray(vecs, np.float32)):
        needles[f"fp32[{i}]"] = np.ascontiguousarray(v).tobytes()
        needles[f"norm[{i}]"] = np.ascontiguousarray(
            normalize_rows(v[None])[0]).tobytes()
        if enc is not None:
            needles[f"enc[{i}]"] = np.ascontiguousarray(enc[i]).tobytes()
    return needles


def assert_absent_everywhere(pool, needles, root=None):
    arena = pool._arena
    hay = {"arena._vecs": arena._vecs.tobytes()}
    if arena._enc is not None:
        hay["arena._enc"] = arena._enc.tobytes()
    if arena._scales is not None:
        hay["arena._scales"] = arena._scales.tobytes()
    for i, b in enumerate(device_haystacks(pool)):
        hay[f"device[{i}]"] = b
    if root is not None:
        for p, b in walk_bytes(root):
            hay[p] = b
    for nname, needle in needles.items():
        for hname, h in hay.items():
            assert needle not in h, f"{nname} found in {hname}"


# ---------------------------------------------------------------------------
# parity: a pooled tenant == a dedicated flat index
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("codec", CODECS)
def test_pool_matches_dedicated_index(codec):
    pool = IndexPool(dim=DIM, dtype=codec, slab_rows=8)
    oracles = {t: oracle_for(codec) for t in ("a", "b", "c")}
    for j, (tid, orc) in enumerate(oracles.items()):
        ks = [f"{tid}{i}" for i in range(10)]
        vs = DATA[j * 10:(j + 1) * 10]
        pool.bulk_insert(tid, ks, vs)
        orc.bulk_insert(ks, vs)
    # interleaved singles: the arena slabs interleave across tenants
    for j, (tid, orc) in enumerate(oracles.items()):
        pool.insert(tid, "solo", EXTRA[j])
        orc.insert("solo", EXTRA[j])
        pool.update(tid, f"{tid}3", EXTRA[j + 4])
        orc.update(f"{tid}3", EXTRA[j + 4])
        pool.delete(tid, f"{tid}7")
        orc.delete(f"{tid}7")
    for tid, orc in oracles.items():
        assert_tenant_bit_for_bit(pool, tid, orc)
        assert pool.size(tid) == orc.size
        assert pool.keys(tid) == orc.keys()
    # unknown tenants / bad ids are rejected, not silently created
    with pytest.raises(KeyError):
        pool.epoch("nobody")
    with pytest.raises(ValueError, match="tenant id"):
        pool.insert("with\x1fsep", "k", DATA[0])


@pytest.mark.parametrize("codec", CODECS)
def test_cross_tenant_batch_matches_per_tenant_queries(codec):
    """query_batch_multi (one serving dispatch, rows from different
    tenants) returns exactly what per-tenant dispatches return."""
    pool = IndexPool(dim=DIM, dtype=codec, slab_rows=8)
    pool.bulk_insert("a", [f"a{i}" for i in range(12)], DATA[:12])
    pool.bulk_insert("b", [f"b{i}" for i in range(6)], DATA[12:18])
    pool.bulk_insert("c", [f"c{i}" for i in range(3)], DATA[18:21])
    q = DATA[:6] + 0.03 * EXTRA[:6]
    tenants = ["a", "b", "a", "c", "b", "a"]
    mk, md = pool.query_batch_multi(q, tenants, k=3)
    for i, tid in enumerate(tenants):
        sk, sd = pool.query_batch(tid, q[i:i + 1], k=3)
        assert mk[i] == sk[0], (codec, i)
        np.testing.assert_allclose(np.asarray(md)[i], np.asarray(sd)[0],
                                   rtol=1e-5, atol=1e-5)
        # every returned key belongs to the right namespace
        assert all(key.startswith(tid) for key in mk[i] if key)


# ---------------------------------------------------------------------------
# per-tenant epochs: one tenant's mutation never invalidates another
# ---------------------------------------------------------------------------
def test_per_tenant_epoch_independence():
    pool = IndexPool(dim=DIM)
    pool.bulk_insert("a", [f"a{i}" for i in range(6)], DATA[:6])
    pool.bulk_insert("b", [f"b{i}" for i in range(6)], DATA[6:12])
    ea, eb = pool.epoch("a"), pool.epoch("b")
    pool.delete("a", "a3")
    assert pool.epoch("a") == ea + 1
    assert pool.epoch("b") == eb            # untouched
    pool.compact("a")
    assert pool.epoch("b") == eb


def test_other_tenant_mutation_leaves_cache_hits_intact():
    """The serving-layer face of epoch independence: tenant A's delete
    drops only A's cached entries; B's identical-bytes query is still a
    cache hit served without a device dispatch."""
    pool = IndexPool(dim=DIM)
    pool.bulk_insert("a", [f"a{i}" for i in range(6)], DATA[:6])
    pool.bulk_insert("b", [f"b{i}" for i in range(6)], DATA[6:12])
    eng = RetrievalEngine(pool, max_batch=8)
    fa = eng.retrieve_one(DATA[0], k=2, tenant="a")
    fb = eng.retrieve_one(DATA[0], k=2, tenant="b")
    assert fa.keys[0].startswith("a") and fb.keys[0].startswith("b")
    pool.delete("a", fa.keys[0])
    again_b = eng.retrieve_one(DATA[0], k=2, tenant="b")
    assert again_b.from_cache and again_b.keys == fb.keys
    again_a = eng.retrieve_one(DATA[0], k=2, tenant="a")
    assert not again_a.from_cache
    assert fa.keys[0] not in again_a.keys   # retraction wins over cache
    assert eng.stats.invalidations == 1     # ONE tenant's entries dropped


# ---------------------------------------------------------------------------
# byte-absence: per-tenant retract + compact, arena shared with others
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("codec", CODECS)
def test_cross_tenant_byte_absence_after_compact(codec, tmp_path):
    root = str(tmp_path / "pool")
    pool = IndexPool(root, dim=DIM, dtype=codec, slab_rows=8)
    pool.bulk_insert("bob", [f"b{i}" for i in range(10)], DATA[:10])
    pool.bulk_insert("alice", [f"s{i}" for i in range(8)], SECRET)
    pool.bulk_insert("carol", [f"c{i}" for i in range(10)], DATA[10:20])
    _, _, _, enc, _ = pool._arena.tenant_rows("alice")
    needles = secret_needles(SECRET, enc)
    eb, ec = pool.epoch("bob"), pool.epoch("carol")
    pool.flush()                            # secrets hit disk first
    for i in range(8):
        pool.delete("alice", f"s{i}")
    pool.compact("alice")
    assert_absent_everywhere(pool, needles, root=root)
    # the *other* tenants sharing the arena are untouched
    assert pool.epoch("bob") == eb and pool.epoch("carol") == ec
    assert pool.size("bob") == 10 and pool.size("carol") == 10
    k, _ = pool.query_batch("bob", DATA[:3], k=3)
    assert all(key.startswith("b") for row in k for key in row)
    # and alice still exists (empty), able to take new rows
    assert pool.size("alice") == 0
    pool.insert("alice", "fresh", EXTRA[0])
    assert pool.query("alice", EXTRA[0], k=1)[0] == ["fresh"]


def test_deleted_rows_never_served_even_before_compact():
    """Before compaction the bytes legitimately persist (tombstones,
    WAL) — but no query path may RETURN a tombstoned row."""
    pool = IndexPool(dim=DIM, slab_rows=8)
    pool.bulk_insert("a", [f"a{i}" for i in range(8)], DATA[:8])
    pool.delete("a", "a0")
    keys, _ = pool.query_batch("a", DATA[:1], k=8)
    assert "a0" not in keys[0]
    mk, _ = pool.query_batch_multi(DATA[:1], ["a"], k=8)
    assert "a0" not in mk[0]


# ---------------------------------------------------------------------------
# LRU paging: evict -> restore is bit-for-bit, residency is invisible
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("codec", CODECS)
def test_evict_restore_bit_for_bit(codec, tmp_path):
    pool = IndexPool(str(tmp_path / "pool"), dim=DIM, dtype=codec,
                     slab_rows=8)
    orc = oracle_for(codec)
    ks = [f"d{i}" for i in range(12)]
    pool.bulk_insert("t", ks, DATA[:12])
    orc.bulk_insert(ks, DATA[:12])
    pool.update("t", "d3", EXTRA[0])
    orc.update("d3", EXTRA[0])
    pool.delete("t", "d9")
    orc.delete("d9")
    pool.evict("t")
    assert "t" not in pool.resident_tenants()
    # churn the arena while t is paged out: its slab space is recycled
    pool.bulk_insert("noise", [f"n{i}" for i in range(16)], EXTRA)
    assert_tenant_bit_for_bit(pool, "t", orc)      # admits + compares
    # mutate after restore: epochs keep counting from where they were
    pool.insert("t", "post", EXTRA[1])
    orc.insert("post", EXTRA[1])
    assert_tenant_bit_for_bit(pool, "t", orc)
    # a second evict/restore cycle after compaction
    pool.delete("t", "d0")
    orc.delete("d0")
    pool.compact("t")
    orc.compact()
    pool.evict("t")
    assert_tenant_bit_for_bit(pool, "t", orc)


def test_multi_batch_splits_when_tenants_exceed_residency(tmp_path):
    """A cross-tenant tick touching more distinct tenants than
    max_resident must not fail: the pool splits it into sub-batches
    the LRU can page through, and results stitch back in input order."""
    pool = IndexPool(str(tmp_path / "pool"), dim=DIM, max_resident=2,
                     slab_rows=8)
    for j, tid in enumerate(("a", "b", "c", "d")):
        pool.bulk_insert(tid, [f"{tid}{i}" for i in range(4)],
                         DATA[j * 4:(j + 1) * 4])
    tenants = ["a", "b", "c", "d", "a", "c"]
    q = DATA[:6]
    mk, md = pool.query_batch_multi(q, tenants, k=2)
    assert len(mk) == 6 and np.asarray(md).shape == (6, 2)
    for i, tid in enumerate(tenants):
        sk, sd = pool.query_batch(tid, q[i:i + 1], k=2)
        assert mk[i] == sk[0], (i, tid)
        np.testing.assert_allclose(np.asarray(md)[i], np.asarray(sd)[0],
                                   rtol=1e-5, atol=1e-5)


def test_lru_admission_evicts_least_recently_used(tmp_path):
    pool = IndexPool(str(tmp_path / "pool"), dim=DIM, max_resident=2,
                     slab_rows=8)
    for j, tid in enumerate(("a", "b", "c")):
        pool.bulk_insert(tid, [f"{tid}{i}" for i in range(4)],
                         DATA[j * 4:(j + 1) * 4])
    assert pool.resident_tenants() == ["b", "c"]   # a paged out
    assert pool.stats["evictions"] == 1
    # touching a pages it back in and evicts the now-LRU b — by QUERY,
    # the paging is completely transparent
    k, _ = pool.query_batch("a", DATA[:1], k=2)
    assert k[0][0].startswith("a")
    assert pool.resident_tenants() == ["c", "a"]
    assert pool.size("b") == 4                     # b still fully intact


def test_slab_reuse_never_leaks_previous_owner(tmp_path):
    """A slab freed by tenant A's eviction and handed to tenant B must
    expose nothing of A — no keys in results (even with k far beyond
    B's size) and no bytes in any packed block — BEFORE any compaction."""
    pool = IndexPool(str(tmp_path / "pool"), dim=DIM, slab_rows=8)
    pool.bulk_insert("alice", [f"s{i}" for i in range(8)], SECRET)
    assert pool._arena._slab_owner[0][0] is not None
    pool.evict("alice")                    # slab returned to the pool
    pool.bulk_insert("bob", ["b0", "b1"], EXTRA[:2])
    # bob reuses freed capacity but the slab tail is zero-filled
    keys, dists = pool.query_batch("bob", SECRET[:4], k=8)
    for row in keys:
        assert all(key is None or key.startswith("b") for key in row)
    needles = secret_needles(SECRET)
    arena = pool._arena
    hay = {"arena._vecs": arena._vecs.tobytes()}
    for i, b in enumerate(device_haystacks(pool)):
        hay[f"device[{i}]"] = b
    for nn, needle in needles.items():
        for hn, h in hay.items():
            assert needle not in h, f"{nn} found in {hn}"
    # ...and alice was not destroyed: restore is intact (durability and
    # isolation are different axes)
    assert pool.size("alice") == 8


# ---------------------------------------------------------------------------
# sharded (S=8): same contract on a real mesh
# ---------------------------------------------------------------------------
SHARDED_CHECK = """
import numpy as np, os, tempfile
from repro.core import IndexPool, make_index
from repro.core.hnsw_build import normalize_rows

codec = {codec!r}
rng = np.random.default_rng(5)
data = rng.normal(size=(24, 16)).astype(np.float32)
sec = rng.normal(size=(8, 16)).astype(np.float32)
extra = rng.normal(size=(8, 16)).astype(np.float32)
root = tempfile.mkdtemp()

pool = IndexPool(root, dim=16, n_shards=8, dtype=codec, slab_rows=4)
oracle = make_index("flat", dim=16, metric="cosine", n_shards=8,
                    dtype=codec)
ks = [f"a{{i}}" for i in range(24)]
pool.bulk_insert("alice", ks, data)
oracle.bulk_insert(ks, data)
pool.bulk_insert("bob", [f"s{{i}}" for i in range(8)], sec)
pool.update("alice", "a3", extra[0]); oracle.update("a3", extra[0])
pool.delete("alice", "a9"); oracle.delete("a9")

# --- parity: keys exact, distances close, canonical state bitwise
q = data[:5] + 0.02 * extra[:5, :]
pk, pd = pool.query_batch("alice", q, k=6)
ok, od = oracle.query_batch(q, k=6)
assert pk == ok, (pk, ok)
np.testing.assert_allclose(np.asarray(pd), np.asarray(od),
                           rtol=1e-5, atol=1e-5)
keys, vecs, alive, enc, scales = pool._arena.tenant_rows("alice")
oa, om = oracle.state_dict()
assert keys == om["keys"] and pool.epoch("alice") == om["epoch"]
assert alive.tobytes() == np.asarray(oa["alive"]).tobytes()
if "vectors" in oa:
    assert vecs.tobytes() == np.asarray(oa["vectors"]).tobytes()
else:
    dec = oracle._codec.from_storage(np.asarray(oa["vectors_enc"]))
    assert enc.tobytes() == np.asarray(dec).tobytes()

# --- evict -> restore bit-for-bit under churn
before = pool._arena.tenant_rows("alice")
ep = pool.epoch("alice")
pool.evict("alice")
pool.bulk_insert("noise", [f"n{{i}}" for i in range(8)], extra)
pool.admit("alice")
after = pool._arena.tenant_rows("alice")
assert before[0] == after[0] and ep == pool.epoch("alice")
for x, y in zip(before[1:], after[1:]):
    assert (x is None) == (y is None)
    if x is not None:
        assert np.asarray(x).tobytes() == np.asarray(y).tobytes()
pk2, pd2 = pool.query_batch("alice", q, k=6)
assert pk2 == pk
assert np.asarray(pd2).tobytes() == np.asarray(pd).tobytes()

# --- per-tenant byte-absence after retract + compact, across all shards
_, _, _, senc, _ = pool._arena.tenant_rows("bob")
needles = []
for i in range(8):
    needles.append(np.ascontiguousarray(sec[i]).tobytes())
    needles.append(np.ascontiguousarray(normalize_rows(sec[i:i+1])[0])
                   .tobytes())
    if senc is not None:
        needles.append(np.ascontiguousarray(senc[i]).tobytes())
pool.flush()
for i in range(8):
    pool.delete("bob", f"s{{i}}")
pool.compact("bob")
arena = pool._arena
hay = [arena._vecs.tobytes()]
if arena._enc is not None:
    hay.append(arena._enc.tobytes())
_, blocks, gids, scl = arena.pack_arena()
for b in (blocks if isinstance(blocks, (list, tuple)) else [blocks]):
    hay.append(np.asarray(b).tobytes())
if scl is not None:
    hay.append(np.asarray(scl).tobytes())
for dp, _, fns in os.walk(root):
    for fn in fns:
        with open(os.path.join(dp, fn), "rb") as f:
            hay.append(f.read())
for n in needles:
    assert all(n not in h for h in hay)
# alice unaffected by bob's compaction
pk3, _ = pool.query_batch("alice", q, k=6)
assert pk3 == pk
print("OK")
"""


@pytest.mark.parametrize("codec", CODECS)
def test_sharded_isolation_contract(codec):
    out = run_sub(SHARDED_CHECK.format(codec=codec))
    assert "OK" in out


# ---------------------------------------------------------------------------
# randomized interleaved workload vs per-tenant single-index oracle
# ---------------------------------------------------------------------------
def _apply_workload(pool, oracles, stores, steps, rng, check_every=True):
    """Interleave insert/bulk/update/delete/query/evict/admit/compact
    across every tenant, mirroring each op on the oracle; queries and
    epochs are compared as we go."""
    tids = list(oracles)
    vecs = make_corpus(256, DIM, seed=int(rng.integers(1 << 30)))
    counters = dict.fromkeys(tids, 0)
    for _ in range(steps):
        tid = tids[int(rng.integers(len(tids)))]
        orc = oracles[tid]
        live = orc.keys()
        op = int(rng.integers(8))
        if op == 0 or not live:                        # insert
            key = f"k{counters[tid]}"
            counters[tid] += 1
            v = vecs[int(rng.integers(len(vecs)))]
            pool.insert(tid, key, v)
            orc.insert(key, v)
        elif op == 1:                                  # bulk (dups ok)
            n = int(rng.integers(1, 5))
            ks = [f"k{counters[tid] + j}" for j in range(n)]
            counters[tid] += n
            vs = vecs[rng.integers(0, len(vecs), n)]
            pool.bulk_insert(tid, ks, vs)
            orc.bulk_insert(ks, vs)
        elif op == 2:                                  # update
            key = live[int(rng.integers(len(live)))]
            v = vecs[int(rng.integers(len(vecs)))]
            pool.update(tid, key, v)
            orc.update(key, v)
        elif op == 3:                                  # delete
            key = live[int(rng.integers(len(live)))]
            pool.delete(tid, key)
            orc.delete(key)
        elif op == 4:                                  # query
            q = vecs[rng.integers(0, len(vecs), 3)]
            k = int(rng.integers(1, 6))
            pk, pd = pool.query_batch(tid, q, k=k)
            ok, od = orc.query_batch(q, k=k)
            assert pk == ok
            assert np.asarray(pd).tobytes() == np.asarray(od).tobytes()
        elif op == 5:                                  # evict (page out)
            if tid in pool.resident_tenants():
                pool.evict(tid)
                if stores is not None:
                    stores[tid].snapshot(orc)
        elif op == 6:                                  # admit (page in)
            pool.admit(tid)
        elif op == 7:                                  # compact
            pool.compact(tid)
            orc.compact()
        if check_every:
            assert pool.epoch(tid) == orc.mutation_epoch, tid


def _npz_equal(a_bytes, b_bytes):
    import io
    a, b = np.load(io.BytesIO(a_bytes)), np.load(io.BytesIO(b_bytes))
    if a.files != b.files:
        return False
    return all(a[f].dtype == b[f].dtype and a[f].shape == b[f].shape
               and a[f].tobytes() == b[f].tobytes() for f in a.files)


def assert_same_store_tree(pool_dir, oracle_dir):
    """Same file set; byte-identical except .npz pages, which are
    zip-archive nondeterministic (timestamps) and compare as parsed
    arrays."""
    pa = {os.path.relpath(p, pool_dir): b for p, b in walk_bytes(pool_dir)}
    ob = {os.path.relpath(p, oracle_dir): b
          for p, b in walk_bytes(oracle_dir)}
    assert set(pa) == set(ob), (set(pa) ^ set(ob))
    for rel in pa:
        if rel.endswith(".npz"):
            assert _npz_equal(pa[rel], ob[rel]), rel
        else:
            assert pa[rel] == ob[rel], rel


def test_randomized_workload_matches_oracle_seeded(tmp_path):
    """Seeded 20-tenant interleaved workload: every query result and
    every epoch matches a dedicated per-tenant index, and at shutdown
    every tenant's store dir holds the same bytes a dedicated store
    would (WAL, config, manifests byte-identical; pages array-equal)."""
    from repro.store import IndexStore

    rng = np.random.default_rng(12)
    tids = [f"t{i}" for i in range(20)]
    pool = IndexPool(str(tmp_path / "pool"), dim=DIM, dtype="int8",
                     slab_rows=8, max_resident=32)
    oracles, stores = {}, {}
    for tid in tids:
        stores[tid] = IndexStore(str(tmp_path / "oracle" / tid),
                                 page_bytes=4 << 20)
        oracles[tid] = oracle_for("int8", store=stores[tid])
        # seed every tenant non-empty so all ops are exercised
        ks = [f"seed{j}" for j in range(3)]
        vs = DATA[rng.integers(0, len(DATA), 3)]
        pool.bulk_insert(tid, ks, vs)
        oracles[tid].bulk_insert(ks, vs)
    _apply_workload(pool, oracles, stores, steps=200, rng=rng)
    pool.flush()
    for tid in tids:
        stores[tid].snapshot(oracles[tid])
        assert_tenant_bit_for_bit(pool, tid, oracles[tid])
        assert_same_store_tree(
            str(tmp_path / "pool" / "tenants" / tid),
            str(tmp_path / "oracle" / tid))


@settings(max_examples=10, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(seed=st.integers(0, 2**31 - 1), steps=st.integers(5, 60),
       n_tenants=st.integers(2, 8))
def test_randomized_workload_matches_oracle_hypothesis(seed, steps,
                                                       n_tenants):
    """Property form of the same contract (skips cleanly when hypothesis
    is not installed — the seeded test above always runs). No store
    root: eviction pages to host spill, the durability-free fast path."""
    rng = np.random.default_rng(seed)
    pool = IndexPool(dim=DIM, dtype="fp32", slab_rows=8)
    oracles = {}
    for i in range(n_tenants):
        tid = f"t{i}"
        oracles[tid] = oracle_for("fp32")
        pool.insert(tid, "seed", DATA[i])
        oracles[tid].insert("seed", DATA[i])
    _apply_workload(pool, oracles, None, steps=steps, rng=rng)
    for tid, orc in oracles.items():
        assert_tenant_bit_for_bit(pool, tid, orc)
