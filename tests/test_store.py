"""Durable store suite (DESIGN.md §7): snapshot + WAL replay bit-for-bit
equality per backend, crash recovery (torn WAL records, kill between
snapshot and WAL truncation, replay idempotence), secure-delete
compaction byte absence, the snapshot_every policy, warm restore through
``make_index(store=...)``, and the export/load tombstone regression."""
import os

import numpy as np
import pytest

from repro.core import INDEX_KINDS, make_index
from repro.core.hnsw_build import normalize_rows
from repro.data.synthetic import make_corpus
from repro.serve.retrieval import RetrievalEngine
from repro.store import IndexStore, WriteAheadLog
from repro.store.wal import FILE_MAGIC

KINDS = list(INDEX_KINDS)
DIM = 16
CFG = dict(dim=DIM, metric="cosine", M=8, ef_construction=40, ef_search=32)

DATA = make_corpus(60, DIM, seed=0)
EXTRA = make_corpus(12, DIM, seed=1)


def fresh(kind, td, **store_kw):
    store = IndexStore(os.path.join(td, "store"), **store_kw)
    return make_index(kind, store=store, **CFG), store


def seed_mutations(idx):
    """Phase 1: the mutation history a snapshot will cover."""
    idx.bulk_insert([f"d{i}" for i in range(60)], DATA)
    idx.insert("solo", EXTRA[0])
    idx.update("d5", EXTRA[1])
    idx.delete("d9")
    idx.delete("d40")


def tail_mutations(idx):
    """Phase 2: the WAL tail replay must reproduce."""
    for j in range(2, 8):
        idx.insert(f"e{j}", EXTRA[j])
    idx.update("e3", EXTRA[8])
    idx.insert("d5", EXTRA[9])           # upsert of an existing key
    idx.delete("d17")


def assert_bit_for_bit(a, b):
    """The acceptance assertion: identical mutation-determined host state
    (array bytes, keys, epoch, HNSW RNG state) AND identical queries."""
    assert type(a) is type(b)
    aa, am = a.state_dict()
    ba, bm = b.state_dict()
    assert set(aa) == set(ba)
    for name in aa:
        x, y = np.asarray(aa[name]), np.asarray(ba[name])
        assert x.dtype == y.dtype and x.shape == y.shape, name
        assert x.tobytes() == y.tobytes(), f"array {name!r} differs"
    assert am == bm
    assert a.mutation_epoch == b.mutation_epoch
    assert a.keys() == b.keys()
    q = DATA[:5]
    ka, da = a.query_batch(q, 6)
    kb, db = b.query_batch(q, 6)
    assert ka == kb
    assert np.asarray(da).tobytes() == np.asarray(db).tobytes()


def walk_bytes(root):
    for dp, _, fns in os.walk(root):
        for fn in fns:
            p = os.path.join(dp, fn)
            with open(p, "rb") as f:
                yield p, f.read()


# ---------------------------------------------------------------------------
# acceptance: snapshot + WAL replay == live index, bit for bit
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("kind", KINDS)
def test_snapshot_plus_wal_replay_bit_for_bit(kind, tmp_path):
    idx, store = fresh(kind, tmp_path)
    seed_mutations(idx)
    store.snapshot(idx)
    idx.query(DATA[0], k=3)              # ivf: trains + logs centroids
    tail_mutations(idx)

    restored = IndexStore(os.path.join(tmp_path, "store")).load_index()
    assert_bit_for_bit(idx, restored)


@pytest.mark.parametrize("kind", KINDS)
def test_wal_only_restore_without_any_snapshot(kind, tmp_path):
    idx, store = fresh(kind, tmp_path)
    seed_mutations(idx)
    restored = IndexStore(os.path.join(tmp_path, "store")).load_index()
    assert_bit_for_bit(idx, restored)


def test_hnsw_bulk_build_path_replays_deterministically(tmp_path):
    store = IndexStore(os.path.join(tmp_path, "store"))
    idx = make_index("hnsw", store=store, use_bulk_build=True, **CFG)
    idx.bulk_insert([f"d{i}" for i in range(60)], DATA)
    idx.delete("d7")
    restored = IndexStore(os.path.join(tmp_path, "store")).load_index()
    assert_bit_for_bit(idx, restored)


def test_restored_epoch_not_zero_and_monotonic(tmp_path):
    idx, store = fresh("flat", tmp_path)
    seed_mutations(idx)
    e = idx.mutation_epoch
    assert e > 0
    restored = IndexStore(os.path.join(tmp_path, "store")).load_index()
    assert restored.mutation_epoch == e
    restored.insert("post", EXTRA[0])
    assert restored.mutation_epoch == e + 1


# ---------------------------------------------------------------------------
# crash recovery
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("kind", ["flat", "hnsw"])
def test_kill_mid_wal_append_truncated_record(kind, tmp_path):
    """A crash mid-append leaves a torn tail record: replay must stop at
    the last intact record — i.e. restore the state just before the op
    that was being logged — and repair the file for future appends."""
    idx, store = fresh(kind, tmp_path)
    seed_mutations(idx)
    wal_path = store.wal.path
    size_before = os.path.getsize(wal_path)
    idx.insert("torn", EXTRA[2])         # the op whose record we mangle
    store.wal.close()
    with open(wal_path, "r+b") as f:     # cut mid-record: frame + 10 bytes
        f.truncate(size_before + 10)

    # reference timeline: everything except the torn op
    ref, _ = fresh(kind, tmp_path / "ref")
    seed_mutations(ref)

    restored = IndexStore(os.path.join(tmp_path, "store")).load_index()
    assert_bit_for_bit(ref, restored)
    assert "torn" not in restored
    # the log was repaired: appending + restoring again works cleanly
    restored.insert("after-crash", EXTRA[3])
    again = IndexStore(os.path.join(tmp_path, "store")).load_index()
    assert_bit_for_bit(restored, again)


def test_kill_between_snapshot_and_wal_truncation(tmp_path):
    """If the process dies after the snapshot directory is published but
    before the WAL is truncated, every WAL record is still present though
    the snapshot already covers a prefix — replay must skip the covered
    records by epoch and reapply only the genuine tail."""
    idx, store = fresh("hnsw", tmp_path)
    seed_mutations(idx)
    with open(store.wal.path, "rb") as f:
        full_wal = f.read()              # as if truncation never happened
    store.snapshot(idx)                  # publishes snapshot, resets WAL
    store.wal.close()
    with open(store.wal.path, "wb") as f:
        f.write(full_wal)                # simulate the crash ordering

    restored = IndexStore(os.path.join(tmp_path, "store")).load_index()
    assert_bit_for_bit(idx, restored)


@pytest.mark.parametrize("kind", KINDS)
def test_replay_is_idempotent(kind, tmp_path):
    """Loading twice from the same store yields identical indexes and
    never mutates the store (replay re-enters below the WAL-logging
    layer)."""
    idx, store = fresh(kind, tmp_path)
    seed_mutations(idx)
    store.snapshot(idx)
    tail_mutations(idx)
    wal_size = os.path.getsize(store.wal.path)
    r1 = IndexStore(os.path.join(tmp_path, "store")).load_index()
    r2 = IndexStore(os.path.join(tmp_path, "store")).load_index()
    # loading appends nothing (querying an ATTACHED ivf index later may,
    # legitimately: centroid training logs a derived record)
    assert os.path.getsize(store.wal.path) == wal_size
    assert_bit_for_bit(r1, r2)


def test_crashed_snapshot_tmp_dir_is_ignored_and_collected(tmp_path):
    idx, store = fresh("flat", tmp_path)
    seed_mutations(idx)
    store.snapshot(idx)
    junk = os.path.join(tmp_path, "store", "snap_999999999999.tmp")
    os.makedirs(junk)
    with open(os.path.join(junk, "vectors.00000.npz"), "wb") as f:
        f.write(b"partial garbage")
    restored = IndexStore(os.path.join(tmp_path, "store")).load_index()
    assert_bit_for_bit(idx, restored)
    restored._store.snapshot(restored)   # GC sweeps the crash debris
    assert not os.path.exists(junk)


def test_torn_first_wal_write_recovers_to_empty(tmp_path):
    store = IndexStore(os.path.join(tmp_path, "store"))
    idx = make_index("flat", store=store, **CFG)     # attach: config.json
    store.wal.close()
    with open(store.wal.path, "wb") as f:
        f.write(FILE_MAGIC[:2])          # crash during the very first write
    restored = IndexStore(os.path.join(tmp_path, "store")).load_index()
    assert restored.size == 0 and restored.mutation_epoch == 0
    restored.insert("first", EXTRA[0])   # log is usable again post-repair
    again = IndexStore(os.path.join(tmp_path, "store")).load_index()
    assert again.keys() == ["first"]


def test_wal_record_framing_roundtrip(tmp_path):
    wal = WriteAheadLog(os.path.join(tmp_path, "w.log"))
    vec = np.arange(8, dtype=np.float32)
    wal.append("insert", epoch=3, meta={"key": "k\n1"},  # newline in key
               arrays={"vec": vec})
    wal.append("delete", epoch=4, meta={"key": "k2"})
    recs = list(wal.records())
    assert [h["op"] for h, _ in recs] == ["insert", "delete"]
    assert recs[0][0]["meta"]["key"] == "k\n1"
    assert np.array_equal(recs[0][1]["vec"], vec)
    assert recs[1][0]["epoch"] == 4 and recs[1][1] == {}


# ---------------------------------------------------------------------------
# secure-delete compaction
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("kind", KINDS)
def test_secure_delete_bytes_absent(kind, tmp_path):
    """Acceptance: after compaction, a deleted vector's bytes — the raw
    WAL payload AND every normalized stored form (f32-batch and
    f64-scalar normalization differ in the last bit) — appear in no file
    under the store directory, and neither does its key."""
    v = DATA[7]
    targets = {v.tobytes(),
               normalize_rows(DATA[7:8])[0].astype(np.float32).tobytes(),
               (v / max(float(np.linalg.norm(v)), 1e-12)
                ).astype(np.float32).tobytes()}

    idx, store = fresh(kind, tmp_path, page_bytes=1024)  # force many pages
    idx.bulk_insert([f"d{i}" for i in range(60)], DATA)
    store.snapshot(idx)
    idx.insert("late", EXTRA[0])         # keeps a live record in the WAL

    # sanity: before compaction the vector's bytes ARE on disk
    assert any(t in b for t in targets for _, b in walk_bytes(store.root))

    idx.delete("d7")
    store.compact(idx)

    for path, blob in walk_bytes(store.root):
        for t in targets:
            assert t not in blob, f"bytes of d7 survive in {path}"
        assert b'"d7"' not in blob, f"key d7 survives in {path}"

    restored = IndexStore(store.root).load_index()
    assert_bit_for_bit(idx, restored)
    assert restored.size == 60           # 60 - d7 + late
    keys, _ = restored.query(DATA[8], k=5)
    assert keys[0] == "d8" and "d7" not in keys


@pytest.mark.parametrize("kind", KINDS)
def test_compact_preserves_live_set_and_bumps_epoch(kind, tmp_path):
    idx, store = fresh(kind, tmp_path)
    seed_mutations(idx)
    live_before = set(idx.keys())
    epoch_before = idx.mutation_epoch
    store.compact(idx)
    assert idx.mutation_epoch > epoch_before
    assert set(idx.keys()) == live_before
    assert idx._row_count() == idx.size  # no tombstoned rows remain
    keys, _ = idx.query(DATA[3], k=5)
    assert keys[0] == "d3"
    assert len(store.snapshots()) == 1   # exactly the compacted snapshot


def test_compact_invalidates_retrieval_cache(tmp_path):
    idx, store = fresh("flat", tmp_path)
    seed_mutations(idx)
    eng = RetrievalEngine(idx, max_batch=8)
    r1 = eng.retrieve_one(DATA[3], k=3)
    r2 = eng.retrieve_one(DATA[3], k=3)
    assert r2.from_cache and r1.keys == r2.keys
    store.compact(idx)                   # epoch bump must flush the LRU
    r3 = eng.retrieve_one(DATA[3], k=3)
    assert not r3.from_cache
    assert eng.stats.invalidations == 1


def test_failed_mutation_after_wal_append_does_not_poison_restore(tmp_path):
    """An op can raise AFTER its record landed (log-before-apply): the
    caller may catch it and keep going. Replay must reproduce that — the
    record is skipped because the deterministic impl raises identically —
    instead of bricking every future restore."""
    idx, store = fresh("flat", tmp_path)
    idx.insert("a", EXTRA[0])
    with pytest.raises(ValueError):
        idx.insert("bad", np.ones(7, np.float32))    # dim 7 != 16
    idx.insert("b", EXTRA[1])                        # app continues
    restored = IndexStore(store.root).load_index()
    assert_bit_for_bit(idx, restored)
    assert restored.keys() == ["a", "b"]


def test_public_compact_on_attached_index_stays_durable(tmp_path):
    """idx.compact() (not just IndexStore.compact) on an attached index
    must trigger the store's compaction hook: otherwise its epoch bumps
    are an unreplayable WAL gap and the deleted bytes stay on disk."""
    idx, store = fresh("flat", tmp_path)
    seed_mutations(idx)
    idx.compact()                                    # public entry point
    idx.insert("after", EXTRA[2])                    # post-compact WAL tail
    restored = IndexStore(store.root).load_index()   # no WalCorruption
    assert_bit_for_bit(idx, restored)
    assert len(store.snapshots()) == 1               # compacted snapshot only


@pytest.mark.parametrize("kind", KINDS)
def test_compact_to_empty_live_set(kind, tmp_path):
    """Compacting away the LAST document is the core secure-delete case
    and must not crash snapshotting (HNSW serializes the no-builder
    state); the emptied store restores at the right epoch and accepts
    new writes."""
    idx, store = fresh(kind, tmp_path)
    idx.insert("only", EXTRA[0])
    idx.delete("only")
    store.compact(idx)
    assert idx.size == 0 and idx.mutation_epoch > 0
    for _, blob in walk_bytes(store.root):
        assert EXTRA[0].tobytes() not in blob
    restored = IndexStore(store.root).load_index()
    assert restored.size == 0
    assert restored.mutation_epoch == idx.mutation_epoch
    restored.insert("reborn", EXTRA[1])
    again = IndexStore(store.root).load_index()
    assert again.keys() == ["reborn"]


def test_same_epoch_snapshot_keeps_derived_centroid_records(tmp_path):
    """IVF centroid training logs a derived record WITHOUT bumping the
    epoch. A second snapshot() at the same epoch must not reset the WAL,
    or the trained centroids would be lost and the restored index would
    silently diverge from the live one."""
    idx, store = fresh("ivf", tmp_path)
    idx.bulk_insert([f"d{i}" for i in range(20)], DATA[:20])
    store.snapshot(idx)                  # epoch E, has_centroids=False
    idx.query(DATA[0], k=3)              # trains + logs derived.centroids
    store.snapshot(idx)                  # same epoch E: must keep the WAL
    idx.insert("tail", EXTRA[0])
    restored = IndexStore(store.root).load_index()
    assert restored._centroids is not None
    assert_bit_for_bit(idx, restored)


# ---------------------------------------------------------------------------
# policies + factory integration
# ---------------------------------------------------------------------------
def test_snapshot_every_policy_auto_snapshots(tmp_path):
    idx, store = fresh("flat", tmp_path, snapshot_every=5)
    for j in range(12):
        idx.insert(f"a{j}", EXTRA[j % len(EXTRA)])
    snaps = store.snapshots()
    assert len(snaps) == 2               # at mutations 5 and 10, keep=2
    # only the records since the last auto-snapshot remain in the WAL
    assert sum(1 for _ in store.wal.records()) == 2
    restored = IndexStore(store.root).load_index()
    assert_bit_for_bit(idx, restored)


def test_make_index_store_cold_then_warm(tmp_path):
    sd = os.path.join(tmp_path, "s")
    idx = make_index("hnsw", store=sd, **CFG)        # cold: creates+attaches
    assert idx.size == 0 and os.path.exists(os.path.join(sd, "config.json"))
    seed_mutations(idx)
    warm = make_index("hnsw", store=sd, **CFG)       # warm: restores
    assert_bit_for_bit(idx, warm)


def test_make_index_store_kind_mismatch_raises(tmp_path):
    sd = os.path.join(tmp_path, "s")
    make_index("flat", store=sd, **CFG)
    with pytest.raises(ValueError, match="holds a 'flat'"):
        make_index("hnsw", store=sd, **CFG)


def test_retrieval_engine_adopts_restored_epoch(tmp_path):
    """Warm serve restore (DESIGN.md §6/§7): the engine must key its cache
    on the RESTORED epoch, and a post-restore delete must invalidate."""
    idx, store = fresh("hnsw", tmp_path)
    seed_mutations(idx)
    store.snapshot(idx)

    restored = IndexStore(store.root).load_index()
    assert restored.mutation_epoch > 0
    eng = RetrievalEngine(restored, max_batch=8)
    assert eng._cache_epoch == restored.mutation_epoch
    r1 = eng.retrieve_one(DATA[3], k=3)
    assert eng.retrieve_one(DATA[3], k=3).from_cache
    top = r1.keys[0]
    restored.delete(top)                 # retraction after the restart
    r3 = eng.retrieve_one(DATA[3], k=3)
    assert not r3.from_cache and top not in r3.keys


# ---------------------------------------------------------------------------
# satellite regression: export/load keeps tombstones on a MUTATED index
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("kind", KINDS)
def test_export_load_after_deletes_matches_live(kind, tmp_path):
    """export -> load -> query must match the live index exactly after a
    mutation history with deletes and updates — in particular the
    tombstone mask must round-trip on every backend."""
    idx = make_index(kind, **CFG)
    seed_mutations(idx)
    tail_mutations(idx)
    p = os.path.join(tmp_path, "idx.npz")
    idx.export(p)
    loaded = type(idx).load(p)
    assert_bit_for_bit(idx, loaded)
    for gone in ("d9", "d40", "d17"):
        assert gone not in loaded
        keys, _ = loaded.query(DATA[int(gone[1:])], k=10)
        assert gone not in keys
    exact, _ = loaded.exact_query(DATA[9], k=10)
    assert "d9" not in exact
