"""Optimizer math, training loop, checkpointing, fault tolerance."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs import get_smoke_config
from repro.data.synthetic import lm_batches
from repro.models import transformer as tf
from repro.train.checkpoint import CheckpointManager
from repro.train.fault_tolerance import (StragglerWatchdog, run_resilient)
from repro.train.optimizer import (AdamWConfig, adamw_init, adamw_update,
                                   clip_by_global_norm, warmup_cosine)
from repro.train.train_loop import fit, make_train_step


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------
def test_adamw_matches_reference_math():
    cfg = AdamWConfig(lr=1e-2, b1=0.9, b2=0.99, eps=1e-8, weight_decay=0.0,
                      grad_clip=0.0)
    p = {"w": jnp.asarray([[1.0, -2.0], [0.5, 3.0]])}
    g = {"w": jnp.asarray([[0.1, 0.2], [-0.3, 0.4]])}
    st_ = adamw_init(p)
    p2, st2, _ = adamw_update(cfg, p, g, st_)
    m = 0.1 * np.asarray(g["w"])
    v = 0.01 * np.asarray(g["w"]) ** 2
    mh = m / (1 - 0.9)
    vh = v / (1 - 0.99)
    want = np.asarray(p["w"]) - 1e-2 * mh / (np.sqrt(vh) + 1e-8)
    np.testing.assert_allclose(np.asarray(p2["w"]), want, rtol=1e-6)


@given(scale=st.floats(0.1, 100.0))
@settings(max_examples=10, deadline=None)
def test_clip_by_global_norm_property(scale):
    g = {"a": jnp.ones((4, 4)) * scale, "b": jnp.ones((2,)) * scale}
    clipped, norm = clip_by_global_norm(g, 1.0)
    from repro.utils import tree_norm
    assert float(tree_norm(clipped)) <= 1.0 + 1e-4


def test_warmup_cosine_schedule():
    s = warmup_cosine(1e-3, 10, 100)
    assert float(s(0)) == 0.0
    assert abs(float(s(10)) - 1e-3) < 1e-9
    assert float(s(100)) < float(s(50)) < float(s(10))
    assert float(s(100)) >= 1e-4 - 1e-9          # min_ratio floor


def test_weight_decay_only_on_matrices():
    cfg = AdamWConfig(lr=1e-2, weight_decay=1.0, grad_clip=0.0)
    p = {"w": jnp.ones((3, 3)), "b": jnp.ones((3,))}
    g = {"w": jnp.zeros((3, 3)), "b": jnp.zeros((3,))}
    p2, _, _ = adamw_update(cfg, p, g, adamw_init(p))
    assert float(jnp.abs(p2["w"] - 1.0).max()) > 1e-4   # decayed
    np.testing.assert_allclose(np.asarray(p2["b"]), 1.0)  # not decayed


# ---------------------------------------------------------------------------
# loop + checkpoint + fault tolerance
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def lm_setup():
    cfg = get_smoke_config("llama3-8b")
    loss_fn = lambda p, tokens, labels: tf.lm_loss(p, cfg, tokens, labels,
                                                   dtype=jnp.float32)
    step = make_train_step(loss_fn, AdamWConfig(lr=1e-3), donate=False)
    return cfg, step


def test_loss_decreases(lm_setup):
    cfg, step = lm_setup
    params = tf.init_lm(jax.random.PRNGKey(0), cfg)
    _, _, hist = fit(params, step, lm_batches(cfg.vocab, 8, 33, seed=0),
                     steps=15, log_every=0)
    assert hist[-1]["loss"] < hist[0]["loss"]


def test_checkpoint_roundtrip_and_gc():
    with tempfile.TemporaryDirectory() as td:
        ckpt = CheckpointManager(td, keep=2)
        state = {"a": jnp.arange(6).reshape(2, 3),
                 "nested": {"b": jnp.ones(4)}}
        for s in (1, 2, 3):
            ckpt.save(s, state, meta={"tag": "x"})
        assert ckpt.all_steps() == [2, 3]         # keep-last-2 GC
        got, meta = ckpt.restore(state, step=3)
        np.testing.assert_array_equal(np.asarray(got["a"]),
                                      np.asarray(state["a"]))
        assert meta["tag"] == "x" and meta["step"] == 3
        assert not [f for f in os.listdir(td) if f.endswith(".tmp.npz")]


def test_resilient_restart_is_exact(lm_setup):
    """Failures + restore must replay to the same final loss."""
    cfg, step = lm_setup

    def batch_fn(s):
        return next(lm_batches(cfg.vocab, 8, 33, seed=0, start_step=s))

    with tempfile.TemporaryDirectory() as td:
        p1 = tf.init_lm(jax.random.PRNGKey(0), cfg)
        _, _, info1 = run_resilient(p1, step, batch_fn, steps=12,
                                    ckpt=CheckpointManager(td + "/a", keep=3),
                                    ckpt_every=5, fail_at=[7])
        p2 = tf.init_lm(jax.random.PRNGKey(0), cfg)
        _, _, info2 = run_resilient(p2, step, batch_fn, steps=12,
                                    ckpt=CheckpointManager(td + "/b", keep=3),
                                    ckpt_every=5)
        assert info1["restarts"] == 1 and info2["restarts"] == 0
        assert abs(info1["losses"][11] - info2["losses"][11]) < 2e-3


def test_straggler_watchdog_flags_outliers():
    wd = StragglerWatchdog(min_samples=5, factor=3.0)
    for i in range(10):
        wd.observe(i, 0.01)
    assert wd.observe(10, 0.2) is True
    assert len(wd.events) == 1 and wd.events[0].step == 10


def test_data_pipeline_deterministic_restart():
    a = next(lm_batches(100, 4, 16, seed=7, start_step=5))
    b = next(lm_batches(100, 4, 16, seed=7, start_step=5))
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = next(lm_batches(100, 4, 16, seed=7, start_step=6))
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_microbatched_step_matches_full_batch(lm_setup):
    cfg, _ = lm_setup
    loss_fn = lambda p, tokens, labels: tf.lm_loss(p, cfg, tokens, labels,
                                                   dtype=jnp.float32)
    s1 = make_train_step(loss_fn, AdamWConfig(lr=1e-3, grad_clip=0.0),
                         microbatches=1, donate=False)
    s2 = make_train_step(loss_fn, AdamWConfig(lr=1e-3, grad_clip=0.0),
                         microbatches=2, donate=False)
    params = tf.init_lm(jax.random.PRNGKey(0), cfg)
    batch = next(lm_batches(cfg.vocab, 8, 33, seed=0))
    from repro.train.optimizer import adamw_init
    p1, _, m1 = s1(params, adamw_init(params), batch)
    p2, _, m2 = s2(params, adamw_init(params), batch)
    # microbatch-mean loss == full-batch loss (linear in batch)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 2e-3
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=3e-3, atol=3e-5)
