"""Serving engine: continuous batching correctness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import transformer as tf
from repro.serve.engine import ServeEngine


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("llama3-8b")
    params = tf.init_lm(jax.random.PRNGKey(0), cfg)
    return cfg, params


def test_continuous_batching_matches_single_slot(setup):
    cfg, params = setup
    prompts = [np.arange(5) % cfg.vocab, np.arange(11) % cfg.vocab,
               np.arange(7) % cfg.vocab, np.arange(9) % cfg.vocab,
               np.arange(4) % cfg.vocab]
    eng = ServeEngine(params, cfg, slots=3, max_len=64, dtype=jnp.float32)
    outs = eng.generate(prompts, max_new_tokens=6)
    for pi in (0, 2, 4):
        solo = ServeEngine(params, cfg, slots=1, max_len=64,
                           dtype=jnp.float32)
        assert solo.generate([prompts[pi]], max_new_tokens=6)[0] == outs[pi]


def test_queue_overflow_drains(setup):
    cfg, params = setup
    eng = ServeEngine(params, cfg, slots=2, max_len=48, dtype=jnp.float32)
    prompts = [np.arange(3 + i) % cfg.vocab for i in range(7)]
    outs = eng.generate(prompts, max_new_tokens=4)
    assert all(len(o) == 4 for o in outs)
    assert not eng.queue and all(a is None for a in eng.active)


def test_engine_rag_path_over_vector_index(setup):
    """The engine's RAG path: retrieval via any VectorIndex backend, then
    batched generation through the slot scheduler."""
    from repro.data.corpus import BUILTIN_CORPUS
    from repro.serve.rag import RAGPipeline

    cfg, params = setup
    eng = ServeEngine(params, cfg, slots=2, max_len=96, dtype=jnp.float32)
    rag = RAGPipeline(index_kind="flat")
    rag.add_documents(BUILTIN_CORPUS)
    outs = eng.generate_rag(rag, ["how does hnsw search work",
                                  "why is on device retrieval private"],
                            k=2, max_new_tokens=4)
    assert len(outs) == 2
    for out in outs:
        assert len(out["docs"]) == 2
        assert "{{context}}" not in out["prompt"]
        assert out["response"]
    assert outs[1]["docs"][0].key.startswith("priv")


def test_eos_terminates_early(setup):
    cfg, params = setup
    eng = ServeEngine(params, cfg, slots=1, max_len=64, dtype=jnp.float32)
    # find what the model emits, then use it as the EOS token
    probe = eng.generate([np.arange(6) % cfg.vocab], max_new_tokens=3)[0]
    eng2 = ServeEngine(params, cfg, slots=1, max_len=64, dtype=jnp.float32)
    r = eng2.submit(np.arange(6) % cfg.vocab, max_new_tokens=10,
                    eos_id=probe[1])
    eng2.run_until_drained()
    assert r.done and len(r.out_tokens) <= 3
