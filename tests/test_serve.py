"""Serving engine: continuous batching correctness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import transformer as tf
from repro.serve.engine import ServeEngine


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("llama3-8b")
    params = tf.init_lm(jax.random.PRNGKey(0), cfg)
    return cfg, params


def test_continuous_batching_matches_single_slot(setup):
    cfg, params = setup
    prompts = [np.arange(5) % cfg.vocab, np.arange(11) % cfg.vocab,
               np.arange(7) % cfg.vocab, np.arange(9) % cfg.vocab,
               np.arange(4) % cfg.vocab]
    eng = ServeEngine(params, cfg, slots=3, max_len=64, dtype=jnp.float32)
    outs = eng.generate(prompts, max_new_tokens=6)
    for pi in (0, 2, 4):
        solo = ServeEngine(params, cfg, slots=1, max_len=64,
                           dtype=jnp.float32)
        assert solo.generate([prompts[pi]], max_new_tokens=6)[0] == outs[pi]


def test_queue_overflow_drains(setup):
    cfg, params = setup
    eng = ServeEngine(params, cfg, slots=2, max_len=48, dtype=jnp.float32)
    prompts = [np.arange(3 + i) % cfg.vocab for i in range(7)]
    outs = eng.generate(prompts, max_new_tokens=4)
    assert all(len(o) == 4 for o in outs)
    assert not eng.queue and all(a is None for a in eng.active)


def test_engine_rag_path_over_vector_index(setup):
    """The engine's RAG path: retrieval via any VectorIndex backend, then
    batched generation through the slot scheduler."""
    from repro.data.corpus import BUILTIN_CORPUS
    from repro.serve.rag import RAGPipeline

    cfg, params = setup
    eng = ServeEngine(params, cfg, slots=2, max_len=96, dtype=jnp.float32)
    rag = RAGPipeline(index_kind="flat")
    rag.add_documents(BUILTIN_CORPUS)
    outs = eng.generate_rag(rag, ["how does hnsw search work",
                                  "why is on device retrieval private"],
                            k=2, max_new_tokens=4)
    assert len(outs) == 2
    for out in outs:
        assert len(out["docs"]) == 2
        assert "{{context}}" not in out["prompt"]
        assert out["response"]
    assert outs[1]["docs"][0].key.startswith("priv")


def test_eos_terminates_early(setup):
    cfg, params = setup
    eng = ServeEngine(params, cfg, slots=1, max_len=64, dtype=jnp.float32)
    # find what the model emits, then use it as the EOS token
    probe = eng.generate([np.arange(6) % cfg.vocab], max_new_tokens=3)[0]
    eng2 = ServeEngine(params, cfg, slots=1, max_len=64, dtype=jnp.float32)
    r = eng2.submit(np.arange(6) % cfg.vocab, max_new_tokens=10,
                    eos_id=probe[1])
    eng2.run_until_drained()
    assert r.done and len(r.out_tokens) <= 3


# ---------------------------------------------------------------------------
# overlapped RAG serving (DESIGN.md §11)
# ---------------------------------------------------------------------------
QUERIES = ["how does hnsw search work",
           "why is on device retrieval private",
           "what does the document store hold",
           "how are vectors compared",
           "when is a flat scan fine",
           "what happens on delete"]


def _fresh_rag(index_kind="flat"):
    from repro.data.corpus import BUILTIN_CORPUS
    from repro.serve.rag import RAGPipeline
    rag = RAGPipeline(index_kind=index_kind)
    rag.add_documents(BUILTIN_CORPUS)
    return rag


def _sequential_barrier(params, cfg, rag, queries, k, max_new_tokens,
                        max_len=96, tenants=None):
    """The pre-overlap oracle: retrieve EVERYTHING first (full barrier),
    then generate each prompt alone on a fresh single-slot engine."""
    from repro.data.corpus import encode_ids
    docs_b = rag.retrieve_batch(queries, k, tenants=tenants)
    rows = []
    for q, docs in zip(queries, docs_b):
        prompt = rag.build_prompt(q, docs)
        ids = encode_ids(prompt, cfg.vocab, max_len - 1)
        eng = ServeEngine(params, cfg, slots=1, max_len=max_len,
                          dtype=jnp.float32)
        toks = eng.generate([ids[ids > 0]], max_new_tokens=max_new_tokens)[0]
        rows.append({"docs": [d.key for d in docs], "tokens": toks})
    return rows


def test_overlap_matches_sequential_barrier_oracle(setup):
    """Tentpole oracle: the overlapped loop under a RANDOMIZED admission
    schedule returns bit-identical tokens and retrieved docs to the
    sequential retrieve-then-generate baseline."""
    cfg, params = setup
    rag = _fresh_rag("hnsw")
    want = _sequential_barrier(params, cfg, rag, QUERIES, k=2,
                               max_new_tokens=5)

    eng = ServeEngine(params, cfg, pipeline=_fresh_rag("hnsw"), slots=2,
                      max_len=96, dtype=jnp.float32)
    rng = np.random.default_rng(7)
    reqs, pending = [], list(QUERIES)
    while pending or eng._work_pending():
        # submit 0-2 new requests per tick: late arrivals overlap with
        # decode ticks already running for earlier ones
        for _ in range(int(rng.integers(0, 3))):
            if pending:
                reqs.append(eng.submit_rag(pending.pop(0), k=2,
                                           max_new_tokens=5))
        eng.step()
    assert all(r.done for r in reqs)
    for r, w in zip(reqs, want):
        assert [d.key for d in r.docs] == w["docs"]
        assert r.out_tokens == w["tokens"]
    # and the schedule actually exercised overlap
    assert eng.stats.overlapped_ticks > 0


def test_overlap_oracle_pool_mode_interleaved_tenants(setup):
    """Same oracle with an IndexPool pipeline and tenants interleaved
    request-by-request (per-request ``tenant`` field, no parallel lists)."""
    from repro.core import IndexPool
    from repro.data.corpus import BUILTIN_CORPUS, HashingEncoder
    from repro.serve.rag import RAGPipeline

    cfg, params = setup

    def build():
        enc = HashingEncoder()
        rag = RAGPipeline(encoder=enc, index=IndexPool(dim=enc.dim))
        rag.add_documents(BUILTIN_CORPUS[:4], tenant="alice")
        rag.add_documents(BUILTIN_CORPUS[4:], tenant="bob")
        return rag

    queries = QUERIES[:4]
    tenants = ["alice", "bob", "alice", "bob"]
    want = _sequential_barrier(params, cfg, build(), queries, k=2,
                               max_new_tokens=4, tenants=tenants)

    eng = ServeEngine(params, cfg, pipeline=build(), slots=2, max_len=96,
                      dtype=jnp.float32)
    reqs = [eng.submit_rag(q, k=2, tenant=t, max_new_tokens=4)
            for q, t in zip(queries, tenants)]
    eng.run_until_drained()
    for r, w in zip(reqs, want):
        assert [d.key for d in r.docs] == w["docs"]
        assert r.out_tokens == w["tokens"]
    # isolation sanity: every doc came from the request's own tenant shard
    a_keys = {k for k, _ in BUILTIN_CORPUS[:4]}
    for r in reqs:
        own = a_keys if r.tenant == "alice" else \
            {k for k, _ in BUILTIN_CORPUS[4:]}
        assert all(d.key in own for d in r.docs)


def test_retrieval_runs_during_decode(setup):
    """A request submitted while another is decoding has its retrieval
    pumped behind the in-flight decode dispatch: after ONE tick it is
    READY without any decode having stalled (stats.overlapped_ticks)."""
    from repro.serve.engine import ACTIVE, READY

    cfg, params = setup
    eng = ServeEngine(params, cfg, pipeline=_fresh_rag(), slots=1,
                      max_len=96, dtype=jnp.float32)
    a = eng.submit_rag(QUERIES[0], k=2, max_new_tokens=8)
    for _ in range(10):
        eng.step()
        if a.state == ACTIVE:
            break
    assert a.state == ACTIVE
    b = eng.submit_rag(QUERIES[1], k=2, max_new_tokens=8)
    eng.step()          # decode for `a` in flight; b's ANN search behind it
    assert b.state == READY
    assert eng.stats.overlapped_ticks >= 1
    eng.run_until_drained()
    assert a.done and b.done
    s = eng.stats.as_dict()
    assert s["overlap_ratio"] > 0
    assert 0 < s["slot_occupancy"] <= 1


def test_mixed_length_admission_evicts_and_reuses_slots(setup):
    """Mixed generation lengths: short requests finish, their slots park
    at cur_len=0 and are reused by queued requests; every request still
    gets exactly its own budget."""
    cfg, params = setup
    eng = ServeEngine(params, cfg, pipeline=_fresh_rag(), slots=2,
                      max_len=96, dtype=jnp.float32)
    budgets = [2, 9, 3, 7, 4, 6]
    reqs = [eng.submit_rag(q, k=2, max_new_tokens=m)
            for q, m in zip(QUERIES, budgets)]
    eng.run_until_drained()
    assert [len(r.out_tokens) for r in reqs] == budgets
    assert eng.stats.admitted == len(QUERIES) > eng.slots
    assert all(a is None for a in eng.active)
    assert eng.poll() and not eng.poll()    # finished queue drains once


def test_midstream_delete_never_reaches_later_prompts(setup):
    """Privacy under overlap: a document retracted AFTER a request's
    retrieval resolved but BEFORE its admission is re-retrieved away —
    the retracted text never appears in any later-built prompt."""
    from repro.serve.engine import READY

    cfg, params = setup
    rag = _fresh_rag()
    eng = ServeEngine(params, cfg, pipeline=rag, slots=1, max_len=96,
                      dtype=jnp.float32)
    # occupy the only slot so the victim request parks in READY
    blocker = eng.submit_rag(QUERIES[2], k=1, max_new_tokens=12)
    victim = eng.submit_rag(QUERIES[0], k=2, max_new_tokens=4)
    for _ in range(10):
        eng.step()
        if victim.state == READY:
            break
    assert victim.state == READY
    top_key = rag.retrieve(QUERIES[0], k=1)[0].key
    doomed_text = rag.store.get(top_key).text
    rag.delete_document(top_key)            # mid-stream retraction
    eng.run_until_drained()
    assert victim.done and eng.stats.re_retrievals >= 1
    assert all(d.key != top_key for d in victim.docs)
    assert doomed_text not in victim.prompt
    assert blocker.done


# ---------------------------------------------------------------------------
# sampler wiring (the old engine accepted sampler= and argmaxed regardless)
# ---------------------------------------------------------------------------
def test_greedy_sampler_output_unchanged(setup):
    """Regression: sampler="greedy" (and the default) still produce the
    exact argmax rollout the pre-sampler engine produced."""
    cfg, params = setup
    prompt = np.arange(9) % cfg.vocab
    # manual argmax reference through the model directly
    ids = jnp.asarray(prompt, jnp.int32)[None, :]
    lens = jnp.asarray([ids.shape[1]], jnp.int32)
    logits, cache = tf.prefill(params, cfg, ids, dtype=jnp.float32,
                               max_len=64, prompt_lens=lens)
    want = [int(jnp.argmax(logits[0, 0]))]
    for _ in range(5):
        tok = jnp.asarray([[want[-1]]], jnp.int32)
        logits, cache = tf.decode_step(params, cfg, tok, cache,
                                       dtype=jnp.float32)
        want.append(int(jnp.argmax(logits[0, 0])))
    for kw in ({}, {"sampler": "greedy"}, {"sampler": "greedy", "seed": 99}):
        eng = ServeEngine(params, cfg, slots=1, max_len=64,
                          dtype=jnp.float32, **kw)
        assert eng.generate([prompt], max_new_tokens=6)[0] == want


def test_unknown_sampler_rejected_loudly(setup):
    cfg, params = setup
    with pytest.raises(ValueError, match="unknown sampler"):
        ServeEngine(params, cfg, sampler="nucleus")
    with pytest.raises(ValueError, match="temperature"):
        ServeEngine(params, cfg, sampler="temperature", temperature=0.0)


def test_temperature_sampling_schedule_independent(setup):
    """Temperature draws fold (rid, position) — not slot or tick — so the
    sampled rollout is identical whatever the admission schedule, and
    changes with the seed."""
    cfg, params = setup
    prompts = [np.arange(4 + 3 * i) % cfg.vocab for i in range(4)]

    def run(slots, seed):
        eng = ServeEngine(params, cfg, slots=slots, max_len=64,
                          dtype=jnp.float32, sampler="temperature",
                          temperature=0.8, seed=seed)
        return eng.generate(prompts, max_new_tokens=6)

    assert run(1, seed=0) == run(3, seed=0)
    assert run(3, seed=0) != run(3, seed=1)
