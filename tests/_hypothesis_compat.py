"""Optional-import shim for hypothesis.

The container may not ship ``hypothesis`` (and it is not installable
offline). Property-based tests import ``given``/``settings``/``st`` from
here instead of from hypothesis directly; when the real library is absent
each ``@given`` test turns into a clean ``pytest.skip`` and the rest of the
suite collects and runs normally.
"""
from __future__ import annotations

try:
    from hypothesis import HealthCheck, given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                   # pragma: no cover - env
    import inspect

    import pytest

    HAVE_HYPOTHESIS = False

    class _Whatever:
        """Stands in for ``strategies``/``HealthCheck``: any attribute access
        or call returns another inert instance, so decorator arguments like
        ``st.integers(0, 50)`` evaluate without the real library."""

        def __getattr__(self, name):
            return _Whatever()

        def __call__(self, *args, **kwargs):
            return _Whatever()

    st = _Whatever()
    HealthCheck = _Whatever()

    def settings(*args, **kwargs):
        def deco(fn):
            return fn
        return deco

    def given(*gargs, **gkwargs):
        def deco(fn):
            # Hide the hypothesis-filled parameters from pytest's fixture
            # resolution: keyword strategies by name, positional ones from
            # the right (hypothesis' own filling order).
            sig = inspect.signature(fn)
            names = [n for n in sig.parameters if n not in gkwargs]
            if gargs:
                names = names[: len(names) - len(gargs)]
            params = [sig.parameters[n] for n in names]

            def skipper(*args, **kwargs):
                pytest.skip("hypothesis not installed")

            skipper.__signature__ = inspect.Signature(params)
            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            return skipper
        return deco
