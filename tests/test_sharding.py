"""Logical-axis sharding rules: divisibility dropping, axis dedup."""
import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import axis_rules, spec_for

pytestmark = pytest.mark.skipif(len(jax.devices()) != 1,
                                reason="expects the single-CPU test env")


def test_spec_divisibility_drop():
    mesh = jax.make_mesh((1,), ("model",))
    with axis_rules(mesh):
        # 7 not divisible by 1? 1 divides everything; use a fake via rules
        assert spec_for((8, 16), ("vocab", "embed")) == P("model", None)


def test_spec_drops_non_dividing_axis():
    # single-device mesh can't express >1 splits; emulate by axis size 1
    mesh = jax.make_mesh((1,), ("model",))
    with axis_rules(mesh, {"vocab": "model"}):
        spec = spec_for((7, 3), ("vocab", None))
        assert spec == P("model", None)      # size-1 axis divides anything


def test_axis_used_once():
    mesh = jax.make_mesh((1,), ("model",))
    with axis_rules(mesh, {"a": "model", "b": "model"}):
        spec = spec_for((4, 4), ("a", "b"))
        assert spec == P("model", None)      # first dim wins, no reuse


def test_rules_override_and_restore():
    mesh = jax.make_mesh((1,), ("model",))
    with axis_rules(mesh, {"embed": "model"}):
        assert spec_for((4,), ("embed",)) == P("model")
    with axis_rules(mesh):
        assert spec_for((4,), ("embed",)) == P(None)


def test_no_mesh_is_noop():
    with axis_rules(None):
        assert spec_for((4, 4), ("vocab", "embed")) == P()
