"""VectorIndex protocol conformance (DESIGN.md §1) across all four
backends, mutation semantics (tombstones, update, export round-trip), and
the HNSW incremental device-graph sync parity (DESIGN.md §3)."""
import os
import tempfile

import numpy as np
import pytest

from repro.core import INDEX_KINDS, make_index, make_index_from_config
from repro.core import hnsw as jhnsw
from repro.data.synthetic import make_corpus

KINDS = list(INDEX_KINDS)


def build(kind, dim=16, n=150, seed=0):
    data = make_corpus(n, dim, seed=seed)
    idx = make_index(kind, dim=dim, metric="cosine", M=8,
                     ef_construction=60, ef_search=48)
    idx.bulk_insert([f"d{i}" for i in range(n)], data)
    return idx, data


# ---------------------------------------------------------------------------
# shared conformance: insert / update / delete / query / export / load
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("kind", KINDS)
def test_conformance_insert_query(kind):
    idx, data = build(kind)
    assert idx.size == 150 and len(idx) == 150
    keys, dists = idx.query(data[7], k=5)
    assert keys[0] == "d7" and float(dists[0]) < 1e-4
    assert len(keys) == len(dists)
    # single-key insert is an upsert path shared by every backend
    idx.insert("extra", data[7] + 0.001)
    assert idx.size == 151 and "extra" in idx
    # batched queries return lists of lists
    bk, bd = idx.query(data[:3], k=4)
    assert len(bk) == 3 and bk[1][0] == "d1"


@pytest.mark.parametrize("kind", KINDS)
def test_conformance_delete_excludes_tombstoned(kind):
    idx, data = build(kind)
    before, _ = idx.query(data[7], k=5)
    assert before[0] == "d7"
    idx.delete("d7")
    after, _ = idx.query(data[7], k=5)
    assert "d7" not in after
    assert idx.size == 149 and "d7" not in idx.keys()
    with pytest.raises(KeyError):
        idx.delete("d7")                    # double delete is an error
    exact, _ = idx.exact_query(data[7], k=5)
    assert "d7" not in exact                # the oracle honors tombstones too


@pytest.mark.parametrize("kind", KINDS)
def test_conformance_update_changes_neighbor(kind):
    idx, data = build(kind)
    probe = make_corpus(1, 16, seed=99)[0]
    winner, _ = idx.query(probe, k=1)
    # move a different key exactly onto the probe: it must take over top-1
    mover = "d33" if winner[0] != "d33" else "d44"
    idx.update(mover, probe)
    got, d = idx.query(probe, k=1)
    assert got[0] == mover and float(d[0]) < 1e-4
    assert idx.size == 150                  # update is not an insert
    with pytest.raises(KeyError):
        idx.update("never-inserted", probe)


@pytest.mark.parametrize("kind", KINDS)
def test_conformance_export_load_roundtrip(kind):
    idx, data = build(kind)
    idx.delete("d3")
    idx.update("d5", data[3])
    with tempfile.TemporaryDirectory() as td:
        p = os.path.join(td, "idx.npz")
        idx.export(p)
        idx2 = type(idx).load(p)
        assert idx2.size == idx.size == 149
        k1, d1 = idx.query(data[3], k=5)
        k2, d2 = idx2.query(data[3], k=5)
        assert k1 == k2 and k2[0] == "d5"
        np.testing.assert_allclose(d1, d2, rtol=1e-6)
        assert "d3" not in k2               # tombstones round-trip
        assert set(idx2.keys()) == set(idx.keys())


@pytest.mark.parametrize("kind", KINDS)
def test_conformance_query_matches_exact_oracle(kind):
    idx, data = build(kind)
    rng = np.random.default_rng(5)
    hits = total = 0
    for qi in rng.integers(0, 150, 10):
        q = data[qi] + 0.05 * rng.normal(size=16).astype(np.float32)
        keys, _ = idx.query(q, k=5)
        exact, _ = idx.exact_query(q, k=5)
        hits += len({k for k in keys if k} & set(exact))
        total += 5
    assert hits / total >= 0.8, (kind, hits / total)


@pytest.mark.parametrize("kind", KINDS)
def test_conformance_empty_index_errors(kind):
    idx = make_index(kind, dim=8, metric="cosine")
    with pytest.raises(ValueError, match="empty"):
        idx.query(np.zeros(8, np.float32), k=1)
    with pytest.raises(ValueError, match="empty"):
        idx.exact_query(np.zeros(8, np.float32), k=1)
    with pytest.raises(ValueError, match="empty"):
        idx.export("/tmp/never-written.npz")
    assert idx.size == 0


@pytest.mark.parametrize("kind", KINDS)
def test_conformance_k_exceeding_live_pads_with_none(kind):
    data = make_corpus(5, 16, seed=8)
    idx = make_index(kind, dim=16, metric="cosine", M=4, ef_construction=20)
    idx.bulk_insert([f"d{i}" for i in range(5)], data)
    idx.delete("d4")
    keys, dists = idx.query(data[0], k=10)
    assert len(keys) == len(dists) == 10       # fixed k slots, every backend
    assert keys[0] == "d0" and keys[4:] == [None] * 6


@pytest.mark.parametrize("kind", KINDS)
def test_conformance_bulk_insert_duplicate_key_collapses(kind):
    """A key repeated within one bulk_insert batch is an upsert: exactly
    one live row survives (last value wins) and delete retracts it fully
    — no ghost row that a query can still surface."""
    data = make_corpus(12, 16, seed=11)
    idx = make_index(kind, dim=16, metric="cosine", M=4, ef_construction=20)
    idx.bulk_insert(["a", "a"] + [f"d{i}" for i in range(10)],
                    np.concatenate([data[:2], data[2:]]))
    assert idx.size == 11
    assert idx.keys().count("a") == 1
    got, d = idx.query(data[1], k=1)       # the LAST duplicate's vector won
    assert got[0] == "a" and float(d[0]) < 1e-4
    idx.delete("a")
    keys, _ = idx.query(data[0], k=idx.size)
    assert "a" not in keys                 # the first dup left no ghost
    keys, _ = idx.query(data[1], k=idx.size)
    assert "a" not in keys


def test_hnsw_bulk_build_duplicate_key_collapses():
    """Same contract through the bulk-build adoption fast path."""
    from repro.core.interface import HNSW
    data = make_corpus(12, 16, seed=12)
    idx = HNSW(distance_function="cosine", M=4, ef_construction=20,
               use_bulk_build=True)
    idx.bulk_insert(["a", "a"] + [f"d{i}" for i in range(10)],
                    np.concatenate([data[:2], data[2:]]))
    assert idx.size == 11
    idx.delete("a")
    keys, _ = idx.query(data[0], k=idx.size)
    assert "a" not in keys
    keys, _ = idx.query(data[1], k=idx.size)
    assert "a" not in keys


def test_make_index_rejects_unknown_kind():
    with pytest.raises(ValueError, match="unknown index kind"):
        make_index("annoy")


def test_make_index_from_config():
    from repro.configs.mememo import smoke_config
    cfg = smoke_config()
    idx = make_index_from_config(cfg)
    from repro.core.interface import HNSW
    assert isinstance(idx, HNSW) and idx.M == cfg.M
    idx_ivf = make_index_from_config(cfg, kind="ivf", nlist=4)
    from repro.core.ivf import IVFVectorIndex
    assert isinstance(idx_ivf, IVFVectorIndex) and idx_ivf.nlist == 4


# ---------------------------------------------------------------------------
# HNSW mutation internals: second bulk_insert, incremental device sync
# ---------------------------------------------------------------------------
def test_hnsw_second_bulk_insert_appends():
    from repro.core.interface import HNSW
    data = make_corpus(300, 16, seed=1)
    more = make_corpus(40, 16, seed=2)
    idx = HNSW(distance_function="cosine", M=8, ef_construction=40,
               use_bulk_build=True)
    idx.bulk_insert([f"a{i}" for i in range(300)], data)
    idx.bulk_insert([f"b{i}" for i in range(40)], more)   # must not drop a*
    assert idx.size == 340
    k, _ = idx.query(data[11], k=1)
    assert k[0] == "a11"
    k, _ = idx.query(more[7], k=1)
    assert k[0] == "b7"


def test_hnsw_incremental_sync_matches_full_rebuild():
    """Dirty-row journal upload must be bit-for-bit identical to a
    from-scratch ``to_device_graph`` over the same host state."""
    idx, data = build("hnsw", n=250, seed=3)
    q = data[:4]
    idx.query(q, k=5)                        # residency: full first upload
    assert not idx._builder.journal          # journal drained by the sync
    new = make_corpus(6, 16, seed=4)
    for j, v in enumerate(new):
        idx.insert(f"n{j}", v)
    idx.delete("d17")
    idx.delete("d91")
    assert idx._builder.journal              # mutations journaled
    idx.query(q, k=5)                        # incremental sync
    dg_inc = idx._device_graph

    b = idx._builder
    dg_full = jhnsw.to_device_graph(
        b.graph_full_capacity(b.max_level_cap), idx._deleted)
    for name in ("vectors", "neighbors0", "upper", "levels", "entry",
                 "deleted"):
        np.testing.assert_array_equal(
            np.asarray(getattr(dg_inc, name)),
            np.asarray(getattr(dg_full, name)), err_msg=name)
    assert dg_inc.max_level == dg_full.max_level
    ids_a, d_a = jhnsw.search_graph(dg_inc, q, k=5, ef=64)
    ids_b, d_b = jhnsw.search_graph(dg_full, q, k=5, ef=64)
    np.testing.assert_array_equal(np.asarray(ids_a), np.asarray(ids_b))
    np.testing.assert_array_equal(np.asarray(d_a), np.asarray(d_b))


def test_hnsw_deleted_entry_point_still_searchable():
    idx, data = build("hnsw", n=120, seed=6)
    entry_key = idx._keys[int(idx._builder.entry)]
    idx.delete(entry_key)                    # tombstone the entry point
    keys, _ = idx.query(data[60], k=3)
    assert entry_key not in keys and keys[0] is not None


# ---------------------------------------------------------------------------
# shard substrate (DESIGN.md §8) — host-side pieces testable on one device;
# the mesh fan-out / cross-shard parity suite is tests/test_sharded.py
# ---------------------------------------------------------------------------
def test_shard_routing_deterministic_and_balanced():
    from repro.core.sharded import shard_of_key
    keys = [f"doc-{i}" for i in range(4000)]
    a = [shard_of_key(k, 8) for k in keys]
    assert a == [shard_of_key(k, 8) for k in keys]   # stable (not hash())
    counts = np.bincount(a, minlength=8)
    assert counts.sum() == 4000 and counts.max() < 700  # roughly balanced
    assert all(shard_of_key(k, 1) == 0 for k in keys[:10])


def test_sharded_rows_free_slot_bookkeeping():
    """Tombstoned slots are reused by later inserts routed to the same
    shard; compaction re-derives a dense layout."""
    from repro.core.sharded import ShardedRows, shard_of_key
    rows = ShardedRows(n_shards=4, metric="cosine", dim=8)
    data = np.random.default_rng(0).normal(size=(40, 8)).astype(np.float32)
    for i in range(40):
        rows.upsert(f"k{i}", data[i])
    assert rows.size == 40
    victim = "k7"
    s7, slot7 = rows.placement_of_row(rows.key2row[victim])
    rows.tombstone(victim)
    # next insert routed to the same shard claims the freed slot
    probe = next(f"n{j}" for j in range(1000)
                 if shard_of_key(f"n{j}", 4) == s7)
    rows.upsert(probe, data[0])
    assert rows.placement_of_row(rows.key2row[probe]) == (s7, slot7)
    stats = rows.shard_stats()
    assert sum(st["live"] for st in stats) == 40
    # upsert of an existing key frees its old slot too
    rows.upsert(probe, data[1])
    assert rows.size == 40
    rows.compact()
    assert rows.row_count == 40 and rows.size == 40
    assert all(st["free"] == 0 for st in rows.shard_stats())
    assert victim not in rows.key2row
    # regression: a pre-existing key repeated WITHIN one batch must free
    # its old slot exactly once — a double release would hand the same
    # slot to two rows and desync the slot tables from the alive mask
    rows.upsert_many(["k3", "k3"], data[:2])
    occupied = {(s, slot) for s in range(4)
                for slot, r in enumerate(rows._slots[s]) if r >= 0}
    assert len(occupied) == int(rows.alive.sum())
    for s in range(4):
        st = rows.shard_stats()[s]
        assert st["slots"] - st["free"] == st["live"]


def test_sharded_without_devices_raises_helpfully():
    """n_shards beyond the process's device count: mutations (host-side)
    work, the first device search raises with the XLA_FLAGS recipe."""
    idx = make_index("flat", dim=8, metric="cosine", n_shards=4)
    idx.bulk_insert(["a", "b"], np.eye(8, dtype=np.float32)[:2])
    assert idx.size == 2 and idx.shard_count == 4
    import jax
    if len(jax.devices()) >= 4:
        pytest.skip("process has enough devices to place 4 shards")
    with pytest.raises(ValueError, match="xla_force_host_platform"):
        idx.query(np.ones(8, np.float32), k=1)


@pytest.mark.parametrize("kind", KINDS)
def test_single_shard_config_roundtrips(kind):
    """n_shards=1 (default) is the historical layout: shard_count reports
    it, config round-trips through export/load."""
    idx, data = build(kind, n=40)
    assert idx.shard_count == 1
    assert idx.config_dict().get("n_shards", 1) == 1
    with tempfile.TemporaryDirectory() as td:
        p = os.path.join(td, "idx.npz")
        idx.export(p)
        idx2 = type(idx).load(p)
        assert idx2.shard_count == 1
        k1, _ = idx.query(data[3], k=3)
        k2, _ = idx2.query(data[3], k=3)
        assert k1 == k2


def test_tiered_query_counts_slow_tier_traffic():
    idx, data = build("tiered", n=200, seed=7)
    idx.query(data[5], k=3)
    stats = idx.stats
    assert stats.transactions > 0 and stats.rows_fetched > 0
    # mutation invalidates the fast tier; stats reset with the new store
    idx.delete("d5")
    keys, _ = idx.query(data[5], k=3)
    assert "d5" not in keys


# ---------------------------------------------------------------------------
# RAGPipeline over the protocol (acceptance: flat + hnsw via make_index)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("kind", ["flat", "hnsw"])
def test_rag_pipeline_over_make_index(kind):
    from repro.data.corpus import BUILTIN_CORPUS
    from repro.serve.rag import RAGPipeline

    rag = RAGPipeline(index_kind=kind)
    rag.add_documents(BUILTIN_CORPUS)
    out = rag.answer("how does mememo prefetch from IndexedDB?", k=3)
    assert any(d.key.startswith("mememo") for d in out["docs"])
    assert "{{user}}" not in out["prompt"]
    # retract a personal document: it must never be retrieved again
    top = out["docs"][0].key
    rag.delete_document(top)
    out2 = rag.answer("how does mememo prefetch from IndexedDB?", k=3)
    assert all(d.key != top for d in out2["docs"])
    # live update: re-embedded text is retrieved under the same key
    rag.update_document("tpu-0", "mememo prefetches neighbors from indexeddb")
    out3 = rag.answer("how does mememo prefetch from IndexedDB?", k=2)
    assert any(d.key == "tpu-0" for d in out3["docs"])
