"""MoE dispatch invariants vs a per-token oracle."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.configs.base import MoEConfig
from repro.models import moe as moe_lib
from repro.models.common import normal_init


def _params(key, d, cfg):
    p = moe_lib.init_moe_layer(key, 1, d, cfg)
    return jax.tree.map(lambda x: x[0], p)


def _oracle(p, cfg, x):
    """Per-token dense oracle: route, weight, SwiGLU each expert — no
    capacity dropping (use with capacity_factor large)."""
    logits = x.astype(jnp.float32) @ p["router"].astype(jnp.float32)
    if cfg.n_slots > cfg.n_experts:
        logits = jnp.where(jnp.arange(cfg.n_slots)[None] < cfg.n_experts,
                           logits, -1e30)
    probs = jax.nn.softmax(logits, -1)
    w, ids = jax.lax.top_k(probs, cfg.top_k)
    w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for t in range(x.shape[0]):
        acc = jnp.zeros((x.shape[1],), jnp.float32)
        for j in range(cfg.top_k):
            e = ids[t, j]
            h1 = x[t].astype(jnp.float32) @ p["we1"][e].astype(jnp.float32)
            h3 = x[t].astype(jnp.float32) @ p["we3"][e].astype(jnp.float32)
            h = jax.nn.silu(h1) * h3
            acc += w[t, j] * (h @ p["we2"][e].astype(jnp.float32))
        out = out.at[t].set(acc)
    return out


def test_moe_matches_per_token_oracle():
    cfg = MoEConfig(n_experts=6, top_k=2, d_ff=16, capacity_factor=32.0)
    d, T = 12, 10
    p = _params(jax.random.PRNGKey(0), d, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (T, d))
    got, aux = moe_lib.moe_ffn(p, cfg, x)
    want = _oracle(p, cfg, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)
    assert float(aux) >= 0.0


def test_padded_experts_match_unpadded():
    base = MoEConfig(n_experts=6, top_k=2, d_ff=16, capacity_factor=32.0)
    pad = dataclasses.replace(base, pad_experts_to=8)
    d, T = 12, 10
    pb = _params(jax.random.PRNGKey(0), d, base)
    pp = _params(jax.random.PRNGKey(0), d, pad)
    # copy the 6 live experts into the padded tree
    for k in ("we1", "we2", "we3"):
        pp[k] = pp[k].at[:6].set(pb[k])
    pp["router"] = pp["router"].at[:, :6].set(pb["router"])
    x = jax.random.normal(jax.random.PRNGKey(1), (T, d))
    got_b, _ = moe_lib.moe_ffn(pb, base, x)
    got_p, _ = moe_lib.moe_ffn(pp, pad, x)
    np.testing.assert_allclose(np.asarray(got_b), np.asarray(got_p),
                               rtol=2e-3, atol=2e-3)


@given(t=st.integers(4, 24), k=st.integers(1, 3))
@settings(max_examples=8, deadline=None)
def test_capacity_bounds_respected(t, k):
    """No expert processes more than C tokens (dropping works)."""
    cfg = MoEConfig(n_experts=4, top_k=k, d_ff=8, capacity_factor=0.5)
    d = 8
    p = _params(jax.random.PRNGKey(2), d, cfg)
    x = jax.random.normal(jax.random.PRNGKey(3), (t, d))
    out, aux = moe_lib.moe_ffn(p, cfg, x)
    assert np.isfinite(np.asarray(out)).all()
    assert np.isfinite(float(aux))


def test_aux_loss_balance_semantics():
    """Switch aux loss: uniform router probs -> exactly aux_weight * 1.0;
    probs concentrated on the experts that receive the traffic -> > 1."""
    cfg = MoEConfig(n_experts=8, top_k=2, d_ff=8, capacity_factor=8.0,
                    aux_loss_weight=1.0)
    d, T = 8, 256
    p = _params(jax.random.PRNGKey(0), d, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (T, d))
    # uniform probs: P_e = 1/E regardless of f -> aux == 1 exactly
    p["router"] = jnp.zeros_like(p["router"])
    _, aux_uniform = moe_lib.moe_ffn(p, cfg, x)
    np.testing.assert_allclose(float(aux_uniform), 1.0, rtol=1e-3)
    # collapse WITH concentrated probs: all mass on experts {0,1} -> aux ~ 4
    # (positive inputs so the weight columns act like strong positive logits)
    p["router"] = p["router"].at[:, :2].set(5.0)
    x_pos = jnp.abs(x) + 0.1
    _, aux_collapse = moe_lib.moe_ffn(p, cfg, x_pos)
    assert float(aux_collapse) > 2.5, float(aux_collapse)
