"""Blocked attention vs the O(S^2) oracle across shapes, plus properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.models.attention import (
    blocked_attention, decode_attention, reference_attention,
    swa_blocked_attention, pick_block,
)


def _qkv(key, b, s, h, kvh, dh, sk=None):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, s, h, dh), jnp.float32)
    k = jax.random.normal(ks[1], (b, sk or s, kvh, dh), jnp.float32)
    v = jax.random.normal(ks[2], (b, sk or s, kvh, dh), jnp.float32)
    return q, k, v


@pytest.mark.parametrize("b,s,h,kvh,dh,bq,bk", [
    (1, 32, 2, 1, 8, 8, 8),
    (2, 64, 4, 2, 16, 16, 32),
    (2, 48, 4, 4, 8, 16, 16),     # MHA, non-pow2 seq
    (1, 128, 8, 2, 8, 32, 64),
])
@pytest.mark.parametrize("causal", [True, False])
def test_blocked_matches_reference(b, s, h, kvh, dh, bq, bk, causal):
    q, k, v = _qkv(jax.random.PRNGKey(0), b, s, h, kvh, dh)
    ref = reference_attention(q, k, v, causal=causal)
    out = blocked_attention(q, k, v, causal=causal, block_q=bq, block_k=bk)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("s,blk", [(64, 16), (128, 32), (64, 8)])
def test_packed_matches_reference(s, blk):
    q, k, v = _qkv(jax.random.PRNGKey(1), 2, s, 4, 2, 16)
    ref = reference_attention(q, k, v, causal=True)
    out = blocked_attention(q, k, v, causal=True, block_q=blk, block_k=blk,
                            impl="packed")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("s,w,bq", [(64, 24, 16), (96, 32, 16), (128, 16, 32),
                                    (64, 64, 16)])
def test_swa_matches_reference(s, w, bq):
    q, k, v = _qkv(jax.random.PRNGKey(2), 2, s, 4, 2, 8)
    ref = reference_attention(q, k, v, causal=True, window=w)
    out = swa_blocked_attention(q, k, v, window=w, block_q=bq, block_k=bq)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_decode_matches_reference_row():
    b, s, h, kvh, dh = 3, 40, 4, 2, 8
    q, k, v = _qkv(jax.random.PRNGKey(3), b, 1, h, kvh, dh, sk=s)
    for cur in [1, 17, 40]:
        ref = reference_attention(q, k[:, :cur], v[:, :cur], causal=False)
        out = decode_attention(q, k, v, jnp.asarray(cur))
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)


def test_decode_per_sequence_lengths():
    """Per-slot cur_len must mask exactly like per-request slicing."""
    b, s, h, kvh, dh = 4, 32, 4, 2, 8
    q, k, v = _qkv(jax.random.PRNGKey(4), b, 1, h, kvh, dh, sk=s)
    lens = jnp.asarray([3, 10, 32, 1])
    out = decode_attention(q, k, v, lens)
    for i, L in enumerate([3, 10, 32, 1]):
        ref = reference_attention(q[i:i+1], k[i:i+1, :L], v[i:i+1, :L],
                                  causal=False)
        np.testing.assert_allclose(np.asarray(out[i]), np.asarray(ref[0]),
                                   rtol=1e-5, atol=1e-5)


@given(s=st.integers(4, 96), b=st.integers(8, 48))
@settings(max_examples=10, deadline=None)
def test_pick_block_divides(s, b):
    blk = pick_block(s, b)
    assert 1 <= blk <= min(s, b) and s % blk == 0


@given(scale=st.floats(0.25, 4.0))
@settings(max_examples=8, deadline=None)
def test_softmax_value_bound(scale):
    """Attention output is a convex combination of values: bounded by them."""
    q, k, v = _qkv(jax.random.PRNGKey(5), 1, 32, 2, 2, 8)
    out = blocked_attention(q * scale, k, v, causal=True, block_q=8, block_k=8)
    assert float(jnp.max(jnp.abs(out))) <= float(jnp.max(jnp.abs(v))) + 1e-4
