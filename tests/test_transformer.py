"""Transformer consistency: decode==forward, SWA ring, MoE, chunked loss."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import transformer as tf


def _decode_vs_forward(cfg, prefix, total, atol=5e-5):
    params = tf.init_lm(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, total), 0, cfg.vocab)
    x, _ = tf.forward_hidden(params, cfg, toks, dtype=jnp.float32)
    w = params["embed"].T if cfg.tie_embeddings else params["out_head"]
    full = x @ w
    logits, cache = tf.prefill(params, cfg, toks[:, :prefix],
                               dtype=jnp.float32, max_len=total)
    errs = [np.abs(np.asarray(logits[:, 0]) - np.asarray(full[:, prefix - 1])).max()]
    for t in range(prefix, total):
        logits, cache = tf.decode_step(params, cfg, toks[:, t:t + 1], cache,
                                       dtype=jnp.float32)
        errs.append(np.abs(np.asarray(logits[:, 0]) - np.asarray(full[:, t])).max())
    assert max(errs) < atol, max(errs)


def test_dense_decode_matches_forward():
    _decode_vs_forward(get_smoke_config("llama3-8b"), 16, 24)


def test_swa_ring_decode_matches_forward():
    cfg = get_smoke_config("h2o-danube-3-4b")   # window 32
    _decode_vs_forward(cfg, 40, 48)             # prompt > window: ring wraps


def test_moe_decode_matches_forward_high_capacity():
    cfg = get_smoke_config("olmoe-1b-7b")
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=16.0))
    _decode_vs_forward(cfg, 16, 22, atol=5e-4)


def test_flash_decode_step_matches_dense_path():
    """The serving hot loop decodes through the flash_decode kernel path
    (attn_impl="flash", the default); it must match the dense reference
    attention bit-for-bit in rollout — including slots at different
    depths (per-sequence cur_len through one dispatch)."""
    cfg = get_smoke_config("llama3-8b")
    params = tf.init_lm(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (3, 12), 0, cfg.vocab)
    lens = jnp.asarray([12, 5, 9], jnp.int32)   # ragged prefixes
    _, c_f = tf.prefill(params, cfg, toks, dtype=jnp.float32, max_len=32,
                        prompt_lens=lens)
    _, c_d = tf.prefill(params, cfg, toks, dtype=jnp.float32, max_len=32,
                        prompt_lens=lens)
    step = jax.random.randint(jax.random.PRNGKey(2), (3, 4), 0, cfg.vocab)
    for t in range(4):
        lf, c_f = tf.decode_step(params, cfg, step[:, t:t + 1], c_f,
                                 dtype=jnp.float32, attn_impl="flash")
        ld, c_d = tf.decode_step(params, cfg, step[:, t:t + 1], c_d,
                                 dtype=jnp.float32, attn_impl="dense")
        np.testing.assert_allclose(np.asarray(lf), np.asarray(ld),
                                   rtol=1e-5, atol=1e-5)
        assert (np.argmax(np.asarray(lf[:, 0]), -1)
                == np.argmax(np.asarray(ld[:, 0]), -1)).all()
    with pytest.raises(ValueError, match="attn_impl"):
        tf.decode_step(params, cfg, step[:, :1], c_f, attn_impl="paged")


def test_chunked_loss_matches_full_loss():
    cfg = get_smoke_config("llama3-8b")
    params = tf.init_lm(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, cfg.vocab)
    full = tf.lm_loss(params, cfg, toks, toks, dtype=jnp.float32)
    cfg_c = dataclasses.replace(cfg, chunked_loss=8)
    chunked = tf.lm_loss(params, cfg_c, toks, toks, dtype=jnp.float32)
    np.testing.assert_allclose(float(full), float(chunked), rtol=2e-5)
    # gradients agree too
    g1 = jax.grad(lambda p: tf.lm_loss(p, cfg, toks, toks,
                                       dtype=jnp.float32))(params)
    g2 = jax.grad(lambda p: tf.lm_loss(p, cfg_c, toks, toks,
                                       dtype=jnp.float32))(params)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-3, atol=1e-5)


def test_packed_attention_loss_matches_masked():
    cfg = get_smoke_config("llama3-8b")
    params = tf.init_lm(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0, cfg.vocab)
    l_masked = tf.lm_loss(params, cfg, toks, toks, dtype=jnp.float32,
                          impl="masked")
    l_packed = tf.lm_loss(params, cfg, toks, toks, dtype=jnp.float32,
                          impl="packed")
    np.testing.assert_allclose(float(l_masked), float(l_packed), rtol=1e-5)


def test_scan_vs_unrolled_layers():
    cfg = get_smoke_config("llama3-8b")
    params = tf.init_lm(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
    a = tf.lm_loss(params, cfg, toks, toks, dtype=jnp.float32)
    cfg_u = dataclasses.replace(cfg, scan_layers=False)
    b = tf.lm_loss(params, cfg_u, toks, toks, dtype=jnp.float32)
    np.testing.assert_allclose(float(a), float(b), rtol=1e-6)


def test_moe_capacity_drops_tokens():
    """With tiny capacity, some tokens must be dropped (output != hi-cap)."""
    cfg = get_smoke_config("olmoe-1b-7b")
    lo = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=0.25))
    hi = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=16.0))
    params = tf.init_lm(jax.random.PRNGKey(0), hi)
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0, cfg.vocab)
    x_lo, _ = tf.forward_hidden(params, lo, toks, dtype=jnp.float32)
    x_hi, _ = tf.forward_hidden(params, hi, toks, dtype=jnp.float32)
    assert np.abs(np.asarray(x_lo) - np.asarray(x_hi)).max() > 1e-4


def test_expert_padding_is_semantically_dead():
    """pad_experts_to adds experts that never receive tokens."""
    cfg = get_smoke_config("granite-moe-3b-a800m")   # 5 experts smoke
    padded = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, pad_experts_to=8))
    params = tf.init_lm(jax.random.PRNGKey(0), padded)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
    loss = tf.lm_loss(params, padded, toks, toks, dtype=jnp.float32)
    assert np.isfinite(float(loss))
    # routing never selects dead experts: router prob mass beyond n_experts=0
    from repro.models import moe as moe_lib
    lp = jax.tree.map(lambda p: p[0], params["layers"])
    x = jax.random.normal(jax.random.PRNGKey(2), (32, cfg.d_model))
    out, aux = moe_lib.moe_ffn(
        {k: lp[k] for k in ("router", "we1", "we2", "we3")}, padded.moe, x)
    assert np.isfinite(np.asarray(out)).all()


def test_int8_kv_cache_decode_accuracy():
    """int8 KV quantisation: decode must track the forward oracle closely."""
    cfg = get_smoke_config("llama3-8b")
    cfg_q = dataclasses.replace(cfg, kv_quant=True)
    params = tf.init_lm(jax.random.PRNGKey(0), cfg)
    T = 24
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, T), 0, cfg.vocab)
    x, _ = tf.forward_hidden(params, cfg, toks, dtype=jnp.float32)
    full = x @ params["out_head"]
    logits, cache = tf.prefill(params, cfg_q, toks[:, :16],
                               dtype=jnp.float32, max_len=T)
    assert cache.k.dtype == jnp.int8 and cache.k_scale is not None
    errs = [np.abs(np.asarray(logits[:, 0]) - np.asarray(full[:, 15])).max()]
    for t in range(16, T):
        logits, cache = tf.decode_step(params, cfg_q, toks[:, t:t + 1],
                                       cache, dtype=jnp.float32)
        errs.append(np.abs(np.asarray(logits[:, 0])
                           - np.asarray(full[:, t])).max())
    scale = np.abs(np.asarray(full)).max()
    assert max(errs) < 0.02 * scale + 0.01, (max(errs), scale)
