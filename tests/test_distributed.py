"""Multi-device tests: spawned subprocesses set the fake-device XLA flag
BEFORE importing jax (the main pytest process must keep 1 CPU device)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_sub(code: str, devices: int = 8, prelude: str = "") -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run(
        [sys.executable, "-c", prelude + textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=480)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_sharded_flat_topk_exact():
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core.distributed import sharded_flat_topk
        from repro.kernels import ref
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        db = jax.random.normal(jax.random.PRNGKey(0), (640, 16))
        q = jax.random.normal(jax.random.PRNGKey(1), (4, 16))
        d, i = jax.jit(lambda a, b: sharded_flat_topk(mesh, a, b, 10,
                                                      metric="l2"))(db, q)
        de, ie = ref.distance_topk_ref(db, q, 10, metric="l2")
        assert np.allclose(np.sort(np.asarray(d)), np.sort(np.asarray(de)),
                           atol=1e-4)
        assert (np.sort(np.asarray(i)) == np.sort(np.asarray(ie))).all()
        print("OK")
    """)
    assert "OK" in out


def test_sharded_flat_topk_awkward_n():
    """Regression: N not a multiple of the shard count used to silently
    drop the trailing ``N mod S`` rows (``n // n_shards`` truncation).
    The DB is now padded with sentinel rows whose ids are masked out of
    the merge — results must be exact at awkward N, including when the
    true top-k lives in the truncated tail and when N < n_shards."""
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core.distributed import sharded_flat_topk
        from repro.kernels import ref
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        # 637 = 8 * 79 + 5: five tail rows used to vanish from the search
        db = jax.random.normal(jax.random.PRNGKey(0), (637, 16))
        q = db[-3:] + 0.001          # true neighbors ARE the tail rows
        d, i = jax.jit(lambda a, b: sharded_flat_topk(mesh, a, b, 10,
                                                      metric="l2"))(db, q)
        de, ie = ref.distance_topk_ref(db, q, 10, metric="l2")
        assert (np.sort(np.asarray(i)) == np.sort(np.asarray(ie))).all(), \\
            "tail rows still dropped"
        assert np.allclose(np.sort(np.asarray(d)), np.sort(np.asarray(de)),
                           atol=1e-4)
        assert np.asarray(i)[0, 0] == 634       # the tail row itself wins
        # degenerate: fewer rows than shards (every shard padded)
        db2 = jax.random.normal(jax.random.PRNGKey(2), (5, 16))
        d2, i2 = jax.jit(lambda a, b: sharded_flat_topk(
            mesh, a, b, 3, metric="l2"))(db2, db2[:2])
        de2, ie2 = ref.distance_topk_ref(db2, db2[:2], 3, metric="l2")
        assert (np.sort(np.asarray(i2)) == np.sort(np.asarray(ie2))).all()
        print("OK")
    """)
    assert "OK" in out


def test_sharded_topk_bf16_wire_recall():
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core.distributed import sharded_flat_topk
        from repro.kernels import ref
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        db = jax.random.normal(jax.random.PRNGKey(0), (4096, 32))
        db = db / jnp.linalg.norm(db, axis=1, keepdims=True)
        q = db[:8] + 0.01
        d, i = jax.jit(lambda a, b: sharded_flat_topk(
            mesh, a.astype(jnp.bfloat16), b, 10, wire_bf16=True))(db, q)
        de, ie = ref.distance_topk_ref(db, q, 10)
        hits = sum(len(set(np.asarray(i)[r]) & set(np.asarray(ie)[r]))
                   for r in range(8))
        assert hits >= 8 * 9, hits          # >=90% recall through bf16 wire
        print("OK")
    """)
    assert "OK" in out


# shared by the tree-merge parity tests: run hierarchical_topk under
# shard_map on the first ``s`` fake devices, tree path (static axis_sizes)
# or all-gather oracle (axis_sizes=None), optionally with the bf16 wire
_MERGE = """
import jax, jax.numpy as jnp, numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.core.sharded import SHARD_AXIS, shard_mesh
from repro.distributed.collectives import hierarchical_topk

def merge(s, d, i, k, tree, wire=False):
    mesh = shard_mesh(s)
    f = jax.jit(shard_map(
        lambda dd, ii: hierarchical_topk(
            dd[0], ii[0], k, (SHARD_AXIS,), wire_bf16=wire,
            tie_break_ids=True, axis_sizes=(s,) if tree else None),
        mesh=mesh,
        in_specs=(P(SHARD_AXIS, None, None),) * 2,
        out_specs=(P(None, None), P(None, None)), check_rep=False))
    spec = NamedSharding(mesh, P(SHARD_AXIS, None, None))
    dd, ii = f(jax.device_put(jnp.asarray(d), spec),
               jax.device_put(jnp.asarray(i), spec))
    return np.asarray(dd), np.asarray(ii)
"""


def test_tree_merge_matches_allgather_oracle():
    """Bitwise parity of the ppermute tree reduction against the
    all-gather oracle at S in {2, 3, 4, 8} (non-power-of-two included),
    under heavy distance ties: the two-key (dist, id) sort must make
    both paths deterministic, identical to each other, and identical to
    a host lexsort ground truth (ties resolve to the smallest id)."""
    out = run_sub(prelude=_MERGE, code="""
        rng = np.random.default_rng(0)
        k, b = 8, 5
        for s in (2, 3, 4, 8):
            # integer distances from a 6-value alphabet: maximal tie
            # pressure across shards, every value exact in bf16 too
            d = np.sort(rng.integers(0, 6, (s, b, k)), -1).astype(np.float32)
            i = rng.permutation(s * b * k).astype(np.int32).reshape(s, b, k)
            td, ti = merge(s, d, i, k, True)
            od, oi = merge(s, d, i, k, False)
            assert (td == od).all() and (ti == oi).all(), s
            td2, ti2 = merge(s, d, i, k, True)     # deterministic re-run
            assert (td == td2).all() and (ti == ti2).all(), s
            dd = d.transpose(1, 0, 2).reshape(b, -1)
            ii = i.transpose(1, 0, 2).reshape(b, -1)
            for r in range(b):
                order = np.lexsort((ii[r], dd[r]))[:k]
                assert (ti[r] == ii[r][order]).all(), (s, r)
                assert (td[r] == dd[r][order]).all(), (s, r)
        print("OK")
    """)
    assert "OK" in out


def test_tree_merge_bf16_wire_parity():
    """The bf16 wire halves the per-round distance payload; with
    bf16-exact inputs the tree must stay bitwise identical to the
    oracle at the same wire precision AND to the fp32-wire result."""
    out = run_sub(prelude=_MERGE, code="""
        rng = np.random.default_rng(1)
        k, b = 6, 4
        for s in (3, 8):
            d = np.sort(rng.integers(0, 5, (s, b, k)), -1).astype(np.float32)
            i = rng.permutation(s * b * k).astype(np.int32).reshape(s, b, k)
            td, ti = merge(s, d, i, k, True, wire=True)
            od, oi = merge(s, d, i, k, False, wire=True)
            assert (td == od).all() and (ti == oi).all(), s
            fd, fi = merge(s, d, i, k, True, wire=False)
            assert (td == fd).all() and (ti == fi).all(), s
        print("OK")
    """)
    assert "OK" in out


def test_compressed_psum_accuracy():
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        from repro.distributed.collectives import compressed_psum
        mesh = jax.make_mesh((8,), ("x",))
        x = jax.random.normal(jax.random.PRNGKey(2), (8, 1000))
        f = shard_map(lambda s: compressed_psum(s[0], "x"), mesh=mesh,
                      in_specs=P("x"), out_specs=P(None), check_rep=False)
        got, want = f(x), jnp.sum(x, axis=0)
        rel = float(jnp.max(jnp.abs(got - want)) / jnp.max(jnp.abs(want)))
        assert rel < 0.03, rel              # int8 quantisation error bound
        print("OK")
    """)
    assert "OK" in out


def test_elastic_checkpoint_reshard():
    """Save under a (4,2) mesh; restore + reshard under (2,4) — elastic."""
    out = run_sub("""
        import tempfile, jax, jax.numpy as jnp, numpy as np
        from repro.train.checkpoint import CheckpointManager
        from repro.distributed.sharding import axis_rules, named_sharding
        mesh_a = jax.make_mesh((4, 2), ("data", "model"))
        mesh_b = jax.make_mesh((2, 4), ("data", "model"))
        state = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
        axes = {"w": ("batch", "mlp")}
        with tempfile.TemporaryDirectory() as td:
            ck = CheckpointManager(td)
            with axis_rules(mesh_a):
                placed = jax.device_put(state["w"],
                                        named_sharding((8, 8), "batch", "mlp"))
            ck.save(1, {"w": placed})
            got, _ = ck.restore_sharded(state, axes, mesh_b)
            assert np.array_equal(np.asarray(got["w"]),
                                  np.asarray(state["w"]))
            shard_shapes = {s.data.shape for s in got["w"].addressable_shards}
            assert shard_shapes == {(4, 2)}, shard_shapes   # (2,4) mesh layout
        print("OK")
    """)
    assert "OK" in out


def test_production_mesh_requires_512():
    out = run_sub("""
        import jax
        from repro.launch.mesh import make_production_mesh
        m1 = make_production_mesh(multi_pod=False)
        assert dict(m1.shape) == {"data": 16, "model": 16}
        m2 = make_production_mesh(multi_pod=True)
        assert dict(m2.shape) == {"pod": 2, "data": 16, "model": 16}
        print("OK")
    """, devices=512)
    assert "OK" in out
