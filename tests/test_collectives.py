"""Properties of the collective building blocks (single-device math)."""
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st


@given(n_shards=st.integers(2, 6), per=st.integers(3, 20),
       k=st.integers(1, 8), seed=st.integers(0, 100))
@settings(max_examples=20, deadline=None)
def test_hierarchical_merge_equals_global_topk(n_shards, per, k, seed):
    """Merging per-shard top-k (k <= per) must equal the global top-k —
    the invariant behind core/distributed.sharded_flat_topk."""
    k = min(k, per)
    rng = np.random.default_rng(seed)
    # unique distances avoid tie-ordering ambiguity
    d = rng.permutation(n_shards * per).astype(np.float32).reshape(n_shards,
                                                                   per)
    ids = np.arange(n_shards * per).reshape(n_shards, per)
    # per-shard top-k (smallest distances)
    local = [(np.sort(d[s])[:k],
              ids[s][np.argsort(d[s])[:k]]) for s in range(n_shards)]
    cand_d = np.concatenate([x[0] for x in local])
    cand_i = np.concatenate([x[1] for x in local])
    order = np.argsort(cand_d)[:k]
    merged_i = set(cand_i[order])
    true_i = set(np.argsort(d.reshape(-1))[:k])
    assert merged_i == true_i


@given(seed=st.integers(0, 50))
@settings(max_examples=10, deadline=None)
def test_int8_roundtrip_error_bound(seed):
    """compressed_psum's quantiser: |dequant(quant(x)) - x| <= max|x|/127."""
    rng = np.random.default_rng(seed)
    x = rng.normal(size=512).astype(np.float32) * rng.uniform(0.1, 10)
    scale = np.abs(x).max() / 127.0 + 1e-20
    q = np.clip(np.round(x / scale), -127, 127)
    err = np.abs(q * scale - x).max()
    assert err <= np.abs(x).max() / 127.0 + 1e-6


def test_bf16_wire_preserves_order_to_resolution():
    """Sorting by bf16-rounded keys only swaps entries whose distances are
    within bf16 resolution of each other (the wire_bf16 guarantee)."""
    rng = np.random.default_rng(0)
    d = np.sort(rng.uniform(0, 2, 64).astype(np.float32))
    d16 = np.asarray(jnp.asarray(d).astype(jnp.bfloat16).astype(jnp.float32))
    order = np.argsort(d16, kind="stable")
    # any inversion must involve values closer than bf16 eps at that scale
    for i, j in enumerate(order):
        if i != j:
            assert abs(d[i] - d[j]) <= 0.01 * max(d[i], 1e-3)
