"""Batched retrieval serving layer (DESIGN.md §6): query_batch protocol
conformance across all four backends, RetrievalEngine bucket coalescing,
and the cache-epoch privacy property (a deleted document can never be
served from cache — and a repeated query never touches the device)."""
import numpy as np
import pytest

from repro.core import INDEX_KINDS, make_index
from repro.data.synthetic import make_corpus
from repro.serve.retrieval import RetrievalEngine, bucket_size

KINDS = list(INDEX_KINDS)


def build(kind, dim=16, n=60, seed=0):
    data = make_corpus(n, dim, seed=seed)
    idx = make_index(kind, dim=dim, metric="cosine", M=8,
                     ef_construction=60, ef_search=48)
    idx.bulk_insert([f"d{i}" for i in range(n)], data)
    return idx, data


def counting(idx):
    """Wrap idx.query_batch to count device dispatches."""
    calls = {"n": 0}
    orig = idx.query_batch

    def wrapped(*a, **kw):
        calls["n"] += 1
        return orig(*a, **kw)

    idx.query_batch = wrapped
    return calls


# ---------------------------------------------------------------------------
# query_batch protocol conformance
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("kind", KINDS)
def test_query_batch_shape_contract(kind):
    idx, data = build(kind)
    keys, dists = idx.query_batch(data[:5], k=4)
    assert len(keys) == 5 and all(len(row) == 4 for row in keys)
    assert np.asarray(dists).shape == (5, 4)
    assert keys[2][0] == "d2"
    # batched even at B=1: no squeeze ambiguity
    k1, d1 = idx.query_batch(data[:1], k=4)
    assert len(k1) == 1 and isinstance(k1[0], list)
    assert np.asarray(d1).shape == (1, 4)
    # 1-D input is a caller bug
    with pytest.raises(ValueError, match=r"\[B, D\]"):
        idx.query_batch(data[0], k=4)


@pytest.mark.parametrize("kind", KINDS)
def test_query_batch_matches_per_query(kind):
    idx, data = build(kind)
    rng = np.random.default_rng(3)
    q = (data[rng.integers(0, 60, 6)]
         + 0.05 * rng.normal(size=(6, 16)).astype(np.float32))
    bk, bd = idx.query_batch(q, k=5)
    bd = np.asarray(bd)
    for i in range(6):
        sk, sd = idx.query(q[i], k=5)
        assert sk == bk[i], (kind, i)
        np.testing.assert_allclose(np.asarray(sd), bd[i],
                                   rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("kind", KINDS)
def test_query_batch_pads_none_for_k_exceeding_live(kind):
    idx, data = build(kind, n=5)
    idx.delete("d4")
    keys, dists = idx.query_batch(data[:2], k=10)
    assert all(len(row) == 10 for row in keys)
    assert np.asarray(dists).shape == (2, 10)
    assert keys[0][0] == "d0" and keys[0][4:] == [None] * 6
    assert "d4" not in keys[0] and "d4" not in keys[1]


@pytest.mark.parametrize("kind", KINDS)
def test_mutation_epoch_bumps(kind):
    idx, data = build(kind)
    ep = idx.mutation_epoch
    idx.insert("new", data[0] + 0.01)
    assert idx.mutation_epoch > ep
    ep = idx.mutation_epoch
    idx.update("new", data[1] + 0.01)
    assert idx.mutation_epoch > ep
    ep = idx.mutation_epoch
    idx.delete("new")
    assert idx.mutation_epoch > ep
    ep = idx.mutation_epoch
    idx.query(data[0], k=3)                  # queries do NOT bump
    assert idx.mutation_epoch == ep


# ---------------------------------------------------------------------------
# RetrievalEngine: coalescing, fan-out, buckets
# ---------------------------------------------------------------------------
def test_bucket_ladder():
    assert [bucket_size(n, 128) for n in (1, 2, 3, 5, 8, 9, 128, 300)] \
        == [1, 2, 4, 8, 8, 16, 128, 128]
    with pytest.raises(ValueError, match="power of two"):
        RetrievalEngine(build("flat")[0], max_batch=12)


@pytest.mark.parametrize("kind", KINDS)
def test_engine_coalesces_one_dispatch(kind):
    idx, data = build(kind)
    calls = counting(idx)
    eng = RetrievalEngine(idx, max_batch=16)
    reqs = [eng.submit(data[i], k=3) for i in range(5)]
    assert not any(r.done for r in reqs)             # async: nothing ran yet
    eng.run_until_drained()
    assert calls["n"] == 1                           # ONE batched dispatch
    assert eng.stats.searched_queries == 5
    assert eng.stats.padded_queries == 3             # padded up to bucket 8
    for i, r in enumerate(reqs):
        assert r.done and r.keys[0] == f"d{i}"


def test_engine_matches_direct_query_and_chunks_large_batches():
    idx, data = build("hnsw")
    eng = RetrievalEngine(idx, max_batch=4, cache_size=0)
    reqs = eng.retrieve(data[:10], k=3)              # 10 > max_batch: chunks
    assert eng.stats.searches == 3                   # 4 + 4 + 2->bucket 2
    for i, r in enumerate(reqs):
        sk, sd = idx.query(data[i], k=3)
        assert r.keys == sk
        np.testing.assert_allclose(r.dists, np.asarray(sd),
                                   rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("kind", KINDS)
def test_engine_ef_knob_accepted_by_every_backend(kind):
    """The serving layer passes one knob set through any backend: ef is
    meaningful for hnsw/tiered and harmlessly ignored by flat/ivf."""
    idx, data = build(kind)
    r = RetrievalEngine(idx, max_batch=8).retrieve_one(data[3], k=3, ef=32)
    assert r.done and r.keys[0] == "d3"


def test_engine_groups_by_k_and_ef():
    idx, data = build("hnsw")
    calls = counting(idx)
    eng = RetrievalEngine(idx, max_batch=16)
    a = eng.submit(data[0], k=3)
    b = eng.submit(data[1], k=5)                     # different k: own group
    c = eng.submit(data[2], k=3)
    eng.run_until_drained()
    assert calls["n"] == 2                           # one dispatch per group
    assert len(a.keys) == 3 and len(b.keys) == 5 and len(c.keys) == 3


# ---------------------------------------------------------------------------
# cache: repeats never touch the device; delete invalidates (privacy)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("kind", KINDS)
def test_repeated_query_served_from_cache_without_device_search(kind):
    idx, data = build(kind)
    eng = RetrievalEngine(idx, max_batch=8)
    first = eng.retrieve_one(data[7], k=3)
    assert not first.from_cache
    calls = counting(idx)
    again = eng.retrieve_one(data[7], k=3)
    assert calls["n"] == 0                    # no device search at all
    assert again.from_cache and again.done
    assert again.keys == first.keys
    np.testing.assert_array_equal(again.dists, first.dists)
    assert eng.stats.cache_hits == 1
    # different k is a different cache entry
    other = eng.retrieve_one(data[7], k=5)
    assert not other.from_cache


@pytest.mark.parametrize("kind", KINDS)
def test_delete_invalidates_cache(kind):
    """The privacy property (DESIGN.md §6): a retracted document must not
    be served from a cached result, for any backend."""
    idx, data = build(kind)
    eng = RetrievalEngine(idx, max_batch=8)
    first = eng.retrieve_one(data[7], k=3)
    assert first.keys[0] == "d7"
    idx.delete("d7")
    after = eng.retrieve_one(data[7], k=3)
    assert not after.from_cache               # cache dropped by epoch bump
    assert "d7" not in after.keys
    assert eng.stats.invalidations == 1


def test_insert_and_update_invalidate_cache_too():
    idx, data = build("flat")
    eng = RetrievalEngine(idx, max_batch=8)
    eng.retrieve_one(data[7], k=3)
    idx.insert("shadow", data[7])             # co-located: ties with d7
    r = eng.retrieve_one(data[7], k=3)
    assert not r.from_cache and "shadow" in r.keys[:2]
    idx.update("shadow", -data[7])            # pushed far away
    r2 = eng.retrieve_one(data[7], k=3)
    assert not r2.from_cache and "shadow" not in r2.keys
    assert r2.keys[0] == "d7"


def test_in_tick_duplicates_share_one_search_row():
    idx, data = build("hnsw")
    eng = RetrievalEngine(idx, max_batch=16)
    reqs = [eng.submit(data[3], k=3) for _ in range(4)]
    reqs.append(eng.submit(data[4], k=3))
    eng.run_until_drained()
    assert eng.stats.searched_queries == 2    # 2 unique rows, 3 dedup
    assert eng.stats.dedup_hits == 3
    assert all(r.keys[0] == "d3" for r in reqs[:4])
    assert reqs[4].keys[0] == "d4"


def test_failing_dispatch_resolves_every_pending_request():
    """A raising backend must not strand async callers: every request of
    the tick resolves (with ``error`` set), including dedup followers,
    and the exception still surfaces."""
    idx, data = build("flat", n=2)
    idx.delete("d0")
    idx.delete("d1")                          # empty: query raises
    eng = RetrievalEngine(idx, max_batch=8)
    reqs = [eng.submit(data[0], k=1), eng.submit(data[0], k=1),
            eng.submit(data[1], k=1)]
    with pytest.raises(ValueError, match="empty"):
        eng.step()
    assert all(r.done and r.error is not None for r in reqs)
    assert not eng.queue                      # nothing silently dropped


def test_cached_results_are_isolated_from_caller_mutation():
    idx, data = build("flat")
    eng = RetrievalEngine(idx, max_batch=8)
    first = eng.retrieve_one(data[7], k=3)
    pristine = list(first.keys)
    first.keys.reverse()                      # caller abuses its result
    again = eng.retrieve_one(data[7], k=3)
    assert again.from_cache and again.keys == pristine
    again.keys.clear()                        # hits are private copies too
    assert eng.retrieve_one(data[7], k=3).keys == pristine


def test_cache_lru_evicts_and_cache_can_be_disabled():
    idx, data = build("flat")
    eng = RetrievalEngine(idx, max_batch=8, cache_size=2)
    for i in range(3):
        eng.retrieve_one(data[i], k=3)        # 3 entries into a 2-slot LRU
    assert eng.stats.evictions == 1
    assert eng.retrieve_one(data[2], k=3).from_cache      # most recent kept
    assert not eng.retrieve_one(data[0], k=3).from_cache  # oldest evicted
    off = RetrievalEngine(idx, max_batch=8, cache_size=0)
    off.retrieve_one(data[0], k=3)
    assert not off.retrieve_one(data[0], k=3).from_cache


# ---------------------------------------------------------------------------
# serving integration: RAGPipeline batched path
# ---------------------------------------------------------------------------
def test_rag_pipeline_retrieve_batch_single_tick():
    from repro.data.corpus import BUILTIN_CORPUS
    from repro.serve.rag import RAGPipeline

    rag = RAGPipeline(index_kind="flat")
    rag.add_documents(BUILTIN_CORPUS)
    calls = counting(rag.index)
    queries = ["how does hnsw search work",
               "why is on device retrieval private",
               "how does hnsw search work"]          # repeat dedups in-tick
    batches = rag.retrieve_batch(queries, k=2)
    assert calls["n"] == 1                           # one tick, one dispatch
    assert len(batches) == 3 and all(len(b) == 2 for b in batches)
    assert [d.key for d in batches[0]] == [d.key for d in batches[2]]
    # single-query path rides the same engine and now hits the cache
    docs = rag.retrieve(queries[0], k=2)
    assert calls["n"] == 1
    assert [d.key for d in docs] == [d.key for d in batches[0]]
    # retraction still wins over the cache end-to-end
    top = batches[0][0].key
    rag.delete_document(top)
    docs2 = rag.retrieve(queries[0], k=2)
    assert all(d.key != top for d in docs2)


# ---------------------------------------------------------------------------
# multi-tenant pool: the cache key carries the tenant (regression)
# ---------------------------------------------------------------------------
def test_cache_key_includes_tenant_identity():
    """Regression: the LRU key used to be (query-hash, B, k, ef) only, so
    two tenants issuing the SAME query would share one cached result —
    tenant B served tenant A's documents. The key now carries the tenant
    id, so identical queries from different tenants are distinct entries."""
    from repro.core import IndexPool

    rng = np.random.default_rng(0)
    data = rng.normal(size=(8, 16)).astype(np.float32)
    pool = IndexPool(dim=16)
    pool.bulk_insert("alice", [f"a{i}" for i in range(4)], data[:4])
    pool.bulk_insert("bob", [f"b{i}" for i in range(4)], data[4:])
    eng = RetrievalEngine(pool, max_batch=8)
    first = eng.retrieve_one(data[0], k=2, tenant="alice")
    assert first.keys[0] == "a0"
    # same query bytes, other tenant: with the old key this was a cache
    # hit serving alice's documents to bob
    other = eng.retrieve_one(data[0], k=2, tenant="bob")
    assert not other.from_cache
    assert all(k.startswith("b") for k in other.keys)
    # same tenant + same query IS still a hit
    again = eng.retrieve_one(data[0], k=2, tenant="alice")
    assert again.from_cache and again.keys == first.keys
    # a pool without a tenant id (or a tenant id on a plain index) is
    # rejected outright rather than risking a shared entry
    with pytest.raises(ValueError, match="tenant"):
        eng.submit(data[0], k=2)
    plain = RetrievalEngine(build("flat")[0], max_batch=8)
    with pytest.raises(ValueError, match="tenant"):
        plain.submit(data[0], k=2, tenant="alice")
