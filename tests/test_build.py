"""Device-resident bulk ingest (DESIGN.md §13).

Parity pins for the vectorized construction path:
  * the batched neighbor-select op vs the host Alg. 4 oracle —
    bit-for-bit, on integer-valued vectors so fp32 arithmetic is exact
    in ANY summation order (np vs XLA dot products cannot diverge);
  * the vectorized reciprocal connect vs the retained host-loop oracle
    — bit-for-bit on random graphs + random edge lists;
  * bulk-vs-sequential recall across awkward batch shapes (1-row tail,
    non-divisible N, batch > N) and codecs;
  * the bootstrap-capped k_cand regression, max_level_cap threading,
    run-to-run determinism (the WAL-replay contract), and the
    adjacency-only H2D accounting.

Sharded reshard-adoption of a bulk-built graph runs in a subprocess
with forced fake devices (the tests/test_sharded.py idiom).
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import dispatch
from repro.core import hnsw as jhnsw
from repro.core import hnsw_build as hb
from repro.kernels import ops

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _int_vectors(rng, n, d, lo=-4, hi=5):
    """Integer-valued fp32 rows: every dot product is an exact small
    integer, so host numpy and XLA produce identical distances and the
    bit-for-bit pins below cannot flake on summation order."""
    return rng.integers(lo, hi, size=(n, d)).astype(np.float32)


def _exact10(data, q, metric="cosine"):
    if metric == "cosine":
        vn = hb.normalize_rows(data)
        qn = hb.normalize_rows(q)
        d = 1.0 - qn @ vn.T
    elif metric == "ip":
        d = 1.0 - q @ data.T
    else:
        d = ((q[:, None, :] - data[None]) ** 2).sum(-1)
    return np.argsort(d, axis=1, kind="stable")[:, :10]


def _recall(g, q, true10):
    ids, _ = jhnsw.search_graph(jhnsw.to_device_graph(g), q, k=10, ef=64)
    return jhnsw.recall_at_k(np.asarray(ids), true10)


# ---------------------------------------------------------------- select op
@pytest.mark.parametrize("metric", ["ip", "l2"])
def test_select_op_matches_host_oracle(metric):
    """ops.select_neighbors == select_heuristic_host per row, including
    -1 padding, duplicate ids, all-invalid rows, and C < m."""
    rng = np.random.default_rng(3)
    n, d, b, c, m = 80, 16, 64, 24, 8
    vectors = _int_vectors(rng, n, d)
    q = _int_vectors(rng, b, d)
    cand = rng.integers(-1, n, size=(b, c)).astype(np.int32)
    cand[0] = -1                                   # fully invalid row
    cand[1, 5:] = cand[1, 4]                       # heavy duplication
    ids, dists = ops.select_neighbors(vectors, q, cand, m=m, metric=metric)
    ids = np.asarray(ids)
    for j in range(b):
        cj = cand[j][cand[j] >= 0]
        cd = list(zip(hb._dist(metric, q[j], vectors[cj]),
                      [int(x) for x in cj]))
        want = hb.select_heuristic_host(metric, vectors, q[j], cd, m)
        got = ids[j][ids[j] >= 0]
        assert np.array_equal(got, want), (j, got, want)
    # width narrower than m still yields well-formed -1-padded output
    ids2, _ = ops.select_neighbors(vectors, q, cand[:, :3], m=m,
                                   metric=metric)
    ids2 = np.asarray(ids2)
    assert ids2.shape == (b, m)
    assert (ids2[0] == -1).all()


# ------------------------------------------------------- reciprocal connect
def _random_builder(rng, n=60, d=12, M=4, metric="l2"):
    b = hb.SequentialBuilder(d, M=M, ef_construction=16, metric=metric,
                             capacity=n, max_level_cap=4, seed=0)
    b.vectors[:n] = _int_vectors(rng, n, d)
    b.levels[:n] = rng.integers(0, 3, size=n)
    b.n, b.entry, b.max_level = n, 0, int(b.levels[:n].max())
    for node in range(n):
        nb0 = rng.choice(n, size=rng.integers(0, 2 * M + 1), replace=False)
        b.neighbors0[node, : len(nb0)] = nb0
        for lc in range(1, int(b.levels[node]) + 1):
            el = np.flatnonzero(b.levels[:n] >= lc)
            up = rng.choice(el, size=min(len(el), rng.integers(0, M + 1)),
                            replace=False)
            b.upper[lc - 1, node, : len(up)] = up
    return b


def test_connect_op_vs_host_oracle_bitforbit():
    """_connect_reciprocal impl='op' == impl='host' on random graphs +
    random back-edge lists (both layers, shared destinations)."""
    import copy

    rng = np.random.default_rng(11)
    for trial in range(3):
        b1 = _random_builder(np.random.default_rng(100 + trial))
        b2 = copy.deepcopy(b1)
        n = b1.n
        ne = 40
        e_dst = rng.integers(0, n, size=ne).astype(np.int32)
        e_lay = np.minimum(rng.integers(0, 3, size=ne),
                           b1.levels[e_dst]).astype(np.int32)
        e_src = rng.integers(0, n, size=ne).astype(np.int32)
        keep = e_src != e_dst
        e_src, e_dst, e_lay = e_src[keep], e_dst[keep], e_lay[keep]
        import jax.numpy as jnp
        d1 = hb._connect_reciprocal(b1, e_src, e_dst, e_lay,
                                    dev_vectors=jnp.asarray(b1.vectors),
                                    impl="op")
        d2 = hb._connect_reciprocal(b2, e_src, e_dst, e_lay, impl="host")
        assert sorted(d1) == sorted(d2)
        assert np.array_equal(b1.neighbors0, b2.neighbors0)
        assert np.array_equal(b1.upper, b2.upper)


# ------------------------------------------------------------ build parity
@pytest.mark.parametrize("n,batch", [(600, 650),   # batch > N
                                     (600, 250),   # non-divisible tail
                                     (601, 200)])  # 1-row tail
def test_bulk_recall_parity_batch_shapes(n, batch, rng):
    data = rng.normal(size=(n, 32)).astype(np.float32)
    q = rng.normal(size=(50, 32)).astype(np.float32)
    true10 = _exact10(data, q)
    r_seq = _recall(hb.build_sequential(data, M=8, ef_construction=40,
                                        seed=1), q, true10)
    g = hb.bulk_build(data, M=8, ef_construction=40, seed=1,
                      bootstrap=64, batch_size=batch)
    assert g.n == n
    r_blk = _recall(g, q, true10)
    assert r_blk >= r_seq - 0.05, (r_blk, r_seq)


def test_bulk_determinism_and_connect_impl_parity(rng):
    """Same inputs -> bit-identical graph (the WAL-replay contract), and
    the vectorized connect matches the host-loop oracle end-to-end."""
    data = rng.normal(size=(400, 24)).astype(np.float32)
    kw = dict(M=6, ef_construction=30, seed=3, bootstrap=32, batch_size=128)
    g1 = hb.bulk_build(data, **kw)
    g2 = hb.bulk_build(data, **kw)
    g3 = hb.bulk_build(data, connect_impl="host", **kw)
    for ga, gb in [(g1, g2), (g1, g3)]:
        assert np.array_equal(ga.neighbors0, gb.neighbors0)
        assert np.array_equal(ga.upper, gb.upper)
        assert np.array_equal(ga.levels, gb.levels)
        assert ga.entry == gb.entry and ga.max_level == gb.max_level


def test_k_cand_tracks_live_prefix(monkeypatch, rng):
    """Regression: the candidate count must cap against the LIVE prefix,
    not the bootstrap size — bootstrap=16, efC=100 used to build every
    batch from 16 candidates forever."""
    seen = []
    orig = jhnsw.search_graph

    def spy(g, queries, k=10, ef=64, **kw):
        seen.append(k)
        return orig(g, queries, k=k, ef=ef, **kw)

    monkeypatch.setattr(jhnsw, "search_graph", spy)
    data = rng.normal(size=(500, 16)).astype(np.float32)
    hb.bulk_build(data, M=4, ef_construction=100, seed=0,
                  bootstrap=16, batch_size=128)
    assert seen[0] == 16            # first batch: only the bootstrap exists
    assert max(seen) == 100         # later batches reach the full efC
    assert seen == sorted(seen)     # cap grows with the prefix


def test_max_level_cap_threading(rng):
    """bulk_build draws levels from the same stream as SequentialBuilder
    and honors max_level_cap (it was hardcoded 12)."""
    data = rng.normal(size=(500, 16)).astype(np.float32)
    g_seq = hb.build_sequential(data, M=4, ef_construction=20, seed=5)
    g_blk = hb.bulk_build(data, M=4, ef_construction=20, seed=5,
                          bootstrap=16, batch_size=128)
    assert np.array_equal(g_blk.levels, g_seq.levels)  # same per-row draws
    g_cap = hb.bulk_build(data, M=4, ef_construction=20, seed=5,
                          bootstrap=16, batch_size=128, max_level_cap=1)
    assert np.array_equal(g_cap.levels, np.minimum(g_seq.levels, 1))
    assert g_cap.max_level <= 1


def test_bulk_build_interface_codecs(rng):
    """use_bulk_build through the HNSW interface at fp32 and int8: bulk
    adoption, query recall vs the exact oracle, and appends after
    adoption keep working."""
    from repro.core.interface import HNSW

    data = rng.normal(size=(400, 24)).astype(np.float32)
    q = rng.normal(size=(30, 24)).astype(np.float32)
    true10 = _exact10(data, q)
    for dtype, floor in [("fp32", 0.85), ("int8", 0.75)]:
        idx = HNSW(M=8, ef_construction=40, use_bulk_build=True,
                   dtype=dtype)
        idx.bulk_insert([f"d{i}" for i in range(len(data))], data)
        keys, _ = idx.query_batch(q, k=10)
        ids = np.asarray([[int(k[1:]) if k is not None else -1 for k in row]
                          for row in keys])
        assert jhnsw.recall_at_k(ids, true10) >= floor
        idx.insert("extra", rng.normal(size=24).astype(np.float32))
        assert idx.size == len(data) + 1
        k2, _ = idx.query(rng.normal(size=24).astype(np.float32), k=5)
        assert len(k2) == 5


# ------------------------------------------------------------- H2D account
def test_adjacency_updates_and_h2d_accounting(rng):
    data = rng.normal(size=(200, 16)).astype(np.float32)
    g = hb.build_sequential(data, M=4, ef_construction=20, seed=0)
    dispatch.reset("hnsw.h2d_bytes")
    dg = jhnsw.to_device_graph(g)
    full = dispatch.get("hnsw.h2d_bytes")
    lmax = g.upper.shape[0]
    assert full == 200 * (16 * 4 + 4 * 8 + 4 * lmax * 4 + 4)
    # adjacency-only scatter: ships int32 rows, leaves vectors alone
    g.neighbors0[7] = -1
    g.neighbors0[7, 0] = 3
    before = np.asarray(dg.vectors).copy()
    dispatch.reset("hnsw.h2d_bytes")
    dg = jhnsw.apply_adjacency_updates(dg, g, [7])
    adj_bytes = dispatch.get("hnsw.h2d_bytes")
    assert adj_bytes == 1 * 4 * (8 + lmax * 4)     # one row, no [D] payload
    row = np.asarray(dg.neighbors0[7])
    assert row[0] == 3 and (row[1:] == -1).all()
    assert np.array_equal(np.asarray(dg.vectors), before)
    # the bulk path's whole-build traffic: one capacity upload + O(M)
    # int32 per inserted row, nowhere near the legacy O(batches) full
    # re-uploads (enough batches here that the ratio is unambiguous)
    data = rng.normal(size=(1000, 16)).astype(np.float32)
    dispatch.reset("hnsw.h2d_bytes")
    hb.bulk_build(data, M=4, ef_construction=20, seed=0,
                  bootstrap=32, batch_size=64)
    blk = dispatch.get("hnsw.h2d_bytes")
    dispatch.reset("hnsw.h2d_bytes")
    hb.bulk_build_legacy(data, M=4, ef_construction=20, seed=0,
                         bootstrap=32, batch_size=64)
    leg = dispatch.get("hnsw.h2d_bytes")
    assert blk < leg / 2, (blk, leg)


# ------------------------------------------------------- sharded adoption
def test_reshard_adopts_bulk_built_graph():
    """A 1-shard bulk-built fp32 snapshot restored at n_shards=4 takes
    the bulk-adoption fast path: canonical key order survives, exact
    results match the original, ANN stays sane, and every child builder
    came from a bulk-built graph."""
    code = """
        import numpy as np
        from repro.core.interface import HNSW
        from repro.core import hnsw_build as hb

        calls = []
        orig = hb.bulk_build
        def spy(*a, **k):
            calls.append(len(a[0]))
            return orig(*a, **k)
        hb.bulk_build = spy

        rng = np.random.default_rng(0)
        data = rng.normal(size=(300, 16)).astype(np.float32)
        keys = [f"d{i}" for i in range(len(data))]
        one = HNSW(M=6, ef_construction=30, use_bulk_build=True)
        one.bulk_insert(keys, data)
        arrays, meta = one.state_dict()
        assert calls == [300]

        four = HNSW(M=6, ef_construction=30, use_bulk_build=True,
                    n_shards=4)
        four.restore_state(arrays, meta)
        # children were bulk-adopted (one bulk_build per non-empty shard)
        assert len(calls) == 1 + sum(
            1 for c in four._shards if c._builder is not None), calls
        assert sum(calls[1:]) == 300
        assert four.keys() == one.keys()
        q = rng.normal(size=(8, 16)).astype(np.float32)
        for b in range(8):
            k1, d1 = one.exact_query(q[b], k=5)
            k4, d4 = four.exact_query(q[b], k=5)
            assert k1 == k4, (k1, k4)
            np.testing.assert_allclose(d1, d4, rtol=1e-5, atol=1e-5)
        kk, _ = four.query_batch(q, k=5)
        assert all(len(r) == 5 for r in kk)
        # mutations after adoption keep routing/behaving
        four.delete("d3")
        assert four.size == 299
        print("OK")
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=480)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "OK" in out.stdout
