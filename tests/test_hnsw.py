"""HNSW builders + lock-step JAX search: recall, parity, properties."""
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import hnsw, hnsw_build
from repro.data.synthetic import make_corpus
from repro.kernels import ref


@pytest.fixture(scope="module")
def built():
    data = make_corpus(1000, 24, seed=0)
    g = hnsw_build.build_sequential(data, M=8, ef_construction=60)
    dg = hnsw.to_device_graph(g)
    queries = make_corpus(32, 24, seed=1)
    qn = queries / np.linalg.norm(queries, axis=1, keepdims=True)
    _, true_i = ref.distance_topk_ref(jnp.asarray(g.vectors), jnp.asarray(qn),
                                      10, metric="cosine")
    return g, dg, queries, np.asarray(true_i)


def test_sequential_recall(built):
    g, dg, queries, true_i = built
    ids, _ = hnsw.search_graph(dg, queries, k=10, ef=64)
    assert hnsw.recall_at_k(np.asarray(ids), true_i) >= 0.85


def test_recall_increases_with_ef(built):
    g, dg, queries, true_i = built
    recalls = []
    for ef in (16, 64, 160):
        ids, _ = hnsw.search_graph(dg, queries, k=10, ef=ef)
        recalls.append(hnsw.recall_at_k(np.asarray(ids), true_i))
    assert recalls[0] <= recalls[1] <= recalls[2] + 0.02
    assert recalls[2] >= 0.9


def test_distances_sorted_and_consistent(built):
    g, dg, queries, _ = built
    ids, dists = hnsw.search_graph(dg, queries, k=10, ef=64)
    d = np.asarray(dists)
    assert (np.diff(d, axis=1) >= -1e-6).all(), "distances must ascend"
    # reported distance matches recomputed cosine distance
    qn = queries / np.linalg.norm(queries, axis=1, keepdims=True)
    for b in range(4):
        for j in range(10):
            i = int(ids[b, j])
            expect = 1.0 - float(qn[b] @ g.vectors[i])
            assert abs(expect - float(d[b, j])) < 1e-4


def test_bulk_build_recall_parity():
    data = make_corpus(800, 16, seed=2)
    queries = make_corpus(24, 16, seed=3)
    qn = queries / np.linalg.norm(queries, axis=1, keepdims=True)
    g_seq = hnsw_build.build_sequential(data, M=8, ef_construction=50)
    g_blk = hnsw_build.bulk_build(data, M=8, ef_construction=50,
                                  bootstrap=100, batch_size=200)
    _, true_i = ref.distance_topk_ref(
        jnp.asarray(g_seq.vectors), jnp.asarray(qn), 10, metric="cosine")
    r_seq = hnsw.recall_at_k(
        np.asarray(hnsw.search_graph(hnsw.to_device_graph(g_seq), queries,
                                     k=10, ef=64)[0]), np.asarray(true_i))
    r_blk = hnsw.recall_at_k(
        np.asarray(hnsw.search_graph(hnsw.to_device_graph(g_blk), queries,
                                     k=10, ef=64)[0]), np.asarray(true_i))
    assert r_blk >= r_seq - 0.1, (r_blk, r_seq)


def test_graph_structure_invariants(built):
    g, *_ = built
    m2 = g.neighbors0.shape[1]
    assert m2 == 2 * 8
    # no self-loops, ids in range
    for i in range(0, g.n, 97):
        nbrs = g.neighbors0[i][g.neighbors0[i] >= 0]
        assert (nbrs != i).all()
        assert (nbrs < g.n).all()
    # entry has the max level
    assert g.levels[g.entry] == g.max_level


@given(seed=st.integers(0, 50))
@settings(max_examples=8, deadline=None)
def test_db_row_query_returns_itself(seed, built):
    g, dg, *_ = built
    rng = np.random.default_rng(seed)
    i = int(rng.integers(0, g.n))
    ids, dists = hnsw.search_graph(dg, g.vectors[i], k=1, ef=48)
    assert float(dists[0, 0]) < 1e-5
