"""Per-assigned-architecture smoke tests (deliverable f): a REDUCED config of
the same family runs one forward/train step on CPU, asserting output shapes
and finiteness. The FULL configs are exercised by launch/dryrun.py only."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config, get_smoke_config
from repro.models import gnn as gnn_lib
from repro.models import recsys as rs
from repro.models import transformer as tf
from repro.models.common import count_params

LM_ARCHS = ["llama3-8b", "h2o-danube-3-4b", "minitron-8b", "olmoe-1b-7b",
            "granite-moe-3b-a800m"]
RECSYS_ARCHS = ["mind", "wide-deep", "bert4rec", "fm"]


def _finite(x):
    return bool(np.isfinite(np.asarray(x, np.float64)).all())


def test_all_assigned_archs_have_configs():
    assert len(ASSIGNED_ARCHS) == 10
    for a in ASSIGNED_ARCHS:
        cfg = get_config(a)
        assert len(cfg.shapes) == 4 or cfg.family == "retrieval"
        assert get_smoke_config(a) is not None


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke_train_step(arch):
    cfg = get_smoke_config(arch)
    params = tf.init_lm(jax.random.PRNGKey(0), cfg)
    assert count_params(params) > 0
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, cfg.vocab)
    # f32 compute: the CPU backend cannot EXECUTE batched bf16 dots
    # (DotThunk); the bf16 path is still lowered/compiled by the dry run
    loss, grads = jax.value_and_grad(
        lambda p: tf.lm_loss(p, cfg, toks, toks, dtype=jnp.float32))(params)
    assert _finite(loss) and loss.shape == ()
    assert _finite(jax.tree.leaves(grads)[0])


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke_serve_step(arch):
    cfg = get_smoke_config(arch)
    params = tf.init_lm(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
    logits, cache = tf.prefill(params, cfg, toks, dtype=jnp.float32,
                               max_len=24)
    assert logits.shape == (2, 1, cfg.vocab) and _finite(logits)
    nxt = jnp.argmax(logits, -1).astype(jnp.int32)
    logits2, cache2 = tf.decode_step(params, cfg, nxt, cache,
                                     dtype=jnp.float32)
    assert logits2.shape == (2, 1, cfg.vocab) and _finite(logits2)
    assert (np.asarray(cache2.cur_len) == 17).all()


def test_gnn_smoke_all_shapes():
    cfg = get_smoke_config("graphsage-reddit")
    key = jax.random.PRNGKey(0)
    n, e, d, c = 60, 240, 8, 5
    p = gnn_lib.init_sage(key, cfg, d, c)
    feats = jax.random.normal(key, (n, d))
    src = jax.random.randint(jax.random.PRNGKey(1), (e,), 0, n)
    dst = jax.random.randint(jax.random.PRNGKey(2), (e,), 0, n)
    y = jax.random.randint(jax.random.PRNGKey(3), (n,), 0, c)
    # full graph
    logits = gnn_lib.sage_full_forward(p, cfg, feats, src, dst)
    assert logits.shape == (n, c) and _finite(logits)
    # sampled (real neighbor sampler)
    from repro.models.sampler import make_csr
    rp, ci = make_csr(n, np.asarray(src), np.asarray(dst))
    loss = gnn_lib.sampled_train_from_graph(
        p, cfg, jnp.asarray(rp), jnp.asarray(ci), feats, jnp.arange(16),
        y[:16], jax.random.PRNGKey(4), cfg.sample_sizes)
    assert _finite(loss)
    # molecule (batched small graphs)
    adj = (jax.random.uniform(key, (4, 10, 10)) < 0.3).astype(jnp.float32)
    mf = jax.random.normal(key, (4, 10, d))
    out = gnn_lib.sage_molecule_forward(p, cfg, mf, adj)
    assert out.shape == (4, c) and _finite(out)


@pytest.mark.parametrize("arch", RECSYS_ARCHS)
def test_recsys_smoke_train_and_serve(arch):
    cfg = get_smoke_config(arch)
    key = jax.random.PRNGKey(0)
    p = rs.INIT[cfg.kind](key, cfg)
    B = 8
    if cfg.kind in ("fm", "wide_deep"):
        ids = jax.random.randint(key, (B, cfg.n_sparse), 0,
                                 cfg.rows_per_field)
        dense = jax.random.normal(key, (B, cfg.n_dense))
        y = jax.random.randint(key, (B,), 0, 2)
        fwd = rs.fm_forward if cfg.kind == "fm" else rs.wide_deep_forward
        lss = rs.fm_loss if cfg.kind == "fm" else rs.wide_deep_loss
        scores = fwd(p, cfg, ids, dense)
        assert scores.shape == (B,) and _finite(scores)
        g = jax.grad(lambda q: lss(q, cfg, ids, dense, y))(p)
        assert _finite(jax.tree.leaves(g)[0])
    elif cfg.kind == "bert4rec":
        seq = jax.random.randint(key, (B, cfg.seq_len), 0, cfg.n_items)
        mpos = jax.random.randint(key, (B, 4), 0, cfg.seq_len)
        lbl = jax.random.randint(key, (B, 4), 0, cfg.n_items)
        loss = rs.bert4rec_masked_loss(p, cfg, seq, mpos, lbl)
        assert _finite(loss)
        ue = rs.bert4rec_user_embedding(p, cfg, seq)
        assert ue.shape == (B, cfg.embed_dim) and _finite(ue)
    else:  # mind
        beh = jax.random.randint(key, (B, cfg.seq_len), 0, cfg.n_items)
        bm = jnp.ones((B, cfg.seq_len))
        tgt = jax.random.randint(key, (B,), 0, cfg.n_items)
        neg = jax.random.randint(key, (B, 5), 0, cfg.n_items)
        loss = rs.mind_loss(p, cfg, beh, bm, tgt, neg)
        assert _finite(loss)
        interests = rs.mind_user_embedding(p, cfg, beh, bm)
        assert interests.shape == (B, cfg.n_interests, cfg.embed_dim)
        norms = np.linalg.norm(np.asarray(interests), axis=-1)
        np.testing.assert_allclose(norms, 1.0, atol=1e-3)


def test_mandated_long_context_skips_documented():
    for arch in ["llama3-8b", "minitron-8b", "olmoe-1b-7b",
                 "granite-moe-3b-a800m"]:
        assert "long_500k" in get_config(arch).skip_shapes
    assert "long_500k" not in get_config("h2o-danube-3-4b").skip_shapes
