"""Shared fixtures. NOTE: no XLA_FLAGS / device-count overrides here —
smoke tests must see the single real CPU device (multi-device tests spawn
subprocesses; see test_distributed.py)."""
import os
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def assert_finite(tree):
    import jax
    for leaf in jax.tree.leaves(tree):
        assert np.all(np.isfinite(np.asarray(leaf, dtype=np.float64))), \
            "non-finite values"
