"""End-to-end behaviour tests for the paper's system: index -> retrieve ->
augment -> generate, plus the paper-claim invariants (recall, prefetch)."""
import numpy as np
import pytest

from repro.core.interface import HNSW
from repro.core.tiered import simulate_search_traffic
from repro.data.corpus import BUILTIN_CORPUS
from repro.data.synthetic import make_corpus
from repro.serve.rag import RAGPipeline


@pytest.fixture(scope="module")
def corpus_index():
    data = make_corpus(1200, 32, seed=0)
    idx = HNSW(distance_function="cosine", M=8, ef_construction=60)
    idx.bulk_insert([f"d{i}" for i in range(len(data))], data)
    return idx, data


def test_query_recall_vs_exact(corpus_index):
    """HNSW must recover >=85% of true neighbors at ef=64 (paper §3.1)."""
    idx, data = corpus_index
    rng = np.random.default_rng(1)
    hits = total = 0
    for qi in rng.integers(0, len(data), 20):
        q = data[qi] + 0.05 * rng.normal(size=data.shape[1])
        keys, _ = idx.query(q, k=10, ef=64)
        exact_keys, _ = idx.exact_query(q, k=10)
        hits += len({k for k in keys if k} & set(exact_keys))
        total += 10
    assert hits / total >= 0.85, hits / total


def test_query_self_is_nearest(corpus_index):
    idx, data = corpus_index
    keys, dists = idx.query(data[42], k=3)
    assert keys[0] == "d42" and dists[0] < 1e-4


def test_prefetch_reduces_transactions(corpus_index):
    """The paper's §3.2 claim: graph prefetching cuts slow-tier reads."""
    idx, data = corpus_index
    g = idx._graph or idx._builder.graph()
    queries = make_corpus(15, 32, seed=3)
    with_p = simulate_search_traffic(g, queries, ef=32, cache_rows=256,
                                     prefetch_p=16)
    without = simulate_search_traffic(g, queries, ef=32, cache_rows=256,
                                      prefetch_p=1, use_graph_prefetch=False)
    assert with_p.transactions < 0.75 * without.transactions
    assert with_p.as_dict()["hit_rate"] > without.as_dict()["hit_rate"]


def test_rag_end_to_end_retrieves_relevant_docs():
    rag = RAGPipeline()
    rag.add_documents(BUILTIN_CORPUS)
    out = rag.answer("how does mememo prefetch from IndexedDB?", k=3)
    assert any(d.key.startswith("mememo") for d in out["docs"])
    assert "{{user}}" not in out["prompt"]
    assert "{{context}}" not in out["prompt"]
    out2 = rag.answer("bandwidth of a TPU chip", k=2)
    assert out2["docs"][0].key.startswith("tpu")
