"""Fused layer-0 beam search (DESIGN.md §12): kernel-vs-oracle parity,
fused-vs-jnp search parity/recall, tombstones, codecs, launch counting,
and the max_iters=0 / recall_at_k satellite regressions."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dispatch, hnsw, hnsw_build
from repro.core.codec import get_codec
from repro.core.interface import HNSW
from repro.data.synthetic import make_corpus
from repro.kernels import ref
from repro.kernels.beam_search import beam_search_pallas


@pytest.fixture(scope="module")
def built():
    data = make_corpus(1000, 24, seed=0)
    g = hnsw_build.build_sequential(data, M=8, ef_construction=60)
    dg = hnsw.to_device_graph(g)
    queries = make_corpus(32, 24, seed=1)
    qn = queries / np.linalg.norm(queries, axis=1, keepdims=True)
    _, true_i = ref.distance_topk_ref(jnp.asarray(g.vectors),
                                      jnp.asarray(qn), 10, metric="cosine")
    return g, dg, queries, np.asarray(true_i)


# ---------------------------------------------------------------------------
# fused vs jnp through search_graph
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("ef", [16, 64])
def test_fused_t1_exact_parity(built, ef):
    """At expand_t=1 the fused visit order IS the sequential-semantics
    reference order: identical ids, distances to float rounding."""
    g, dg, queries, _ = built
    i_ref, d_ref = hnsw.search_graph(dg, queries, k=10, ef=ef,
                                     beam_impl="jnp")
    i_fus, d_fus = hnsw.search_graph(dg, queries, k=10, ef=ef,
                                     beam_impl="fused", beam_expand=1)
    np.testing.assert_array_equal(np.asarray(i_fus), np.asarray(i_ref))
    np.testing.assert_allclose(np.asarray(d_fus), np.asarray(d_ref),
                               rtol=2e-7, atol=0)


@pytest.mark.parametrize("t", [2, 4])
def test_fused_recall_matches_reference(built, t):
    """T-expansion may visit MORE nodes than the one-at-a-time order,
    never fewer useful ones: recall within 0.005 of the jnp path."""
    g, dg, queries, true_i = built
    i_ref, _ = hnsw.search_graph(dg, queries, k=10, ef=64, beam_impl="jnp")
    i_fus, _ = hnsw.search_graph(dg, queries, k=10, ef=64,
                                 beam_impl="fused", beam_expand=t)
    r_ref = hnsw.recall_at_k(np.asarray(i_ref), true_i)
    r_fus = hnsw.recall_at_k(np.asarray(i_fus), true_i)
    assert r_fus >= r_ref - 0.005, (r_fus, r_ref)
    assert r_fus >= 0.85


def test_fused_tombstone_filtering(built):
    """Deleted rows stay traversable but are never returned — on the
    fused path exactly as on the reference path."""
    g, dg, queries, _ = built
    rng = np.random.default_rng(7)
    deleted = rng.random(g.n) < 0.2
    dgd = hnsw.to_device_graph(g, deleted)
    for impl, kw in (("jnp", {}), ("fused", {}),
                     ("fused", {"beam_expand": 1})):
        ids, dists = hnsw.search_graph(dgd, queries, k=10, ef=64,
                                       beam_impl=impl, **kw)
        ids = np.asarray(ids)
        live = ids[ids >= 0]
        assert not deleted[live].any(), f"{impl} returned deleted ids"
        assert (np.asarray(dists)[ids < 0] >= 1e38).all()
    # T=1 with tombstones is still bitwise the reference
    i_ref, _ = hnsw.search_graph(dgd, queries, k=10, ef=64, beam_impl="jnp")
    i_fus, _ = hnsw.search_graph(dgd, queries, k=10, ef=64,
                                 beam_impl="fused", beam_expand=1)
    np.testing.assert_array_equal(np.asarray(i_fus), np.asarray(i_ref))


def test_fused_all_deleted_returns_nothing(built):
    g, dg, queries, _ = built
    dgd = hnsw.to_device_graph(g, np.ones(g.n, bool))
    for impl in ("jnp", "fused"):
        ids, dists = hnsw.search_graph(dgd, queries, k=10, ef=32,
                                       beam_impl=impl)
        assert (np.asarray(ids) == -1).all()
        assert (np.asarray(dists) >= 1e38).all()


def test_empty_index_raises():
    idx = HNSW()
    with pytest.raises(ValueError, match="empty"):
        idx.query_batch(np.zeros((2, 8), np.float32), k=3)


@pytest.mark.parametrize("dtype", ["int8", "bf16"])
def test_fused_codec_decode_parity(dtype):
    """In-kernel codec decode (DESIGN.md §9): the fused beam over
    encoded rows matches the jnp path over the same encoded rows."""
    data = make_corpus(600, 16, seed=4)
    g = hnsw_build.build_sequential(data, M=8, ef_construction=50)
    codec = get_codec(dtype)
    enc, scales = codec.encode(np.asarray(g.vectors, np.float32))
    dg = hnsw.to_device_graph(g, None, enc=enc, scales=scales)
    queries = make_corpus(16, 16, seed=5)
    i_ref, d_ref = hnsw.search_graph(dg, queries, k=10, ef=48,
                                     beam_impl="jnp")
    i_fus, d_fus = hnsw.search_graph(dg, queries, k=10, ef=48,
                                     beam_impl="fused", beam_expand=1)
    np.testing.assert_array_equal(np.asarray(i_fus), np.asarray(i_ref))
    np.testing.assert_allclose(np.asarray(d_fus), np.asarray(d_ref),
                               rtol=2e-7, atol=0)


# ---------------------------------------------------------------------------
# satellite regressions
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("impl", ["jnp", "fused"])
def test_max_iters_zero_means_zero_expansions(built, impl):
    """max_iters=0 used to be treated as unset (``max_iters or ef``).
    It must mean ZERO beam expansions: only the entry point (as seen
    after the greedy descent) can come back."""
    g, dg, queries, _ = built
    ids, dists = hnsw.search_graph(dg, queries, k=10, ef=64, max_iters=0,
                                   beam_impl=impl)
    ids, dists = np.asarray(ids), np.asarray(dists)
    assert (ids[:, 1:] == -1).all(), "expansions happened at max_iters=0"
    assert (ids[:, 0] >= 0).all()
    assert (dists[:, 0] < 1e38).all()
    # and max_iters=0 really differs from the default budget
    full_ids, _ = hnsw.search_graph(dg, queries, k=10, ef=64,
                                    beam_impl=impl)
    assert (np.asarray(full_ids) >= 0).all()


def test_recall_at_k_matches_set_loop():
    """Vectorized recall_at_k ≡ the per-row Python set loop, including
    -1 pads and duplicated ids on either side."""
    rng = np.random.default_rng(11)
    for _ in range(20):
        b, k = int(rng.integers(1, 9)), int(rng.integers(1, 9))
        found = rng.integers(-1, 12, (b, k))
        true = rng.integers(-1, 12, (b, k))
        hits = 0
        for f_row, t_row in zip(found, true):
            hits += len({int(x) for x in f_row} & {int(x) for x in t_row})
        expect = hits / true.size
        assert hnsw.recall_at_k(found, true) == pytest.approx(expect)
    assert hnsw.recall_at_k(np.zeros((0, 5)), np.zeros((0, 5))) == 0.0


def test_dispatch_counter_fused_one_launch(built):
    """Launch economics (core/dispatch.py): ONE beam launch per fused
    search, O(ef) per jnp search."""
    g, dg, queries, _ = built
    dispatch.reset("hnsw.beam_launches")
    hnsw.search_graph(dg, queries, k=10, ef=64, beam_impl="fused")
    assert dispatch.get("hnsw.beam_launches") == 1
    dispatch.reset("hnsw.beam_launches")
    hnsw.search_graph(dg, queries, k=10, ef=64, beam_impl="jnp")
    assert dispatch.get("hnsw.beam_launches") == 64
    dispatch.reset("hnsw.beam_launches")
    hnsw.search_graph(dg, queries, k=10, ef=64, max_iters=5,
                      beam_impl="jnp")
    assert dispatch.get("hnsw.beam_launches") == 5


def test_beam_impl_validated(built):
    g, dg, queries, _ = built
    with pytest.raises(ValueError, match="beam_impl"):
        hnsw.search_graph(dg, queries, k=10, ef=16, beam_impl="magic")
    with pytest.raises(ValueError, match="beam_impl"):
        HNSW(beam_impl="magic")


# ---------------------------------------------------------------------------
# Pallas kernel (interpret mode) vs the jnp oracle
# ---------------------------------------------------------------------------
def _random_graph(rng, n, d, m2, dtype=np.float32):
    vectors = rng.normal(size=(n, d)).astype(np.float32)
    nbrs = rng.integers(0, n, (n, m2)).astype(np.int32)
    nbrs[rng.random((n, m2)) < 0.15] = -1          # ragged -1 pads
    return vectors.astype(dtype), nbrs


@pytest.mark.parametrize("ef,t,max_iters,metric", [
    (8, 1, None, "cosine"),
    (16, 4, None, "cosine"),
    (16, 2, 5, "l2"),
    (8, 4, 0, "cosine"),
    (16, 3, None, "l2"),               # t does not divide the budget
])
def test_kernel_matches_oracle(ef, t, max_iters, metric):
    rng = np.random.default_rng(ef * 131 + t)
    n, d, b, m2 = 300, 16, 8, 12
    vectors, nbrs = _random_graph(rng, n, d, m2)
    q = rng.normal(size=(b, d)).astype(np.float32)
    ep = rng.integers(0, n, b).astype(np.int32)
    ep_dist = np.asarray(ref.gather_distance_ref(
        jnp.asarray(vectors), jnp.asarray(q), jnp.asarray(ep)[:, None],
        metric=metric))[:, 0]
    args = (jnp.asarray(vectors), jnp.asarray(nbrs), jnp.asarray(q),
            jnp.asarray(ep), jnp.asarray(ep_dist))
    kw = dict(ef=ef, metric=metric, expand_t=t, max_iters=max_iters)
    ki, kd = beam_search_pallas(*args, **kw, interpret=True)
    ri, rd = ref.beam_search_ref(*args, **kw)
    np.testing.assert_array_equal(np.asarray(ki), np.asarray(ri))
    np.testing.assert_allclose(np.asarray(kd), np.asarray(rd),
                               rtol=3e-7, atol=1e-6)


def test_kernel_int8_scales_matches_oracle():
    rng = np.random.default_rng(3)
    n, d, b, m2, ef = 256, 16, 8, 10, 16
    vectors, nbrs = _random_graph(rng, n, d, m2)
    enc, scales = get_codec("int8").encode(vectors)
    q = rng.normal(size=(b, d)).astype(np.float32)
    ep = rng.integers(0, n, b).astype(np.int32)
    ep_dist = np.asarray(ref.gather_distance_ref(
        jnp.asarray(enc), jnp.asarray(q), jnp.asarray(ep)[:, None],
        metric="cosine", scales=jnp.asarray(scales)))[:, 0]
    args = (jnp.asarray(enc), jnp.asarray(nbrs), jnp.asarray(q),
            jnp.asarray(ep), jnp.asarray(ep_dist))
    kw = dict(ef=ef, metric="cosine", scales=jnp.asarray(scales),
              expand_t=4)
    ki, kd = beam_search_pallas(*args, **kw, interpret=True)
    ri, rd = ref.beam_search_ref(*args, **kw)
    np.testing.assert_array_equal(np.asarray(ki), np.asarray(ri))
    np.testing.assert_allclose(np.asarray(kd), np.asarray(rd),
                               rtol=3e-7, atol=1e-6)


def test_kernel_block_shrink_odd_batch():
    """block_q larger than B and a B that needs shrinking both work."""
    rng = np.random.default_rng(9)
    n, d, m2, ef = 200, 8, 8, 8
    vectors, nbrs = _random_graph(rng, n, d, m2)
    for b in (3, 5):
        q = rng.normal(size=(b, d)).astype(np.float32)
        ep = rng.integers(0, n, b).astype(np.int32)
        ep_dist = np.asarray(ref.gather_distance_ref(
            jnp.asarray(vectors), jnp.asarray(q),
            jnp.asarray(ep)[:, None]))[:, 0]
        args = (jnp.asarray(vectors), jnp.asarray(nbrs), jnp.asarray(q),
                jnp.asarray(ep), jnp.asarray(ep_dist))
        ki, kd = beam_search_pallas(*args, ef=ef, expand_t=2,
                                    interpret=True)
        ri, rd = ref.beam_search_ref(*args, ef=ef, expand_t=2)
        np.testing.assert_array_equal(np.asarray(ki), np.asarray(ri))
        np.testing.assert_allclose(np.asarray(kd), np.asarray(rd),
                                   rtol=3e-7, atol=1e-6)


def test_beam_merge_sort_equals_bitonic():
    """The oracle's lax.sort merge and the kernel's bitonic merge are
    the same function on live entries."""
    rng = np.random.default_rng(21)
    b, efp, w, ef = 4, 16, 8, 13
    bd = np.sort(rng.normal(size=(b, efp)).astype(np.float32), axis=-1)
    bi = np.argsort(rng.random((b, efp)), axis=-1).astype(np.int32)
    bx = rng.random((b, efp)) < 0.5
    # candidate ids disjoint from beam ids (dedup runs before merge)
    cd = rng.normal(size=(b, w)).astype(np.float32)
    ci = (rng.permutation(np.arange(100, 100 + w))[None]
          .repeat(b, 0).astype(np.int32))
    a = ref.beam_merge(jnp.asarray(bd), jnp.asarray(bi), jnp.asarray(bx),
                       jnp.asarray(cd), jnp.asarray(ci), ef,
                       use_bitonic=True)
    s = ref.beam_merge(jnp.asarray(bd), jnp.asarray(bi), jnp.asarray(bx),
                       jnp.asarray(cd), jnp.asarray(ci), ef,
                       use_bitonic=False)
    for x, y in zip(a, s):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
