"""Pipeline parallelism (subprocess: needs >1 device)."""
import os
import subprocess
import sys
import textwrap

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_sub(code: str, devices: int = 4) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=480)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_pipeline_matches_sequential():
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.distributed.pipeline import pipeline_apply

        mesh = jax.make_mesh((4,), ("pp",))
        S, M, mb, d = 4, 6, 8, 16
        key = jax.random.PRNGKey(0)
        w = jax.random.normal(key, (S, d, d)) * 0.3
        b = jax.random.normal(jax.random.PRNGKey(1), (S, d)) * 0.1
        params = {"w": w, "b": b}
        x = jax.random.normal(jax.random.PRNGKey(2), (M, mb, d))

        def stage(p, h):
            return jnp.tanh(h @ p["w"] + p["b"])

        got = pipeline_apply(mesh, "pp", stage, params, x)
        # sequential oracle
        want = x
        for s in range(S):
            ps = jax.tree.map(lambda a: a[s], params)
            want = jax.vmap(lambda h: stage(ps, h))(want)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)
        print("OK")
    """)
    assert "OK" in out


def test_bubble_fraction():
    from repro.distributed.pipeline import pipeline_bubble_fraction
    assert pipeline_bubble_fraction(4, 12) == 3 / 15
    assert pipeline_bubble_fraction(1, 8) == 0.0
