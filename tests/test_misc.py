"""Interface parity, tiered store, HLO analyzer, data pipeline, costs."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.interface import HNSW
from repro.core.tiered import TieredVectorStore, auto_prefetch_p
from repro.data import synthetic
from repro.data.corpus import HashingEncoder, encode_ids


# ---------------------------------------------------------------------------
# Code-1 API parity
# ---------------------------------------------------------------------------
def test_code1_api_parity():
    """The exact call sequence of the paper's Code 1."""
    values = synthetic.make_corpus(300, 16, seed=0)
    keys = [f"k{i}" for i in range(300)]
    index = HNSW(distance_function="cosine")        # defaults like the TS lib
    index.bulkInsert(keys, values)                  # camelCase alias
    found, distances = index.query(values[7], 5)
    assert found[0] == "k7"
    assert len(found) == len(distances) == 5


def test_incremental_insert_then_query():
    idx = HNSW(distance_function="l2", M=8, ef_construction=40)
    rng = np.random.default_rng(0)
    for i in range(64):
        idx.insert(f"v{i}", rng.normal(size=8))
    assert idx.size == 64
    keys, _ = idx.query(np.zeros(8), k=3)
    assert len(keys) == 3


def test_export_load_roundtrip():
    values = synthetic.make_corpus(200, 12, seed=1)
    idx = HNSW(distance_function="cosine", M=6, ef_construction=30)
    idx.bulk_insert([f"d{i}" for i in range(200)], values)
    with tempfile.TemporaryDirectory() as td:
        p = os.path.join(td, "idx.npz")
        idx.export_index(p)
        idx2 = HNSW.load_index(p)
        k1, d1 = idx.query(values[3], k=5)
        k2, d2 = idx2.query(values[3], k=5)
        assert k1 == k2
        np.testing.assert_allclose(d1, d2, rtol=1e-6)


def test_bad_metric_rejected():
    with pytest.raises(ValueError):
        HNSW(distance_function="manhattan")


# ---------------------------------------------------------------------------
# tiered store mechanics
# ---------------------------------------------------------------------------
def test_tiered_lru_eviction_and_counters():
    data = np.arange(40, dtype=np.float32).reshape(10, 4)
    st = TieredVectorStore(data, cache_rows=4, prefetch_p=1)
    st.read([0, 1, 2, 3])
    assert st.stats.misses == 4 and st.stats.hits == 0
    st.read([0])
    assert st.stats.hits == 1
    st.read([4, 5])                      # evicts 1, 2 (LRU; 0 was touched)
    assert st.stats.evictions == 2
    got = st.read([7])
    np.testing.assert_array_equal(got[0], data[7])


def test_auto_prefetch_matches_paper_scaling():
    """p scales inversely with dim (paper: auto from vector dimension)."""
    assert auto_prefetch_p(384) < auto_prefetch_p(64)
    assert auto_prefetch_p(384) >= 1


# ---------------------------------------------------------------------------
# hashing encoder / tokenizer
# ---------------------------------------------------------------------------
def test_hashing_encoder_deterministic_and_normalised():
    enc = HashingEncoder(dim=64)
    v1 = enc.encode("hello world retrieval")
    v2 = enc.encode("hello world retrieval")
    np.testing.assert_array_equal(v1, v2)
    np.testing.assert_allclose(np.linalg.norm(v1[0]), 1.0, atol=1e-5)
    # related text closer than unrelated
    a = enc.encode(["dense retrieval with graphs",
                    "graph based dense retrieval",
                    "cooking pasta with tomatoes"])
    assert a[0] @ a[1] > a[0] @ a[2]


def test_encode_ids_fixed_shape():
    ids = encode_ids("a b c", vocab=100, max_len=8)
    assert ids.shape == (8,) and ids.dtype == np.int32
    assert (ids[3:] == 0).all() and (ids[:3] > 0).all()


# ---------------------------------------------------------------------------
# HLO analyzer: scan-trip correction on a real compiled program
# ---------------------------------------------------------------------------
def test_hlo_analyzer_counts_scan_trips():
    from repro.launch.hlo_analysis import analyze

    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        out, _ = jax.lax.scan(body, x, None, length=5)
        return out.sum()

    x = jax.ShapeDtypeStruct((8, 16), jnp.float32)
    w = jax.ShapeDtypeStruct((16, 16), jnp.float32)
    txt = jax.jit(f).lower(x, w).compile().as_text()
    an = analyze(txt)
    dot_flops = 2 * 8 * 16 * 16
    assert an["flops"] >= 5 * dot_flops          # 5 trips counted
    assert an["flops"] < 12 * dot_flops


def test_hlo_analyzer_scan_equals_unroll():
    from repro.launch.hlo_analysis import analyze

    def scan_f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        return jax.lax.scan(body, x, None, length=4)[0].sum()

    def unroll_f(x, w):
        for _ in range(4):
            x = jnp.tanh(x @ w)
        return x.sum()

    x = jax.ShapeDtypeStruct((8, 16), jnp.float32)
    w = jax.ShapeDtypeStruct((16, 16), jnp.float32)
    a = analyze(jax.jit(scan_f).lower(x, w).compile().as_text())
    b = analyze(jax.jit(unroll_f).lower(x, w).compile().as_text())
    assert abs(a["flops"] - b["flops"]) / b["flops"] < 0.25


# ---------------------------------------------------------------------------
# synthetic data
# ---------------------------------------------------------------------------
def test_ctr_batches_deterministic():
    a = next(synthetic.ctr_batches(5, 100, 3, 16, seed=1, start_step=2))
    b = next(synthetic.ctr_batches(5, 100, 3, 16, seed=1, start_step=2))
    np.testing.assert_array_equal(a["sparse_ids"], b["sparse_ids"])
    assert set(np.unique(a["labels"])) <= {0, 1}


def test_make_graph_homophily():
    g = synthetic.make_graph(400, 6, 8, 4, seed=0)
    same = (g.labels[g.edge_src] == g.labels[g.edge_dst]).mean()
    assert same > 0.5       # community structure exists
    assert g.row_ptr[-1] == len(g.col_idx)
