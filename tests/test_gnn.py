"""GraphSAGE: segment-sum message passing vs dense-adjacency oracle,
neighbor sampler properties."""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.configs import get_smoke_config
from repro.models import gnn as gnn_lib
from repro.models.common import l2_normalize
from repro.models.sampler import make_csr, sample_neighbors


def test_segment_sum_matches_dense_adjacency():
    """Full-graph forward == molecule (dense adjacency) forward on the
    same graph: two independent lowerings of the same math."""
    cfg = get_smoke_config("graphsage-reddit")
    n, d, c = 20, 6, 3
    key = jax.random.PRNGKey(0)
    p = gnn_lib.init_sage(key, cfg, d, c)
    feats = jax.random.normal(key, (n, d))
    adj = (jax.random.uniform(jax.random.PRNGKey(1), (n, n)) < 0.3)
    adj = adj.astype(jnp.float32)
    src, dst = jnp.nonzero(adj.T)    # edge src->dst: adj[dst, src]=1
    out_seg = gnn_lib.sage_full_forward(p, cfg, feats, src.astype(jnp.int32),
                                        dst.astype(jnp.int32))
    # dense path on a batch of one graph, without the pooling head
    h = feats
    deg = jnp.maximum(adj.sum(-1, keepdims=True), 1.0)
    for lp in p["layers"]:
        agg = (adj @ h) / deg
        h = gnn_lib._sage_layer(lp, h, agg, final=False)
    out_dense = h @ p["w_out"]
    np.testing.assert_allclose(np.asarray(out_seg), np.asarray(out_dense),
                               rtol=1e-4, atol=1e-4)


def test_sampler_ids_are_neighbors():
    rng = np.random.default_rng(0)
    n, e = 40, 200
    src = rng.integers(0, n, e)
    dst = rng.integers(0, n, e)
    rp, ci = make_csr(n, src, dst)
    adj = {i: set() for i in range(n)}
    for s, t in zip(src, dst):
        adj[int(s)].add(int(t))
    seeds = jnp.arange(n)
    out = np.asarray(sample_neighbors(jax.random.PRNGKey(0),
                                      jnp.asarray(rp), jnp.asarray(ci),
                                      seeds, 7))
    for i in range(n):
        for x in out[i]:
            if adj[i]:
                assert int(x) in adj[i], (i, x)
            else:
                assert int(x) == i       # degree-0 -> self loop


@given(fanout=st.integers(1, 12))
@settings(max_examples=6, deadline=None)
def test_sampler_shape_and_bounds(fanout):
    rng = np.random.default_rng(1)
    n = 25
    src = rng.integers(0, n, 80)
    dst = rng.integers(0, n, 80)
    rp, ci = make_csr(n, src, dst)
    out = sample_neighbors(jax.random.PRNGKey(1), jnp.asarray(rp),
                           jnp.asarray(ci), jnp.arange(10), fanout)
    assert out.shape == (10, fanout)
    assert (np.asarray(out) >= 0).all() and (np.asarray(out) < n).all()


def test_training_improves_on_community_graph():
    """GraphSAGE should beat chance on the homophilous synthetic graph."""
    from repro.data.synthetic import make_graph
    from repro.train.optimizer import AdamWConfig
    from repro.train.train_loop import make_train_step

    cfg = get_smoke_config("graphsage-reddit")
    g = make_graph(300, 8, 16, 4, seed=0)
    p = gnn_lib.init_sage(jax.random.PRNGKey(0), cfg, 16, 4)
    feats, src, dst = map(jnp.asarray, (g.feats, g.edge_src, g.edge_dst))
    y = jnp.asarray(g.labels)
    mask = jnp.ones_like(y, jnp.float32)

    loss_fn = lambda p_, **_: gnn_lib.sage_full_loss(p_, cfg, feats, src,
                                                     dst, y, mask)
    step = make_train_step(loss_fn, AdamWConfig(lr=1e-2), donate=False)
    from repro.train.optimizer import adamw_init
    opt = adamw_init(p)
    losses = []
    for i in range(30):
        p, opt, m = step(p, opt, {})
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.7, losses[::10]
    logits = gnn_lib.sage_full_forward(p, cfg, feats, src, dst)
    acc = float((jnp.argmax(logits, -1) == y).mean())
    assert acc > 0.5, acc       # 4 classes -> chance 0.25
