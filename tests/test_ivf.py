"""IVF-Flat index (beyond-paper ANN backend)."""
import jax.numpy as jnp
import numpy as np

from repro.core.ivf import build_ivf, search_ivf
from repro.data.synthetic import make_corpus
from repro.kernels import ref


def test_ivf_recall_on_clustered_data():
    n, dim = 4000, 32
    data = make_corpus(n, dim, seed=0)
    idx = build_ivf(data, nlist=32, metric="cosine")
    rng = np.random.default_rng(1)
    queries = (data[rng.integers(0, n, 24)]
               + 0.1 * rng.normal(size=(24, dim)).astype(np.float32))
    qn = queries / np.linalg.norm(queries, axis=1, keepdims=True)
    _, true_i = ref.distance_topk_ref(idx.vectors, jnp.asarray(qn), 10)
    ids, dists = search_ivf(idx, queries, k=10, nprobe=8)
    hits = sum(len(set(np.asarray(ids)[r]) & set(np.asarray(true_i)[r]))
               for r in range(24))
    assert hits / 240 >= 0.8, hits / 240
    # nprobe=nlist must be exact
    ids_all, _ = search_ivf(idx, queries, k=10, nprobe=32)
    hits = sum(len(set(np.asarray(ids_all)[r]) & set(np.asarray(true_i)[r]))
               for r in range(24))
    assert hits / 240 >= 0.999


def test_ivf_recall_increases_with_nprobe():
    data = make_corpus(2000, 16, seed=2)
    idx = build_ivf(data, nlist=16)
    rng = np.random.default_rng(3)
    queries = data[rng.integers(0, 2000, 16)] + 0.05 * rng.normal(
        size=(16, 16)).astype(np.float32)
    qn = queries / np.linalg.norm(queries, axis=1, keepdims=True)
    _, true_i = ref.distance_topk_ref(idx.vectors, jnp.asarray(qn), 5)
    rec = []
    for nprobe in (1, 4, 16):
        ids, _ = search_ivf(idx, queries, k=5, nprobe=nprobe)
        rec.append(sum(len(set(np.asarray(ids)[r]) & set(np.asarray(true_i)[r]))
                       for r in range(16)) / 80)
    assert rec[0] <= rec[1] + 0.05 and rec[1] <= rec[2] + 1e-9
    assert rec[2] >= 0.99


def test_ivf_self_query():
    data = make_corpus(800, 24, seed=4)
    idx = build_ivf(data, nlist=16)
    ids, dists = search_ivf(idx, data[123], k=1, nprobe=4)
    assert int(ids[0]) == 123 and float(dists[0]) < 1e-5
