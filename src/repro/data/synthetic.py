"""Deterministic synthetic data pipeline (seeded, shard-aware).

Every generator yields numpy batches from a counting PRNG stream, so any
batch index is reproducible from (seed, step) alone — which is what lets a
restarted/re-sharded training job replay the exact stream from its restored
step (fault tolerance without data-loader state).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np


def _rng(seed: int, step: int) -> np.random.Generator:
    return np.random.default_rng(np.random.SeedSequence([seed, step]))


# ---------------------------------------------------------------------------
# LM token stream
# ---------------------------------------------------------------------------
def lm_batches(vocab: int, batch: int, seq: int, *, seed: int = 0,
               start_step: int = 0, dp_rank: int = 0, dp_size: int = 1
               ) -> Iterator[dict]:
    """Markov-ish synthetic token stream (not uniform: gives learnable
    structure so loss decreases in the e2e example)."""
    step = start_step
    while True:
        rng = _rng(seed, step * dp_size + dp_rank)
        base = rng.integers(0, vocab, size=(batch, 1))
        drift = rng.integers(-16, 17, size=(batch, seq)).cumsum(axis=1)
        toks = np.abs(base + drift) % vocab
        yield {"tokens": toks[:, :-1].astype(np.int32),
               "labels": toks[:, 1:].astype(np.int32)}
        step += 1


# ---------------------------------------------------------------------------
# RecSys streams
# ---------------------------------------------------------------------------
def ctr_batches(n_sparse: int, rows_per_field: int, n_dense: int, batch: int,
                *, seed: int = 0, start_step: int = 0) -> Iterator[dict]:
    step = start_step
    while True:
        rng = _rng(seed, step)
        ids = rng.zipf(1.2, size=(batch, n_sparse)) % rows_per_field
        dense = rng.normal(size=(batch, n_dense)).astype(np.float32)
        # planted linear signal so training can actually fit something
        w = np.random.default_rng(seed).normal(size=n_dense)
        logit = dense @ w + 0.1 * (ids.sum(-1) % 7 - 3)
        y = (logit + rng.logistic(size=batch) > 0).astype(np.int32)
        yield {"sparse_ids": ids.astype(np.int32), "dense": dense, "labels": y}
        step += 1


def seq_rec_batches(n_items: int, seq_len: int, batch: int, *, seed: int = 0,
                    start_step: int = 0, n_neg: int = 16) -> Iterator[dict]:
    step = start_step
    while True:
        rng = _rng(seed, step)
        # clustered user tastes: items drawn around a per-user center
        center = rng.integers(0, n_items, size=(batch, 1))
        seq = (center + rng.integers(-50, 51, size=(batch, seq_len))) % n_items
        target = (center[:, 0] + rng.integers(-50, 51, size=batch)) % n_items
        neg = rng.integers(0, n_items, size=(batch, n_neg))
        mask_len = rng.integers(seq_len // 2, seq_len + 1, size=batch)
        mask = (np.arange(seq_len)[None] < mask_len[:, None])
        yield {"behavior": seq.astype(np.int32),
               "behavior_mask": mask.astype(np.float32),
               "target": target.astype(np.int32),
               "neg": neg.astype(np.int32)}
        step += 1


def masked_item_batches(n_items: int, seq_len: int, batch: int, *,
                        seed: int = 0, start_step: int = 0,
                        mask_rate: float = 0.2) -> Iterator[dict]:
    mask_id = n_items          # reserved token
    step = start_step
    while True:
        rng = _rng(seed, step)
        center = rng.integers(0, n_items, size=(batch, 1))
        seq = (center + rng.integers(-50, 51, size=(batch, seq_len))) % n_items
        m = rng.random((batch, seq_len)) < mask_rate
        inp = np.where(m, mask_id, seq)
        yield {"item_seq": inp.astype(np.int32),
               "labels": seq.astype(np.int32),
               "label_mask": m.astype(np.float32)}
        step += 1


# ---------------------------------------------------------------------------
# Graphs
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class SyntheticGraph:
    feats: np.ndarray       # [N, D]
    labels: np.ndarray      # [N]
    edge_src: np.ndarray    # [E]
    edge_dst: np.ndarray    # [E]
    row_ptr: np.ndarray     # CSR
    col_idx: np.ndarray


def make_graph(n_nodes: int, avg_degree: int, d_feat: int, n_classes: int,
               *, seed: int = 0) -> SyntheticGraph:
    """Community graph: labels = communities; features = noisy label means —
    so GraphSAGE aggregation genuinely helps (homophily)."""
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, n_classes, size=n_nodes)
    e = n_nodes * avg_degree
    src = rng.integers(0, n_nodes, size=e)
    same = rng.random(e) < 0.7
    # intra-community edge: pick dst with the same label via label buckets
    buckets = [np.where(labels == c)[0] for c in range(n_classes)]
    dst = rng.integers(0, n_nodes, size=e)        # default: random edge
    for c in range(n_classes):
        sel = same & (labels[src] == c)
        if sel.any() and len(buckets[c]):
            dst[sel] = rng.choice(buckets[c], size=int(sel.sum()))
    centers = rng.normal(size=(n_classes, d_feat)) * 2.0
    feats = centers[labels] + rng.normal(size=(n_nodes, d_feat))
    from repro.models.sampler import make_csr
    row_ptr, col_idx = make_csr(n_nodes, src, dst)
    return SyntheticGraph(feats.astype(np.float32), labels.astype(np.int32),
                          src.astype(np.int32), dst.astype(np.int32),
                          row_ptr, col_idx)


def molecule_batches(batch: int, n_nodes: int, d_feat: int, n_classes: int,
                     *, seed: int = 0, start_step: int = 0,
                     edge_p: float = 0.15) -> Iterator[dict]:
    step = start_step
    while True:
        rng = _rng(seed, step)
        adj = (rng.random((batch, n_nodes, n_nodes)) < edge_p)
        adj = np.maximum(adj, adj.transpose(0, 2, 1)).astype(np.float32)
        feats = rng.normal(size=(batch, n_nodes, d_feat)).astype(np.float32)
        labels = (adj.sum((1, 2)) > edge_p * n_nodes * n_nodes).astype(np.int32) \
            % n_classes
        yield {"feats": feats, "adj": adj, "labels": labels}
        step += 1


# ---------------------------------------------------------------------------
# Retrieval corpora (clustered: realistic ANN difficulty)
# ---------------------------------------------------------------------------
def make_corpus(n: int, dim: int, *, n_clusters: int = 64, seed: int = 0
                ) -> np.ndarray:
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(n_clusters, dim)).astype(np.float32) * 1.5
    assign = rng.integers(0, n_clusters, size=n)
    return (centers[assign]
            + rng.normal(size=(n, dim)).astype(np.float32)).astype(np.float32)
