"""Document store + tokenizer + hashing embedder for the RAG pipeline.

No pretrained weights exist in this container, so the default embedder is a
deterministic *hashed bag-of-ngrams random projection*: genuinely useful
lexical-semantic retrieval (same family as classic LSA/feature hashing),
replacing GTE-small in the paper's pipeline. The neural path
(models/encoder.py) plugs into the same interface for in-framework-trained
embeddings.
"""
from __future__ import annotations

import dataclasses
import hashlib
import re

import numpy as np

_WORD = re.compile(r"[a-z0-9]+")


def tokenize(text: str) -> list[str]:
    return _WORD.findall(text.lower())


def hash_token(tok: str, vocab: int) -> int:
    h = hashlib.blake2b(tok.encode(), digest_size=8).digest()
    return int.from_bytes(h, "little") % vocab


def encode_ids(text: str, vocab: int, max_len: int) -> np.ndarray:
    ids = [hash_token(t, vocab - 2) + 2 for t in tokenize(text)][:max_len]
    out = np.zeros(max_len, np.int32)          # 0 = pad
    out[: len(ids)] = ids
    return out


class HashingEncoder:
    """text -> unit-norm dense vector. Hashed 1-2gram counts -> fixed random
    projection (seeded): deterministic, vocabulary-free, no training."""

    def __init__(self, dim: int = 384, buckets: int = 2 ** 18, seed: int = 0):
        self.dim = dim
        self.buckets = buckets
        rng = np.random.default_rng(seed)
        # projection realised lazily per bucket via hashing trick:
        # row r of the projection = rademacher stream seeded by (seed, r)
        self.seed = seed

    def _bucket_vec(self, b: int) -> np.ndarray:
        rng = np.random.default_rng(np.random.SeedSequence([self.seed, b]))
        return rng.standard_normal(self.dim).astype(np.float32)

    def encode(self, texts) -> np.ndarray:
        if isinstance(texts, str):
            texts = [texts]
        out = np.zeros((len(texts), self.dim), np.float32)
        for i, t in enumerate(texts):
            toks = tokenize(t)
            grams = toks + [a + "_" + b for a, b in zip(toks, toks[1:])]
            for g in grams:
                out[i] += self._bucket_vec(hash_token(g, self.buckets))
            n = np.linalg.norm(out[i])
            if n > 0:
                out[i] /= n
        return out


@dataclasses.dataclass
class Document:
    key: str
    text: str


class DocumentStore:
    """Key-value raw-document store — the IndexedDB counterpart (§2.1: raw
    docs in IndexedDB, HNSW keys match)."""

    def __init__(self):
        self._docs: dict[str, Document] = {}

    def add(self, key: str, text: str):
        self._docs[key] = Document(key, text)

    def get(self, key: str) -> Document:
        return self._docs[key]

    def remove(self, key: str):
        del self._docs[key]

    def __len__(self):
        return len(self._docs)

    def keys(self) -> list[str]:
        return list(self._docs)

    def texts(self) -> list[str]:
        return [d.text for d in self._docs.values()]


# a small built-in corpus so examples run offline (paper/table facts)
BUILTIN_CORPUS = [
    ("hnsw-0", "HNSW builds a multilayer graph where each node keeps at most "
               "M neighbors per layer and search descends greedily from the "
               "top layer."),
    ("hnsw-1", "The efConstruction parameter controls how many candidates "
               "are examined while inserting a new element into an HNSW "
               "index."),
    ("hnsw-2", "Query-time recall of HNSW rises with the efSearch beam "
               "width at the cost of more distance computations."),
    ("mememo-0", "MeMemo stores vector payloads in IndexedDB and keeps only "
                 "keys and the HNSW graph topology in RAM."),
    ("mememo-1", "MeMemo prefetches p graph neighbors of a missed element "
                 "in one IndexedDB transaction to amortize slow storage "
                 "reads."),
    ("mememo-2", "Inserting one million 384 dimensional vectors with M 5 "
                 "and efConstruction 20 took about 94 minutes in Chrome."),
    ("rag-0", "Retrieval augmented generation grounds a language model "
              "response with documents fetched from an external knowledge "
              "base."),
    ("rag-1", "RAG Playground lets developers paste a query, inspect "
              "retrieved documents, and edit the prompt template with user "
              "and context placeholders."),
    ("tpu-0", "A TPU v5e chip reaches 197 teraflops in bfloat16 with 819 "
              "gigabytes per second of HBM bandwidth."),
    ("tpu-1", "Pallas kernels tile HBM arrays into VMEM blocks so the MXU "
              "systolic array stays fed."),
    ("priv-0", "On device retrieval keeps personal documents private "
               "because no query or document ever leaves the client."),
    ("priv-1", "Personal finance, education, and medicine are domains "
               "where data privacy forbids server side retrieval."),
]


def builtin_store() -> DocumentStore:
    store = DocumentStore()
    for k, t in BUILTIN_CORPUS:
        store.add(k, t)
    return store
