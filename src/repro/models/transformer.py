"""Decoder-only transformer LM: GQA + RoPE + RMSNorm + SwiGLU (+ SWA, + MoE).

Layer parameters are stacked along a leading L axis and the layer stack runs
under ``jax.lax.scan`` (keeps the HLO O(1) in depth — essential for the
single-core dry-run compiles) with optional per-layer remat.

Entry points:
  init_lm / lm_param_axes                 params + logical sharding axes
  lm_loss(params, cfg, tokens, labels)    training loss (full or chunked vocab)
  prefill(params, cfg, tokens)            build KV cache, return last logits
  decode_step(params, cfg, token, cache)  one token through the cache
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import LMConfig
from repro.distributed.sharding import shard
from repro.models import moe as moe_lib
from repro.models.attention import (
    blocked_attention,
    decode_attention,
    swa_blocked_attention,
)
from repro.models.common import normal_init, rms_norm, apply_rope, softmax_xent


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------
def init_lm(key, cfg: LMConfig, dtype=jnp.float32) -> dict:
    L, D, V = cfg.n_layers, cfg.d_model, cfg.vocab
    H, KVH, Dh, F = cfg.n_heads, cfg.n_kv_heads, cfg.dh, cfg.d_ff
    ks = jax.random.split(key, 10)
    params: dict[str, Any] = {
        "embed": normal_init(ks[0], (V, D), 0.02, dtype),
        "final_norm": jnp.ones((D,), dtype),
        "layers": {
            "attn_norm": jnp.ones((L, D), dtype),
            "ffn_norm": jnp.ones((L, D), dtype),
            "wq": normal_init(ks[1], (L, D, H * Dh), 0.02, dtype),
            "wk": normal_init(ks[2], (L, D, KVH * Dh), 0.02, dtype),
            "wv": normal_init(ks[3], (L, D, KVH * Dh), 0.02, dtype),
            "wo": normal_init(ks[4], (L, H * Dh, D), 0.02 / (2 * L) ** 0.5, dtype),
        },
    }
    if cfg.moe is not None:
        params["layers"].update(moe_lib.init_moe_layer(ks[5], L, D, cfg.moe))
    else:
        params["layers"].update({
            "w1": normal_init(ks[6], (L, D, F), 0.02, dtype),
            "w3": normal_init(ks[7], (L, D, F), 0.02, dtype),
            "w2": normal_init(ks[8], (L, F, D), 0.02 / (2 * L) ** 0.5, dtype),
        })
    if not cfg.tie_embeddings:
        params["out_head"] = normal_init(ks[9], (D, V), 0.02, dtype)
    return params


def lm_param_axes(cfg: LMConfig) -> dict:
    """Logical sharding axes, mirroring the params tree."""
    axes: dict[str, Any] = {
        "embed": ("vocab", "embed"),
        "final_norm": ("embed",),
        "layers": {
            "attn_norm": ("layers", "embed"),
            "ffn_norm": ("layers", "embed"),
            "wq": ("layers", "embed", "heads"),
            "wk": ("layers", "embed", "kv_heads"),
            "wv": ("layers", "embed", "kv_heads"),
            "wo": ("layers", "heads", "embed"),
        },
    }
    if cfg.moe is not None:
        axes["layers"].update(moe_lib.moe_layer_axes())
    else:
        axes["layers"].update({
            "w1": ("layers", "embed", "mlp"),
            "w3": ("layers", "embed", "mlp"),
            "w2": ("layers", "mlp", "embed"),
        })
    if not cfg.tie_embeddings:
        axes["out_head"] = ("embed", "vocab")
    return axes


# ---------------------------------------------------------------------------
# Layer body (shared by train / prefill / decode)
# ---------------------------------------------------------------------------
def _w(lp: dict, name: str, dtype, *axes) -> jax.Array:
    """Weight in compute dtype with its sharding pinned BEFORE use, so any
    FSDP all-gather moves bf16 bytes, not the f32 master copy (halves the
    dominant collective term — EXPERIMENTS.md §Perf A)."""
    return shard(lp[name].astype(dtype), *axes)


def _qkv(lp: dict, cfg: LMConfig, h: jax.Array, positions: jax.Array):
    """h [B,S,D] -> q [B,S,H,Dh], k,v [B,S,KVH,Dh] with RoPE applied."""
    B, S, D = h.shape
    H, KVH, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.dh
    q = jnp.einsum("bsd,dh->bsh", h, _w(lp, "wq", h.dtype, "embed", "heads"),
                   preferred_element_type=jnp.float32).astype(h.dtype)
    k = jnp.einsum("bsd,dh->bsh", h, _w(lp, "wk", h.dtype, "embed", "kv_heads"),
                   preferred_element_type=jnp.float32).astype(h.dtype)
    v = jnp.einsum("bsd,dh->bsh", h, _w(lp, "wv", h.dtype, "embed", "kv_heads"),
                   preferred_element_type=jnp.float32).astype(h.dtype)
    q = shard(q.reshape(B, S, H, Dh), "batch", "seq", "act_heads", None)
    k = k.reshape(B, S, KVH, Dh)
    v = v.reshape(B, S, KVH, Dh)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _attn_out(lp: dict, cfg: LMConfig, attn: jax.Array) -> jax.Array:
    B, S = attn.shape[:2]
    out = jnp.einsum("bsh,hd->bsd", attn.reshape(B, S, -1),
                     _w(lp, "wo", attn.dtype, "heads", "embed"),
                     preferred_element_type=jnp.float32).astype(attn.dtype)
    return shard(out, "batch", "seq", "act_embed")


def _dense_ffn(lp: dict, cfg: LMConfig, h: jax.Array) -> jax.Array:
    h1 = jnp.einsum("bsd,df->bsf", h, _w(lp, "w1", h.dtype, "embed", "mlp"),
                    preferred_element_type=jnp.float32)
    h3 = jnp.einsum("bsd,df->bsf", h, _w(lp, "w3", h.dtype, "embed", "mlp"),
                    preferred_element_type=jnp.float32)
    g = shard((jax.nn.silu(h1) * h3).astype(h.dtype), "batch", "seq", "mlp")
    out = jnp.einsum("bsf,fd->bsd", g, _w(lp, "w2", h.dtype, "mlp", "embed"),
                     preferred_element_type=jnp.float32).astype(h.dtype)
    return shard(out, "batch", "seq", "act_embed")


def _ffn(lp: dict, cfg: LMConfig, x: jax.Array) -> tuple[jax.Array, jax.Array]:
    h = rms_norm(x, lp["ffn_norm"], cfg.norm_eps)
    if cfg.moe is not None:
        B, S, D = h.shape
        out, aux = moe_lib.moe_ffn(
            {k: lp[k] for k in ("router", "we1", "we2", "we3")},
            cfg.moe, h.reshape(B * S, D))
        return x + out.reshape(B, S, D), aux
    return x + _dense_ffn(lp, cfg, h), jnp.zeros((), jnp.float32)


def _train_layer(cfg: LMConfig, impl: str, x: jax.Array, lp: dict):
    """One decoder layer on a full sequence (no cache)."""
    B, S, _ = x.shape
    positions = jnp.arange(S)[None, :]
    h = rms_norm(x, lp["attn_norm"], cfg.norm_eps)
    q, k, v = _qkv(lp, cfg, h, positions)
    if cfg.sliding_window is not None:
        attn = swa_blocked_attention(q, k, v, window=cfg.sliding_window,
                                     block_q=cfg.attn_block_q,
                                     block_k=cfg.attn_block_q)
    else:
        attn = blocked_attention(q, k, v, causal=True, impl=impl,
                                 block_q=cfg.attn_block_q,
                                 block_k=cfg.attn_block_k)
    x = x + _attn_out(lp, cfg, attn)
    x, aux = _ffn(lp, cfg, x)
    return x, aux


# ---------------------------------------------------------------------------
# Full forward / loss
# ---------------------------------------------------------------------------
def _embed(params: dict, cfg: LMConfig, tokens: jax.Array, dtype) -> jax.Array:
    x = jnp.take(params["embed"], tokens, axis=0).astype(dtype)
    return shard(x, "batch", "seq", "act_embed")


def _head(params: dict, cfg: LMConfig, x: jax.Array) -> jax.Array:
    if cfg.tie_embeddings:
        w = shard(params["embed"].astype(x.dtype), "vocab", "embed").T
    else:
        w = shard(params["out_head"].astype(x.dtype), "embed", "vocab")
    logits = jnp.einsum("bsd,dv->bsv", x, w,
                        preferred_element_type=jnp.float32)
    return shard(logits, "batch", "seq", "vocab")


def cast_params_for_compute(params: dict, cfg: LMConfig, dtype) -> dict:
    """One sharding-pinned cast of the whole parameter tree to the compute
    dtype at step entry: every downstream FSDP all-gather then moves bf16
    bytes instead of the f32 master copy (halves weight-gather traffic)."""
    if params["embed"].dtype == dtype:
        return params
    axes = lm_param_axes(cfg)

    def cast(p, a):
        return shard(p.astype(dtype), *a)

    return jax.tree.map(cast, params, axes,
                        is_leaf=lambda x: isinstance(x, tuple)
                        and all(isinstance(y, (str, type(None))) for y in x))


def forward_hidden(params: dict, cfg: LMConfig, tokens: jax.Array,
                   dtype=jnp.bfloat16, impl: str = "masked"):
    """Token ids [B,S] -> final hidden states [B,S,D], plus MoE aux loss."""
    params = cast_params_for_compute(params, cfg, dtype)
    x = _embed(params, cfg, tokens, dtype)
    body = functools.partial(_train_layer, cfg, impl)
    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)

    def scan_fn(carry, lp):
        x, aux = carry
        x, a = body(x, lp)
        return (x, aux + a), None

    if cfg.scan_layers:
        (x, aux), _ = jax.lax.scan(scan_fn, (x, jnp.zeros((), jnp.float32)),
                                   params["layers"])
    else:
        aux = jnp.zeros((), jnp.float32)
        for i in range(cfg.n_layers):
            lp = jax.tree.map(lambda p: p[i], params["layers"])
            x, a = body(x, lp)
            aux = aux + a
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x, aux


def lm_loss(params: dict, cfg: LMConfig, tokens: jax.Array, labels: jax.Array,
            dtype=jnp.bfloat16, impl: str = "masked") -> jax.Array:
    """Causal LM loss. ``cfg.chunked_loss``>0 scans the vocab projection over
    sequence chunks under remat — never materialises [B,S,V] logits."""
    params = cast_params_for_compute(params, cfg, dtype)
    x, aux = forward_hidden(params, cfg, tokens, dtype, impl)
    if cfg.chunked_loss <= 0:
        logits = _head(params, cfg, x)
        return softmax_xent(logits, labels) + aux

    B, S, D = x.shape
    cs = min(cfg.chunked_loss, S)
    assert S % cs == 0
    if cfg.tie_embeddings:
        w = shard(params["embed"].astype(x.dtype), "vocab", "embed").T
    else:
        w = shard(params["out_head"].astype(x.dtype), "embed", "vocab")

    @functools.partial(jax.checkpoint, prevent_cse=False)
    def chunk_nll(x_c, y_c):
        logits = jnp.einsum("bsd,dv->bsv", x_c, w,
                            preferred_element_type=jnp.float32)
        logits = shard(logits, "batch", "seq", "vocab")
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, y_c[..., None], axis=-1)[..., 0]
        return jnp.sum(lse - ll)

    def step(tot, i):
        x_c = jax.lax.dynamic_slice_in_dim(x, i * cs, cs, axis=1)
        y_c = jax.lax.dynamic_slice_in_dim(labels, i * cs, cs, axis=1)
        return tot + chunk_nll(x_c, y_c), None

    tot, _ = jax.lax.scan(step, jnp.zeros((), jnp.float32), jnp.arange(S // cs))
    return tot / (B * S) + aux


# ---------------------------------------------------------------------------
# KV-cache serving
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class KVCache:
    """Stacked-layer KV cache. k/v: [L, B, S_cache, KVH, Dh].

    ``cur_len`` is PER-SEQUENCE [B]: every serving slot carries its own
    position (continuous batching admits/retires slots independently).
    With ``cfg.kv_quant`` the payloads are int8 and ``k_scale``/``v_scale``
    hold per-(layer, seq, position, head) f32 scales — halves decode HBM
    traffic + doubles servable context per chip (EXPERIMENTS.md §Perf).
    """
    k: jax.Array
    v: jax.Array
    cur_len: jax.Array
    k_scale: jax.Array | None = None
    v_scale: jax.Array | None = None

    def tree_flatten(self):
        return (self.k, self.v, self.cur_len, self.k_scale, self.v_scale), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def _quantize_kv(x: jax.Array):
    """x [..., Dh] -> (int8 payload, f32 scale[...])."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / 127.0 + 1e-9
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize_kv(q: jax.Array, scale: jax.Array, dtype) -> jax.Array:
    return (q.astype(jnp.float32) * scale[..., None]).astype(dtype)


jax.tree_util.register_pytree_node(
    KVCache, KVCache.tree_flatten, KVCache.tree_unflatten)


def cache_len(cfg: LMConfig, seq_len: int) -> int:
    """SWA archs keep a ring buffer of the window; full attention keeps S."""
    if cfg.sliding_window is not None:
        return min(seq_len, cfg.sliding_window)
    return seq_len


def init_cache(cfg: LMConfig, batch: int, seq_len: int,
               dtype=jnp.bfloat16) -> KVCache:
    L, KVH, Dh = cfg.n_layers, cfg.n_kv_heads, cfg.dh
    S = cache_len(cfg, seq_len)
    shape = (L, batch, S, KVH, Dh)
    pay_dtype = jnp.int8 if cfg.kv_quant else dtype
    k = shard(jnp.zeros(shape, pay_dtype), None, "batch", "kv_seq", None, None)
    v = shard(jnp.zeros(shape, pay_dtype), None, "batch", "kv_seq", None, None)
    scale = None
    if cfg.kv_quant:
        scale = shard(jnp.zeros(shape[:-1], jnp.float32),
                      None, "batch", "kv_seq", None)
    return KVCache(k=k, v=v, cur_len=jnp.zeros((batch,), jnp.int32),
                   k_scale=scale, v_scale=scale)


def prefill(params: dict, cfg: LMConfig, tokens: jax.Array,
            dtype=jnp.bfloat16, max_len: int | None = None,
            prompt_lens: jax.Array | None = None
            ) -> tuple[jax.Array, KVCache]:
    """Run the prompt, build a cache with capacity ``max_len``, return the
    last-valid-position logits. ``max_len`` defaults to the prompt length
    (dry-run semantics); generation should pass prompt + budget.
    ``prompt_lens`` [B] supports right-padded batched prompts: logits come
    from position ``len-1`` and the cache length is per-sequence."""
    B, S = tokens.shape
    Sc = cache_len(cfg, max_len or S)
    x = _embed(params, cfg, tokens, dtype)
    positions = jnp.arange(S)[None, :]

    def layer_fn(carry, lp):
        x, li = carry
        h = rms_norm(x, lp["attn_norm"], cfg.norm_eps)
        q, k, v = _qkv(lp, cfg, h, positions)
        if cfg.sliding_window is not None:
            attn = swa_blocked_attention(q, k, v, window=cfg.sliding_window,
                                         block_q=cfg.attn_block_q,
                                         block_k=cfg.attn_block_q)
        else:
            attn = blocked_attention(q, k, v, causal=True,
                                     block_q=cfg.attn_block_q,
                                     block_k=cfg.attn_block_k)
        x = x + _attn_out(lp, cfg, attn)
        x, _ = _ffn(lp, cfg, x)
        # cache layout invariant: position p lives at slot p % Sc (ring).
        if Sc < S:       # SWA ring smaller than the prompt: keep last Sc
            k_keep = jnp.roll(k[:, S - Sc:], S % Sc, axis=1)
            v_keep = jnp.roll(v[:, S - Sc:], S % Sc, axis=1)
        elif Sc > S:     # room to grow: pad to capacity
            pad = [(0, 0), (0, Sc - S), (0, 0), (0, 0)]
            k_keep, v_keep = jnp.pad(k, pad), jnp.pad(v, pad)
        else:
            k_keep, v_keep = k, v
        if cfg.kv_quant:
            kq, ks = _quantize_kv(k_keep)
            vq, vs = _quantize_kv(v_keep)
            return (x, li + 1), ((kq, ks), (vq, vs))
        return (x, li + 1), ((k_keep, None), (v_keep, None))

    (x, _), ((k_all, ks_all), (v_all, vs_all)) = jax.lax.scan(
        layer_fn, (x, jnp.zeros((), jnp.int32)), params["layers"])
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if prompt_lens is None:
        logits = _head(params, cfg, x[:, -1:, :])
        lens = jnp.full((B,), S, jnp.int32)
    else:
        lens = jnp.asarray(prompt_lens, jnp.int32)
        idx = jnp.clip(lens - 1, 0, S - 1)
        x_last = jnp.take_along_axis(x, idx[:, None, None], axis=1)  # [B,1,D]
        logits = _head(params, cfg, x_last)
    k_all = shard(k_all, None, "batch", "kv_seq", None, None)
    v_all = shard(v_all, None, "batch", "kv_seq", None, None)
    cache = KVCache(k=k_all, v=v_all, cur_len=lens,
                    k_scale=ks_all, v_scale=vs_all)
    return logits, cache


def decode_step(params: dict, cfg: LMConfig, token: jax.Array,
                cache: KVCache, dtype=jnp.bfloat16,
                attn_impl: str = "flash"
                ) -> tuple[jax.Array, KVCache]:
    """token [B,1] int32 -> (logits [B,1,V], updated cache). One new token
    per sequence; every slot advances its own ``cur_len`` (continuous
    batching).

    ``attn_impl`` selects the decode-attention hot loop:
      "flash" (default) — ``kernels/ops.flash_decode``: the split-K Pallas
        flash-decode kernel on TPU, its jnp oracle elsewhere. Takes the
        per-sequence ``cur_len`` vector, so one compiled dispatch serves
        slots at different depths — shape-stable across admissions and
        evictions (DESIGN.md §11).
      "dense" — ``models/attention.decode_attention``: the sharding-
        annotated jnp path (KV-sequence sharding lowers its reductions to
        all-reduces; use under a mesh with a sharded cache).
    Both compute the same masked softmax attention in f32; decode_step
    output is parity-tested between them (tests/test_transformer.py).
    """
    if attn_impl not in ("flash", "dense"):
        raise ValueError(f"unknown attn_impl {attn_impl!r}; "
                         "expected 'flash' or 'dense'")
    B = token.shape[0]
    Sc = cache.k.shape[2]
    x = _embed(params, cfg, token, dtype)
    pos = jnp.broadcast_to(jnp.asarray(cache.cur_len, jnp.int32), (B,))
    write_idx = pos % Sc    # ring invariant; full-attn caches sized >= max pos
    positions = pos[:, None]
    b_idx = jnp.arange(B)

    def layer_fn(carry, lp):
        x, kc, vc, ksc, vsc, li = carry
        h = rms_norm(x, lp["attn_norm"], cfg.norm_eps)
        q, k_new, v_new = _qkv(lp, cfg, h, positions)     # k_new [B,1,KVH,Dh]
        k_l = jax.lax.dynamic_index_in_dim(kc, li, 0, keepdims=False)
        v_l = jax.lax.dynamic_index_in_dim(vc, li, 0, keepdims=False)
        if cfg.kv_quant:
            kq, ks = _quantize_kv(k_new[:, 0])
            vq, vs = _quantize_kv(v_new[:, 0])
            k_l = k_l.at[b_idx, write_idx].set(kq)
            v_l = v_l.at[b_idx, write_idx].set(vq)
            ks_l = jax.lax.dynamic_index_in_dim(ksc, li, 0, keepdims=False)
            vs_l = jax.lax.dynamic_index_in_dim(vsc, li, 0, keepdims=False)
            ks_l = ks_l.at[b_idx, write_idx].set(ks)
            vs_l = vs_l.at[b_idx, write_idx].set(vs)
            ksc = jax.lax.dynamic_update_index_in_dim(ksc, ks_l, li, 0)
            vsc = jax.lax.dynamic_update_index_in_dim(vsc, vs_l, li, 0)
            k_att = _dequantize_kv(k_l, ks_l, x.dtype)
            v_att = _dequantize_kv(v_l, vs_l, x.dtype)
        else:
            k_l = k_l.at[b_idx, write_idx].set(k_new[:, 0].astype(kc.dtype))
            v_l = v_l.at[b_idx, write_idx].set(v_new[:, 0].astype(vc.dtype))
            k_att, v_att = k_l, v_l
        kc = jax.lax.dynamic_update_index_in_dim(kc, k_l, li, 0)
        vc = jax.lax.dynamic_update_index_in_dim(vc, v_l, li, 0)
        n_valid = jnp.minimum(pos + 1, Sc)
        if attn_impl == "flash":
            from repro.kernels import ops
            a = ops.flash_decode(q[:, 0], k_att, v_att, n_valid)
            attn = a.astype(x.dtype)[:, None]          # [B,1,H,Dh]
        else:
            attn = decode_attention(q, k_att, v_att, n_valid)
        x = x + _attn_out(lp, cfg, attn)
        x, _ = _ffn(lp, cfg, x)
        return (x, kc, vc, ksc, vsc, li + 1), None

    zero_s = jnp.zeros((), jnp.int32)
    ksc0 = cache.k_scale if cache.k_scale is not None else zero_s
    vsc0 = cache.v_scale if cache.v_scale is not None else zero_s
    (x, kc, vc, ksc, vsc, _), _ = jax.lax.scan(
        layer_fn, (x, cache.k, cache.v, ksc0, vsc0, zero_s),
        params["layers"])
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = _head(params, cfg, x)
    return logits, KVCache(
        k=kc, v=vc, cur_len=pos + 1,
        k_scale=ksc if cfg.kv_quant else None,
        v_scale=vsc if cfg.kv_quant else None)
