"""Shared model building blocks (pure-JAX, no flax): norms, RoPE, init."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def normal_init(key, shape, scale: float = 0.02, dtype=jnp.float32):
    return (scale * jax.random.normal(key, shape, dtype=jnp.float32)).astype(dtype)


def rms_norm(x: jax.Array, gamma: jax.Array, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    return (y * gamma.astype(jnp.float32)).astype(dtype)


def layer_norm(x: jax.Array, gamma: jax.Array, beta: jax.Array, eps: float = 1e-5):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * gamma.astype(jnp.float32) + beta.astype(jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    """Inverse frequencies [head_dim//2]."""
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., S, H, Dh]; positions: broadcastable to [..., S]."""
    dh = x.shape[-1]
    inv = rope_freqs(dh, theta)                       # [dh/2]
    ang = positions[..., None].astype(jnp.float32) * inv  # [..., S, dh/2]
    cos = jnp.cos(ang)[..., None, :]                  # [..., S, 1, dh/2]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------
def softmax_xent(logits: jax.Array, labels: jax.Array, mask=None) -> jax.Array:
    """Mean cross-entropy; logits [..., V], labels [...] int32."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


def sigmoid_xent(logits: jax.Array, labels: jax.Array) -> jax.Array:
    logits = logits.astype(jnp.float32)
    labels = labels.astype(jnp.float32)
    return jnp.mean(
        jnp.maximum(logits, 0) - logits * labels + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    )


def l2_normalize(x: jax.Array, axis: int = -1, eps: float = 1e-12) -> jax.Array:
    n = jnp.sqrt(jnp.sum(jnp.square(x.astype(jnp.float32)), axis=axis, keepdims=True))
    return (x.astype(jnp.float32) / jnp.maximum(n, eps)).astype(x.dtype)


def count_params(tree) -> int:
    return sum(int(np.prod(p.shape)) for p in jax.tree.leaves(tree))
