"""Blocked (flash-style) attention in pure JAX, GQA-aware.

Three lowered regimes:
  * ``blocked_attention`` — training/prefill, nested scan over (q blocks, kv
    blocks) with running log-sum-exp; O(block) memory. ``impl='masked'``
    computes the full rectangle with causal masking (2x FLOP waste on the
    causal upper triangle — the *baseline*); ``impl='packed'`` packs the
    causal lower triangle onto a constant-work scan so compiled FLOPs match
    useful FLOPs (hillclimb lever, see EXPERIMENTS.md §Perf).
  * ``swa_blocked_attention`` — sliding-window: per q block only the
    ``window/bk + 1`` kv blocks in band are touched (sub-quadratic; the
    long_500k path for h2o-danube).
  * ``decode_attention`` — single new token vs a KV cache; direct reduction,
    f32 accumulation. KV-sequence sharding turns the softmax reductions into
    small all-reduces (flash-decode split-K without a hand-rolled collective).

All einsums accumulate in float32 (``preferred_element_type``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard

NEG_INF = -1e30


def pick_block(s: int, b: int) -> int:
    """Largest divisor of ``s`` that is <= ``b`` (so odd test lengths work)."""
    b = min(b, s)
    while s % b != 0:
        b -= 1
    return max(b, 1)


def _gqa_scores(q: jax.Array, k: jax.Array) -> jax.Array:
    """q [B,bq,H,Dh], k [B,bk,KVH,Dh] -> scores [B,H,bq,bk] (f32)."""
    b, sq, h, dh = q.shape
    kvh = k.shape[2]
    g = h // kvh
    qg = q.reshape(b, sq, kvh, g, dh)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k, preferred_element_type=jnp.float32)
    return s.reshape(b, kvh * g, sq, k.shape[1])


def _gqa_values(p: jax.Array, v: jax.Array) -> jax.Array:
    """p [B,H,bq,bk] (f32), v [B,bk,KVH,Dh] -> [B,bq,H,Dh] (f32)."""
    b, h, sq, sk = p.shape
    kvh = v.shape[2]
    g = h // kvh
    pg = p.reshape(b, kvh, g, sq, sk)
    o = jnp.einsum("bkgqs,bskd->bqkgd", pg, v, preferred_element_type=jnp.float32)
    return o.reshape(b, sq, h, v.shape[-1])


def _merge_block(carry, scores, v_blk, block_mask):
    """Online-softmax merge of one kv block. carry = (m, l, acc) in f32.

    m [B,H,bq], l [B,H,bq], acc [B,bq,H,Dh].
    """
    m, l, acc = carry
    scores = jnp.where(block_mask, scores, NEG_INF)
    m_new = jnp.maximum(m, jnp.max(scores, axis=-1))
    m_safe = jnp.where(m_new <= NEG_INF / 2, 0.0, m_new)   # fully-masked guard
    p = jnp.where(block_mask, jnp.exp(scores - m_safe[..., None]), 0.0)
    alpha = jnp.where(m <= NEG_INF / 2, 0.0, jnp.exp(m - m_safe))
    l_new = l * alpha + jnp.sum(p, axis=-1)
    acc_new = acc * alpha[..., None].swapaxes(1, 2) + _gqa_values(p, v_blk)
    return (m_new, l_new, acc_new)


def _finalize(l, acc, dtype):
    return (acc / jnp.maximum(l, 1e-30)[..., None].swapaxes(1, 2)).astype(dtype)


def blocked_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    block_q: int = 512,
    block_k: int = 1024,
    impl: str = "masked",
) -> jax.Array:
    """Flash-style attention. q [B,S,H,Dh]; k,v [B,Sk,KVH,Dh] -> [B,S,H,Dh]."""
    b, sq, h, dh = q.shape
    sk = k.shape[1]
    block_q = pick_block(sq, block_q)
    block_k = pick_block(sk, block_k)
    nq, nk = sq // block_q, sk // block_k

    if impl == "packed" and causal and sq == sk and block_q == block_k and nq % 2 == 0:
        return _packed_causal_attention(q, k, v, blk=block_q)

    sm_scale = dh ** -0.5
    qb = q.reshape(b, nq, block_q, h, dh)

    def q_block_step(_, iq):
        q_i = jax.lax.dynamic_index_in_dim(qb, iq, axis=1, keepdims=False) * sm_scale
        q_pos = iq * block_q + jnp.arange(block_q)

        def kv_step(carry, jk):
            k_j = jax.lax.dynamic_slice_in_dim(k, jk * block_k, block_k, axis=1)
            v_j = jax.lax.dynamic_slice_in_dim(v, jk * block_k, block_k, axis=1)
            scores = _gqa_scores(q_i, k_j)                         # [B,H,bq,bk]
            if causal:
                k_pos = jk * block_k + jnp.arange(block_k)
                mask = (q_pos[:, None] >= k_pos[None, :])[None, None]
            else:
                mask = jnp.ones((1, 1, block_q, block_k), dtype=bool)
            return _merge_block(carry, scores, v_j, mask), None

        m0 = jnp.full((b, h, block_q), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, h, block_q), jnp.float32)
        a0 = jnp.zeros((b, block_q, h, dh), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), jnp.arange(nk))
        return None, _finalize(l, acc, q.dtype)

    _, out = jax.lax.scan(q_block_step, None, jnp.arange(nq))
    # out: [nq, B, bq, H, Dh] -> [B, S, H, Dh]
    return out.transpose(1, 0, 2, 3, 4).reshape(b, sq, h, dh)


def _packed_causal_attention(q, k, v, *, blk: int):
    """Causal attention with the lower triangle packed onto a rectangle.

    Pair q-block row ``i`` (needs ``i+1`` kv blocks) with row ``nb-1-i``
    (needs ``nb-i`` kv blocks): together ``nb+1`` kv-block units per scan
    step — constant work, zero masked-out whole blocks. Compiled attention
    FLOPs ≈ useful causal FLOPs (+ the diagonal half-blocks), versus 2x for
    the masked baseline.
    """
    b, s, h, dh = q.shape
    kvh = v.shape[2]
    nb = s // blk
    half = nb // 2
    sm_scale = dh ** -0.5
    qb = q.reshape(b, nb, blk, h, dh)
    kb = k.reshape(b, nb, blk, kvh, dh)
    vb = v.reshape(b, nb, blk, kvh, dh)
    n_slots = nb + 1

    def step(_, i):
        i_lo = i                      # row needing i+1 kv blocks
        i_hi = nb - 1 - i             # row needing nb-i kv blocks
        q_lo = jax.lax.dynamic_index_in_dim(qb, i_lo, 1, keepdims=False) * sm_scale
        q_hi = jax.lax.dynamic_index_in_dim(qb, i_hi, 1, keepdims=False) * sm_scale

        def slot(carry, s_idx):
            (m_lo, l_lo, a_lo, m_hi, l_hi, a_hi) = carry
            is_lo = s_idx <= i_lo
            kv_idx = jnp.where(is_lo, s_idx, s_idx - (i_lo + 1))
            k_j = jax.lax.dynamic_index_in_dim(kb, kv_idx, 1, keepdims=False)
            v_j = jax.lax.dynamic_index_in_dim(vb, kv_idx, 1, keepdims=False)
            q_i = jnp.where(is_lo, q_lo, q_hi)
            row = jnp.where(is_lo, i_lo, i_hi)
            # select the active carry, merge once, scatter back
            sel = lambda a_, b_: jnp.where(is_lo, a_, b_)
            m_c, l_c, a_c = sel(m_lo, m_hi), sel(l_lo, l_hi), sel(a_lo, a_hi)
            scores = _gqa_scores(q_i, k_j)
            q_pos = row * blk + jnp.arange(blk)
            k_pos = kv_idx * blk + jnp.arange(blk)
            mask = (q_pos[:, None] >= k_pos[None, :])[None, None]
            m_n, l_n, a_n = _merge_block((m_c, l_c, a_c), scores, v_j, mask)
            upd = lambda new, old, active: jnp.where(active, new, old)
            out = (
                upd(m_n, m_lo, is_lo), upd(l_n, l_lo, is_lo), upd(a_n, a_lo, is_lo),
                upd(m_n, m_hi, ~is_lo), upd(l_n, l_hi, ~is_lo), upd(a_n, a_hi, ~is_lo),
            )
            return out, None

        m0 = jnp.full((b, h, blk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, h, blk), jnp.float32)
        a0 = jnp.zeros((b, blk, h, dh), jnp.float32)
        carry, _ = jax.lax.scan(slot, (m0, l0, a0, m0, l0, a0), jnp.arange(n_slots))
        m_lo, l_lo, a_lo, m_hi, l_hi, a_hi = carry
        return None, (i_lo, _finalize(l_lo, a_lo, q.dtype),
                      i_hi, _finalize(l_hi, a_hi, q.dtype))

    _, (idx_lo, out_lo, idx_hi, out_hi) = jax.lax.scan(step, None, jnp.arange(half))
    order = jnp.concatenate([idx_lo, idx_hi])            # [nb]
    blocks = jnp.concatenate([out_lo, out_hi], axis=0)   # [nb, B, blk, H, Dh]
    blocks = blocks[jnp.argsort(order)]
    return blocks.transpose(1, 0, 2, 3, 4).reshape(b, s, h, dh)


def swa_blocked_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    window: int,
    block_q: int = 512,
    block_k: int = 512,
) -> jax.Array:
    """Causal sliding-window attention; touches only in-band kv blocks."""
    b, sq, h, dh = q.shape
    sk = k.shape[1]
    block_q = pick_block(sq, block_q)
    block_k = pick_block(sk, block_k)
    if sk <= window:  # window covers every prefix -> plain causal
        return blocked_attention(q, k, v, causal=True,
                                 block_q=block_q, block_k=block_k)
    nq = sq // block_q
    # kv span needed by one q block: window + block_q positions, block-aligned
    span = min(((window + block_q) // block_k + 1) * block_k, sk)
    sm_scale = dh ** -0.5
    qb = q.reshape(b, nq, block_q, h, dh)

    def q_block_step(_, iq):
        q_i = jax.lax.dynamic_index_in_dim(qb, iq, 1, keepdims=False) * sm_scale
        q_lo = iq * block_q
        start = jnp.clip(q_lo + block_q - span, 0, sk - span)
        k_w = jax.lax.dynamic_slice_in_dim(k, start, span, axis=1)
        v_w = jax.lax.dynamic_slice_in_dim(v, start, span, axis=1)
        scores = _gqa_scores(q_i, k_w)                       # [B,H,bq,span]
        q_pos = q_lo + jnp.arange(block_q)
        k_pos = start + jnp.arange(span)
        mask = (q_pos[:, None] >= k_pos[None, :]) & \
               (k_pos[None, :] > q_pos[:, None] - window)
        scores = jnp.where(mask[None, None], scores, NEG_INF)
        m = jnp.max(scores, axis=-1, keepdims=True)
        p = jnp.exp(scores - m)
        l = jnp.sum(p, axis=-1, keepdims=True)
        out = _gqa_values(p / jnp.maximum(l, 1e-30), v_w)
        return None, out.astype(q.dtype)

    _, out = jax.lax.scan(q_block_step, None, jnp.arange(nq))
    return out.transpose(1, 0, 2, 3, 4).reshape(b, sq, h, dh)


def decode_attention(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    cur_len: jax.Array,
    *,
    window: int | None = None,
) -> jax.Array:
    """One-token attention against the cache.

    q [B,1,H,Dh]; k_cache/v_cache [B,S,KVH,Dh]; ``cur_len``: number of valid
    positions — scalar or per-sequence [B] (continuous batching: every slot
    carries its own length). Returns [B,1,H,Dh]. With the cache sharded
    along S ("kv_seq" -> model axis) the max/sum reductions lower to tiny
    all-reduces: split-K flash-decode, scheduled by the SPMD partitioner.
    """
    b, _, h, dh = q.shape
    s = k_cache.shape[1]
    k_cache = shard(k_cache, "batch", "kv_seq", None, None)
    v_cache = shard(v_cache, "batch", "kv_seq", None, None)
    scores = _gqa_scores(q * dh ** -0.5, k_cache)       # [B,H,1,S]
    pos = jnp.arange(s)
    cur = jnp.broadcast_to(jnp.asarray(cur_len, jnp.int32), (b,))
    valid = pos[None, :] < cur[:, None]                 # [B,S]
    if window is not None:
        valid &= pos[None, :] >= cur[:, None] - window
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    m = jnp.max(scores, axis=-1, keepdims=True)
    p = jnp.exp(scores - m)
    p = p / jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
    out = _gqa_values(p, v_cache)
    return out.astype(q.dtype)


def reference_attention(q, k, v, *, causal=True, window=None):
    """O(S^2)-memory oracle for tests."""
    b, sq, h, dh = q.shape
    sk = k.shape[1]
    scores = _gqa_scores(q * dh ** -0.5, k)
    q_pos = jnp.arange(sq) + (sk - sq)
    k_pos = jnp.arange(sk)
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= q_pos[:, None] >= k_pos[None, :]
    if window is not None:
        mask &= k_pos[None, :] > q_pos[:, None] - window
    scores = jnp.where(mask[None, None], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    return _gqa_values(p, v).astype(q.dtype)
