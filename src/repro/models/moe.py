"""Top-k token-choice MoE with capacity dropping (GShard/Switch style),
expert-parallel over the "model" mesh axis.

Dispatch uses the scatter/gather formulation (position-in-expert via one-hot
cumsum) instead of the [T, E, C] one-hot einsum: at 1M tokens x 64 experts
the one-hot dispatch tensor alone would be ~40 GiB x top_k, while the
scatter form keeps peak extra memory at the [E, C, D] expert buffers.

Dispatch locality: tokens are reshaped to [G, T/G, D] where G = the data-
parallel shard count, and every dispatch op (cumsum, gather, combine
scatter) carries the G dim, constrained to the ("pod","data") axes. Each
data shard therefore routes its own tokens with LOCAL capacity and the
combine never materialises a replicated [T, D] reduce — EP traffic is only
the expert transfer on the model axis. (Same effect as a hand-written
shard_map dispatch, but expressed in pure pjit; the partial-auto shard_map
version tripped an XLA CPU crash — see EXPERIMENTS.md §Perf B.)

Expert-count alignment: ``pad_experts_to`` adds dead experts (masked from
routing) so the expert dim divides the mesh axis — granite's 40 experts pad
to 48 for a 16-way axis; without it the partitioner falls back to
TP-within-expert and all-reduces multi-TB expert buffers.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.distributed.sharding import current_mesh, shard
from repro.models.common import normal_init


def init_moe_layer(key, n_layers: int, d_model: int, cfg: MoEConfig) -> dict:
    kr, k1, k2, k3 = jax.random.split(key, 4)
    E, F = cfg.n_slots, cfg.d_ff
    L, D = n_layers, d_model
    return {
        "router": normal_init(kr, (L, D, E), 0.02),
        "we1": normal_init(k1, (L, E, D, F), 0.02),
        "we3": normal_init(k3, (L, E, D, F), 0.02),
        "we2": normal_init(k2, (L, E, F, D), 0.02 / (2 * L) ** 0.5),
    }


def moe_layer_axes() -> dict:
    return {
        "router": ("layers", "embed", "expert"),
        "we1": ("layers", "expert", "embed", "expert_mlp"),
        "we3": ("layers", "expert", "embed", "expert_mlp"),
        "we2": ("layers", "expert", "expert_mlp", "embed"),
    }


def capacity(n_tokens: int, cfg: MoEConfig) -> int:
    c = int(cfg.capacity_factor * n_tokens * cfg.top_k / cfg.n_experts)
    return max(8 * ((c + 7) // 8), 8)


def _dp_groups(T: int) -> int:
    """Number of token groups = product of the mesh axes the active
    "dp_group" rule maps to (1 without a mesh). With the default rule this
    is the data-parallel shard count; the moe-fsdp tuning maps it to every
    axis, which shards tokens 256-way and replicates (FSDP-gathers) the
    expert weights instead — zero token movement (EXPERIMENTS.md §Perf B).
    """
    from repro.distributed.sharding import _CTX, _mesh_axes_for
    mesh = current_mesh()
    if mesh is None:
        return 1
    g = 1
    for a in _mesh_axes_for("dp_group", mesh):
        g *= mesh.shape[a]
    if g <= 1 or T % g or (T // g) < 1:
        return 1
    return g


def moe_ffn(p: dict, cfg: MoEConfig, x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """x: [T, D] tokens -> (out [T, D], aux_loss scalar).

    ``p`` holds this layer's slices: router [D,E], we1/we3 [E,D,F], we2 [E,F,D].
    """
    T, D = x.shape
    G = _dp_groups(T)
    xg = shard(x.reshape(G, T // G, D), "dp_group", None, None)
    out, aux = _moe_ffn_grouped(p, cfg, xg)
    return shard(out.reshape(T, D), "tokens", None), aux


def _moe_ffn_grouped(p: dict, cfg: MoEConfig, xg: jax.Array
                     ) -> tuple[jax.Array, jax.Array]:
    """xg: [G, Tl, D] (G sharded over the data axes) -> ([G, Tl, D], aux)."""
    G, Tl, D = xg.shape
    E, K = cfg.n_slots, cfg.top_k
    C = capacity(Tl, cfg)

    logits = jnp.einsum("gtd,de->gte", xg.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    if cfg.n_slots > cfg.n_experts:     # EP padding: dead experts never route
        alive = jnp.arange(E) < cfg.n_experts
        logits = jnp.where(alive[None, None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)                      # [G,Tl,E]
    gate_w, ids = jax.lax.top_k(probs, K)                        # [G,Tl,K]
    gate_w = gate_w / jnp.maximum(jnp.sum(gate_w, -1, keepdims=True), 1e-9)

    # --- position of each assignment within its expert (per group) --------
    flat_ids = ids.reshape(G, Tl * K)                            # [G,A]
    onehot = jax.nn.one_hot(flat_ids, E, dtype=jnp.int32)        # [G,A,E]
    onehot = shard(onehot, "dp_group", None, None)
    pos = jnp.sum((jnp.cumsum(onehot, axis=1) - 1) * onehot, axis=-1)
    keep = pos < C
    slot = jnp.where(keep, flat_ids * C + pos, E * C)            # sink slot

    token_idx = jnp.broadcast_to(
        (jnp.arange(Tl * K, dtype=jnp.int32) // K)[None], (G, Tl * K))
    g_idx = jnp.broadcast_to(jnp.arange(G)[:, None], (G, Tl * K))
    slot_to_token = jnp.zeros((G, E * C + 1), jnp.int32) \
        .at[g_idx, slot].set(token_idx, mode="drop")
    slot_weight = jnp.zeros((G, E * C + 1), jnp.float32) \
        .at[g_idx, slot].set(gate_w.reshape(G, Tl * K), mode="drop")

    # --- dispatch (gather stays within each group) -------------------------
    gathered = jnp.take_along_axis(
        xg, slot_to_token[:, : E * C, None], axis=1)             # [G,E*C,D]
    gathered = shard(gathered.reshape(G, E, C, D),
                     "dp_group", "expert", "capacity", None)

    # --- expert compute (SwiGLU) -------------------------------------------
    h1 = jnp.einsum("gecd,edf->gecf", gathered, p["we1"].astype(xg.dtype),
                    preferred_element_type=jnp.float32)
    h3 = jnp.einsum("gecd,edf->gecf", gathered, p["we3"].astype(xg.dtype),
                    preferred_element_type=jnp.float32)
    h = shard((jax.nn.silu(h1) * h3).astype(xg.dtype),
              "dp_group", "expert", "capacity", "expert_mlp")
    expert_out = jnp.einsum("gecf,efd->gecd", h, p["we2"].astype(xg.dtype),
                            preferred_element_type=jnp.float32)
    expert_out = shard(expert_out, "dp_group", "expert", "capacity", None)

    # --- combine (scatter-add stays within each group) ----------------------
    weighted = (expert_out.reshape(G, E * C, D)
                * slot_weight[:, : E * C, None]).astype(jnp.float32)
    g_idx2 = jnp.broadcast_to(jnp.arange(G)[:, None], (G, E * C))
    out = jnp.zeros((G, Tl, D), jnp.float32) \
        .at[g_idx2, slot_to_token[:, : E * C]].add(weighted)
    out = shard(out, "dp_group", None, None)

    # --- load-balancing aux loss (Switch): E * sum_e f_e * P_e --------------
    # f_e = fraction of routed assignments landing on e (sums to <= 1 with
    # capacity drops); P_e = mean router prob. Balanced routing (both
    # uniform) gives aux == aux_loss_weight * 1.0 exactly.
    f_e = jnp.mean(onehot.astype(jnp.float32)
                   * keep[..., None].astype(jnp.float32), axis=(0, 1))
    p_e = jnp.mean(probs, axis=(0, 1))
    aux = cfg.aux_loss_weight * E * jnp.sum(f_e * p_e)
    return out.astype(xg.dtype), aux
