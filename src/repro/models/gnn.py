"""GraphSAGE (mean aggregator) in three lowering regimes.

JAX has no CSR/CSC sparse (BCOO only), so message passing is built from
``jnp.take`` gathers over an edge index + ``jax.ops.segment_sum`` scatters —
this IS the system, per the assignment brief:

  * full-graph:   gather src feats [E,D] -> segment_sum into dst -> degree
                  normalise. Edges shard over ("pod","data"): each shard
                  produces partial node sums, the SPMD partitioner inserts the
                  psum (classic distributed full-batch GNN).
  * sampled:      dense fanout tensors [B,f1,f2,D] from the neighbor sampler
                  (minibatch_lg); pure dense means/matmuls — MXU friendly.
  * batched-small (molecule): dense normalised adjacency matmul per graph.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import GNNConfig
from repro.distributed.sharding import shard
from repro.models.common import normal_init, softmax_xent, l2_normalize


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------
def init_sage(key, cfg: GNNConfig, d_feat: int, n_classes: int) -> dict:
    dims = [d_feat] + [cfg.d_hidden] * cfg.n_layers
    params: dict[str, Any] = {"layers": []}
    keys = jax.random.split(key, cfg.n_layers + 1)
    for i in range(cfg.n_layers):
        k1, k2 = jax.random.split(keys[i])
        scale = (2.0 / dims[i]) ** 0.5
        params["layers"].append({
            "w_self": normal_init(k1, (dims[i], dims[i + 1]), scale),
            "w_neigh": normal_init(k2, (dims[i], dims[i + 1]), scale),
            "b": jnp.zeros((dims[i + 1],)),
        })
    params["w_out"] = normal_init(keys[-1], (cfg.d_hidden, n_classes), 0.02)
    return params


def sage_param_axes(cfg: GNNConfig) -> dict:
    layer = {"w_self": ("node_feat", None), "w_neigh": ("node_feat", None),
             "b": (None,)}
    return {"layers": [dict(layer) for _ in range(cfg.n_layers)],
            "w_out": (None, None)}


def _sage_layer(lp: dict, h_self: jax.Array, h_agg: jax.Array,
                final: bool) -> jax.Array:
    out = (h_self @ lp["w_self"] + h_agg @ lp["w_neigh"] + lp["b"])
    out = out if final else jax.nn.relu(out)
    return l2_normalize(out, axis=-1)


# ---------------------------------------------------------------------------
# Full-graph forward (full_graph_sm / ogb_products)
# ---------------------------------------------------------------------------
def _edge_groups(e: int) -> int:
    """Edge-parallel group count = the data-axis size (1 without a mesh)."""
    from repro.distributed.sharding import current_mesh, _mesh_axes_for
    mesh = current_mesh()
    if mesh is None:
        return 1
    g = 1
    for a in _mesh_axes_for("edges", mesh):
        g *= mesh.shape[a]
    return g if (g > 1 and e % g == 0) else 1


def _grouped_segment_mean(msg: jax.Array, edge_dst: jax.Array, n: int,
                          inv_deg: jax.Array) -> jax.Array:
    """segment-sum with edge-shard locality: edges grouped by data shard,
    one segment_sum over G*N segments (each group scatters only into its
    own [N,D] slice), then a tree-sum over the sharded group dim — the
    partitioner emits per-shard partials + one psum instead of replicating
    the [E,D] update tensor (ogb_products: 60 GiB -> fits)."""
    e, d = msg.shape
    g = _edge_groups(e)
    if g == 1:
        return jax.ops.segment_sum(msg, edge_dst, n) * inv_deg[:, None]
    group = (jnp.arange(e, dtype=jnp.int32) // (e // g))
    seg = edge_dst + group * n
    parts = jax.ops.segment_sum(msg, seg, g * n).reshape(g, n, d)
    parts = shard(parts, "edges", None, None)      # group dim on data axes
    agg = jnp.sum(parts, axis=0)                   # -> psum across shards
    return shard(agg, "nodes", None) * inv_deg[:, None]


def sage_full_forward(params: dict, cfg: GNNConfig, feats: jax.Array,
                      edge_src: jax.Array, edge_dst: jax.Array) -> jax.Array:
    """feats [N,D]; edge_src/dst [E] int32 -> logits [N,C]."""
    n = feats.shape[0]
    edge_src = shard(edge_src, "edges")
    edge_dst = shard(edge_dst, "edges")
    deg = jax.ops.segment_sum(jnp.ones_like(edge_dst, jnp.float32), edge_dst, n)
    inv_deg = 1.0 / jnp.maximum(deg, 1.0)
    h = shard(feats, "nodes", None)
    for i, lp in enumerate(params["layers"]):
        msg = jnp.take(h, edge_src, axis=0)                  # [E, D] gather
        msg = shard(msg, "edges", None)
        agg = _grouped_segment_mean(msg, edge_dst, n, inv_deg)
        h = _sage_layer(lp, h, agg, final=False)
        h = shard(h, "nodes", None)
    return h @ params["w_out"]


def sage_full_loss(params, cfg, feats, edge_src, edge_dst, labels, label_mask):
    logits = sage_full_forward(params, cfg, feats, edge_src, edge_dst)
    return softmax_xent(logits, labels, label_mask)


# ---------------------------------------------------------------------------
# Sampled minibatch forward (minibatch_lg): dense fanout tensors
# ---------------------------------------------------------------------------
def sage_sampled_forward(params: dict, cfg: GNNConfig, x_self: jax.Array,
                         x_n1: jax.Array, x_n2: jax.Array) -> jax.Array:
    """x_self [B,D], x_n1 [B,f1,D], x_n2 [B,f1,f2,D] -> logits [B,C].

    Two-layer SAGE on the sampled tree (fanout f1, f2): layer 1 embeds the
    depth-1 frontier (aggregating depth-2), layer 2 embeds the seeds.
    """
    assert cfg.n_layers == 2, "sampled path implements the 2-layer config"
    l1, l2 = params["layers"]
    x_self = shard(x_self, "batch", None)
    x_n1 = shard(x_n1, "batch", None, None)
    h_n1 = _sage_layer(l1, x_n1, jnp.mean(x_n2, axis=2), final=False)   # [B,f1,H]
    h_self = _sage_layer(l1, x_self, jnp.mean(x_n1, axis=1), final=False)
    h = _sage_layer(l2, h_self, jnp.mean(h_n1, axis=1), final=False)    # [B,H]
    return h @ params["w_out"]


def sage_sampled_loss(params, cfg, x_self, x_n1, x_n2, labels):
    logits = sage_sampled_forward(params, cfg, x_self, x_n1, x_n2)
    return softmax_xent(logits, labels)


def sampled_train_from_graph(params, cfg, row_ptr, col_idx, feats, seeds,
                             labels, key, fanouts):
    """End-to-end sampled loss: neighbor sampling + feature gather + SAGE.

    This is the lowered program for minibatch_lg: the sampler runs on-device
    so the dry run proves the whole path (CSR arrays are inputs).
    """
    from repro.models.sampler import sample_neighbors
    k1, k2 = jax.random.split(key)
    f1, f2 = fanouts
    n1 = sample_neighbors(k1, row_ptr, col_idx, seeds, f1)        # [B, f1]
    n2 = sample_neighbors(k2, row_ptr, col_idx, n1.reshape(-1), f2)
    feats = shard(feats, "nodes", None)
    b = seeds.shape[0]
    x_self = jnp.take(feats, seeds, axis=0)
    x_n1 = jnp.take(feats, n1.reshape(-1), axis=0).reshape(b, f1, -1)
    x_n2 = jnp.take(feats, n2.reshape(-1), axis=0).reshape(b, f1, f2, -1)
    return sage_sampled_loss(params, cfg, x_self, x_n1, x_n2, labels)


# ---------------------------------------------------------------------------
# Batched small graphs (molecule): dense adjacency matmul
# ---------------------------------------------------------------------------
def sage_molecule_forward(params: dict, cfg: GNNConfig, feats: jax.Array,
                          adj: jax.Array) -> jax.Array:
    """feats [G,n,D], adj [G,n,n] (0/1) -> graph logits [G,C]."""
    deg = jnp.maximum(jnp.sum(adj, axis=-1, keepdims=True), 1.0)
    h = shard(feats, "batch", None, None)
    for lp in params["layers"]:
        agg = jnp.einsum("gij,gjd->gid", adj, h,
                         preferred_element_type=jnp.float32) / deg
        h = _sage_layer(lp, h, agg, final=False)
    pooled = jnp.mean(h, axis=1)                                  # [G, H]
    return pooled @ params["w_out"]


def sage_molecule_loss(params, cfg, feats, adj, labels):
    logits = sage_molecule_forward(params, cfg, feats, adj)
    return softmax_xent(logits, labels)
