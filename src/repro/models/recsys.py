"""RecSys model zoo: FM, Wide&Deep, BERT4Rec, MIND.

The memory hog is the sparse embedding tables (n_fields x 10^6 rows). JAX has
no native EmbeddingBag — lookups are ``jnp.take`` gathers (+
``jax.ops.segment_sum`` for multi-hot bags, see kernels/embedding_bag.py for
the Pallas hot path). Tables are stacked [F, R, K] and row-sharded over the
"model" axis (DLRM-style table parallelism); the gather over the sharded row
dim lowers to the partitioned-gather + all-reduce pattern under SPMD.

Every model also exposes ``user_embedding`` / item table access so the
retrieval_cand cell routes through the paper's retrieval core
(1 query x 1M candidates = MeMemo's own workload).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import RecsysConfig
from repro.distributed.sharding import shard
from repro.models.common import normal_init, sigmoid_xent, softmax_xent, l2_normalize
from repro.models import encoder as enc_lib


# ---------------------------------------------------------------------------
# Shared: sparse table lookup
# ---------------------------------------------------------------------------
def lookup(table: jax.Array, ids: jax.Array) -> jax.Array:
    """table [F,R,K], ids [B,F] -> [B,F,K] (one id per field)."""
    f = table.shape[0]
    table = shard(table, "fields", "table_rows", "feature_dim")
    out = table[jnp.arange(f)[None, :], ids]          # advanced-index gather
    return shard(out, "batch", "fields", "feature_dim")


def _mlp_init(key, dims: tuple[int, ...]) -> list[dict]:
    layers = []
    for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
        k = jax.random.fold_in(key, i)
        layers.append({"w": normal_init(k, (a, b), (2.0 / a) ** 0.5),
                       "b": jnp.zeros((b,))})
    return layers


def _mlp_apply(layers: list[dict], x: jax.Array, final_act: bool = False):
    for i, lp in enumerate(layers):
        x = x @ lp["w"] + lp["b"]
        if i < len(layers) - 1 or final_act:
            x = jax.nn.relu(x)
    return x


# ---------------------------------------------------------------------------
# FM — pairwise interactions via the O(nk) sum-square trick (Rendle ICDM'10)
# ---------------------------------------------------------------------------
def init_fm(key, cfg: RecsysConfig) -> dict:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    F, R, K = cfg.n_sparse, cfg.rows_per_field, cfg.embed_dim
    return {
        "table": normal_init(k1, (F, R, K), 0.01),
        "w_sparse": normal_init(k2, (F, R), 0.01),      # per-field linear
        "w_dense": normal_init(k3, (cfg.n_dense, 1), 0.01),
        "v_dense": normal_init(k4, (cfg.n_dense, K), 0.01),
        "bias": jnp.zeros(()),
    }


def fm_param_axes(cfg: RecsysConfig) -> dict:
    return {"table": ("fields", "table_rows", "feature_dim"),
            "w_sparse": ("fields", "table_rows"),
            "w_dense": (None, None), "v_dense": (None, "feature_dim"),
            "bias": ()}


def fm_forward(params: dict, cfg: RecsysConfig, sparse_ids: jax.Array,
               dense: jax.Array) -> jax.Array:
    """sparse_ids [B,F] int32, dense [B,n_dense] -> logits [B]."""
    F = cfg.n_sparse
    emb = lookup(params["table"], sparse_ids)                       # [B,F,K]
    lin_s = params["w_sparse"][jnp.arange(F)[None, :], sparse_ids]  # [B,F]
    lin = jnp.sum(lin_s, -1) + (dense @ params["w_dense"])[:, 0] + params["bias"]
    # include dense features as value-scaled factors: v_i * x_i
    vx_dense = params["v_dense"][None] * dense[..., None]           # [B,n_dense,K]
    vx = jnp.concatenate([emb, vx_dense], axis=1)                   # [B,F+nd,K]
    s = jnp.sum(vx, axis=1)                                         # Σ v_i x_i
    s2 = jnp.sum(jnp.square(vx), axis=1)                            # Σ (v_i x_i)²
    pair = 0.5 * jnp.sum(jnp.square(s) - s2, axis=-1)               # [B]
    return lin + pair


def fm_loss(params, cfg, sparse_ids, dense, labels):
    return sigmoid_xent(fm_forward(params, cfg, sparse_ids, dense), labels)


# ---------------------------------------------------------------------------
# Wide & Deep
# ---------------------------------------------------------------------------
def init_wide_deep(key, cfg: RecsysConfig) -> dict:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    F, R, K = cfg.n_sparse, cfg.rows_per_field, cfg.embed_dim
    mlp_dims = (F * K + cfg.n_dense,) + tuple(cfg.mlp_dims) + (1,)
    return {
        "table": normal_init(k1, (F, R, K), 0.01),
        "wide": normal_init(k2, (F, R), 0.01),          # wide = linear on sparse
        "wide_dense": normal_init(k3, (cfg.n_dense, 1), 0.01),
        "deep": _mlp_init(k4, mlp_dims),
        "bias": jnp.zeros(()),
    }


def wide_deep_param_axes(cfg: RecsysConfig) -> dict:
    n_mlp = len(cfg.mlp_dims) + 1
    return {"table": ("fields", "table_rows", "feature_dim"),
            "wide": ("fields", "table_rows"),
            "wide_dense": (None, None),
            "deep": [{"w": (None, "mlp"), "b": ("mlp",)} if i == 0 else
                     {"w": ("mlp", None), "b": (None,)} for i in range(n_mlp)],
            "bias": ()}


def wide_deep_forward(params, cfg: RecsysConfig, sparse_ids, dense):
    B, F = sparse_ids.shape
    emb = lookup(params["table"], sparse_ids).reshape(B, -1)        # [B,F*K]
    deep_in = jnp.concatenate([emb, dense], axis=-1)
    deep = _mlp_apply(params["deep"], deep_in)[:, 0]
    wide_s = params["wide"][jnp.arange(F)[None, :], sparse_ids]
    wide = jnp.sum(wide_s, -1) + (dense @ params["wide_dense"])[:, 0]
    return deep + wide + params["bias"]


def wide_deep_loss(params, cfg, sparse_ids, dense, labels):
    return sigmoid_xent(wide_deep_forward(params, cfg, sparse_ids, dense), labels)


# ---------------------------------------------------------------------------
# BERT4Rec — bidirectional encoder over item sequences, masked-item loss
# ---------------------------------------------------------------------------
def _bert4rec_enc_cfg(cfg: RecsysConfig) -> enc_lib.EncoderConfig:
    # +mask +pad, then padded to a mesh-divisible size: an odd item vocab
    # (60002) cannot shard over a 16/256-way axis, which silently
    # REPLICATES the [B, M, V] logits (39 GiB/device at train_batch scale)
    vocab = cfg.n_items + 2
    vocab += (-vocab) % 256
    return enc_lib.EncoderConfig(
        vocab=vocab,
        d_model=cfg.embed_dim,
        n_blocks=cfg.n_blocks,
        n_heads=cfg.n_heads,
        d_ff=4 * cfg.embed_dim,
        max_len=cfg.seq_len,
        pool="none",
    )


def init_bert4rec(key, cfg: RecsysConfig) -> dict:
    return {"encoder": enc_lib.init_encoder(key, _bert4rec_enc_cfg(cfg))}


def bert4rec_param_axes(cfg: RecsysConfig) -> dict:
    return {"encoder": enc_lib.encoder_param_axes(_bert4rec_enc_cfg(cfg))}


def bert4rec_scores(params, cfg: RecsysConfig, item_seq: jax.Array) -> jax.Array:
    """item_seq [B,S] -> per-position item logits [B,S,n_items+2] (tied)."""
    ecfg = _bert4rec_enc_cfg(cfg)
    h = enc_lib.encoder_forward(params["encoder"], ecfg, item_seq)
    logits = jnp.einsum("bsd,vd->bsv", h, params["encoder"]["embed"],
                        preferred_element_type=jnp.float32)
    return shard(logits, "batch", "seq", "vocab")


def bert4rec_loss(params, cfg: RecsysConfig, item_seq, labels, label_mask):
    """Masked-item prediction (positions with label_mask==1)."""
    logits = bert4rec_scores(params, cfg, item_seq)
    return softmax_xent(logits, labels, label_mask)


def bert4rec_masked_loss(params, cfg: RecsysConfig, item_seq, masked_pos,
                         labels) -> jax.Array:
    """Fixed-count masked-position loss: gathers hidden states at ``M``
    pre-chosen positions before the vocab projection, so logits are
    [B, M, V] instead of [B, S, V] — the production-scale train path
    (BERT-style data pipelines pre-select the masked positions anyway).
    """
    ecfg = _bert4rec_enc_cfg(cfg)
    h = enc_lib.encoder_forward(params["encoder"], ecfg, item_seq)   # [B,S,D]
    hm = jnp.take_along_axis(h, masked_pos[..., None], axis=1)       # [B,M,D]
    logits = jnp.einsum("bmd,vd->bmv", hm, params["encoder"]["embed"],
                        preferred_element_type=jnp.float32)
    logits = shard(logits, "batch", None, "vocab")
    return softmax_xent(logits, labels)


def bert4rec_user_embedding(params, cfg: RecsysConfig, item_seq) -> jax.Array:
    """Sequence-level user vector = last-position hidden (for retrieval)."""
    ecfg = _bert4rec_enc_cfg(cfg)
    h = enc_lib.encoder_forward(params["encoder"], ecfg, item_seq)
    return l2_normalize(h[:, -1], axis=-1)


# ---------------------------------------------------------------------------
# MIND — multi-interest extraction via B2I dynamic (capsule) routing
# ---------------------------------------------------------------------------
def init_mind(key, cfg: RecsysConfig) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    K = cfg.embed_dim
    return {
        "items": normal_init(k1, (cfg.n_items, K), 0.02),
        "s_matrix": normal_init(k2, (K, K), 0.02),       # bilinear routing map
        "mlp": _mlp_init(k3, (K,) + tuple(cfg.mlp_dims) + (K,)),
    }


def mind_param_axes(cfg: RecsysConfig) -> dict:
    n_mlp = len(cfg.mlp_dims) + 1
    return {"items": ("table_rows", "feature_dim"),
            "s_matrix": (None, None),
            "mlp": [{"w": (None, None), "b": (None,)} for _ in range(n_mlp)]}


def mind_interests(params, cfg: RecsysConfig, behavior: jax.Array,
                   behavior_mask: jax.Array) -> jax.Array:
    """behavior [B,S] item ids (+mask [B,S]) -> interests [B,I,K].

    B2I dynamic routing (cfg.capsule_iters iterations): routing logits are
    NOT backprop targets across iterations (stop_gradient, per the paper).
    """
    B, S = behavior.shape
    I, K = cfg.n_interests, cfg.embed_dim
    e = jnp.take(params["items"], behavior, axis=0)                 # [B,S,K]
    e = shard(e, "batch", "seq", "feature_dim")
    eh = e @ params["s_matrix"]                                      # [B,S,K]
    mask = behavior_mask.astype(jnp.float32)
    logits0 = jnp.zeros((B, I, S), jnp.float32)

    def routing_iter(logits, _):
        w = jax.nn.softmax(logits, axis=1)                           # over I
        w = w * mask[:, None, :]
        cand = jnp.einsum("bis,bsk->bik", w, jax.lax.stop_gradient(eh))
        cap = _squash(cand)
        upd = jnp.einsum("bik,bsk->bis", cap, jax.lax.stop_gradient(eh))
        return logits + upd, None

    logits, _ = jax.lax.scan(routing_iter, logits0,
                             None, length=max(cfg.capsule_iters - 1, 0))
    w = jax.nn.softmax(logits, axis=1) * mask[:, None, :]
    caps = _squash(jnp.einsum("bis,bsk->bik", w, eh))                # grads flow
    out = caps + _mlp_apply(params["mlp"], caps, final_act=False)
    return l2_normalize(out, axis=-1)


def _squash(x: jax.Array) -> jax.Array:
    n2 = jnp.sum(jnp.square(x), axis=-1, keepdims=True)
    return (n2 / (1.0 + n2)) * x * jax.lax.rsqrt(n2 + 1e-9)


def mind_loss(params, cfg: RecsysConfig, behavior, behavior_mask, target,
              neg_items) -> jax.Array:
    """Label-aware attention + sampled softmax over [target; negatives]."""
    interests = mind_interests(params, cfg, behavior, behavior_mask)  # [B,I,K]
    tgt = jnp.take(params["items"], target, axis=0)                   # [B,K]
    neg = jnp.take(params["items"], neg_items, axis=0)                # [B,Nneg,K]
    # label-aware attention: pow(softmax) over interests wrt the target
    att = jnp.einsum("bik,bk->bi", interests, tgt)
    att = jax.nn.softmax(2.0 * att, axis=-1)
    user = jnp.einsum("bi,bik->bk", att, interests)                   # [B,K]
    cand = jnp.concatenate([tgt[:, None], neg], axis=1)               # [B,1+N,K]
    logits = jnp.einsum("bk,bnk->bn", user, cand)
    labels = jnp.zeros((behavior.shape[0],), jnp.int32)
    return softmax_xent(logits, labels)


def mind_user_embedding(params, cfg: RecsysConfig, behavior, behavior_mask):
    """Max-scoring retrieval uses all interests; we export [B,I,K]."""
    return mind_interests(params, cfg, behavior, behavior_mask)


# ---------------------------------------------------------------------------
# Uniform entry points used by launch/dryrun + smoke tests
# ---------------------------------------------------------------------------
INIT = {"fm": init_fm, "wide_deep": init_wide_deep,
        "bert4rec": init_bert4rec, "mind": init_mind}
AXES = {"fm": fm_param_axes, "wide_deep": wide_deep_param_axes,
        "bert4rec": bert4rec_param_axes, "mind": mind_param_axes}
