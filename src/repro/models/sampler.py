"""Uniform fanout neighbor sampler over a CSR graph — fixed-shape, jittable.

GraphSAGE's sampled-training path (minibatch_lg) requires a *real* neighbor
sampler. CSR layout: ``row_ptr [N+1]``, ``col_idx [E]``. For each seed we draw
``fanout`` neighbors uniformly **with replacement** (the GraphSAGE estimator
is unbiased under with-replacement sampling and it keeps shapes static).
Zero-degree nodes fall back to self-loops.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sample_neighbors(key: jax.Array, row_ptr: jax.Array, col_idx: jax.Array,
                     seeds: jax.Array, fanout: int) -> jax.Array:
    """seeds [B] int32 -> sampled neighbor ids [B, fanout] int32."""
    b = seeds.shape[0]
    start = jnp.take(row_ptr, seeds)
    deg = jnp.take(row_ptr, seeds + 1) - start                    # [B]
    u = jax.random.uniform(key, (b, fanout))
    offs = jnp.floor(u * jnp.maximum(deg, 1)[:, None]).astype(jnp.int32)
    idx = jnp.clip(start[:, None] + offs, 0, col_idx.shape[0] - 1)
    nbrs = jnp.take(col_idx, idx)                                 # [B, fanout]
    return jnp.where(deg[:, None] > 0, nbrs, seeds[:, None])


def make_csr(n_nodes: int, edge_src, edge_dst):
    """Host-side CSR construction from an edge list (numpy)."""
    import numpy as np
    order = np.argsort(edge_src, kind="stable")
    src = np.asarray(edge_src)[order]
    dst = np.asarray(edge_dst)[order]
    counts = np.bincount(src, minlength=n_nodes)
    row_ptr = np.zeros(n_nodes + 1, np.int32)
    np.cumsum(counts, out=row_ptr[1:])
    return row_ptr, dst.astype(np.int32)
