"""Bidirectional transformer encoder (pre-LN, GELU FFN, learned positions).

Two consumers:
  * the RAG query/document embedder (GTE-small-style, 384-d — paper §2.1);
  * the BERT4Rec backbone (items as vocab, masked-item training).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard
from repro.models.attention import blocked_attention
from repro.models.common import layer_norm, normal_init, l2_normalize


@dataclasses.dataclass(frozen=True)
class EncoderConfig:
    vocab: int
    d_model: int
    n_blocks: int
    n_heads: int
    d_ff: int
    max_len: int
    norm_eps: float = 1e-12
    pool: str = "mean"          # mean | cls | none


def init_encoder(key, cfg: EncoderConfig) -> dict:
    L, D, F = cfg.n_blocks, cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 8)
    return {
        "embed": normal_init(ks[0], (cfg.vocab, D), 0.02),
        "pos": normal_init(ks[1], (cfg.max_len, D), 0.02),
        "layers": {
            "ln1_g": jnp.ones((L, D)), "ln1_b": jnp.zeros((L, D)),
            "ln2_g": jnp.ones((L, D)), "ln2_b": jnp.zeros((L, D)),
            "wqkv": normal_init(ks[2], (L, D, 3 * D), 0.02),
            "wo": normal_init(ks[3], (L, D, D), 0.02 / (2 * L) ** 0.5),
            "w1": normal_init(ks[4], (L, D, F), 0.02),
            "b1": jnp.zeros((L, F)),
            "w2": normal_init(ks[5], (L, F, D), 0.02 / (2 * L) ** 0.5),
            "b2": jnp.zeros((L, D)),
        },
        "final_g": jnp.ones((D,)), "final_b": jnp.zeros((D,)),
    }


def encoder_param_axes(cfg: EncoderConfig) -> dict:
    return {
        "embed": ("vocab", "embed"), "pos": (None, "embed"),
        "layers": {
            "ln1_g": ("layers", "embed"), "ln1_b": ("layers", "embed"),
            "ln2_g": ("layers", "embed"), "ln2_b": ("layers", "embed"),
            "wqkv": ("layers", "embed", "heads"),
            "wo": ("layers", "heads", "embed"),
            "w1": ("layers", "embed", "mlp"), "b1": ("layers", "mlp"),
            "w2": ("layers", "mlp", "embed"), "b2": ("layers", "embed"),
        },
        "final_g": ("embed",), "final_b": ("embed",),
    }


def encoder_forward(params: dict, cfg: EncoderConfig, tokens: jax.Array,
                    mask: jax.Array | None = None,
                    dtype=jnp.float32) -> jax.Array:
    """tokens [B,S] -> hidden [B,S,D] (or pooled [B,D] per cfg.pool)."""
    B, S = tokens.shape
    D, H = cfg.d_model, cfg.n_heads
    x = (jnp.take(params["embed"], tokens, axis=0)
         + params["pos"][None, :S]).astype(dtype)
    x = shard(x, "batch", "seq", "act_embed")

    def block(x, lp):
        h = layer_norm(x, lp["ln1_g"], lp["ln1_b"], cfg.norm_eps)
        qkv = jnp.einsum("bsd,de->bse", h, lp["wqkv"].astype(dtype),
                         preferred_element_type=jnp.float32).astype(dtype)
        q, k, v = jnp.split(qkv, 3, axis=-1)
        q = q.reshape(B, S, H, D // H)
        k = k.reshape(B, S, H, D // H)
        v = v.reshape(B, S, H, D // H)
        attn = blocked_attention(q, k, v, causal=False,
                                 block_q=min(256, S), block_k=min(256, S))
        out = jnp.einsum("bsd,de->bse", attn.reshape(B, S, D),
                         lp["wo"].astype(dtype),
                         preferred_element_type=jnp.float32).astype(dtype)
        x = x + out
        h = layer_norm(x, lp["ln2_g"], lp["ln2_b"], cfg.norm_eps)
        g = jax.nn.gelu(jnp.einsum("bsd,df->bsf", h, lp["w1"].astype(dtype),
                                   preferred_element_type=jnp.float32)
                        + lp["b1"].astype(jnp.float32))
        out = jnp.einsum("bsf,fd->bsd", g.astype(dtype),
                         lp["w2"].astype(dtype),
                         preferred_element_type=jnp.float32).astype(dtype)
        return x + out + lp["b2"].astype(dtype), None

    # remat per block: without it the backward saves every attention
    # intermediate of every block (bert4rec train: 83 GiB/device -> fits)
    block = jax.checkpoint(block, prevent_cse=False)
    x, _ = jax.lax.scan(block, x, params["layers"])
    x = layer_norm(x, params["final_g"], params["final_b"], cfg.norm_eps)
    if cfg.pool == "none":
        return x
    if cfg.pool == "cls":
        return x[:, 0]
    if mask is not None:
        w = mask.astype(jnp.float32)[..., None]
        pooled = jnp.sum(x * w, axis=1) / jnp.maximum(jnp.sum(w, axis=1), 1.0)
    else:
        pooled = jnp.mean(x, axis=1)
    return l2_normalize(pooled, axis=-1)
