"""Distributed retrieval over a STATIC array: DB rows sharded over the
whole mesh, per-shard top-k + hierarchical merge (DESIGN.md §4/§8).

This is the pod-scale version of the paper's on-device search: "on-device"
becomes "on-pod" — the whole corpus lives in pod HBM, no external vector
service is consulted, and a query costs one log-depth top-k tree reduction.

The MUTABLE generalization of this helper lives in ``core/sharded.py``:
``ShardedRows`` adds keyed CRUD, deterministic key->shard routing, and
per-shard free-slot bookkeeping on top of the same fan-out/merge dataflow,
and is what the ``VectorIndex`` backends are built on. This module stays
as the thin static-array entry point the dry-run/HLO tooling uses.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.distributed.collectives import hierarchical_topk
from repro.kernels import ops


def sharded_flat_topk(mesh: Mesh, db: jax.Array, queries: jax.Array, k: int,
                      *, metric: str = "cosine",
                      wire_bf16: bool = False) -> tuple[jax.Array, jax.Array]:
    """db [N, D] (rows sharded over every mesh axis), queries [B, D]
    (replicated) -> (dists [B, k], global ids [B, k]) replicated.

    N need not be a multiple of the shard count: the DB is padded up to
    one with sentinel rows whose ids are masked to (-1, INF) BEFORE the
    merge — previously ``n // n_shards`` silently dropped the trailing
    ``N mod S`` rows from the search. Because the sentinel rows' vector
    payload is zeros (their distances can rank arbitrarily well, e.g.
    cosine distance 1.0), each shard over-fetches ``k + pad`` local
    candidates, masks, and re-selects k — padding can therefore never
    displace a real row from the local top-k.
    """
    axes = tuple(mesh.axis_names)
    n = db.shape[0]
    n_shards = int(np.prod([mesh.shape[a] for a in axes]))
    rows_per = -(-n // n_shards)               # ceil: nothing dropped
    pad = rows_per * n_shards - n
    if pad:
        db = jnp.concatenate(
            [db, jnp.zeros((pad, db.shape[1]), db.dtype)], axis=0)

    def local(db_l, q_l):
        kk = min(rows_per, k + pad)
        d, i = ops.flat_topk(db_l, q_l.astype(db_l.dtype), kk, metric=metric)
        if wire_bf16:
            # genuinely bf16 from the source: leaves XLA no convert to
            # commute above the merge all-gathers (wire bytes halve)
            d = d.astype(jnp.bfloat16)
        shard_id = jnp.zeros((), jnp.int32)
        for a in axes:                       # row-major flattened shard index
            shard_id = shard_id * mesh.shape[a] + jax.lax.axis_index(a)
        i = i + shard_id * rows_per
        # sentinel mask: padded rows (global id >= n) must not reach the
        # merge — their distance becomes +inf and their id -1
        from repro.core.sharded import trim_merge_width
        sentinel = i >= n
        d = jnp.where(sentinel, jnp.asarray(jnp.inf, d.dtype), d)
        i = jnp.where(sentinel, -1, i)
        d, i = trim_merge_width(d, i, k, jnp.asarray(jnp.inf, d.dtype))
        # innermost axis first: smallest hop first in the merge tree;
        # static axis sizes engage the ppermute tree reduction per axis
        merge_axes = tuple(reversed(axes))
        return hierarchical_topk(d, i, k, merge_axes, wire_bf16,
                                 axis_sizes=tuple(int(mesh.shape[a])
                                                  for a in merge_axes))

    fn = shard_map(local, mesh=mesh,
                   in_specs=(P(axes, None), P(None, None)),
                   out_specs=(P(None, None), P(None, None)),
                   check_rep=False)   # post-merge values ARE replicated
    return fn(db, queries)


def make_retrieval_step(mesh: Mesh, k: int, metric: str = "cosine"):
    """jit-able retrieval step for the dry-run: (db, q) -> (dists, ids)."""

    @functools.partial(jax.jit,
                       in_shardings=(NamedSharding(mesh, P(tuple(mesh.axis_names), None)),
                                     NamedSharding(mesh, P(None, None))),
                       out_shardings=NamedSharding(mesh, P(None, None)))
    def retrieval_step(db, q):
        return sharded_flat_topk(mesh, db, q, k, metric=metric)

    return retrieval_step
