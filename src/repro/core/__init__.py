# The paper's primary contribution — implement the SYSTEM here
# (scheduler, optimizer, data path, serving loop, etc.) in the
# host framework. Add sibling subpackages for substrates.
#
# Unified retrieval layer: every ANN backend (flat / IVF / HNSW / tiered)
# implements the mutable keyed ``VectorIndex`` protocol; construct one via
# ``make_index(kind, **cfg)``. See DESIGN.md §1.
from repro.core.index import (INDEX_KINDS, VectorIndex, make_index,
                              make_index_from_config)
# Multi-tenant pool: many small private indexes over one shared device
# arena, with per-tenant epochs + LRU paging. See DESIGN.md §10.
from repro.core.tenancy import IndexPool

__all__ = ["INDEX_KINDS", "VectorIndex", "make_index",
           "make_index_from_config", "IndexPool"]
