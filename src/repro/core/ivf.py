"""IVF-Flat index — beyond-paper ANN backend (the paper cites PQ/FAISS-style
coarse quantisation as the other major ANN family; IVF is its TPU-friendly
core: fixed-shape gathers + the same fused distance kernels as HNSW).

Build: a few Lloyd iterations of k-means (pure jnp) -> ``nlist`` centroids;
rows go into fixed-capacity inverted lists (padded, -1). Search: score the
query against centroids, take ``nprobe`` lists, gather their rows (one
``gather_distance`` wave per query batch), exact top-k over candidates.
Everything is fixed-shape, so the whole query path jit-compiles once.
"""
from __future__ import annotations

import dataclasses
import functools
import json
import os
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.hnsw_build import normalize_rows
from repro.core.index import VectorIndex
from repro.kernels import ops


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class IVFIndex:
    vectors: jax.Array        # [N, D] (normalised if cosine)
    centroids: jax.Array      # [nlist, D]
    lists: jax.Array          # [nlist, cap] int32, -1 padded
    metric: str

    def tree_flatten(self):
        return (self.vectors, self.centroids, self.lists), (self.metric,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, metric=aux[0])

    @property
    def n(self):
        return self.vectors.shape[0]


def kmeans(x: jnp.ndarray, k: int, iters: int = 8, seed: int = 0):
    key = jax.random.PRNGKey(seed)
    init = jax.random.choice(key, x.shape[0], (k,), replace=False)
    cent = x[init]

    def step(cent, _):
        d = (jnp.sum(x * x, 1)[:, None] - 2 * x @ cent.T
             + jnp.sum(cent * cent, 1)[None, :])
        assign = jnp.argmin(d, 1)
        sums = jax.ops.segment_sum(x, assign, k)
        cnt = jax.ops.segment_sum(jnp.ones(x.shape[0]), assign, k)
        new = jnp.where(cnt[:, None] > 0, sums / jnp.maximum(cnt[:, None], 1),
                        cent)
        return new, None

    cent, _ = jax.lax.scan(step, cent, None, length=iters)
    d = (jnp.sum(x * x, 1)[:, None] - 2 * x @ cent.T
         + jnp.sum(cent * cent, 1)[None, :])
    return cent, jnp.argmin(d, 1)


def build_ivf(vectors, *, nlist: int = 64, metric: str = "cosine",
              iters: int = 8, seed: int = 0) -> IVFIndex:
    v = np.asarray(vectors, np.float32)
    if metric == "cosine":
        v = normalize_rows(v)
    vj = jnp.asarray(v)
    cent, assign = kmeans(vj, nlist, iters, seed)
    assign = np.asarray(assign)
    counts = np.bincount(assign, minlength=nlist)
    cap = int(counts.max())
    lists = np.full((nlist, cap), -1, np.int32)
    cursor = np.zeros(nlist, np.int64)
    for i, a in enumerate(assign):
        lists[a, cursor[a]] = i
        cursor[a] += 1
    return IVFIndex(vectors=vj, centroids=cent,
                    lists=jnp.asarray(lists), metric=metric)


@functools.partial(jax.jit, static_argnames=("k", "nprobe"))
def _search(idx: IVFIndex, q: jax.Array, k: int, nprobe: int):
    b = q.shape[0]
    cap = idx.lists.shape[1]
    # coarse: nearest nprobe centroids
    cd = ops.gather_distance(
        idx.centroids, q,
        jnp.broadcast_to(jnp.arange(idx.centroids.shape[0]),
                         (b, idx.centroids.shape[0])), metric=idx.metric)
    _, probe = jax.lax.top_k(-cd, nprobe)                 # [B, nprobe]
    cand = jnp.take(idx.lists, probe, axis=0).reshape(b, nprobe * cap)
    valid = cand >= 0
    ids = jnp.clip(cand, 0, idx.n - 1)
    d = ops.gather_distance(idx.vectors, q, ids, metric=idx.metric)
    d = jnp.where(valid, d, jnp.float32(3e38))
    neg, j = jax.lax.top_k(-d, k)
    return jnp.take_along_axis(ids, j, axis=1), -neg


def search_ivf(idx: IVFIndex, queries, k: int = 10, nprobe: int = 8):
    q = jnp.asarray(queries, jnp.float32)
    squeeze = q.ndim == 1
    if squeeze:
        q = q[None]
    if idx.metric == "cosine":
        q = q / jnp.maximum(jnp.linalg.norm(q, axis=-1, keepdims=True), 1e-12)
    ids, dists = _search(idx, q, k, min(nprobe, idx.centroids.shape[0]))
    if squeeze:
        return ids[0], dists[0]
    return ids, dists


class IVFVectorIndex(VectorIndex):
    """Keyed mutable IVF backend (DESIGN.md §1/§4).

    Centroids are trained once (k-means over the rows present at the first
    query); later inserts are assigned to their nearest existing centroid —
    classic IVF ``add`` semantics. Deletes drop the row from its inverted
    list at the next device pack (no tombstone needed in the search path
    because packing already excludes dead rows). The packed device index is
    rebuilt lazily after mutations.
    """

    def __init__(self, *, metric: str = "cosine", dim: int | None = None,
                 nlist: int = 64, nprobe: int = 8, iters: int = 8,
                 seed: int = 0):
        if metric not in ("cosine", "ip", "l2"):
            raise ValueError(f"unknown metric {metric!r}")
        self.metric = metric
        self.dim = dim
        self.nlist = nlist
        self.nprobe = nprobe
        self.iters = iters
        self.seed = seed
        self._vecs = np.zeros((0, dim or 0), np.float32)
        self._keys: list[str] = []
        self._key2row: dict[str, int] = {}
        self._alive = np.zeros(0, bool)
        self._centroids: np.ndarray | None = None   # trained lazily
        self._idx: IVFIndex | None = None           # packed device index
        self._live_rows: np.ndarray | None = None

    # ------------------------------------------------------------ mutation
    def _append(self, key: str, v: np.ndarray):
        if key in self._key2row:
            self._alive[self._key2row[key]] = False
        row = len(self._keys)
        self._vecs = np.concatenate([self._vecs, v[None]])
        self._keys.append(key)
        self._alive = np.concatenate([self._alive, np.ones(1, bool)])
        self._key2row[key] = row
        self._idx = None
        self._bump_epoch()

    def insert(self, key: str, value: Sequence[float]) -> None:
        v = np.asarray(value, np.float32).reshape(-1)
        if self.metric == "cosine":
            v = v / max(float(np.linalg.norm(v)), 1e-12)
        if self.dim is None:
            self.dim = v.shape[0]
            self._vecs = np.zeros((0, self.dim), np.float32)
        self._append(key, v)

    def bulk_insert(self, keys: Sequence[str], values) -> None:
        values = np.asarray(values, np.float32)
        if len(keys) != len(values):
            raise ValueError("keys/values length mismatch")
        if self.metric == "cosine":
            values = normalize_rows(values)
        for key in keys:
            if key in self._key2row:
                self._alive[self._key2row[key]] = False
        if self.dim is None:
            self.dim = values.shape[1]
            self._vecs = np.zeros((0, self.dim), np.float32)
        base = len(self._keys)
        self._vecs = np.concatenate([self._vecs, values])
        self._keys.extend(keys)
        self._alive = np.concatenate([self._alive, np.ones(len(keys), bool)])
        for j, key in enumerate(keys):
            self._key2row[key] = base + j
        self._idx = None
        self._bump_epoch()

    def update(self, key: str, value: Sequence[float]) -> None:
        if key not in self._key2row:
            raise KeyError(key)
        self.insert(key, value)

    def delete(self, key: str) -> None:
        row = self._key2row.pop(key)
        self._alive[row] = False
        self._idx = None
        self._bump_epoch()

    # --------------------------------------------------------------- query
    def _pack(self) -> IVFIndex:
        """(Re)build the padded device lists over live rows only."""
        if self._idx is not None:
            return self._idx
        live = np.flatnonzero(self._alive)
        if live.size == 0:
            raise ValueError("index is empty")
        self._live_rows = live
        v = self._vecs[live]
        nlist = min(self.nlist, live.size)
        if self._centroids is None or self._centroids.shape[0] != nlist:
            cent, assign = kmeans(jnp.asarray(v), nlist, self.iters, self.seed)
            self._centroids = np.asarray(cent)
            assign = np.asarray(assign)
        else:
            cent = jnp.asarray(self._centroids)
            d = (np.sum(v * v, 1)[:, None] - 2 * v @ self._centroids.T
                 + np.sum(self._centroids ** 2, 1)[None, :])
            assign = np.argmin(d, 1)
        counts = np.bincount(assign, minlength=nlist)
        cap = max(int(counts.max()), 1)
        lists = np.full((nlist, cap), -1, np.int32)
        cursor = np.zeros(nlist, np.int64)
        for i, a in enumerate(assign):
            lists[a, cursor[a]] = i
            cursor[a] += 1
        self._idx = IVFIndex(vectors=jnp.asarray(v), centroids=jnp.asarray(cent),
                             lists=jnp.asarray(lists), metric=self.metric)
        return self._idx

    def query_batch(self, queries, k: int = 10, nprobe: int | None = None,
                    **kw):
        """One fixed-shape probed search for the whole [B, D] batch.

        Extra search kwargs from other backends (e.g. hnsw's ``ef``) are
        accepted and ignored so the serving layer can pass one knob set
        through any backend."""
        idx = self._pack()
        q = np.asarray(queries, np.float32)
        if q.ndim != 2:
            raise ValueError(f"query_batch expects [B, D], got {q.shape}")
        ids, d = search_ivf(idx, q, k=min(k, idx.n),
                            nprobe=nprobe or self.nprobe)
        ids, d = np.asarray(ids), np.asarray(d)
        from repro.core.flat import _pad_results
        return _pad_results(
            [[self._keys[int(self._live_rows[j])] if j >= 0 else None
              for j in row] for row in ids], d, k)

    def exact_query(self, query, k: int = 10):
        idx = self._pack()
        # nprobe = nlist probes every list -> exact over the live set
        return self.query(query, k, nprobe=idx.centroids.shape[0])

    # --------------------------------------------------------- persistence
    def export(self, path: str) -> None:
        if not self._keys:
            raise ValueError("index is empty")
        meta = {"metric": self.metric, "dim": self.dim, "nlist": self.nlist,
                "nprobe": self.nprobe, "keys": self._keys}
        tmp = path + ".tmp.npz"
        cent = (self._centroids if self._centroids is not None
                else np.zeros((0, self.dim), np.float32))
        np.savez_compressed(tmp[:-4], vectors=self._vecs, alive=self._alive,
                            centroids=cent,
                            meta=np.frombuffer(json.dumps(meta).encode(),
                                               dtype=np.uint8))
        os.replace(tmp, path)

    @classmethod
    def load(cls, path: str) -> "IVFVectorIndex":
        z = np.load(path, allow_pickle=False)
        meta = json.loads(bytes(z["meta"]).decode())
        idx = cls(metric=meta["metric"], dim=meta["dim"],
                  nlist=meta["nlist"], nprobe=meta["nprobe"])
        idx._vecs = np.asarray(z["vectors"], np.float32)
        idx._alive = np.asarray(z["alive"], bool)
        idx._keys = list(meta["keys"])
        idx._key2row = {k: i for i, k in enumerate(idx._keys)
                        if idx._alive[i]}
        cent = np.asarray(z["centroids"], np.float32)
        idx._centroids = cent if cent.size else None
        return idx

    @property
    def size(self) -> int:
        return len(self._key2row)

    def keys(self) -> list[str]:
        return [k for i, k in enumerate(self._keys) if self._alive[i]]
