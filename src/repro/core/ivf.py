"""IVF-Flat index — beyond-paper ANN backend (the paper cites PQ/FAISS-style
coarse quantisation as the other major ANN family; IVF is its TPU-friendly
core: fixed-shape gathers + the same fused distance kernels as HNSW).

Build: a few Lloyd iterations of k-means (pure jnp) -> ``nlist`` centroids;
rows go into fixed-capacity inverted lists (padded, -1). Search: score the
query against centroids, take ``nprobe`` lists, gather their rows (one
``gather_distance`` wave per query batch), exact top-k over candidates.
Everything is fixed-shape, so the whole query path jit-compiles once.

Sharded operation (DESIGN.md §8): the coarse quantiser is GLOBAL (trained
once over all live rows, replicated to every shard — it is canonical
state), while the inverted lists and row payloads are PER-SHARD: each
shard keeps lists over its own hash-routed rows, probes the same
``nprobe`` clusters as every other shard, scores only its local
candidates (``nprobe * cap / S`` distance work per device), and the
per-shard top-k merges through the hierarchical tree. The union of the
shards' probed candidates is exactly the 1-shard candidate set, which is
why shard count does not change results.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.codec import (check_codec_arrays as _check_codec_arrays,
                              effective_rerank, get_codec, rerank_exact)
from repro.core.hnsw_build import normalize_rows
from repro.core.index import VectorIndex
from repro.core.sharded import (SHARD_AXIS, ShardedRows, hierarchical_topk,
                                resolve_wire_bf16, trim_merge_width)
from repro.kernels import ops


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class IVFIndex:
    vectors: jax.Array        # [N, D] (normalised if cosine); may be
                              # codec-encoded (DESIGN.md §9)
    centroids: jax.Array      # [nlist, D] always fp32 (trained state)
    lists: jax.Array          # [nlist, cap] int32, -1 padded
    metric: str
    scales: jax.Array | None = None   # [N] per-row decode scales (int8)

    def tree_flatten(self):
        return ((self.vectors, self.centroids, self.lists, self.scales),
                (self.metric,))

    @classmethod
    def tree_unflatten(cls, aux, children):
        vectors, centroids, lists, scales = children
        return cls(vectors=vectors, centroids=centroids, lists=lists,
                   metric=aux[0], scales=scales)

    @property
    def n(self):
        return self.vectors.shape[0]


def kmeans(x: jnp.ndarray, k: int, iters: int = 8, seed: int = 0):
    key = jax.random.PRNGKey(seed)
    init = jax.random.choice(key, x.shape[0], (k,), replace=False)
    cent = x[init]

    def step(cent, _):
        d = (jnp.sum(x * x, 1)[:, None] - 2 * x @ cent.T
             + jnp.sum(cent * cent, 1)[None, :])
        assign = jnp.argmin(d, 1)
        sums = jax.ops.segment_sum(x, assign, k)
        cnt = jax.ops.segment_sum(jnp.ones(x.shape[0]), assign, k)
        new = jnp.where(cnt[:, None] > 0, sums / jnp.maximum(cnt[:, None], 1),
                        cent)
        return new, None

    cent, _ = jax.lax.scan(step, cent, None, length=iters)
    d = (jnp.sum(x * x, 1)[:, None] - 2 * x @ cent.T
         + jnp.sum(cent * cent, 1)[None, :])
    return cent, jnp.argmin(d, 1)


def build_ivf(vectors, *, nlist: int = 64, metric: str = "cosine",
              iters: int = 8, seed: int = 0) -> IVFIndex:
    v = np.asarray(vectors, np.float32)
    if metric == "cosine":
        v = normalize_rows(v)
    vj = jnp.asarray(v)
    cent, assign = kmeans(vj, nlist, iters, seed)
    assign = np.asarray(assign)
    counts = np.bincount(assign, minlength=nlist)
    cap = int(counts.max())
    lists = np.full((nlist, cap), -1, np.int32)
    cursor = np.zeros(nlist, np.int64)
    for i, a in enumerate(assign):
        lists[a, cursor[a]] = i
        cursor[a] += 1
    return IVFIndex(vectors=vj, centroids=cent,
                    lists=jnp.asarray(lists), metric=metric)


@functools.partial(jax.jit, static_argnames=("k", "nprobe"))
def _search(idx: IVFIndex, q: jax.Array, k: int, nprobe: int):
    b = q.shape[0]
    cap = idx.lists.shape[1]
    # coarse: nearest nprobe centroids
    cd = ops.gather_distance(
        idx.centroids, q,
        jnp.broadcast_to(jnp.arange(idx.centroids.shape[0]),
                         (b, idx.centroids.shape[0])), metric=idx.metric)
    _, probe = jax.lax.top_k(-cd, nprobe)                 # [B, nprobe]
    cand = jnp.take(idx.lists, probe, axis=0).reshape(b, nprobe * cap)
    valid = cand >= 0
    ids = jnp.clip(cand, 0, idx.n - 1)
    d = ops.gather_distance(idx.vectors, q, ids, metric=idx.metric,
                            scales=idx.scales)
    d = jnp.where(valid, d, jnp.float32(3e38))
    neg, j = jax.lax.top_k(-d, k)
    out_ids = jnp.take_along_axis(ids, j, axis=1)
    # list-padding slots that reached the top-k (fewer live candidates
    # than k) must not leak a clipped row id: mark them missing
    out_ids = jnp.where(-neg >= jnp.float32(3e38), -1, out_ids)
    return out_ids, -neg


def search_ivf(idx: IVFIndex, queries, k: int = 10, nprobe: int = 8):
    q = jnp.asarray(queries, jnp.float32)
    squeeze = q.ndim == 1
    if squeeze:
        q = q[None]
    if idx.metric == "cosine":
        q = q / jnp.maximum(jnp.linalg.norm(q, axis=-1, keepdims=True), 1e-12)
    nprobe = min(nprobe, idx.centroids.shape[0])
    # the probed lists expose at most nprobe*cap candidates; top_k cannot
    # take more than that — callers pad the shortfall (protocol: k slots)
    k = min(k, nprobe * idx.lists.shape[1])
    ids, dists = _search(idx, q, k, nprobe)
    if squeeze:
        return ids[0], dists[0]
    return ids, dists


# ---------------------------------------------------------------------------
# sharded probe: per-shard lists, global centroids, hierarchical merge
# ---------------------------------------------------------------------------
@functools.lru_cache(maxsize=64)
def _ivf_fanout_fn(mesh, k: int, nprobe: int, metric: str,
                   has_scales: bool = False, wire_bf16: bool = False):
    """Compiled sharded IVF search. blocks [S,R,D] + lists [S,nlist,cap] +
    gids [S,R] (and, for a scaled codec, scales [S,R]) sharded over
    ``"shard"``; centroids [nlist,D] and queries [B,D] replicated ->
    (dists [B,k], global row ids [B,k]) replicated. Every shard probes
    the SAME clusters (the coarse score is replicated arithmetic on
    replicated fp32 centroids), gathers only its local members — decoding
    codec rows inside the fused kernel (DESIGN.md §9) — and the per-shard
    top-k merges through the hierarchical tree."""
    INF = jnp.float32(3e38)

    def local(blk, lists, gid, cent, q, scl=None):
        blk, lists, gid = blk[0], lists[0], gid[0]
        b = q.shape[0]
        nlist, cap = lists.shape
        r = blk.shape[0]
        cd = ops.gather_distance(
            cent, q, jnp.broadcast_to(jnp.arange(nlist), (b, nlist)),
            metric=metric)
        _, probe = jax.lax.top_k(-cd, nprobe)             # [B, nprobe]
        cand = jnp.take(lists, probe, axis=0).reshape(b, nprobe * cap)
        valid = cand >= 0
        slots = jnp.clip(cand, 0, r - 1)
        d = ops.gather_distance(blk, q, slots, metric=metric,
                                scales=None if scl is None else scl[0])
        d = jnp.where(valid, d, INF)
        g = jnp.take(gid, slots)
        d, g = trim_merge_width(d, g, k, INF)
        g = jnp.where(d >= INF, -1, g)
        return hierarchical_topk(d, g, k, (SHARD_AXIS,),
                                 wire_bf16=wire_bf16, tie_break_ids=True,
                                 axis_sizes=(mesh.shape[SHARD_AXIS],))

    if has_scales:
        fn = shard_map(
            lambda blk, lists, gid, scl, cent, q:
                local(blk, lists, gid, cent, q, scl),
            mesh=mesh,
            in_specs=(P(SHARD_AXIS, None, None), P(SHARD_AXIS, None, None),
                      P(SHARD_AXIS, None), P(SHARD_AXIS, None),
                      P(None, None), P(None, None)),
            out_specs=(P(None, None), P(None, None)),
            check_rep=False)
        return jax.jit(fn)
    fn = shard_map(local, mesh=mesh,
                   in_specs=(P(SHARD_AXIS, None, None),
                             P(SHARD_AXIS, None, None), P(SHARD_AXIS, None),
                             P(None, None), P(None, None)),
                   out_specs=(P(None, None), P(None, None)),
                   check_rep=False)
    return jax.jit(fn)


class IVFVectorIndex(VectorIndex):
    """Keyed mutable IVF backend (DESIGN.md §1/§4/§8).

    Centroids are trained once (k-means over the rows present at the first
    query); later inserts are assigned to their nearest existing centroid —
    classic IVF ``add`` semantics. Deletes drop the row from its inverted
    list at the next device pack (no tombstone needed in the search path
    because packing already excludes dead rows). The packed device index is
    rebuilt lazily after mutations.

    Because centroids are *trained-once* state that depends on when the
    first query ran (not only on the mutation history), training emits a
    ``derived.centroids`` WAL record when a store is attached — WAL replay
    then reproduces the exact centroids, keeping a warm restore bit-for-bit
    equal to the live index (DESIGN.md §7).

    With ``n_shards > 1`` storage and routing live in ``ShardedRows``;
    the centroids stay global (canonical state, so ``state_dict`` is
    identical at any shard count) while each shard packs inverted lists
    over its own rows and searches them locally (DESIGN.md §8).
    """

    kind = "ivf"

    def __init__(self, *, metric: str = "cosine", dim: int | None = None,
                 nlist: int = 64, nprobe: int = 8, iters: int = 8,
                 seed: int = 0, n_shards: int = 1, dtype: str = "fp32",
                 rerank_factor: int | None = None):
        if metric not in ("cosine", "ip", "l2"):
            raise ValueError(f"unknown metric {metric!r}")
        self.metric = metric
        self.dim = dim
        self.nlist = nlist
        self.nprobe = nprobe
        self.iters = iters
        self.seed = seed
        self.n_shards = int(n_shards)
        self.dtype = str(dtype)
        self.rerank_factor = rerank_factor
        self._codec = get_codec(self.dtype)
        # rows are normalised at INSERT time for cosine (classic IVF add
        # semantics), so the substrate packs them raw — and under a lossy
        # codec quantizes the already-normalized rows once at ingest
        # (DESIGN.md §9)
        self._rows = ShardedRows(n_shards=self.n_shards, metric=metric,
                                 dim=dim, normalize_on_pack=False,
                                 codec=self._codec)
        self._centroids: np.ndarray | None = None   # trained lazily
        self._idx: IVFIndex | None = None           # S==1 packed device index
        self._live_rows: np.ndarray | None = None   # S==1 pack order
        self._spack = None                          # S>1 sharded pack

    # ------------------------------------------------------------ mutation
    def _invalidate(self) -> None:
        self._idx = None
        self._live_rows = None
        self._spack = None

    def _insert_impl(self, key: str, value: np.ndarray) -> None:
        v = np.asarray(value, np.float32).reshape(-1)
        if self.metric == "cosine":
            v = v / max(float(np.linalg.norm(v)), 1e-12)
        self._rows.upsert(key, v)
        self.dim = self._rows.dim
        self._invalidate()
        self._bump_epoch()

    def _bulk_insert_impl(self, keys: list[str], values: np.ndarray) -> None:
        values = np.asarray(values, np.float32)
        if self.metric == "cosine":
            values = normalize_rows(values)
        self._rows.upsert_many(keys, values)
        self.dim = self._rows.dim
        self._invalidate()
        self._bump_epoch()

    def _update_impl(self, key: str, value: np.ndarray) -> None:
        self._insert_impl(key, value)

    def _delete_impl(self, key: str) -> None:
        self._rows.tombstone(key)
        self._invalidate()
        self._bump_epoch()

    def _compact_impl(self) -> None:
        """Physically drop tombstoned rows (DESIGN.md §7). Centroids are
        dropped too — they are aggregates over data that may include the
        deleted rows (a singleton cluster's centroid IS the deleted
        vector) — and retrain over live rows at the next pack."""
        self._rows.compact()
        self._centroids = None
        self._invalidate()
        self._bump_epoch()

    # ----------------------------------------------------------- training
    def _coarse(self, live: np.ndarray) -> tuple[np.ndarray, np.ndarray, int]:
        """-> (centroids, assignment over live rows, nlist). Shared by the
        single-device and sharded packs so the quantiser (and therefore
        the candidate sets) is identical at any shard count."""
        v = self._rows.vectors[live]
        nlist = min(self.nlist, live.size)
        if self._centroids is None or self._centroids.shape[0] != nlist:
            cent, assign = kmeans(jnp.asarray(v), nlist, self.iters, self.seed)
            self._centroids = np.asarray(cent)
            assign = np.asarray(assign)
            # derived-state journaling (DESIGN.md §7): training happened at
            # query time, outside the mutation history, so replay alone
            # cannot reproduce it — log the trained centroids so a warm
            # restore lands on the exact same coarse quantiser
            if self._store is not None:
                self._store.wal_append("derived.centroids",
                                       epoch=self._epoch, meta={},
                                       arrays={"centroids": self._centroids})
        else:
            d = (np.sum(v * v, 1)[:, None] - 2 * v @ self._centroids.T
                 + np.sum(self._centroids ** 2, 1)[None, :])
            assign = np.argmin(d, 1)
        return self._centroids, assign, nlist

    # --------------------------------------------------------------- query
    def _pack(self) -> IVFIndex:
        """(Re)build the single-device padded lists over live rows only."""
        if self._idx is not None:
            return self._idx
        live = np.flatnonzero(self._rows.alive)
        if live.size == 0:
            raise ValueError("index is empty")
        self._live_rows = live
        cent, assign, nlist = self._coarse(live)
        counts = np.bincount(assign, minlength=nlist)
        cap = max(int(counts.max()), 1)
        lists = np.full((nlist, cap), -1, np.int32)
        cursor = np.zeros(nlist, np.int64)
        for i, a in enumerate(assign):
            lists[a, cursor[a]] = i
            cursor[a] += 1
        if self._codec.lossy:
            # device payload = canonical encoded rows; the fine distance
            # decodes in-kernel (asymmetric, DESIGN.md §9)
            vecs = jnp.asarray(self._rows.encoded[live])
            scl = (jnp.asarray(self._rows.scales[live])
                   if self._rows.scales is not None else None)
        else:
            vecs, scl = jnp.asarray(self._rows.vectors[live]), None
        self._idx = IVFIndex(vectors=vecs, centroids=jnp.asarray(cent),
                             lists=jnp.asarray(lists), metric=self.metric,
                             scales=scl)
        return self._idx

    def _pack_sharded(self):
        """(Re)build the per-shard inverted lists (DESIGN.md §8): every
        live row's slot joins its cluster's list ON ITS OWNING SHARD."""
        if self._spack is not None:
            return self._spack
        live = np.flatnonzero(self._rows.alive)
        if live.size == 0:
            raise ValueError("index is empty")
        mesh, blocks, gids, scl, _slack = self._rows.pack()
        cent, assign, nlist = self._coarse(live)
        s_lists: list[list[list[int]]] = [
            [[] for _ in range(nlist)] for _ in range(self.n_shards)]
        counts = np.bincount(assign, minlength=nlist)
        cap_global = max(int(counts.max()), 1)    # 1-shard-equivalent cap:
        cap = 1                                   # keeps the k clamp equal
        for rank, row in enumerate(live):
            s, slot = self._rows.placement_of_row(int(row))
            bucket = s_lists[s][int(assign[rank])]
            bucket.append(slot)
            cap = max(cap, len(bucket))
        lists = np.full((self.n_shards, nlist, cap), -1, np.int32)
        for s in range(self.n_shards):
            for c in range(nlist):
                m = s_lists[s][c]
                lists[s, c, :len(m)] = m
        lj = jax.device_put(jnp.asarray(lists),
                            NamedSharding(mesh, P(SHARD_AXIS, None, None)))
        self._spack = (mesh, blocks, lj, gids, scl, jnp.asarray(cent),
                       nlist, cap_global, int(live.size))
        return self._spack

    def query_batch(self, queries, k: int = 10, nprobe: int | None = None,
                    **kw):
        """One fixed-shape probed search for the whole [B, D] batch —
        single-dispatch sharded fan-out when ``n_shards > 1``.

        Under a lossy codec (DESIGN.md §9) the probed candidates are
        scored asymmetrically (fp32 query vs encoded rows, decode fused
        in-kernel), the search over-fetches ``k·rerank_factor``, and the
        survivors rerank exactly in fp32 from the canonical host rows.

        Extra search kwargs from other backends (e.g. hnsw's ``ef``) are
        accepted and ignored so the serving layer can pass one knob set
        through any backend."""
        q = np.asarray(queries, np.float32)
        if q.ndim != 2:
            raise ValueError(f"query_batch expects [B, D], got {q.shape}")
        rf = effective_rerank(self._codec, self.rerank_factor)
        from repro.core.flat import _pad_results
        if self.n_shards == 1:
            idx = self._pack()
            ids, d = search_ivf(idx, q, k=min(k * rf, idx.n),
                                nprobe=nprobe or self.nprobe)
            ids, d = np.asarray(ids), np.asarray(d)
            if rf > 1:
                gids = np.where(ids >= 0, self._live_rows[ids], -1)
                d, gids = self._rows.rerank_topk(q, gids, k)
                return _pad_results(
                    [[self._rows.key_of_row(int(r)) if r >= 0 else None
                      for r in row] for row in gids], d, k)
            return _pad_results(
                [[self._rows.key_of_row(int(self._live_rows[j]))
                  if j >= 0 else None for j in row] for row in ids], d, k)
        mesh, blocks, lists, gids, scl, cent, nlist, cap_global, n_live = \
            self._pack_sharded()
        qj = jnp.asarray(q)
        if self.metric == "cosine":
            qj = qj / jnp.maximum(
                jnp.linalg.norm(qj, axis=-1, keepdims=True), 1e-12)
        npr = min(nprobe or self.nprobe, nlist)
        # same candidate-capacity clamp the 1-shard path applies
        k_eff = min(min(k * rf, n_live), npr * cap_global)
        fn = _ivf_fanout_fn(mesh, k_eff, npr, self.metric,
                            has_scales=scl is not None,
                            wire_bf16=resolve_wire_bf16(None))
        d, g = (fn(blocks, lists, gids, scl, cent, qj) if scl is not None
                else fn(blocks, lists, gids, cent, qj))
        d, g = np.asarray(d), np.asarray(g)
        if rf > 1:
            d, g = self._rows.rerank_topk(q, g, k)
        return _pad_results(
            [[self._rows.key_of_row(int(r)) if r >= 0 else None
              for r in row] for row in g], d, k)

    def exact_query(self, query, k: int = 10):
        # nprobe = nlist probes every list -> exact over the live set
        if self.n_shards == 1:
            idx = self._pack()
            return self.query(query, k, nprobe=idx.centroids.shape[0])
        nlist = self._pack_sharded()[6]
        return self.query(query, k, nprobe=nlist)

    # --------------------------------------------------------- persistence
    # Canonical state only (DESIGN.md §8): vectors + tombstones + keys +
    # the GLOBAL centroids — per-shard lists are derived pack state, so
    # the same state_dict restores onto any shard count.
    def config_dict(self) -> dict:
        return {"metric": self.metric, "dim": self.dim, "nlist": self.nlist,
                "nprobe": self.nprobe, "iters": self.iters,
                "seed": self.seed, "n_shards": self.n_shards,
                "dtype": self.dtype, "rerank_factor": self.rerank_factor}

    def state_dict(self) -> tuple[dict, dict]:
        cent = (self._centroids if self._centroids is not None
                else np.zeros((0, self.dim or 0), np.float32))
        if self._codec.lossy:
            arrays = {"vectors_enc":
                      self._codec.to_storage(self._rows.encoded),
                      "alive": self._rows.alive, "centroids": cent}
            if self._rows.scales is not None:
                arrays["scales"] = self._rows.scales
        else:
            arrays = {"vectors": self._rows.vectors,
                      "alive": self._rows.alive, "centroids": cent}
        meta = {"keys": list(self._rows.key_list), "epoch": self._epoch,
                "has_centroids": self._centroids is not None}
        return arrays, meta

    def restore_state(self, arrays: dict, meta: dict) -> None:
        _check_codec_arrays(self._codec, arrays, self.kind)
        if self._codec.lossy:
            self._rows.restore_encoded(arrays["vectors_enc"],
                                       arrays.get("scales"),
                                       list(meta["keys"]),
                                       np.asarray(arrays["alive"], bool))
        else:
            self._rows.restore(np.asarray(arrays["vectors"], np.float32),
                               list(meta["keys"]),
                               np.asarray(arrays["alive"], bool))
        if self._rows.dim:
            self.dim = self._rows.dim
        self._centroids = (np.asarray(arrays["centroids"], np.float32)
                           if meta["has_centroids"] else None)
        self._epoch = int(meta["epoch"])
        self._invalidate()

    def _apply_derived(self, op: str, meta: dict, arrays: dict) -> None:
        if op != "derived.centroids":
            raise ValueError(f"IVFVectorIndex cannot replay {op!r}")
        self._centroids = np.asarray(arrays["centroids"], np.float32)
        self._invalidate()

    def _row_count(self) -> int:
        return self._rows.row_count

    @property
    def size(self) -> int:
        return self._rows.size

    def _contains(self, key: str) -> bool:
        return self._rows.contains(key)

    def keys(self) -> list[str]:
        return self._rows.live_keys()

    @property
    def shard_count(self) -> int:
        return self.n_shards

    def shard_stats(self) -> list[dict]:
        return self._rows.shard_stats()
