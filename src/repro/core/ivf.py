"""IVF-Flat index — beyond-paper ANN backend (the paper cites PQ/FAISS-style
coarse quantisation as the other major ANN family; IVF is its TPU-friendly
core: fixed-shape gathers + the same fused distance kernels as HNSW).

Build: a few Lloyd iterations of k-means (pure jnp) -> ``nlist`` centroids;
rows go into fixed-capacity inverted lists (padded, -1). Search: score the
query against centroids, take ``nprobe`` lists, gather their rows (one
``gather_distance`` wave per query batch), exact top-k over candidates.
Everything is fixed-shape, so the whole query path jit-compiles once.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.hnsw_build import normalize_rows
from repro.core.index import VectorIndex
from repro.kernels import ops


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class IVFIndex:
    vectors: jax.Array        # [N, D] (normalised if cosine)
    centroids: jax.Array      # [nlist, D]
    lists: jax.Array          # [nlist, cap] int32, -1 padded
    metric: str

    def tree_flatten(self):
        return (self.vectors, self.centroids, self.lists), (self.metric,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, metric=aux[0])

    @property
    def n(self):
        return self.vectors.shape[0]


def kmeans(x: jnp.ndarray, k: int, iters: int = 8, seed: int = 0):
    key = jax.random.PRNGKey(seed)
    init = jax.random.choice(key, x.shape[0], (k,), replace=False)
    cent = x[init]

    def step(cent, _):
        d = (jnp.sum(x * x, 1)[:, None] - 2 * x @ cent.T
             + jnp.sum(cent * cent, 1)[None, :])
        assign = jnp.argmin(d, 1)
        sums = jax.ops.segment_sum(x, assign, k)
        cnt = jax.ops.segment_sum(jnp.ones(x.shape[0]), assign, k)
        new = jnp.where(cnt[:, None] > 0, sums / jnp.maximum(cnt[:, None], 1),
                        cent)
        return new, None

    cent, _ = jax.lax.scan(step, cent, None, length=iters)
    d = (jnp.sum(x * x, 1)[:, None] - 2 * x @ cent.T
         + jnp.sum(cent * cent, 1)[None, :])
    return cent, jnp.argmin(d, 1)


def build_ivf(vectors, *, nlist: int = 64, metric: str = "cosine",
              iters: int = 8, seed: int = 0) -> IVFIndex:
    v = np.asarray(vectors, np.float32)
    if metric == "cosine":
        v = normalize_rows(v)
    vj = jnp.asarray(v)
    cent, assign = kmeans(vj, nlist, iters, seed)
    assign = np.asarray(assign)
    counts = np.bincount(assign, minlength=nlist)
    cap = int(counts.max())
    lists = np.full((nlist, cap), -1, np.int32)
    cursor = np.zeros(nlist, np.int64)
    for i, a in enumerate(assign):
        lists[a, cursor[a]] = i
        cursor[a] += 1
    return IVFIndex(vectors=vj, centroids=cent,
                    lists=jnp.asarray(lists), metric=metric)


@functools.partial(jax.jit, static_argnames=("k", "nprobe"))
def _search(idx: IVFIndex, q: jax.Array, k: int, nprobe: int):
    b = q.shape[0]
    cap = idx.lists.shape[1]
    # coarse: nearest nprobe centroids
    cd = ops.gather_distance(
        idx.centroids, q,
        jnp.broadcast_to(jnp.arange(idx.centroids.shape[0]),
                         (b, idx.centroids.shape[0])), metric=idx.metric)
    _, probe = jax.lax.top_k(-cd, nprobe)                 # [B, nprobe]
    cand = jnp.take(idx.lists, probe, axis=0).reshape(b, nprobe * cap)
    valid = cand >= 0
    ids = jnp.clip(cand, 0, idx.n - 1)
    d = ops.gather_distance(idx.vectors, q, ids, metric=idx.metric)
    d = jnp.where(valid, d, jnp.float32(3e38))
    neg, j = jax.lax.top_k(-d, k)
    out_ids = jnp.take_along_axis(ids, j, axis=1)
    # list-padding slots that reached the top-k (fewer live candidates
    # than k) must not leak a clipped row id: mark them missing
    out_ids = jnp.where(-neg >= jnp.float32(3e38), -1, out_ids)
    return out_ids, -neg


def search_ivf(idx: IVFIndex, queries, k: int = 10, nprobe: int = 8):
    q = jnp.asarray(queries, jnp.float32)
    squeeze = q.ndim == 1
    if squeeze:
        q = q[None]
    if idx.metric == "cosine":
        q = q / jnp.maximum(jnp.linalg.norm(q, axis=-1, keepdims=True), 1e-12)
    nprobe = min(nprobe, idx.centroids.shape[0])
    # the probed lists expose at most nprobe*cap candidates; top_k cannot
    # take more than that — callers pad the shortfall (protocol: k slots)
    k = min(k, nprobe * idx.lists.shape[1])
    ids, dists = _search(idx, q, k, nprobe)
    if squeeze:
        return ids[0], dists[0]
    return ids, dists


class IVFVectorIndex(VectorIndex):
    """Keyed mutable IVF backend (DESIGN.md §1/§4).

    Centroids are trained once (k-means over the rows present at the first
    query); later inserts are assigned to their nearest existing centroid —
    classic IVF ``add`` semantics. Deletes drop the row from its inverted
    list at the next device pack (no tombstone needed in the search path
    because packing already excludes dead rows). The packed device index is
    rebuilt lazily after mutations.

    Because centroids are *trained-once* state that depends on when the
    first query ran (not only on the mutation history), training emits a
    ``derived.centroids`` WAL record when a store is attached — WAL replay
    then reproduces the exact centroids, keeping a warm restore bit-for-bit
    equal to the live index (DESIGN.md §7).
    """

    kind = "ivf"

    def __init__(self, *, metric: str = "cosine", dim: int | None = None,
                 nlist: int = 64, nprobe: int = 8, iters: int = 8,
                 seed: int = 0):
        if metric not in ("cosine", "ip", "l2"):
            raise ValueError(f"unknown metric {metric!r}")
        self.metric = metric
        self.dim = dim
        self.nlist = nlist
        self.nprobe = nprobe
        self.iters = iters
        self.seed = seed
        self._vecs = np.zeros((0, dim or 0), np.float32)
        self._keys: list[str] = []
        self._key2row: dict[str, int] = {}
        self._alive = np.zeros(0, bool)
        self._centroids: np.ndarray | None = None   # trained lazily
        self._idx: IVFIndex | None = None           # packed device index
        self._live_rows: np.ndarray | None = None

    # ------------------------------------------------------------ mutation
    def _append(self, key: str, v: np.ndarray):
        if key in self._key2row:
            self._alive[self._key2row[key]] = False
        row = len(self._keys)
        self._vecs = np.concatenate([self._vecs, v[None]])
        self._keys.append(key)
        self._alive = np.concatenate([self._alive, np.ones(1, bool)])
        self._key2row[key] = row
        self._idx = None
        self._bump_epoch()

    def _insert_impl(self, key: str, value: np.ndarray) -> None:
        v = np.asarray(value, np.float32).reshape(-1)
        if self.metric == "cosine":
            v = v / max(float(np.linalg.norm(v)), 1e-12)
        if self.dim is None:
            self.dim = v.shape[0]
            self._vecs = np.zeros((0, self.dim), np.float32)
        self._append(key, v)

    def _bulk_insert_impl(self, keys: list[str], values: np.ndarray) -> None:
        if self.metric == "cosine":
            values = normalize_rows(values)
        for key in keys:
            if key in self._key2row:
                self._alive[self._key2row[key]] = False
        if self.dim is None:
            self.dim = values.shape[1]
            self._vecs = np.zeros((0, self.dim), np.float32)
        base = len(self._keys)
        self._vecs = np.concatenate([self._vecs, values])
        self._keys.extend(keys)
        self._alive = np.concatenate([self._alive, np.ones(len(keys), bool)])
        for j, key in enumerate(keys):
            self._key2row[key] = base + j
        self._idx = None
        self._bump_epoch()

    def _update_impl(self, key: str, value: np.ndarray) -> None:
        self._insert_impl(key, value)

    def _delete_impl(self, key: str) -> None:
        row = self._key2row.pop(key)
        self._alive[row] = False
        self._idx = None
        self._bump_epoch()

    def _compact_impl(self) -> None:
        """Physically drop tombstoned rows (DESIGN.md §7). Centroids are
        dropped too — they are aggregates over data that may include the
        deleted rows (a singleton cluster's centroid IS the deleted
        vector) — and retrain over live rows at the next pack."""
        live = np.flatnonzero(self._alive)
        self._vecs = np.ascontiguousarray(self._vecs[live])
        self._keys = [self._keys[i] for i in live]
        self._alive = np.ones(live.size, bool)
        self._key2row = {k: i for i, k in enumerate(self._keys)}
        self._centroids = None
        self._idx = None
        self._live_rows = None
        self._bump_epoch()

    # --------------------------------------------------------------- query
    def _pack(self) -> IVFIndex:
        """(Re)build the padded device lists over live rows only."""
        if self._idx is not None:
            return self._idx
        live = np.flatnonzero(self._alive)
        if live.size == 0:
            raise ValueError("index is empty")
        self._live_rows = live
        v = self._vecs[live]
        nlist = min(self.nlist, live.size)
        if self._centroids is None or self._centroids.shape[0] != nlist:
            cent, assign = kmeans(jnp.asarray(v), nlist, self.iters, self.seed)
            self._centroids = np.asarray(cent)
            assign = np.asarray(assign)
            # derived-state journaling (DESIGN.md §7): training happened at
            # query time, outside the mutation history, so replay alone
            # cannot reproduce it — log the trained centroids so a warm
            # restore lands on the exact same coarse quantiser
            if self._store is not None:
                self._store.wal_append("derived.centroids",
                                       epoch=self._epoch, meta={},
                                       arrays={"centroids": self._centroids})
        else:
            cent = jnp.asarray(self._centroids)
            d = (np.sum(v * v, 1)[:, None] - 2 * v @ self._centroids.T
                 + np.sum(self._centroids ** 2, 1)[None, :])
            assign = np.argmin(d, 1)
        counts = np.bincount(assign, minlength=nlist)
        cap = max(int(counts.max()), 1)
        lists = np.full((nlist, cap), -1, np.int32)
        cursor = np.zeros(nlist, np.int64)
        for i, a in enumerate(assign):
            lists[a, cursor[a]] = i
            cursor[a] += 1
        self._idx = IVFIndex(vectors=jnp.asarray(v), centroids=jnp.asarray(cent),
                             lists=jnp.asarray(lists), metric=self.metric)
        return self._idx

    def query_batch(self, queries, k: int = 10, nprobe: int | None = None,
                    **kw):
        """One fixed-shape probed search for the whole [B, D] batch.

        Extra search kwargs from other backends (e.g. hnsw's ``ef``) are
        accepted and ignored so the serving layer can pass one knob set
        through any backend."""
        idx = self._pack()
        q = np.asarray(queries, np.float32)
        if q.ndim != 2:
            raise ValueError(f"query_batch expects [B, D], got {q.shape}")
        ids, d = search_ivf(idx, q, k=min(k, idx.n),
                            nprobe=nprobe or self.nprobe)
        ids, d = np.asarray(ids), np.asarray(d)
        from repro.core.flat import _pad_results
        return _pad_results(
            [[self._keys[int(self._live_rows[j])] if j >= 0 else None
              for j in row] for row in ids], d, k)

    def exact_query(self, query, k: int = 10):
        idx = self._pack()
        # nprobe = nlist probes every list -> exact over the live set
        return self.query(query, k, nprobe=idx.centroids.shape[0])

    # --------------------------------------------------------- persistence
    def config_dict(self) -> dict:
        return {"metric": self.metric, "dim": self.dim, "nlist": self.nlist,
                "nprobe": self.nprobe, "iters": self.iters,
                "seed": self.seed}

    def state_dict(self) -> tuple[dict, dict]:
        cent = (self._centroids if self._centroids is not None
                else np.zeros((0, self.dim or 0), np.float32))
        arrays = {"vectors": self._vecs, "alive": self._alive,
                  "centroids": cent}
        meta = {"keys": list(self._keys), "epoch": self._epoch,
                "has_centroids": self._centroids is not None}
        return arrays, meta

    def restore_state(self, arrays: dict, meta: dict) -> None:
        self._vecs = np.asarray(arrays["vectors"], np.float32)
        self._alive = np.asarray(arrays["alive"], bool)
        if self._vecs.shape[1]:
            self.dim = int(self._vecs.shape[1])
        self._keys = list(meta["keys"])
        self._key2row = {k: i for i, k in enumerate(self._keys)
                         if self._alive[i]}
        self._centroids = (np.asarray(arrays["centroids"], np.float32)
                           if meta["has_centroids"] else None)
        self._epoch = int(meta["epoch"])
        self._idx = None
        self._live_rows = None

    def _apply_derived(self, op: str, meta: dict, arrays: dict) -> None:
        if op != "derived.centroids":
            raise ValueError(f"IVFVectorIndex cannot replay {op!r}")
        self._centroids = np.asarray(arrays["centroids"], np.float32)
        self._idx = None

    def _row_count(self) -> int:
        return len(self._keys)

    @property
    def size(self) -> int:
        return len(self._key2row)

    def _contains(self, key: str) -> bool:
        return key in self._key2row

    def keys(self) -> list[str]:
        return [k for i, k in enumerate(self._keys) if self._alive[i]]
