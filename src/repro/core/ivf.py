"""IVF-Flat index — beyond-paper ANN backend (the paper cites PQ/FAISS-style
coarse quantisation as the other major ANN family; IVF is its TPU-friendly
core: fixed-shape gathers + the same fused distance kernels as HNSW).

Build: a few Lloyd iterations of k-means (pure jnp) -> ``nlist`` centroids;
rows go into fixed-capacity inverted lists (padded, -1). Search: score the
query against centroids, take ``nprobe`` lists, gather their rows (one
``gather_distance`` wave per query batch), exact top-k over candidates.
Everything is fixed-shape, so the whole query path jit-compiles once.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.hnsw_build import normalize_rows
from repro.kernels import ops


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class IVFIndex:
    vectors: jax.Array        # [N, D] (normalised if cosine)
    centroids: jax.Array      # [nlist, D]
    lists: jax.Array          # [nlist, cap] int32, -1 padded
    metric: str

    def tree_flatten(self):
        return (self.vectors, self.centroids, self.lists), (self.metric,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, metric=aux[0])

    @property
    def n(self):
        return self.vectors.shape[0]


def kmeans(x: jnp.ndarray, k: int, iters: int = 8, seed: int = 0):
    key = jax.random.PRNGKey(seed)
    init = jax.random.choice(key, x.shape[0], (k,), replace=False)
    cent = x[init]

    def step(cent, _):
        d = (jnp.sum(x * x, 1)[:, None] - 2 * x @ cent.T
             + jnp.sum(cent * cent, 1)[None, :])
        assign = jnp.argmin(d, 1)
        sums = jax.ops.segment_sum(x, assign, k)
        cnt = jax.ops.segment_sum(jnp.ones(x.shape[0]), assign, k)
        new = jnp.where(cnt[:, None] > 0, sums / jnp.maximum(cnt[:, None], 1),
                        cent)
        return new, None

    cent, _ = jax.lax.scan(step, cent, None, length=iters)
    d = (jnp.sum(x * x, 1)[:, None] - 2 * x @ cent.T
         + jnp.sum(cent * cent, 1)[None, :])
    return cent, jnp.argmin(d, 1)


def build_ivf(vectors, *, nlist: int = 64, metric: str = "cosine",
              iters: int = 8, seed: int = 0) -> IVFIndex:
    v = np.asarray(vectors, np.float32)
    if metric == "cosine":
        v = normalize_rows(v)
    vj = jnp.asarray(v)
    cent, assign = kmeans(vj, nlist, iters, seed)
    assign = np.asarray(assign)
    counts = np.bincount(assign, minlength=nlist)
    cap = int(counts.max())
    lists = np.full((nlist, cap), -1, np.int32)
    cursor = np.zeros(nlist, np.int64)
    for i, a in enumerate(assign):
        lists[a, cursor[a]] = i
        cursor[a] += 1
    return IVFIndex(vectors=vj, centroids=cent,
                    lists=jnp.asarray(lists), metric=metric)


@functools.partial(jax.jit, static_argnames=("k", "nprobe"))
def _search(idx: IVFIndex, q: jax.Array, k: int, nprobe: int):
    b = q.shape[0]
    cap = idx.lists.shape[1]
    # coarse: nearest nprobe centroids
    cd = ops.gather_distance(
        idx.centroids, q,
        jnp.broadcast_to(jnp.arange(idx.centroids.shape[0]),
                         (b, idx.centroids.shape[0])), metric=idx.metric)
    _, probe = jax.lax.top_k(-cd, nprobe)                 # [B, nprobe]
    cand = jnp.take(idx.lists, probe, axis=0).reshape(b, nprobe * cap)
    valid = cand >= 0
    ids = jnp.clip(cand, 0, idx.n - 1)
    d = ops.gather_distance(idx.vectors, q, ids, metric=idx.metric)
    d = jnp.where(valid, d, jnp.float32(3e38))
    neg, j = jax.lax.top_k(-d, k)
    return jnp.take_along_axis(ids, j, axis=1), -neg


def search_ivf(idx: IVFIndex, queries, k: int = 10, nprobe: int = 8):
    q = jnp.asarray(queries, jnp.float32)
    squeeze = q.ndim == 1
    if squeeze:
        q = q[None]
    if idx.metric == "cosine":
        q = q / jnp.maximum(jnp.linalg.norm(q, axis=-1, keepdims=True), 1e-12)
    ids, dists = _search(idx, q, k, min(nprobe, idx.centroids.shape[0]))
    if squeeze:
        return ids[0], dists[0]
    return ids, dists
