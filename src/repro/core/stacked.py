"""One-dispatch segment fan-out for graph-backed shards (DESIGN.md §8).

A sharded HNSW is a segment set: each shard owns an independent graph
over its hash-routed keys. The original sharded ``query_batch`` looped
``child.query_batch(...)`` in Python — S device dispatches plus a host
merge per batch, which is exactly the S=8 latency cliff BENCH smoke
measured (per-shard scan time shrinks with S, dispatch + host merge
grows with it).

This module compiles the whole fan-out into ONE XLA program at any
shard count: the per-shard ``DeviceGraph`` pytrees are stacked along a
leading [S, ...] axis (capacity-padded to the largest shard; padded
rows are unreachable — no inbound edges — and masked via the existing
tombstone machinery), the lock-step beam search runs per shard under
``shard_map`` on the shard mesh, and the per-shard candidates merge
in-program through the ppermute tree reduction
(``hierarchical_topk``). Global result ids are ``gid = s * cap + node``
so the caller can invert them to (shard, node) without a table.

The stacked arrays are built from the children's RESIDENT device
graphs (device-side pad + stack, no host repack) and are meant to be
cached by the index keyed on ``mutation_epoch`` — steady-state sharded
search then touches zero host bytes and issues exactly one dispatch.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import dispatch
from repro.core import hnsw as jhnsw
from repro.core.sharded import SHARD_AXIS, resolve_wire_bf16
from repro.distributed.collectives import hierarchical_topk

INF = np.float32(3e38)

# incremented once per compiled stacked-search invocation: tests assert
# a sharded ``query_batch`` is exactly ONE device dispatch at any S.
# Kept as the historical module global; the named counters in
# core/dispatch.py ("stacked.search_stacked", "stacked.beam_launches")
# are bumped in lockstep.
DISPATCH_COUNT = 0


@dataclasses.dataclass(frozen=True)
class StackedGraphs:
    """Per-shard DeviceGraphs stacked along a leading [S, ...] axis,
    capacity-padded to the largest shard and resident on the shard mesh.
    Empty shards hold an all-tombstoned placeholder so the mesh size is
    always exactly the index's shard count."""
    mesh: Mesh
    vectors: jax.Array      # [S, cap, D] storage dtype (DESIGN.md §9)
    neighbors0: jax.Array   # [S, cap, 2M] int32, -1 pad
    upper: jax.Array        # [S, L, cap, M] int32, -1 pad
    levels: jax.Array       # [S, cap] int32
    entry: jax.Array        # [S] int32
    deleted: jax.Array      # [S, cap] bool tombstones
    scales: jax.Array | None  # [S, cap] f32 decode scales (int8 codec)
    max_level: int          # max over shards: static descent unroll depth
    metric: str
    cap: int                # padded per-shard capacity: gid = s*cap + node


def stack_device_graphs(graphs: list[jhnsw.DeviceGraph | None],
                        mesh: Mesh) -> StackedGraphs:
    """Stack per-shard resident graphs (None = empty shard) into one
    [S, ...] pytree sharded over ``mesh``. All inputs are device arrays,
    so padding + stacking is device work — the host never rebuilds row
    blocks (contrast the exact phase's ``build_exact_blocks``)."""
    live = [g for g in graphs if g is not None]
    if not live:
        raise ValueError("index is empty")
    proto = live[0]
    cap = max(g.n for g in live)
    layers = proto.upper.shape[0]
    m = proto.upper.shape[2] if proto.upper.ndim == 3 else 1
    m2 = proto.neighbors0.shape[1]
    dim = proto.vectors.shape[1]
    has_scales = proto.scales is not None
    vecs, n0s, ups, lvls, ents, dels, scls = [], [], [], [], [], [], []
    for g in graphs:
        if g is None:
            # unreachable placeholder: no edges, entry 0, everything
            # tombstoned — the beam returns (INF, -1) for this shard
            vecs.append(jnp.zeros((cap, dim), proto.vectors.dtype))
            n0s.append(jnp.full((cap, m2), -1, jnp.int32))
            ups.append(jnp.full((layers, cap, m), -1, jnp.int32))
            lvls.append(jnp.zeros((cap,), jnp.int32))
            ents.append(jnp.zeros((), jnp.int32))
            dels.append(jnp.ones((cap,), bool))
            if has_scales:
                scls.append(jnp.zeros((cap,), jnp.float32))
            continue
        pad = cap - g.n
        vecs.append(jnp.pad(g.vectors, ((0, pad), (0, 0))))
        n0s.append(jnp.pad(g.neighbors0, ((0, pad), (0, 0)),
                           constant_values=-1))
        ups.append(jnp.pad(g.upper, ((0, 0), (0, pad), (0, 0)),
                           constant_values=-1))
        lvls.append(jnp.pad(g.levels, (0, pad)))
        ents.append(g.entry)
        dels.append(jnp.pad(g.deleted, (0, pad), constant_values=True))
        if has_scales:
            scls.append(jnp.pad(g.scales, (0, pad)))

    def put(x, *axes):
        return jax.device_put(x, NamedSharding(mesh, P(SHARD_AXIS, *axes)))

    return StackedGraphs(
        mesh=mesh,
        vectors=put(jnp.stack(vecs), None, None),
        neighbors0=put(jnp.stack(n0s), None, None),
        upper=put(jnp.stack(ups), None, None, None),
        levels=put(jnp.stack(lvls), None),
        entry=put(jnp.stack(ents)),
        deleted=put(jnp.stack(dels), None),
        scales=put(jnp.stack(scls), None) if has_scales else None,
        max_level=max(g.max_level for g in live),
        metric=proto.metric,
        cap=cap)


@functools.lru_cache(maxsize=32)
def _stacked_search_fn(mesh: Mesh, k: int, ef: int, metric: str,
                       max_level: int, has_scales: bool, wire_bf16: bool,
                       beam_impl: str = "fused"):
    """Compiled stacked fan-out: every shard runs the full lock-step
    search (``hnsw.search_core`` — greedy descent + ef-beam + tombstone
    filter) over its own slice, then the per-shard top-k merges through
    the ppermute tree. ``max_level`` is the max over shards: shards with
    shallower graphs see all-(-1) neighbor rows on the extra layers, so
    their descent terminates after one probe per layer.

    Cache keys are (mesh, k, ef, metric, max_level, has_scales,
    wire_bf16) — all O(1)-valued per index configuration (max_level is
    bounded by the builder's layer cap), so the cache cannot churn."""
    n_shards = mesh.shape[SHARD_AXIS]

    def local(vectors, neighbors0, upper, levels, entry, deleted, q,
              scl=None):
        g = jhnsw.DeviceGraph(
            vectors=vectors[0], neighbors0=neighbors0[0], upper=upper[0],
            levels=levels[0], entry=entry[0], deleted=deleted[0],
            max_level=max_level, metric=metric,
            scales=None if scl is None else scl[0])
        ids, d = jhnsw.search_core(g, q, k, ef, beam_impl=beam_impl)
        cap = vectors.shape[1]
        my = jax.lax.axis_index(SHARD_AXIS)
        gid = jnp.where(ids >= 0, my * cap + ids, -1)
        d = jnp.where(ids >= 0, d, jnp.float32(INF))
        return hierarchical_topk(d, gid, k, (SHARD_AXIS,),
                                 wire_bf16=wire_bf16, tie_break_ids=True,
                                 axis_sizes=(n_shards,))

    graph_specs = (P(SHARD_AXIS, None, None), P(SHARD_AXIS, None, None),
                   P(SHARD_AXIS, None, None, None), P(SHARD_AXIS, None),
                   P(SHARD_AXIS), P(SHARD_AXIS, None))
    out_specs = (P(None, None), P(None, None))
    if has_scales:
        fn = shard_map(
            lambda vectors, neighbors0, upper, levels, entry, deleted,
            scl, q: local(vectors, neighbors0, upper, levels, entry,
                          deleted, q, scl),
            mesh=mesh,
            in_specs=graph_specs + (P(SHARD_AXIS, None), P(None, None)),
            out_specs=out_specs,
            check_rep=False)     # post-merge values ARE replicated
        return jax.jit(fn)
    fn = shard_map(local, mesh=mesh,
                   in_specs=graph_specs + (P(None, None),),
                   out_specs=out_specs, check_rep=False)
    return jax.jit(fn)


def search_stacked(st: StackedGraphs, queries, k: int, ef: int,
                   wire_bf16: bool | None = None,
                   beam_impl: str = "fused"
                   ) -> tuple[np.ndarray, np.ndarray]:
    """Batched k-NN over a stacked segment set: queries [B, D] ->
    (dists [B, k], gids [B, k]), missing slots (INF, -1). One compiled
    dispatch regardless of shard count; the only per-query host->device
    movement is the query batch itself. ``beam_impl`` selects each
    shard's layer-0 beam (fused one-launch kernel vs jnp reference) —
    the same kernel rides under shard_map, so the fan-out stays a
    single dispatch either way."""
    global DISPATCH_COUNT
    q = jnp.asarray(queries, jnp.float32)
    if st.metric == "cosine":
        q = q / jnp.maximum(jnp.linalg.norm(q, axis=-1, keepdims=True),
                            1e-12)
    fn = _stacked_search_fn(st.mesh, k, max(ef, k), st.metric,
                            st.max_level, st.scales is not None,
                            resolve_wire_bf16(wire_bf16), beam_impl)
    DISPATCH_COUNT += 1
    dispatch.bump("stacked.search_stacked")
    dispatch.bump("stacked.beam_launches",
                  dispatch.beam_launches(beam_impl, max(ef, k)))
    if st.scales is not None:
        d, gid = fn(st.vectors, st.neighbors0, st.upper, st.levels,
                    st.entry, st.deleted, st.scales, q)
    else:
        d, gid = fn(st.vectors, st.neighbors0, st.upper, st.levels,
                    st.entry, st.deleted, q)
    return np.asarray(d), np.asarray(gid)
