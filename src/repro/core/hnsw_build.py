"""HNSW construction.

Two builders, both emitting the same dense-tensor ``HNSWGraph``:

* ``SequentialBuilder`` — faithful Malkov & Yashunin (Alg. 1-4, incl. the
  neighbor-selection heuristic) in numpy. This is the recall REFERENCE and
  the apples-to-apples counterpart of the paper's in-browser construction
  (§5: 1M x 384-d, M=5, efConstruction=20 ≈ 94 min in Chrome).

* ``bulk_build`` — the TPU adaptation of the paper's batched-write insight
  (§3.2/C3): assign all levels up front, bootstrap a sequential prefix, then
  insert the remainder in large batches whose candidate searches run as ONE
  lock-step batched JAX beam search per batch. Orders of magnitude faster;
  recall parity is validated in tests/benchmarks.
"""
from __future__ import annotations

import dataclasses
import heapq

import numpy as np


# ---------------------------------------------------------------------------
# Graph container (numpy; converted to jnp by repro.core.hnsw)
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class HNSWGraph:
    vectors: np.ndarray          # [N, D] (normalised if cosine)
    neighbors0: np.ndarray       # [N, 2M] int32, -1 padded (layer 0)
    upper: np.ndarray            # [L_max, N, M] int32, -1 padded (layers 1..)
    levels: np.ndarray           # [N] int32
    entry: int
    max_level: int
    metric: str = "cosine"
    n: int = 0                   # number of live rows (<= capacity)

    @property
    def M(self) -> int:
        return self.upper.shape[2] if self.upper.shape[0] else self.neighbors0.shape[1] // 2

    def memory_bytes(self) -> dict:
        return {
            "vectors (slow tier)": self.vectors.nbytes,
            "graph (fast tier)": self.neighbors0.nbytes + self.upper.nbytes
                                  + self.levels.nbytes,
        }


def normalize_rows(x: np.ndarray) -> np.ndarray:
    n = np.linalg.norm(x, axis=-1, keepdims=True)
    return x / np.maximum(n, 1e-12)


def _prep(vectors: np.ndarray, metric: str) -> np.ndarray:
    v = np.ascontiguousarray(vectors, dtype=np.float32)
    if metric == "cosine":
        v = normalize_rows(v)
    return v


def _dist(metric: str, q: np.ndarray, x: np.ndarray) -> np.ndarray:
    """q [D], x [K, D] -> [K]. cosine assumes pre-normalised rows."""
    if metric in ("cosine", "ip"):
        return 1.0 - x @ q
    d = x - q[None, :]
    return np.einsum("kd,kd->k", d, d)


# ---------------------------------------------------------------------------
# Faithful sequential builder (Malkov & Yashunin)
# ---------------------------------------------------------------------------
class SequentialBuilder:
    def __init__(self, dim: int, *, M: int = 16, ef_construction: int = 200,
                 metric: str = "cosine", capacity: int = 1024,
                 max_level_cap: int = 12, seed: int = 0):
        self.dim = dim
        self.M = M
        self.m_max0 = 2 * M
        self.efc = ef_construction
        self.metric = metric
        self.mL = 1.0 / np.log(M) if M > 1 else 1.0
        self.max_level_cap = max_level_cap
        self.rng = np.random.default_rng(seed)
        self.n = 0
        self.entry = -1
        self.max_level = -1
        cap = max(capacity, 8)
        self.vectors = np.zeros((cap, dim), np.float32)
        self.levels = np.zeros(cap, np.int32)
        self.neighbors0 = np.full((cap, self.m_max0), -1, np.int32)
        self.upper = np.full((max_level_cap, cap, M), -1, np.int32)
        # dirty-row journal: ids whose row data (vector / adjacency / level)
        # changed since the consumer last synced. Drives the incremental
        # device-graph upload (DESIGN.md §3); consumers clear it after sync.
        self.journal: set[int] = set()

    @classmethod
    def from_graph(cls, g: HNSWGraph, *, ef_construction: int = 200,
                   max_level_cap: int = 12, seed: int = 0
                   ) -> "SequentialBuilder":
        """Adopt an existing graph (e.g. from ``bulk_build``) as mutable
        builder state, so later inserts APPEND instead of replacing it."""
        n = g.n
        b = cls(g.vectors.shape[1], M=g.M, ef_construction=ef_construction,
                metric=g.metric, capacity=max(n, 8),
                max_level_cap=max_level_cap, seed=seed)
        b.vectors[:n] = g.vectors[:n]
        b.levels[:n] = g.levels[:n]
        b.neighbors0[:n] = g.neighbors0[:n]
        b.upper[: g.upper.shape[0], :n] = g.upper[:, :n]
        b.n = n
        b.entry = int(g.entry)
        b.max_level = int(g.max_level)
        return b

    # -- storage helpers ----------------------------------------------------
    def _grow(self, need: int):
        cap = self.vectors.shape[0]
        if need <= cap:
            return
        new = max(need, cap * 2)
        self.vectors = np.concatenate(
            [self.vectors, np.zeros((new - cap, self.dim), np.float32)])
        self.levels = np.concatenate([self.levels, np.zeros(new - cap, np.int32)])
        self.neighbors0 = np.concatenate(
            [self.neighbors0, np.full((new - cap, self.m_max0), -1, np.int32)])
        self.upper = np.concatenate(
            [self.upper, np.full((self.max_level_cap, new - cap, self.M), -1,
                                 np.int32)], axis=1)

    def _nbrs(self, node: int, layer: int) -> np.ndarray:
        row = self.neighbors0[node] if layer == 0 else self.upper[layer - 1, node]
        return row[row >= 0]

    def _set_nbrs(self, node: int, layer: int, ids: np.ndarray):
        cap = self.m_max0 if layer == 0 else self.M
        row = np.full(cap, -1, np.int32)
        row[: len(ids)] = ids[:cap]
        if layer == 0:
            self.neighbors0[node] = row
        else:
            self.upper[layer - 1, node] = row
        self.journal.add(int(node))

    # -- Alg. 2: greedy ef-search on one layer -------------------------------
    def _search_layer(self, q: np.ndarray, eps: list[int], ef: int,
                      layer: int) -> list[tuple[float, int]]:
        visited = set(eps)
        d0 = _dist(self.metric, q, self.vectors[eps])
        cand = [(d, e) for d, e in zip(d0, eps)]          # min-heap
        heapq.heapify(cand)
        res = [(-d, e) for d, e in zip(d0, eps)]          # max-heap (neg)
        heapq.heapify(res)
        while cand:
            d_c, c = heapq.heappop(cand)
            if d_c > -res[0][0] and len(res) >= ef:
                break
            nbrs = [x for x in self._nbrs(c, layer) if x not in visited]
            if not len(nbrs):
                continue
            visited.update(int(x) for x in nbrs)
            dists = _dist(self.metric, q, self.vectors[nbrs])
            for d, e in zip(dists, nbrs):
                if len(res) < ef or d < -res[0][0]:
                    heapq.heappush(cand, (d, int(e)))
                    heapq.heappush(res, (-d, int(e)))
                    if len(res) > ef:
                        heapq.heappop(res)
        out = sorted([(-nd, e) for nd, e in res])
        return out[:ef]

    # -- Alg. 4: neighbor-selection heuristic --------------------------------
    def _select_heuristic(self, q: np.ndarray, cand: list[tuple[float, int]],
                          m: int) -> np.ndarray:
        cand = sorted(cand)
        selected: list[tuple[float, int]] = []
        for d_q, e in cand:
            if len(selected) >= m:
                break
            ev = self.vectors[e]
            ok = True
            for _, s in selected:
                if _dist(self.metric, ev, self.vectors[s][None])[0] < d_q:
                    ok = False
                    break
            if ok:
                selected.append((d_q, e))
        # backfill with pruned candidates (keepPrunedConnections=True)
        if len(selected) < m:
            chosen = {e for _, e in selected}
            for d_q, e in cand:
                if len(selected) >= m:
                    break
                if e not in chosen:
                    selected.append((d_q, e))
        return np.array([e for _, e in selected], np.int32)

    # -- Alg. 1: insert -------------------------------------------------------
    def insert(self, vec: np.ndarray, level: int | None = None,
               prenormalized: bool = False) -> int:
        # prenormalized: the caller already put ``vec`` in its final
        # stored form (metric normalization + codec quantization,
        # DESIGN.md §9) — re-normalizing here would perturb the bytes the
        # snapshot layer treats as canonical.
        self._grow(self.n + 1)
        q = np.asarray(vec, np.float32)
        if self.metric == "cosine" and not prenormalized:
            q = q / max(float(np.linalg.norm(q)), 1e-12)
        node = self.n
        self.vectors[node] = q
        if level is None:
            level = int(-np.log(self.rng.uniform(1e-12, 1.0)) * self.mL)
        lvl = min(level, self.max_level_cap)
        self.levels[node] = lvl
        self.n += 1
        self.journal.add(node)

        if self.entry < 0:
            self.entry, self.max_level = node, lvl
            return node

        ep = [self.entry]
        for lc in range(self.max_level, lvl, -1):
            ep = [self._search_layer(q, ep, 1, lc)[0][1]]
        for lc in range(min(lvl, self.max_level), -1, -1):
            w = self._search_layer(q, ep, self.efc, lc)
            m = self.m_max0 if lc == 0 else self.M
            nbrs = self._select_heuristic(q, w, self.M)
            self._set_nbrs(node, lc, nbrs)
            for e in nbrs:
                cur = self._nbrs(int(e), lc)
                if node not in cur:
                    cur = np.append(cur, node).astype(np.int32)
                if len(cur) > m:       # shrink with the same heuristic
                    ev = self.vectors[int(e)]
                    cand = list(zip(_dist(self.metric, ev, self.vectors[cur]),
                                    [int(c) for c in cur]))
                    cur = self._select_heuristic(ev, cand, m)
                self._set_nbrs(int(e), lc, cur)
            ep = [e for _, e in w]
        if lvl > self.max_level:
            self.entry, self.max_level = node, lvl
        return node

    def add_batch(self, vecs: np.ndarray):
        for v in vecs:
            self.insert(v)

    def graph(self) -> HNSWGraph:
        n = self.n
        lmax = max(int(self.levels[:n].max(initial=0)), 0)
        return HNSWGraph(
            vectors=self.vectors[:n],
            neighbors0=self.neighbors0[:n],
            upper=self.upper[:lmax, :n].copy(),
            levels=self.levels[:n],
            entry=self.entry,
            max_level=self.max_level,
            metric=self.metric,
            n=n,
        )

    def graph_full_capacity(self, lmax: int) -> HNSWGraph:
        """Fixed-shape view over the whole capacity (not-yet-inserted rows are
        unreachable); keeps batched-search shapes constant across bulk
        batches so the search jit-compiles exactly once."""
        return HNSWGraph(
            vectors=self.vectors,
            neighbors0=self.neighbors0,
            upper=self.upper[:lmax],
            levels=self.levels,
            entry=self.entry,
            max_level=self.max_level,
            metric=self.metric,
            n=self.n,
        )


def build_sequential(vectors: np.ndarray, *, M: int = 16,
                     ef_construction: int = 200, metric: str = "cosine",
                     seed: int = 0) -> HNSWGraph:
    v = _prep(vectors, metric)
    b = SequentialBuilder(v.shape[1], M=M, ef_construction=ef_construction,
                          metric=metric, capacity=len(v), seed=seed)
    b.add_batch(v)
    return b.graph()


# ---------------------------------------------------------------------------
# Bulk builder (TPU adaptation of C3): batched lock-step inserts
# ---------------------------------------------------------------------------
def _pow2_ceil(x: int) -> int:
    return 1 << max(int(x) - 1, 0).bit_length()


def select_heuristic_host(metric: str, vectors: np.ndarray, q: np.ndarray,
                          cand: list[tuple[float, int]], m: int) -> np.ndarray:
    """Module-level host oracle for the batched select op (Malkov Alg. 4
    with keepPrunedConnections backfill) — the loop the vectorized
    ``kernels.ops.select_neighbors`` is parity-pinned against
    (tests/test_build.py). Identical to
    ``SequentialBuilder._select_heuristic`` plus keep-first dedup of
    candidate ids, which the batched reciprocal connect needs: a batch
    member can select a destination whose forward list already contains
    it, so the merged candidate row may repeat an id."""
    seen: set[int] = set()
    uniq = []
    for d_q, e in cand:
        if e not in seen:
            seen.add(e)
            uniq.append((float(d_q), int(e)))
    uniq.sort()                       # (d, id): ties break on id, as the op
    selected: list[tuple[float, int]] = []
    for d_q, e in uniq:
        if len(selected) >= m:
            break
        ev = vectors[e]
        ok = True
        for _, s in selected:
            if _dist(metric, ev, vectors[s][None])[0] < d_q:
                ok = False
                break
        if ok:
            selected.append((d_q, e))
    if len(selected) < m:             # keepPrunedConnections backfill
        chosen = {e for _, e in selected}
        for d_q, e in uniq:
            if len(selected) >= m:
                break
            if e not in chosen:
                selected.append((d_q, e))
    return np.array([e for _, e in selected], np.int32)


def _select_batched(dev_vectors, q: np.ndarray, cand: np.ndarray,
                    *, m: int, metric: str) -> np.ndarray:
    """Chunked driver for ``ops.select_neighbors``: q [R, D] f32, cand
    [R, C] i32 -1-pad -> ids [R, m] i32 -1-pad.

    Rows pad to a pow2 chunk and C pads to a pow2 width so the jitted op
    compiles once per (chunk, C, m) bucket; the chunk bounds the op's
    [chunk, C, C] pairwise block to ~256 MB however wide the candidate
    lists get."""
    from repro.kernels import ops

    r, c = cand.shape
    if r == 0:
        return np.zeros((0, m), np.int32)
    # candidate width stays exact (the [*, C, C] pairwise block is the
    # op's dominant cost — pow2-padding C would pay up to 4x for air);
    # the caller keeps C bounded to a small set of values per build
    cw = max(c, 1)
    # row bucket: pow2, memory-bounded, floored at 256 so the op compiles
    # once per (C, m) bucket instead of once per small-group row count
    chunk = min(max(1 << 26 >> (2 * (cw.bit_length() - 1)), 16), 4096)
    chunk = min(chunk, max(256, _pow2_ceil(r)))
    out = np.empty((r, m), np.int32)
    for s in range(0, r, chunk):
        e = min(s + chunk, r)
        qs, cs = q[s:e], cand[s:e]
        if e - s < chunk:
            qs = np.concatenate(
                [qs, np.zeros((chunk - (e - s), q.shape[1]), np.float32)])
            cs = np.concatenate(
                [cs, np.full((chunk - (e - s), c), -1, np.int32)])
        ids, _ = ops.select_neighbors(dev_vectors, qs, cs, m=m, metric=metric)
        out[s:e] = np.asarray(ids)[: e - s]
    return out


def _connect_reciprocal(b: SequentialBuilder, e_src: np.ndarray,
                        e_dst: np.ndarray, e_lay: np.ndarray,
                        dev_vectors=None, impl: str = "op") -> list[int]:
    """Batched reciprocal connect (DESIGN.md §13): apply one batch's
    back-edges (src -> dst at layer) by DESTINATION — group the edge list
    with a host sort-segment pass, then re-select each touched row once
    from (current adjacency ∪ new sources) with the same Alg. 4
    heuristic, vectorized over all destinations of a layer.

    Replaces the sequential per-edge append+shrink round-trips: one
    combined select per (dst, layer) per batch, sources merged in
    ascending id (= canonical seq) order, so the result is deterministic
    regardless of how the edge list was produced. ``impl`` selects the
    vectorized op ("op") or the retained host-loop oracle ("host") —
    tests pin them bit-for-bit. Returns the touched row ids (the
    adjacency-dirty set the device sync must scatter)."""
    dirty: list[int] = []
    for lc in np.unique(e_lay):
        sel_m = e_lay == lc
        ordi = np.lexsort((e_src[sel_m], e_dst[sel_m]))
        dst = e_dst[sel_m][ordi]
        src = e_src[sel_m][ordi]
        udst, starts, cnts = np.unique(dst, return_index=True,
                                       return_counts=True)
        gcount = len(udst)
        gmax = int(cnts.max())
        cap = b.m_max0 if lc == 0 else b.M
        adj = (b.neighbors0[udst] if lc == 0
               else b.upper[lc - 1, udst])                  # [G, cap]
        srcs = np.full((gcount, _pow2_ceil(gmax)), -1, np.int32)
        srcs[np.repeat(np.arange(gcount), cnts),
             np.arange(len(src)) - np.repeat(starts, cnts)] = src
        cand = np.concatenate([adj, srcs], axis=1)
        if impl == "op":
            sel = _select_batched(dev_vectors, b.vectors[udst], cand,
                                  m=cap, metric=b.metric)
        else:                                     # host-loop oracle
            sel = np.full((gcount, cap), -1, np.int32)
            for gi, e in enumerate(udst):
                ids = cand[gi][cand[gi] >= 0]
                ev = b.vectors[int(e)]
                cd = list(zip(_dist(b.metric, ev, b.vectors[ids]),
                              [int(c) for c in ids]))
                keep = select_heuristic_host(b.metric, b.vectors, ev, cd, cap)
                sel[gi, : len(keep)] = keep
        if lc == 0:
            b.neighbors0[udst] = sel
        else:
            b.upper[lc - 1, udst] = sel
        dirty.extend(int(x) for x in udst)
    return dirty


def bulk_build(vectors: np.ndarray, *, M: int = 16, ef_construction: int = 200,
               metric: str = "cosine", seed: int = 0,
               bootstrap: int = 256, batch_size: int = 1024,
               prenormalized: bool = False, max_level_cap: int = 12,
               beam_impl: str = "fused",
               connect_impl: str = "op") -> HNSWGraph:
    """Device-resident bulk ingest (DESIGN.md §13).

    Assign levels up front; bootstrap a sequential prefix; then insert
    the remainder in batches against ONE capacity-padded resident
    ``DeviceGraph``. Per batch:

      1. one fused beam launch (``beam_impl``) finds every member's
         ``min(ef_construction, prefix)`` candidates over the prefix —
         the graph is already resident, so nothing re-uploads;
      2. a host self-distance block adds each member's intra-batch
         top-K so batch members can become each other's neighbors;
      3. forward edges: every (member, layer) row goes through the
         batched Alg. 4 select op (``kernels.ops.select_neighbors``);
      4. back edges: :func:`_connect_reciprocal` re-selects each touched
         destination row once, vectorized per layer;
      5. only the adjacency of batch ∪ touched rows scatters back
         (``apply_adjacency_updates``) — per-batch H2D is
         O(dirty·M) int32, not the O(capacity·D) full re-upload the
         legacy path (:func:`bulk_build_legacy`) pays.

    ``prenormalized``: rows are already in their final stored form (codec
    quantization happens after normalization, DESIGN.md §9) — skip the
    metric prep. Deterministic for fixed inputs (WAL-replay contract):
    no data-dependent host iteration order survives the sort-segment
    grouping."""
    from repro.core import hnsw as jhnsw   # lazy: keeps numpy path import-light

    if connect_impl not in ("op", "host"):
        raise ValueError(f"unknown connect_impl {connect_impl!r}")
    v = (np.ascontiguousarray(vectors, dtype=np.float32) if prenormalized
         else _prep(vectors, metric))
    n, d = v.shape
    rng = np.random.default_rng(seed)
    mL = 1.0 / np.log(M) if M > 1 else 1.0
    levels = np.minimum(
        (-np.log(rng.uniform(1e-12, 1.0, n)) * mL).astype(np.int32),
        max_level_cap)
    # bootstrap prefix: highest-level points first so the hierarchy exists
    # (and the entry point / max_level never move after the bootstrap)
    order = np.argsort(-levels, kind="stable")
    v_ord = v[order]
    lv_ord = levels[order]

    nb = max(min(bootstrap, n), 1)     # >= 1: the beam needs an entry point
    b = SequentialBuilder(d, M=M, ef_construction=ef_construction,
                          metric=metric, capacity=n,
                          max_level_cap=max_level_cap, seed=seed)
    for i in range(nb):
        b.insert(v_ord[i], level=int(lv_ord[i]), prenormalized=prenormalized)
    if b.n >= n:
        return _permute_graph(b.graph(), order)

    m_max0 = 2 * M
    lmax_cap = max(int(lv_ord.max(initial=0)), 1)
    ef_b = max(ef_construction, M + 1)

    # resident graph: ALL vectors/levels go up in the one full upload —
    # rows beyond the live prefix have no edges, so the beam cannot reach
    # them, but their payloads are gatherable by id, which is exactly
    # what the intra-batch select needs. After this, vectors never move
    # host->device again; batches ship int32 adjacency only.
    b._grow(n)
    b.vectors[nb:n] = v_ord[nb:n]
    b.levels[nb:n] = lv_ord[nb:n]
    host_g = b.graph_full_capacity(lmax_cap)
    dg = jhnsw.to_device_graph(host_g)

    while b.n < n:
        lo = b.n
        hi = min(lo + batch_size, n)
        bsz = hi - lo
        batch = v_ord[lo:hi]
        # live-prefix candidate cap (the bootstrap-sized cap was a bug:
        # bootstrap=64, efC=200 built every batch from 64 candidates)
        k_cand = min(ef_construction, lo)
        # 1. one beam launch over exactly bsz queries (the zero-padded
        # tail rows of the old fixed-shape batch are not searched)
        cand_ids, _ = jhnsw.search_graph(dg, batch, k=k_cand, ef=ef_b,
                                         beam_impl=beam_impl)
        cand_ids = np.asarray(cand_ids, np.int32)
        # 2. intra-batch top-K via one host self-distance block
        kb = min(bsz - 1, k_cand)
        if kb > 0:
            if metric in ("cosine", "ip"):
                blk = 1.0 - batch @ batch.T
            else:
                sq = np.einsum("bd,bd->b", batch, batch)
                blk = sq[:, None] - 2.0 * (batch @ batch.T) + sq[None, :]
            np.fill_diagonal(blk, np.inf)
            # argpartition + sort-the-slice: O(B² + B·kb·log kb), not a
            # full O(B² log B) row sort for kb « B
            part = np.argpartition(blk, kb - 1, axis=1)[:, :kb]
            ordl = np.argsort(np.take_along_axis(blk, part, axis=1),
                              axis=1, kind="stable")
            top = np.take_along_axis(part, ordl, axis=1)
            cand_ids = np.concatenate(
                [cand_ids, (lo + top).astype(np.int32)], axis=1)
        # 3. forward edges: one (member, layer) row per live layer,
        # level-masked candidates, batched select at m=M
        lvls = lv_ord[lo:hi].astype(np.int64)
        counts = lvls + 1
        pj = np.repeat(np.arange(bsz), counts)
        plc = (np.arange(counts.sum())
               - np.repeat(np.cumsum(counts) - counts, counts))
        crows = cand_ids[pj]                                  # [R, C]
        clev = np.where(crows >= 0, b.levels[np.clip(crows, 0, n - 1)], -1)
        crows = np.where(clev >= plc[:, None], crows, -1)
        sel = _select_batched(dg.vectors, batch[pj], crows, m=M,
                              metric=metric)                  # [R, M]
        nodes = (lo + pj).astype(np.int32)
        for lc in np.unique(plc):
            rm = plc == lc
            if lc == 0:
                b.neighbors0[nodes[rm], :M] = sel[rm]   # fresh rows: -1 tail
            else:
                b.upper[lc - 1, nodes[rm]] = sel[rm]
        # 4. reciprocal connect, grouped by destination
        vm = sel.ravel() >= 0
        dirty = _connect_reciprocal(
            b, np.repeat(nodes, M)[vm], sel.ravel()[vm],
            np.repeat(plc, M)[vm].astype(np.int32),
            dev_vectors=dg.vectors, impl=connect_impl)
        b.n = hi
        # 5. adjacency-only scatter of the dirty rows
        dg = jhnsw.apply_adjacency_updates(
            dg, host_g, set(range(lo, hi)) | set(dirty))

    return _permute_graph(b.graph(), order)


def bulk_build_legacy(vectors: np.ndarray, *, M: int = 16,
                      ef_construction: int = 200,
                      metric: str = "cosine", seed: int = 0,
                      bootstrap: int = 256, batch_size: int = 1024,
                      prenormalized: bool = False) -> HNSWGraph:
    """The pre-§13 bulk builder, retained verbatim as the benchmark
    baseline (`bench_build`'s `h2d_vs_legacy` honesty column): it
    re-uploads the full capacity graph EVERY batch (O(N²/batch) H2D)
    and connects every edge in per-node per-layer host loops. Also keeps
    the bootstrap-capped ``k_cand`` bug the resident path fixes —
    this is the measured pre-PR behavior, not a reference semantics."""
    from repro.core import hnsw as jhnsw   # lazy: keeps numpy path import-light

    v = (np.ascontiguousarray(vectors, dtype=np.float32) if prenormalized
         else _prep(vectors, metric))
    n, d = v.shape
    rng = np.random.default_rng(seed)
    mL = 1.0 / np.log(M) if M > 1 else 1.0
    levels = np.minimum((-np.log(rng.uniform(1e-12, 1.0, n)) * mL).astype(np.int32),
                        12)
    # bootstrap prefix: highest-level points first so the hierarchy exists
    order = np.argsort(-levels, kind="stable")
    v_ord = v[order]
    lv_ord = levels[order]

    nb = min(bootstrap, n)
    b = SequentialBuilder(d, M=M, ef_construction=ef_construction,
                          metric=metric, capacity=n, seed=seed)
    for i in range(nb):
        b.insert(v_ord[i], level=int(lv_ord[i]), prenormalized=prenormalized)

    m_max0 = 2 * M
    lmax_cap = max(int(lv_ord.max(initial=0)), 1)
    k_cand = min(ef_construction, nb)
    ef_b = max(ef_construction, M + 1)
    while b.n < n:
        lo = b.n
        hi = min(lo + batch_size, n)
        batch = v_ord[lo:hi]
        if hi - lo < batch_size:            # pad the tail batch (fixed shapes)
            batch = np.concatenate(
                [batch, np.zeros((batch_size - (hi - lo), d), np.float32)])
        b._grow(n)
        g = b.graph_full_capacity(lmax_cap)
        # one batched beam search over the prefix for all batch members
        cand_ids, cand_dist = jhnsw.search_graph(
            jhnsw.to_device_graph(g), batch, k=k_cand, ef=ef_b)
        cand_ids = np.asarray(cand_ids)
        cand_dist = np.asarray(cand_dist)
        for j in range(hi - lo):
            node = b.n
            lvl = int(lv_ord[node])
            b.vectors[node] = batch[j]
            b.levels[node] = lvl
            b.n += 1
            ids = cand_ids[j][cand_ids[j] >= 0]
            dist = cand_dist[j][: len(ids)]
            for lc in range(min(lvl, b.max_level), -1, -1):
                mask = b.levels[ids] >= lc
                ids_l, dist_l = ids[mask], dist[mask]
                if not len(ids_l):
                    continue
                nbrs = b._select_heuristic(batch[j],
                                           list(zip(dist_l, ids_l.tolist())), M)
                b._set_nbrs(node, lc, nbrs)
                mcap = m_max0 if lc == 0 else M
                for e in nbrs:
                    cur = b._nbrs(int(e), lc)
                    if node not in cur:
                        cur = np.append(cur, node).astype(np.int32)
                    if len(cur) > mcap:
                        ev = b.vectors[int(e)]
                        cd = list(zip(_dist(metric, ev, b.vectors[cur]),
                                      [int(c) for c in cur]))
                        cur = b._select_heuristic(ev, cd, mcap)
                    b._set_nbrs(int(e), lc, cur)
            if lvl > b.max_level:
                b.entry, b.max_level = node, lvl

    return _permute_graph(b.graph(), order)


def _permute_graph(g: HNSWGraph, order: np.ndarray) -> HNSWGraph:
    """Graph built over permuted rows -> graph in original row order."""
    n = g.n
    new_of_old = np.asarray(order[:n], np.int64)   # builder id -> original id

    def remap_ids(a):
        out = np.full_like(a, -1)
        valid = a >= 0
        out[valid] = new_of_old[a[valid]]
        return out

    return HNSWGraph(
        vectors=_scatter_rows(g.vectors, new_of_old),
        neighbors0=_scatter_rows(remap_ids(g.neighbors0), new_of_old),
        upper=np.stack([_scatter_rows(remap_ids(u), new_of_old) for u in g.upper])
              if g.upper.shape[0] else g.upper,
        levels=_scatter_rows(g.levels, new_of_old),
        entry=int(new_of_old[g.entry]) if g.entry >= 0 else -1,
        max_level=g.max_level,
        metric=g.metric,
        n=n,
    )


def _scatter_rows(a: np.ndarray, new_of_old: np.ndarray) -> np.ndarray:
    out = np.empty_like(a)
    out[new_of_old] = a
    return out
