"""MeMemo-parity public API (paper §2.1, Code 1) — now a full
``VectorIndex`` backend with real mutation semantics (DESIGN.md §1/§3).

TypeScript original:
    const index = new HNSW({ distanceFunction: 'cosine' });
    await index.bulkInsert(keys, values);
    const { keys, distances } = await index.query(query, k);
    index.exportIndex() / loadIndex()

Python equivalent (camelCase aliases kept for 1:1 parity):
    index = HNSW(distance_function="cosine", M=5, ef_construction=20)
    index.bulk_insert(keys, values)
    index.update("doc-3", new_vec)       # delete + reinsert, same key
    index.delete("doc-7")                # tombstone: excluded from results
    keys, distances = index.query(query, k=10)
    index.export_index(path); HNSW.load_index(path)

Mutation model: the ``SequentialBuilder`` is the canonical mutable host
graph. Deletes are soft (a tombstone mask threaded through the device-side
beam search — deleted ids stay traversable, hnswlib-style); updates are
delete + reinsert under the same key. After the first query materialises a
resident ``DeviceGraph`` (capacity-padded, fixed shapes), later mutations
upload only the builder's dirty-row journal via ``apply_row_updates``
instead of re-converting the whole graph (DESIGN.md §3).
"""
from __future__ import annotations

import json
import os
from typing import Sequence

import numpy as np

from repro.core import hnsw as jhnsw
from repro.core import hnsw_build as build
from repro.core.flat import FlatIndex
from repro.core.index import VectorIndex


class HNSW(VectorIndex):
    def __init__(self, distance_function: str = "cosine", *, M: int = 16,
                 ef_construction: int = 200, ef_search: int = 64,
                 seed: int = 0, use_bulk_build: bool = False):
        if distance_function not in ("cosine", "ip", "l2"):
            raise ValueError(f"unknown distanceFunction {distance_function!r}")
        self.metric = distance_function
        self.M = M
        self.ef_construction = ef_construction
        self.ef_search = ef_search
        self.seed = seed
        self.use_bulk_build = use_bulk_build
        self._keys: list[str] = []                 # node id -> key
        self._key2id: dict[str, int] = {}          # live keys only
        self._deleted = np.zeros(0, bool)          # tombstones, capacity-sized
        self._builder: build.SequentialBuilder | None = None
        # compat only: external code reads `idx._graph or idx._builder.graph()`
        self._graph: build.HNSWGraph | None = None
        self._device_graph: jhnsw.DeviceGraph | None = None
        self._deleted_dirty = False

    # ------------------------------------------------------------ mutation
    def insert(self, key: str, value: Sequence[float]) -> None:
        """Upsert one (key, vector); existing keys are updated in place."""
        if key in self._key2id:
            self.delete(key)
        v = np.asarray(value, np.float32)
        if self._builder is None:
            self._builder = build.SequentialBuilder(
                v.shape[-1], M=self.M, ef_construction=self.ef_construction,
                metric=self.metric, seed=self.seed)
        node = self._builder.insert(v)
        assert node == len(self._keys)
        self._keys.append(key)
        self._key2id[key] = node
        self._bump_epoch()

    def bulk_insert(self, keys: Sequence[str], values) -> None:
        values = np.asarray(values, np.float32)
        assert len(keys) == len(values), "keys/values length mismatch"
        if self.use_bulk_build and self._builder is None:
            g = build.bulk_build(
                values, M=self.M, ef_construction=self.ef_construction,
                metric=self.metric, seed=self.seed)
            # adopt as mutable builder state so a LATER bulk_insert / insert
            # appends instead of silently replacing the graph
            self._builder = build.SequentialBuilder.from_graph(
                g, ef_construction=self.ef_construction, seed=self.seed)
            self._keys = list(keys)
            self._key2id = {k: i for i, k in enumerate(self._keys)}
            self._device_graph = None
            self._bump_epoch()
            return
        for k, v in zip(keys, values):
            self.insert(k, v)

    bulkInsert = bulk_insert   # TS-parity alias

    def update(self, key: str, value: Sequence[float]) -> None:
        """Replace the vector of an existing key (delete + reinsert)."""
        if key not in self._key2id:
            raise KeyError(key)
        self.insert(key, value)

    def delete(self, key: str) -> None:
        """Soft-delete: tombstone the row; it stays traversable but is
        never returned from query/exact_query again."""
        node = self._key2id.pop(key)               # KeyError if absent
        self._ensure_tombstones()
        self._deleted[node] = True
        self._deleted_dirty = True
        self._bump_epoch()

    def _ensure_tombstones(self):
        cap = self._builder.vectors.shape[0] if self._builder is not None else 0
        if self._deleted.shape[0] < cap:
            pad = np.zeros(cap - self._deleted.shape[0], bool)
            self._deleted = np.concatenate([self._deleted, pad])

    # ----------------------------------------------------- device residency
    def _dg(self) -> jhnsw.DeviceGraph:
        """Resident device graph, synced incrementally when possible."""
        if self._builder is None:
            raise ValueError("index is empty")
        b = self._builder
        self._ensure_tombstones()
        g = b.graph_full_capacity(b.max_level_cap)   # fixed [12, cap, M] upper
        dg = self._device_graph
        if dg is None or dg.vectors.shape != g.vectors.shape:
            # first upload, or capacity growth: full conversion
            self._device_graph = jhnsw.to_device_graph(g, self._deleted)
            b.journal.clear()
            self._deleted_dirty = False
        elif b.journal or self._deleted_dirty or dg.max_level != g.max_level:
            # incremental: only dirty rows travel to the device
            self._device_graph = jhnsw.apply_row_updates(
                dg, g, b.journal,
                self._deleted if self._deleted_dirty else None)
            b.journal.clear()
            self._deleted_dirty = False
        return self._device_graph

    # --------------------------------------------------------------- query
    def query_batch(self, queries, k: int = 10, ef: int | None = None):
        """One lock-step device search for the whole [B, D] batch.

        All B queries advance together through ``search_graph`` (DESIGN.md
        §2); the compiled program is cached per (B, k, ef) shape, which is
        why the serving layer coalesces into power-of-two B buckets.
        """
        q = np.asarray(queries, np.float32)
        if q.ndim != 2:
            raise ValueError(f"query_batch expects [B, D], got {q.shape}")
        ids, dists = jhnsw.search_graph(self._dg(), q, k=k,
                                        ef=ef or self.ef_search)
        ids, dists = np.asarray(ids), np.asarray(dists)
        keys = [[self._keys[i] if i >= 0 else None for i in row] for row in ids]
        return keys, dists

    def exact_query(self, query, k: int = 10):
        """Brute-force oracle over the same LIVE vectors -> (keys, dists)."""
        if self._builder is None:
            raise ValueError("index is empty")
        self._ensure_tombstones()
        n = self._builder.n
        live = np.flatnonzero(~self._deleted[:n])
        if live.size == 0:
            raise ValueError("index is empty")
        flat = FlatIndex(vectors=np.asarray(self._builder.vectors[live]),
                         metric=self.metric)
        q = np.asarray(query, np.float32)
        squeeze = q.ndim == 1
        if squeeze:
            q = q[None]
        d, i = flat.query(q, min(k, live.size))
        d, i = np.asarray(d), np.asarray(i)
        keys = [[self._keys[int(live[j])] for j in row] for row in i]
        if squeeze:
            return keys[0], d[0]
        return keys, d

    @property
    def size(self) -> int:
        return len(self._key2id)

    def keys(self) -> list[str]:
        n = self._builder.n if self._builder is not None else 0
        self._ensure_tombstones()
        return [self._keys[i] for i in range(n) if not self._deleted[i]]

    # ------------------------------------------------------- persistence
    def export(self, path: str) -> None:
        if self._builder is None:
            raise ValueError("index is empty")
        g = self._builder.graph()
        self._ensure_tombstones()
        meta = {
            "metric": self.metric, "M": self.M,
            "ef_construction": self.ef_construction,
            "ef_search": self.ef_search,
            "entry": int(g.entry), "max_level": int(g.max_level),
            "n": int(g.n), "keys": self._keys[: g.n],
        }
        tmp = path + ".tmp.npz"          # atomic: write sidecar, then rename
        np.savez_compressed(tmp[:-4],    # np.savez appends the .npz itself
                            vectors=g.vectors, neighbors0=g.neighbors0,
                            upper=g.upper, levels=g.levels,
                            deleted=self._deleted[: g.n],
                            meta=np.frombuffer(
                                json.dumps(meta).encode(), dtype=np.uint8))
        os.replace(tmp, path)

    export_index = export
    exportIndex = export

    @classmethod
    def load(cls, path: str) -> "HNSW":
        z = np.load(path, allow_pickle=False)
        meta = json.loads(bytes(z["meta"]).decode())
        idx = cls(distance_function=meta["metric"], M=meta["M"],
                  ef_construction=meta["ef_construction"],
                  ef_search=meta["ef_search"])
        g = build.HNSWGraph(
            vectors=z["vectors"], neighbors0=z["neighbors0"],
            upper=z["upper"], levels=z["levels"], entry=meta["entry"],
            max_level=meta["max_level"], metric=meta["metric"], n=meta["n"])
        idx._builder = build.SequentialBuilder.from_graph(
            g, ef_construction=meta["ef_construction"])
        idx._keys = list(meta["keys"])
        deleted = (np.asarray(z["deleted"], bool) if "deleted" in z.files
                   else np.zeros(meta["n"], bool))
        idx._ensure_tombstones()
        idx._deleted[: meta["n"]] = deleted
        idx._key2id = {k: i for i, k in enumerate(idx._keys)
                       if not idx._deleted[i]}
        return idx

    load_index = load
    loadIndex = load
