"""MeMemo-parity public API (paper §2.1, Code 1) — now a full
``VectorIndex`` backend with real mutation semantics (DESIGN.md §1/§3).

TypeScript original:
    const index = new HNSW({ distanceFunction: 'cosine' });
    await index.bulkInsert(keys, values);
    const { keys, distances } = await index.query(query, k);
    index.exportIndex() / loadIndex()

Python equivalent (camelCase aliases kept for 1:1 parity):
    index = HNSW(distance_function="cosine", M=5, ef_construction=20)
    index.bulk_insert(keys, values)
    index.update("doc-3", new_vec)       # delete + reinsert, same key
    index.delete("doc-7")                # tombstone: excluded from results
    keys, distances = index.query(query, k=10)
    index.export_index(path); HNSW.load_index(path)

Mutation model: the ``SequentialBuilder`` is the canonical mutable host
graph. Deletes are soft (a tombstone mask threaded through the device-side
beam search — deleted ids stay traversable, hnswlib-style); updates are
delete + reinsert under the same key. After the first query materialises a
resident ``DeviceGraph`` (capacity-padded, fixed shapes), later mutations
upload only the builder's dirty-row journal via ``apply_row_updates``
instead of re-converting the whole graph (DESIGN.md §3).
"""
from __future__ import annotations

import numpy as np

from repro.core import hnsw as jhnsw
from repro.core import hnsw_build as build
from repro.core.flat import FlatIndex
from repro.core.index import VectorIndex


class HNSW(VectorIndex):
    kind = "hnsw"

    def __init__(self, distance_function: str = "cosine", *, M: int = 16,
                 ef_construction: int = 200, ef_search: int = 64,
                 seed: int = 0, use_bulk_build: bool = False):
        if distance_function not in ("cosine", "ip", "l2"):
            raise ValueError(f"unknown distanceFunction {distance_function!r}")
        self.metric = distance_function
        self.M = M
        self.ef_construction = ef_construction
        self.ef_search = ef_search
        self.seed = seed
        self.use_bulk_build = use_bulk_build
        self._keys: list[str] = []                 # node id -> key
        self._key2id: dict[str, int] = {}          # live keys only
        self._deleted = np.zeros(0, bool)          # tombstones, capacity-sized
        self._builder: build.SequentialBuilder | None = None
        # compat only: external code reads `idx._graph or idx._builder.graph()`
        self._graph: build.HNSWGraph | None = None
        self._device_graph: jhnsw.DeviceGraph | None = None
        self._deleted_dirty = False

    # ------------------------------------------------------------ mutation
    def _insert_impl(self, key: str, value: np.ndarray) -> None:
        """Upsert one (key, vector); existing keys are updated in place."""
        if key in self._key2id:
            self._delete_impl(key)
        v = np.asarray(value, np.float32)
        if self._builder is None:
            self._builder = build.SequentialBuilder(
                v.shape[-1], M=self.M, ef_construction=self.ef_construction,
                metric=self.metric, seed=self.seed)
        node = self._builder.insert(v)
        assert node == len(self._keys)
        self._keys.append(key)
        self._key2id[key] = node
        self._bump_epoch()

    def _bulk_insert_impl(self, keys: list[str], values: np.ndarray) -> None:
        if self.use_bulk_build and self._builder is None:
            g = build.bulk_build(
                values, M=self.M, ef_construction=self.ef_construction,
                metric=self.metric, seed=self.seed)
            # adopt as mutable builder state so a LATER bulk_insert / insert
            # appends instead of silently replacing the graph
            self._builder = build.SequentialBuilder.from_graph(
                g, ef_construction=self.ef_construction, seed=self.seed)
            self._keys = list(keys)
            self._key2id = {k: i for i, k in enumerate(self._keys)}
            self._device_graph = None
            self._bump_epoch()
            return
        for k, v in zip(keys, values):
            self._insert_impl(k, v)

    bulkInsert = VectorIndex.bulk_insert   # TS-parity alias

    def _update_impl(self, key: str, value: np.ndarray) -> None:
        """Replace the vector of an existing key (delete + reinsert)."""
        self._insert_impl(key, value)

    def _delete_impl(self, key: str) -> None:
        """Soft-delete: tombstone the row; it stays traversable but is
        never returned from query/exact_query again."""
        node = self._key2id.pop(key)               # KeyError if absent
        self._ensure_tombstones()
        self._deleted[node] = True
        self._deleted_dirty = True
        self._bump_epoch()

    def _compact_impl(self) -> None:
        """Physically drop tombstoned rows (DESIGN.md §7): rebuild the
        graph from scratch over live vectors only. Deleted rows stop
        existing host-side — this is the expensive half of secure delete
        (tombstoning stays the cheap everyday path); the store layer
        rewrites the on-disk pages afterwards."""
        if self._builder is None:
            self._bump_epoch()
            return
        self._ensure_tombstones()
        n = self._builder.n
        live = np.flatnonzero(~self._deleted[:n])
        vecs = self._builder.vectors[live].copy()
        keys = [self._keys[i] for i in live]
        self._builder = None                       # fresh graph + fresh RNG
        self._keys = []
        self._key2id = {}
        self._deleted = np.zeros(0, bool)
        self._device_graph = None
        self._deleted_dirty = False
        for k, v in zip(keys, vecs):
            self._insert_impl(k, v)                # bumps epoch per insert
        if not keys:
            self._bump_epoch()

    def _ensure_tombstones(self):
        cap = self._builder.vectors.shape[0] if self._builder is not None else 0
        if self._deleted.shape[0] < cap:
            pad = np.zeros(cap - self._deleted.shape[0], bool)
            self._deleted = np.concatenate([self._deleted, pad])

    # ----------------------------------------------------- device residency
    def _dg(self) -> jhnsw.DeviceGraph:
        """Resident device graph, synced incrementally when possible."""
        if self._builder is None:
            raise ValueError("index is empty")
        b = self._builder
        self._ensure_tombstones()
        g = b.graph_full_capacity(b.max_level_cap)   # fixed [12, cap, M] upper
        dg = self._device_graph
        if dg is None or dg.vectors.shape != g.vectors.shape:
            # first upload, or capacity growth: full conversion
            self._device_graph = jhnsw.to_device_graph(g, self._deleted)
            b.journal.clear()
            self._deleted_dirty = False
        elif b.journal or self._deleted_dirty or dg.max_level != g.max_level:
            # incremental: only dirty rows travel to the device
            self._device_graph = jhnsw.apply_row_updates(
                dg, g, b.journal,
                self._deleted if self._deleted_dirty else None)
            b.journal.clear()
            self._deleted_dirty = False
        return self._device_graph

    # --------------------------------------------------------------- query
    def query_batch(self, queries, k: int = 10, ef: int | None = None):
        """One lock-step device search for the whole [B, D] batch.

        All B queries advance together through ``search_graph`` (DESIGN.md
        §2); the compiled program is cached per (B, k, ef) shape, which is
        why the serving layer coalesces into power-of-two B buckets.
        """
        q = np.asarray(queries, np.float32)
        if q.ndim != 2:
            raise ValueError(f"query_batch expects [B, D], got {q.shape}")
        ids, dists = jhnsw.search_graph(self._dg(), q, k=k,
                                        ef=ef or self.ef_search)
        ids, dists = np.asarray(ids), np.asarray(dists)
        keys = [[self._keys[i] if i >= 0 else None for i in row] for row in ids]
        return keys, dists

    def exact_query(self, query, k: int = 10):
        """Brute-force oracle over the same LIVE vectors -> (keys, dists)."""
        if self._builder is None:
            raise ValueError("index is empty")
        self._ensure_tombstones()
        n = self._builder.n
        live = np.flatnonzero(~self._deleted[:n])
        if live.size == 0:
            raise ValueError("index is empty")
        flat = FlatIndex(vectors=np.asarray(self._builder.vectors[live]),
                         metric=self.metric)
        q = np.asarray(query, np.float32)
        squeeze = q.ndim == 1
        if squeeze:
            q = q[None]
        d, i = flat.query(q, min(k, live.size))
        d, i = np.asarray(d), np.asarray(i)
        keys = [[self._keys[int(live[j])] for j in row] for row in i]
        if squeeze:
            return keys[0], d[0]
        return keys, d

    @property
    def size(self) -> int:
        return len(self._key2id)

    def _contains(self, key: str) -> bool:
        return key in self._key2id

    def _row_count(self) -> int:
        return self._builder.n if self._builder is not None else 0

    def keys(self) -> list[str]:
        n = self._builder.n if self._builder is not None else 0
        self._ensure_tombstones()
        return [self._keys[i] for i in range(n) if not self._deleted[i]]

    # ------------------------------------------------------- persistence
    def config_dict(self) -> dict:
        return {"metric": self.metric, "M": self.M,
                "ef_construction": self.ef_construction,
                "ef_search": self.ef_search, "seed": self.seed,
                "use_bulk_build": self.use_bulk_build}

    def state_dict(self) -> tuple[dict, dict]:
        """Full mutation-determined host state, CAPACITY-padded: the
        builder's fixed-shape arrays go to disk as-is, so restore adopts
        them directly and the first query does one plain device upload —
        no graph rebuild (the expensive path the paper measures at 94 min
        for 1M rows). The builder RNG state rides along so WAL replay of
        later inserts draws the exact same levels (DESIGN.md §7).

        An index with no builder (nothing ever inserted, or compacted
        down to zero live rows) serializes as the empty state — a store
        must still be able to snapshot it: compacting away the LAST
        document is precisely the secure-delete case."""
        if self._builder is None:
            arrays = {"vectors": np.zeros((0, 0), np.float32),
                      "levels": np.zeros(0, np.int32),
                      "neighbors0": np.zeros((0, 2 * self.M), np.int32),
                      "upper": np.zeros((0, 0, self.M), np.int32),
                      "deleted": np.zeros(0, bool)}
            meta = {"keys": [], "epoch": self._epoch, "n": 0, "entry": -1,
                    "max_level": -1, "max_level_cap": 12, "rng_state": None}
            return arrays, meta
        b = self._builder
        self._ensure_tombstones()
        arrays = {"vectors": b.vectors, "levels": b.levels,
                  "neighbors0": b.neighbors0, "upper": b.upper,
                  "deleted": self._deleted}
        meta = {"keys": list(self._keys), "epoch": self._epoch,
                "n": int(b.n), "entry": int(b.entry),
                "max_level": int(b.max_level),
                "max_level_cap": int(b.max_level_cap),
                "rng_state": b.rng.bit_generator.state}
        return arrays, meta

    def restore_state(self, arrays: dict, meta: dict) -> None:
        if meta["n"] == 0:                # empty state: no builder yet
            self._builder = None
            self._keys = []
            self._key2id = {}
            self._deleted = np.zeros(0, bool)
            self._epoch = int(meta["epoch"])
            self._device_graph = None
            self._deleted_dirty = False
            return
        vectors = np.asarray(arrays["vectors"], np.float32)
        b = build.SequentialBuilder(
            vectors.shape[1], M=self.M,
            ef_construction=self.ef_construction, metric=self.metric,
            capacity=vectors.shape[0], max_level_cap=meta["max_level_cap"],
            seed=self.seed)
        b.vectors = vectors
        b.levels = np.asarray(arrays["levels"], np.int32)
        b.neighbors0 = np.asarray(arrays["neighbors0"], np.int32)
        b.upper = np.asarray(arrays["upper"], np.int32)
        b.n = int(meta["n"])
        b.entry = int(meta["entry"])
        b.max_level = int(meta["max_level"])
        b.rng.bit_generator.state = meta["rng_state"]
        self._builder = b
        self._keys = list(meta["keys"])
        self._deleted = np.asarray(arrays["deleted"], bool).copy()
        self._key2id = {k: i for i, k in enumerate(self._keys)
                        if not self._deleted[i]}
        self._epoch = int(meta["epoch"])
        self._device_graph = None
        self._deleted_dirty = False

    export_index = VectorIndex.export
    exportIndex = VectorIndex.export
    load_index = VectorIndex.load
    loadIndex = VectorIndex.load
