"""MeMemo-parity public API (paper §2.1, Code 1).

TypeScript original:
    const index = new HNSW({ distanceFunction: 'cosine' });
    await index.bulkInsert(keys, values);
    const { keys, distances } = await index.query(query, k);
    index.exportIndex() / loadIndex()

Python equivalent (camelCase aliases kept for 1:1 parity):
    index = HNSW(distance_function="cosine", M=5, ef_construction=20)
    index.bulk_insert(keys, values)
    keys, distances = index.query(query, k=10)
    index.export_index(path); HNSW.load_index(path)
"""
from __future__ import annotations

import json
import os
import tempfile
from typing import Sequence

import numpy as np

from repro.core import hnsw as jhnsw
from repro.core import hnsw_build as build
from repro.core.flat import FlatIndex


class HNSW:
    def __init__(self, distance_function: str = "cosine", *, M: int = 16,
                 ef_construction: int = 200, ef_search: int = 64,
                 seed: int = 0, use_bulk_build: bool = False):
        if distance_function not in ("cosine", "ip", "l2"):
            raise ValueError(f"unknown distanceFunction {distance_function!r}")
        self.metric = distance_function
        self.M = M
        self.ef_construction = ef_construction
        self.ef_search = ef_search
        self.seed = seed
        self.use_bulk_build = use_bulk_build
        self._keys: list[str] = []
        self._builder: build.SequentialBuilder | None = None
        self._graph: build.HNSWGraph | None = None
        self._device_graph: jhnsw.DeviceGraph | None = None

    # ------------------------------------------------------------------ api
    def insert(self, key: str, value: Sequence[float]) -> None:
        v = np.asarray(value, np.float32)
        if self._builder is None:
            self._builder = build.SequentialBuilder(
                v.shape[-1], M=self.M, ef_construction=self.ef_construction,
                metric=self.metric, seed=self.seed)
        self._builder.insert(v)
        self._keys.append(key)
        self._graph = self._device_graph = None

    def bulk_insert(self, keys: Sequence[str], values) -> None:
        values = np.asarray(values, np.float32)
        assert len(keys) == len(values), "keys/values length mismatch"
        if self.use_bulk_build and self._builder is None:
            self._graph = build.bulk_build(
                values, M=self.M, ef_construction=self.ef_construction,
                metric=self.metric, seed=self.seed)
            self._keys = list(keys)
            self._device_graph = None
            return
        for k, v in zip(keys, values):
            self.insert(k, v)

    bulkInsert = bulk_insert   # TS-parity alias

    def _dg(self) -> jhnsw.DeviceGraph:
        if self._graph is None:
            if self._builder is None:
                raise ValueError("index is empty")
            self._graph = self._builder.graph()
        if self._device_graph is None:
            self._device_graph = jhnsw.to_device_graph(self._graph)
        return self._device_graph

    def query(self, query, k: int = 10, ef: int | None = None):
        """-> (keys, distances); batched queries return lists of lists."""
        q = np.asarray(query, np.float32)
        squeeze = q.ndim == 1
        ids, dists = jhnsw.search_graph(self._dg(), q, k=k,
                                        ef=ef or self.ef_search)
        ids, dists = np.asarray(ids), np.asarray(dists)
        keys = [[self._keys[i] if i >= 0 else None for i in row] for row in ids]
        if squeeze:
            return keys[0], dists[0]
        return keys, dists

    def exact_query(self, query, k: int = 10):
        """Brute-force oracle over the same vectors."""
        g = self._graph or self._builder.graph()
        flat = FlatIndex(vectors=np.asarray(g.vectors), metric=self.metric)
        d, i = flat.query(query, k)
        return np.asarray(i), np.asarray(d)

    @property
    def size(self) -> int:
        if self._graph is not None:
            return self._graph.n
        return self._builder.n if self._builder else 0

    # ------------------------------------------------------- persistence
    def export_index(self, path: str) -> None:
        g = self._graph or (self._builder.graph() if self._builder else None)
        if g is None:
            raise ValueError("index is empty")
        meta = {
            "metric": self.metric, "M": self.M,
            "ef_construction": self.ef_construction,
            "ef_search": self.ef_search,
            "entry": int(g.entry), "max_level": int(g.max_level),
            "n": int(g.n), "keys": self._keys,
        }
        tmp = path + ".tmp.npz"          # atomic: write sidecar, then rename
        np.savez_compressed(tmp[:-4],    # np.savez appends the .npz itself
                            vectors=g.vectors, neighbors0=g.neighbors0,
                            upper=g.upper, levels=g.levels,
                            meta=np.frombuffer(
                                json.dumps(meta).encode(), dtype=np.uint8))
        os.replace(tmp, path)

    exportIndex = export_index

    @classmethod
    def load_index(cls, path: str) -> "HNSW":
        z = np.load(path, allow_pickle=False)
        meta = json.loads(bytes(z["meta"]).decode())
        idx = cls(distance_function=meta["metric"], M=meta["M"],
                  ef_construction=meta["ef_construction"],
                  ef_search=meta["ef_search"])
        idx._graph = build.HNSWGraph(
            vectors=z["vectors"], neighbors0=z["neighbors0"],
            upper=z["upper"], levels=z["levels"], entry=meta["entry"],
            max_level=meta["max_level"], metric=meta["metric"], n=meta["n"])
        idx._keys = list(meta["keys"])
        return idx

    loadIndex = load_index
