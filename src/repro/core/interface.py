"""MeMemo-parity public API (paper §2.1, Code 1) — now a full
``VectorIndex`` backend with real mutation semantics (DESIGN.md §1/§3).

TypeScript original:
    const index = new HNSW({ distanceFunction: 'cosine' });
    await index.bulkInsert(keys, values);
    const { keys, distances } = await index.query(query, k);
    index.exportIndex() / loadIndex()

Python equivalent (camelCase aliases kept for 1:1 parity):
    index = HNSW(distance_function="cosine", M=5, ef_construction=20)
    index.bulk_insert(keys, values)
    index.update("doc-3", new_vec)       # delete + reinsert, same key
    index.delete("doc-7")                # tombstone: excluded from results
    keys, distances = index.query(query, k=10)
    index.export_index(path); HNSW.load_index(path)

Mutation model: the ``SequentialBuilder`` is the canonical mutable host
graph. Deletes are soft (a tombstone mask threaded through the device-side
beam search — deleted ids stay traversable, hnswlib-style); updates are
delete + reinsert under the same key. After the first query materialises a
resident ``DeviceGraph`` (capacity-padded, fixed shapes), later mutations
upload only the builder's dirty-row journal via ``apply_row_updates``
instead of re-converting the whole graph (DESIGN.md §3).

Sharded operation (``n_shards > 1``, DESIGN.md §8): a navigable
small-world graph cannot be row-partitioned without breaking its search
invariants, so the sharded HNSW is a FAISS/Milvus-style segment set —
each shard owns an independent graph over its hash-routed keys. CRUD
routes to the owning shard (same ``shard_of_key`` as every backend), ANN
queries run the lock-step beam search on every shard's graph in ONE
compiled dispatch (the stacked segment fan-out, ``core/stacked.py``,
cached per mutation epoch) and merge in-program, and the exact/flat
phase queries epoch-cached device-resident blocks
(``build_exact_blocks``/``exact_topk_blocks``). Per-shard graphs are smaller
(N/S rows -> cheaper expansions) and per-shard ANN results are merged
candidates, so cross-shard-count parity holds for ``exact_query`` but
``query_batch`` is parity-at-the-recall-level only — the per-shard
graphs are different (valid) indexes. A global insertion-sequence table
rides in ``state_dict`` so a snapshot can be RESHARDED on restore:
rows replay into fresh per-shard builders in canonical order.
"""
from __future__ import annotations

import numpy as np

from repro.core import hnsw as jhnsw
from repro.core import hnsw_build as build
from repro.core import stacked as jstacked
from repro.core.codec import (check_codec_arrays as _check_codec_arrays,
                              effective_rerank, get_codec, rerank_exact)
from repro.core.flat import FlatIndex
from repro.core.hnsw_build import normalize_rows
from repro.core.index import VectorIndex
from repro.core.sharded import (build_exact_blocks, exact_topk_blocks,
                                shard_mesh, shard_of_key)


class HNSW(VectorIndex):
    kind = "hnsw"

    def __init__(self, distance_function: str = "cosine", *, M: int = 16,
                 ef_construction: int = 200, ef_search: int = 64,
                 seed: int = 0, use_bulk_build: bool = False,
                 n_shards: int = 1, dtype: str = "fp32",
                 rerank_factor: int | None = None,
                 beam_impl: str = "fused"):
        if distance_function not in ("cosine", "ip", "l2"):
            raise ValueError(f"unknown distanceFunction {distance_function!r}")
        if beam_impl not in ("fused", "jnp"):
            raise ValueError(f"unknown beam_impl {beam_impl!r}; "
                             "expected 'fused' or 'jnp'")
        self.metric = distance_function
        # layer-0 beam implementation (DESIGN.md §12): "fused" runs the
        # whole ef-beam as one kernel launch; "jnp" is the per-hop
        # while_loop reference (the parity oracle)
        self.beam_impl = beam_impl
        self.M = M
        self.ef_construction = ef_construction
        self.ef_search = ef_search
        self.seed = seed
        self.use_bulk_build = use_bulk_build
        self.n_shards = int(n_shards)
        # row-storage codec (DESIGN.md §9): lossy codecs quantize each row
        # once at ingest (after metric normalization); the encoded bytes
        # are canonical — the device graph and snapshot pages hold them,
        # the builder's fp32 vectors are their exact decode, and ANN
        # queries over-fetch k·rerank_factor then rerank exactly in fp32
        self.dtype = str(dtype)
        self.rerank_factor = rerank_factor
        self._codec = get_codec(self.dtype)
        self._keys: list[str] = []                 # node id -> key
        self._key2id: dict[str, int] = {}          # live keys only
        self._deleted = np.zeros(0, bool)          # tombstones, capacity-sized
        self._builder: build.SequentialBuilder | None = None
        # canonical encoded rows [n, D] + per-row scales [n] (lossy only;
        # node-id aligned with the builder, appended per insert)
        self._enc: np.ndarray | None = None
        self._scales: np.ndarray | None = None
        # compat only: external code reads `idx._graph or idx._builder.graph()`
        self._graph: build.HNSWGraph | None = None
        self._device_graph: jhnsw.DeviceGraph | None = None
        self._deleted_dirty = False
        # sharded segment set (n_shards > 1): child graphs + routing +
        # the canonical insertion-sequence table (DESIGN.md §8)
        self._shards: list["HNSW"] = []
        self._key2shard: dict[str, int] = {}
        self._seq: dict[str, int] = {}
        self._next_seq = 0
        # epoch-keyed derived device state (sharded only, DESIGN.md §8):
        # the stacked segment set, the gid-aligned fp32 rerank rows, and
        # the exact-phase placed blocks. Mutations invalidate via the
        # epoch key; restores drop them explicitly (_drop_derived) since
        # a restore may land on the same epoch with different rows.
        self._stacked_cache: tuple[int, jstacked.StackedGraphs] | None = None
        self._rerank_rows_cache: tuple[int, np.ndarray] | None = None
        self._exact_cache: tuple | None = None
        if self.n_shards > 1:
            self._shards = [
                HNSW(distance_function=distance_function, M=M,
                     ef_construction=ef_construction, ef_search=ef_search,
                     seed=seed + j, use_bulk_build=False, n_shards=1,
                     dtype=self.dtype, rerank_factor=rerank_factor,
                     beam_impl=beam_impl)
                for j in range(self.n_shards)]

    # --------------------------------------------------- shard plumbing
    @property
    def shard_count(self) -> int:
        return self.n_shards

    def _mirror(self, child: "HNSW", fn, *args) -> None:
        """Run a child-shard impl and mirror its epoch delta onto the
        outer index, so the outer ``mutation_epoch`` advances exactly as
        the 1-shard index would for the same op (cache-invalidation
        parity across shard counts, DESIGN.md §6/§8)."""
        before = child._epoch
        fn(*args)
        self._epoch += child._epoch - before

    # ------------------------------------------------------------ mutation
    def _quantize(self, v: np.ndarray
                  ) -> tuple[np.ndarray, np.ndarray | None, float | None]:
        """Put one raw row in its final stored form (DESIGN.md §9):
        metric normalization, then ONE codec encode whose decode becomes
        the stored fp32 row — so the encoded bytes are canonical and the
        snapshot round-trip is bit-stable."""
        if self.metric == "cosine":
            v = v / max(float(np.linalg.norm(v)), 1e-12)
        enc, scales = self._codec.encode(v[None])
        v = self._codec.decode(enc, scales)[0]
        return v, enc[0], (None if scales is None else scales[0])

    def _append_enc(self, enc_row: np.ndarray,
                    scale: float | None) -> None:
        if self._enc is None:
            self._enc = np.zeros((0, enc_row.shape[-1]),
                                 self._codec.enc_dtype)
        self._enc = np.concatenate([self._enc, enc_row[None]])
        if scale is not None:
            if self._scales is None:
                self._scales = np.zeros(0, np.float32)
            self._scales = np.concatenate(
                [self._scales, np.asarray([scale], np.float32)])

    def _insert_node(self, key: str, v: np.ndarray,
                     enc_row: np.ndarray | None,
                     scale: float | None) -> None:
        """Commit one ALREADY-FINAL row (quantized by ``_quantize`` or
        carried over by compaction) to the builder + enc side arrays."""
        if self._builder is None:
            self._builder = build.SequentialBuilder(
                v.shape[-1], M=self.M, ef_construction=self.ef_construction,
                metric=self.metric, seed=self.seed)
        node = self._builder.insert(v, prenormalized=True)
        assert node == len(self._keys)
        self._keys.append(key)
        self._key2id[key] = node
        if enc_row is not None:
            self._append_enc(enc_row, scale)
        self._bump_epoch()

    def _insert_impl(self, key: str, value: np.ndarray) -> None:
        """Upsert one (key, vector); existing keys are updated in place."""
        if self.n_shards > 1:
            s = shard_of_key(key, self.n_shards)
            self._mirror(self._shards[s], self._shards[s]._insert_impl,
                         key, np.asarray(value, np.float32))
            self._key2shard[key] = s
            self._seq[key] = self._next_seq
            self._next_seq += 1
            return
        if key in self._key2id:
            self._delete_impl(key)
        v = np.asarray(value, np.float32)
        if self._codec.lossy:
            v, enc_row, scale = self._quantize(v)
            self._insert_node(key, v, enc_row, scale)
            return
        if self._builder is None:
            self._builder = build.SequentialBuilder(
                v.shape[-1], M=self.M, ef_construction=self.ef_construction,
                metric=self.metric, seed=self.seed)
        node = self._builder.insert(v)
        assert node == len(self._keys)
        self._keys.append(key)
        self._key2id[key] = node
        self._bump_epoch()

    def _bulk_insert_impl(self, keys: list[str], values: np.ndarray) -> None:
        if self.n_shards > 1:
            # routed inserts in global order: deterministic per-shard
            # insertion sequences regardless of batch boundaries
            if self.use_bulk_build and self._row_count() == 0:
                # epoch parity with the 1-shard bulk-build path, which
                # bumps ONCE for the whole first batch — the WAL replays
                # one record per template call, so the epoch delta per
                # record must match at every shard count or reshard-
                # restore skips/faults on the records that follow
                before = self._epoch
                for k, v in zip(keys, values):
                    self._insert_impl(k, v)
                self._epoch = before + 1
                return
            for k, v in zip(keys, values):
                self._insert_impl(k, v)
            return
        if self.use_bulk_build and self._builder is None:
            values = np.asarray(values, np.float32)
            if self._codec.lossy:
                # normalize + quantize the whole batch once; the graph is
                # built over the decoded (final, stored) rows (§9)
                if self.metric == "cosine":
                    values = normalize_rows(values)
                enc, scales = self._codec.encode(values)
                values = self._codec.decode(enc, scales)
                self._enc = enc
                self._scales = scales
            self._adopt_bulk_graph(keys, values,
                                   prenormalized=self._codec.lossy)
            return
        for k, v in zip(keys, values):
            self._insert_impl(k, v)

    def _adopt_bulk_graph(self, keys: list[str], values: np.ndarray,
                          prenormalized: bool) -> None:
        """Build a whole graph through the device-resident bulk ingest
        (DESIGN.md §13) and adopt it as mutable builder state, so a
        LATER bulk_insert / insert appends instead of silently replacing
        the graph. ``values`` must already be final stored rows when
        ``prenormalized`` (codec decode, §9)."""
        g = build.bulk_build(
            values, M=self.M, ef_construction=self.ef_construction,
            metric=self.metric, seed=self.seed,
            prenormalized=prenormalized, beam_impl=self.beam_impl)
        self._builder = build.SequentialBuilder.from_graph(
            g, ef_construction=self.ef_construction, seed=self.seed)
        self._keys = list(keys)
        self._key2id = {k: i for i, k in enumerate(self._keys)}
        self._device_graph = None
        self._bump_epoch()

    bulkInsert = VectorIndex.bulk_insert   # TS-parity alias

    def _update_impl(self, key: str, value: np.ndarray) -> None:
        """Replace the vector of an existing key (delete + reinsert)."""
        self._insert_impl(key, value)

    def _delete_impl(self, key: str) -> None:
        """Soft-delete: tombstone the row; it stays traversable but is
        never returned from query/exact_query again."""
        if self.n_shards > 1:
            s = self._key2shard.pop(key)           # KeyError if absent
            self._seq.pop(key, None)
            self._mirror(self._shards[s], self._shards[s]._delete_impl, key)
            return
        node = self._key2id.pop(key)               # KeyError if absent
        self._ensure_tombstones()
        self._deleted[node] = True
        self._deleted_dirty = True
        self._bump_epoch()

    def _compact_impl(self) -> None:
        """Physically drop tombstoned rows (DESIGN.md §7): rebuild the
        graph from scratch over live vectors only. Deleted rows stop
        existing host-side — this is the expensive half of secure delete
        (tombstoning stays the cheap everyday path); the store layer
        rewrites the on-disk pages afterwards."""
        if self.n_shards > 1:
            # child epochs are internal; the OUTER delta must match what
            # the 1-shard path produces for the same live set (one bump
            # per reinserted row, or one bump when nothing is live) —
            # naive mirroring would add +1 per EMPTY child and break
            # epoch parity across shard counts
            live_total = self.size
            for child in self._shards:
                child._compact_impl()
            self._epoch += live_total if live_total else 1
            return
        if self._builder is None:
            self._bump_epoch()
            return
        self._ensure_tombstones()
        n = self._builder.n
        live = np.flatnonzero(~self._deleted[:n])
        vecs = self._builder.vectors[live].copy()
        keys = [self._keys[i] for i in live]
        # carry the CANONICAL encoded rows through the rebuild: a deleted
        # row's encoded bytes + scale die here with its fp32 bytes
        # (secure delete, §9), while live rows keep their exact encoding
        # (re-quantizing an already-quantized row would perturb bytes)
        enc = self._enc[live].copy() if self._enc is not None else None
        scl = self._scales[live].copy() if self._scales is not None else None
        self._builder = None                       # fresh graph + fresh RNG
        self._keys = []
        self._key2id = {}
        self._deleted = np.zeros(0, bool)
        self._enc = None
        self._scales = None
        self._device_graph = None
        self._deleted_dirty = False
        if self._codec.lossy:
            for i, (k, v) in enumerate(zip(keys, vecs)):
                self._insert_node(k, v, enc[i],    # bumps epoch per insert
                                  None if scl is None else scl[i])
        else:
            for k, v in zip(keys, vecs):
                self._insert_impl(k, v)            # bumps epoch per insert
        if not keys:
            self._bump_epoch()

    def _ensure_tombstones(self):
        cap = self._builder.vectors.shape[0] if self._builder is not None else 0
        if self._deleted.shape[0] < cap:
            pad = np.zeros(cap - self._deleted.shape[0], bool)
            self._deleted = np.concatenate([self._deleted, pad])

    # ----------------------------------------------------- device residency
    def _enc_capacity(self, cap: int
                      ) -> tuple[np.ndarray | None, np.ndarray | None]:
        """Canonical encoded rows padded to the builder's capacity view
        (zeros beyond ``n`` — matching the builder's zero rows), the
        shape the device graph and snapshots use (§9)."""
        if self._enc is None:
            return None, None
        n, d = self._enc.shape
        enc = np.zeros((cap, d), self._codec.enc_dtype)
        enc[:n] = self._enc
        scl = None
        if self._scales is not None:
            scl = np.zeros(cap, np.float32)
            scl[:n] = self._scales
        return enc, scl

    def _dg(self) -> jhnsw.DeviceGraph:
        """Resident device graph, synced incrementally when possible.
        Under a lossy codec the resident vectors are the ENCODED rows
        (+ scale table): HBM holds ``codec.bytes_per_vector`` per row and
        every distance decodes inside the gather kernel (§9)."""
        if self._builder is None:
            raise ValueError("index is empty")
        b = self._builder
        self._ensure_tombstones()
        g = b.graph_full_capacity(b.max_level_cap)   # fixed [12, cap, M] upper
        dg = self._device_graph
        if dg is None or dg.vectors.shape != g.vectors.shape:
            # first upload, or capacity growth: full conversion
            enc, scl = self._enc_capacity(g.vectors.shape[0])
            self._device_graph = jhnsw.to_device_graph(
                g, self._deleted, enc=enc, scales=scl)
            b.journal.clear()
            self._deleted_dirty = False
        elif b.journal or self._deleted_dirty or dg.max_level != g.max_level:
            # incremental: only dirty rows travel to the device. The
            # scatter indexes enc/scales by dirty row id (< n), so the
            # canonical [n, D] arrays are handed over AS-IS — building
            # the capacity-padded view here would make every sync O(N)
            # host work instead of O(|dirty|)
            self._device_graph = jhnsw.apply_row_updates(
                dg, g, b.journal,
                self._deleted if self._deleted_dirty else None,
                enc=self._enc, scales=self._scales)
            b.journal.clear()
            self._deleted_dirty = False
        return self._device_graph

    # --------------------------------------------------------------- query
    def query_batch(self, queries, k: int = 10, ef: int | None = None):
        """One lock-step device search for the whole [B, D] batch.

        All B queries advance together through ``search_graph`` (DESIGN.md
        §2); the compiled program is cached per (B, k, ef) shape, which is
        why the serving layer coalesces into power-of-two B buckets.

        Sharded: the same lock-step search runs on every shard's graph
        (each N/S-row graph is a cheaper search) and the per-shard
        candidates merge by distance (DESIGN.md §8).
        """
        q = np.asarray(queries, np.float32)
        if q.ndim != 2:
            raise ValueError(f"query_batch expects [B, D], got {q.shape}")
        if self.n_shards > 1:
            return self._query_batch_sharded(q, k, ef)
        rf = effective_rerank(self._codec, self.rerank_factor)
        ids, dists = jhnsw.search_graph(self._dg(), q, k=k * rf,
                                        ef=ef or self.ef_search,
                                        beam_impl=self.beam_impl)
        ids, dists = np.asarray(ids), np.asarray(dists)
        if rf > 1:
            # over-fetched beam candidates rerank exactly in fp32 against
            # the canonical host rows (§9); beam already dropped
            # tombstoned ids, so every candidate is live
            n = self._builder.n
            dists, ids = rerank_exact(self._builder.vectors[:n], q, ids, k,
                                      metric=self.metric)
        keys = [[self._keys[i] if i >= 0 else None for i in row] for row in ids]
        return keys, dists

    def _drop_derived(self) -> None:
        """Drop the epoch-keyed derived device state. Needed on restore:
        a restored index can land on the SAME epoch number as the cached
        state while holding different rows, so the epoch key alone is
        not a safe invalidator there."""
        self._stacked_cache = None
        self._rerank_rows_cache = None
        self._exact_cache = None

    def _stacked(self) -> jstacked.StackedGraphs:
        """Epoch-cached stacked segment set: per-shard resident device
        graphs stacked along [S, ...] (core/stacked.py). Rebuilt only
        when the index mutates; ``_dg()`` keeps each child's resident
        graph synced incrementally, so a rebuild after a small mutation
        moves O(dirty) host bytes, then pads + stacks on device."""
        if (self._stacked_cache is not None
                and self._stacked_cache[0] == self._epoch):
            return self._stacked_cache[1]
        graphs = [child._dg() if child._builder is not None else None
                  for child in self._shards]
        st = jstacked.stack_device_graphs(graphs, shard_mesh(self.n_shards))
        self._stacked_cache = (self._epoch, st)
        return st

    def _rerank_rows(self, st: jstacked.StackedGraphs) -> np.ndarray:
        """Epoch-cached gid-aligned canonical fp32 rows [S*cap, D]: the
        stacked search's global ids index this array directly, so the
        lossy-codec rerank (DESIGN.md §9) needs no id remapping."""
        if (self._rerank_rows_cache is not None
                and self._rerank_rows_cache[0] == self._epoch):
            return self._rerank_rows_cache[1]
        dim = int(st.vectors.shape[-1])
        rows = np.zeros((self.n_shards * st.cap, dim), np.float32)
        for s, child in enumerate(self._shards):
            if child._builder is not None:
                n = child._builder.n
                rows[s * st.cap:s * st.cap + n] = child._builder.vectors[:n]
        self._rerank_rows_cache = (self._epoch, rows)
        return rows

    def _query_batch_sharded(self, q: np.ndarray, k: int, ef: int | None):
        """One compiled dispatch at any shard count: per-shard beam
        search + in-program tree merge over the epoch-cached stacked
        segment set (core/stacked.py). Lossy codecs over-fetch
        ``k * rerank_factor`` per shard, merge in-program, and rerank
        the merged candidates exactly in fp32 against the gid-aligned
        canonical rows."""
        st = self._stacked()
        rf = effective_rerank(self._codec, self.rerank_factor)
        kf = k * rf
        d, gid = jstacked.search_stacked(st, q, kf,
                                         max(ef or self.ef_search, kf),
                                         beam_impl=self.beam_impl)
        if rf > 1:
            d, gid = rerank_exact(self._rerank_rows(st), q, gid, k,
                                  metric=self.metric)
        cap = st.cap
        keys = [[self._shards[int(g) // cap]._keys[int(g) % cap]
                 if g >= 0 else None for g in row] for row in gid]
        return keys, d

    def _query_batch_sharded_loop(self, q: np.ndarray, k: int,
                                  ef: int | None):
        """Per-child Python fan-out (S dispatches + host merge): the
        pre-compiled-path implementation, kept as the parity oracle for
        the stacked fan-out (tests/test_sharded.py)."""
        parts = [(child.query_batch(q, k=k, ef=ef))
                 for child in self._shards if child._builder is not None]
        if not parts:
            raise ValueError("index is empty")
        d_cat = np.concatenate([d for _, d in parts], axis=1)     # [B, C*k]
        k_cat = [sum((pk[b] for pk, _ in parts), [])
                 for b in range(q.shape[0])]
        order = np.argsort(d_cat, axis=1, kind="stable")[:, :k]
        dists = np.take_along_axis(d_cat, order, axis=1)
        keys = [[k_cat[b][j] for j in order[b]] for b in range(q.shape[0])]
        return keys, dists

    def exact_query(self, query, k: int = 10):
        """Brute-force oracle over the same LIVE vectors -> (keys, dists).

        Sharded: the flat phase queries the epoch-cached device blocks —
        every shard scans its own live rows with the fused kernel and the
        per-shard top-k merges through the ppermute tree
        (``exact_topk_blocks``, DESIGN.md §8), so exact results are
        shard-count independent and steady-state calls upload nothing."""
        if self.n_shards > 1:
            return self._exact_query_sharded(query, k)
        if self._builder is None:
            raise ValueError("index is empty")
        self._ensure_tombstones()
        n = self._builder.n
        live = np.flatnonzero(~self._deleted[:n])
        if live.size == 0:
            raise ValueError("index is empty")
        flat = FlatIndex(vectors=np.asarray(self._builder.vectors[live]),
                         metric=self.metric)
        q = np.asarray(query, np.float32)
        squeeze = q.ndim == 1
        if squeeze:
            q = q[None]
        d, i = flat.query(q, min(k, live.size))
        d, i = np.asarray(d), np.asarray(i)
        keys = [[self._keys[int(live[j])] for j in row] for row in i]
        if squeeze:
            return keys[0], d[0]
        return keys, d

    def _live_by_seq(self) -> list[tuple[int, str, int, int]]:
        """Live rows in canonical (insertion-sequence) order:
        [(seq, key, shard, node)]."""
        items = []
        for s, child in enumerate(self._shards):
            for key, node in child._key2id.items():
                items.append((self._seq[key], key, s, node))
        items.sort()
        return items

    def _exact_placed(self):
        """Epoch-cached exact-phase blocks: (items, placed). The host
        repack + ``device_put`` of the [S, R, D] block array happens once
        per mutation epoch (same invalidation contract as the serve-layer
        LRU); steady-state exact search then queries resident blocks
        with zero host-byte movement (``exact_topk_blocks``)."""
        if (self._exact_cache is not None
                and self._exact_cache[0] == self._epoch):
            return self._exact_cache[1], self._exact_cache[2]
        items = self._live_by_seq()
        # canonical gid = rank in insertion order, grouped per shard in
        # one O(live) pass
        ranks: list[list[int]] = [[] for _ in range(self.n_shards)]
        nodes: list[list[int]] = [[] for _ in range(self.n_shards)]
        for rank, (_, _, s, node) in enumerate(items):
            ranks[s].append(rank)
            nodes[s].append(node)
        dim = 0
        groups = []
        for s, child in enumerate(self._shards):
            if child._builder is not None:
                dim = int(child._builder.vectors.shape[1])
            if ranks[s] and child._builder is not None:
                vecs = np.asarray(child._builder.vectors[nodes[s]],
                                  np.float32)
            else:
                vecs = np.zeros((0, 0), np.float32)
            groups.append((vecs, np.asarray(ranks[s], np.int32)))
        # lossy codecs: rows are already in final stored form (normalized
        # BEFORE quantization, §9) — re-normalizing the quantized rows
        # here would score different values than the 1-shard exact path
        placed = build_exact_blocks(
            groups, dim, normalize=(self.metric == "cosine"
                                    and not self._codec.lossy))
        self._exact_cache = (self._epoch, items, placed)
        return items, placed

    def _exact_query_sharded(self, query, k: int):
        items, placed = self._exact_placed()
        if not items:
            raise ValueError("index is empty")
        q = np.asarray(query, np.float32)
        squeeze = q.ndim == 1
        if squeeze:
            q = q[None]
        d, g = exact_topk_blocks(placed, q, min(k, len(items)),
                                 metric=self.metric)
        keys = [[items[int(j)][1] if j >= 0 else None for j in row]
                for row in g]
        if squeeze:
            return keys[0], d[0]
        return keys, d

    @property
    def size(self) -> int:
        if self.n_shards > 1:
            return len(self._key2shard)
        return len(self._key2id)

    def _contains(self, key: str) -> bool:
        if self.n_shards > 1:
            return key in self._key2shard
        return key in self._key2id

    def _row_count(self) -> int:
        if self.n_shards > 1:
            return sum(c._row_count() for c in self._shards)
        return self._builder.n if self._builder is not None else 0

    def keys(self) -> list[str]:
        if self.n_shards > 1:
            return [k for _, k in sorted(
                (self._seq[k], k) for k in self._key2shard)]
        n = self._builder.n if self._builder is not None else 0
        self._ensure_tombstones()
        return [self._keys[i] for i in range(n) if not self._deleted[i]]

    def shard_stats(self) -> list[dict]:
        # same convention at every shard count: slots = rows ever held
        # (tombstones included), free = tombstoned, live = slots - free
        if self.n_shards == 1:
            return [{"shard": 0, "slots": self._row_count(),
                     "free": self._row_count() - self.size,
                     "live": self.size}]
        return [{"shard": s, "slots": c._row_count(),
                 "free": c._row_count() - c.size, "live": c.size}
                for s, c in enumerate(self._shards)]

    # ------------------------------------------------------- persistence
    def config_dict(self) -> dict:
        return {"metric": self.metric, "M": self.M,
                "ef_construction": self.ef_construction,
                "ef_search": self.ef_search, "seed": self.seed,
                "use_bulk_build": self.use_bulk_build,
                "n_shards": self.n_shards, "dtype": self.dtype,
                "rerank_factor": self.rerank_factor,
                "beam_impl": self.beam_impl}

    def state_dict(self) -> tuple[dict, dict]:
        """Full mutation-determined host state, CAPACITY-padded: the
        builder's fixed-shape arrays go to disk as-is, so restore adopts
        them directly and the first query does one plain device upload —
        no graph rebuild (the expensive path the paper measures at 94 min
        for 1M rows). The builder RNG state rides along so WAL replay of
        later inserts draws the exact same levels (DESIGN.md §7).

        An index with no builder (nothing ever inserted, or compacted
        down to zero live rows) serializes as the empty state — a store
        must still be able to snapshot it: compacting away the LAST
        document is precisely the secure-delete case.

        Sharded: one namespaced sub-state per shard plus the canonical
        insertion-sequence table — which is what lets a snapshot restore
        at a DIFFERENT shard count (rows replay into fresh builders in
        canonical order; DESIGN.md §8)."""
        if self.n_shards > 1:
            arrays: dict = {}
            shard_meta = []
            for j, child in enumerate(self._shards):
                a, m = child.state_dict()
                for name, v in a.items():
                    arrays[f"s{j}__{name}"] = v
                shard_meta.append(m)
            meta = {"n_shards": self.n_shards, "epoch": self._epoch,
                    "shards": shard_meta,
                    "seq": sorted(self._seq.items(), key=lambda kv: kv[1]),
                    "next_seq": self._next_seq}
            return arrays, meta
        if self._builder is None:
            arrays = {"levels": np.zeros(0, np.int32),
                      "neighbors0": np.zeros((0, 2 * self.M), np.int32),
                      "upper": np.zeros((0, 0, self.M), np.int32),
                      "deleted": np.zeros(0, bool)}
            if self._codec.lossy:
                arrays["vectors_enc"] = self._codec.to_storage(
                    np.zeros((0, 0), self._codec.enc_dtype))
                if self._codec.uses_scales:
                    arrays["scales"] = np.zeros(0, np.float32)
            else:
                arrays["vectors"] = np.zeros((0, 0), np.float32)
            meta = {"keys": [], "epoch": self._epoch, "n": 0, "entry": -1,
                    "max_level": -1, "max_level_cap": 12, "rng_state": None}
            return arrays, meta
        b = self._builder
        self._ensure_tombstones()
        arrays = {"levels": b.levels, "neighbors0": b.neighbors0,
                  "upper": b.upper, "deleted": self._deleted}
        if self._codec.lossy:
            # persist the CANONICAL encoded rows + scales, capacity-padded
            # like the builder arrays: ≈4x smaller pages, and restore
            # decodes back to the exact builder vectors (§9)
            enc, scl = self._enc_capacity(b.vectors.shape[0])
            arrays["vectors_enc"] = self._codec.to_storage(enc)
            if scl is not None:
                arrays["scales"] = scl
        else:
            arrays["vectors"] = b.vectors
        meta = {"keys": list(self._keys), "epoch": self._epoch,
                "n": int(b.n), "entry": int(b.entry),
                "max_level": int(b.max_level),
                "max_level_cap": int(b.max_level_cap),
                "rng_state": b.rng.bit_generator.state}
        return arrays, meta

    def restore_state(self, arrays: dict, meta: dict) -> None:
        _check_codec_arrays(self._codec, arrays, self.kind)
        rec_shards = int(meta.get("n_shards", 1))
        if rec_shards != self.n_shards:
            # shard-count changed between snapshot and restore: replay the
            # canonical row sequence into the new layout (DESIGN.md §8).
            self._restore_resharded(arrays, meta, rec_shards)
            return
        if self.n_shards > 1:
            for j, (child, m) in enumerate(zip(self._shards, meta["shards"])):
                sub = {name[len(f"s{j}__"):]: v for name, v in arrays.items()
                       if name.startswith(f"s{j}__")}
                child.restore_state(sub, m)
            self._key2shard = {k: s for s, c in enumerate(self._shards)
                               for k in c._key2id}
            self._seq = {k: int(v) for k, v in meta["seq"]}
            self._next_seq = int(meta["next_seq"])
            self._epoch = int(meta["epoch"])
            self._drop_derived()
            return
        if meta["n"] == 0:                # empty state: no builder yet
            self._builder = None
            self._keys = []
            self._key2id = {}
            self._deleted = np.zeros(0, bool)
            self._enc = None
            self._scales = None
            self._epoch = int(meta["epoch"])
            self._device_graph = None
            self._deleted_dirty = False
            return
        n = int(meta["n"])
        if self._codec.lossy:
            # adopt the stored ENCODED rows as canonical and decode the
            # builder's fp32 side from them — never re-encode (§9)
            enc_cap = self._codec.from_storage(arrays["vectors_enc"])
            scl_cap = (np.asarray(arrays["scales"], np.float32)
                       if "scales" in arrays else None)
            vectors = self._codec.decode(enc_cap, scl_cap)
            self._enc = np.ascontiguousarray(enc_cap[:n])
            self._scales = (None if scl_cap is None
                            else np.ascontiguousarray(scl_cap[:n]))
        else:
            vectors = np.asarray(arrays["vectors"], np.float32)
            self._enc = None
            self._scales = None
        b = build.SequentialBuilder(
            vectors.shape[1], M=self.M,
            ef_construction=self.ef_construction, metric=self.metric,
            capacity=vectors.shape[0], max_level_cap=meta["max_level_cap"],
            seed=self.seed)
        b.vectors = vectors
        b.levels = np.asarray(arrays["levels"], np.int32)
        b.neighbors0 = np.asarray(arrays["neighbors0"], np.int32)
        b.upper = np.asarray(arrays["upper"], np.int32)
        b.n = n
        b.entry = int(meta["entry"])
        b.max_level = int(meta["max_level"])
        b.rng.bit_generator.state = meta["rng_state"]
        self._builder = b
        self._keys = list(meta["keys"])
        self._deleted = np.asarray(arrays["deleted"], bool).copy()
        self._key2id = {k: i for i, k in enumerate(self._keys)
                        if not self._deleted[i]}
        self._epoch = int(meta["epoch"])
        self._device_graph = None
        self._deleted_dirty = False

    def _recorded_rows(self, arrays: dict, prefix: str = ""):
        """Recorded rows -> (fp32 vectors, encoded rows | None,
        scales | None), whatever codec wrote them (§9)."""
        if f"{prefix}vectors" in arrays:
            return (np.asarray(arrays[f"{prefix}vectors"], np.float32),
                    None, None)
        enc = self._codec.from_storage(arrays[f"{prefix}vectors_enc"])
        scl = arrays.get(f"{prefix}scales")
        return self._codec.decode(enc, scl), enc, scl

    def _canonical_rows(self, arrays: dict, meta: dict, rec_shards: int
                        ) -> list[tuple]:
        """Live rows of a recorded state in canonical insertion order:
        [(seq, key, vector, enc_row|None, scale|None)] — the
        shard-layout-independent view, encodings included so a reshard
        replay keeps the canonical bytes instead of re-quantizing (§9)."""
        def _row(vecs, enc, scl, node):
            return (vecs[node],
                    None if enc is None else enc[node],
                    None if scl is None else scl[node])

        rows: list[tuple] = []
        if rec_shards == 1:
            n = int(meta["n"])
            deleted = np.asarray(arrays["deleted"], bool)
            vecs, enc, scl = self._recorded_rows(arrays)
            for node in range(n):
                if not deleted[node]:
                    rows.append((node, meta["keys"][node],
                                 *_row(vecs, enc, scl, node)))
            return rows
        seqmap = {k: int(v) for k, v in meta["seq"]}
        for j, m in enumerate(meta["shards"]):
            n = int(m["n"])
            if n == 0:
                continue
            deleted = np.asarray(arrays[f"s{j}__deleted"], bool)
            vecs, enc, scl = self._recorded_rows(arrays, prefix=f"s{j}__")
            for node in range(n):
                key = m["keys"][node]
                if not deleted[node]:
                    rows.append((seqmap[key], key,
                                 *_row(vecs, enc, scl, node)))
        rows.sort(key=lambda r: r[0])
        return rows

    def _insert_canonical(self, key: str, vec: np.ndarray,
                          enc_row: np.ndarray | None,
                          scale: float | None) -> None:
        """Reshard-replay insert of an already-final row: routes like
        ``_insert_impl`` but ADOPTS the recorded encoding instead of
        re-quantizing — re-encoding a decoded row is not guaranteed to
        reproduce the same scale bytes, and the canonical encoding must
        survive a reshard (§9). fp32 rows take the historical replay
        path unchanged."""
        if self.n_shards > 1:
            s = shard_of_key(key, self.n_shards)
            self._mirror(self._shards[s], self._shards[s]._insert_canonical,
                         key, vec, enc_row, scale)
            self._key2shard[key] = s
            self._seq[key] = self._next_seq
            self._next_seq += 1
            return
        if enc_row is None:
            self._insert_impl(key, vec)
            return
        self._insert_node(key, vec, enc_row, scale)

    def _restore_resharded(self, arrays: dict, meta: dict,
                           rec_shards: int) -> None:
        """Adopt a snapshot recorded at a different shard count: a
        deterministic REBUILD — live rows replay into fresh builders in
        canonical order (tombstoned rows do not survive; fresh builders
        draw fresh levels). Epoch and the sequence table are preserved so
        epoch-keyed consumers and ``keys()`` order are unaffected."""
        rows = self._canonical_rows(arrays, meta, rec_shards)
        # reset to empty in the CURRENT layout
        self._builder = None
        self._keys = []
        self._key2id = {}
        self._deleted = np.zeros(0, bool)
        self._enc = None
        self._scales = None
        self._device_graph = None
        self._deleted_dirty = False
        self._drop_derived()
        self._key2shard = {}
        self._seq = {}
        self._next_seq = 0
        if self.n_shards > 1:
            self._shards = [
                HNSW(distance_function=self.metric, M=self.M,
                     ef_construction=self.ef_construction,
                     ef_search=self.ef_search, seed=self.seed + j,
                     use_bulk_build=False, n_shards=1, dtype=self.dtype,
                     rerank_factor=self.rerank_factor,
                     beam_impl=self.beam_impl)
                for j in range(self.n_shards)]
        if (self.use_bulk_build and rows
                and all(r[3] is None for r in rows)):
            # bulk adoption fast path (DESIGN.md §13): a reshard is a
            # from-scratch rebuild over canonical fp32 rows, exactly the
            # shape the device-resident bulk ingest serves — each target
            # builder adopts one bulk-built graph instead of replaying
            # rows through per-row sequential inserts. Lossy codecs keep
            # the replay path: adopted rows must keep their recorded
            # encodings, which the builder-level bulk path re-derives.
            if self.n_shards == 1:
                self._adopt_bulk_graph([r[1] for r in rows],
                                       np.stack([r[2] for r in rows]),
                                       prenormalized=True)
            else:
                per: list[list[tuple]] = [[] for _ in range(self.n_shards)]
                for r in rows:
                    s = shard_of_key(r[1], self.n_shards)
                    per[s].append(r)
                    self._key2shard[r[1]] = s
                    self._seq[r[1]] = self._next_seq
                    self._next_seq += 1
                for s, child_rows in enumerate(per):
                    if child_rows:
                        self._shards[s]._adopt_bulk_graph(
                            [r[1] for r in child_rows],
                            np.stack([r[2] for r in child_rows]),
                            prenormalized=True)
        else:
            for _, key, vec, enc_row, scale in rows:
                self._insert_canonical(key, vec, enc_row, scale)
        if self.n_shards > 1:
            if rec_shards == 1:
                self._seq = {key: seq for seq, key, *_ in rows}
                self._next_seq = int(meta["n"])
            else:
                self._seq = {k: int(v) for k, v in meta["seq"]}
                self._next_seq = int(meta["next_seq"])
        self._epoch = int(meta["epoch"])

    export_index = VectorIndex.export
    exportIndex = VectorIndex.export
    load_index = VectorIndex.load
    loadIndex = VectorIndex.load
