"""Two-tier memory model with graph-aware prefetching — MeMemo §3.2 (C2).

The paper's mechanism: vectors live in a slow bulk tier (IndexedDB), RAM
keeps only keys + graph topology + a cache of ``p`` vectors; on a cache miss
the store prefetches ``p`` *graph neighbors on the current layer* of the
missed element in ONE bulk transaction. ``p`` is auto-derived from the
vector dimension.

We reproduce the mechanism and its accounting (transactions, hits, misses)
exactly, with the tiers renamed for the TPU mapping (HBM <-> VMEM). The
Pallas ``gather_distance`` kernel is the compiled embodiment of the same
policy (wave-batched DMA); this module is the *analyzable* model that lets
benchmarks/bench_tiered.py reproduce the paper's transaction-savings claim
and pick ``p``.
"""
from __future__ import annotations

import collections
import dataclasses

import numpy as np

from repro.core.hnsw_build import HNSWGraph

# paper: "p is automatically determined by the vector dimension".  We model
# the fast tier granting a fixed byte budget per transaction (1 MiB, f32).
PREFETCH_BYTE_BUDGET = 1 << 20


def auto_prefetch_p(dim: int, itemsize: int = 4) -> int:
    return max(1, PREFETCH_BYTE_BUDGET // (dim * itemsize))


@dataclasses.dataclass
class TierStats:
    transactions: int = 0          # slow-tier bulk reads
    rows_fetched: int = 0          # rows moved slow -> fast
    hits: int = 0
    misses: int = 0
    evictions: int = 0

    def as_dict(self) -> dict:
        total = max(self.hits + self.misses, 1)
        return {**dataclasses.asdict(self), "hit_rate": self.hits / total}


class TieredVectorStore:
    """Slow tier: full vector array. Fast tier: LRU cache of `cache_rows`.

    ``read(ids, layer_neighbors)``: for each requested row, a miss triggers
    ONE transaction that prefetches the row plus up to ``p-1`` of its
    current-layer graph neighbors (the paper's policy). Without neighbor
    info it falls back to fetching the next ``p`` sequential rows (the
    Dexie-style batched read the paper compares against).
    """

    def __init__(self, vectors: np.ndarray, *, cache_rows: int,
                 prefetch_p: int | None = None):
        self.slow = vectors
        self.dim = vectors.shape[1]
        self.p = prefetch_p or auto_prefetch_p(self.dim, vectors.itemsize)
        self.cache_rows = max(cache_rows, self.p)
        self.cache: "collections.OrderedDict[int, np.ndarray]" = \
            collections.OrderedDict()
        self.stats = TierStats()

    def _admit(self, row_id: int, row: np.ndarray):
        if row_id in self.cache:
            self.cache.move_to_end(row_id)
            return
        if len(self.cache) >= self.cache_rows:
            self.cache.popitem(last=False)
            self.stats.evictions += 1
        self.cache[row_id] = row

    def _transaction(self, ids: list[int]):
        """One slow-tier bulk read of len(ids) rows."""
        self.stats.transactions += 1
        self.stats.rows_fetched += len(ids)
        for i in ids:
            self._admit(i, self.slow[i])

    def read(self, ids, neighbor_fn=None) -> np.ndarray:
        """Fetch rows by id; ``neighbor_fn(id) -> iterable`` gives the
        current-layer graph neighbors used for prefetch."""
        out = np.empty((len(ids), self.dim), self.slow.dtype)
        for j, i in enumerate(ids):
            i = int(i)
            if i in self.cache:
                self.stats.hits += 1
                self.cache.move_to_end(i)
            else:
                self.stats.misses += 1
                batch = [i]
                if neighbor_fn is not None:
                    for nb in neighbor_fn(i):
                        if len(batch) >= self.p:
                            break
                        nb = int(nb)
                        if nb >= 0 and nb not in self.cache and nb not in batch:
                            batch.append(nb)
                else:
                    batch.extend(x for x in range(i + 1, min(i + self.p,
                                                             len(self.slow))))
                self._transaction(batch)
            out[j] = self.cache[i]
        return out


def graph_neighbor_fn(g: HNSWGraph, layer: int):
    table = g.neighbors0 if layer == 0 else g.upper[layer - 1]

    def fn(i: int):
        row = table[i]
        return row[row >= 0]

    return fn


def simulate_search_traffic(g: HNSWGraph, queries: np.ndarray, *, ef: int,
                            cache_rows: int, prefetch_p: int | None,
                            use_graph_prefetch: bool = True) -> TierStats:
    """Replay HNSW layer-0 beam searches through the tiered store, counting
    slow-tier transactions — the experiment behind the paper's §3.2 claim."""
    from repro.core.hnsw_build import _dist

    store = TieredVectorStore(g.vectors, cache_rows=cache_rows,
                              prefetch_p=prefetch_p)
    nb_fn = graph_neighbor_fn(g, 0) if use_graph_prefetch else None
    for q in queries:
        if g.metric == "cosine":
            q = q / max(float(np.linalg.norm(q)), 1e-12)
        ep = g.entry
        beam = [(float(_dist(g.metric, q, store.read([ep], nb_fn))[0]), ep)]
        visited = {ep}
        expanded: set[int] = set()
        for _ in range(ef):
            cands = [(d, i) for d, i in beam if i not in expanded]
            if not cands:
                break
            _, cur = min(cands)
            expanded.add(cur)
            nbrs = [int(x) for x in g.neighbors0[cur] if x >= 0
                    and int(x) not in visited]
            if not nbrs:
                continue
            visited.update(nbrs)
            rows = store.read(nbrs, nb_fn)
            d = _dist(g.metric, q, rows)
            beam.extend(zip(d.tolist(), nbrs))
            beam = sorted(beam)[:ef]
    return store.stats
