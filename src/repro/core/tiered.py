"""Two-tier memory model with graph-aware prefetching — MeMemo §3.2 (C2).

The paper's mechanism: vectors live in a slow bulk tier (IndexedDB), RAM
keeps only keys + graph topology + a cache of ``p`` vectors; on a cache miss
the store prefetches ``p`` *graph neighbors on the current layer* of the
missed element in ONE bulk transaction. ``p`` is auto-derived from the
vector dimension.

We reproduce the mechanism and its accounting (transactions, hits, misses)
exactly, with the tiers renamed for the TPU mapping (HBM <-> VMEM). The
Pallas ``gather_distance`` kernel is the compiled embodiment of the same
policy (wave-batched DMA); this module is the *analyzable* model that lets
benchmarks/bench_tiered.py reproduce the paper's transaction-savings claim
and pick ``p``.
"""
from __future__ import annotations

import collections
import dataclasses

import numpy as np

from repro.core.hnsw_build import HNSWGraph, _dist
from repro.core.index import VectorIndex

# paper: "p is automatically determined by the vector dimension".  We model
# the fast tier granting a fixed byte budget per transaction (1 MiB, f32).
PREFETCH_BYTE_BUDGET = 1 << 20


def auto_prefetch_p(dim: int, itemsize: int = 4) -> int:
    return max(1, PREFETCH_BYTE_BUDGET // (dim * itemsize))


@dataclasses.dataclass
class TierStats:
    transactions: int = 0          # slow-tier bulk reads
    rows_fetched: int = 0          # rows moved slow -> fast
    hits: int = 0
    misses: int = 0
    evictions: int = 0

    def as_dict(self) -> dict:
        total = max(self.hits + self.misses, 1)
        return {**dataclasses.asdict(self), "hit_rate": self.hits / total}


class TieredVectorStore:
    """Slow tier: full vector array. Fast tier: LRU cache of `cache_rows`.

    ``read(ids, layer_neighbors)``: for each requested row, a miss triggers
    ONE transaction that prefetches the row plus up to ``p-1`` of its
    current-layer graph neighbors (the paper's policy). Without neighbor
    info it falls back to fetching the next ``p`` sequential rows (the
    Dexie-style batched read the paper compares against).

    ``codec`` (DESIGN.md §9): a lossy codec makes the SLOW tier hold the
    encoded rows (+ per-row scales) — the bytes-constrained tier the
    paper models — and ``read`` decodes on admission, so the fast tier
    serves fp32 rows to the distance math. Because the prefetch budget is
    in BYTES, an int8 slow tier prefetches ~4x more neighbors per
    transaction — exactly the paper's bytes-per-transaction economics.
    """

    def __init__(self, vectors: np.ndarray, *, cache_rows: int,
                 prefetch_p: int | None = None, codec=None):
        self.codec = codec if (codec is not None and codec.lossy) else None
        if self.codec is not None:
            self.slow, self._slow_scales = self.codec.encode(
                np.asarray(vectors, np.float32))
            itemsize = self.codec.enc_dtype.itemsize
        else:
            self.slow = vectors
            self._slow_scales = None
            itemsize = vectors.itemsize
        self.dim = vectors.shape[1]
        self.p = prefetch_p or auto_prefetch_p(self.dim, itemsize)
        self.cache_rows = max(cache_rows, self.p)
        self.cache: "collections.OrderedDict[int, np.ndarray]" = \
            collections.OrderedDict()
        self.stats = TierStats()

    @property
    def slow_tier_bytes(self) -> int:
        """Bytes the slow tier actually holds (encoded under a codec)."""
        total = self.slow.nbytes
        if self._slow_scales is not None:
            total += self._slow_scales.nbytes
        return total

    def _slow_row(self, i: int) -> np.ndarray:
        if self.codec is None:
            return self.slow[i]
        return self.codec.decode(self.slow[i][None],
                                 self._slow_scales[i:i + 1]
                                 if self._slow_scales is not None
                                 else None)[0]

    def _admit(self, row_id: int, row: np.ndarray):
        if row_id in self.cache:
            self.cache.move_to_end(row_id)
            return
        if len(self.cache) >= self.cache_rows:
            self.cache.popitem(last=False)
            self.stats.evictions += 1
        self.cache[row_id] = row

    def _transaction(self, ids: list[int]):
        """One slow-tier bulk read of len(ids) rows."""
        self.stats.transactions += 1
        self.stats.rows_fetched += len(ids)
        for i in ids:
            self._admit(i, self._slow_row(i))

    def read(self, ids, neighbor_fn=None) -> np.ndarray:
        """Fetch rows by id; ``neighbor_fn(id) -> iterable`` gives the
        current-layer graph neighbors used for prefetch. Rows come back
        fp32-decoded when the slow tier is codec-encoded."""
        out = np.empty((len(ids), self.dim),
                       np.float32 if self.codec is not None
                       else self.slow.dtype)
        for j, i in enumerate(ids):
            i = int(i)
            if i in self.cache:
                self.stats.hits += 1
                self.cache.move_to_end(i)
            else:
                self.stats.misses += 1
                batch = [i]
                if neighbor_fn is not None:
                    for nb in neighbor_fn(i):
                        if len(batch) >= self.p:
                            break
                        nb = int(nb)
                        if nb >= 0 and nb not in self.cache and nb not in batch:
                            batch.append(nb)
                else:
                    batch.extend(x for x in range(i + 1, min(i + self.p,
                                                             len(self.slow))))
                self._transaction(batch)
            out[j] = self.cache[i]
        return out


def graph_neighbor_fn(g: HNSWGraph, layer: int):
    table = g.neighbors0 if layer == 0 else g.upper[layer - 1]

    def fn(i: int):
        row = table[i]
        return row[row >= 0]

    return fn


class TieredIndex(VectorIndex):
    """``VectorIndex`` backend whose query path runs through the two-tier
    store (DESIGN.md §4): graph topology + keys live in the fast tier, the
    vector payload in the slow tier, and every search pays (and counts)
    slow-tier transactions with graph-aware prefetching — the queryable
    version of the §3.2 accounting model.

    Mutations delegate to an inner HNSW index (tombstones included); any
    mutation invalidates the fast-tier cache, so the next query re-warms it
    against the current graph. ``stats`` accumulates TierStats across
    queries between mutations.

    Sharded operation (``n_shards > 1``, DESIGN.md §8): the inner HNSW is
    the sharded segment set, so CRUD routes by key hash, the exact/flat
    phase fans out through the sharded top-k substrate, and the tiered
    accounting search runs per shard — each shard gets its OWN two-tier
    store (its graph and payload are independent), results merge by
    distance, and ``stats`` aggregates slow-tier traffic across shards.
    """

    kind = "tiered"

    def __init__(self, *, metric: str = "cosine", M: int = 16,
                 ef_construction: int = 200, ef_search: int = 64,
                 cache_rows: int = 1024, prefetch_p: int | None = None,
                 seed: int = 0, use_bulk_build: bool = False,
                 n_shards: int = 1, dtype: str = "fp32",
                 rerank_factor: int | None = None,
                 beam_impl: str = "fused"):
        from repro.core.codec import get_codec
        from repro.core.interface import HNSW   # lazy: avoid import cycle
        self.n_shards = int(n_shards)
        self.dtype = str(dtype)
        self.rerank_factor = rerank_factor
        self.beam_impl = beam_impl
        self._codec = get_codec(self.dtype)
        self.inner = HNSW(distance_function=metric, M=M,
                          ef_construction=ef_construction,
                          ef_search=ef_search, seed=seed,
                          use_bulk_build=use_bulk_build,
                          n_shards=self.n_shards, dtype=self.dtype,
                          rerank_factor=rerank_factor,
                          beam_impl=beam_impl)
        self.metric = metric
        self.ef_search = ef_search
        self.cache_rows = cache_rows
        self.prefetch_p = prefetch_p
        # fast-tier cache; NOT the durability IndexStore (that is the
        # base class's ``_store``)
        self._tier_store: TieredVectorStore | None = None
        self._g: HNSWGraph | None = None
        # sharded: one (graph, tier store, child) triple per shard
        self._tier_shards: list | None = None

    # ------------------------------------------------------------ mutation
    # NB: mutations delegate to the INNER index's impl layer — the inner
    # HNSW is never store-attached (the outer TieredIndex owns WAL
    # logging), so going through its public mutators would only repeat
    # the validation the outer template method already did.
    def _invalidate(self):
        self._tier_store = None
        self._g = None
        self._tier_shards = None
        self._bump_epoch()

    def _insert_impl(self, key: str, value: np.ndarray) -> None:
        self.inner._insert_impl(key, value)
        self._invalidate()

    def _bulk_insert_impl(self, keys: list[str], values: np.ndarray) -> None:
        self.inner._bulk_insert_impl(keys, values)
        self._invalidate()

    def _update_impl(self, key: str, value: np.ndarray) -> None:
        self.inner._update_impl(key, value)
        self._invalidate()

    def _delete_impl(self, key: str) -> None:
        self.inner._delete_impl(key)
        self._invalidate()

    def _compact_impl(self) -> None:
        """Physically drop tombstoned rows: rebuild the inner graph over
        live vectors (DESIGN.md §7) and re-warm the tiers lazily."""
        self.inner._compact_impl()
        self._invalidate()

    # --------------------------------------------------------------- query
    def _tiers(self) -> tuple[HNSWGraph, "TieredVectorStore"]:
        if self.inner._builder is None:
            raise ValueError("index is empty")
        if self._g is None:
            self._g = self.inner._builder.graph()
            self._tier_store = TieredVectorStore(self._g.vectors,
                                                 cache_rows=self.cache_rows,
                                                 prefetch_p=self.prefetch_p,
                                                 codec=self._codec)
        return self._g, self._tier_store

    def _tiers_sharded(self) -> list:
        """Per-shard (graph, tier store, child-HNSW) triples: every shard's
        payload is an independent slow tier with its own fast-tier cache
        (DESIGN.md §8). Empty shards are skipped."""
        if self._tier_shards is None:
            out = []
            for child in self.inner._shards:
                if child._builder is None:
                    continue
                g = child._builder.graph()
                out.append((g, TieredVectorStore(
                    g.vectors, cache_rows=self.cache_rows,
                    prefetch_p=self.prefetch_p, codec=self._codec), child))
            if not out:
                raise ValueError("index is empty")
            self._tier_shards = out
        return self._tier_shards

    @property
    def stats(self) -> TierStats:
        if self.n_shards == 1:
            return self._tiers()[1].stats
        total = TierStats()
        for _, store, _ in self._tiers_sharded():
            s = store.stats
            total.transactions += s.transactions
            total.rows_fetched += s.rows_fetched
            total.hits += s.hits
            total.misses += s.misses
            total.evictions += s.evictions
        return total

    def query_batch(self, queries, k: int = 10, ef: int | None = None):
        """Batched search through the two-tier store. The host-side beam is
        the *accounting model* (it counts slow-tier transactions), so the
        batch runs query-at-a-time — but all B queries share one warmed
        fast-tier cache, which is exactly the amortisation the model is
        meant to expose. Sharded: each shard's beam runs over its own
        (smaller) graph + tier store; candidates merge by distance."""
        ef = max(ef or self.ef_search, k)
        q = np.asarray(queries, np.float32)
        if q.ndim != 2:
            raise ValueError(f"query_batch expects [B, D], got {q.shape}")
        if self.n_shards > 1:
            return self._query_batch_sharded(q, k, ef)
        g, store = self._tiers()
        self.inner._ensure_tombstones()
        deleted = self.inner._deleted
        out_keys, out_d = [], []
        for qv in q:
            ids, dists = _tiered_beam_search(g, deleted, store, qv, k, ef)
            out_keys.append([self.inner._keys[i] if i >= 0 else None
                             for i in ids])
            out_d.append(dists)
        return out_keys, np.asarray(out_d, np.float32)

    def _query_batch_sharded(self, q: np.ndarray, k: int, ef: int):
        """Sharded ANN: delegate to the inner HNSW's one-dispatch stacked
        fan-out (core/stacked.py) — the per-shard graphs ARE the inner
        index's graphs, so the compiled path searches exactly the same
        segment set the host loop did, in one XLA dispatch instead of S
        host-driven beam searches. The host loop survives as
        ``_query_batch_sharded_loop`` (the tier-traffic accounting model
        and the stacked path's parity oracle); slow-tier transaction
        counting for sharded searches goes through it or
        ``simulate_search_traffic``."""
        return self.inner._query_batch_sharded(q, k, ef)

    def _query_batch_sharded_loop(self, q: np.ndarray, k: int, ef: int):
        tiers = self._tiers_sharded()
        out_keys, out_d = [], []
        for qv in q:
            cand: list[tuple[float, str]] = []
            for g, store, child in tiers:
                child._ensure_tombstones()
                ids, dists = _tiered_beam_search(g, child._deleted, store,
                                                 qv, k, ef)
                cand.extend((d, child._keys[i])
                            for d, i in zip(dists, ids) if i >= 0)
            cand.sort(key=lambda c: c[0])
            cand = cand[:k]
            out_keys.append([key for _, key in cand]
                            + [None] * (k - len(cand)))
            out_d.append([d for d, _ in cand]
                         + [float(np.float32(3e38))] * (k - len(cand)))
        return out_keys, np.asarray(out_d, np.float32)

    def exact_query(self, query, k: int = 10):
        return self.inner.exact_query(query, k)

    # --------------------------------------------------------- persistence
    def config_dict(self) -> dict:
        return {"metric": self.metric, "M": self.inner.M,
                "ef_construction": self.inner.ef_construction,
                "ef_search": self.ef_search,
                "cache_rows": self.cache_rows,
                "prefetch_p": self.prefetch_p,
                "seed": self.inner.seed,
                "use_bulk_build": self.inner.use_bulk_build,
                "n_shards": self.n_shards, "dtype": self.dtype,
                "rerank_factor": self.rerank_factor,
                "beam_impl": self.beam_impl}

    def state_dict(self) -> tuple[dict, dict]:
        """The durable state IS the inner HNSW's (graph + tombstones +
        RNG); the tier split is a runtime view re-derived on first query.
        Only the outer epoch is added — it is what serving caches key on.
        """
        arrays, meta = self.inner.state_dict()
        meta = dict(meta, outer_epoch=self._epoch)
        return arrays, meta

    def restore_state(self, arrays: dict, meta: dict) -> None:
        self.inner.restore_state(arrays, meta)
        self._epoch = int(meta["outer_epoch"])
        self._tier_store = None
        self._g = None
        self._tier_shards = None

    def _row_count(self) -> int:
        return self.inner._row_count()

    @property
    def size(self) -> int:
        return self.inner.size

    def _contains(self, key: str) -> bool:
        return self.inner._contains(key)

    def keys(self) -> list[str]:
        return self.inner.keys()

    @property
    def shard_count(self) -> int:
        return self.n_shards

    def shard_stats(self) -> list[dict]:
        return self.inner.shard_stats()


def _tiered_beam_search(g: HNSWGraph, deleted: np.ndarray,
                        store: "TieredVectorStore", q: np.ndarray, k: int,
                        ef: int) -> tuple[list[int], list[float]]:
    """Host-side HNSW search reading vectors exclusively through the tiered
    store (greedy upper-layer descent + ef-beam on layer 0). Tombstoned ids
    are traversable but excluded from the returned top-k."""
    if g.metric == "cosine":
        q = q / max(float(np.linalg.norm(q)), 1e-12)
    ep = int(g.entry)
    d_ep = float(_dist(g.metric, q, store.read([ep],
                                               graph_neighbor_fn(g, 0)))[0])
    # greedy descent through the upper layers
    for layer in range(g.max_level, 0, -1):
        nb_fn = graph_neighbor_fn(g, layer)
        improved = True
        while improved:
            improved = False
            nbrs = [int(x) for x in nb_fn(ep)]
            if not nbrs:
                break
            d = _dist(g.metric, q, store.read(nbrs, nb_fn))
            j = int(np.argmin(d))
            if float(d[j]) < d_ep:
                ep, d_ep = nbrs[j], float(d[j])
                improved = True
    # ef-beam best-first search on layer 0
    nb_fn = graph_neighbor_fn(g, 0)
    beam = [(d_ep, ep)]
    visited = {ep}
    expanded: set[int] = set()
    for _ in range(ef):
        cands = [(d, i) for d, i in beam if i not in expanded]
        if not cands:
            break
        _, cur = min(cands)
        expanded.add(cur)
        nbrs = [int(x) for x in g.neighbors0[cur] if x >= 0
                and int(x) not in visited]
        if not nbrs:
            continue
        visited.update(nbrs)
        d = _dist(g.metric, q, store.read(nbrs, nb_fn))
        beam.extend(zip(d.tolist(), nbrs))
        beam = sorted(beam)[:ef]
    live = [(d, i) for d, i in beam if not deleted[i]][:k]
    ids = [i for _, i in live] + [-1] * (k - len(live))
    dists = [d for d, _ in live] + [float(np.float32(3e38))] * (k - len(live))
    return ids, dists


def simulate_search_traffic(g: HNSWGraph, queries: np.ndarray, *, ef: int,
                            cache_rows: int, prefetch_p: int | None,
                            use_graph_prefetch: bool = True) -> TierStats:
    """Replay HNSW layer-0 beam searches through the tiered store, counting
    slow-tier transactions — the experiment behind the paper's §3.2 claim."""
    from repro.core.hnsw_build import _dist

    store = TieredVectorStore(g.vectors, cache_rows=cache_rows,
                              prefetch_p=prefetch_p)
    nb_fn = graph_neighbor_fn(g, 0) if use_graph_prefetch else None
    for q in queries:
        if g.metric == "cosine":
            q = q / max(float(np.linalg.norm(q)), 1e-12)
        ep = g.entry
        beam = [(float(_dist(g.metric, q, store.read([ep], nb_fn))[0]), ep)]
        visited = {ep}
        expanded: set[int] = set()
        for _ in range(ef):
            cands = [(d, i) for d, i in beam if i not in expanded]
            if not cands:
                break
            _, cur = min(cands)
            expanded.add(cur)
            nbrs = [int(x) for x in g.neighbors0[cur] if x >= 0
                    and int(x) not in visited]
            if not nbrs:
                continue
            visited.update(nbrs)
            rows = store.read(nbrs, nb_fn)
            d = _dist(g.metric, q, rows)
            beam.extend(zip(d.tolist(), nbrs))
            beam = sorted(beam)[:ef]
    return store.stats
