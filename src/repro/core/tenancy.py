"""Multi-tenant index pool: many small private indexes, one device arena
(DESIGN.md §10).

MeMemo's deployment shape is millions of *per-user* corpora, not one big
index — a user's few-hundred-row private knowledge base is the unit of
isolation, admission, and deletion. Before this layer the process served
exactly one ``VectorIndex``; naively instantiating one index per tenant
would cost one XLA buffer (and one compiled search) per user.

``IndexPool`` multiplexes tenants over ONE shared ``ShardedRows`` arena:

  * **namespacing** — a tenant's rows live in the arena under
    ``tenant_id + NS_SEP + key``; the same blake2b key->shard routing
    spreads every tenant across the mesh.
  * **slab allocation** — ``SlabRows`` hands out per-shard slot capacity
    in fixed ``slab_rows``-sized slabs, each owned by exactly one tenant
    at a time. Resident tenants therefore pack into shared ``[S, R, D]``
    device blocks (one buffer for the whole pool, DESIGN.md §8) while a
    tenant's *search* gathers only its own slabs — per-query cost scales
    with the tenant's corpus, not the arena.
  * **per-tenant epochs** — the pool keeps a ``mutation_epoch`` per
    tenant with exactly the per-op bump schedule a dedicated
    ``FlatVectorIndex`` would have, so one user's delete invalidates
    only *their* cache entries (serve/retrieval.py keys its LRU on
    ``(tenant, query, ...)`` and validates per tenant).
  * **LRU residency** — at most ``max_resident`` tenants hold arena
    capacity; the rest live in per-tenant ``IndexStore`` dirs
    (``root/tenants/<id>``, DESIGN.md §7). Evict = snapshot + remove the
    tenant's rows from the arena + ``_drop_derived()``; admit = the
    existing bit-for-bit warm restore adopted back into the arena.
    Because the stored state is the same canonical (codec-encoded)
    arrays a single index persists, evict→restore round-trips are
    bit-identical to a never-evicted index.
  * **byte absence, per tenant** — ``compact(tid)`` physically removes
    the tenant's tombstoned rows from the host arrays, from the shared
    device blocks (rebuilt without them), and from the tenant's store
    (snapshot + WAL truncation + old-snapshot purge — the secure-delete
    contract of DESIGN.md §7, scoped to one tenant). Other tenants'
    rows, epochs, and cached results are untouched.

What shared slabs do NOT guarantee before compaction: a tombstoned row's
bytes remain in the tenant's own host-canonical arrays (and store WAL)
until ``compact(tid)`` — exactly like a single index. They are, however,
never packed into device blocks again, never returned by any query, and
never visible to another tenant: a freed slab handed to tenant B is
zero-filled at pack time (free slots carry gid -1 and 0-rows), so slab
reuse cannot expose the previous owner's vectors.
"""
from __future__ import annotations

import collections
import dataclasses
import functools
import os
import urllib.parse

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core.codec import VectorCodec, effective_rerank, get_codec
from repro.core.flat import FlatVectorIndex, _pad_results
from repro.core.hnsw_build import normalize_rows
from repro.core.sharded import (INF, SHARD_AXIS, ShardedRows, _quantize_slack,
                                place_blocks, shard_mesh, shard_of_key,
                                trim_merge_width)
from repro.distributed.collectives import hierarchical_topk
from repro.kernels import ops

# Unit separator: cannot appear in tenant ids or doc keys (validated at
# the pool boundary), so the namespaced key is unambiguous.
NS_SEP = "\x1f"


def tenant_key(tid: str, key: str) -> str:
    """Namespaced arena key for one tenant's document."""
    return tid + NS_SEP + key


def split_tenant_key(nskey: str) -> tuple[str, str]:
    """Inverse of :func:`tenant_key` -> (tenant_id, doc key)."""
    tid, _, key = nskey.partition(NS_SEP)
    return tid, key


# ---------------------------------------------------------------------------
# compiled tenant-scoped search (slab gather + fused top-k + tree merge)
# ---------------------------------------------------------------------------
def _slab_gather(blocks, gids, scl, tbl, slab_rows: int):
    """Gather one tenant's slabs out of a shard's packed block.

    blocks [RT, D] (RT = n_slabs * slab_rows), gids [RT], tbl [L] slab
    ids (-1 padding) -> (db [L*R, D], gid [L*R], scales [L*R] | None).
    Padding entries clip to slab 0 — which may hold ANOTHER tenant's live
    rows — so their gathered gids are force-masked to -1 here; nothing
    downstream may trust a gid at a padded position.
    """
    nsl = max(blocks.shape[0] // slab_rows, 1)
    idx = jnp.clip(tbl, 0, nsl - 1)
    db = jnp.take(blocks.reshape(nsl, slab_rows, -1), idx,
                  axis=0).reshape(-1, blocks.shape[-1])
    g = jnp.take(gids.reshape(nsl, slab_rows), idx, axis=0).reshape(-1)
    g = jnp.where(jnp.repeat(tbl >= 0, slab_rows), g, -1)
    s = None
    if scl is not None:
        s = jnp.take(scl.reshape(nsl, slab_rows), idx, axis=0).reshape(-1)
    return db, g, s


def _slab_local_topk(blocks, gids, scl, tbl, q, *, k: int, slack: int,
                     metric: str, slab_rows: int):
    """One shard's tenant-scoped top-k: gather the tenant's slabs, run
    the SAME fused ``flat_topk`` kernel the single-index path uses over
    the [L*R, D] gathered db, over-fetch ``k + slack`` (slack bounds the
    invalid rows: free slots inside the tenant's slabs + whole padding
    slabs — the kernel cannot mask mid-scan, DESIGN.md §8), mask by gid,
    and trim to the k-wide merge format."""
    db, g, s = _slab_gather(blocks, gids, scl, tbl, slab_rows)
    kk = min(k + slack, db.shape[0])
    d, i = ops.flat_topk(db, q, kk, metric=metric, scales=s)
    gg = jnp.take(g, i)
    d = jnp.where(gg >= 0, d, jnp.float32(INF))
    d, gg = trim_merge_width(d, gg, k, jnp.float32(INF))
    gg = jnp.where(d >= jnp.float32(INF), -1, gg)
    return d, gg


@functools.lru_cache(maxsize=256)
def _slab_topk_single(k: int, slack: int, metric: str, has_scales: bool,
                      slab_rows: int):
    """S == 1 tenant search: one fused dispatch over the gathered slabs."""
    def run(blocks, gids, scl, tbl, q):
        if metric == "cosine":
            q = q / jnp.maximum(
                jnp.linalg.norm(q, axis=-1, keepdims=True), 1e-12)
        return _slab_local_topk(blocks, gids, scl, tbl, q, k=k, slack=slack,
                                metric=metric, slab_rows=slab_rows)

    if has_scales:
        return jax.jit(run)
    return jax.jit(lambda blocks, gids, tbl, q: run(blocks, gids, None,
                                                    tbl, q))


@functools.lru_cache(maxsize=256)
def _slab_topk_sharded(mesh, k: int, slack: int, metric: str,
                       has_scales: bool, slab_rows: int):
    """S > 1 tenant search: per-shard slab gather + fused scan under
    shard_map, merged through the same ppermute tree as the single-index
    fan-out (ids exact, ties break on the smaller gid)."""
    n_shards = mesh.shape[SHARD_AXIS]

    def local(blocks, gids, scl, tbl, q):
        blocks, gids, tbl = blocks[0], gids[0], tbl[0]
        scl = None if scl is None else scl[0]
        if metric == "cosine":
            q = q / jnp.maximum(
                jnp.linalg.norm(q, axis=-1, keepdims=True), 1e-12)
        d, gg = _slab_local_topk(blocks, gids, scl, tbl, q, k=k, slack=slack,
                                 metric=metric, slab_rows=slab_rows)
        return hierarchical_topk(d, gg, k, (SHARD_AXIS,), tie_break_ids=True,
                                 axis_sizes=(n_shards,))

    if has_scales:
        fn = shard_map(local, mesh=mesh,
                       in_specs=(P(SHARD_AXIS, None, None),
                                 P(SHARD_AXIS, None), P(SHARD_AXIS, None),
                                 P(SHARD_AXIS, None), P(None, None)),
                       out_specs=(P(None, None), P(None, None)),
                       check_rep=False)
    else:
        fn = shard_map(lambda b, g, t, q: local(b, g, None, t, q), mesh=mesh,
                       in_specs=(P(SHARD_AXIS, None, None),
                                 P(SHARD_AXIS, None), P(SHARD_AXIS, None),
                                 P(None, None)),
                       out_specs=(P(None, None), P(None, None)),
                       check_rep=False)
    return jax.jit(fn)


def _multi_local_topk(blocks, gids, scl, tbl, q, *, k: int, metric: str,
                      slab_rows: int):
    """Cross-tenant one-dispatch search: every query row carries its OWN
    slab table. tbl [B, L], q [B, D] -> (d [B, k], gids [B, k]).

    Per-query gather ([B, L, R, D]) + masked einsum + top_k: unlike the
    single-tenant path the mask is applied BEFORE selection (this path is
    plain jnp, not the fused kernel), so no slack over-fetch is needed.
    Rows are decoded in-graph (bf16 upcast / int8 * scale) — the same
    asymmetric-scan semantics as ``flat_topk``'s fused decode.
    """
    nsl = max(blocks.shape[0] // slab_rows, 1)
    d_ = blocks.shape[-1]
    idx = jnp.clip(tbl, 0, nsl - 1)                          # [B, L]
    rows = jnp.take(blocks.reshape(nsl, slab_rows, d_), idx,
                    axis=0)                                  # [B, L, R, D]
    g = jnp.take(gids.reshape(nsl, slab_rows), idx, axis=0)  # [B, L, R]
    valid = (tbl >= 0)[:, :, None] & (g >= 0)
    x = rows.astype(jnp.float32)
    if scl is not None:
        x = x * jnp.take(scl.reshape(nsl, slab_rows), idx,
                         axis=0)[..., None]
    if metric == "cosine":
        q = q / jnp.maximum(jnp.linalg.norm(q, axis=-1, keepdims=True),
                            1e-12)
    if metric == "l2":
        d = (jnp.sum(q * q, axis=-1)[:, None, None]
             - 2.0 * jnp.einsum("blrd,bd->blr", x, q)
             + jnp.sum(x * x, axis=-1))
    else:
        d = jnp.float32(1.0) - jnp.einsum("blrd,bd->blr", x, q)
    b = tbl.shape[0]
    d = jnp.where(valid, d, jnp.float32(INF)).reshape(b, -1)
    g = g.reshape(b, -1)
    kk = min(k, d.shape[1])
    neg, j = jax.lax.top_k(-d, kk)
    dd = -neg
    gg = jnp.take_along_axis(g, j, axis=1)
    dd, gg = trim_merge_width(dd, gg, k, jnp.float32(INF))
    gg = jnp.where(dd >= jnp.float32(INF), -1, gg)
    return dd, gg


@functools.lru_cache(maxsize=256)
def _slab_topk_multi(mesh, k: int, metric: str, has_scales: bool,
                     slab_rows: int):
    """Compiled cross-tenant dispatch; ``mesh`` is None for S == 1."""
    if mesh is None:
        def run(blocks, gids, scl, tbl, q):
            return _multi_local_topk(blocks, gids, scl, tbl, q, k=k,
                                     metric=metric, slab_rows=slab_rows)
        if has_scales:
            return jax.jit(run)
        return jax.jit(lambda blocks, gids, tbl, q: run(blocks, gids, None,
                                                        tbl, q))
    n_shards = mesh.shape[SHARD_AXIS]

    def local(blocks, gids, scl, tbl, q):
        blocks, gids, tbl = blocks[0], gids[0], tbl[0]
        scl = None if scl is None else scl[0]
        d, gg = _multi_local_topk(blocks, gids, scl, tbl, q, k=k,
                                  metric=metric, slab_rows=slab_rows)
        return hierarchical_topk(d, gg, k, (SHARD_AXIS,), tie_break_ids=True,
                                 axis_sizes=(n_shards,))

    if has_scales:
        fn = shard_map(local, mesh=mesh,
                       in_specs=(P(SHARD_AXIS, None, None),
                                 P(SHARD_AXIS, None), P(SHARD_AXIS, None),
                                 P(SHARD_AXIS, None, None), P(None, None)),
                       out_specs=(P(None, None), P(None, None)),
                       check_rep=False)
    else:
        fn = shard_map(lambda b, g, t, q: local(b, g, None, t, q), mesh=mesh,
                       in_specs=(P(SHARD_AXIS, None, None),
                                 P(SHARD_AXIS, None),
                                 P(SHARD_AXIS, None, None), P(None, None)),
                       out_specs=(P(None, None), P(None, None)),
                       check_rep=False)
    return jax.jit(fn)


# ---------------------------------------------------------------------------
# slab-granular arena
# ---------------------------------------------------------------------------
class SlabRows(ShardedRows):
    """``ShardedRows`` whose per-shard slot space is carved into fixed
    ``slab_rows``-sized slabs, each owned by one tenant at a time.

    The canonical layer (host vectors / keys / alive) is untouched —
    rows append in arena order exactly as before, so per-tenant
    extraction preserves each tenant's own insertion order (what the
    store-parity contract needs). Only *placement* changes: a row's slot
    comes from a slab owned by its tenant (``_owner_of_row`` parses the
    namespace prefix), a tombstoned slot returns to its slab, and a slab
    whose slots are all free is released to the arena-wide pool for the
    next tenant that needs capacity. ``pack_arena`` zero-fills free
    slots, so a reused slab never carries its previous owner's bytes to
    the device.
    """

    def __init__(self, *, slab_rows: int = 64, n_shards: int = 1,
                 metric: str = "cosine", dim: int | None = None,
                 codec: VectorCodec | str | None = None):
        if slab_rows < 1:
            raise ValueError(f"slab_rows must be >= 1, got {slab_rows}")
        self.slab_rows = int(slab_rows)
        # per shard: slab -> owner tenant (None = free), slab -> free-slot
        # stack, owner -> slab ids (insertion order = allocation order)
        self._slab_owner: list[list[str | None]] = \
            [[] for _ in range(n_shards)]
        self._slab_free: list[list[list[int]]] = \
            [[] for _ in range(n_shards)]
        self._owner_slabs: list[dict[str, list[int]]] = \
            [{} for _ in range(n_shards)]
        # derived-state versioning: bumped on every _invalidate so the
        # lazily-built device arena and per-tenant slab tables self-stale
        self.pack_epoch = 0
        self._arena = None
        self._tables: dict[str, tuple] = {}
        super().__init__(n_shards=n_shards, metric=metric, dim=dim,
                         normalize_on_pack=True, codec=codec)

    # --------------------------------------------------------- slab layout
    def _owner_of_row(self, row: int) -> str:
        return self._keys[row].partition(NS_SEP)[0]

    def _alloc_slab(self, shard: int, owner: str) -> int:
        """Hand ``owner`` a slab on ``shard``: reuse a released slab if
        one exists (its slots are already free + zero-packed), else grow
        the shard's slot space by one slab."""
        owners = self._slab_owner[shard]
        r = self.slab_rows
        j = next((i for i, o in enumerate(owners) if o is None), None)
        if j is None:
            j = len(owners)
            owners.append(owner)
            self._slab_free[shard].append([])
            base = j * r
            self._slots[shard].extend([-1] * r)
            self._free[shard].extend(range(base, base + r))
        else:
            owners[j] = owner
        # canonical allocation order inside the slab (deterministic
        # regardless of the previous owner's release order)
        self._slab_free[shard][j] = list(range((j + 1) * r - 1,
                                               j * r - 1, -1))
        self._owner_slabs[shard].setdefault(owner, []).append(j)
        return j

    def _free_slab(self, shard: int, j: int) -> None:
        owner = self._slab_owner[shard][j]
        self._slab_owner[shard][j] = None
        slabs = self._owner_slabs[shard].get(owner)
        if slabs is not None:
            slabs.remove(j)
            if not slabs:
                del self._owner_slabs[shard][owner]

    def _take_slot(self, shard: int, j: int, row: int) -> int:
        slot = self._slab_free[shard][j].pop()
        self._slots[shard][slot] = row
        self._free[shard].remove(slot)
        return slot

    def _claim_slot(self, shard: int, row: int) -> int:
        owner = self._owner_of_row(row)
        for j in self._owner_slabs[shard].get(owner, ()):
            if self._slab_free[shard][j]:
                return self._take_slot(shard, j, row)
        return self._take_slot(shard, self._alloc_slab(shard, owner), row)

    def _release_row(self, row: int) -> None:
        shard, slot = int(self._row_shard[row]), int(self._row_slot[row])
        super()._release_row(row)
        j = slot // self.slab_rows
        self._slab_free[shard][j].append(slot)
        if len(self._slab_free[shard][j]) == self.slab_rows:
            self._free_slab(shard, j)      # wholly empty -> reusable

    def _reset_layout(self, vecs, keys, alive, enc=None, scales=None) -> None:
        self._slab_owner = [[] for _ in range(self.n_shards)]
        self._slab_free = [[] for _ in range(self.n_shards)]
        self._owner_slabs = [{} for _ in range(self.n_shards)]
        super()._reset_layout(vecs, keys, alive, enc=enc, scales=scales)

    def _maybe_relayout(self) -> None:
        # slab padding is by-design free capacity, not dead weight: the
        # base free-fraction repack would thrash the slab assignment on
        # every pack. Dead slots are reclaimed per tenant by compact()
        # and evict() instead.
        pass

    def _invalidate(self) -> None:
        super()._invalidate()
        self._arena = None
        self._tables.clear()
        self.pack_epoch += 1

    # ---------------------------------------------------- tenant extraction
    def owner_mask(self, tid: str) -> np.ndarray:
        """Bool [T] mask of arena rows (live AND tombstoned) owned by
        ``tid``."""
        pre = tid + NS_SEP
        n = len(self._keys)
        return np.fromiter((k.startswith(pre) for k in self._keys),
                           bool, count=n) if n else np.zeros(0, bool)

    def tenant_rows(self, tid: str):
        """Extract one tenant's canonical state, in the tenant's own
        insertion order, with raw (un-namespaced) keys ->
        (keys, vecs, alive, enc, scales). Includes tombstoned rows: this
        is exactly the state a dedicated single index would persist."""
        idx = np.flatnonzero(self.owner_mask(tid))
        keys = [self._keys[i].partition(NS_SEP)[2] for i in idx]
        d = self.dim or 0
        vecs = (np.ascontiguousarray(self._vecs[idx]) if idx.size
                else np.zeros((0, d), np.float32))
        alive = self._alive[idx].copy() if idx.size else np.zeros(0, bool)
        enc = scales = None
        if self._enc is not None:
            enc = (np.ascontiguousarray(self._enc[idx]) if idx.size
                   else np.zeros((0, d), self.codec.enc_dtype))
        if self._scales is not None:
            scales = (np.ascontiguousarray(self._scales[idx]) if idx.size
                      else np.zeros(0, np.float32))
        return keys, vecs, alive, enc, scales

    def adopt_rows(self, keys: list[str], vecs: np.ndarray,
                   alive: np.ndarray, enc: np.ndarray | None = None,
                   scales: np.ndarray | None = None) -> None:
        """Append restored tenant rows (namespaced keys) preserving the
        canonical encodings — the arena-side half of warm restore. Rows
        arrive in the tenant's stored order; dead rows keep their
        tombstone and own no slot (same as ``_reset_layout``)."""
        vecs = np.asarray(vecs, np.float32)
        alive = np.asarray(alive, bool)
        n = len(keys)
        if n and vecs.shape[1]:
            self._ensure_dim(int(vecs.shape[1]))
        self._vecs = np.concatenate([self._vecs, vecs])
        if self._enc is not None:
            if enc is None:
                raise ValueError(
                    f"{self.codec.name} arena needs encoded rows to adopt")
            self._enc = np.concatenate(
                [self._enc, np.asarray(enc, self.codec.enc_dtype)])
        if self._scales is not None:
            self._scales = np.concatenate(
                [self._scales, np.asarray(scales, np.float32)])
        base = len(self._keys)
        self._keys.extend(keys)
        self._alive = np.concatenate([self._alive, alive])
        shards = np.full(n, -1, np.int32)
        slots = np.full(n, -1, np.int32)
        for j, key in enumerate(keys):
            if not alive[j]:
                continue
            row = base + j
            self._key2row[key] = row
            s = shard_of_key(key, self.n_shards)
            shards[j] = s
            slots[j] = self._claim_slot(s, row)
        self._row_shard = np.concatenate([self._row_shard, shards])
        self._row_slot = np.concatenate([self._row_slot, slots])
        self._invalidate()

    def remove_rows(self, keep: np.ndarray) -> None:
        """Physically drop every row where ``keep`` is False: canonical
        arrays re-pack over the kept rows (fresh buffers — the dropped
        vectors' bytes survive in NO host array) and slab placement is
        re-derived. Eviction and per-tenant compaction both land here."""
        keep = np.asarray(keep, bool)
        vecs = np.ascontiguousarray(self._vecs[keep])
        keys = [k for k, m in zip(self._keys, keep) if m]
        alive = self._alive[keep].copy()
        enc = (np.ascontiguousarray(self._enc[keep])
               if self._enc is not None else None)
        scales = (np.ascontiguousarray(self._scales[keep])
                  if self._scales is not None else None)
        self._reset_layout(vecs, keys, alive, enc=enc, scales=scales)

    # ------------------------------------------------------------- device
    def pack_arena(self):
        """(Re)build the SHARED device blocks over every resident
        tenant's live rows: [S, n_slabs*R, D] (+ [S, RT] gids, + scale
        table for int8), uploaded once per mutation epoch. Free slots —
        including every slot of a released slab — are zero-filled with
        gid -1, which is what makes slab reuse safe. S == 1 keeps plain
        single-device arrays (no mesh)."""
        if self._arena is not None:
            return self._arena
        s_n, r = self.n_shards, self.slab_rows
        nsl = max(max((len(o) for o in self._slab_owner), default=0), 1)
        d = self.dim or 1
        lossy = self.codec.lossy
        rows_src = self._enc if lossy else self._vecs
        blocks = np.zeros((s_n, nsl * r, d), rows_src.dtype)
        gids = np.full((s_n, nsl * r), -1, np.int32)
        scl = (np.zeros((s_n, nsl * r), np.float32)
               if self._scales is not None else None)
        for s in range(s_n):
            table = np.asarray(self._slots[s], np.int64)
            occ = np.flatnonzero(table >= 0)
            if occ.size:
                blocks[s, occ] = rows_src[table[occ]]
                gids[s, occ] = table[occ]
                if scl is not None:
                    scl[s, occ] = self._scales[table[occ]]
        if not lossy and self.normalize_on_pack and self.metric == "cosine":
            blocks = normalize_rows(blocks)
        if s_n == 1:
            self._arena = (None, jnp.asarray(blocks[0]),
                           jnp.asarray(gids[0]),
                           None if scl is None else jnp.asarray(scl[0]))
        else:
            mesh = shard_mesh(s_n)
            if scl is None:
                bl, gi = place_blocks(blocks, gids, mesh)
                sc = None
            else:
                bl, gi, sc = place_blocks(blocks, gids, mesh, scl)
            self._arena = (mesh, bl, gi, sc)
        return self._arena

    def arena_device_bytes(self) -> int:
        """Device bytes of the packed shared arena (blocks + gids +
        scales) — the whole pool's footprint, NOT per tenant."""
        _, bl, gi, sc = self.pack_arena()
        return bl.nbytes + gi.nbytes + (sc.nbytes if sc is not None else 0)

    # ------------------------------------------------------------- search
    def tenant_table(self, tid: str):
        """-> (tbl [S, L] int32 slab ids (-1 pad), L, quantized slack,
        live rows). L is the tenant's per-shard slab count rounded up to
        a power of two, so the compiled search is shared across tenants
        of similar size (the batch-bucket trick, DESIGN.md §6); slack
        bounds the invalid rows per shard (free slots + padding slabs).
        Cached per ``pack_epoch``."""
        ent = self._tables.get(tid)
        if ent is not None and ent[0] == self.pack_epoch:
            return ent[1:]
        s_n, r = self.n_shards, self.slab_rows
        per = [self._owner_slabs[s].get(tid, []) for s in range(s_n)]
        mx = max(len(p) for p in per)
        l_pad = 1 if mx <= 1 else 1 << (mx - 1).bit_length()
        tbl = np.full((s_n, l_pad), -1, np.int32)
        live = 0
        slack = 0
        for s in range(s_n):
            shard_live = 0
            for c, j in enumerate(per[s]):
                tbl[s, c] = j
                shard_live += r - len(self._slab_free[s][j])
            live += shard_live
            slack = max(slack, l_pad * r - shard_live)
        out = (tbl, l_pad, _quantize_slack(slack), live)
        self._tables[tid] = (self.pack_epoch,) + out
        return out

    def tenant_live(self, tid: str) -> int:
        return self.tenant_table(tid)[3]

    def tenant_topk(self, tid: str, queries: np.ndarray, k: int
                    ) -> tuple[np.ndarray, np.ndarray]:
        """Exact top-k over ONE tenant's live rows -> (dists [B, k],
        arena gids [B, k], (INF, -1)-padded). One compiled dispatch; the
        db it scans is the tenant's slabs gathered in-graph, so cost
        scales with the tenant, not the arena."""
        tbl, _, slack, live = self.tenant_table(tid)
        if live == 0:
            raise ValueError("index is empty")
        q = jnp.asarray(np.asarray(queries, np.float32))
        mesh, blocks, gids, scl = self.pack_arena()
        if mesh is None:
            fn = _slab_topk_single(k, slack, self.metric, scl is not None,
                                   self.slab_rows)
            args = (blocks, gids) + (() if scl is None else (scl,)) \
                + (jnp.asarray(tbl[0]), q)
        else:
            fn = _slab_topk_sharded(mesh, k, slack, self.metric,
                                    scl is not None, self.slab_rows)
            args = (blocks, gids) + (() if scl is None else (scl,)) \
                + (jnp.asarray(tbl), q)
        d, g = fn(*args)
        return np.asarray(d), np.asarray(g)

    def multi_topk(self, tables: np.ndarray, queries: np.ndarray, k: int
                   ) -> tuple[np.ndarray, np.ndarray]:
        """Cross-tenant one-dispatch top-k: ``tables`` [S, B, L] carries
        one slab table per query row (rows of DIFFERENT tenants batch
        together when their padded L matches)."""
        q = jnp.asarray(np.asarray(queries, np.float32))
        mesh, blocks, gids, scl = self.pack_arena()
        fn = _slab_topk_multi(mesh, k, self.metric, scl is not None,
                              self.slab_rows)
        tb = jnp.asarray(tables[0] if mesh is None else tables)
        args = (blocks, gids) + (() if scl is None else (scl,)) + (tb, q)
        d, g = fn(*args)
        return np.asarray(d), np.asarray(g)


# ---------------------------------------------------------------------------
# the pool
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class _TenantState:
    epoch: int = 0
    resident: bool = False
    store: object | None = None        # IndexStore | None
    spill: tuple | None = None         # (arrays, meta) when root is None
    since_snapshot: int = 0


class IndexPool:
    """Tenant-aware multiplexer over one shared :class:`SlabRows` arena.

    Public surface mirrors ``VectorIndex`` with a leading ``tenant_id``
    (mutators validate and raise exactly like a dedicated index, and the
    per-tenant ``epoch(tid)`` follows the same bump schedule), plus the
    pool-only verbs: ``evict``/``admit`` (LRU paging against per-tenant
    ``IndexStore`` dirs), ``compact(tid)`` (per-tenant secure delete),
    and ``query_batch_multi`` (one dispatch across tenants).

    root=None keeps evicted tenants in host memory (tests / ephemeral
    pools); with a root, evicted state lives ONLY on disk.
    """

    def __init__(self, root: str | None = None, *, dim: int | None = None,
                 metric: str = "cosine", n_shards: int = 1,
                 dtype: str = "fp32", rerank_factor: int | None = None,
                 max_resident: int = 64, slab_rows: int = 64,
                 snapshot_every: int | None = None):
        if metric not in ("cosine", "ip", "l2"):
            raise ValueError(f"unknown metric {metric!r}")
        if max_resident < 1:
            raise ValueError(f"max_resident must be >= 1, got {max_resident}")
        self.root = str(root) if root is not None else None
        self.metric = metric
        self.dim = dim
        self.n_shards = int(n_shards)
        self.dtype = str(dtype)
        self.rerank_factor = rerank_factor
        self.max_resident = int(max_resident)
        self.slab_rows = int(slab_rows)
        self.snapshot_every = snapshot_every
        self._codec = get_codec(self.dtype)
        self._arena = SlabRows(slab_rows=self.slab_rows,
                               n_shards=self.n_shards, metric=metric,
                               dim=dim, codec=self._codec)
        self._tenants: dict[str, _TenantState] = {}
        self._resident: "collections.OrderedDict[str, None]" = \
            collections.OrderedDict()
        self._epoch = 0                       # pool-global (engine compat)
        self.stats = {"admissions": 0, "evictions": 0, "snapshots": 0}

    # ----------------------------------------------------------- identity
    @property
    def mutation_epoch(self) -> int:
        """Pool-global mutation counter (sum of all tenants' mutations) —
        the coarse signal non-tenant-aware consumers key on. Tenant-aware
        caches use :meth:`epoch` instead."""
        return self._epoch

    @property
    def shard_count(self) -> int:
        return self.n_shards

    @property
    def storage_dtype(self) -> str:
        return self.dtype

    def epoch(self, tid: str) -> int:
        """Per-tenant mutation epoch — same bump schedule as a dedicated
        index (+1 per insert/update/delete, +1 per bulk batch, +1 per
        compact), durable across evict/restore. KeyError for a tenant
        the pool has never seen."""
        t = self._tenants.get(tid)
        if t is None:
            raise KeyError(tid)
        return t.epoch

    def tenants(self) -> list[str]:
        return list(self._tenants)

    def resident_tenants(self) -> list[str]:
        return list(self._resident)

    # ---------------------------------------------------------- residency
    def _validate_id(self, s: str, what: str) -> None:
        if not isinstance(s, str) or not s or NS_SEP in s:
            raise ValueError(f"invalid {what}: {s!r} (non-empty string "
                             "without the namespace separator)")

    def _tenant_dir(self, tid: str) -> str:
        return os.path.join(self.root, "tenants",
                            urllib.parse.quote(tid, safe=""))

    def _touch(self, tid: str) -> None:
        self._resident[tid] = None
        self._resident.move_to_end(tid)

    def _empty_adapter(self):
        return FlatVectorIndex(metric=self.metric,
                               dim=self.dim or self._arena.dim, n_shards=1,
                               dtype=self.dtype,
                               rerank_factor=self.rerank_factor)

    def _adapter(self, tid: str, t: _TenantState) -> FlatVectorIndex:
        """The tenant's state as a real ``FlatVectorIndex`` — what the
        store snapshots/attaches. Bit-for-bit the index a never-pooled
        tenant would have: same canonical arrays (tenant insertion
        order, tombstones included), same epoch, same config."""
        fv = self._empty_adapter()
        keys, vecs, alive, enc, scales = self._arena.tenant_rows(tid)
        if keys:
            if self._codec.lossy:
                arrays = {"vectors_enc": self._codec.to_storage(enc),
                          "alive": alive}
                if scales is not None:
                    arrays["scales"] = scales
            else:
                arrays = {"vectors": vecs, "alive": alive}
            fv.restore_state(arrays, {"keys": keys, "epoch": t.epoch})
        else:
            fv._epoch = t.epoch
        return fv

    def _ensure_resident(self, tid: str, create: bool = False
                         ) -> _TenantState:
        self._validate_id(tid, "tenant id")
        t = self._tenants.get(tid)
        if t is None:
            store = None
            if self.root is not None:
                from repro.store import IndexStore
                store = IndexStore(self._tenant_dir(tid),
                                   page_bytes=4 << 20)
                if store.has_state():
                    t = _TenantState(store=store)
                    self._tenants[tid] = t
                    return self._admit(tid, t)
            if not create:
                raise KeyError(tid)
            t = _TenantState(store=store, resident=True)
            if store is not None:
                store.attach(self._empty_adapter())   # config.json now:
                # WAL-only restore needs it before any record replays
            self._tenants[tid] = t
            self._make_room(exclude=tid)
            self._touch(tid)
            return t
        if not t.resident:
            return self._admit(tid, t)
        self._touch(tid)
        return t

    def _make_room(self, exclude: str) -> None:
        while len(self._resident) >= self.max_resident:
            victim = next(t for t in self._resident if t != exclude)
            self.evict(victim)

    def _admit(self, tid: str, t: _TenantState) -> _TenantState:
        """Page a tenant back into the arena: bit-for-bit warm restore
        (snapshot + WAL replay via the store, DESIGN.md §7) adopted into
        fresh slabs."""
        self._make_room(exclude=tid)
        arrays = meta = None
        if t.store is not None and t.store.has_state():
            fv = t.store.load_index(expect_kind="flat")
            arrays, meta = fv.state_dict()
        elif t.spill is not None:
            arrays, meta = t.spill
        if arrays is not None and len(meta["keys"]):
            nskeys = [tenant_key(tid, k) for k in meta["keys"]]
            alive = np.asarray(arrays["alive"], bool)
            if self._codec.lossy:
                enc = self._codec.from_storage(arrays["vectors_enc"])
                scales = arrays.get("scales")
                vecs = self._codec.decode(enc, scales)
            else:
                enc = scales = None
                vecs = np.asarray(arrays["vectors"], np.float32)
            self._arena.adopt_rows(nskeys, vecs, alive, enc=enc,
                                   scales=scales)
            self.dim = self.dim or self._arena.dim
        if meta is not None:
            t.epoch = int(meta["epoch"])
        t.spill = None
        t.resident = True
        self._touch(tid)
        self.stats["admissions"] += 1
        return t

    def admit(self, tid: str) -> None:
        """Explicitly page a tenant in (queries/mutations do it
        implicitly)."""
        self._ensure_resident(tid)

    def evict(self, tid: str) -> None:
        """Page a tenant out: snapshot its state to the per-tenant store
        (or host spill), physically remove its rows from the arena
        (canonical arrays re-packed, freed slabs returned to the pool),
        and drop every derived device structure (the ``_drop_derived``
        residency contract — no stale block may outlive residency)."""
        t = self._tenants.get(tid)
        if t is None:
            raise KeyError(tid)
        if not t.resident:
            return
        self._snapshot_tenant(tid, t)
        self._arena.remove_rows(~self._arena.owner_mask(tid))
        self._drop_derived()
        t.resident = False
        self._resident.pop(tid, None)
        self.stats["evictions"] += 1

    def _snapshot_tenant(self, tid: str, t: _TenantState) -> None:
        fv = self._adapter(tid, t)
        if t.store is not None:
            t.store.snapshot(fv)
            t.since_snapshot = 0
            self.stats["snapshots"] += 1
        else:
            t.spill = fv.state_dict()

    def flush(self) -> None:
        """Snapshot every resident tenant (shutdown durability)."""
        for tid in list(self._resident):
            self._snapshot_tenant(tid, self._tenants[tid])

    def _drop_derived(self) -> None:
        """Invalidate every device-derived structure: packed arena
        blocks, gid maps, scale tables, and per-tenant slab tables.
        Called on evict (and implicitly by every arena mutation via
        ``_invalidate``)."""
        self._arena._invalidate()

    # ------------------------------------------------------------ mutation
    def _wal(self, t: _TenantState, op: str, meta: dict,
             arrays: dict | None = None) -> None:
        if t.store is not None:
            t.store.wal_append(op, epoch=t.epoch, meta=meta, arrays=arrays)

    def _finish_mutation(self, tid: str, t: _TenantState) -> None:
        t.epoch += 1
        self._epoch += 1
        t.since_snapshot += 1
        if (self.snapshot_every is not None
                and t.since_snapshot >= self.snapshot_every):
            self._snapshot_tenant(tid, t)

    def insert(self, tid: str, key: str, value) -> None:
        """Upsert one (key, vector) into a tenant's namespace."""
        self._validate_id(key, "key")
        t = self._ensure_resident(tid, create=True)
        v = np.asarray(value, np.float32)
        self._wal(t, "insert", {"key": key}, {"vec": v})
        self._arena.upsert(tenant_key(tid, key), v.reshape(-1))
        self.dim = self.dim or self._arena.dim
        self._finish_mutation(tid, t)

    def bulk_insert(self, tid: str, keys, values) -> None:
        """Batched upsert — ONE WAL record, last-wins on in-batch
        duplicates (same collapse the ``VectorIndex`` template does)."""
        values = np.asarray(values, np.float32)
        if len(keys) != len(values):
            raise ValueError("keys/values length mismatch")
        keys = list(keys)
        for k in keys:
            self._validate_id(k, "key")
        if len(set(keys)) != len(keys):
            last: dict = {}
            for i, k in enumerate(keys):
                last[k] = i
            keep = sorted(last.values())
            keys = [keys[i] for i in keep]
            values = values[keep]
        t = self._ensure_resident(tid, create=True)
        self._wal(t, "bulk_insert", {"keys": keys}, {"vec": values})
        self._arena.upsert_many([tenant_key(tid, k) for k in keys], values)
        self.dim = self.dim or self._arena.dim
        self._finish_mutation(tid, t)

    def update(self, tid: str, key: str, value) -> None:
        """Replace an existing key's vector. KeyError if absent."""
        t = self._ensure_resident(tid, create=True)
        if not self._arena.contains(tenant_key(tid, key)):
            raise KeyError(key)
        v = np.asarray(value, np.float32)
        self._wal(t, "update", {"key": key}, {"vec": v})
        self._arena.upsert(tenant_key(tid, key), v.reshape(-1))
        self._finish_mutation(tid, t)

    def delete(self, tid: str, key: str) -> None:
        """Soft-delete one key: never returned again, and only THIS
        tenant's epoch bumps (other tenants' caches stay valid)."""
        t = self._ensure_resident(tid)
        if not self._arena.contains(tenant_key(tid, key)):
            raise KeyError(key)
        self._wal(t, "delete", {"key": key})
        self._arena.tombstone(tenant_key(tid, key))
        self._finish_mutation(tid, t)

    def compact(self, tid: str) -> None:
        """Per-tenant secure delete (DESIGN.md §7, scoped): physically
        drop the tenant's tombstoned rows from the host arrays and the
        shared device blocks, publish a fresh snapshot of the compacted
        state, truncate the WAL (old records held the deleted vectors'
        insert payloads), and purge every older snapshot. After this the
        deleted rows' bytes — fp32, encoded, and scales — exist in no
        arena buffer, no slab, no page, and no WAL. Other tenants are
        untouched (their epochs do not move)."""
        t = self._ensure_resident(tid)
        dead = self._arena.owner_mask(tid) & ~self._arena.alive
        if dead.any():
            self._arena.remove_rows(~dead)
        t.epoch += 1                       # same bump a dedicated compact has
        self._epoch += 1
        t.since_snapshot = 0
        if t.store is not None:
            t.store.on_compact(self._adapter(tid, t))
        elif t.spill is not None:
            t.spill = None                 # spilled pre-compact state dies too

    # --------------------------------------------------------------- query
    def query_batch(self, tid: str, queries, k: int = 10, **kw):
        """One tenant, one dispatch: [B, D] -> (keys, dists) with the
        ``VectorIndex`` shape contract (None / INF padding). Under a
        lossy codec the slab scan is asymmetric, over-fetches
        ``k·rerank_factor``, and reranks exactly in fp32 from the
        canonical host rows (DESIGN.md §9)."""
        t = self._ensure_resident(tid)
        q = np.asarray(queries, np.float32)
        if q.ndim != 2:
            raise ValueError(f"query_batch expects [B, D], got {q.shape}")
        rf = effective_rerank(self._codec, self.rerank_factor)
        if rf <= 1:
            d, rows = self._arena.tenant_topk(tid, q, k)
        else:
            _, cand = self._arena.tenant_topk(tid, q, k * rf)
            d, rows = self._arena.rerank_topk(q, cand, k)
        return self._rows_to_keys(rows, d, k)

    def query(self, tid: str, query, k: int = 10, **kw):
        q = np.asarray(query, np.float32)
        if q.ndim == 1:
            keys, d = self.query_batch(tid, q[None], k, **kw)
            return keys[0], d[0]
        return self.query_batch(tid, q, k, **kw)

    def query_batch_multi(self, queries, tenants, k: int = 10, **kw):
        """ONE logical dispatch for a batch whose rows belong to
        DIFFERENT tenants (the serving layer's cross-tenant tick,
        DESIGN.md §6): rows group by their tenant's padded slab width L —
        a group of one tenant runs the fused single-tenant kernel, a
        mixed group runs the per-query-gather path — and results come
        back in input order."""
        q = np.asarray(queries, np.float32)
        if q.ndim != 2:
            raise ValueError(f"query_batch_multi expects [B, D], "
                             f"got {q.shape}")
        tenants = list(tenants)
        if len(tenants) != q.shape[0]:
            raise ValueError("queries/tenants length mismatch")
        uniq = list(dict.fromkeys(tenants))
        if len(uniq) > self.max_resident:
            # more distinct tenants than can be co-resident: split the
            # tick into sub-batches of <= max_resident tenants and let
            # the LRU page between them — results stitch back in input
            # order, so callers never see the split
            out_keys: list = [None] * len(tenants)
            out_dists = [None] * len(tenants)
            for j in range(0, len(uniq), self.max_resident):
                grp = set(uniq[j:j + self.max_resident])
                idx = [i for i, t in enumerate(tenants) if t in grp]
                gk, gd = self.query_batch_multi(
                    q[idx], [tenants[i] for i in idx], k, **kw)
                gd = np.asarray(gd)
                for p, i in enumerate(idx):
                    out_keys[i] = gk[p]
                    out_dists[i] = gd[p]
            return out_keys, np.stack(out_dists)
        for tid in uniq:
            self._ensure_resident(tid)
        rf = effective_rerank(self._codec, self.rerank_factor)
        kk = k * rf if rf > 1 else k
        b = q.shape[0]
        out_d = np.full((b, kk), INF, np.float32)
        out_g = np.full((b, kk), -1, np.int64)
        # group rows by padded slab width; empty tenants raise like a
        # dedicated empty index would
        by_l: dict[int, list[int]] = {}
        for i, tid in enumerate(tenants):
            _, l_pad, _, live = self._arena.tenant_table(tid)
            if live == 0:
                raise ValueError("index is empty")
            by_l.setdefault(l_pad, []).append(i)
        for l_pad, rows_idx in by_l.items():
            g_tenants = [tenants[i] for i in rows_idx]
            g_q = q[rows_idx]
            if len(set(g_tenants)) == 1:
                d, g = self._arena.tenant_topk(g_tenants[0], g_q, kk)
            else:
                tables = np.stack(
                    [self._arena.tenant_table(tid)[0]
                     for tid in g_tenants], axis=1)        # [S, B_g, L]
                d, g = self._arena.multi_topk(tables, g_q, kk)
            out_d[rows_idx] = d
            out_g[rows_idx] = g
        if rf > 1:
            out_d, out_g = self._arena.rerank_topk(q, out_g, k)
        return self._rows_to_keys(out_g, out_d, k)

    def _rows_to_keys(self, rows: np.ndarray, d: np.ndarray, k: int):
        keys = [[split_tenant_key(self._arena.key_of_row(int(r)))[1]
                 if r >= 0 else None for r in row] for row in rows]
        d = np.asarray(d)
        keys = [row_k[:k] for row_k in keys]
        return _pad_results(keys, d[:, :k], k)

    # ----------------------------------------------------------- introspect
    def size(self, tid: str) -> int:
        """Live keys of one tenant (pages it in if needed)."""
        self._ensure_resident(tid)
        return self._arena.tenant_live(tid)

    def contains(self, tid: str, key: str) -> bool:
        try:
            self._ensure_resident(tid)
        except KeyError:
            return False
        return self._arena.contains(tenant_key(tid, key))

    def keys(self, tid: str) -> list[str]:
        """One tenant's live keys in insertion order."""
        self._ensure_resident(tid)
        pre = tid + NS_SEP
        return [k.partition(NS_SEP)[2]
                for i, k in enumerate(self._arena.key_list)
                if self._arena.alive[i] and k.startswith(pre)]

    def pool_stats(self) -> dict:
        """Occupancy + paging counters (logging / bench)."""
        arena = self._arena
        slabs = sum(len(o) for o in arena._slab_owner)
        owned = sum(sum(o is not None for o in sh)
                    for sh in arena._slab_owner)
        return {**self.stats, "tenants": len(self._tenants),
                "resident": len(self._resident),
                "arena_rows": arena.row_count, "arena_live": arena.size,
                "slabs": slabs, "slabs_owned": owned,
                "slab_rows": self.slab_rows,
                "arena_bytes": arena.arena_device_bytes()}
