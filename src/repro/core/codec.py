"""VectorCodec — pluggable row storage for every layer of the index
(DESIGN.md §9).

MeMemo's binding constraint is bytes, not FLOPs: the browser setting caps
how large a private corpus can live on-device (paper §5, C2/C3), and a
float32 row path makes every vector cost ``4·D`` bytes in device blocks
AND in snapshot pages. The codec layer makes the storage dtype a
first-class, centrally-owned abstraction:

  * ``fp32``  — identity. Bit-for-bit the historical path everywhere
    (the pre-codec test suite is its parity oracle).
  * ``bf16``  — truncated mantissa, 2 bytes/dim, no side table.
  * ``int8``  — scalar quantization with ONE fp32 scale per row
    (``scale = max|x| / 127``, symmetric): 1 byte/dim + 4 bytes/row.

Dataflow contract (quantize-at-ingest):

  * the ENCODED array is canonical. A lossy index encodes each row once,
    at ingest (after any metric normalization), and keeps both the
    encoded bytes and their fp32 decode as parallel host state — the
    fp32 side stays insertion-ordered, so shard routing, resharding, and
    WAL replay semantics are untouched (DESIGN.md §8).
  * device blocks and snapshot pages hold the encoded bytes + scales
    (the ≈4x memory/disk win); searches compute ASYMMETRIC distance —
    fp32 query against encoded rows, scales fused into the kernel,
    fp32 accumulation (kernels/distance_topk.py, gather_distance.py).
  * because the encoded array is canonical (never re-derived by a
    second encode), snapshot -> restore -> snapshot is bit-stable and a
    restored index equals the live one byte for byte, per codec.
  * secure delete must erase BOTH representations: compaction drops a
    deleted row's encoded bytes and its fp32 decode from every host
    array, device block, and store page (DESIGN.md §7/§9).

ANN search under a lossy codec over-fetches ``k · rerank_factor``
candidates and re-scores them exactly in fp32 from the canonical host
rows (:func:`rerank_exact`), then returns the best k — widening the
candidate set the quantized first pass hands to the exact re-scorer.
"""
from __future__ import annotations

import numpy as np

try:                                    # jax's own dtype package; always
    import ml_dtypes                    # present alongside jax, but gate
    _BF16 = np.dtype(ml_dtypes.bfloat16)   # anyway (bf16 codec degrades
except Exception:                       # to unavailable, not ImportError
    ml_dtypes = None
    _BF16 = None

INF = np.float32(3e38)

CODEC_NAMES = ("fp32", "bf16", "int8")


class VectorCodec:
    """One row-storage format: encode/decode + storage/device dtypes.

    ``name``            factory name ("fp32" | "bf16" | "int8")
    ``lossy``           False only for fp32 — lossless codecs skip the
                        encoded side arrays entirely and keep the
                        historical fp32 path bit-for-bit
    ``uses_scales``     True when rows carry a per-row fp32 scale
    ``enc_dtype``       numpy dtype of the encoded array
    ``default_rerank``  over-fetch factor for ANN search (k·factor
                        candidates, exact fp32 rerank)
    """

    name: str = "fp32"
    lossy: bool = False
    uses_scales: bool = False
    default_rerank: int = 1
    enc_dtype = np.dtype(np.float32)

    # ------------------------------------------------------------ encode
    def encode(self, x: np.ndarray) -> tuple[np.ndarray, np.ndarray | None]:
        """fp32 rows [..., D] -> (encoded rows, per-row scales or None)."""
        return np.ascontiguousarray(x, np.float32), None

    def decode(self, enc: np.ndarray,
               scales: np.ndarray | None = None) -> np.ndarray:
        """Inverse of :meth:`encode` -> fp32 rows."""
        return np.asarray(enc, np.float32)

    def roundtrip(self, x: np.ndarray) -> np.ndarray:
        return self.decode(*self.encode(x))

    # ----------------------------------------------------------- storage
    # Snapshot pages / npz exports only hold builtin numpy dtypes (a
    # bfloat16 array silently loses its dtype through np.save), so the
    # on-disk view goes through these two hooks.
    def to_storage(self, enc: np.ndarray) -> np.ndarray:
        return enc

    def from_storage(self, arr: np.ndarray) -> np.ndarray:
        return np.asarray(arr, self.enc_dtype)

    # ------------------------------------------------------------- sizes
    def bytes_per_vector(self, dim: int) -> int:
        """Encoded bytes per row (scale included when the codec has one)."""
        return dim * self.enc_dtype.itemsize + (4 if self.uses_scales else 0)


class Bf16Codec(VectorCodec):
    name = "bf16"
    lossy = True
    uses_scales = False
    default_rerank = 1

    def __init__(self):
        if _BF16 is None:
            raise RuntimeError("bf16 codec needs ml_dtypes (ships with jax)")
        self.enc_dtype = _BF16

    def encode(self, x):
        return np.ascontiguousarray(x, np.float32).astype(self.enc_dtype), None

    def decode(self, enc, scales=None):
        return np.asarray(enc).astype(np.float32)

    def to_storage(self, enc):
        # uint16 bit-view: np.save round-trips it losslessly
        return np.asarray(enc, self.enc_dtype).view(np.uint16)

    def from_storage(self, arr):
        return np.asarray(arr, np.uint16).view(self.enc_dtype)


class Int8Codec(VectorCodec):
    """Symmetric scalar quantization, one fp32 scale per row:
    ``scale = max|x| / 127``, ``enc = round(x / scale)`` in [-127, 127].
    All-zero rows get scale 1.0 so decode stays a plain multiply."""

    name = "int8"
    lossy = True
    uses_scales = True
    default_rerank = 4
    enc_dtype = np.dtype(np.int8)

    def encode(self, x):
        x = np.ascontiguousarray(x, np.float32)
        amax = np.max(np.abs(x), axis=-1)
        scales = np.where(amax > 0, amax / np.float32(127.0),
                          np.float32(1.0)).astype(np.float32)
        q = np.clip(np.rint(x / scales[..., None]), -127, 127)
        return q.astype(np.int8), scales

    def decode(self, enc, scales=None):
        if scales is None:
            raise ValueError("int8 decode needs the per-row scales")
        return (np.asarray(enc, np.float32)
                * np.asarray(scales, np.float32)[..., None])


_CODECS: dict[str, VectorCodec] = {}


def get_codec(name: str) -> VectorCodec:
    """Codec by name ("fp32" | "bf16" | "int8"); instances are shared."""
    key = str(name).lower()
    if key not in CODEC_NAMES:
        raise ValueError(f"unknown storage dtype {name!r}; expected one of "
                         f"{CODEC_NAMES}")
    if key not in _CODECS:
        _CODECS[key] = {"fp32": VectorCodec, "bf16": Bf16Codec,
                        "int8": Int8Codec}[key]()
    return _CODECS[key]


def effective_rerank(codec: VectorCodec, rerank_factor: int | None) -> int:
    """The over-fetch factor a backend should use: the configured value,
    else the codec default. Lossless codecs never rerank (factor 1) —
    the first pass already IS the exact fp32 search."""
    if not codec.lossy:
        return 1
    rf = rerank_factor if rerank_factor is not None else codec.default_rerank
    return max(int(rf), 1)


def check_codec_arrays(codec: VectorCodec, arrays: dict, kind: str) -> None:
    """Cross-dtype restore guard (DESIGN.md §9): encoded pages cannot be
    transcoded, so an index restoring state written under a different
    storage dtype must fail loudly and helpfully, not with a KeyError."""
    has_enc = any(name.split("__")[-1] == "vectors_enc" for name in arrays)
    if codec.lossy and not has_enc and arrays:
        raise ValueError(
            f"cannot restore a {kind!r} index as dtype={codec.name!r}: the "
            "stored state holds fp32 rows. Storage dtype is part of the "
            "stored bytes — restore with dtype='fp32', or re-ingest the "
            f"corpus into a fresh {codec.name} store.")
    if not codec.lossy and has_enc:
        raise ValueError(
            f"cannot restore a {kind!r} index as dtype='fp32': the stored "
            "state holds codec-encoded rows (bf16/int8 pages cannot be "
            "transcoded back). Restore with the dtype the store records "
            "in config.json, or re-ingest into a fresh fp32 store.")


def rerank_exact(vectors: np.ndarray, queries: np.ndarray, ids: np.ndarray,
                 k: int, *, metric: str) -> tuple[np.ndarray, np.ndarray]:
    """Exact fp32 re-scoring of over-fetched ANN candidates.

    vectors [N, D] — the canonical host rows, fp32, already metric-
    normalized where the backend stores them normalized (cosine);
    queries [B, D] raw (normalized here for cosine); ids [B, KK] with -1
    marking missing candidates -> (dists [B, k], ids [B, k]), missing
    slots (INF, -1). Ties break on the smaller id, mirroring the device
    merge's ``tie_break_ids`` (DESIGN.md §8).
    """
    q = np.asarray(queries, np.float32)
    if metric == "cosine":
        q = q / np.maximum(np.linalg.norm(q, axis=-1, keepdims=True), 1e-12)
    b = q.shape[0]
    out_d = np.full((b, k), INF, np.float32)
    out_i = np.full((b, k), -1, np.int64)
    ids = np.asarray(ids)
    for row in range(b):
        cand = np.unique(ids[row][ids[row] >= 0]).astype(np.int64)
        if cand.size == 0:
            continue
        x = np.asarray(vectors, np.float32)[cand]
        if metric in ("cosine", "ip"):
            d = np.float32(1.0) - x @ q[row]
        else:
            diff = x - q[row][None, :]
            d = np.einsum("kd,kd->k", diff, diff)
        d = d.astype(np.float32)
        order = np.lexsort((cand, d))[:k]
        out_d[row, : order.size] = d[order]
        out_i[row, : order.size] = cand[order]
    return out_d, out_i
