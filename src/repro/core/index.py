"""The unified mutable retrieval layer: one ``VectorIndex`` protocol for
every ANN backend (flat / IVF / HNSW / tiered).

MeMemo's core promise is an *updatable* private knowledge base on-device:
users add, correct, and retract personal documents, and the serving layer
must not care which index structure sits underneath. Every backend
implements the same keyed CRUD + query contract:

    idx = make_index("hnsw", dim=384, metric="cosine")
    idx.bulk_insert(keys, vectors)
    idx.insert("doc-1", vec)            # single upsert
    idx.update("doc-1", new_vec)        # re-embed in place
    idx.delete("doc-0")                 # retract (tombstone, never returned)
    keys, dists = idx.query(q, k=10)    # ANN search
    keys, dists = idx.query_batch(Q, k) # batched ANN: [B,D] -> lists of lists
    keys, dists = idx.exact_query(q, k) # brute-force oracle, same live set
    idx.export(path); Idx.load(path)    # one-file persistence (state_dict)
    idx.mutation_epoch                  # bumped by every mutation (caching)

Design notes (DESIGN.md §1):
  * keys are caller-owned strings; inserting an existing key is an update;
  * ``delete`` is a soft delete everywhere — backends keep fixed device
    shapes and exclude tombstoned rows from results (HNSW keeps them
    traversable, hnswlib-style; see DESIGN.md §3); ``compact()`` is the
    physical complement: it drops tombstoned rows for real (DESIGN.md §7);
  * ``size`` counts live (non-deleted) keys;
  * ``query``/``exact_query`` return ``(keys, dists)``; batched queries
    return lists of lists. Missing slots (k > live) come back as ``None``;
  * ``query_batch`` is the serving-layer entry point: input is always
    [B, D], output is always batched (lists of lists), even at B=1 — no
    squeeze ambiguity. All four backends run it as ONE device dispatch
    (tiered, whose search is the host-side accounting model, loops);
  * every mutation bumps ``mutation_epoch``. The epoch is what lets a
    result cache (serve/retrieval.py) guarantee a retracted document is
    never served from a stale entry — the privacy property (DESIGN.md §6).

Persistence (DESIGN.md §7): the public mutators here are TEMPLATE
methods — they validate, write-ahead-log to an attached ``IndexStore``
(repro.store), then call the backend's ``_*_impl``. Backends therefore
implement ``_insert_impl``/``_update_impl``/``_delete_impl``/
``_bulk_insert_impl`` plus a uniform serialization triple
(``config_dict``/``state_dict``/``restore_state``) that snapshots, WAL
replay, and the one-file ``export``/``load`` are all built on.
"""
from __future__ import annotations

import abc
import json
import os
from typing import Sequence

import numpy as np

_STATE_FORMAT_VERSION = 1
_ARR_PREFIX = "arr_"


class VectorIndex(abc.ABC):
    """Keyed, mutable ANN index. All four backends implement this."""

    kind: str                  # factory name: "flat" | "ivf" | "hnsw" | ...
    metric: str
    _epoch: int = 0            # mutation counter; instance attr on first bump
    _store = None              # IndexStore when attached (repro.store)

    # -------------------------------------------------------------- shards
    @property
    def shard_count(self) -> int:
        """Number of mesh shards the corpus is partitioned over
        (DESIGN.md §8). 1 = the single-device layout. Backends that
        accept ``n_shards`` override this; key->shard routing is
        ``repro.core.sharded.shard_of_key`` everywhere."""
        return 1

    # --------------------------------------------------------------- codec
    @property
    def storage_dtype(self) -> str:
        """Row-storage codec name (DESIGN.md §9): "fp32" | "bf16" |
        "int8". Backends that accept ``dtype=`` set it; the serving layer
        is codec-transparent and only surfaces this for logging/stats."""
        return getattr(self, "dtype", "fp32")

    # -------------------------------------------------------------- epoch
    @property
    def mutation_epoch(self) -> int:
        """Monotonic counter bumped by every insert/update/delete.

        Consumers that cache query results key their validity on this
        value: any mutation — in particular ``delete``, the privacy
        operation — invalidates everything cached under the old epoch.
        The epoch is persisted by snapshots and WAL records, so a
        warm-restored index resumes at the exact epoch the live one died
        at (DESIGN.md §7) and epoch-keyed invariants survive restarts.
        """
        return self._epoch

    def _bump_epoch(self) -> None:
        self._epoch = self._epoch + 1

    # --------------------------------------------------- store integration
    def _log_mutation(self, op: str, meta: dict,
                      arrays: dict | None = None) -> None:
        """Append one WAL record BEFORE the mutation touches index state.
        No-op when no store is attached. The record carries the epoch
        *before* the op, which is how replay skips records a snapshot
        already covers (repro.store.store). An op that raises AFTER its
        record landed is replayed the same way: the deterministic impl
        raises identically, replay skips the record, and the epoch chain
        of the following records confirms nothing was applied."""
        if self._store is not None:
            self._store.wal_append(op, epoch=self._epoch, meta=meta,
                                   arrays=arrays)

    def _notify_store(self) -> None:
        """After a mutation applied: drive the store's snapshot_every
        policy."""
        if self._store is not None:
            self._store.notify_mutation(self)

    def _apply_derived(self, op: str, meta: dict, arrays: dict) -> None:
        """Replay hook for ``derived.*`` WAL records — derived state a
        backend trains outside the mutation path but that queries depend
        on (IVF centroids). Backends with such state override this."""
        raise ValueError(f"{type(self).__name__} cannot replay {op!r}")

    # ------------------------------------------------------------ mutation
    # Public mutators are final template methods: validate -> WAL ->
    # _*_impl -> notify. Backends implement the _*_impl layer and MUST NOT
    # log or notify there (replay re-enters through the impls).
    def insert(self, key: str, value: Sequence[float]) -> None:
        """Upsert one (key, vector) pair."""
        v = np.asarray(value, np.float32)
        self._log_mutation("insert", {"key": key}, {"vec": v})
        self._insert_impl(key, v)
        self._notify_store()

    def bulk_insert(self, keys: Sequence[str], values) -> None:
        """Batched upsert (paper C3) — ONE WAL record for the whole batch.

        A key repeated WITHIN the batch collapses last-wins BEFORE the
        batch is logged or applied: an upsert sequence must leave exactly
        one live row per key, and the backends' batch fast paths (HNSW
        bulk-build adoption, the sharded block append) assume unique keys
        — without the collapse they leave ghost rows that ``delete``
        cannot retract."""
        values = np.asarray(values, np.float32)
        if len(keys) != len(values):
            raise ValueError("keys/values length mismatch")
        keys = list(keys)
        if len(set(keys)) != len(keys):
            last: dict = {}
            for i, k in enumerate(keys):
                last[k] = i
            keep = sorted(last.values())
            keys = [keys[i] for i in keep]
            values = values[keep]
        self._log_mutation("bulk_insert", {"keys": keys}, {"vec": values})
        self._bulk_insert_impl(keys, values)
        self._notify_store()

    def update(self, key: str, value: Sequence[float]) -> None:
        """Replace the vector of an existing key. KeyError if absent."""
        if not self._contains(key):
            raise KeyError(key)
        v = np.asarray(value, np.float32)
        self._log_mutation("update", {"key": key}, {"vec": v})
        self._update_impl(key, v)
        self._notify_store()

    def delete(self, key: str) -> None:
        """Soft-delete a key: never returned again. KeyError if absent."""
        if not self._contains(key):
            raise KeyError(key)
        self._log_mutation("delete", {"key": key})
        self._delete_impl(key)
        self._notify_store()

    @abc.abstractmethod
    def _insert_impl(self, key: str, value: np.ndarray) -> None: ...

    def _bulk_insert_impl(self, keys: list[str], values: np.ndarray) -> None:
        for k, v in zip(keys, values):
            self._insert_impl(k, v)

    @abc.abstractmethod
    def _update_impl(self, key: str, value: np.ndarray) -> None: ...

    @abc.abstractmethod
    def _delete_impl(self, key: str) -> None: ...

    def compact(self) -> None:
        """Physically drop tombstoned rows and bump the epoch (so
        epoch-keyed caches invalidate). Compaction is NOT WAL-logged —
        on a store-attached index the store immediately publishes a
        fresh snapshot of the compacted state, truncates the WAL, and
        purges old snapshots (the secure-delete contract, DESIGN.md §7).
        That hook also keeps restore sound: without it the epoch bumps
        would leave a gap the WAL cannot replay across."""
        self._compact_impl()
        if self._store is not None:
            self._store.on_compact(self)

    @abc.abstractmethod
    def _compact_impl(self) -> None: ...

    # --------------------------------------------------------------- query
    def query(self, query, k: int = 10, **kw):
        """ANN top-k -> (keys, dists); a 1-D query returns one row, a
        [B, D] batch returns lists of lists. Thin squeeze wrapper over
        :meth:`query_batch` — shared by every backend."""
        q = np.asarray(query, np.float32)
        if q.ndim == 1:
            keys, d = self.query_batch(q[None], k, **kw)
            return keys[0], d[0]
        return self.query_batch(q, k, **kw)

    @abc.abstractmethod
    def query_batch(self, queries, k: int = 10, **kw):
        """Batched ANN search: queries [B, D] -> (keys, dists) where keys
        is a list of B lists of k key-or-None and dists is [B, k].

        Unlike ``query``, the result is batched even for B=1 — this is the
        shape contract the serving layer (RetrievalEngine) relies on.
        Implementations raise ValueError on non-2-D input and run the
        whole batch as one device dispatch where the backend allows.
        """

    @abc.abstractmethod
    def exact_query(self, query, k: int = 10):
        """Brute-force top-k over the same live vectors -> (keys, dists)."""

    # --------------------------------------------------------- persistence
    # All persistence — one-file export/load here, chunked snapshots and
    # WAL replay in repro.store — is built on one uniform serialization
    # triple every backend implements (DESIGN.md §7):
    #   config_dict()   -> kwargs that recreate an EMPTY index via
    #                      make_index(self.kind, **cfg)
    #   state_dict()    -> (arrays, meta): full mutation-determined host
    #                      state — vectors, tombstones, graph tables,
    #                      keys, epoch, RNG state (HNSW)
    #   restore_state() -> inverse of state_dict on a fresh instance
    @abc.abstractmethod
    def config_dict(self) -> dict: ...

    @abc.abstractmethod
    def state_dict(self) -> tuple[dict, dict]: ...

    @abc.abstractmethod
    def restore_state(self, arrays: dict, meta: dict) -> None: ...

    @abc.abstractmethod
    def _row_count(self) -> int:
        """Total rows ever inserted, INCLUDING tombstoned ones."""

    def export(self, path: str) -> None:
        """Write the whole index to one npz (vectors, keys, tombstones,
        epoch — everything ``state_dict`` captures), atomically."""
        if self._row_count() == 0:
            raise ValueError("index is empty")
        arrays, meta = self.state_dict()
        head = {"format_version": _STATE_FORMAT_VERSION, "kind": self.kind,
                "config": self.config_dict(), "meta": meta}
        tmp = path + ".tmp.npz"
        with open(tmp, "wb") as f:        # file handle: no .npz suffixing
            np.savez(f, __head__=np.frombuffer(json.dumps(head).encode(),
                                               dtype=np.uint8),
                     **{_ARR_PREFIX + k: v for k, v in arrays.items()})
        os.replace(tmp, path)

    @classmethod
    def load(cls, path: str) -> "VectorIndex":
        """Inverse of :meth:`export`. Returns an instance of the kind the
        file records (== ``cls`` when called on the concrete backend)."""
        with np.load(path, allow_pickle=False) as z:
            head = json.loads(bytes(z["__head__"]).decode())
            arrays = {k[len(_ARR_PREFIX):]: z[k] for k in z.files
                      if k.startswith(_ARR_PREFIX)}
        idx = make_index(head["kind"], **head["config"])
        idx.restore_state(arrays, head["meta"])
        return idx

    # ----------------------------------------------------------- introspect
    @property
    @abc.abstractmethod
    def size(self) -> int:
        """Number of live (non-deleted) keys."""

    def __len__(self) -> int:
        return self.size

    @abc.abstractmethod
    def _contains(self, key: str) -> bool:
        """O(1) live-key membership (validation on the mutation path)."""

    def __contains__(self, key: str) -> bool:
        return self._contains(key)

    @abc.abstractmethod
    def keys(self) -> list[str]:
        """Live keys, in insertion order."""


# ---------------------------------------------------------------------------
# factory
# ---------------------------------------------------------------------------
INDEX_KINDS = ("flat", "ivf", "hnsw", "tiered")


def _construct(kind: str, cfg: dict) -> VectorIndex:
    if kind == "flat":
        from repro.core.flat import FlatVectorIndex
        cfg.pop("M", None); cfg.pop("ef_construction", None)
        cfg.pop("ef_search", None); cfg.pop("beam_impl", None)
        return FlatVectorIndex(**cfg)
    if kind == "ivf":
        from repro.core.ivf import IVFVectorIndex
        cfg.pop("M", None); cfg.pop("ef_construction", None)
        cfg.pop("ef_search", None); cfg.pop("beam_impl", None)
        return IVFVectorIndex(**cfg)
    if kind == "hnsw":
        from repro.core.interface import HNSW
        cfg.pop("dim", None)          # HNSW infers dim from the first insert
        metric = cfg.pop("metric", "cosine")
        return HNSW(distance_function=metric, **cfg)
    if kind == "tiered":
        from repro.core.tiered import TieredIndex
        cfg.pop("dim", None)
        return TieredIndex(**cfg)
    raise ValueError(f"unknown index kind {kind!r}; expected one of "
                     f"{INDEX_KINDS}")


def make_index(kind: str, store=None, **cfg) -> VectorIndex:
    """Create a VectorIndex backend by name.

    kind: "flat" | "ivf" | "hnsw" | "tiered". ``cfg`` passes through to the
    backend constructor (common: metric, dim, n_shards, dtype,
    rerank_factor; hnsw/tiered: M, ef_construction, ef_search; ivf:
    nlist, nprobe).

    dtype selects the row-storage codec (DESIGN.md §9): "fp32" (default,
    bit-for-bit the historical path), "bf16", or "int8" (scalar-quantized,
    per-row scale). Encoded rows live in the device blocks and snapshot
    pages; lossy ANN searches over-fetch ``k·rerank_factor`` candidates
    and rerank exactly in fp32 from the canonical host rows.

    n_shards partitions the corpus over a device mesh (DESIGN.md §8):
    CRUD routes to the owning shard by key hash, queries fan out to every
    shard and merge through the hierarchical top-k tree. 1 (default) is
    the single-device layout.

    store: optional durability home — an ``IndexStore`` or a directory
    path (DESIGN.md §7). If the store already holds an index, it is
    warm-restored (snapshot + WAL replay; ``cfg`` is ignored in favor of
    the stored construction params — EXCEPT ``n_shards``, which overrides
    so a snapshot can be resharded onto the current machine — and a
    ``kind`` mismatch raises). Otherwise a fresh index is created and
    attached, so every mutation from here on is write-ahead logged.
    """
    kind = kind.lower()
    if kind not in INDEX_KINDS:
        raise ValueError(f"unknown index kind {kind!r}; expected one of "
                         f"{INDEX_KINDS}")
    if store is not None:
        from repro.store import IndexStore
        if not isinstance(store, IndexStore):
            store = IndexStore(str(store))
        if store.has_state():
            return store.load_index(expect_kind=kind,
                                    n_shards=cfg.get("n_shards"),
                                    expect_dtype=cfg.get("dtype"))
        idx = _construct(kind, cfg)
        store.attach(idx)
        return idx
    return _construct(kind, cfg)


def make_index_from_config(cfg, kind: str | None = None, store=None,
                           **overrides) -> VectorIndex:
    """Build an index from a ``RetrievalConfig`` (configs/mememo.py)."""
    kind = kind or getattr(cfg, "index_kind", "hnsw")
    params = dict(dim=cfg.dim, metric=cfg.metric, M=cfg.M,
                  ef_construction=cfg.ef_construction,
                  ef_search=cfg.ef_search)
    if kind == "ivf":
        params = dict(dim=cfg.dim, metric=cfg.metric,
                      nlist=getattr(cfg, "nlist", 64),
                      nprobe=getattr(cfg, "nprobe", 8))
    # only forward n_shards / index_dtype when the config (or caller)
    # actually sets them: an unconditional default would count as an
    # explicit override in make_index — silently resharding a warm
    # multi-shard store, or tripping the cross-dtype restore rejection
    n_sh = getattr(cfg, "n_shards", None)
    if n_sh is not None:
        params["n_shards"] = n_sh
    dt = getattr(cfg, "index_dtype", None)
    if dt is not None:
        params["dtype"] = dt
    bi = getattr(cfg, "beam_impl", None)
    if bi is not None:
        params["beam_impl"] = bi
    params.update(overrides)
    return make_index(kind, store=store, **params)
