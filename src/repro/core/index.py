"""The unified mutable retrieval layer: one ``VectorIndex`` protocol for
every ANN backend (flat / IVF / HNSW / tiered).

MeMemo's core promise is an *updatable* private knowledge base on-device:
users add, correct, and retract personal documents, and the serving layer
must not care which index structure sits underneath. Every backend
implements the same keyed CRUD + query contract:

    idx = make_index("hnsw", dim=384, metric="cosine")
    idx.bulk_insert(keys, vectors)
    idx.insert("doc-1", vec)            # single upsert
    idx.update("doc-1", new_vec)        # re-embed in place
    idx.delete("doc-0")                 # retract (tombstone, never returned)
    keys, dists = idx.query(q, k=10)    # ANN search
    keys, dists = idx.query_batch(Q, k) # batched ANN: [B,D] -> lists of lists
    keys, dists = idx.exact_query(q, k) # brute-force oracle, same live set
    idx.export(path); Idx.load(path)    # tombstones + keys round-trip
    idx.mutation_epoch                  # bumped by every mutation (caching)

Design notes (DESIGN.md §1):
  * keys are caller-owned strings; inserting an existing key is an update;
  * ``delete`` is a soft delete everywhere — backends keep fixed device
    shapes and exclude tombstoned rows from results (HNSW keeps them
    traversable, hnswlib-style; see DESIGN.md §3);
  * ``size`` counts live (non-deleted) keys;
  * ``query``/``exact_query`` return ``(keys, dists)``; batched queries
    return lists of lists. Missing slots (k > live) come back as ``None``;
  * ``query_batch`` is the serving-layer entry point: input is always
    [B, D], output is always batched (lists of lists), even at B=1 — no
    squeeze ambiguity. All four backends run it as ONE device dispatch
    (tiered, whose search is the host-side accounting model, loops);
  * every mutation bumps ``mutation_epoch``. The epoch is what lets a
    result cache (serve/retrieval.py) guarantee a retracted document is
    never served from a stale entry — the privacy property (DESIGN.md §6).
"""
from __future__ import annotations

import abc
from typing import Sequence

import numpy as np


class VectorIndex(abc.ABC):
    """Keyed, mutable ANN index. All four backends implement this."""

    metric: str
    _epoch: int = 0        # mutation counter; instance attr on first bump

    # -------------------------------------------------------------- epoch
    @property
    def mutation_epoch(self) -> int:
        """Monotonic counter bumped by every insert/update/delete.

        Consumers that cache query results key their validity on this
        value: any mutation — in particular ``delete``, the privacy
        operation — invalidates everything cached under the old epoch.
        """
        return self._epoch

    def _bump_epoch(self) -> None:
        self._epoch = self._epoch + 1

    # ------------------------------------------------------------ mutation
    @abc.abstractmethod
    def insert(self, key: str, value: Sequence[float]) -> None:
        """Upsert one (key, vector) pair."""

    def bulk_insert(self, keys: Sequence[str], values) -> None:
        """Batched upsert; backends override when they have a faster path."""
        values = np.asarray(values, np.float32)
        if len(keys) != len(values):
            raise ValueError("keys/values length mismatch")
        for k, v in zip(keys, values):
            self.insert(k, v)

    @abc.abstractmethod
    def update(self, key: str, value: Sequence[float]) -> None:
        """Replace the vector of an existing key. KeyError if absent."""

    @abc.abstractmethod
    def delete(self, key: str) -> None:
        """Soft-delete a key: never returned again. KeyError if absent."""

    # --------------------------------------------------------------- query
    def query(self, query, k: int = 10, **kw):
        """ANN top-k -> (keys, dists); a 1-D query returns one row, a
        [B, D] batch returns lists of lists. Thin squeeze wrapper over
        :meth:`query_batch` — shared by every backend."""
        q = np.asarray(query, np.float32)
        if q.ndim == 1:
            keys, d = self.query_batch(q[None], k, **kw)
            return keys[0], d[0]
        return self.query_batch(q, k, **kw)

    @abc.abstractmethod
    def query_batch(self, queries, k: int = 10, **kw):
        """Batched ANN search: queries [B, D] -> (keys, dists) where keys
        is a list of B lists of k key-or-None and dists is [B, k].

        Unlike ``query``, the result is batched even for B=1 — this is the
        shape contract the serving layer (RetrievalEngine) relies on.
        Implementations raise ValueError on non-2-D input and run the
        whole batch as one device dispatch where the backend allows.
        """

    @abc.abstractmethod
    def exact_query(self, query, k: int = 10):
        """Brute-force top-k over the same live vectors -> (keys, dists)."""

    # --------------------------------------------------------- persistence
    @abc.abstractmethod
    def export(self, path: str) -> None:
        """Write the index (vectors, keys, tombstones) to ``path``."""

    @classmethod
    @abc.abstractmethod
    def load(cls, path: str) -> "VectorIndex":
        """Inverse of :meth:`export`."""

    # ----------------------------------------------------------- introspect
    @property
    @abc.abstractmethod
    def size(self) -> int:
        """Number of live (non-deleted) keys."""

    def __len__(self) -> int:
        return self.size

    def __contains__(self, key: str) -> bool:
        return key in self.keys()

    @abc.abstractmethod
    def keys(self) -> list[str]:
        """Live keys, in insertion order."""


# ---------------------------------------------------------------------------
# factory
# ---------------------------------------------------------------------------
INDEX_KINDS = ("flat", "ivf", "hnsw", "tiered")


def make_index(kind: str, **cfg) -> VectorIndex:
    """Create a VectorIndex backend by name.

    kind: "flat" | "ivf" | "hnsw" | "tiered". ``cfg`` passes through to the
    backend constructor (common: metric, dim; hnsw/tiered: M,
    ef_construction, ef_search; ivf: nlist, nprobe).
    """
    kind = kind.lower()
    if kind == "flat":
        from repro.core.flat import FlatVectorIndex
        cfg.pop("M", None); cfg.pop("ef_construction", None)
        cfg.pop("ef_search", None)
        return FlatVectorIndex(**cfg)
    if kind == "ivf":
        from repro.core.ivf import IVFVectorIndex
        cfg.pop("M", None); cfg.pop("ef_construction", None)
        cfg.pop("ef_search", None)
        return IVFVectorIndex(**cfg)
    if kind == "hnsw":
        from repro.core.interface import HNSW
        cfg.pop("dim", None)          # HNSW infers dim from the first insert
        metric = cfg.pop("metric", "cosine")
        return HNSW(distance_function=metric, **cfg)
    if kind == "tiered":
        from repro.core.tiered import TieredIndex
        cfg.pop("dim", None)
        return TieredIndex(**cfg)
    raise ValueError(f"unknown index kind {kind!r}; expected one of "
                     f"{INDEX_KINDS}")


def make_index_from_config(cfg, kind: str | None = None, **overrides
                           ) -> VectorIndex:
    """Build an index from a ``RetrievalConfig`` (configs/mememo.py)."""
    kind = kind or getattr(cfg, "index_kind", "hnsw")
    params = dict(dim=cfg.dim, metric=cfg.metric, M=cfg.M,
                  ef_construction=cfg.ef_construction,
                  ef_search=cfg.ef_search)
    if kind == "ivf":
        params = dict(dim=cfg.dim, metric=cfg.metric,
                      nlist=getattr(cfg, "nlist", 64),
                      nprobe=getattr(cfg, "nprobe", 8))
    params.update(overrides)
    return make_index(kind, **params)
