"""Dispatch-count observability for the search path (DESIGN.md §12).

Generalizes the single ``stacked.DISPATCH_COUNT`` module global into
named host-side counters that tests and benches read to assert launch
economics — e.g. "fused beam = 1 kernel launch per search, jnp beam =
O(ef) per-hop gather dispatches" — and that bench rows report as a
``dispatches`` column.

Counters are bumped at the PYTHON boundary of each compiled entry point
(never inside a trace): they count what a call *submits* per invocation
under the compiled program's static launch structure, which is exactly
the quantity the fused kernel collapses. Not thread-safe by design —
the serving layer already serializes device work onto one dispatcher.
"""
from __future__ import annotations

from collections import defaultdict

_COUNTS: defaultdict[str, int] = defaultdict(int)


def bump(name: str, n: int = 1) -> None:
    """Add ``n`` to counter ``name`` (created at 0 on first use)."""
    _COUNTS[name] += int(n)


def get(name: str) -> int:
    return _COUNTS[name]


def reset(*names: str) -> None:
    """Reset the given counters, or ALL counters when called bare."""
    if names:
        for name in names:
            _COUNTS.pop(name, None)
    else:
        _COUNTS.clear()


def snapshot() -> dict[str, int]:
    return dict(_COUNTS)


def beam_launches(beam_impl: str, ef: int,
                  max_iters: int | None = None) -> int:
    """Device launches one search contributes on the layer-0 beam path.

    ``fused`` runs the whole ef-beam as ONE kernel launch
    (kernels/beam_search.py). ``jnp`` compiles to a ``while_loop`` whose
    body re-dispatches the gather+sort work every hop — its static hop
    bound (``max_iters``, default ef) is the per-call launch count the
    fused kernel eliminates."""
    if beam_impl == "fused":
        return 1
    return max(int(ef if max_iters is None else max_iters), 1)
