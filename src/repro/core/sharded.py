"""Shard-aware row substrate: the mesh owns the corpus (DESIGN.md §8).

Before this layer, every ``VectorIndex`` backend stored its rows and ran
its search on a single device, while the pod-scale path
(``core/distributed.sharded_flat_topk``) only worked on a static array
with no CRUD. ``ShardedRows`` unifies the two: it is the keyed, mutable
row store the flat and IVF backends are built on, and its search is the
general fan-out/merge primitive the static helper now delegates to.

Three layers of state:

  * **canonical** (what persists; shard-count independent): append-only
    host vectors ``[T, D]`` in insertion order, the row -> key table, and
    the ``alive`` tombstone mask. ``state arrays`` serialize ONLY this —
    a snapshot taken at 8 shards restores onto 1 (or vice versa) because
    placement is derived, not stored (DESIGN.md §8, resharding).
  * **placement** (derived): deterministic key->shard routing
    (``shard_of_key``: stable blake2b, never Python ``hash``) plus
    per-shard slot tables with free-slot reuse — a tombstoned row's slot
    is handed to the next insert routed to the same shard, so block
    shapes stay put under mutation churn (same motivation as the HNSW
    capacity padding, DESIGN.md §3).
  * **device** (lazy): row blocks ``[S, R, D]`` + global-id map
    ``[S, R]`` placed with ``NamedSharding`` over the ``"shard"`` mesh
    axis. Queries are replicated; each shard runs the fused
    ``flat_topk`` kernel over its own block and the per-shard top-k
    merges through the existing ``hierarchical_topk`` tree
    (distributed/collectives.py) — one log-depth reduction.

Single-shard indexes (``n_shards=1``, the default) bypass the mesh
machinery entirely and run the exact same single-device code path as
before this layer existed — bit-for-bit, which is what lets the whole
pre-existing test suite double as the sharded path's parity oracle.
"""
from __future__ import annotations

import dataclasses
import functools
import hashlib
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.codec import VectorCodec, get_codec, rerank_exact
from repro.core.hnsw_build import normalize_rows
from repro.distributed.collectives import hierarchical_topk
from repro.kernels import ops

INF = np.float32(3e38)
SHARD_AXIS = "shard"


def resolve_wire_bf16(flag: bool | None) -> bool:
    """Resolve a per-call/per-index ``wire_bf16`` knob: explicit values
    win; None falls back to the REPRO_WIRE_BF16 env toggle (off by
    default — bf16 wire halves merge bytes but costs bitwise parity with
    the 1-shard path, so it is opt-in)."""
    if flag is not None:
        return bool(flag)
    return os.environ.get("REPRO_WIRE_BF16", "0") == "1"
# re-layout the slot tables when free (tombstoned/reusable) slots exceed
# this fraction of block capacity: bounds the top-k slack (see pack())
REPACK_FREE_FRACTION = 0.25


def shard_of_key(key: str, n_shards: int) -> int:
    """Deterministic key -> owning shard. Stable across processes and
    restarts (blake2b, NOT Python ``hash``): the WAL replays mutations
    through the same routing the live index used, and a resharded
    restore re-derives placement from keys alone."""
    if n_shards <= 1:
        return 0
    h = hashlib.blake2b(key.encode("utf-8"), digest_size=8).digest()
    return int.from_bytes(h, "little") % n_shards


def ensure_shard_devices(n_shards: int) -> None:
    """Raise early (with the CPU-simulation recipe) when the process
    cannot place ``n_shards`` shards."""
    n_dev = len(jax.devices())
    if n_shards > n_dev:
        raise ValueError(
            f"n_shards={n_shards} needs {n_shards} devices, found {n_dev}; "
            "on CPU simulate with XLA_FLAGS=--xla_force_host_platform_"
            f"device_count={n_shards} (set before importing jax)")


@functools.lru_cache(maxsize=8)
def shard_mesh(n_shards: int) -> Mesh:
    """1-D mesh over the first ``n_shards`` devices, axis ``"shard"``."""
    ensure_shard_devices(n_shards)
    return jax.make_mesh((n_shards,), (SHARD_AXIS,),
                         devices=jax.devices()[:n_shards])


# ---------------------------------------------------------------------------
# fan-out search: per-shard fused top-k + hierarchical merge
# ---------------------------------------------------------------------------
def trim_merge_width(d: jax.Array, ids: jax.Array, k: int, inf
                     ) -> tuple[jax.Array, jax.Array]:
    """Bring one shard's masked candidate set to exactly the k-wide merge
    format: re-select k when over-fetched, pad with (inf, -1) when the
    shard is short. Callers mask invalid candidates (free slots, DB
    padding, list padding) to distance ``inf`` BEFORE calling — this is
    the one place the local-result shape meets the merge contract, shared
    by the flat fan-out, the IVF fan-out, and the static pod-scale path
    (core/distributed.py)."""
    kk = d.shape[1]
    if kk > k:
        neg, j = jax.lax.top_k(-d, k)
        return -neg, jnp.take_along_axis(ids, j, axis=1)
    if kk < k:
        b = d.shape[0]
        d = jnp.concatenate([d, jnp.full((b, k - kk), inf, d.dtype)], axis=1)
        ids = jnp.concatenate(
            [ids, jnp.full((b, k - kk), -1, ids.dtype)], axis=1)
    return d, ids


@functools.lru_cache(maxsize=64)
def _fanout_topk_fn(mesh: Mesh, k: int, slack: int, metric: str,
                    has_scales: bool = False, wire_bf16: bool = False):
    """Compiled sharded exact top-k.

    blocks [S, R, D] + gids [S, R] (sharded over ``"shard"``), queries
    [B, D] (replicated) -> (dists [B, k], global ids [B, k]) replicated.
    Blocks may be codec-encoded (DESIGN.md §9); with ``has_scales`` a
    sharded [S, R] scale table rides along and the per-row decode fuses
    into the distance kernel. Slots with gid < 0 (free slots / block
    padding) must not reach the merge, but the fused ``flat_topk``
    kernel cannot mask mid-kernel — so each shard over-fetches
    ``k + slack`` candidates (slack = the pack-time bound on dead slots
    per shard), masks by gid, and re-selects k. Missing slots come back
    as (INF, -1).

    The merge runs the ppermute tree reduction (static axis size from
    the mesh); ``wire_bf16`` halves its distance payload per round at
    the cost of bf16-resolution ordering (ids stay exact). Cache keys
    are (mesh, k, quantized slack, metric, has_scales, wire_bf16) —
    every component takes O(log R) or O(1) distinct values as the
    corpus grows, so the lru_cache cannot churn across epochs.
    """
    n_shards = mesh.shape[SHARD_AXIS]

    def local(blk, gid, q, scl=None):
        blk, gid = blk[0], gid[0]
        r = blk.shape[0]
        kk = min(k + slack, r)
        d, i = ops.flat_topk(blk, q, kk, metric=metric,
                             scales=None if scl is None else scl[0])
        g = jnp.take(gid, i)
        d = jnp.where(g >= 0, d, jnp.float32(INF))
        d, g = trim_merge_width(d, g, k, jnp.float32(INF))
        g = jnp.where(d >= jnp.float32(INF), -1, g)
        return hierarchical_topk(d, g, k, (SHARD_AXIS,),
                                 wire_bf16=wire_bf16, tie_break_ids=True,
                                 axis_sizes=(n_shards,))

    if has_scales:
        fn = shard_map(lambda blk, gid, scl, q: local(blk, gid, q, scl),
                       mesh=mesh,
                       in_specs=(P(SHARD_AXIS, None, None),
                                 P(SHARD_AXIS, None), P(SHARD_AXIS, None),
                                 P(None, None)),
                       out_specs=(P(None, None), P(None, None)),
                       check_rep=False)
        return jax.jit(fn)
    fn = shard_map(local, mesh=mesh,
                   in_specs=(P(SHARD_AXIS, None, None), P(SHARD_AXIS, None),
                             P(None, None)),
                   out_specs=(P(None, None), P(None, None)),
                   check_rep=False)      # post-merge values ARE replicated
    return jax.jit(fn)


def _quantize_slack(slack: int) -> int:
    """Round the dead-slot bound up to a power of two so the compiled
    fan-out is reused across nearby pack states (same trick as the
    serving layer's batch buckets, DESIGN.md §6)."""
    if slack <= 0:
        return 0
    return 1 << (slack - 1).bit_length()


# incremented on every block upload — tests assert steady-state sharded
# search performs ZERO per-query device_put of row blocks (ISSUE 6)
PLACE_COUNT = 0


def place_blocks(blocks: np.ndarray, gids: np.ndarray, mesh: Mesh,
                 scales: np.ndarray | None = None):
    """Upload one [S, R, D] block array + its [S, R] gid map (and, for a
    scaled codec, the [S, R] scale table), row blocks resident on their
    owning shard's device."""
    global PLACE_COUNT
    PLACE_COUNT += 1
    b = jax.device_put(jnp.asarray(blocks),
                       NamedSharding(mesh, P(SHARD_AXIS, None, None)))
    g = jax.device_put(jnp.asarray(gids),
                       NamedSharding(mesh, P(SHARD_AXIS, None)))
    if scales is None:
        return b, g
    s = jax.device_put(jnp.asarray(scales),
                       NamedSharding(mesh, P(SHARD_AXIS, None)))
    return b, g, s


@dataclasses.dataclass(frozen=True)
class ExactBlocks:
    """Device-resident exact-phase row blocks, built once per mutation
    epoch and reused for every query until the index mutates (the same
    invalidation contract the serve-layer LRU uses). ``slack`` is already
    ``_quantize_slack``-rounded, so the compiled-fn cache key derived
    from an ExactBlocks never takes more than O(log R) distinct values
    as the corpus grows."""
    mesh: Mesh
    blocks: jax.Array            # [S, R, D] sharded over "shard"
    gids: jax.Array              # [S, R] sharded over "shard"
    slack: int                   # quantized over-fetch bound
    n_rows: int                  # total live rows across groups


def build_exact_blocks(groups, dim: int, *, normalize: bool = False
                       ) -> ExactBlocks | None:
    """Host repack + upload of per-shard row groups -> placed blocks.

    groups: list of (vectors [n_s, D], gids [n_s]) — one entry per shard
    (n_s may be 0). Returns None when every group is empty (degenerate
    case: no block array is materialized and nothing touches a device).
    The expensive half of the old one-shot ``fanout_exact_topk``; cache
    the result keyed by ``mutation_epoch`` and query it many times via
    ``exact_topk_blocks``.
    """
    s = len(groups)
    total = sum(v.shape[0] for v, _ in groups)
    if total == 0:
        return None
    r = max(v.shape[0] for v, _ in groups)
    blocks = np.zeros((s, r, dim), np.float32)
    gids = np.full((s, r), -1, np.int32)
    slack = 0
    for j, (v, g) in enumerate(groups):
        if v.shape[0]:
            blocks[j, :v.shape[0]] = normalize_rows(v) if normalize else v
            gids[j, :v.shape[0]] = g
        slack = max(slack, r - v.shape[0])
    mesh = shard_mesh(s)
    bl, gi = place_blocks(blocks, gids, mesh)
    return ExactBlocks(mesh=mesh, blocks=bl, gids=gi,
                       slack=_quantize_slack(slack), n_rows=total)


def exact_topk_blocks(placed: ExactBlocks, queries, k: int, *, metric: str,
                      wire_bf16: bool | None = None
                      ) -> tuple[np.ndarray, np.ndarray]:
    """Query already-placed exact-phase blocks: zero host-byte movement
    on the steady-state path — one compiled dispatch over resident
    device blocks."""
    q = jnp.asarray(queries, jnp.float32)
    if metric == "cosine":
        q = q / jnp.maximum(jnp.linalg.norm(q, axis=-1, keepdims=True), 1e-12)
    fn = _fanout_topk_fn(placed.mesh, k, placed.slack, metric,
                         wire_bf16=resolve_wire_bf16(wire_bf16))
    d, g = fn(placed.blocks, placed.gids, q)
    return np.asarray(d), np.asarray(g)


def fanout_exact_topk(groups, queries, k: int, *, metric: str,
                      normalize: bool = False,
                      wire_bf16: bool | None = None
                      ) -> tuple[np.ndarray, np.ndarray]:
    """One-shot sharded exact search over explicit per-shard row groups
    (``build_exact_blocks`` + ``exact_topk_blocks`` back to back; callers
    with a mutation epoch should cache the built blocks instead).
    queries [B, D] -> (dists [B, k], gids [B, k]), missing slots
    (INF, -1); all-empty groups short-circuit host-side with no device
    work at all.
    """
    queries = np.asarray(queries, np.float32)
    placed = build_exact_blocks(groups, queries.shape[1],
                                normalize=normalize)
    if placed is None:
        b = queries.shape[0]
        return (np.full((b, k), INF, np.float32),
                np.full((b, k), -1, np.int32))
    return exact_topk_blocks(placed, queries, k, metric=metric,
                             wire_bf16=wire_bf16)


# ---------------------------------------------------------------------------
# the mutable substrate
# ---------------------------------------------------------------------------
class ShardedRows:
    """Keyed mutable row storage partitioned across the mesh.

    The flat and IVF backends delegate their storage, routing, and
    bookkeeping here; HNSW/tiered use the routing + fan-out helpers.
    All mutators are host-side and cheap; device blocks are packed
    lazily on the first search after a mutation (the same laziness the
    single-device backends always had).
    """

    def __init__(self, *, n_shards: int = 1, metric: str = "cosine",
                 dim: int | None = None, normalize_on_pack: bool = False,
                 codec: VectorCodec | str | None = None,
                 wire_bf16: bool | None = None):
        if n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {n_shards}")
        self.n_shards = n_shards
        self.metric = metric
        self.dim = dim
        # None -> REPRO_WIRE_BF16 env default (resolve_wire_bf16)
        self.wire_bf16 = wire_bf16
        # metric-appropriate normalization at pack time (flat semantics);
        # IVF normalizes at insert instead and packs raw. Under a LOSSY
        # codec the normalization moves to ingest (rows must be in final
        # form BEFORE they are quantized once, DESIGN.md §9) and pack
        # uploads the canonical encoded rows untouched.
        self.normalize_on_pack = normalize_on_pack
        self.codec = (codec if isinstance(codec, VectorCodec)
                      else get_codec(codec or "fp32"))
        # canonical: fp32 decode (insertion-ordered; what reranking,
        # training, and the exact phases read) + for lossy codecs the
        # encoded rows and per-row scales (what devices and snapshots
        # hold — encoded ONCE at ingest, never re-derived)
        self._vecs = np.zeros((0, dim or 0), np.float32)
        self._enc = (np.zeros((0, dim or 0), self.codec.enc_dtype)
                     if self.codec.lossy else None)
        self._scales = (np.zeros(0, np.float32)
                        if self.codec.uses_scales else None)
        self._keys: list[str] = []
        self._key2row: dict[str, int] = {}
        self._alive = np.zeros(0, bool)
        # placement
        self._row_shard = np.zeros(0, np.int32)
        self._row_slot = np.zeros(0, np.int32)
        self._slots: list[list[int]] = [[] for _ in range(n_shards)]
        self._free: list[list[int]] = [[] for _ in range(n_shards)]
        # device (lazy)
        self._device = None          # S>1: (mesh, blocks, gids, scl, slack)
        self._flat = None            # S==1: FlatIndex over live rows
        self._live_rows: np.ndarray | None = None

    # ------------------------------------------------------------ canonical
    @property
    def vectors(self) -> np.ndarray:
        return self._vecs

    @property
    def encoded(self) -> np.ndarray | None:
        """Canonical codec-encoded rows [T, D] (None for fp32)."""
        return self._enc

    @property
    def scales(self) -> np.ndarray | None:
        """Canonical per-row decode scales [T] (int8 codec only)."""
        return self._scales

    @property
    def alive(self) -> np.ndarray:
        return self._alive

    @property
    def key_list(self) -> list[str]:
        return self._keys

    @property
    def key2row(self) -> dict[str, int]:
        return self._key2row

    @property
    def size(self) -> int:
        return len(self._key2row)

    @property
    def row_count(self) -> int:
        return len(self._keys)

    def live_keys(self) -> list[str]:
        return [k for i, k in enumerate(self._keys) if self._alive[i]]

    def key_of_row(self, row: int) -> str:
        return self._keys[row]

    def placement_of_row(self, row: int) -> tuple[int, int]:
        """-> (shard, slot) of a live row."""
        return int(self._row_shard[row]), int(self._row_slot[row])

    def shard_stats(self) -> list[dict]:
        """Per-shard occupancy: live rows, free slots, block capacity."""
        out = []
        for s in range(self.n_shards):
            free = len(self._free[s])
            out.append({"shard": s, "slots": len(self._slots[s]),
                        "free": free, "live": len(self._slots[s]) - free})
        return out

    # ------------------------------------------------------------ mutation
    def _invalidate(self) -> None:
        self._device = None
        self._flat = None
        self._live_rows = None

    def _ensure_dim(self, d: int) -> None:
        if self.dim is None:
            self.dim = d
            self._vecs = np.zeros((0, d), np.float32)
            if self._enc is not None:
                self._enc = np.zeros((0, d), self.codec.enc_dtype)

    def _ingest(self, vecs: np.ndarray
                ) -> tuple[np.ndarray, np.ndarray | None, np.ndarray | None]:
        """Raw fp32 rows -> (canonical fp32, encoded, scales).

        Lossy codecs quantize HERE, once, after any metric normalization
        (DESIGN.md §9): the encoded rows become canonical and the fp32
        side is their exact decode, so re-encoding never happens and
        snapshot round-trips are bit-stable. fp32 passes through
        untouched (the historical path)."""
        vecs = np.asarray(vecs, np.float32)
        if not self.codec.lossy:
            return vecs, None, None
        if self.normalize_on_pack and self.metric == "cosine":
            vecs = normalize_rows(vecs)
        enc, scales = self.codec.encode(vecs)
        return self.codec.decode(enc, scales), enc, scales

    def _claim_slot(self, shard: int, row: int) -> int:
        free = self._free[shard]
        if free:
            slot = free.pop()
            self._slots[shard][slot] = row
        else:
            slot = len(self._slots[shard])
            self._slots[shard].append(row)
        return slot

    def _release_row(self, row: int) -> None:
        self._alive[row] = False
        s, slot = int(self._row_shard[row]), int(self._row_slot[row])
        self._slots[s][slot] = -1
        self._free[s].append(slot)

    def _append_enc(self, enc: np.ndarray | None,
                    scales: np.ndarray | None) -> None:
        if self._enc is not None:
            self._enc = np.concatenate([self._enc, enc])
        if self._scales is not None:
            self._scales = np.concatenate(
                [self._scales, np.asarray(scales, np.float32)])

    def _append_row(self, key: str, vec: np.ndarray) -> int:
        row = len(self._keys)
        self._vecs = np.concatenate([self._vecs, vec[None]])
        self._keys.append(key)
        self._alive = np.concatenate([self._alive, np.ones(1, bool)])
        self._key2row[key] = row
        shard = shard_of_key(key, self.n_shards)
        slot = self._claim_slot(shard, row)
        self._row_shard = np.concatenate(
            [self._row_shard, np.array([shard], np.int32)])
        self._row_slot = np.concatenate(
            [self._row_slot, np.array([slot], np.int32)])
        return row

    def upsert(self, key: str, vec: np.ndarray) -> None:
        vec = np.asarray(vec, np.float32).reshape(-1)
        self._ensure_dim(vec.shape[0])
        vec, enc, scales = self._ingest(vec[None])
        old = self._key2row.pop(key, None)
        if old is not None:
            self._release_row(old)
        self._append_row(key, vec[0])
        self._append_enc(enc, scales)
        self._invalidate()

    def upsert_many(self, keys: list[str], vecs: np.ndarray) -> None:
        vecs = np.asarray(vecs, np.float32)
        self._ensure_dim(vecs.shape[1])
        vecs, enc, scales = self._ingest(vecs)
        # pop as we release: a pre-existing key repeated WITHIN the batch
        # must free its old slot exactly once (a double release would
        # push the slot onto the free stack twice and hand it to two rows)
        for key in keys:
            old = self._key2row.pop(key, None)
            if old is not None:
                self._release_row(old)
        base = len(self._keys)
        n = len(keys)
        self._vecs = np.concatenate([self._vecs, vecs])
        self._append_enc(enc, scales)
        self._keys.extend(keys)
        self._alive = np.concatenate([self._alive, np.ones(n, bool)])
        shards = np.zeros(n, np.int32)
        slots = np.zeros(n, np.int32)
        for j, key in enumerate(keys):
            self._key2row[key] = base + j
            shards[j] = shard_of_key(key, self.n_shards)
            slots[j] = self._claim_slot(int(shards[j]), base + j)
        self._row_shard = np.concatenate([self._row_shard, shards])
        self._row_slot = np.concatenate([self._row_slot, slots])
        self._invalidate()

    def tombstone(self, key: str) -> None:
        self._release_row(self._key2row.pop(key))
        self._invalidate()

    def contains(self, key: str) -> bool:
        return key in self._key2row

    def compact(self) -> None:
        """Physically drop tombstoned rows: canonical arrays re-pack over
        live rows and the per-shard slot tables are rebuilt dense — the
        complement of the store layer's secure-delete page rewrite
        (DESIGN.md §7): after this, a deleted vector's bytes — the fp32
        decode AND the codec-encoded bytes + scale (DESIGN.md §9) —
        exist in no host array and in no shard's device block."""
        live = np.flatnonzero(self._alive)
        vecs = np.ascontiguousarray(self._vecs[live])
        keys = [self._keys[i] for i in live]
        enc = (np.ascontiguousarray(self._enc[live])
               if self._enc is not None else None)
        scales = (np.ascontiguousarray(self._scales[live])
                  if self._scales is not None else None)
        self._reset_layout(vecs, keys, np.ones(live.size, bool),
                           enc=enc, scales=scales)

    def _reset_layout(self, vecs: np.ndarray, keys: list[str],
                      alive: np.ndarray, enc: np.ndarray | None = None,
                      scales: np.ndarray | None = None) -> None:
        """Adopt canonical arrays and re-derive placement from scratch
        (compaction, restore, resharding all land here)."""
        self._vecs = np.asarray(vecs, np.float32)
        if self._enc is not None:
            if enc is None:
                raise ValueError(
                    f"{self.codec.name} rows need their encoded arrays; "
                    "got fp32-only state (cross-dtype restore?)")
            self._enc = np.asarray(enc, self.codec.enc_dtype)
        if self._scales is not None:
            self._scales = np.asarray(scales, np.float32)
        if self._vecs.shape[1]:
            self.dim = int(self._vecs.shape[1])
        self._keys = list(keys)
        self._alive = np.asarray(alive, bool).copy()
        self._key2row = {k: i for i, k in enumerate(self._keys)
                         if self._alive[i]}
        n = len(self._keys)
        self._row_shard = np.full(n, -1, np.int32)
        self._row_slot = np.full(n, -1, np.int32)
        self._slots = [[] for _ in range(self.n_shards)]
        self._free = [[] for _ in range(self.n_shards)]
        for row in range(n):
            if not self._alive[row]:
                continue                 # dead rows own no slot
            shard = shard_of_key(self._keys[row], self.n_shards)
            self._row_shard[row] = shard
            self._row_slot[row] = self._claim_slot(shard, row)
        self._invalidate()

    def restore(self, vecs: np.ndarray, keys: list[str],
                alive: np.ndarray) -> None:
        """Inverse of the canonical accessors: placement is re-derived,
        which is why a snapshot reshards freely (DESIGN.md §8)."""
        if self.codec.lossy:
            raise ValueError(
                f"{self.codec.name} rows restore from encoded state "
                "(restore_encoded); got fp32-only state — the store was "
                "written by a different storage dtype")
        self._reset_layout(vecs, keys, alive)

    def restore_encoded(self, enc: np.ndarray, scales: np.ndarray | None,
                        keys: list[str], alive: np.ndarray) -> None:
        """Adopt snapshotted encoded rows (+ scales) as canonical and
        re-derive the fp32 side by decoding — the encoded array is never
        re-derived, so restore is bit-for-bit (DESIGN.md §9)."""
        enc = self.codec.from_storage(enc)
        self._reset_layout(self.codec.decode(enc, scales), keys, alive,
                           enc=enc, scales=scales)

    # --------------------------------------------------------------- pack
    def _maybe_relayout(self) -> None:
        total = sum(len(s) for s in self._slots)
        free = sum(len(f) for f in self._free)
        if total and free / total > REPACK_FREE_FRACTION:
            # too many dead slots: re-derive a dense layout (slot churn
            # is fine here — the device blocks are being rebuilt anyway)
            self._reset_layout(self._vecs, self._keys, self._alive)

    def pack(self):
        """(Re)build the device placement over live rows.

        S == 1 -> a ``FlatIndex`` (bit-for-bit the pre-shard path for
                  fp32; encoded rows + scale column for lossy codecs).
        S > 1  -> (mesh, blocks [S,R,D], gids [S,R], scales [S,R]|None,
                  slack). Blocks hold the codec-encoded rows, so device
                  bytes shrink with the codec (DESIGN.md §9).
        """
        live = np.flatnonzero(self._alive)
        if live.size == 0:
            raise ValueError("index is empty")
        lossy = self.codec.lossy
        if self.n_shards == 1:
            if self._flat is None:
                from repro.core.flat import FlatIndex
                self._live_rows = live
                if lossy:
                    # rows were normalized + encoded at ingest; upload
                    # the canonical encoded bytes as-is
                    self._flat = FlatIndex(
                        vectors=jnp.asarray(self._enc[live]),
                        metric=self.metric,
                        scales=(jnp.asarray(self._scales[live])
                                if self._scales is not None else None))
                else:
                    v = self._vecs[live]
                    self._flat = (FlatIndex.build(v, metric=self.metric)
                                  if self.normalize_on_pack else
                                  FlatIndex(vectors=jnp.asarray(v),
                                            metric=self.metric))
            return self._flat
        if self._device is None:
            self._maybe_relayout()
            mesh = shard_mesh(self.n_shards)
            r = max(max(len(s) for s in self._slots), 1)
            rows_src = self._enc if lossy else self._vecs
            blocks = np.zeros((self.n_shards, r, self.dim or 1),
                              rows_src.dtype)
            gids = np.full((self.n_shards, r), -1, np.int32)
            scl = (np.zeros((self.n_shards, r), np.float32)
                   if self._scales is not None else None)
            slack = 0
            for s in range(self.n_shards):
                dead = r - (len(self._slots[s]) - len(self._free[s]))
                slack = max(slack, dead)
                table = np.asarray(self._slots[s], np.int64)
                occ = np.flatnonzero(table >= 0)     # occupied slots only
                if occ.size:
                    blocks[s, occ] = rows_src[table[occ]]
                    gids[s, occ] = table[occ]
                    if scl is not None:
                        scl[s, occ] = self._scales[table[occ]]
            if not lossy and self.normalize_on_pack \
                    and self.metric == "cosine":
                # row-wise, so identical bits to normalizing each shard's
                # rows separately; free slots stay zero (norm clamped)
                blocks = normalize_rows(blocks)
            if scl is None:
                bl, gi = place_blocks(blocks, gids, mesh)
                sc = None
            else:
                bl, gi, sc = place_blocks(blocks, gids, mesh, scl)
            self._device = (mesh, bl, gi, sc, _quantize_slack(slack))
        return self._device

    # -------------------------------------------------------------- search
    def topk(self, queries: np.ndarray, k: int
             ) -> tuple[np.ndarray, np.ndarray]:
        """Exact top-k over live rows (asymmetric under a lossy codec:
        fp32 query vs encoded rows) -> (dists, global row ids).

        S == 1 returns ``min(k, live)`` columns (exactly the historical
        single-device behaviour — callers pad); S > 1 always returns k
        columns with missing slots as (INF, -1).
        """
        q = np.asarray(queries, np.float32)
        if self.n_shards == 1:
            flat = self.pack()
            d, i = flat.query(q, min(k, flat.n))
            d, i = np.asarray(d), np.asarray(i)
            return d, self._live_rows[i]
        mesh, blocks, gids, scl, slack = self.pack()
        qj = jnp.asarray(q)
        if self.metric == "cosine" and self.normalize_on_pack:
            qj = qj / jnp.maximum(
                jnp.linalg.norm(qj, axis=-1, keepdims=True), 1e-12)
        fn = _fanout_topk_fn(mesh, k, slack, self.metric,
                             has_scales=scl is not None,
                             wire_bf16=resolve_wire_bf16(self.wire_bf16))
        d, g = (fn(blocks, gids, scl, qj) if scl is not None
                else fn(blocks, gids, qj))
        return np.asarray(d), np.asarray(g)

    def rerank_topk(self, queries: np.ndarray, gids: np.ndarray, k: int
                    ) -> tuple[np.ndarray, np.ndarray]:
        """Exact fp32 re-scoring of over-fetched candidates against the
        canonical host rows (DESIGN.md §9): the second half of the lossy
        search contract (asymmetric first pass over-fetches
        ``k·rerank_factor``, this picks the true best k)."""
        return rerank_exact(self._vecs, queries, gids, k,
                            metric=self.metric)

    def device_block_bytes(self) -> int:
        """Bytes the packed device representation holds per the current
        live set (blocks + gid map + scale table) — the codec's device
        footprint (benchmarks/bench_memory.py)."""
        packed = self.pack()
        if self.n_shards == 1:
            total = packed.vectors.nbytes
            if packed.scales is not None:
                total += packed.scales.nbytes
            return total
        _, bl, gi, sc, _ = packed
        return bl.nbytes + gi.nbytes + (sc.nbytes if sc is not None else 0)
