"""Batch-synchronous HNSW search in JAX (fixed shapes, lock-step).

The browser algorithm is pointer-chasing best-first search; on TPU every
query in the batch advances together (DESIGN.md §2):

  * upper layers: greedy descent, one hop per ``while_loop`` iteration, all
    queries stepping simultaneously until none improves;
  * layer 0: ef-beam best-first search. The beam is a sorted array of
    (dist, id, expanded); each iteration expands the best unexpanded entry of
    every query, gathers its 2M neighbors (the ``gather_distance`` hot spot —
    Pallas kernel on TPU, fused gather+dot here), merges candidates with a
    two-key sort and adjacent-duplicate masking.

Work per query  = ef expansions x 2M neighbor distances — identical to the
sequential algorithm's expansion budget, so recall matches the reference
builder (validated in tests/test_hnsw.py).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dispatch
from repro.core.hnsw_build import HNSWGraph
from repro.distributed.sharding import shard

INF = jnp.float32(3.0e38)

# frontier nodes expanded per hop on the fused beam path (DESIGN.md §12):
# each DMA round amortizes over T nodes, so the one-launch kernel runs
# ceil(ef / T) hops against the same ef-expansion budget as the reference
DEFAULT_EXPAND_T = 4


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class DeviceGraph:
    """HNSW graph as dense device tensors.

    ``deleted`` is the tombstone mask (DESIGN.md §3): tombstoned rows stay
    traversable during beam search (hnswlib-style, so graph connectivity
    survives deletions) but are excluded from returned results.

    ``vectors`` holds the rows in their STORAGE dtype (DESIGN.md §9):
    f32 historically, bf16/int8 under a lossy codec — with ``scales``
    carrying the int8 per-row decode scales. Every distance decodes in
    fp32 (fused into the gather kernel), so HBM holds the small encoding
    while the math stays asymmetric fp32.
    """
    vectors: jax.Array      # [N, D] storage dtype (normalised if cosine)
    neighbors0: jax.Array   # [N, 2M] int32 (-1 pad)
    upper: jax.Array        # [L, N, M] int32 (-1 pad); L may be 0
    levels: jax.Array       # [N] int32
    entry: jax.Array        # scalar int32
    deleted: jax.Array      # [N] bool tombstones
    max_level: int          # static
    metric: str             # static
    scales: jax.Array | None = None   # [N] f32 decode scales (int8 codec)

    def tree_flatten(self):
        return ((self.vectors, self.neighbors0, self.upper, self.levels,
                 self.entry, self.deleted, self.scales),
                (self.max_level, self.metric))

    @classmethod
    def tree_unflatten(cls, aux, children):
        (vectors, neighbors0, upper, levels, entry, deleted,
         scales) = children
        return cls(vectors, neighbors0, upper, levels, entry, deleted,
                   max_level=aux[0], metric=aux[1], scales=scales)

    @property
    def n(self) -> int:
        return self.vectors.shape[0]


def to_device_graph(g: HNSWGraph, deleted: np.ndarray | None = None,
                    enc: np.ndarray | None = None,
                    scales: np.ndarray | None = None) -> DeviceGraph:
    """Full host->device conversion (the from-scratch path; incremental
    updates go through :func:`apply_row_updates`).

    ``enc``/``scales``: codec-encoded rows to upload INSTEAD of the host
    f32 vectors (same [N, D] capacity view, DESIGN.md §9)."""
    n = g.vectors.shape[0]
    if deleted is None:
        deleted = np.zeros(n, bool)
    v = g.vectors if enc is None else enc
    dispatch.bump("hnsw.h2d_bytes",
                  n * (v.shape[1] * (4 if enc is None else v.itemsize)
                       + 4 * g.neighbors0.shape[1]
                       + 4 * g.upper.shape[0] * (g.upper.shape[2]
                                                 if g.upper.shape[0] else 0)
                       + 4 + (4 if scales is not None else 0)))
    return DeviceGraph(
        vectors=(jnp.asarray(g.vectors, jnp.float32) if enc is None
                 else jnp.asarray(enc)),
        neighbors0=jnp.asarray(g.neighbors0, jnp.int32),
        upper=jnp.asarray(g.upper, jnp.int32),
        levels=jnp.asarray(g.levels, jnp.int32),
        entry=jnp.asarray(max(g.entry, 0), jnp.int32),
        deleted=jnp.asarray(deleted[:n], bool),
        max_level=int(g.max_level),
        metric=g.metric,
        scales=None if scales is None else jnp.asarray(scales, jnp.float32),
    )


@functools.partial(jax.jit, donate_argnums=(0, 1, 2, 3))
def _scatter_rows_jit(vectors, neighbors0, upper, levels,
                      rows, v_new, n0_new, u_new, l_new):
    """Donated in-place row scatter: the resident buffers are updated
    without a whole-buffer copy (O(|rows|) work, not O(N))."""
    vectors = vectors.at[rows].set(v_new)
    neighbors0 = neighbors0.at[rows].set(n0_new)
    if upper.shape[0]:
        upper = upper.at[:, rows].set(u_new)
    levels = levels.at[rows].set(l_new)
    return vectors, neighbors0, upper, levels


@functools.partial(jax.jit, donate_argnums=(0, 1, 2, 3, 4))
def _scatter_rows_scaled_jit(vectors, scales, neighbors0, upper, levels,
                             rows, v_new, s_new, n0_new, u_new, l_new):
    """Codec variant of the donated scatter: the encoded row payload and
    its per-row scale travel together (DESIGN.md §9)."""
    vectors = vectors.at[rows].set(v_new)
    scales = scales.at[rows].set(s_new)
    neighbors0 = neighbors0.at[rows].set(n0_new)
    if upper.shape[0]:
        upper = upper.at[:, rows].set(u_new)
    levels = levels.at[rows].set(l_new)
    return vectors, scales, neighbors0, upper, levels


def apply_row_updates(dg: DeviceGraph, g: HNSWGraph, rows,
                      deleted: np.ndarray | None = None,
                      enc: np.ndarray | None = None,
                      scales: np.ndarray | None = None) -> DeviceGraph:
    """Incremental device-graph sync (DESIGN.md §3): copy only the dirty
    ``rows`` of the host graph into the resident device tensors — O(|rows|)
    transfer + in-place donated scatter instead of a full re-upload.

    CONSUMES ``dg``: its buffers are donated to the updated graph, so the
    caller must drop its reference and use the returned DeviceGraph.
    Shapes must match (the host graph is the same capacity-padded view the
    resident graph was built from). ``deleted`` refreshes the tombstone
    mask; entry/max_level are always refreshed (scalar-cheap).

    ``enc``/``scales``: the codec-encoded capacity view when the resident
    graph stores encoded rows — dirty rows scatter the encoded payload
    (+ scale) instead of the f32 vectors (DESIGN.md §9).
    """
    if dg.vectors.shape != g.vectors.shape or dg.upper.shape != g.upper.shape:
        raise ValueError("capacity/layer shape changed; full rebuild required")
    rows = np.asarray(sorted(int(r) for r in rows), np.int32)
    if rows.size:
        # pad the row set to the next power of two so the jitted scatter
        # compiles once per bucket, not once per distinct dirty-row count;
        # pad slots repeat rows[0] with identical payload (idempotent)
        bucket = 1 << (int(rows.size) - 1).bit_length()
        pad = np.full(bucket - rows.size, rows[0], np.int32)
        rp = np.concatenate([rows, pad])
        u_new = (g.upper[:, rp] if g.upper.shape[0]
                 else np.zeros((0, bucket, 1), np.int32))
        v_new = (jnp.asarray(g.vectors[rp], jnp.float32) if enc is None
                 else jnp.asarray(enc[rp]))
        dispatch.bump("hnsw.h2d_bytes",
                      bucket * (g.vectors.shape[1]
                                * (4 if enc is None else enc.itemsize)
                                + 4 * g.neighbors0.shape[1]
                                + 4 * g.upper.shape[0]
                                * (g.upper.shape[2] if g.upper.shape[0] else 0)
                                + 4 + (4 if scales is not None else 0)))
        if scales is None:
            vectors, neighbors0, upper, levels = _scatter_rows_jit(
                dg.vectors, dg.neighbors0, dg.upper, dg.levels,
                jnp.asarray(rp), v_new,
                jnp.asarray(g.neighbors0[rp], jnp.int32),
                jnp.asarray(u_new, jnp.int32),
                jnp.asarray(g.levels[rp], jnp.int32))
            dg = dataclasses.replace(dg, vectors=vectors,
                                     neighbors0=neighbors0,
                                     upper=upper, levels=levels)
        else:
            vectors, scl, neighbors0, upper, levels = \
                _scatter_rows_scaled_jit(
                    dg.vectors, dg.scales, dg.neighbors0, dg.upper,
                    dg.levels, jnp.asarray(rp), v_new,
                    jnp.asarray(scales[rp], jnp.float32),
                    jnp.asarray(g.neighbors0[rp], jnp.int32),
                    jnp.asarray(u_new, jnp.int32),
                    jnp.asarray(g.levels[rp], jnp.int32))
            dg = dataclasses.replace(dg, vectors=vectors, scales=scl,
                                     neighbors0=neighbors0, upper=upper,
                                     levels=levels)
    new_deleted = dg.deleted if deleted is None \
        else jnp.asarray(deleted[: dg.n], bool)
    return dataclasses.replace(
        dg, entry=jnp.asarray(max(int(g.entry), 0), jnp.int32),
        deleted=new_deleted, max_level=int(g.max_level))


@functools.partial(jax.jit, donate_argnums=(0, 1))
def _scatter_adj_jit(neighbors0, upper, rows, n0_new, u_new):
    """Donated adjacency-only scatter: bulk ingest's reciprocal connect
    touches the NEIGHBOR LISTS of up to batch·M existing rows whose
    vectors are unchanged — shipping full rows there would re-upload
    O(D) payload bytes per back-edge and erase the dirty-rows-only win
    (DESIGN.md §13). This path moves only the int32 adjacency."""
    neighbors0 = neighbors0.at[rows].set(n0_new)
    if upper.shape[0]:
        upper = upper.at[:, rows].set(u_new)
    return neighbors0, upper


def apply_adjacency_updates(dg: DeviceGraph, g: HNSWGraph,
                            rows) -> DeviceGraph:
    """Scatter only neighbors0/upper for the dirty ``rows`` (vectors,
    levels, scales untouched) + refresh entry/max_level. Same donation
    contract as :func:`apply_row_updates`: CONSUMES ``dg``."""
    if dg.neighbors0.shape != g.neighbors0.shape \
            or dg.upper.shape != g.upper.shape:
        raise ValueError("capacity/layer shape changed; full rebuild required")
    rows = np.asarray(sorted(int(r) for r in rows), np.int32)
    if rows.size:
        bucket = 1 << (int(rows.size) - 1).bit_length()
        pad = np.full(bucket - rows.size, rows[0], np.int32)
        rp = np.concatenate([rows, pad])
        u_new = (g.upper[:, rp] if g.upper.shape[0]
                 else np.zeros((0, bucket, 1), np.int32))
        dispatch.bump("hnsw.h2d_bytes",
                      bucket * 4 * (g.neighbors0.shape[1]
                                    + g.upper.shape[0]
                                    * (g.upper.shape[2]
                                       if g.upper.shape[0] else 0)))
        neighbors0, upper = _scatter_adj_jit(
            dg.neighbors0, dg.upper, jnp.asarray(rp),
            jnp.asarray(g.neighbors0[rp], jnp.int32),
            jnp.asarray(u_new, jnp.int32))
        dg = dataclasses.replace(dg, neighbors0=neighbors0, upper=upper)
    return dataclasses.replace(
        dg, entry=jnp.asarray(max(int(g.entry), 0), jnp.int32),
        max_level=int(g.max_level))


# ---------------------------------------------------------------------------
# distances
# ---------------------------------------------------------------------------
def batched_dist(metric: str, q: jax.Array, x: jax.Array) -> jax.Array:
    """q [B, D], x [B, K, D] -> [B, K] (f32 accumulate)."""
    if metric in ("cosine", "ip"):
        return 1.0 - jnp.einsum("bd,bkd->bk", q, x,
                                preferred_element_type=jnp.float32)
    d = x - q[:, None, :]
    return jnp.einsum("bkd,bkd->bk", d, d, preferred_element_type=jnp.float32)


def gather_distance(metric: str, vectors: jax.Array, q: jax.Array,
                    ids: jax.Array,
                    scales: jax.Array | None = None) -> jax.Array:
    """Fused gather(HBM)->distance: ids [B, K] (clamped), q [B, D] -> [B, K].

    On TPU this routes to kernels/gather_distance.py; the jnp fallback keeps
    identical semantics (invalid ids must be masked by the caller).
    ``scales`` fuses the codec decode into the distance (DESIGN.md §9).
    """
    from repro.kernels import ops
    return ops.gather_distance(vectors, q, ids, metric=metric, scales=scales)


def _prep_queries(g: DeviceGraph, queries) -> jax.Array:
    q = jnp.asarray(queries, jnp.float32)
    if q.ndim == 1:
        q = q[None]
    if g.metric == "cosine":
        q = q / jnp.maximum(
            jnp.linalg.norm(q, axis=-1, keepdims=True), 1e-12)
    return q


# ---------------------------------------------------------------------------
# upper-layer greedy descent (all queries lock-step)
# ---------------------------------------------------------------------------
def _greedy_layer(g: DeviceGraph, q: jax.Array, ep: jax.Array,
                  ep_dist: jax.Array, layer: int) -> tuple[jax.Array, jax.Array]:
    """One layer's greedy descent. ep/ep_dist [B]. Static layer index."""
    nbr_table = g.upper[layer - 1]          # [N, M]

    def cond(state):
        _, _, improved = state
        return jnp.any(improved)

    def body(state):
        ep, ep_dist, _ = state
        nbrs = jnp.take(nbr_table, ep, axis=0)                 # [B, M]
        valid = nbrs >= 0
        ids = jnp.clip(nbrs, 0, g.n - 1)
        d = gather_distance(g.metric, g.vectors, q, ids, g.scales)
        d = jnp.where(valid, d, INF)
        j = jnp.argmin(d, axis=-1)
        best_d = jnp.take_along_axis(d, j[:, None], 1)[:, 0]
        best_i = jnp.take_along_axis(ids, j[:, None], 1)[:, 0]
        improved = best_d < ep_dist
        return (jnp.where(improved, best_i, ep),
                jnp.where(improved, best_d, ep_dist),
                improved)

    init = (ep, ep_dist, jnp.ones_like(ep, bool))
    ep, ep_dist, _ = jax.lax.while_loop(cond, body, init)
    return ep, ep_dist


# ---------------------------------------------------------------------------
# layer-0 beam search
# ---------------------------------------------------------------------------
def _beam_search(g: DeviceGraph, q: jax.Array, ep: jax.Array,
                 ep_dist: jax.Array, ef: int, max_iters: int | None = None):
    """ef-beam best-first search on layer 0. Returns sorted (ids, dists)."""
    b = q.shape[0]
    m2 = g.neighbors0.shape[1]
    # explicit None check: max_iters=0 means ZERO expansions (entry point
    # only), not "default to ef"
    max_iters = ef if max_iters is None else max_iters

    beam_d = jnp.full((b, ef), INF).at[:, 0].set(ep_dist)
    beam_i = jnp.full((b, ef), -1, jnp.int32).at[:, 0].set(ep)
    beam_x = jnp.zeros((b, ef), bool)                    # expanded?

    def cond(state):
        beam_d, beam_i, beam_x, it = state
        frontier = (~beam_x) & (beam_i >= 0)
        return jnp.logical_and(it < max_iters, jnp.any(frontier))

    def body(state):
        beam_d, beam_i, beam_x, it = state
        # best unexpanded candidate per query
        cand_d = jnp.where(beam_x | (beam_i < 0), INF, beam_d)
        j = jnp.argmin(cand_d, axis=-1)                      # [B]
        has = jnp.take_along_axis(cand_d, j[:, None], 1)[:, 0] < INF
        cur = jnp.take_along_axis(beam_i, j[:, None], 1)[:, 0]
        beam_x = beam_x.at[jnp.arange(b), j].set(beam_x[jnp.arange(b), j] | has)
        # expand: gather 2M neighbors + distances
        nbrs = jnp.take(g.neighbors0, jnp.clip(cur, 0, g.n - 1), axis=0)
        valid = (nbrs >= 0) & has[:, None]
        ids = jnp.clip(nbrs, 0, g.n - 1)
        d = gather_distance(g.metric, g.vectors, q, ids, g.scales)
        d = jnp.where(valid, d, INF)
        # merge into beam: two-key sort then adjacent-dup masking
        all_d = jnp.concatenate([beam_d, d], axis=1)         # [B, ef+2M]
        all_i = jnp.concatenate([beam_i, ids], axis=1)
        all_x = jnp.concatenate(
            [beam_x, jnp.zeros((b, m2), bool)], axis=1)
        all_i = jnp.where(all_d >= INF, -1, all_i)
        sd, si, sx = jax.lax.sort((all_d, all_i, all_x), num_keys=2)
        dup = jnp.concatenate(
            [jnp.zeros((b, 1), bool), (si[:, 1:] == si[:, :-1]) & (si[:, 1:] >= 0)],
            axis=1)
        sd = jnp.where(dup, INF, sd)
        sx = jnp.where(dup, True, sx)
        sd, si, sx = jax.lax.sort((sd, si, sx), num_keys=2)
        return (sd[:, :ef], si[:, :ef], sx[:, :ef], it + 1)

    beam_d, beam_i, beam_x, _ = jax.lax.while_loop(
        cond, body, (beam_d, beam_i, beam_x, jnp.zeros((), jnp.int32)))
    return beam_i, beam_d


def _beam_search_fused(g: DeviceGraph, q: jax.Array, ep: jax.Array,
                       ep_dist: jax.Array, ef: int,
                       max_iters: int | None = None,
                       expand_t: int | None = None):
    """One-launch layer-0 beam search (kernels/beam_search.py via
    ops.beam_search): the whole ef-beam — neighbor gather, fused codec
    decode, bitonic merge — runs in a single kernel, expanding the top-T
    frontier nodes per hop. The jnp fallback off-TPU runs the identical
    algorithm (``ref.beam_search_ref``)."""
    from repro.kernels import ops
    return ops.beam_search(
        g.vectors, g.neighbors0, q, ep, ep_dist, ef=ef, metric=g.metric,
        scales=g.scales,
        expand_t=DEFAULT_EXPAND_T if expand_t is None else expand_t,
        max_iters=max_iters)


def search_core(g: DeviceGraph, q: jax.Array, k: int, ef: int,
                max_iters: int | None = None, beam_impl: str = "fused",
                beam_expand: int | None = None):
    """Traceable whole-search body (descent + beam + tombstone filter),
    shared by the single-graph jit below and the stacked segment fan-out
    (core/stacked.py), which calls it per-shard inside ``shard_map``.
    Queries must already be prepped (``_prep_queries``).

    ``beam_impl`` selects the layer-0 beam: "fused" (default) runs the
    whole beam as one kernel launch (DESIGN.md §12); "jnp" is the
    per-hop ``while_loop`` reference. ``beam_expand`` overrides the
    fused path's per-hop expansion width (default DEFAULT_EXPAND_T)."""
    if beam_impl not in ("fused", "jnp"):
        raise ValueError(f"unknown beam_impl {beam_impl!r}; "
                         "expected 'fused' or 'jnp'")
    ep = jnp.broadcast_to(g.entry, q.shape[:1])
    x0 = jnp.take(g.vectors, ep, axis=0)
    if g.scales is not None:                 # decode the entry row (§9)
        x0 = x0.astype(jnp.float32) * jnp.take(g.scales, ep)[:, None]
    ep_dist = batched_dist(g.metric, q, x0[:, None])[:, 0]
    for layer in range(g.max_level, 0, -1):      # static unroll (few layers)
        ep, ep_dist = _greedy_layer(g, q, ep, ep_dist, layer)
    if beam_impl == "fused":
        beam_i, beam_d = _beam_search_fused(g, q, ep, ep_dist, ef,
                                            max_iters, beam_expand)
    else:
        beam_i, beam_d = _beam_search(g, q, ep, ep_dist, ef, max_iters)
    # tombstone filter: deleted rows were traversable during the beam search
    # but must not be returned (DESIGN.md §3)
    dead = jnp.take(g.deleted, jnp.clip(beam_i, 0, g.n - 1)) | (beam_i < 0)
    beam_d = jnp.where(dead, INF, beam_d)
    beam_i = jnp.where(dead, -1, beam_i)
    beam_d, beam_i = jax.lax.sort((beam_d, beam_i), num_keys=1,
                                  is_stable=True)
    return beam_i[:, :k], beam_d[:, :k]


@functools.partial(jax.jit, static_argnames=("k", "ef", "max_iters",
                                             "beam_impl", "beam_expand"))
def _search_jit(g: DeviceGraph, q: jax.Array, k: int, ef: int,
                max_iters: int | None, beam_impl: str,
                beam_expand: int | None):
    return search_core(g, q, k, ef, max_iters, beam_impl, beam_expand)


def search_graph(g: DeviceGraph, queries, k: int = 10, ef: int = 64,
                 max_iters: int | None = None, beam_impl: str = "fused",
                 beam_expand: int | None = None):
    """Batched k-NN query. queries [B, D] (or [D]) -> (ids [B,k], dist [B,k]).

    ``beam_impl``/``beam_expand``: layer-0 beam selection, see
    ``search_core``. Launch economics are counted host-side
    (core/dispatch.py): one fused beam launch vs O(ef) per-hop
    dispatches on the jnp path."""
    q = _prep_queries(g, queries)
    ef = max(ef, k)
    dispatch.bump("hnsw.search_graph")
    dispatch.bump("hnsw.beam_launches",
                  dispatch.beam_launches(beam_impl, ef, max_iters))
    return _search_jit(g, q, k, ef, max_iters, beam_impl, beam_expand)


def recall_at_k(found_ids: np.ndarray, true_ids: np.ndarray) -> float:
    """Mean fraction of true k-NN recovered.

    Vectorized broadcast membership (set semantics: duplicate found ids
    count once, duplicate true ids count once — parity with the old
    per-row Python set loop, without O(B·k) interpreter work inside
    benchmark hot loops)."""
    f = np.asarray(found_ids)
    t = np.asarray(true_ids)
    if t.size == 0:
        return 0.0
    member = (t[:, :, None] == f[:, None, :]).any(axis=2)      # [B, K]
    # count each distinct true id once per row (first occurrence)
    k = t.shape[1]
    dup = ((t[:, :, None] == t[:, None, :])
           & (np.arange(k)[None, :, None] > np.arange(k)[None, None, :]))
    member &= ~dup.any(axis=2)
    return float(member.sum()) / max(t.size, 1)
