"""Exact flat index: brute-force top-k (the recall oracle + retrieval_cand).

Routes through kernels/ops.flat_topk (Pallas distance+top-k on TPU, jnp
reference on CPU). This is also the "real time at 1M" claim's workload
(paper section 5): one query against the full database.

Two layers here:
  * ``FlatIndex`` — the immutable device-array core (kept as-is: it is the
    oracle other backends call into);
  * ``FlatVectorIndex`` — the keyed, mutable ``VectorIndex`` backend
    (DESIGN.md §1): host-side storage with tombstones, device array
    rebuilt lazily from live rows on the first query after a mutation.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.hnsw_build import normalize_rows
from repro.core.index import VectorIndex
from repro.kernels import ops


@dataclasses.dataclass
class FlatIndex:
    vectors: jax.Array          # [N, D] (normalised if cosine)
    metric: str = "cosine"

    @classmethod
    def build(cls, vectors, metric: str = "cosine") -> "FlatIndex":
        v = np.asarray(vectors, np.float32)
        if metric == "cosine":
            v = normalize_rows(v)
        return cls(vectors=jnp.asarray(v), metric=metric)

    def query(self, queries, k: int = 10):
        q = jnp.asarray(queries, jnp.float32)
        squeeze = q.ndim == 1
        if squeeze:
            q = q[None]
        if self.metric == "cosine":
            q = q / jnp.maximum(jnp.linalg.norm(q, axis=-1, keepdims=True), 1e-12)
        d, i = ops.flat_topk(self.vectors, q, k, metric=self.metric)
        if squeeze:
            return d[0], i[0]
        return d, i

    @property
    def n(self) -> int:
        return self.vectors.shape[0]


def _pad_results(keys: list[list], d: np.ndarray, k: int
                 ) -> tuple[list[list], np.ndarray]:
    """Protocol shape contract: k > live pads keys with None, dists with
    INF, so every backend returns exactly k slots (DESIGN.md §1)."""
    short = k - d.shape[1]
    if short <= 0:
        return keys, d
    keys = [row + [None] * short for row in keys]
    d = np.concatenate(
        [d, np.full((d.shape[0], short), np.float32(3e38))], axis=1)
    return keys, d


class FlatVectorIndex(VectorIndex):
    """Mutable keyed flat index. Exact by construction, so ``query`` and
    ``exact_query`` coincide. Mutations mark the device array stale; the
    next query compacts live rows host-side and re-uploads once."""

    kind = "flat"

    def __init__(self, *, metric: str = "cosine", dim: int | None = None):
        if metric not in ("cosine", "ip", "l2"):
            raise ValueError(f"unknown metric {metric!r}")
        self.metric = metric
        self.dim = dim
        self._vecs = np.zeros((0, dim or 0), np.float32)   # raw host vectors
        self._keys: list[str] = []                         # row -> key
        self._key2row: dict[str, int] = {}
        self._alive = np.zeros(0, bool)
        self._flat: FlatIndex | None = None                # device cache
        self._live_rows: np.ndarray | None = None

    # ------------------------------------------------------------ mutation
    def _insert_impl(self, key: str, value: np.ndarray) -> None:
        v = np.asarray(value, np.float32).reshape(-1)
        if self.dim is None:
            self.dim = v.shape[0]
            self._vecs = np.zeros((0, self.dim), np.float32)
        if key in self._key2row:
            self._alive[self._key2row[key]] = False
        row = len(self._keys)
        self._vecs = np.concatenate([self._vecs, v[None]])
        self._keys.append(key)
        self._alive = np.concatenate([self._alive, np.ones(1, bool)])
        self._key2row[key] = row
        self._flat = None
        self._bump_epoch()

    def _bulk_insert_impl(self, keys: list[str], values: np.ndarray) -> None:
        for key in keys:
            if key in self._key2row:
                self._alive[self._key2row[key]] = False
        if self.dim is None:
            self.dim = values.shape[1]
            self._vecs = np.zeros((0, self.dim), np.float32)
        base = len(self._keys)
        self._vecs = np.concatenate([self._vecs, values])
        self._keys.extend(keys)
        self._alive = np.concatenate([self._alive, np.ones(len(keys), bool)])
        for j, key in enumerate(keys):
            self._key2row[key] = base + j
        self._flat = None
        self._bump_epoch()

    def _update_impl(self, key: str, value: np.ndarray) -> None:
        self._insert_impl(key, value)

    def _delete_impl(self, key: str) -> None:
        row = self._key2row.pop(key)
        self._alive[row] = False
        self._flat = None
        self._bump_epoch()

    def _compact_impl(self) -> None:
        """Physically drop tombstoned rows (DESIGN.md §7): live rows are
        re-packed contiguously and dead vectors cease to exist host-side."""
        live = np.flatnonzero(self._alive)
        self._vecs = np.ascontiguousarray(self._vecs[live])
        self._keys = [self._keys[i] for i in live]
        self._alive = np.ones(live.size, bool)
        self._key2row = {k: i for i, k in enumerate(self._keys)}
        self._flat = None
        self._live_rows = None
        self._bump_epoch()

    # --------------------------------------------------------------- query
    def _device(self) -> FlatIndex:
        if self._flat is None:
            live = np.flatnonzero(self._alive)
            if live.size == 0:
                raise ValueError("index is empty")
            self._live_rows = live
            self._flat = FlatIndex.build(self._vecs[live], metric=self.metric)
        return self._flat

    def query_batch(self, queries, k: int = 10, **kw):
        """One device dispatch for the whole [B, D] batch (exact top-k)."""
        flat = self._device()
        q = np.asarray(queries, np.float32)
        if q.ndim != 2:
            raise ValueError(f"query_batch expects [B, D], got {q.shape}")
        d, i = flat.query(q, min(k, flat.n))
        d, i = np.asarray(d), np.asarray(i)
        return _pad_results(
            [[self._keys[int(self._live_rows[j])] for j in row] for row in i],
            d, k)

    def exact_query(self, query, k: int = 10):
        return self.query(query, k)        # flat IS the brute-force oracle

    # --------------------------------------------------------- persistence
    def config_dict(self) -> dict:
        return {"metric": self.metric, "dim": self.dim}

    def state_dict(self) -> tuple[dict, dict]:
        arrays = {"vectors": self._vecs, "alive": self._alive}
        meta = {"keys": list(self._keys), "epoch": self._epoch}
        return arrays, meta

    def restore_state(self, arrays: dict, meta: dict) -> None:
        self._vecs = np.asarray(arrays["vectors"], np.float32)
        self._alive = np.asarray(arrays["alive"], bool)
        if self._vecs.shape[1]:
            self.dim = int(self._vecs.shape[1])
        self._keys = list(meta["keys"])
        self._key2row = {k: i for i, k in enumerate(self._keys)
                         if self._alive[i]}
        self._epoch = int(meta["epoch"])
        self._flat = None
        self._live_rows = None

    def _row_count(self) -> int:
        return len(self._keys)

    @property
    def size(self) -> int:
        return len(self._key2row)

    def _contains(self, key: str) -> bool:
        return key in self._key2row

    def keys(self) -> list[str]:
        return [k for i, k in enumerate(self._keys) if self._alive[i]]
