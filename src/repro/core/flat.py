"""Exact flat index: brute-force top-k (the recall oracle + retrieval_cand).

Routes through kernels/ops.flat_topk (Pallas distance+top-k on TPU, jnp
reference on CPU). This is also the "real time at 1M" claim's workload
(paper section 5): one query against the full database.

Two layers here:
  * ``FlatIndex`` — the immutable device-array core (kept as-is: it is the
    oracle other backends call into);
  * ``FlatVectorIndex`` — the keyed, mutable ``VectorIndex`` backend
    (DESIGN.md §1): host-side storage with tombstones, device array
    rebuilt lazily from live rows on the first query after a mutation.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.hnsw_build import normalize_rows
from repro.core.index import VectorIndex
from repro.kernels import ops


@dataclasses.dataclass
class FlatIndex:
    vectors: jax.Array          # [N, D] (normalised if cosine)
    metric: str = "cosine"

    @classmethod
    def build(cls, vectors, metric: str = "cosine") -> "FlatIndex":
        v = np.asarray(vectors, np.float32)
        if metric == "cosine":
            v = normalize_rows(v)
        return cls(vectors=jnp.asarray(v), metric=metric)

    def query(self, queries, k: int = 10):
        q = jnp.asarray(queries, jnp.float32)
        squeeze = q.ndim == 1
        if squeeze:
            q = q[None]
        if self.metric == "cosine":
            q = q / jnp.maximum(jnp.linalg.norm(q, axis=-1, keepdims=True), 1e-12)
        d, i = ops.flat_topk(self.vectors, q, k, metric=self.metric)
        if squeeze:
            return d[0], i[0]
        return d, i

    @property
    def n(self) -> int:
        return self.vectors.shape[0]


def _pad_results(keys: list[list], d: np.ndarray, k: int
                 ) -> tuple[list[list], np.ndarray]:
    """Protocol shape contract: k > live pads keys with None, dists with
    INF, so every backend returns exactly k slots (DESIGN.md §1)."""
    short = k - d.shape[1]
    if short <= 0:
        return keys, d
    keys = [row + [None] * short for row in keys]
    d = np.concatenate(
        [d, np.full((d.shape[0], short), np.float32(3e38))], axis=1)
    return keys, d


class FlatVectorIndex(VectorIndex):
    """Mutable keyed flat index. Exact by construction, so ``query`` and
    ``exact_query`` coincide. Mutations mark the device array stale; the
    next query compacts live rows host-side and re-uploads once."""

    def __init__(self, *, metric: str = "cosine", dim: int | None = None):
        if metric not in ("cosine", "ip", "l2"):
            raise ValueError(f"unknown metric {metric!r}")
        self.metric = metric
        self.dim = dim
        self._vecs = np.zeros((0, dim or 0), np.float32)   # raw host vectors
        self._keys: list[str] = []                         # row -> key
        self._key2row: dict[str, int] = {}
        self._alive = np.zeros(0, bool)
        self._flat: FlatIndex | None = None                # device cache
        self._live_rows: np.ndarray | None = None

    # ------------------------------------------------------------ mutation
    def insert(self, key: str, value: Sequence[float]) -> None:
        v = np.asarray(value, np.float32).reshape(-1)
        if self.dim is None:
            self.dim = v.shape[0]
            self._vecs = np.zeros((0, self.dim), np.float32)
        if key in self._key2row:
            self._alive[self._key2row[key]] = False
        row = len(self._keys)
        self._vecs = np.concatenate([self._vecs, v[None]])
        self._keys.append(key)
        self._alive = np.concatenate([self._alive, np.ones(1, bool)])
        self._key2row[key] = row
        self._flat = None
        self._bump_epoch()

    def bulk_insert(self, keys: Sequence[str], values) -> None:
        values = np.asarray(values, np.float32)
        if len(keys) != len(values):
            raise ValueError("keys/values length mismatch")
        for key in keys:
            if key in self._key2row:
                self._alive[self._key2row[key]] = False
        if self.dim is None:
            self.dim = values.shape[1]
            self._vecs = np.zeros((0, self.dim), np.float32)
        base = len(self._keys)
        self._vecs = np.concatenate([self._vecs, values])
        self._keys.extend(keys)
        self._alive = np.concatenate([self._alive, np.ones(len(keys), bool)])
        for j, key in enumerate(keys):
            self._key2row[key] = base + j
        self._flat = None
        self._bump_epoch()

    def update(self, key: str, value: Sequence[float]) -> None:
        if key not in self._key2row:
            raise KeyError(key)
        self.insert(key, value)

    def delete(self, key: str) -> None:
        row = self._key2row.pop(key)               # KeyError if absent
        self._alive[row] = False
        self._flat = None
        self._bump_epoch()

    # --------------------------------------------------------------- query
    def _device(self) -> FlatIndex:
        if self._flat is None:
            live = np.flatnonzero(self._alive)
            if live.size == 0:
                raise ValueError("index is empty")
            self._live_rows = live
            self._flat = FlatIndex.build(self._vecs[live], metric=self.metric)
        return self._flat

    def query_batch(self, queries, k: int = 10, **kw):
        """One device dispatch for the whole [B, D] batch (exact top-k)."""
        flat = self._device()
        q = np.asarray(queries, np.float32)
        if q.ndim != 2:
            raise ValueError(f"query_batch expects [B, D], got {q.shape}")
        d, i = flat.query(q, min(k, flat.n))
        d, i = np.asarray(d), np.asarray(i)
        return _pad_results(
            [[self._keys[int(self._live_rows[j])] for j in row] for row in i],
            d, k)

    def exact_query(self, query, k: int = 10):
        return self.query(query, k)        # flat IS the brute-force oracle

    # --------------------------------------------------------- persistence
    def export(self, path: str) -> None:
        if not self._keys:
            raise ValueError("index is empty")
        meta = {"metric": self.metric, "dim": self.dim, "keys": self._keys}
        tmp = path + ".tmp.npz"
        np.savez_compressed(tmp[:-4], vectors=self._vecs, alive=self._alive,
                            meta=np.frombuffer(json.dumps(meta).encode(),
                                               dtype=np.uint8))
        os.replace(tmp, path)

    @classmethod
    def load(cls, path: str) -> "FlatVectorIndex":
        z = np.load(path, allow_pickle=False)
        meta = json.loads(bytes(z["meta"]).decode())
        idx = cls(metric=meta["metric"], dim=meta["dim"])
        idx._vecs = np.asarray(z["vectors"], np.float32)
        idx._alive = np.asarray(z["alive"], bool)
        idx._keys = list(meta["keys"])
        idx._key2row = {k: i for i, k in enumerate(idx._keys)
                        if idx._alive[i]}
        return idx

    @property
    def size(self) -> int:
        return len(self._key2row)

    def keys(self) -> list[str]:
        return [k for i, k in enumerate(self._keys) if self._alive[i]]
