"""Exact flat index: brute-force top-k (the recall oracle + retrieval_cand).

Routes through kernels/ops.flat_topk (Pallas distance+top-k on TPU, jnp
reference on CPU). This is also the "real time at 1M" claim's workload
(paper section 5): one query against the full database.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.hnsw_build import normalize_rows
from repro.kernels import ops


@dataclasses.dataclass
class FlatIndex:
    vectors: jax.Array          # [N, D] (normalised if cosine)
    metric: str = "cosine"

    @classmethod
    def build(cls, vectors, metric: str = "cosine") -> "FlatIndex":
        v = np.asarray(vectors, np.float32)
        if metric == "cosine":
            v = normalize_rows(v)
        return cls(vectors=jnp.asarray(v), metric=metric)

    def query(self, queries, k: int = 10):
        q = jnp.asarray(queries, jnp.float32)
        squeeze = q.ndim == 1
        if squeeze:
            q = q[None]
        if self.metric == "cosine":
            q = q / jnp.maximum(jnp.linalg.norm(q, axis=-1, keepdims=True), 1e-12)
        d, i = ops.flat_topk(self.vectors, q, k, metric=self.metric)
        if squeeze:
            return d[0], i[0]
        return d, i

    @property
    def n(self) -> int:
        return self.vectors.shape[0]
