"""Exact flat index: brute-force top-k (the recall oracle + retrieval_cand).

Routes through kernels/ops.flat_topk (Pallas distance+top-k on TPU, jnp
reference on CPU). This is also the "real time at 1M" claim's workload
(paper section 5): one query against the full database.

Two layers here:
  * ``FlatIndex`` — the immutable device-array core (kept as-is: it is the
    oracle other backends call into);
  * ``FlatVectorIndex`` — the keyed, mutable ``VectorIndex`` backend
    (DESIGN.md §1), built on the shard-aware ``ShardedRows`` substrate
    (DESIGN.md §8): rows live in per-shard device blocks routed by key
    hash, queries fan out to every shard and merge through the
    hierarchical top-k tree. With ``n_shards=1`` (the default) the
    substrate collapses to the historical single-device path —
    bit-for-bit, so the existing suite doubles as the parity oracle.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.codec import (check_codec_arrays as _check_codec_arrays,
                              effective_rerank, get_codec)
from repro.core.hnsw_build import normalize_rows
from repro.core.index import VectorIndex
from repro.core.sharded import ShardedRows
from repro.kernels import ops


@dataclasses.dataclass
class FlatIndex:
    vectors: jax.Array          # [N, D] (normalised if cosine); may be
                                # codec-encoded (f32/bf16/int8, DESIGN.md §9)
    metric: str = "cosine"
    scales: jax.Array | None = None   # [N] per-row decode scales (int8)

    @classmethod
    def build(cls, vectors, metric: str = "cosine") -> "FlatIndex":
        v = np.asarray(vectors, np.float32)
        if metric == "cosine":
            v = normalize_rows(v)
        return cls(vectors=jnp.asarray(v), metric=metric)

    def query(self, queries, k: int = 10):
        q = jnp.asarray(queries, jnp.float32)
        squeeze = q.ndim == 1
        if squeeze:
            q = q[None]
        if self.metric == "cosine":
            q = q / jnp.maximum(jnp.linalg.norm(q, axis=-1, keepdims=True), 1e-12)
        d, i = ops.flat_topk(self.vectors, q, k, metric=self.metric,
                             scales=self.scales)
        if squeeze:
            return d[0], i[0]
        return d, i

    @property
    def n(self) -> int:
        return self.vectors.shape[0]


def _pad_results(keys: list[list], d: np.ndarray, k: int
                 ) -> tuple[list[list], np.ndarray]:
    """Protocol shape contract: k > live pads keys with None, dists with
    INF, so every backend returns exactly k slots (DESIGN.md §1)."""
    short = k - d.shape[1]
    if short <= 0:
        return keys, d
    keys = [row + [None] * short for row in keys]
    d = np.concatenate(
        [d, np.full((d.shape[0], short), np.float32(3e38))], axis=1)
    return keys, d


class FlatVectorIndex(VectorIndex):
    """Mutable keyed flat index. Exact by construction, so ``query`` and
    ``exact_query`` coincide. Storage, key->shard routing, and free-slot
    bookkeeping live in ``ShardedRows``; mutations mark the device
    block(s) stale and the next query re-packs once (DESIGN.md §8).

    ``dtype`` picks the row codec (fp32 | bf16 | int8, DESIGN.md §9):
    device blocks and snapshot pages hold the encoded rows; lossy
    searches run the asymmetric scan, over-fetch ``k·rerank_factor``
    candidates, and rerank exactly in fp32 from the canonical host rows.
    """

    kind = "flat"

    def __init__(self, *, metric: str = "cosine", dim: int | None = None,
                 n_shards: int = 1, dtype: str = "fp32",
                 rerank_factor: int | None = None):
        if metric not in ("cosine", "ip", "l2"):
            raise ValueError(f"unknown metric {metric!r}")
        self.metric = metric
        self.dim = dim
        self.n_shards = int(n_shards)
        self.dtype = str(dtype)
        self.rerank_factor = rerank_factor
        self._codec = get_codec(self.dtype)
        self._rows = ShardedRows(n_shards=self.n_shards, metric=metric,
                                 dim=dim, normalize_on_pack=True,
                                 codec=self._codec)

    # ------------------------------------------------------------ mutation
    def _insert_impl(self, key: str, value: np.ndarray) -> None:
        self._rows.upsert(key, np.asarray(value, np.float32).reshape(-1))
        self.dim = self._rows.dim
        self._bump_epoch()

    def _bulk_insert_impl(self, keys: list[str], values: np.ndarray) -> None:
        self._rows.upsert_many(keys, values)
        self.dim = self._rows.dim
        self._bump_epoch()

    def _update_impl(self, key: str, value: np.ndarray) -> None:
        self._insert_impl(key, value)

    def _delete_impl(self, key: str) -> None:
        self._rows.tombstone(key)
        self._bump_epoch()

    def _compact_impl(self) -> None:
        """Physically drop tombstoned rows (DESIGN.md §7): live rows are
        re-packed contiguously — host-side AND in every shard's block —
        and dead vectors cease to exist."""
        self._rows.compact()
        self._bump_epoch()

    # --------------------------------------------------------------- query
    def query_batch(self, queries, k: int = 10, **kw):
        """ONE sharded device dispatch for the whole [B, D] batch: every
        shard scans its own rows, per-shard top-k merges through the
        hierarchical tree (exact top-k either way). Under a lossy codec
        the scan is asymmetric (fp32 query vs encoded rows), over-fetches
        ``k·rerank_factor`` candidates, and reranks exactly in fp32 from
        the canonical host rows (DESIGN.md §9)."""
        q = np.asarray(queries, np.float32)
        if q.ndim != 2:
            raise ValueError(f"query_batch expects [B, D], got {q.shape}")
        rf = effective_rerank(self._codec, self.rerank_factor)
        if rf <= 1:
            d, rows = self._rows.topk(q, k)
        else:
            _, cand = self._rows.topk(q, k * rf)
            d, rows = self._rows.rerank_topk(q, cand, k)
        keys = [[self._rows.key_of_row(int(r)) if r >= 0 else None
                 for r in row] for row in rows]
        return _pad_results(keys, d, k)

    def exact_query(self, query, k: int = 10):
        return self.query(query, k)        # flat IS the brute-force oracle

    # --------------------------------------------------------- persistence
    # Canonical state only (DESIGN.md §8): shard placement is derived
    # from the keys, so the SAME state_dict restores onto any shard count.
    # Under a lossy codec the persisted rows are the ENCODED bytes +
    # scales (DESIGN.md §9) — the fp32 side is their exact decode, so
    # snapshots shrink with the codec and restore stays bit-for-bit.
    def config_dict(self) -> dict:
        return {"metric": self.metric, "dim": self.dim,
                "n_shards": self.n_shards, "dtype": self.dtype,
                "rerank_factor": self.rerank_factor}

    def state_dict(self) -> tuple[dict, dict]:
        if self._codec.lossy:
            arrays = {"vectors_enc":
                      self._codec.to_storage(self._rows.encoded),
                      "alive": self._rows.alive}
            if self._rows.scales is not None:
                arrays["scales"] = self._rows.scales
        else:
            arrays = {"vectors": self._rows.vectors,
                      "alive": self._rows.alive}
        meta = {"keys": list(self._rows.key_list), "epoch": self._epoch}
        return arrays, meta

    def restore_state(self, arrays: dict, meta: dict) -> None:
        _check_codec_arrays(self._codec, arrays, self.kind)
        if self._codec.lossy:
            self._rows.restore_encoded(arrays["vectors_enc"],
                                       arrays.get("scales"),
                                       list(meta["keys"]),
                                       np.asarray(arrays["alive"], bool))
        else:
            self._rows.restore(np.asarray(arrays["vectors"], np.float32),
                               list(meta["keys"]),
                               np.asarray(arrays["alive"], bool))
        if self._rows.dim:
            self.dim = self._rows.dim
        self._epoch = int(meta["epoch"])

    def _row_count(self) -> int:
        return self._rows.row_count

    @property
    def size(self) -> int:
        return self._rows.size

    def _contains(self, key: str) -> bool:
        return self._rows.contains(key)

    def keys(self) -> list[str]:
        return self._rows.live_keys()

    @property
    def shard_count(self) -> int:
        return self.n_shards

    def shard_stats(self) -> list[dict]:
        return self._rows.shard_stats()
