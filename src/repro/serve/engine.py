"""KV-cache serving engine: slot-based continuous batching with retrieval
overlapped behind the decode loop (DESIGN.md §11).

A fixed pool of B slots decodes in lock step (one jitted ``decode_step``
per engine tick serves every active slot through the flash-decode kernel
path); requests join free slots after a batched prefill and leave on
EOS/max-tokens, at which point queued requests are admitted — vLLM-style
continuous batching restricted to fixed shapes (TPU-friendly: no
recompilation as load changes).

RAG requests are first-class (:class:`RagRequest`): ``submit_rag`` enters
them into a tick state machine

    QUEUED -> RETRIEVING -> READY -> ACTIVE -> DONE

whose RETRIEVING stage runs on the already-async ``RetrievalEngine``
*behind* the in-flight decode dispatch: each tick the engine (1) submits
newly queued retrievals, (2) admits retrieval-completed requests into
free slots (batched prefill of the augmented prompt), (3) dispatches one
decode token for every active slot, and (4) pumps one retrieval
coalescing tick in the window between the decode dispatch and its
materialization — so retrieval latency for queued requests hides behind
decode compute and end-to-end req/s scales with ``slots`` instead of
paying retrieve-then-generate serially per batch (the sequential barrier
the old ``generate_rag`` was).

Privacy under overlap: a prompt is only ever built from retrieval
results whose mutation epoch is still current at admission — if a
document is retracted while a request waits in READY, the request is
sent back to RETRIEVING (counted in ``stats.re_retrievals``), so a
deleted doc can never appear in a later-admitted prompt.
"""
from __future__ import annotations

import dataclasses
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import LMConfig
from repro.models import transformer as tf

# RagRequest lifecycle states (the tick state machine, DESIGN.md §11)
QUEUED = "queued"            # submitted, retrieval not yet dispatched
RETRIEVING = "retrieving"    # ANN search in flight on the RetrievalEngine
READY = "ready"              # docs available, waiting for a free slot
ACTIVE = "active"            # prompt prefilled into a slot, decoding
DONE = "done"                # finished (EOS / max tokens / cache full)


@dataclasses.dataclass
class Request:
    """Plain LM generation request (no retrieval stage)."""
    rid: int
    prompt: np.ndarray                  # [S] int32
    max_new_tokens: int = 16
    eos_id: int | None = None
    out_tokens: list[int] = dataclasses.field(default_factory=list)
    done: bool = False
    rag: "RagRequest | None" = None     # backlink when fronting a RagRequest


@dataclasses.dataclass
class RagRequest:
    """First-class RAG serving request (one per user query).

    Everything request-scoped lives here — query, ``k``, the per-request
    ``tenant`` (None = single-index mode; this field replaces the old
    parallel ``tenants=`` list kwargs), generation budget, and the
    lifecycle ``state`` — so the engine API is ``submit_rag()`` /
    ``poll()`` / ``run_until_drained()`` instead of the inverted
    ``generate_rag(pipeline, queries, ...)`` batch call.
    """
    query: str
    k: int = 3
    tenant: str | None = None
    max_new_tokens: int = 16
    eos_id: int | None = None
    rid: int = -1
    state: str = QUEUED
    docs: list = dataclasses.field(default_factory=list)
    prompt: str | None = None           # augmented prompt (built at admission)
    prompt_ids: np.ndarray | None = None
    out_tokens: list[int] = dataclasses.field(default_factory=list)
    response: str | None = None
    done: bool = False
    _handle: object = dataclasses.field(default=None, repr=False)
    _epoch: int | None = dataclasses.field(default=None, repr=False)

    def result(self) -> dict:
        """Legacy ``generate_rag`` row shape (the shim returns these)."""
        return {"query": self.query, "docs": self.docs,
                "prompt": self.prompt, "response": self.response}


@dataclasses.dataclass
class EngineStats:
    """Per-engine counters; ``as_dict`` derives the two headline ratios:

    ``overlap_ratio`` — fraction of retrieval coalescing ticks that ran
      while a decode dispatch was in flight (1.0 = every retrieval fully
      hidden behind decode; 0.0 = every retrieval paid serially, the old
      barrier behaviour).
    ``slot_occupancy`` — mean fraction of slots active per decode tick.
    """
    slots: int = 0
    ticks: int = 0
    decode_ticks: int = 0
    tokens_out: int = 0
    prefills: int = 0                # batched prefill dispatches
    admitted: int = 0                # requests admitted into slots
    finished: int = 0
    retrieval_ticks: int = 0         # retrieval coalescing ticks pumped
    overlapped_ticks: int = 0        # ...that ran during an in-flight decode
    re_retrievals: int = 0           # READY results invalidated by a mutation
    occupied_slot_ticks: int = 0     # sum over decode ticks of active slots

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["overlap_ratio"] = (self.overlapped_ticks
                              / max(self.retrieval_ticks, 1))
        d["slot_occupancy"] = (self.occupied_slot_ticks
                               / max(self.decode_ticks * self.slots, 1))
        return d


SAMPLERS = ("greedy", "temperature")


class ServeEngine:
    """Continuous-batching serving engine over one LM (+ optional RAG
    pipeline).

    Parameters
    ----------
    pipeline:    a ``RAGPipeline`` bound at construction; required for
                 ``submit_rag``. Plain ``submit``/``generate`` work
                 without one.
    sampler:     "greedy" (argmax) or "temperature" (categorical at
                 ``temperature``). Sampling keys fold (request rid, token
                 position) into ``seed`` — NOT the slot or tick — so
                 sampled output is identical under any admission schedule
                 (the overlap-parity oracle holds for both samplers).
    """

    def __init__(self, params, cfg: LMConfig, *, pipeline=None,
                 slots: int = 4, max_len: int = 256, dtype=jnp.float32,
                 sampler: str = "greedy", temperature: float = 1.0,
                 seed: int = 0):
        if sampler not in SAMPLERS:
            raise ValueError(f"unknown sampler {sampler!r}; "
                             f"expected one of {SAMPLERS}")
        if sampler == "temperature" and temperature <= 0:
            raise ValueError(f"temperature must be > 0, got {temperature}")
        self.params = params
        self.cfg = cfg
        self.pipeline = pipeline
        self.slots = slots
        self.max_len = max_len
        self.dtype = dtype
        self.sampler = sampler
        self.temperature = float(temperature)
        self.key = jax.random.PRNGKey(seed)
        self.queue: deque[Request] = deque()          # plain LM requests
        self.rag_queue: deque[RagRequest] = deque()   # QUEUED
        self.retrieving: list[RagRequest] = []        # RETRIEVING
        self.ready: deque[RagRequest] = deque()       # READY (FIFO admission)
        self._finished: deque[RagRequest] = deque()   # for poll()
        self.active: list[Request | None] = [None] * slots
        self._next_rid = 0
        self.stats = EngineStats(slots=slots)
        self.cache = tf.init_cache(cfg, slots, max_len, dtype)
        self._decode = jax.jit(
            lambda p, t, c: tf.decode_step(p, cfg, t, c, dtype=dtype))
        self._prefill = jax.jit(
            lambda p, t, lens: tf.prefill(p, cfg, t, dtype=dtype,
                                          max_len=max_len, prompt_lens=lens))

    # legacy counters (benchmarks/tests read these)
    @property
    def ticks(self) -> int:
        return self.stats.ticks

    @property
    def tokens_out(self) -> int:
        return self.stats.tokens_out

    # ------------------------------------------------------------ sampling
    def _sample(self, logits_row: np.ndarray, rid: int, t: int) -> int:
        """Sample token ``t`` of request ``rid`` from one [V] logits row.

        The PRNG key folds (rid, t) — never the slot index or engine tick
        — so the draw is a pure function of the request and position:
        identical under the sequential barrier, the overlapped loop, and
        any randomized admission schedule (oracle-parity contract)."""
        if self.sampler == "greedy":
            return int(np.argmax(logits_row))
        key = jax.random.fold_in(jax.random.fold_in(self.key, rid), t)
        return int(jax.random.categorical(
            key, jnp.asarray(logits_row, jnp.float32) / self.temperature))

    # ------------------------------------------------------------ intake
    def submit(self, prompt_ids, max_new_tokens: int = 16,
               eos_id: int | None = None) -> Request:
        r = Request(self._next_rid, np.asarray(prompt_ids, np.int32),
                    max_new_tokens, eos_id)
        self._next_rid += 1
        self.queue.append(r)
        return r

    def submit_rag(self, query: str, *, k: int = 3,
                   tenant: str | None = None, max_new_tokens: int = 16,
                   eos_id: int | None = None) -> RagRequest:
        """Enqueue one RAG request; returns its handle immediately.

        The request's retrieval is dispatched on a later tick and runs
        behind in-flight decode compute; watch ``.state`` / ``.done`` or
        collect finished requests via :meth:`poll`."""
        if self.pipeline is None:
            raise ValueError("submit_rag needs a pipeline: construct "
                             "ServeEngine(..., pipeline=RAGPipeline(...))")
        r = RagRequest(query=query, k=k, tenant=tenant,
                       max_new_tokens=max_new_tokens, eos_id=eos_id,
                       rid=self._next_rid)
        self._next_rid += 1
        self.rag_queue.append(r)
        return r

    def poll(self) -> list[RagRequest]:
        """RAG requests finished since the last poll, completion order."""
        out = list(self._finished)
        self._finished.clear()
        return out

    # ------------------------------------------------------------ RAG flow
    def _pump_rag(self) -> None:
        """QUEUED -> RETRIEVING: hand every new request's query to the
        RetrievalEngine (submission only — no dispatch, no blocking)."""
        while self.rag_queue:
            r = self.rag_queue.popleft()
            r._handle = self.pipeline.submit_retrieval(r.query, r.k,
                                                       tenant=r.tenant)
            r.state = RETRIEVING
            self.retrieving.append(r)

    def _poll_retrieval(self, decode_in_flight: bool) -> None:
        """Pump one retrieval coalescing tick (if anything is pending)
        and move resolved requests RETRIEVING -> READY. Called in the
        window between the decode dispatch and its materialization: when
        ``decode_in_flight`` the retrieval work is hidden behind decode
        compute (counted in ``stats.overlapped_ticks``)."""
        if self.pipeline is None or not self.retrieving:
            return
        if self.pipeline.retriever.pending:
            self.pipeline.poll_retrieval()
            self.stats.retrieval_ticks += 1
            if decode_in_flight:
                self.stats.overlapped_ticks += 1
        still: list[RagRequest] = []
        for r in self.retrieving:
            if r._handle.done:
                # record validity now: the search ran this tick and host
                # code is single-threaded, so the current epoch IS the
                # epoch the results are valid for
                r._epoch = self.pipeline.current_epoch(r.tenant)
                r.state = READY
                self.ready.append(r)
            else:
                still.append(r)
        self.retrieving = still

    def _prepare_rag(self, r: RagRequest) -> bool:
        """Materialize a READY request's docs + prompt for admission.
        Returns False (and re-queues the retrieval) if the index mutated
        since the search ran — the privacy invariant: a prompt is only
        built from results whose epoch is still current, so a doc
        retracted mid-stream can never reach a later-admitted prompt."""
        if self.pipeline.current_epoch(r.tenant) != r._epoch:
            r._handle = self.pipeline.submit_retrieval(r.query, r.k,
                                                       tenant=r.tenant)
            r._epoch = None
            r.state = RETRIEVING
            self.retrieving.append(r)
            self.stats.re_retrievals += 1
            return False
        from repro.data.corpus import encode_ids
        r.docs = r._handle.docs()
        r.prompt = self.pipeline.build_prompt(r.query, r.docs)
        ids = encode_ids(r.prompt, self.cfg.vocab, self.max_len - 1)
        r.prompt_ids = ids[ids > 0]
        return True

    # ------------------------------------------------------------ admission
    def _admit(self):
        """Fill free slots: batched prefill of up to ``slots`` prompts.
        READY RAG requests admit first (they already waited through
        retrieval), then the plain queue."""
        free = [i for i, a in enumerate(self.active) if a is None]
        if not free:
            return
        take: list[Request] = []
        while len(take) < len(free) and (self.ready or self.queue):
            if self.ready:
                rr = self.ready.popleft()
                if not self._prepare_rag(rr):
                    continue            # epoch moved: back to RETRIEVING
                req = Request(rr.rid, rr.prompt_ids, rr.max_new_tokens,
                              rr.eos_id, out_tokens=rr.out_tokens, rag=rr)
                rr.state = ACTIVE
                take.append(req)
            else:
                take.append(self.queue.popleft())
        if not take:
            return
        # Fixed-shape prefill (the "no recompilation as load changes"
        # promise): always ``slots`` rows, prompt length bucketed to a
        # power of two (capped at max_len-1) — so one engine compiles at
        # most a handful of prefill shapes however admission interleaves.
        # Pad rows/positions are dead: prompt_lens picks the real last
        # position and cur_len masks pad KV out of every later decode.
        need = max(len(r.prompt) for r in take)
        plen = 16
        while plen < need:
            plen *= 2
        plen = max(need, min(plen, self.max_len - 1))
        batch = np.zeros((self.slots, plen), np.int32)
        lens = np.zeros(self.slots, np.int32)
        for j, r in enumerate(take):
            batch[j, : len(r.prompt)] = r.prompt
            lens[j] = len(r.prompt)
        logits, cache = self._prefill(self.params, jnp.asarray(batch),
                                      jnp.asarray(lens))
        first = np.asarray(logits[:, 0], np.float32)        # [B,V]
        self.stats.prefills += 1
        k, v, cur = self.cache.k, self.cache.v, self.cache.cur_len
        ks, vs = self.cache.k_scale, self.cache.v_scale
        span = cache.k.shape[2]
        for j, r in enumerate(take):
            slot = free[j]
            self.active[slot] = r
            self.stats.admitted += 1
            r.out_tokens.append(self._sample(first[j], r.rid, 0))
            # copy this request's prefilled KV rows into its slot
            k = k.at[:, slot, :span].set(cache.k[:, j])
            v = v.at[:, slot, :span].set(cache.v[:, j])
            if ks is not None:
                ks = ks.at[:, slot, :span].set(cache.k_scale[:, j])
                vs = vs.at[:, slot, :span].set(cache.v_scale[:, j])
            cur = cur.at[slot].set(int(lens[j]))
        self.cache = tf.KVCache(k=k, v=v, cur_len=cur, k_scale=ks, v_scale=vs)

    # ------------------------------------------------------------- tick
    def step(self):
        """One engine tick of the overlapped loop:

        1. QUEUED -> RETRIEVING (submit new retrievals, non-blocking)
        2. READY -> ACTIVE (batched prefill into free slots)
        3. dispatch one decode token for every active slot (async)
        4. pump one retrieval coalescing tick *while the decode runs*
        5. materialize the decode, sample, evict finished slots
        """
        if self.pipeline is not None:
            self._pump_rag()
        self._admit()
        n_active = sum(a is not None for a in self.active)
        logits = None
        if n_active:
            last = np.zeros((self.slots, 1), np.int32)
            for i, r in enumerate(self.active):
                if r is not None and r.out_tokens:
                    last[i, 0] = r.out_tokens[-1]
            logits, self.cache = self._decode(self.params,
                                              jnp.asarray(last), self.cache)
            # decode is dispatched, not materialized: the host is free
            self.stats.decode_ticks += 1
            self.stats.occupied_slot_ticks += n_active
        # ---- overlap window: retrieval runs behind the in-flight decode
        self._poll_retrieval(decode_in_flight=bool(n_active))
        self.stats.ticks += 1
        if logits is None:
            return
        nxt = np.asarray(logits[:, 0], np.float32)   # blocks on the decode
        cur = np.asarray(self.cache.cur_len)
        for i, r in enumerate(self.active):
            if r is None:
                continue
            tok = self._sample(nxt[i], r.rid, len(r.out_tokens))
            r.out_tokens.append(tok)
            self.stats.tokens_out += 1
            if (r.eos_id is not None and tok == r.eos_id) \
                    or len(r.out_tokens) >= r.max_new_tokens \
                    or cur[i] >= self.max_len - 1:
                r.done = True
                if r.rag is not None:
                    rr = r.rag
                    rr.state = DONE
                    rr.done = True
                    rr.response = " ".join(f"<{t}>" for t in rr.out_tokens)
                    self._finished.append(rr)
                self.stats.finished += 1
                self.active[i] = None
                # park the slot at position 0 (keeps idle decodes in-bounds;
                # re-admission overwrites + re-masks the rows)
                self.cache = dataclasses.replace(
                    self.cache, cur_len=self.cache.cur_len.at[i].set(0))

    def _work_pending(self) -> bool:
        return bool(self.queue or self.rag_queue or self.retrieving
                    or self.ready or any(a is not None for a in self.active))

    def run_until_drained(self, max_ticks: int = 10_000) -> None:
        while self._work_pending() and self.stats.ticks < max_ticks:
            self.step()

    def generate(self, prompts: list, max_new_tokens: int = 16) -> list[list[int]]:
        reqs = [self.submit(p, max_new_tokens) for p in prompts]
        self.run_until_drained()
        return [r.out_tokens for r in reqs]

    # ------------------------------------------------------------ RAG shim
    def generate_rag(self, pipeline, queries: list[str], *, k: int = 3,
                     max_new_tokens: int = 16,
                     tenants: list[str] | None = None) -> list[dict]:
        """DEPRECATED shim over the first-class request API: binds
        ``pipeline`` to the engine (if none is bound yet), submits one
        :class:`RagRequest` per query — ``tenants`` maps onto the
        per-request ``tenant`` field — and drains. New code should
        construct ``ServeEngine(..., pipeline=...)`` and use
        ``submit_rag()`` / ``poll()`` / ``run_until_drained()`` directly;
        unlike this batch call, the streaming API lets retrieval for
        late-arriving requests hide behind decode ticks already running.
        """
        if self.pipeline is None:
            self.pipeline = pipeline
        elif self.pipeline is not pipeline:
            raise ValueError(
                "engine is already bound to a different pipeline; "
                "construct one ServeEngine(..., pipeline=...) per pipeline")
        ts = tenants if tenants is not None else [None] * len(queries)
        if len(ts) != len(queries):
            raise ValueError("queries/tenants length mismatch")
        reqs = [self.submit_rag(q, k=k, tenant=t,
                                max_new_tokens=max_new_tokens)
                for q, t in zip(queries, ts)]
        self.run_until_drained()
        self.poll()                      # shim callers never poll; drain it
        return [r.result() for r in reqs]
