"""KV-cache serving engine with slot-based continuous batching.

A fixed pool of B slots decodes in lock step (one jitted ``decode_step`` per
engine tick serves every active slot); requests join free slots after a
batched prefill and leave on EOS/max-tokens, at which point queued requests
are admitted — vLLM-style continuous batching restricted to fixed shapes
(TPU-friendly: no recompilation as load changes).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import LMConfig
from repro.models import transformer as tf


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                  # [S] int32
    max_new_tokens: int = 16
    eos_id: int | None = None
    out_tokens: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, params, cfg: LMConfig, *, slots: int = 4,
                 max_len: int = 256, dtype=jnp.float32,
                 sampler: str = "greedy", seed: int = 0):
        self.params = params
        self.cfg = cfg
        self.slots = slots
        self.max_len = max_len
        self.dtype = dtype
        self.sampler = sampler
        self.key = jax.random.PRNGKey(seed)
        self.queue: deque[Request] = deque()
        self.active: list[Request | None] = [None] * slots
        self._next_rid = 0
        self.cache = tf.init_cache(cfg, slots, max_len, dtype)
        self._decode = jax.jit(
            lambda p, t, c: tf.decode_step(p, cfg, t, c, dtype=dtype))
        self._prefill = jax.jit(
            lambda p, t, lens: tf.prefill(p, cfg, t, dtype=dtype,
                                          max_len=max_len, prompt_lens=lens))
        self.ticks = 0
        self.tokens_out = 0

    # ------------------------------------------------------------ intake
    def submit(self, prompt_ids, max_new_tokens: int = 16,
               eos_id: int | None = None) -> Request:
        r = Request(self._next_rid, np.asarray(prompt_ids, np.int32),
                    max_new_tokens, eos_id)
        self._next_rid += 1
        self.queue.append(r)
        return r

    def _admit(self):
        """Fill free slots: batched prefill of up to `slots` queued prompts."""
        free = [i for i, a in enumerate(self.active) if a is None]
        if not free or not self.queue:
            return
        take = [self.queue.popleft() for _ in range(min(len(free), len(self.queue)))]
        # right-pad to a common length; per-request prompt_lens mask the pads
        plen = max(len(r.prompt) for r in take)
        batch = np.zeros((len(take), plen), np.int32)
        lens = np.zeros(len(take), np.int32)
        for j, r in enumerate(take):
            batch[j, : len(r.prompt)] = r.prompt
            lens[j] = len(r.prompt)
        logits, cache = self._prefill(self.params, jnp.asarray(batch),
                                      jnp.asarray(lens))
        first = np.asarray(jnp.argmax(logits[:, 0], -1))
        k, v, cur = self.cache.k, self.cache.v, self.cache.cur_len
        ks, vs = self.cache.k_scale, self.cache.v_scale
        span = cache.k.shape[2]
        for j, r in enumerate(take):
            slot = free[j]
            self.active[slot] = r
            r.out_tokens.append(int(first[j]))
            # copy this request's prefilled KV rows into its slot
            k = k.at[:, slot, :span].set(cache.k[:, j])
            v = v.at[:, slot, :span].set(cache.v[:, j])
            if ks is not None:
                ks = ks.at[:, slot, :span].set(cache.k_scale[:, j])
                vs = vs.at[:, slot, :span].set(cache.v_scale[:, j])
            cur = cur.at[slot].set(int(lens[j]))
        self.cache = tf.KVCache(k=k, v=v, cur_len=cur, k_scale=ks, v_scale=vs)

    # ------------------------------------------------------------- tick
    def step(self):
        """One engine tick: admit, decode one token for every active slot."""
        self._admit()
        if not any(a is not None for a in self.active):
            return
        last = np.zeros((self.slots, 1), np.int32)
        for i, r in enumerate(self.active):
            if r is not None and r.out_tokens:
                last[i, 0] = r.out_tokens[-1]
        logits, self.cache = self._decode(self.params, jnp.asarray(last),
                                          self.cache)
        nxt = np.asarray(jnp.argmax(logits[:, 0], -1))
        cur = np.asarray(self.cache.cur_len)
        self.ticks += 1
        for i, r in enumerate(self.active):
            if r is None:
                continue
            tok = int(nxt[i])
            r.out_tokens.append(tok)
            self.tokens_out += 1
            if (r.eos_id is not None and tok == r.eos_id) \
                    or len(r.out_tokens) >= r.max_new_tokens \
                    or cur[i] >= self.max_len - 1:
                r.done = True
                self.active[i] = None
                # park the slot at position 0 (keeps idle decodes in-bounds;
                # re-admission overwrites + re-masks the rows)
                self.cache = dataclasses.replace(
                    self.cache, cur_len=self.cache.cur_len.at[i].set(0))

    def run_until_drained(self, max_ticks: int = 10_000) -> None:
        while (self.queue or any(a is not None for a in self.active)) \
                and self.ticks < max_ticks:
            self.step()

    def generate(self, prompts: list, max_new_tokens: int = 16) -> list[list[int]]:
        reqs = [self.submit(p, max_new_tokens) for p in prompts]
        self.run_until_drained()
        return [r.out_tokens for r in reqs]

    # ------------------------------------------------------------ RAG path
    def generate_rag(self, pipeline, queries: list[str], *, k: int = 3,
                     max_new_tokens: int = 16,
                     tenants: list[str] | None = None) -> list[dict]:
        """Serve RAG requests through the continuous-batching engine.

        ``pipeline`` is a RAGPipeline over any VectorIndex backend: every
        retrieval for the batch runs in ONE RetrievalEngine tick (bucket-
        coalesced batched ANN + result cache, DESIGN.md §6), then every
        augmented prompt is submitted at once so the slot scheduler batches
        the generation — instead of the one-request-at-a-time
        ``pipeline.answer`` loop. When the pipeline fronts an IndexPool,
        ``tenants`` gives one tenant id per query; requests from different
        tenants still coalesce into the same retrieval dispatch.
        """
        from repro.data.corpus import encode_ids
        retrieved = pipeline.retrieve_batch(queries, k, tenants=tenants) \
            if tenants is not None else pipeline.retrieve_batch(queries, k)
        prompts = [pipeline.build_prompt(q, docs)
                   for q, docs in zip(queries, retrieved)]
        reqs = []
        for p in prompts:
            ids = encode_ids(p, self.cfg.vocab, self.max_len - 1)
            reqs.append(self.submit(ids[ids > 0], max_new_tokens))
        self.run_until_drained()
        return [{"query": q, "docs": docs, "prompt": p,
                 "response": " ".join(f"<{t}>" for t in r.out_tokens)}
                for q, docs, p, r in zip(queries, retrieved, prompts, reqs)]
