"""Batched retrieval serving layer — the retrieval-side twin of
``ServeEngine``'s continuous batching (DESIGN.md §6).

``RAGPipeline.retrieve`` used to run one device search per query even
though every backend's lock-step search already executes a whole batch in
one compiled dispatch. ``RetrievalEngine`` closes that gap:

  * requests are **submitted asynchronously** (``submit`` returns a
    ``RetrievalRequest`` future-like handle, vLLM-style);
  * each tick **coalesces** everything pending into per-(k, ef) groups and
    pads each group up to a fixed **power-of-two batch bucket** — so the
    jitted lock-step search compiles once per bucket instead of once per
    observed batch size (the same trick as ``apply_row_updates``' dirty-row
    padding, DESIGN.md §3);
  * each group runs as ONE ``index.query_batch`` dispatch through any
    ``VectorIndex`` backend, and results fan back out to the callers. On
    a sharded index (DESIGN.md §8) that single dispatch IS the mesh-wide
    fan-out — every shard scans its rows and the per-shard top-k merges
    on-device — so the engine stays one-dispatch-per-group at any shard
    count, and shard-ROUTED mutations keep cache invalidation correct:
    a mutation that touches only one shard still bumps the index's
    GLOBAL ``mutation_epoch`` (sharded backends mirror every per-shard
    epoch delta onto the outer index), so the whole LRU drops exactly
    as it would on a single device;
  * an **LRU result cache** keyed on (tenant, query-vector hash, k, ef)
    serves repeats without touching the device. The tenant dimension is
    load-bearing isolation (DESIGN.md §10): two tenants submitting the
    IDENTICAL query vector must never share a cached result — their
    corpora differ — so the key carries the tenant id (None for a
    single-index engine, where the index identity is fixed per engine).
    The cache is validated against the index's ``mutation_epoch``: every
    insert/update/delete bumps the epoch and drops the cache, so a
    retracted document can never be served from a stale entry — deletion
    stays the paper's first-class privacy operation even with caching in
    front of the index (DESIGN.md §6). Fronting an ``IndexPool`` the
    validation is PER TENANT (``pool.epoch(tid)``): one user's delete
    drops only their entries, everyone else keeps their hits.
    The epoch is durable: a store-backed index (DESIGN.md §7) restores at
    the exact epoch it died at, and the engine adopts it at construction
    (``_cache_epoch = index.mutation_epoch``) — never assume epoch 0 —
    so cache-validity semantics survive process restarts, and an in-place
    ``compact()`` (which bumps the epoch) flushes the cache like any other
    mutation.

Fronting an :class:`repro.core.tenancy.IndexPool` (detected by its
``query_batch_multi``), every ``submit`` carries a ``tenant`` id and each
per-(k, ef) tick group runs as ONE cross-tenant dispatch — per-tick
coalescing batches queries across tenants where the slab layout allows
(rows group device-side by padded slab width).

Typical use (this is what ``RAGPipeline``/``ServeEngine.generate_rag`` do):

    eng = RetrievalEngine(index, max_batch=128)
    reqs = [eng.submit(qv, k=10) for qv in query_vectors]
    eng.run_until_drained()
    for r in reqs:
        r.keys, r.dists      # k keys (None-padded) + [k] f32 distances

Everything is synchronous under the hood (one process, one device stream);
"async" here means *decoupled submission from execution*, which is what
lets the serving loop gather a full tick's worth of queries before paying
for a dispatch.
"""
from __future__ import annotations

import collections
import dataclasses
import hashlib

import numpy as np

from repro.core.index import VectorIndex

# Bucket ladder: pending batches are padded up to the next power of two so
# the jitted search sees at most log2(max_batch)+1 distinct batch shapes.
MAX_BATCH_DEFAULT = 128


def bucket_size(n: int, max_batch: int) -> int:
    """Smallest power of two >= n, capped at max_batch."""
    b = 1
    while b < n and b < max_batch:
        b <<= 1
    return min(b, max_batch)


@dataclasses.dataclass
class RetrievalRequest:
    """Handle returned by ``submit``; filled in when its tick executes."""
    rid: int
    query: np.ndarray                 # [D] f32 (contiguous; hashed for cache)
    k: int
    ef: int | None = None
    tenant: str | None = None         # IndexPool namespace (None = single)
    keys: list | None = None          # k entries, None-padded (DESIGN.md §1)
    dists: np.ndarray | None = None   # [k] f32, INF-padded
    done: bool = False
    from_cache: bool = False
    error: Exception | None = None    # set if this request's dispatch raised
    _ck: tuple | None = dataclasses.field(default=None, repr=False)


@dataclasses.dataclass
class RetrievalStats:
    requests: int = 0
    ticks: int = 0
    searches: int = 0        # device dispatches (one per group per tick)
    searched_queries: int = 0  # real rows sent to the device (excl. padding)
    padded_queries: int = 0    # rows added to reach the bucket size
    cache_hits: int = 0      # served from the LRU without any search
    dedup_hits: int = 0      # shared an identical in-flight tick-mate's row
    cache_misses: int = 0    # actually searched on the device
    evictions: int = 0
    invalidations: int = 0   # whole-cache drops due to an epoch bump

    def as_dict(self) -> dict:
        served = self.cache_hits + self.dedup_hits
        total = max(served + self.cache_misses, 1)
        return {**dataclasses.asdict(self), "hit_rate": served / total}


class RetrievalEngine:
    """Continuous-batching front end over any ``VectorIndex``.

    Parameters
    ----------
    index:      any VectorIndex backend (flat / ivf / hnsw / tiered).
    max_batch:  bucket ladder cap; also the most queries one device
                dispatch carries (bigger pending groups run in chunks).
    cache_size: LRU capacity in (query, k, ef) entries; 0 disables caching.
    """

    def __init__(self, index: VectorIndex, *, max_batch: int = MAX_BATCH_DEFAULT,
                 cache_size: int = 1024):
        if max_batch < 1 or max_batch & (max_batch - 1):
            raise ValueError(f"max_batch must be a power of two, got {max_batch}")
        self.index = index
        # IndexPool front-end (DESIGN.md §10): requests carry a tenant id,
        # dispatches go through query_batch_multi, and cache validity is
        # tracked per tenant instead of one global epoch.
        self._multi = hasattr(index, "query_batch_multi")
        self.shards = getattr(index, "shard_count", 1)
        # codec transparency (DESIGN.md §9): the engine never touches the
        # row encoding — query_batch returns decoded results and every
        # mutation bumps mutation_epoch regardless of dtype, so the
        # cache-epoch privacy invariant is codec-independent. Surfaced
        # here only for logging.
        self.index_dtype = getattr(index, "storage_dtype", "fp32")
        self.max_batch = max_batch
        self.cache_size = cache_size
        self.queue: collections.deque[RetrievalRequest] = collections.deque()
        self.stats = RetrievalStats()
        self._next_rid = 0
        # LRU: (tenant, qhash, dim, k, ef) -> (keys, dists); an entry is
        # valid only for the epoch its tenant (or the whole index, when
        # tenant is None) was at when it was stored
        self._cache: "collections.OrderedDict[tuple, tuple]" = \
            collections.OrderedDict()
        self._cache_epoch = index.mutation_epoch
        self._tenant_epochs: dict[str, int] = {}

    # ------------------------------------------------------------- intake
    def submit(self, query, k: int = 10, ef: int | None = None,
               tenant: str | None = None) -> RetrievalRequest:
        """Enqueue one query vector; returns a handle resolved by ``step``.
        Fronting an ``IndexPool``, ``tenant`` is REQUIRED (there is no
        un-namespaced corpus to search); on a plain index it is
        rejected (the backend cannot route it)."""
        if self._multi and tenant is None:
            raise ValueError("this engine fronts an IndexPool: "
                             "submit(..., tenant=...) is required")
        if not self._multi and tenant is not None:
            raise ValueError(f"tenant={tenant!r} needs an IndexPool index; "
                             f"{type(self.index).__name__} is single-tenant")
        q = np.ascontiguousarray(np.asarray(query, np.float32).reshape(-1))
        r = RetrievalRequest(self._next_rid, q, int(k), ef, tenant)
        self._next_rid += 1
        self.stats.requests += 1
        self.queue.append(r)
        return r

    # -------------------------------------------------------------- cache
    @staticmethod
    def _cache_key(r: RetrievalRequest) -> tuple:
        """Cache identity of one request. The leading tenant component is
        the isolation boundary (DESIGN.md §10): identical query bytes
        under two tenants are two DIFFERENT entries, and per-tenant
        invalidation drops exactly the keys whose first component
        matches. (The index itself is engine-fixed, so the tenant id is
        the whole index-identity dimension of the key.)"""
        h = hashlib.blake2b(r.query.tobytes(), digest_size=16)
        return (r.tenant, h.digest(), r.query.shape[0], r.k, r.ef)

    def _check_epoch(self) -> None:
        """Drop cached results whose index state mutated since they were
        stored. delete() bumping the epoch is the privacy guarantee: a
        retracted document cannot be served from cache (DESIGN.md §6).
        On an ``IndexPool`` the check is per tenant: tenant A's delete
        drops A's entries and ONLY A's — B's hits survive."""
        if self._multi:
            for tid, known in list(self._tenant_epochs.items()):
                cur = self.index.epoch(tid)
                if cur != known:
                    dropped = [ck for ck in self._cache if ck[0] == tid]
                    for ck in dropped:
                        del self._cache[ck]
                    if dropped:
                        self.stats.invalidations += 1
                    self._tenant_epochs[tid] = cur
            self._cache_epoch = self.index.mutation_epoch
            return
        ep = self.index.mutation_epoch
        if ep != self._cache_epoch:
            if self._cache:
                self.stats.invalidations += 1
            self._cache.clear()
            self._cache_epoch = ep

    def _cache_get(self, key: tuple):
        if self.cache_size <= 0:
            return None
        hit = self._cache.get(key)
        if hit is not None:
            self._cache.move_to_end(key)
        return hit

    def _cache_put(self, key: tuple, keys: list, dists: np.ndarray) -> None:
        if self.cache_size <= 0:
            return
        if key in self._cache:
            self._cache.move_to_end(key)
        elif len(self._cache) >= self.cache_size:
            self._cache.popitem(last=False)
            self.stats.evictions += 1
        # private copies: callers own the request's keys/dists and may
        # mutate them; the cache must serve pristine results
        self._cache[key] = (list(keys), np.array(dists))

    # --------------------------------------------------------------- tick
    def step(self) -> int:
        """One engine tick: serve cache hits, coalesce the misses into
        power-of-two buckets per (k, ef), dispatch, fan out. Returns the
        number of requests completed this tick.

        Identical queries pending in the SAME tick are deduplicated: one
        leader row goes to the device, followers share its result (counted
        as ``dedup_hits``) — under bursty concurrent load, repeats that
        arrive together cost one search even before they reach the LRU.

        If a backend dispatch raises (e.g. ``ValueError("index is empty")``
        after every document was retracted), every request of the failing
        group — and its dedup followers — is resolved with ``error`` set,
        the OTHER groups still run, and the first exception re-raises after
        the tick settles: no request is ever silently dropped.
        """
        if not self.queue:
            return 0
        self._check_epoch()
        pending, self.queue = list(self.queue), collections.deque()
        groups: dict[tuple, list[RetrievalRequest]] = {}
        followers: dict[tuple, list[RetrievalRequest]] = {}  # ck -> dups
        leaders: dict[tuple, RetrievalRequest] = {}
        done = 0
        for r in pending:
            r._ck = ck = self._cache_key(r)
            hit = self._cache_get(ck)
            if hit is not None:
                r.keys, r.dists = list(hit[0]), hit[1].copy()
                r.from_cache = r.done = True
                self.stats.cache_hits += 1
                done += 1
            elif ck in leaders:
                followers.setdefault(ck, []).append(r)
                self.stats.dedup_hits += 1
            else:
                leaders[ck] = r
                self.stats.cache_misses += 1
                groups.setdefault((r.k, r.ef), []).append(r)
        first_err: Exception | None = None
        for (k, ef), reqs in groups.items():
            for lo in range(0, len(reqs), self.max_batch):
                chunk = reqs[lo:lo + self.max_batch]
                try:
                    done += self._dispatch(chunk, k, ef)
                except Exception as e:
                    for r in chunk:
                        r.error, r.done = e, True
                        done += 1
                    first_err = first_err or e
        for ck, dups in followers.items():
            leader = leaders[ck]
            for r in dups:
                if leader.error is not None:
                    r.error = leader.error
                else:
                    r.keys, r.dists = list(leader.keys), leader.dists.copy()
                    r.from_cache = True
                r.done = True
                done += 1
        self.stats.ticks += 1
        if first_err is not None:
            raise first_err
        return done

    def _dispatch(self, reqs: list[RetrievalRequest], k: int,
                  ef: int | None) -> int:
        """Pad one group to its bucket, run ONE batched device search, fan
        the rows back out to the callers and into the cache."""
        n = len(reqs)
        bucket = bucket_size(n, self.max_batch)
        q = np.stack([r.query for r in reqs])
        if bucket > n:
            # pad by repeating row 0: numerically benign, result rows are
            # sliced off below, and the compiled shape stays on the ladder
            q = np.concatenate([q, np.repeat(q[:1], bucket - n, axis=0)])
        kw = {} if ef is None else {"ef": ef}
        if self._multi:
            # cross-tenant coalescing (DESIGN.md §10): the whole group —
            # rows of DIFFERENT tenants — goes down as one dispatch;
            # padding rows replicate row 0's tenant along with its query
            tenants = [r.tenant for r in reqs] \
                + [reqs[0].tenant] * (bucket - n)
            keys, dists = self.index.query_batch_multi(q, tenants, k=k,
                                                       **kw)
        else:
            keys, dists = self.index.query_batch(q, k=k, **kw)
        dists = np.asarray(dists)
        self.stats.searches += 1
        self.stats.searched_queries += n
        self.stats.padded_queries += bucket - n
        for r, row_keys, row_d in zip(reqs, keys, dists):
            r.keys, r.dists = list(row_keys), np.asarray(row_d)
            r.done = True
            self._cache_put(r._ck, r.keys, r.dists)
            if self._multi:
                # record validity at store time: mutations cannot
                # interleave mid-tick (single-threaded), so the tenant's
                # current epoch IS the epoch the search ran at
                self._tenant_epochs.setdefault(r.tenant,
                                               self.index.epoch(r.tenant))
        return n

    # ---------------------------------------------------------- frontends
    @property
    def pending(self) -> int:
        """Requests submitted but not yet dispatched (queue depth)."""
        return len(self.queue)

    def poll(self) -> int:
        """Non-blocking pump for in-flight serving ticks (DESIGN.md §11):
        run at most ONE coalescing tick — and only if anything is pending
        — then return the number of requests completed. This is the
        surface the overlapped ``ServeEngine`` loop calls while a decode
        dispatch is in flight: it never loops, never blocks on an empty
        queue, and one call costs at most one device dispatch per (k, ef)
        group."""
        if not self.queue:
            return 0
        return self.step()

    def run_until_drained(self, max_ticks: int = 10_000) -> None:
        ticks = 0
        while self.queue and ticks < max_ticks:
            self.step()
            ticks += 1

    def retrieve(self, queries, k: int = 10, ef: int | None = None,
                 tenants=None) -> list[RetrievalRequest]:
        """Batch convenience: submit all rows of [B, D], drain, return the
        resolved requests in submission order. ``tenants`` is one tenant
        id for the whole batch or a per-row list (IndexPool only)."""
        q = np.asarray(queries, np.float32)
        if q.ndim == 1:
            q = q[None]
        if tenants is None or isinstance(tenants, str):
            tenants = [tenants] * q.shape[0]
        if len(tenants) != q.shape[0]:
            raise ValueError("queries/tenants length mismatch")
        reqs = [self.submit(row, k=k, ef=ef, tenant=t)
                for row, t in zip(q, tenants)]
        self.run_until_drained()
        return reqs

    def retrieve_one(self, query, k: int = 10, ef: int | None = None,
                     tenant: str | None = None) -> RetrievalRequest:
        return self.retrieve(np.asarray(query, np.float32)[None], k, ef,
                             tenants=tenant)[0]
