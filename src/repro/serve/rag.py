"""RAG pipeline — the paper's end-to-end loop (C4, §2 RAG Playground):

    encode(query) -> k-NN retrieve (any VectorIndex, on-device) -> fill the
    {{user}}/{{context}} prompt template -> generate with the LM.

Everything stays on the "device" (this process / the pod): no external
retrieval service — the privacy property the paper is about. The retriever
is any ``VectorIndex`` backend (flat / ivf / hnsw / tiered; DESIGN.md §1),
so the pipeline also carries the protocol's CRUD: documents can be added,
re-embedded (update), and retracted (delete) after indexing — deletion is
the first-class privacy operation.

Retrieval goes through a ``RetrievalEngine`` (serve/retrieval.py): queries
are coalesced into power-of-two batch buckets and repeated queries hit an
LRU cache that every mutation invalidates (DESIGN.md §6), so ``delete``
stays privacy-safe even with caching in front of the index.

Multi-tenant serving (DESIGN.md §10): construct with ``index=IndexPool(...)``
and every data/retrieve verb takes a ``tenant`` id — each user gets a
private corpus (documents, embeddings, AND cached results are namespaced),
while one shared device arena and one engine serve all of them. Retrieval
for a batch of different tenants still coalesces into one dispatch per
tick.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

from repro.core.index import VectorIndex, make_index
from repro.data.corpus import DocumentStore, HashingEncoder, encode_ids
from repro.serve.retrieval import RetrievalEngine

DEFAULT_TEMPLATE = (
    "You are a helpful assistant. Use the context to answer.\n"
    "Context:\n{{context}}\n"
    "Question: {{user}}\n"
    "Answer:"
)


@dataclasses.dataclass
class RetrievedDoc:
    key: str
    text: str
    distance: float


@dataclasses.dataclass
class PendingRetrieval:
    """Handle returned by :meth:`RAGPipeline.submit_retrieval` — the async
    retrieval entry point the overlapped serving loop polls (DESIGN.md
    §11). Wraps the underlying ``RetrievalEngine`` request (``None`` when
    the corpus was empty at submission: resolved immediately with no
    docs) and defers the key -> document-text materialization until the
    caller actually needs the docs — so a document retracted between
    search and admission is re-checked by the engine's epoch guard before
    any prompt is built from it."""
    request: object | None              # RetrievalRequest | None
    tenant: str | None
    _pipeline: "RAGPipeline" = dataclasses.field(repr=False, default=None)

    @property
    def done(self) -> bool:
        return self.request is None or self.request.done

    def docs(self) -> list[RetrievedDoc]:
        """Materialize the retrieved documents (requires ``done``)."""
        if self.request is None:
            return []
        if not self.request.done:
            raise RuntimeError("retrieval still in flight: poll first")
        if self.request.error is not None:
            raise self.request.error
        return self._pipeline._materialize(
            self.request.keys, self.request.dists, self.tenant)


class RAGPipeline:
    def __init__(self, *, encoder: HashingEncoder | None = None,
                 index: VectorIndex | None = None,
                 index_kind: str = "hnsw",
                 store: DocumentStore | None = None,
                 index_store=None,
                 template: str = DEFAULT_TEMPLATE,
                 generate_fn: Callable[[str], str] | None = None,
                 M: int = 16, ef_construction: int = 100,
                 retrieval_batch: int = 128, retrieval_cache: int = 1024,
                 index_shards: int | None = None,
                 index_dtype: str | None = None,
                 index_beam_impl: str | None = None):
        # index_store: an ``IndexStore`` (or path) making the index durable
        # (DESIGN.md §7) — a warm store restores the previous session's
        # index, mutation_epoch included, instead of building a fresh one.
        # index_shards: partition the index over the device mesh
        # (DESIGN.md §8); None keeps the backend default (or, on a warm
        # restore, the stored shard count).
        # index_dtype: row-storage codec (DESIGN.md §9, fp32/bf16/int8);
        # None keeps the backend default — and, on a warm restore, the
        # stored codec (an explicit mismatch with a warm store is
        # rejected: encoded pages cannot be transcoded).
        # index_beam_impl: HNSW layer-0 beam implementation (DESIGN.md
        # §12, "fused" one-launch kernel vs "jnp" reference); None keeps
        # the backend default.
        self.encoder = encoder or HashingEncoder()
        shard_cfg = {} if index_shards is None else {"n_shards": index_shards}
        if index_dtype is not None:
            shard_cfg["dtype"] = index_dtype
        if index_beam_impl is not None:
            shard_cfg["beam_impl"] = index_beam_impl
        self.index = index if index is not None else make_index(
            index_kind, store=index_store, metric="cosine",
            dim=self.encoder.dim, M=M, ef_construction=ef_construction,
            **shard_cfg)
        self.store = store or DocumentStore()
        self.template = template
        self.generate_fn = generate_fn
        # Pool mode: the "index" is an IndexPool and every verb below takes
        # a tenant id. Document-store text keys are namespaced the same way
        # the pool namespaces vector keys, so two tenants' texts can never
        # collide (or leak into each other's prompts).
        self.pool_mode = hasattr(self.index, "query_batch_multi")
        self.retriever = RetrievalEngine(self.index,
                                         max_batch=retrieval_batch,
                                         cache_size=retrieval_cache)

    def _tid(self, tenant: str | None) -> str | None:
        if self.pool_mode:
            if tenant is None:
                raise ValueError(
                    "pipeline fronts an IndexPool: pass tenant=")
            return tenant
        if tenant is not None:
            raise ValueError("tenant= requires an IndexPool index")
        return None

    def _doc_key(self, key: str, tenant: str | None) -> str:
        if tenant is None:
            return key
        from repro.core.tenancy import tenant_key
        return tenant_key(tenant, key)

    # --------------------------------------------------------------- data
    def add_documents(self, docs: list[tuple[str, str]],
                      tenant: str | None = None):
        """docs: [(key, text)] — embed + index + store (bulk write, C3)."""
        tenant = self._tid(tenant)
        keys = [k for k, _ in docs]
        texts = [t for _, t in docs]
        vecs = self.encoder.encode(texts)
        if self.pool_mode:
            self.index.bulk_insert(tenant, keys, vecs)
        else:
            self.index.bulk_insert(keys, vecs)
        for k, t in docs:
            self.store.add(self._doc_key(k, tenant), t)

    def add_document(self, key: str, text: str, tenant: str | None = None):
        tenant = self._tid(tenant)
        vec = self.encoder.encode(text)[0]
        if self.pool_mode:
            self.index.insert(tenant, key, vec)
        else:
            self.index.insert(key, vec)
        self.store.add(self._doc_key(key, tenant), text)

    def register_texts(self, docs: list[tuple[str, str]],
                       tenant: str | None = None):
        """Warm-restart companion to ``add_documents``: (re)populate the
        text store WITHOUT touching the index. A warm-restored index
        (``index_store=``) already holds the embeddings; re-inserting them
        would burn WAL records and epoch bumps for nothing. Only documents
        the index actually knows are registered."""
        tenant = self._tid(tenant)
        for k, t in docs:
            known = (self.index.contains(tenant, k) if self.pool_mode
                     else k in self.index)
            if known:
                self.store.add(self._doc_key(k, tenant), t)

    def update_document(self, key: str, text: str,
                        tenant: str | None = None):
        """Re-embed + replace an indexed document in place."""
        tenant = self._tid(tenant)
        vec = self.encoder.encode(text)[0]
        if self.pool_mode:
            self.index.update(tenant, key, vec)
        else:
            self.index.update(key, vec)
        self.store.add(self._doc_key(key, tenant), text)

    def delete_document(self, key: str, tenant: str | None = None):
        """Retract a document: tombstoned in the index, purged from the
        store — it can never be retrieved into a prompt again."""
        tenant = self._tid(tenant)
        if self.pool_mode:
            self.index.delete(tenant, key)
        else:
            self.index.delete(key)
        self.store.remove(self._doc_key(key, tenant))

    # ------------------------------------------------------------ retrieve
    def _size_for(self, tenant: str | None) -> int:
        """Live row count of the (tenant's) corpus — ONE accessor for the
        pool and single-index cases, so every retrieve verb shares one
        code path (the per-request ``tenant`` field is the only tenancy
        surface; ``tenant=None`` IS single-index mode)."""
        if self.pool_mode:
            if tenant is None:
                raise ValueError(
                    "pipeline fronts an IndexPool: pass tenant=")
            return self.index.size(tenant)
        if tenant is not None:
            raise ValueError("tenant= requires an IndexPool index")
        return self.index.size

    def current_epoch(self, tenant: str | None = None) -> int:
        """Mutation epoch governing retrieval validity for ``tenant``
        (the whole index when ``tenant`` is None). The overlapped serving
        loop records this when a retrieval resolves and re-checks it at
        admission: a prompt is only ever built from results whose epoch
        is still current (DESIGN.md §11 privacy invariant)."""
        if self.pool_mode and tenant is not None:
            return self.index.epoch(tenant)
        return self.index.mutation_epoch

    def _materialize(self, keys, dists, tenant: str | None
                     ) -> list[RetrievedDoc]:
        return [RetrievedDoc(key,
                             self.store.get(self._doc_key(key, tenant)).text,
                             float(d))
                for key, d in zip(keys, dists) if key is not None]

    def submit_retrieval(self, query: str, k: int = 3,
                         tenant: str | None = None) -> PendingRetrieval:
        """Async retrieval entry point (DESIGN.md §11): encode the query
        and enqueue it on the RetrievalEngine WITHOUT dispatching —
        returns a :class:`PendingRetrieval` the caller polls via
        :meth:`poll_retrieval`. This is what lets ``ServeEngine`` run
        retrieval for queued requests while its decode dispatch is in
        flight. An empty corpus resolves immediately with no docs (the
        everything-retracted case must not error the serving loop)."""
        size = self._size_for(tenant)
        if size == 0:
            return PendingRetrieval(None, tenant, self)
        qv = self.encoder.encode([query])[0]
        req = self.retriever.submit(qv, k=min(k, size), tenant=tenant)
        return PendingRetrieval(req, tenant, self)

    def poll_retrieval(self) -> int:
        """Run at most one RetrievalEngine coalescing tick (non-blocking;
        see ``RetrievalEngine.poll``). Returns requests completed."""
        return self.retriever.poll()

    def retrieve(self, query: str, k: int = 3,
                 tenant: str | None = None) -> list[RetrievedDoc]:
        return self.retrieve_batch([query], k,
                                   tenants=None if tenant is None
                                   else [tenant])[0]

    def retrieve_batch(self, queries: list[str], k: int = 3,
                       tenants: list[str] | None = None
                       ) -> list[list[RetrievedDoc]]:
        """Retrieve for many queries in ONE RetrievalEngine tick: a single
        submission pass, then one bucket-coalesced device search per
        (k, ef) group. Pool and single-index callers share this one code
        path: ``tenants`` is an optional per-query tenant list that
        defaults to all-``None`` (single-index mode); requests from
        different tenants still coalesce into the same dispatch."""
        if tenants is None:
            tenants = [None] * len(queries)
        if len(tenants) != len(queries):
            raise ValueError("queries/tenants length mismatch")
        pend = [self.submit_retrieval(q, k, tenant=t)
                for q, t in zip(queries, tenants)]
        self.retriever.run_until_drained()
        return [p.docs() for p in pend]

    # ------------------------------------------------------------- prompt
    def build_prompt(self, query: str, docs: list[RetrievedDoc]) -> str:
        ctx = "\n".join(f"[{i+1}] {d.text}" for i, d in enumerate(docs))
        return (self.template
                .replace("{{context}}", ctx)
                .replace("{{user}}", query))

    # ------------------------------------------------------------ generate
    def answer(self, query: str, k: int = 3,
               tenant: str | None = None) -> dict:
        docs = self.retrieve(query, k, tenant=tenant)
        prompt = self.build_prompt(query, docs)
        out = self.generate_fn(prompt) if self.generate_fn else None
        return {"query": query, "docs": docs, "prompt": prompt,
                "response": out}


def lm_generate_fn(engine, vocab: int, max_len: int, detokenize=None):
    """Adapt a ServeEngine into RAGPipeline.generate_fn (hashed tokenizer)."""
    def fn(prompt: str) -> str:
        ids = encode_ids(prompt, vocab, max_len)
        ids = ids[ids > 0]
        out = engine.generate([ids], max_new_tokens=16)[0]
        if detokenize:
            return detokenize(out)
        return " ".join(f"<{t}>" for t in out)
    return fn
