"""RAG pipeline — the paper's end-to-end loop (C4, §2 RAG Playground):

    encode(query) -> k-NN retrieve (HNSW, on-device) -> fill the
    {{user}}/{{context}} prompt template -> generate with the LM.

Everything stays on the "device" (this process / the pod): no external
retrieval service — the privacy property the paper is about.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

from repro.core.interface import HNSW
from repro.data.corpus import DocumentStore, HashingEncoder, encode_ids

DEFAULT_TEMPLATE = (
    "You are a helpful assistant. Use the context to answer.\n"
    "Context:\n{{context}}\n"
    "Question: {{user}}\n"
    "Answer:"
)


@dataclasses.dataclass
class RetrievedDoc:
    key: str
    text: str
    distance: float


class RAGPipeline:
    def __init__(self, *, encoder: HashingEncoder | None = None,
                 index: HNSW | None = None,
                 store: DocumentStore | None = None,
                 template: str = DEFAULT_TEMPLATE,
                 generate_fn: Callable[[str], str] | None = None,
                 M: int = 16, ef_construction: int = 100):
        self.encoder = encoder or HashingEncoder()
        self.index = index or HNSW(distance_function="cosine", M=M,
                                   ef_construction=ef_construction)
        self.store = store or DocumentStore()
        self.template = template
        self.generate_fn = generate_fn

    # --------------------------------------------------------------- data
    def add_documents(self, docs: list[tuple[str, str]]):
        """docs: [(key, text)] — embed + index + store (bulk write, C3)."""
        keys = [k for k, _ in docs]
        texts = [t for _, t in docs]
        vecs = self.encoder.encode(texts)
        self.index.bulk_insert(keys, vecs)
        for k, t in docs:
            self.store.add(k, t)

    # ------------------------------------------------------------ retrieve
    def retrieve(self, query: str, k: int = 3) -> list[RetrievedDoc]:
        qv = self.encoder.encode(query)[0]
        keys, dists = self.index.query(qv, k=min(k, self.index.size))
        return [RetrievedDoc(key, self.store.get(key).text, float(d))
                for key, d in zip(keys, dists) if key is not None]

    # ------------------------------------------------------------- prompt
    def build_prompt(self, query: str, docs: list[RetrievedDoc]) -> str:
        ctx = "\n".join(f"[{i+1}] {d.text}" for i, d in enumerate(docs))
        return (self.template
                .replace("{{context}}", ctx)
                .replace("{{user}}", query))

    # ------------------------------------------------------------ generate
    def answer(self, query: str, k: int = 3) -> dict:
        docs = self.retrieve(query, k)
        prompt = self.build_prompt(query, docs)
        out = self.generate_fn(prompt) if self.generate_fn else None
        return {"query": query, "docs": docs, "prompt": prompt,
                "response": out}


def lm_generate_fn(engine, vocab: int, max_len: int, detokenize=None):
    """Adapt a ServeEngine into RAGPipeline.generate_fn (hashed tokenizer)."""
    def fn(prompt: str) -> str:
        ids = encode_ids(prompt, vocab, max_len)
        ids = ids[ids > 0]
        out = engine.generate([ids], max_new_tokens=16)[0]
        if detokenize:
            return detokenize(out)
        return " ".join(f"<{t}>" for t in out)
    return fn
