"""graphsage-reddit [gnn] — 2 layers, mean agg, fanout 25-10. [arXiv:1706.02216; paper]"""
from repro.configs.base import ArchConfig, GNNConfig, GNN_SHAPES

CONFIG = ArchConfig(
    arch_id="graphsage-reddit",
    family="gnn",
    model=GNNConfig(
        name="graphsage-reddit",
        n_layers=2,
        d_hidden=128,
        aggregator="mean",
        sample_sizes=(25, 10),
    ),
    shapes=GNN_SHAPES,
    source="arXiv:1706.02216",
)


def smoke_config() -> GNNConfig:
    return GNNConfig(
        name="graphsage-smoke",
        n_layers=2,
        d_hidden=16,
        aggregator="mean",
        sample_sizes=(5, 3),
    )
