"""minitron-8b [dense] — pruned nemotron, 256k vocab. [arXiv:2407.14679; hf]"""
from repro.configs.base import ArchConfig, LMConfig, LM_SHAPES

CONFIG = ArchConfig(
    arch_id="minitron-8b",
    family="lm",
    model=LMConfig(
        name="minitron-8b",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        d_ff=16384,
        vocab=256000,
        rope_theta=10000.0,
    ),
    shapes=LM_SHAPES,
    source="arXiv:2407.14679",
    skip_shapes=("long_500k",),   # pure full attention (DESIGN.md section 5)
)


def smoke_config() -> LMConfig:
    return LMConfig(
        name="minitron-8b-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=160,
        vocab=512,
        rope_theta=10000.0,
        attn_block_q=16,
        attn_block_k=16,
    )
