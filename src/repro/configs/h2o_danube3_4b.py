"""h2o-danube-3-4b [dense] — llama+mistral mix, SWA. [arXiv:2401.16818; unverified]

The sliding-window attention makes this the designated sub-quadratic
long-context arch: the only LM that runs the long_500k cell.
"""
from repro.configs.base import ArchConfig, LMConfig, LM_SHAPES

CONFIG = ArchConfig(
    arch_id="h2o-danube-3-4b",
    family="lm",
    model=LMConfig(
        name="h2o-danube-3-4b",
        n_layers=24,
        d_model=3840,
        n_heads=32,
        n_kv_heads=8,
        d_ff=10240,
        vocab=32000,
        rope_theta=10000.0,
        sliding_window=4096,          # mistral-style SWA
    ),
    shapes=LM_SHAPES,
    source="arXiv:2401.16818",
)


def smoke_config() -> LMConfig:
    return LMConfig(
        name="h2o-danube-3-4b-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab=256,
        rope_theta=10000.0,
        sliding_window=32,
        attn_block_q=16,
        attn_block_k=16,
    )
