"""llama3-8b [dense] — GQA, 128k vocab. [arXiv:2407.21783; unverified]"""
from repro.configs.base import ArchConfig, LMConfig, LM_SHAPES

CONFIG = ArchConfig(
    arch_id="llama3-8b",
    family="lm",
    model=LMConfig(
        name="llama3-8b",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        d_ff=14336,
        vocab=128256,
        rope_theta=500000.0,
    ),
    shapes=LM_SHAPES,
    source="arXiv:2407.21783",
    # pure full attention: long_500k mandated skip (DESIGN.md section 5)
    skip_shapes=("long_500k",),
)


def smoke_config() -> LMConfig:
    return LMConfig(
        name="llama3-8b-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab=256,
        rope_theta=500000.0,
        attn_block_q=16,
        attn_block_k=16,
    )
