"""mind [recsys] — multi-interest capsule routing. [arXiv:1904.08030; unverified]"""
from repro.configs.base import ArchConfig, RecsysConfig, RECSYS_SHAPES

CONFIG = ArchConfig(
    arch_id="mind",
    family="recsys",
    model=RecsysConfig(
        name="mind",
        kind="mind",
        embed_dim=64,
        n_interests=4,
        capsule_iters=3,
        interaction="multi-interest",
        seq_len=50,
        n_items=1_000_000,
        mlp_dims=(256, 64),
    ),
    shapes=RECSYS_SHAPES,
    source="arXiv:1904.08030",
)


def smoke_config() -> RecsysConfig:
    return RecsysConfig(
        name="mind-smoke",
        kind="mind",
        embed_dim=16,
        n_interests=2,
        capsule_iters=2,
        interaction="multi-interest",
        seq_len=10,
        n_items=500,
        mlp_dims=(32, 16),
    )
