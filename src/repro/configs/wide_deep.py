"""wide-deep [recsys] — wide linear + deep MLP. [arXiv:1606.07792; paper]"""
from repro.configs.base import ArchConfig, RecsysConfig, RECSYS_SHAPES

CONFIG = ArchConfig(
    arch_id="wide-deep",
    family="recsys",
    model=RecsysConfig(
        name="wide-deep",
        kind="wide_deep",
        n_sparse=40,
        embed_dim=32,
        mlp_dims=(1024, 512, 256),
        interaction="concat",
        rows_per_field=1_000_000,
    ),
    shapes=RECSYS_SHAPES,
    source="arXiv:1606.07792",
)


def smoke_config() -> RecsysConfig:
    return RecsysConfig(
        name="wide-deep-smoke",
        kind="wide_deep",
        n_sparse=6,
        embed_dim=8,
        mlp_dims=(32, 16),
        interaction="concat",
        rows_per_field=100,
        n_dense=4,
    )
