"""Config dataclasses for every architecture family + shape specs.

Every assigned architecture gets one module in this package exporting
``CONFIG`` (exact published dims) and ``smoke_config()`` (reduced same-family
config for CPU smoke tests).  The registry in ``__init__`` resolves
``--arch <id>``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional


# ---------------------------------------------------------------------------
# Shapes
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str            # train | prefill | decode | sampled_train | serve | retrieval
    dims: dict[str, int] = dataclasses.field(default_factory=dict)

    def __getitem__(self, k: str) -> int:
        return self.dims[k]


LM_SHAPES = (
    ShapeSpec("train_4k", "train", {"seq_len": 4096, "global_batch": 256}),
    ShapeSpec("prefill_32k", "prefill", {"seq_len": 32768, "global_batch": 32}),
    ShapeSpec("decode_32k", "decode", {"seq_len": 32768, "global_batch": 128}),
    ShapeSpec("long_500k", "decode", {"seq_len": 524288, "global_batch": 1}),
)

GNN_SHAPES = (
    ShapeSpec("full_graph_sm", "train",
              {"n_nodes": 2708, "n_edges": 10556, "d_feat": 1433, "n_classes": 7}),
    ShapeSpec("minibatch_lg", "sampled_train",
              {"n_nodes": 232965, "n_edges": 114615892, "batch_nodes": 1024,
               "fanout1": 15, "fanout2": 10, "d_feat": 602, "n_classes": 41}),
    ShapeSpec("ogb_products", "train",
              {"n_nodes": 2449029, "n_edges": 61859140, "d_feat": 100, "n_classes": 47}),
    ShapeSpec("molecule", "train",
              {"n_nodes": 30, "n_edges": 64, "batch": 128, "d_feat": 16, "n_classes": 2}),
)

RECSYS_SHAPES = (
    ShapeSpec("train_batch", "train", {"batch": 65536}),
    ShapeSpec("serve_p99", "serve", {"batch": 512}),
    ShapeSpec("serve_bulk", "serve", {"batch": 262144}),
    ShapeSpec("retrieval_cand", "retrieval", {"batch": 1, "n_candidates": 1_000_000}),
)


# ---------------------------------------------------------------------------
# Model configs
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff: int                    # per-expert hidden
    capacity_factor: float = 1.25
    aux_loss_weight: float = 0.01
    router_dtype: str = "float32"
    # EP alignment: pad the expert dim to a mesh-divisible count; padded
    # experts are masked out of routing (never receive tokens). 0 = off.
    pad_experts_to: int = 0

    @property
    def n_slots(self) -> int:
        return max(self.pad_experts_to, self.n_experts)


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0            # 0 -> d_model // n_heads
    rope_theta: float = 500000.0
    norm_eps: float = 1e-5
    sliding_window: Optional[int] = None   # SWA width; None = full attention
    moe: Optional[MoEConfig] = None
    tie_embeddings: bool = False
    # implementation knobs (hillclimb levers)
    attn_block_q: int = 512      # blocked-attention query tile
    attn_block_k: int = 1024     # blocked-attention key tile
    chunked_loss: int = 0        # 0 = full logits; >0 = vocab-loss seq chunk size
    remat: bool = True           # activation checkpointing on layer scan
    scan_layers: bool = True
    kv_quant: bool = False       # int8 KV cache (+per-position f32 scales)

    @property
    def dh(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def param_count(self) -> int:
        d, f, v, L = self.d_model, self.d_ff, self.vocab, self.n_layers
        attn = d * self.n_heads * self.dh + 2 * d * self.n_kv_heads * self.dh \
            + self.n_heads * self.dh * d
        if self.moe:
            ffn = 3 * d * self.moe.d_ff * self.moe.n_experts + d * self.moe.n_experts
        else:
            ffn = 3 * d * f
        emb = v * d * (1 if self.tie_embeddings else 2)
        return L * (attn + ffn + 2 * d) + emb + d

    @property
    def active_param_count(self) -> int:
        """Params touched per token (MoE: only routed experts)."""
        if not self.moe:
            return self.param_count
        d, v, L = self.d_model, self.vocab, self.n_layers
        attn = d * self.n_heads * self.dh + 2 * d * self.n_kv_heads * self.dh \
            + self.n_heads * self.dh * d
        ffn = 3 * d * self.moe.d_ff * self.moe.top_k + d * self.moe.n_experts
        emb = v * d * (1 if self.tie_embeddings else 2)
        return L * (attn + ffn + 2 * d) + emb + d


@dataclasses.dataclass(frozen=True)
class GNNConfig:
    name: str
    n_layers: int = 2
    d_hidden: int = 128
    aggregator: str = "mean"
    sample_sizes: tuple[int, ...] = (25, 10)


@dataclasses.dataclass(frozen=True)
class RecsysConfig:
    name: str
    kind: str                    # fm | wide_deep | bert4rec | mind
    embed_dim: int
    n_sparse: int = 0
    rows_per_field: int = 1_000_000     # synthetic hashed vocab per sparse field
    n_dense: int = 13                   # criteo-style dense features
    mlp_dims: tuple[int, ...] = ()
    # sequential models
    seq_len: int = 0
    n_items: int = 0
    n_blocks: int = 0
    n_heads: int = 0
    # MIND
    n_interests: int = 0
    capsule_iters: int = 0
    interaction: str = ""

    @property
    def table_param_count(self) -> int:
        if self.kind in ("bert4rec", "mind"):
            return self.n_items * self.embed_dim
        return self.n_sparse * self.rows_per_field * self.embed_dim


@dataclasses.dataclass(frozen=True)
class RetrievalConfig:
    """MeMemo's own configuration (paper section 3, Code 1 parity)."""
    name: str = "mememo"
    dim: int = 384                     # GTE-small embeddings (paper section 2.1)
    metric: str = "cosine"
    M: int = 5                         # paper section 5 benchmark setting
    ef_construction: int = 20
    ef_search: int = 64
    prefetch_p: int = 0                # 0 -> auto from dim (paper section 3.2)
    n_vectors: int = 1_000_000
    # VectorIndex backend selection (core/index.py make_index): the paper's
    # own index is HNSW; flat/ivf/tiered serve other workload points.
    index_kind: str = "hnsw"
    nlist: int = 64                    # ivf: number of inverted lists
    nprobe: int = 8                    # ivf: lists probed per query
    # row-storage codec (DESIGN.md §9): None -> backend default (fp32);
    # "bf16"/"int8" shrink device blocks + snapshot pages per vector
    index_dtype: str | None = None
    # layer-0 beam implementation (DESIGN.md §12): None -> backend
    # default ("fused" one-launch kernel); "jnp" is the per-hop
    # while_loop reference path
    beam_impl: str | None = None


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    arch_id: str
    family: str                        # lm | gnn | recsys
    model: Any
    shapes: tuple[ShapeSpec, ...]
    source: str = ""
    skip_shapes: tuple[str, ...] = ()  # mandated skips (noted in DESIGN.md)

    def shape(self, name: str) -> ShapeSpec:
        for s in self.shapes:
            if s.name == name:
                return s
        raise KeyError(name)

    @property
    def runnable_shapes(self) -> tuple[ShapeSpec, ...]:
        return tuple(s for s in self.shapes if s.name not in self.skip_shapes)
