"""Architecture registry: ``get_config("--arch id")`` resolution."""
from __future__ import annotations

import importlib

from repro.configs.base import (  # noqa: F401
    ArchConfig,
    GNNConfig,
    LMConfig,
    MoEConfig,
    RecsysConfig,
    RetrievalConfig,
    ShapeSpec,
    GNN_SHAPES,
    LM_SHAPES,
    RECSYS_SHAPES,
)

_MODULES = {
    "llama3-8b": "llama3_8b",
    "h2o-danube-3-4b": "h2o_danube3_4b",
    "minitron-8b": "minitron_8b",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "granite-moe-3b-a800m": "granite_moe_3b",
    "graphsage-reddit": "graphsage_reddit",
    "mind": "mind",
    "wide-deep": "wide_deep",
    "bert4rec": "bert4rec",
    "fm": "fm",
    "mememo": "mememo",
}

ASSIGNED_ARCHS = tuple(a for a in _MODULES if a != "mememo")
ALL_ARCHS = tuple(_MODULES)


def _module(arch_id: str):
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")


def get_config(arch_id: str) -> ArchConfig:
    return _module(arch_id).CONFIG


def get_smoke_config(arch_id: str):
    return _module(arch_id).smoke_config()


def list_archs() -> list[str]:
    return list(ALL_ARCHS)
