"""The paper's own configuration: MeMemo HNSW retrieval (section 5 benchmark).

1M x 384-d vectors, cosine metric, M=5, efConstruction=20 -- the exact
setting behind the paper's "94 minutes in Chrome" construction number.
"""
from repro.configs.base import ArchConfig, RetrievalConfig, ShapeSpec

RETRIEVAL_SHAPES = (
    ShapeSpec("build_1m", "build", {"n_vectors": 1_000_000, "dim": 384}),
    ShapeSpec("query_1m", "retrieval", {"batch": 1024, "n_candidates": 1_000_000,
                                        "dim": 384, "k": 10}),
    ShapeSpec("query_rt", "retrieval", {"batch": 1, "n_candidates": 1_000_000,
                                        "dim": 384, "k": 10}),
)

CONFIG = ArchConfig(
    arch_id="mememo",
    family="retrieval",
    model=RetrievalConfig(
        name="mememo",
        dim=384,
        metric="cosine",
        M=5,
        ef_construction=20,
        ef_search=64,
        n_vectors=1_000_000,
    ),
    shapes=RETRIEVAL_SHAPES,
    source="doi:10.1145/3626772.3657662",
)


def smoke_config() -> RetrievalConfig:
    return RetrievalConfig(
        name="mememo-smoke",
        dim=16,
        metric="cosine",
        M=5,
        ef_construction=20,
        ef_search=24,
        n_vectors=512,
    )


def make_paper_index(kind: str | None = None, **overrides):
    """The paper-configured retriever as a ``VectorIndex`` (any backend)."""
    from repro.core.index import make_index_from_config
    return make_index_from_config(CONFIG.model, kind=kind, **overrides)


def make_smoke_index(kind: str | None = None, **overrides):
    from repro.core.index import make_index_from_config
    return make_index_from_config(smoke_config(), kind=kind, **overrides)
