"""granite-moe-3b-a800m [moe] — MoE 40e top-8.
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]
"""
from repro.configs.base import ArchConfig, LMConfig, MoEConfig, LM_SHAPES

CONFIG = ArchConfig(
    arch_id="granite-moe-3b-a800m",
    family="lm",
    model=LMConfig(
        name="granite-moe-3b-a800m",
        n_layers=32,
        d_model=1536,
        n_heads=24,
        n_kv_heads=8,
        d_ff=512,
        vocab=49155,
        head_dim=64,
        rope_theta=10000.0,
        moe=MoEConfig(n_experts=40, top_k=8, d_ff=512),
        tie_embeddings=True,
    ),
    shapes=LM_SHAPES,
    source="hf:ibm-granite/granite-3.0-1b-a400m-base",
    skip_shapes=("long_500k",),   # full attention (DESIGN.md section 5)
)


def smoke_config() -> LMConfig:
    return LMConfig(
        name="granite-moe-smoke",
        n_layers=2,
        d_model=48,
        n_heads=4,
        n_kv_heads=2,
        d_ff=32,
        vocab=256,
        head_dim=12,
        rope_theta=10000.0,
        moe=MoEConfig(n_experts=5, top_k=2, d_ff=32),
        tie_embeddings=True,
        attn_block_q=16,
        attn_block_k=16,
    )
