"""fm [recsys] — factorization machine, O(nk) sum-square trick.
[ICDM'10 (Rendle); paper]
"""
from repro.configs.base import ArchConfig, RecsysConfig, RECSYS_SHAPES

CONFIG = ArchConfig(
    arch_id="fm",
    family="recsys",
    model=RecsysConfig(
        name="fm",
        kind="fm",
        n_sparse=39,
        embed_dim=10,
        interaction="fm-2way",
        rows_per_field=1_000_000,
    ),
    shapes=RECSYS_SHAPES,
    source="ICDM'10 (Rendle)",
)


def smoke_config() -> RecsysConfig:
    return RecsysConfig(
        name="fm-smoke",
        kind="fm",
        n_sparse=5,
        embed_dim=4,
        interaction="fm-2way",
        rows_per_field=64,
        n_dense=3,
    )
