"""bert4rec [recsys] — bidirectional sequence encoder. [arXiv:1904.06690; paper]"""
from repro.configs.base import ArchConfig, RecsysConfig, RECSYS_SHAPES

CONFIG = ArchConfig(
    arch_id="bert4rec",
    family="recsys",
    model=RecsysConfig(
        name="bert4rec",
        kind="bert4rec",
        embed_dim=64,
        n_blocks=2,
        n_heads=2,
        seq_len=200,
        interaction="bidir-seq",
        n_items=60_000,
    ),
    shapes=RECSYS_SHAPES,
    source="arXiv:1904.06690",
)


def smoke_config() -> RecsysConfig:
    return RecsysConfig(
        name="bert4rec-smoke",
        kind="bert4rec",
        embed_dim=16,
        n_blocks=2,
        n_heads=2,
        seq_len=20,
        interaction="bidir-seq",
        n_items=300,
    )
