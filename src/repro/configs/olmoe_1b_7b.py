"""olmoe-1b-7b [moe] — 64 experts top-8. [arXiv:2409.02060; hf]"""
from repro.configs.base import ArchConfig, LMConfig, MoEConfig, LM_SHAPES

CONFIG = ArchConfig(
    arch_id="olmoe-1b-7b",
    family="lm",
    model=LMConfig(
        name="olmoe-1b-7b",
        n_layers=16,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        d_ff=1024,
        vocab=50304,
        rope_theta=10000.0,
        moe=MoEConfig(n_experts=64, top_k=8, d_ff=1024),
    ),
    shapes=LM_SHAPES,
    source="arXiv:2409.02060",
    skip_shapes=("long_500k",),   # full attention (DESIGN.md section 5)
)


def smoke_config() -> LMConfig:
    return LMConfig(
        name="olmoe-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=64,
        vocab=256,
        rope_theta=10000.0,
        moe=MoEConfig(n_experts=8, top_k=2, d_ff=64),
        attn_block_q=16,
        attn_block_k=16,
    )
