"""Fused layer-0 beam search — the whole ef-beam HNSW search in ONE
kernel launch per query block (DESIGN.md §12).

The jnp search (``core.hnsw._beam_search``) pays per hop: a separate
``gather_distance`` dispatch plus two full [B, ef+2M] ``lax.sort``s,
with the ``while_loop`` state bouncing through HBM between hops. This
kernel keeps the ENTIRE search resident: the beam (dist, id, expanded)
lives in VMEM scratch across hops, neighbor lists and candidate vector
rows stream in over the same double-buffered DMA machinery as
``gather_distance`` (HBM row fetch on semaphore pairs, wave i's
distances compute while wave i+1 is in flight), and the merge is a
single bitonic merge of the sorted beam against bitonic-sorted
candidates — the beam is already sorted, so only the fresh T·2M
candidates pay a full sort network.

Per hop, the top-T unexpanded beam entries expand together (``expand_t``
static, default 4) so each DMA round amortizes over multiple frontier
nodes: hops = ceil(budget / T) instead of budget, with the last hop's
selection truncated to the total expansion budget (``max_iters``;
default ef, plus one slack hop at T>1 to match the re-ranking
one-at-a-time order's recall). The frontier/dedup/merge math
is the SAME code the jnp oracle runs (``ref.beam_select_frontier`` /
``ref.beam_dedup_valid`` / ``ref.beam_merge``), so fused-vs-jnp parity
is structural.

Shapes / dtypes
  vectors    [N, D]   f32 / bf16 / int8 (HBM, ``memory_space=ANY``;
                      the per-row decode fuses into the distance)
  neighbors0 [N, 2M]  i32 layer-0 adjacency, -1 pad (HBM)
  q          [B, D]   f32 prepped queries
  ep, ep_dist [B]     layer-0 entry points (from the greedy descent)
  scales     [N] f32  optional per-row decode scales (int8 codec)
  ->  (ids [B, ef] i32, dists [B, ef] f32) ascending by (d, id);
      empty slots (-1, INF). Tombstone filtering stays in the caller
      (``core.hnsw.search_core``), as on the jnp path.

Grid / memory plan
  grid = (B / block_q,). Beam state [BQ, EFp] (EFp = next pow2 of ef)
  plus the selected-node ids, fetched neighbor lists [BQ*T, 2M], and
  candidate distances [BQ, T*2M] all live in VMEM scratch; the
  early-exit flag is one SMEM word guarding each hop body (``pl.when``),
  so converged blocks skip the remaining hops' DMA entirely. Vector
  rows ride a [2, wave, D] double buffer exactly like gather_distance.

Fallback
  ``interpret=None`` resolves platform-aware (kernels.resolve_interpret);
  ``ops.beam_search`` only selects this path on TPU (or
  REPRO_PALLAS=interpret) and otherwise runs ``ref.beam_search_ref`` —
  the identical algorithm on the same helpers.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import ref, resolve_interpret

INF = ref.BEAM_INF


def _kernel(metric: str, ef: int, efp: int, t: int, wave: int, hops: int,
            budget: int, n_rows: int, has_scales: bool, *refs):
    if has_scales:
        (ep_ref, epd_ref, q_ref, nbr_tbl, db_ref, scl_ref,
         outi_ref, outd_ref,
         bd_ref, bi_ref, bx_ref, sel_ref, nbr_s, vrow_s, cd_ref, s_s,
         done_ref, nbr_sem, v_sems, s_sems) = refs
    else:
        (ep_ref, epd_ref, q_ref, nbr_tbl, db_ref,
         outi_ref, outd_ref,
         bd_ref, bi_ref, bx_ref, sel_ref, nbr_s, vrow_s, cd_ref,
         done_ref, nbr_sem, v_sems) = refs
        scl_ref = s_s = s_sems = None
    bq = q_ref.shape[0]
    m2 = nbr_tbl.shape[1]
    w = t * m2

    # beam init: slot 0 = the entry point, the rest (INF, -1, expanded)
    col = jax.lax.broadcasted_iota(jnp.int32, (bq, efp), 1)
    bd_ref[...] = jnp.where(col == 0, epd_ref[...], INF)
    bi_ref[...] = jnp.where(col == 0, ep_ref[...], -1)
    bx_ref[...] = (col != 0).astype(jnp.int32)
    done_ref[0] = 0

    def dma_rows(slot, w_idx):
        """Issue the vector-row DMAs for flat wave ``w_idx``."""
        def issue(i, _):
            flat = w_idx * wave + i
            c = flat % w
            row = jnp.clip(nbr_s[(flat // w) * t + c // m2, c % m2],
                           0, n_rows - 1)
            pltpu.make_async_copy(
                db_ref.at[pl.ds(row, 1)], vrow_s.at[slot, pl.ds(i, 1)],
                v_sems.at[slot]).start()
            if has_scales:
                pltpu.make_async_copy(
                    scl_ref.at[pl.ds(row, 1)],
                    s_s.at[slot, pl.ds(i, 1)], s_sems.at[slot]).start()
            return 0
        jax.lax.fori_loop(0, wave, issue, 0)

    def wait_rows(slot):
        def wfn(i, _):
            pltpu.make_async_copy(
                db_ref.at[pl.ds(0, 1)], vrow_s.at[slot, pl.ds(i, 1)],
                v_sems.at[slot]).wait()
            if has_scales:
                pltpu.make_async_copy(
                    scl_ref.at[pl.ds(0, 1)],
                    s_s.at[slot, pl.ds(i, 1)], s_sems.at[slot]).wait()
            return 0
        jax.lax.fori_loop(0, wave, wfn, 0)

    def hop(h, _):
        @pl.when(done_ref[0] == 0)
        def _():
            bd = bd_ref[...]
            bi = bi_ref[...]
            bx = bx_ref[...] != 0
            t_live = jnp.minimum(t, budget - h * t)
            bx2, nodes = ref.beam_select_frontier(bd, bi, bx, t_live, t)
            sel_ref[...] = nodes

            # phase 1: T neighbor-list rows per query, one DMA burst
            def issue_n(i, _):
                row = jnp.clip(sel_ref[i // t, i % t], 0, n_rows - 1)
                pltpu.make_async_copy(
                    nbr_tbl.at[pl.ds(row, 1)], nbr_s.at[pl.ds(i, 1)],
                    nbr_sem.at[0]).start()
                return 0
            jax.lax.fori_loop(0, bq * t, issue_n, 0)

            def wait_n(i, _):
                pltpu.make_async_copy(
                    nbr_tbl.at[pl.ds(0, 1)], nbr_s.at[pl.ds(i, 1)],
                    nbr_sem.at[0]).wait()
                return 0
            jax.lax.fori_loop(0, bq * t, wait_n, 0)

            # phase 2: candidate vector rows in double-buffered waves,
            # fused codec decode + distance per row (gather_distance idiom)
            total_waves = (bq * w) // wave
            dma_rows(0, 0)

            def step(w_idx, _):
                slot = w_idx % 2

                @pl.when(w_idx + 1 < total_waves)
                def _():
                    dma_rows((w_idx + 1) % 2, w_idx + 1)

                wait_rows(slot)
                rows = vrow_s[slot]

                def one(i, _):
                    flat = w_idx * wave + i
                    b_i, c = flat // w, flat % w
                    qv = q_ref[b_i, :].astype(jnp.float32)
                    xv = rows[i, :].astype(jnp.float32)
                    if has_scales:
                        xv = xv * s_s[slot, i, 0]         # fused decode
                    if metric in ("cosine", "ip"):
                        dist = 1.0 - jnp.sum(qv * xv)
                    else:
                        dist = jnp.sum((qv - xv) ** 2)
                    cd_ref[b_i, c] = dist
                    return 0

                jax.lax.fori_loop(0, wave, one, 0)
                return 0

            jax.lax.fori_loop(0, total_waves, step, 0)

            # phase 3: dedup + single bitonic merge, all VMEM vector work
            nbrs = nbr_s[...].reshape(bq, t, m2)
            valid = ((nodes >= 0)[:, :, None] & (nbrs >= 0)).reshape(bq, w)
            cand = jnp.clip(nbrs, 0, n_rows - 1).reshape(bq, w)
            valid = ref.beam_dedup_valid(cand, valid, bi)
            cd = jnp.where(valid, cd_ref[...], INF)
            ci = jnp.where(valid, cand, -1)
            nbd, nbi, nbx = ref.beam_merge(bd, bi, bx2, cd, ci, ef)
            bd_ref[...] = nbd
            bi_ref[...] = nbi
            bx_ref[...] = nbx.astype(jnp.int32)
            done_ref[0] = (
                1 - jnp.any((~nbx) & (nbi >= 0)).astype(jnp.int32))
        return 0

    if hops > 0:
        jax.lax.fori_loop(0, hops, hop, 0)
    outd_ref[...] = bd_ref[...][:, :ef]
    outi_ref[...] = bi_ref[...][:, :ef]


@functools.partial(jax.jit, static_argnames=("metric", "ef", "expand_t",
                                             "max_iters", "block_q",
                                             "wave", "interpret"))
def _call(vectors, neighbors0, q, ep, ep_dist, scales, metric, ef,
          expand_t, max_iters, block_q, wave, interpret):
    b, d = q.shape
    n, m2 = neighbors0.shape
    t = max(1, min(int(expand_t), int(ef)))
    # default budget: ef expansions, plus ONE slack hop at t>1 — group
    # frontier selection spends some budget on nodes the re-ranking
    # one-at-a-time order would skip, and the slack hop restores its
    # recall (measured; see DESIGN.md §12). t=1 stays exactly ef so the
    # visit order is bitwise the sequential reference.
    budget = ((int(ef) + (t if t > 1 else 0)) if max_iters is None
              else int(max_iters))
    hops = -(-budget // t) if budget > 0 else 0
    efp = ref.next_pow2(ef)
    block_q = min(block_q, b)
    while b % block_q:
        block_q -= 1
    w = t * m2
    wave = min(wave, block_q * w)
    while (block_q * w) % wave:
        wave -= 1
    has_scales = scales is not None

    in_specs = [
        pl.BlockSpec((block_q, 1), lambda i: (i, 0)),     # entry ids
        pl.BlockSpec((block_q, 1), lambda i: (i, 0)),     # entry dists
        pl.BlockSpec((block_q, d), lambda i: (i, 0)),     # queries
        pl.BlockSpec(memory_space=pl.ANY),                # neighbors0
        pl.BlockSpec(memory_space=pl.ANY),                # db rows
    ]
    args = [ep.reshape(b, 1).astype(jnp.int32),
            ep_dist.reshape(b, 1).astype(jnp.float32),
            q.astype(jnp.float32), neighbors0, vectors]
    if has_scales:
        in_specs.append(pl.BlockSpec(memory_space=pl.ANY))
        args.append(scales.reshape(-1, 1).astype(jnp.float32))
    scratch_shapes = [
        pltpu.VMEM((block_q, efp), jnp.float32),          # beam dists
        pltpu.VMEM((block_q, efp), jnp.int32),            # beam ids
        pltpu.VMEM((block_q, efp), jnp.int32),            # expanded flags
        pltpu.VMEM((block_q, t), jnp.int32),              # selected nodes
        pltpu.VMEM((block_q * t, m2), jnp.int32),         # neighbor rows
        pltpu.VMEM((2, wave, d), vectors.dtype),          # row double-buffer
        pltpu.VMEM((block_q, w), jnp.float32),            # candidate dists
    ]
    if has_scales:
        scratch_shapes.append(pltpu.VMEM((2, wave, 1), jnp.float32))
    scratch_shapes.append(pltpu.SMEM((1,), jnp.int32))    # early-exit flag
    scratch_shapes.append(pltpu.SemaphoreType.DMA((1,)))  # neighbor-list sem
    scratch_shapes.append(pltpu.SemaphoreType.DMA((2,)))  # row sem pair
    if has_scales:
        scratch_shapes.append(pltpu.SemaphoreType.DMA((2,)))

    return pl.pallas_call(
        functools.partial(_kernel, metric, int(ef), efp, t, wave, hops,
                          budget, n, has_scales),
        grid=(b // block_q,),
        in_specs=in_specs,
        out_specs=(pl.BlockSpec((block_q, ef), lambda i: (i, 0)),
                   pl.BlockSpec((block_q, ef), lambda i: (i, 0))),
        out_shape=(jax.ShapeDtypeStruct((b, ef), jnp.int32),
                   jax.ShapeDtypeStruct((b, ef), jnp.float32)),
        scratch_shapes=scratch_shapes,
        interpret=interpret,
    )(*args)


def beam_search_pallas(vectors: jax.Array, neighbors0: jax.Array,
                       q: jax.Array, ep: jax.Array, ep_dist: jax.Array,
                       *, ef: int, metric: str = "cosine",
                       scales: jax.Array | None = None, expand_t: int = 4,
                       max_iters: int | None = None, block_q: int = 8,
                       wave: int = 16, interpret: bool | None = None
                       ) -> tuple[jax.Array, jax.Array]:
    """One kernel launch per query block for the whole layer-0 ef-beam
    search. ``interpret=None`` resolves platform-aware."""
    return _call(vectors, neighbors0, q, ep, ep_dist, scales, metric,
                 int(ef), int(expand_t),
                 None if max_iters is None else int(max_iters),
                 block_q, wave, resolve_interpret(interpret))
