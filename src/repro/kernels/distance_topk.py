"""Blocked distance-matrix + per-tile top-k kernel (flat exact search).

The flat-index hot loop (and the recsys ``retrieval_cand`` cell): score a
query block against the whole database and keep the k best. Two-phase
split-K top-k:

  phase 1 (this kernel): grid (B tiles x N tiles). Each step loads a
    [BQ, D] query tile and a [BN, D] database tile into VMEM (BlockSpec),
    computes the [BQ, BN] distance tile on the MXU, then extracts the tile's
    top-k with k min-extraction passes (min/where/iota only — Mosaic-safe).
  phase 2 (ops.flat_topk): one tiny ``lax.top_k`` over the [B, n_tiles*k]
    partials.

MXU alignment: D and BN should be multiples of 128 for peak; the kernel is
shape-generic and the wrapper picks aligned tiles when it can.

Shapes / dtypes
  db   [N, D]  f32 (any float dtype; cast to f32 in-kernel)
  q    [B, D]  f32
  ->   dists [B, T*k] f32, ids [B, T*k] i32   (T = N / block_n tiles;
       per-tile partials — NOT the final top-k, see phase 2 above)

Grid / block layout
  grid = (B / block_q, N / block_n); block (i, j) loads q tile i and db
  tile j via BlockSpec (automatic HBM->VMEM pipelining), writes its k
  partials at output block column j. block_q/block_n are shrunk to the
  largest divisor of B/N when they don't divide evenly.

Fallback
  ``interpret=True`` runs the same kernel under the Pallas interpreter
  (any backend; this is how tests/test_kernels.py runs on CPU).
  ``ops.flat_topk`` only calls this on TPU (or REPRO_PALLAS=interpret);
  otherwise it uses the jnp oracle ``ref.distance_topk_ref`` — one
  [B, N] distance matrix + ``lax.top_k``, numerically identical.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BIG = 3.0e38   # plain float: pallas kernels must not capture traced constants


def _kernel(metric: str, k: int, q_ref, db_ref, dist_ref, idx_ref):
    j = pl.program_id(1)
    bn = db_ref.shape[0]
    q = q_ref[...].astype(jnp.float32)                    # [BQ, D]
    x = db_ref[...].astype(jnp.float32)                   # [BN, D]
    scores = jax.lax.dot_general(q, x, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    if metric in ("cosine", "ip"):
        d = 1.0 - scores                                  # [BQ, BN]
    else:
        qn = jnp.sum(q * q, axis=1, keepdims=True)
        xn = jnp.sum(x * x, axis=1)[None, :]
        d = qn - 2.0 * scores + xn
    col = jax.lax.broadcasted_iota(jnp.int32, d.shape, 1)
    base = j * bn

    for i in range(k):                                    # static, k small
        m = jnp.min(d, axis=1)                            # [BQ]
        pos = jnp.min(jnp.where(d == m[:, None], col, jnp.int32(2 ** 30)),
                      axis=1)                             # first argmin
        dist_ref[:, i] = m
        idx_ref[:, i] = pos + base
        d = jnp.where(col == pos[:, None], BIG, d)


@functools.partial(jax.jit, static_argnames=("k", "metric", "block_q",
                                             "block_n", "interpret"))
def distance_topk_pallas(db: jax.Array, q: jax.Array, k: int,
                         *, metric: str = "cosine", block_q: int = 128,
                         block_n: int = 1024, interpret: bool = True):
    """db [N,D], q [B,D] -> per-tile partials (dists [B,T*k], ids [B,T*k]).

    Callers finish with a [B, T*k] -> [B, k] top-k merge (see ops.flat_topk).
    """
    b, d = q.shape
    n = db.shape[0]
    block_q = min(block_q, b)
    while b % block_q:
        block_q -= 1
    block_n = min(block_n, n)
    while n % block_n:
        block_n -= 1
    assert k <= block_n, (k, block_n)
    tiles = n // block_n

    grid = (b // block_q, tiles)
    dists, ids = pl.pallas_call(
        functools.partial(_kernel, metric, k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_q, d), lambda i, j: (i, 0)),      # q
            pl.BlockSpec((block_n, d), lambda i, j: (j, 0)),      # db tile
        ],
        out_specs=[
            pl.BlockSpec((block_q, k), lambda i, j: (i, j)),
            pl.BlockSpec((block_q, k), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, tiles * k), jnp.float32),
            jax.ShapeDtypeStruct((b, tiles * k), jnp.int32),
        ],
        interpret=interpret,
    )(q, db)
    return dists, ids
