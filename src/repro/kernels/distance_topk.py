"""Blocked distance-matrix + per-tile top-k kernel (flat exact search).

The flat-index hot loop (and the recsys ``retrieval_cand`` cell): score a
query block against the whole database and keep the k best. Two-phase
split-K top-k:

  phase 1 (this kernel): grid (B tiles x N tiles). Each step loads a
    [BQ, D] query tile and a [BN, D] database tile into VMEM (BlockSpec),
    computes the [BQ, BN] distance tile on the MXU, then extracts the tile's
    top-k with k min-extraction passes (min/where/iota only — Mosaic-safe).
  phase 2 (ops.flat_topk): one tiny ``lax.top_k`` over the [B, n_tiles*k]
    partials.

MXU alignment: D and BN should be multiples of 128 for peak; the kernel is
shape-generic and the wrapper PADS to the tile multiple when B or N do not
divide — padded db rows are masked to +inf in-kernel (they can never reach
the top-k), padded query rows are sliced off the output. Earlier versions
instead SHRANK block_q/block_n to the largest divisor, which degenerates to
1-row blocks (a B×N program grid) whenever B or N is prime — the
regression test at N=997, B=7 in tests/test_kernels.py pins the fix.

Codec-encoded databases (DESIGN.md §9): ``db`` may be any dtype the codec
emits (f32 / bf16 / int8); rows are cast to f32 in-kernel and, when a
``scales`` [N] table is passed, multiplied by their per-row scale BEFORE
the distance — the fused decode-distance (asymmetric: fp32 query vs
encoded rows, fp32 accumulation on the MXU). With ``scales=None`` the
fp32 path is bit-for-bit the historical kernel.

Shapes / dtypes
  db     [N, D]  any float/int8 dtype (cast to f32 in-kernel)
  q      [B, D]  f32
  scales [N] f32 optional per-row decode scales (int8 codec)
  ->     dists [B, T*k] f32, ids [B, T*k] i32   (T = ceil(N / block_n)
         tiles; per-tile partials — NOT the final top-k, see phase 2)

Grid / block layout
  grid = (ceil(B / block_q), ceil(N / block_n)); block (i, j) loads q tile
  i and db tile j via BlockSpec (automatic HBM->VMEM pipelining), writes
  its k partials at output block column j.

Fallback
  ``interpret=None`` resolves platform-aware (kernels.resolve_interpret):
  the Pallas interpreter off-TPU, the compiled kernel on TPU — callers no
  longer pass the flag. ``ops.flat_topk`` only calls this on TPU (or
  REPRO_PALLAS=interpret); otherwise it uses the jnp oracle
  ``ref.distance_topk_ref`` — one [B, N] distance matrix + ``lax.top_k``,
  numerically identical.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import resolve_interpret

BIG = 3.0e38   # plain float: pallas kernels must not capture traced constants


def _kernel(metric: str, k: int, n_total: int, has_scales: bool, *refs):
    if has_scales:
        q_ref, db_ref, s_ref, dist_ref, idx_ref = refs
    else:
        q_ref, db_ref, dist_ref, idx_ref = refs
        s_ref = None
    j = pl.program_id(1)
    bn = db_ref.shape[0]
    q = q_ref[...].astype(jnp.float32)                    # [BQ, D]
    x = db_ref[...].astype(jnp.float32)                   # [BN, D]
    if s_ref is not None:
        x = x * s_ref[...].astype(jnp.float32)            # decode: [BN,1]·row
    scores = jax.lax.dot_general(q, x, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    if metric in ("cosine", "ip"):
        d = 1.0 - scores                                  # [BQ, BN]
    else:
        qn = jnp.sum(q * q, axis=1, keepdims=True)
        xn = jnp.sum(x * x, axis=1)[None, :]
        d = qn - 2.0 * scores + xn
    col = jax.lax.broadcasted_iota(jnp.int32, d.shape, 1)
    base = j * bn
    # mask db PADDING rows (global id >= N) out of the tile's top-k; a
    # no-op on fully-valid tiles, so divisible shapes are bit-identical
    d = jnp.where(col + base < n_total, d, BIG)

    for i in range(k):                                    # static, k small
        m = jnp.min(d, axis=1)                            # [BQ]
        pos = jnp.min(jnp.where(d == m[:, None], col, jnp.int32(2 ** 30)),
                      axis=1)                             # first argmin
        dist_ref[:, i] = m
        idx_ref[:, i] = pos + base
        d = jnp.where(col == pos[:, None], BIG, d)


@functools.partial(jax.jit, static_argnames=("k", "metric", "block_q",
                                             "block_n", "interpret"))
def _call(db, q, scales, k, metric, block_q, block_n, interpret):
    b, d = q.shape
    n = db.shape[0]
    block_q = min(block_q, b)
    block_n = min(block_n, n)
    assert k <= block_n, (k, block_n)
    # pad to the tile multiple instead of shrinking the tiles (see module
    # docstring): padded q rows are sliced off, padded db rows masked
    pb = -(-b // block_q) * block_q
    pn = -(-n // block_n) * block_n
    if pb > b:
        q = jnp.concatenate([q, jnp.zeros((pb - b, d), q.dtype)])
    if pn > n:
        db = jnp.concatenate([db, jnp.zeros((pn - n, d), db.dtype)])
        if scales is not None:
            scales = jnp.concatenate(
                [scales, jnp.zeros(pn - n, scales.dtype)])
    tiles = pn // block_n
    has_scales = scales is not None

    in_specs = [
        pl.BlockSpec((block_q, d), lambda i, j: (i, 0)),      # q
        pl.BlockSpec((block_n, d), lambda i, j: (j, 0)),      # db tile
    ]
    args = [q, db]
    if has_scales:
        in_specs.append(pl.BlockSpec((block_n, 1), lambda i, j: (j, 0)))
        args.append(scales.reshape(pn, 1).astype(jnp.float32))

    grid = (pb // block_q, tiles)
    dists, ids = pl.pallas_call(
        functools.partial(_kernel, metric, k, n, has_scales),
        grid=grid,
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((block_q, k), lambda i, j: (i, j)),
            pl.BlockSpec((block_q, k), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((pb, tiles * k), jnp.float32),
            jax.ShapeDtypeStruct((pb, tiles * k), jnp.int32),
        ],
        interpret=interpret,
    )(*args)
    return dists[:b], ids[:b]


def distance_topk_pallas(db: jax.Array, q: jax.Array, k: int,
                         *, metric: str = "cosine",
                         scales: jax.Array | None = None,
                         block_q: int = 128, block_n: int = 1024,
                         interpret: bool | None = None):
    """db [N,D] (+ optional scales [N]), q [B,D] -> per-tile partials
    (dists [B,T*k], ids [B,T*k]).

    Callers finish with a [B, T*k] -> [B, k] top-k merge (see
    ops.flat_topk). ``interpret=None`` resolves platform-aware.
    """
    return _call(db, q, scales, k, metric, block_q, block_n,
                 resolve_interpret(interpret))
