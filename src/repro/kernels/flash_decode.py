"""Flash-decode kernel: one-token GQA attention over a long KV cache.

Split-K over the sequence: grid (B, S tiles); running (m, l, acc) scratch
carries the online softmax across tiles (classic flash decoding). The KV
tiles stream HBM->VMEM via BlockSpec; per tile the score/PV matmuls run per
KV head (static loop, G query heads per KV head).

Shapes / dtypes
  q        [B, H, Dh]       any float (cast to f32 for scores)
  k, v     [B, S, KVH, Dh]  any float; H = G * KVH (GQA groups)
  cur_len  i32 scalar or [B]  live prefix length; positions >= cur_len are
                            masked (cache slots are capacity-padded). The
                            [B] form is the continuous-batching contract
                            (DESIGN.md §11): every serving slot carries its
                            OWN position, so one dispatch decodes slots at
                            different depths — admissions/evictions never
                            change the compiled shape, only the mask.
  ->       out [B, H, Dh] f32

Grid / block layout
  grid = (B, S / block_s); program (i, j) loads query row i (VMEM) and KV
  tile j [1, block_s, KVH, Dh] (BlockSpec-pipelined). cur_len sits in
  SMEM as a [B] vector; program (i, j) reads its own row's length.
  Scratch m/l [H, 1] + acc [H, Dh] carry the online softmax across
  the j axis (sequential grid dim on TPU); tile 0 initialises them, the
  last tile writes acc / l. block_s is shrunk to divide S.

Fallback
  ``interpret=True`` runs the kernel under the Pallas interpreter.
  ``ops.flash_decode`` dispatches to Pallas only on TPU (or
  REPRO_PALLAS=interpret); elsewhere the jnp oracle
  ``ref.flash_decode_ref`` computes the same masked softmax-attention in
  one shot. ``models/transformer.py``'s decode step consumes either.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30   # plain float: pallas kernels must not capture traced constants


def _kernel(st: int, kvh: int, g: int, cur_ref, q_ref, k_ref, v_ref, out_ref,
            m_sc, l_sc, acc_sc):
    i = pl.program_id(0)
    j = pl.program_id(1)
    nj = pl.num_programs(1)
    dh = q_ref.shape[2]
    scale = dh ** -0.5

    @pl.when(j == 0)
    def _():
        m_sc[...] = jnp.full_like(m_sc, NEG)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    kt = k_ref[0]                                   # [st, KVH, Dh]
    vt = v_ref[0]
    q = q_ref[0]                                    # [H, Dh]
    pos = j * st + jax.lax.broadcasted_iota(jnp.int32, (1, st), 1)[0]
    valid = pos < cur_ref[i]                        # [st]; per-sequence length

    for h in range(kvh):
        sl = slice(h * g, (h + 1) * g)
        qg = q[sl, :].astype(jnp.float32) * scale   # [G, Dh]
        kh = kt[:, h, :].astype(jnp.float32)        # [st, Dh]
        s = jax.lax.dot_general(qg, kh, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # [G, st]
        s = jnp.where(valid[None, :], s, NEG)
        m_prev = m_sc[sl, 0]
        l_prev = l_sc[sl, 0]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        m_safe = jnp.where(m_new <= NEG / 2, 0.0, m_new)
        p = jnp.where(valid[None, :], jnp.exp(s - m_safe[:, None]), 0.0)
        alpha = jnp.where(m_prev <= NEG / 2, 0.0, jnp.exp(m_prev - m_safe))
        vh = vt[:, h, :].astype(jnp.float32)        # [st, Dh]
        pv = jax.lax.dot_general(p, vh, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        m_sc[sl, 0] = m_new
        l_sc[sl, 0] = l_prev * alpha + jnp.sum(p, axis=1)
        acc_sc[sl, :] = acc_sc[sl, :] * alpha[:, None] + pv

    @pl.when(j == nj - 1)
    def _():
        out_ref[0] = (acc_sc[...]
                      / jnp.maximum(l_sc[...], 1e-30)).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def flash_decode_pallas(q: jax.Array, k: jax.Array, v: jax.Array,
                        cur_len: jax.Array, *, block_s: int = 512,
                        interpret: bool = True) -> jax.Array:
    """q [B,H,Dh]; k,v [B,S,KVH,Dh]; cur_len scalar or [B] i32 -> [B,H,Dh] f32."""
    b, h, dh = q.shape
    s, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    block_s = min(block_s, s)
    while s % block_s:
        block_s -= 1
    # scalar cur_len broadcasts to one length per batch row; [B] passes
    # through — every slot masks at its own depth (one compiled shape)
    cur = jnp.broadcast_to(
        jnp.asarray(cur_len, jnp.int32).reshape(-1), (b,))

    grid = (b, s // block_s)
    return pl.pallas_call(
        functools.partial(_kernel, block_s, kvh, g),
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),                    # cur_len
            pl.BlockSpec((1, h, dh), lambda i, j: (i, 0, 0)),         # q
            pl.BlockSpec((1, block_s, kvh, dh), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, block_s, kvh, dh), lambda i, j: (i, j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, h, dh), lambda i, j: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, dh), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((h, 1), jnp.float32),       # m
            pltpu.VMEM((h, 1), jnp.float32),       # l
            pltpu.VMEM((h, dh), jnp.float32),      # acc
        ],
        interpret=interpret,
    )(cur, q, k, v)
