# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.
from __future__ import annotations

import os


def resolve_interpret(interpret: bool | None) -> bool:
    """Platform-aware default for the Pallas ``interpret`` flag.

    ``None`` (the default in the retrieval kernels) resolves to
    "interpret only off-TPU": a TPU process compiles the real kernels
    without every caller having to pass ``interpret=False``, while CPU
    runs keep executing the same kernels under the interpreter. Override
    per-call with an explicit bool, or process-wide with
    ``REPRO_PALLAS_INTERPRET=1|0``.
    """
    if interpret is not None:
        return bool(interpret)
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env.lower() not in ("0", "false", "")
    import jax
    return jax.default_backend() != "tpu"
