"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Every kernel test sweeps shapes/dtypes and asserts allclose against these.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def gather_distance_ref(vectors: jax.Array, q: jax.Array, ids: jax.Array,
                        *, metric: str = "cosine",
                        scales: jax.Array | None = None) -> jax.Array:
    """vectors [N,D], q [B,D], ids [B,K] (valid, clamped) -> dists [B,K].

    ``scales`` [N] decodes codec-encoded rows (DESIGN.md §9): each
    gathered row is ``row · scale`` in fp32 — the asymmetric-distance
    contract (fp32 query vs encoded rows, fp32 accumulation)."""
    x = jnp.take(vectors, ids, axis=0).astype(jnp.float32)  # [B,K,D]
    if scales is not None:
        x = x * jnp.take(scales, ids).astype(jnp.float32)[..., None]
    if metric in ("cosine", "ip"):
        return 1.0 - jnp.einsum("bd,bkd->bk", q.astype(jnp.float32), x)
    d = x - q.astype(jnp.float32)[:, None, :]
    return jnp.einsum("bkd,bkd->bk", d, d)


def distance_topk_ref(db: jax.Array, q: jax.Array, k: int,
                      *, metric: str = "cosine",
                      scales: jax.Array | None = None
                      ) -> tuple[jax.Array, jax.Array]:
    """db [N,D], q [B,D] -> (dists [B,k] ascending, ids [B,k]).

    ``scales`` [N] decodes codec-encoded db rows in fp32 before the
    distance (asymmetric distance, DESIGN.md §9)."""
    x = db.astype(jnp.float32)
    if scales is not None:
        x = x * scales.astype(jnp.float32)[:, None]
    if metric in ("cosine", "ip"):
        d = 1.0 - jnp.einsum("bd,nd->bn", q.astype(jnp.float32), x)
    else:
        d = (jnp.sum(q.astype(jnp.float32) ** 2, -1)[:, None]
             - 2.0 * jnp.einsum("bd,nd->bn", q.astype(jnp.float32), x)
             + jnp.sum(x ** 2, -1)[None, :])
    neg, ids = jax.lax.top_k(-d, k)
    return -neg, ids


def embedding_bag_ref(table: jax.Array, ids: jax.Array,
                      weights: jax.Array | None = None,
                      *, combine: str = "sum") -> jax.Array:
    """table [R,E], ids [B,L] -> bags [B,E]; weights [B,L] optional."""
    g = jnp.take(table, ids, axis=0).astype(jnp.float32)   # [B,L,E]
    if weights is not None:
        g = g * weights.astype(jnp.float32)[..., None]
    s = jnp.sum(g, axis=1)
    if combine == "mean":
        n = (ids.shape[1] if weights is None
             else jnp.maximum(jnp.sum(weights, -1, keepdims=True), 1e-9))
        s = s / n
    return s


# ---------------------------------------------------------------------------
# fused beam search (kernels/beam_search.py): shared algorithm + jnp oracle
# ---------------------------------------------------------------------------
# The helpers below are used BOTH by ``beam_search_ref`` and by the Pallas
# kernel body (which swaps the gather for double-buffered DMA but runs the
# identical frontier/dedup/merge math on the fetched values) — one
# implementation, so fused-vs-jnp parity is structural, not coincidental.

# == core.hnsw.INF (empty-slot distance); a Python float so the Pallas
# kernel body can close over it without capturing a device constant
BEAM_INF = 3.0e38


def next_pow2(n: int) -> int:
    return 1 if n <= 1 else 1 << (int(n) - 1).bit_length()


def _compare_exchange(d, i, x, stride: int, asc_mask):
    """One bitonic compare-exchange stage on (dist, id, payload) triples
    along the last axis, ordered by the two-key (d, id) lexicographic
    compare. ``asc_mask`` [W] is each position's block direction. The
    partner of position p is p ^ stride — p+stride in lower halves,
    p-stride in upper halves — so a pair of rolls never wraps a pair
    across the array edge."""
    lower = (jnp.arange(d.shape[-1]) & stride) == 0
    pd = jnp.where(lower, jnp.roll(d, -stride, -1), jnp.roll(d, stride, -1))
    pi = jnp.where(lower, jnp.roll(i, -stride, -1), jnp.roll(i, stride, -1))
    px = jnp.where(lower, jnp.roll(x, -stride, -1), jnp.roll(x, stride, -1))
    le = (d < pd) | ((d == pd) & (i <= pi))
    keep = jnp.where(lower == asc_mask, le, ~le)
    return (jnp.where(keep, d, pd), jnp.where(keep, i, pi),
            jnp.where(keep, x, px))


def bitonic_sort(d, i, x, *, ascending: bool = True):
    """Full bitonic sort along the last axis (width must be a power of
    two) by the two-key (d, id) order. ~log²W compare-exchange stages of
    pure vector ops — no lax.sort, so the same network runs inside the
    Pallas kernel body."""
    w = d.shape[-1]
    idx = jnp.arange(w)
    size = 2
    while size <= w:
        asc_mask = ((idx & size) == 0) == bool(ascending)
        stride = size // 2
        while stride:
            d, i, x = _compare_exchange(d, i, x, stride, asc_mask)
            stride //= 2
        size *= 2
    return d, i, x


def bitonic_merge(d, i, x):
    """Bitonic merge: a bitonic input along the last axis (power-of-two
    width) sorts ascending in log W compare-exchange stages — the cheap
    half of a full sort, and the reason the beam stays sorted between
    hops instead of being re-sorted."""
    asc = jnp.ones(d.shape[-1], bool)
    stride = d.shape[-1] // 2
    while stride:
        d, i, x = _compare_exchange(d, i, x, stride, asc)
        stride //= 2
    return d, i, x


def beam_select_frontier(bd, bi, bx, t_live, t: int):
    """Mark the first ``t_live`` (<= t) unexpanded entries of the
    (ascending-sorted) beam as expanded and extract their node ids.
    Returns (new_bx, nodes [B, t] with -1 for unfilled slots). Rank among
    unexpanded entries comes from a strict-lower-triangular matmul —
    MXU-friendly and Mosaic-safe, where a lane cumsum is not."""
    efp = bd.shape[-1]
    unexp = (~bx) & (bi >= 0)
    tri = (jnp.arange(efp)[:, None] < jnp.arange(efp)[None, :]
           ).astype(jnp.float32)
    rank = jnp.dot(unexp.astype(jnp.float32), tri,
                   preferred_element_type=jnp.float32).astype(jnp.int32)
    sel = unexp & (rank < t_live)
    nodes = jnp.stack(
        [jnp.max(jnp.where(sel & (rank == j), bi, -1), axis=-1)
         for j in range(t)], axis=-1)
    return bx | sel, nodes


def beam_dedup_valid(cand, valid, bi):
    """Drop candidates already in the beam, or duplicated EARLIER in the
    flat candidate list (cross-list dups from multi-node expansion; the
    builder guarantees uniqueness within one neighbor list, not across
    lists). Keeping the earliest copy matches the reference semantics:
    duplicate copies carry bitwise-identical distances."""
    w = cand.shape[-1]
    in_beam = jnp.any(cand[:, :, None] == bi[:, None, :], axis=-1)
    eq = cand[:, :, None] == cand[:, None, :]
    earlier = jnp.arange(w)[:, None] > jnp.arange(w)[None, :]
    dup = jnp.any(eq & earlier[None] & valid[:, None, :], axis=-1)
    return valid & ~in_beam & ~dup


def beam_merge(bd, bi, bx, cd, ci, ef: int, use_bitonic: bool = True):
    """One-hop beam merge: bitonic-sort the candidates DESCENDING, glue
    them after the already-ascending beam (+ an INF plateau up to the
    next power of two) — the concatenation is bitonic by construction —
    and run a single bitonic merge. Entries past ``ef`` reset to
    (INF, -1, expanded) so the logical beam width stays exactly ef
    (recall parity with the ef-wide reference beam).

    ``use_bitonic=False`` swaps the network for one ``lax.sort`` over
    the plain concatenation — output-identical (live (d, id) keys are
    unique after dedup; ties exist only among (INF, -1) pads, whose
    expanded bit is never read downstream) but much cheaper as compiled
    XLA, where the network's O(log^2 W) elementwise stages lose to the
    native sort. The kernel keeps the network: Mosaic has no sort."""
    b, efp = bd.shape
    w = cd.shape[-1]
    if not use_bitonic:
        md = jnp.concatenate([bd, cd], axis=-1)
        mi = jnp.concatenate([bi, ci], axis=-1)
        mx = jnp.concatenate([bx, jnp.zeros((b, w), bool)], axis=-1)
        md, mi, mx = jax.lax.sort((md, mi, mx), dimension=-1, num_keys=2)
        live = jnp.arange(efp) < ef
        return (jnp.where(live, md[:, :efp], BEAM_INF),
                jnp.where(live, mi[:, :efp], -1),
                jnp.where(live, mx[:, :efp], True))
    wp = next_pow2(w)
    if wp > w:
        cd = jnp.concatenate(
            [cd, jnp.full((b, wp - w), BEAM_INF)], axis=-1)
        ci = jnp.concatenate(
            [ci, jnp.full((b, wp - w), -1, jnp.int32)], axis=-1)
    cx = jnp.zeros((b, wp), bool)
    cd, ci, cx = bitonic_sort(cd, ci, cx, ascending=False)
    pad = next_pow2(efp + wp) - efp - wp
    md = jnp.concatenate([bd, jnp.full((b, pad), BEAM_INF), cd], axis=-1)
    mi = jnp.concatenate(
        [bi, jnp.full((b, pad), -1, jnp.int32), ci], axis=-1)
    mx = jnp.concatenate([bx, jnp.ones((b, pad), bool), cx], axis=-1)
    md, mi, mx = bitonic_merge(md, mi, mx)
    live = jnp.arange(efp) < ef
    return (jnp.where(live, md[:, :efp], BEAM_INF),
            jnp.where(live, mi[:, :efp], -1),
            jnp.where(live, mx[:, :efp], True))


def beam_search_ref(vectors: jax.Array, neighbors0: jax.Array,
                    q: jax.Array, ep: jax.Array, ep_dist: jax.Array,
                    *, ef: int, metric: str = "cosine",
                    scales: jax.Array | None = None, expand_t: int = 4,
                    max_iters: int | None = None
                    ) -> tuple[jax.Array, jax.Array]:
    """jnp oracle for the fused layer-0 ef-beam search kernel: identical
    frontier selection, dedup, and bitonic merge, with the kernel's
    per-hop DMA gather replaced by ``gather_distance_ref``.

    vectors [N, D] (any codec dtype; ``scales`` [N] decodes), neighbors0
    [N, 2M] i32 (-1 pad), q [B, D] f32, ep/ep_dist [B] layer-0 entry
    points. Returns (ids [B, ef], dists [B, ef]) ascending by (d, id);
    empty slots are (-1, INF).

    ``expand_t`` nodes expand per hop against a TOTAL expansion budget of
    ``max_iters`` (default ef, plus one slack hop when expand_t > 1), so
    hops = ceil(budget / expand_t) with the last hop truncated. At
    expand_t=1 the visit order is exactly the sequential-semantics
    ``core.hnsw._beam_search`` order."""
    b = q.shape[0]
    n, m2 = neighbors0.shape
    t = max(1, min(int(expand_t), int(ef)))
    # default budget: ef, plus one slack hop at t>1 (kept in lockstep
    # with kernels/beam_search.py — group frontier selection needs the
    # slack to match the one-at-a-time order's recall, DESIGN.md §12)
    budget = ((int(ef) + (t if t > 1 else 0)) if max_iters is None
              else int(max_iters))
    hops = -(-budget // t) if budget > 0 else 0
    efp = next_pow2(ef)
    col = jnp.arange(efp)[None, :]
    bd = jnp.where(col == 0, ep_dist[:, None].astype(jnp.float32), BEAM_INF)
    bi = jnp.where(col == 0, ep[:, None].astype(jnp.int32), -1)
    bx = jnp.broadcast_to(col != 0, (b, efp))

    def cond(state):
        bd, bi, bx, hop = state
        return (hop < hops) & jnp.any((~bx) & (bi >= 0))

    def body(state):
        bd, bi, bx, hop = state
        t_live = jnp.minimum(t, budget - hop * t)
        bx, nodes = beam_select_frontier(bd, bi, bx, t_live, t)
        nbrs = jnp.take(neighbors0, jnp.clip(nodes, 0, n - 1), axis=0)
        valid = ((nodes >= 0)[:, :, None] & (nbrs >= 0)).reshape(b, t * m2)
        cand = jnp.clip(nbrs, 0, n - 1).reshape(b, t * m2)
        d = gather_distance_ref(vectors, q, cand, metric=metric,
                                scales=scales)
        valid = beam_dedup_valid(cand, valid, bi)
        cd = jnp.where(valid, d, BEAM_INF)
        ci = jnp.where(valid, cand, -1)
        bd, bi, bx = beam_merge(bd, bi, bx, cd, ci, int(ef),
                                use_bitonic=False)
        return bd, bi, bx, hop + 1

    bd, bi, bx, _ = jax.lax.while_loop(
        cond, body, (bd, bi, bx, jnp.zeros((), jnp.int32)))
    return bi[:, :ef], bd[:, :ef]


# ---------------------------------------------------------------------------
# batched neighbor-selection heuristic (HNSW construction, DESIGN.md §13)
# ---------------------------------------------------------------------------
def select_neighbors_ref(vectors: jax.Array, q: jax.Array,
                         cand_ids: jax.Array, *, m: int,
                         metric: str = "cosine",
                         scales: jax.Array | None = None
                         ) -> tuple[jax.Array, jax.Array]:
    """Batched Malkov & Yashunin Alg. 4 (the neighbor-selection heuristic
    with ``keepPrunedConnections=True``), output-identical per row to the
    host oracle ``hnsw_build.select_heuristic_host``.

    vectors [N, D] (any codec dtype; ``scales`` [N] decodes), q [B, D]
    f32, cand_ids [B, C] i32 with -1 padding -> (ids [B, m] i32 -1-pad,
    dists [B, m] f32 INF-pad, ascending by selection order).

    Per row: candidates sort by the two-key (dist-to-q, id) order (the
    host sorts (d, e) tuples — ties break on id); a masked keep-scan
    walks them in that order keeping candidate ``i`` iff no
    already-kept ``j`` is closer to ``i`` than ``q`` is
    (``pd[i, j] < d[i]`` rejects); the first ``m`` keeps are the
    heuristic picks, and pruned/untested candidates backfill in sorted
    order. The pairwise block ``pd`` is one [B, C, C] einsum — the
    O(B·C²·D) work the per-node host loops serialized.

    Duplicate ids keep their first occurrence (the reciprocal-connect
    caller merges an existing adjacency row with new back-edge sources,
    where an intra-batch source can already be a forward neighbor)."""
    b, c = cand_ids.shape
    if c < m:                      # width must cover the output slots
        cand_ids = jnp.concatenate(
            [cand_ids, jnp.full((b, m - c), -1, jnp.int32)], axis=1)
        c = m
    n = vectors.shape[0]
    valid = cand_ids >= 0
    idc = jnp.clip(cand_ids, 0, n - 1)
    # keep-first dedup (same mask construction as beam_dedup_valid)
    eq = idc[:, :, None] == idc[:, None, :]
    earlier = jnp.arange(c)[:, None] > jnp.arange(c)[None, :]
    dup = jnp.any(eq & earlier[None] & valid[:, None, :], axis=-1)
    valid = valid & ~dup
    d = gather_distance_ref(vectors, q, idc, metric=metric, scales=scales)
    d = jnp.where(valid, d, BEAM_INF)
    sid = jnp.where(valid, cand_ids, jnp.iinfo(jnp.int32).max)
    sd, si = jax.lax.sort((d, sid), num_keys=2)          # (d, id) ascending
    svalid = sd < BEAM_INF
    # pairwise distances between the sorted candidates, decoded in fp32
    x = jnp.take(vectors, jnp.clip(si, 0, n - 1), axis=0).astype(jnp.float32)
    if scales is not None:
        x = x * jnp.take(scales, jnp.clip(si, 0, n - 1)
                         ).astype(jnp.float32)[..., None]
    if metric in ("cosine", "ip"):
        pd = 1.0 - jnp.einsum("bid,bjd->bij", x, x,
                              preferred_element_type=jnp.float32)
    else:
        sq = jnp.sum(x * x, axis=-1)
        pd = (sq[:, :, None] - 2.0 * jnp.einsum(
            "bid,bjd->bij", x, x, preferred_element_type=jnp.float32)
            + sq[:, None, :])

    def step(i, kept):
        # candidate i survives iff no already-kept j dominates it:
        # pd[i, j] < d(i, q) is the host oracle's strict rejection test
        ok = svalid[:, i] & ~jnp.any(kept & (pd[:, i, :] < sd[:, i, None]),
                                     axis=-1)
        return kept.at[:, i].set(ok)

    kept = jax.lax.fori_loop(0, c, step, jnp.zeros((b, c), bool))
    rank = jnp.cumsum(kept, axis=-1) - kept.astype(jnp.int32)
    primary = kept & (rank < m)
    # heuristic picks first (in sorted order), then backfill in sorted
    # order; invalid slots sorted to the very end by construction
    pos = jnp.broadcast_to(jnp.arange(c)[None, :], (b, c))
    key = jnp.where(primary, pos, pos + c)
    order = jnp.argsort(key, axis=-1)[:, :m]
    out_i = jnp.take_along_axis(si, order, axis=1)
    out_d = jnp.take_along_axis(sd, order, axis=1)
    out_v = jnp.take_along_axis(svalid, order, axis=1)
    return (jnp.where(out_v, out_i, -1).astype(jnp.int32),
            jnp.where(out_v, out_d, BEAM_INF))


def flash_decode_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                     cur_len: jax.Array) -> jax.Array:
    """q [B,H,Dh]; k,v [B,S,KVH,Dh]; mask pos >= cur_len -> out [B,H,Dh].

    ``cur_len`` is a scalar or [B] (continuous batching: each serving
    slot masks at its own depth within one dispatch)."""
    b, h, dh = q.shape
    s, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    qg = q.reshape(b, kvh, g, dh).astype(jnp.float32) * dh ** -0.5
    scores = jnp.einsum("bkgd,bskd->bkgs", qg, k.astype(jnp.float32))
    cur = jnp.broadcast_to(
        jnp.asarray(cur_len, jnp.int32).reshape(-1), (b,))
    mask = jnp.arange(s)[None, None, None, :] < cur[:, None, None, None]
    scores = jnp.where(mask, scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p, v.astype(jnp.float32))
    return out.reshape(b, h, dh)
