"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Every kernel test sweeps shapes/dtypes and asserts allclose against these.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def gather_distance_ref(vectors: jax.Array, q: jax.Array, ids: jax.Array,
                        *, metric: str = "cosine",
                        scales: jax.Array | None = None) -> jax.Array:
    """vectors [N,D], q [B,D], ids [B,K] (valid, clamped) -> dists [B,K].

    ``scales`` [N] decodes codec-encoded rows (DESIGN.md §9): each
    gathered row is ``row · scale`` in fp32 — the asymmetric-distance
    contract (fp32 query vs encoded rows, fp32 accumulation)."""
    x = jnp.take(vectors, ids, axis=0).astype(jnp.float32)  # [B,K,D]
    if scales is not None:
        x = x * jnp.take(scales, ids).astype(jnp.float32)[..., None]
    if metric in ("cosine", "ip"):
        return 1.0 - jnp.einsum("bd,bkd->bk", q.astype(jnp.float32), x)
    d = x - q.astype(jnp.float32)[:, None, :]
    return jnp.einsum("bkd,bkd->bk", d, d)


def distance_topk_ref(db: jax.Array, q: jax.Array, k: int,
                      *, metric: str = "cosine",
                      scales: jax.Array | None = None
                      ) -> tuple[jax.Array, jax.Array]:
    """db [N,D], q [B,D] -> (dists [B,k] ascending, ids [B,k]).

    ``scales`` [N] decodes codec-encoded db rows in fp32 before the
    distance (asymmetric distance, DESIGN.md §9)."""
    x = db.astype(jnp.float32)
    if scales is not None:
        x = x * scales.astype(jnp.float32)[:, None]
    if metric in ("cosine", "ip"):
        d = 1.0 - jnp.einsum("bd,nd->bn", q.astype(jnp.float32), x)
    else:
        d = (jnp.sum(q.astype(jnp.float32) ** 2, -1)[:, None]
             - 2.0 * jnp.einsum("bd,nd->bn", q.astype(jnp.float32), x)
             + jnp.sum(x ** 2, -1)[None, :])
    neg, ids = jax.lax.top_k(-d, k)
    return -neg, ids


def embedding_bag_ref(table: jax.Array, ids: jax.Array,
                      weights: jax.Array | None = None,
                      *, combine: str = "sum") -> jax.Array:
    """table [R,E], ids [B,L] -> bags [B,E]; weights [B,L] optional."""
    g = jnp.take(table, ids, axis=0).astype(jnp.float32)   # [B,L,E]
    if weights is not None:
        g = g * weights.astype(jnp.float32)[..., None]
    s = jnp.sum(g, axis=1)
    if combine == "mean":
        n = (ids.shape[1] if weights is None
             else jnp.maximum(jnp.sum(weights, -1, keepdims=True), 1e-9))
        s = s / n
    return s


def flash_decode_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                     cur_len: jax.Array) -> jax.Array:
    """q [B,H,Dh]; k,v [B,S,KVH,Dh]; mask pos >= cur_len -> out [B,H,Dh].

    ``cur_len`` is a scalar or [B] (continuous batching: each serving
    slot masks at its own depth within one dispatch)."""
    b, h, dh = q.shape
    s, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    qg = q.reshape(b, kvh, g, dh).astype(jnp.float32) * dh ** -0.5
    scores = jnp.einsum("bkgd,bskd->bkgs", qg, k.astype(jnp.float32))
    cur = jnp.broadcast_to(
        jnp.asarray(cur_len, jnp.int32).reshape(-1), (b,))
    mask = jnp.arange(s)[None, None, None, :] < cur[:, None, None, None]
    scores = jnp.where(mask, scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p, v.astype(jnp.float32))
    return out.reshape(b, h, dh)
