"""EmbeddingBag kernel: wave-DMA gather + in-VMEM reduce (recsys hot loop).

JAX has no native EmbeddingBag; the jnp path (gather [B,L,E] then reduce)
materialises the full gathered tensor in HBM. This kernel keeps the bag
reduction in VMEM: the table stays in HBM (memory_space=ANY), bag member
rows stream in via double-buffered DMA waves, and each wave accumulates into
the output tile — HBM traffic is exactly rows-read + bags-written.

Shapes / dtypes
  table    [R, E]  any float (accumulation in f32)
  ids      [B, L]  i32 rows into ``table`` (pad a short bag with weight-0
                   slots — ids must still be in [0, R))
  weights  [B, L]  f32 or None (None -> all-ones; "mean" divides by the
                   weight sum per bag, clamped away from 0)
  ->       bags [B, E] f32; combine in {"sum", "mean"}

Grid / block layout
  grid = (B / block_b,): one step per bag block. ids/weights tiles
  [block_b, L] live in VMEM (BlockSpec); the table is never tiled in.
  scratch [2, wave, E] + 2 DMA semaphores double-buffer the row fetches
  (block_b*L fetches issued ``wave`` at a time), and acc [block_b, E]
  holds the running weighted sums; the combine normalisation happens once
  at the end. ``wave`` is shrunk to divide block_b*L.

Fallback
  ``interpret=True`` runs the kernel under the Pallas interpreter (CPU
  kernel tests). ``ops.embedding_bag`` picks Pallas only on TPU (or
  REPRO_PALLAS=interpret); otherwise the jnp oracle
  ``ref.embedding_bag_ref`` does the gather-then-reduce in HBM — same
  numbers, more traffic. The recsys models route through ``ops``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(combine: str, wave: int, ids_ref, w_ref, table_ref, out_ref,
            scratch, acc, sems):
    bq, l = ids_ref.shape
    e = out_ref.shape[1]
    total = bq * l
    total_waves = total // wave

    def dma(slot, w_idx):
        def issue(i, _):
            flat = w_idx * wave + i
            row = ids_ref[flat // l, flat % l]
            pltpu.make_async_copy(
                table_ref.at[pl.ds(row, 1)], scratch.at[slot, pl.ds(i, 1)],
                sems.at[slot]).start()
            return 0
        jax.lax.fori_loop(0, wave, issue, 0)

    def wait(slot):
        def w(i, _):
            pltpu.make_async_copy(
                table_ref.at[pl.ds(0, 1)], scratch.at[slot, pl.ds(i, 1)],
                sems.at[slot]).wait()
            return 0
        jax.lax.fori_loop(0, wave, w, 0)

    acc[...] = jnp.zeros_like(acc)
    dma(0, 0)

    def step(w_idx, _):
        slot = w_idx % 2

        @pl.when(w_idx + 1 < total_waves)
        def _():
            dma((w_idx + 1) % 2, w_idx + 1)

        wait(slot)
        rows = scratch[slot].astype(jnp.float32)            # [wave, E]

        def one(i, _):
            flat = w_idx * wave + i
            b_i, l_i = flat // l, flat % l
            wgt = w_ref[b_i, l_i].astype(jnp.float32)
            acc[b_i, :] = acc[b_i, :] + rows[i, :] * wgt
            return 0

        jax.lax.fori_loop(0, wave, one, 0)
        return 0

    jax.lax.fori_loop(0, total_waves, step, 0)
    if combine == "mean":
        denom = jnp.maximum(jnp.sum(w_ref[...].astype(jnp.float32), axis=1,
                                    keepdims=True), 1e-9)
        out_ref[...] = acc[...] / denom
    else:
        out_ref[...] = acc[...]


@functools.partial(jax.jit, static_argnames=("combine", "block_b", "wave",
                                             "interpret"))
def embedding_bag_pallas(table: jax.Array, ids: jax.Array,
                         weights: jax.Array | None = None,
                         *, combine: str = "sum", block_b: int = 8,
                         wave: int = 8, interpret: bool = True) -> jax.Array:
    """table [R,E] (HBM), ids [B,L], weights [B,L] -> bags [B,E] f32."""
    b, l = ids.shape
    e = table.shape[1]
    if weights is None:
        weights = jnp.ones((b, l), jnp.float32)
    block_b = min(block_b, b)
    while b % block_b:
        block_b -= 1
    wave = min(wave, block_b * l)
    while (block_b * l) % wave:
        wave -= 1

    return pl.pallas_call(
        functools.partial(_kernel, combine, wave),
        grid=(b // block_b,),
        in_specs=[
            pl.BlockSpec((block_b, l), lambda i: (i, 0)),    # ids
            pl.BlockSpec((block_b, l), lambda i: (i, 0)),    # weights
            pl.BlockSpec(memory_space=pl.ANY),            # table
        ],
        out_specs=pl.BlockSpec((block_b, e), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, e), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((2, wave, e), table.dtype),
            pltpu.VMEM((block_b, e), jnp.float32),
            pltpu.SemaphoreType.DMA((2,)),
        ],
        interpret=interpret,
    )(ids, weights, table)
