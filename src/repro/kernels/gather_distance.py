"""Fused gather(HBM)→VMEM + distance kernel — MeMemo's prefetch (C2) on TPU.

HNSW frontier expansion reads K graph-neighbor vectors per query and scores
them against the query. The browser version amortises IndexedDB transactions
by prefetching ``p`` neighbors per miss; here the analogue is wave-batched
async DMA: the database stays in HBM (``memory_space=ANY``), each wave issues
``WAVE`` row DMAs into a double-buffered VMEM scratch, and the distance for
wave ``i`` computes while wave ``i+1`` is in flight.

Codec-encoded databases (DESIGN.md §9): ``vectors`` may be any dtype the
codec emits (f32 / bf16 / int8) — the scratch buffer matches it, so an
int8 row moves 4x fewer bytes per DMA. When a per-row ``scales`` [N] f32
table is passed, each row's scale rides its own (overlapped) 4-byte DMA
and the decode (``row · scale`` in f32) fuses into the distance — the
asymmetric-distance contract: fp32 query vs encoded rows, fp32
accumulation. ``scales=None`` keeps the fp32 path bit-for-bit.

Shapes / dtypes
  vectors [N, D]  f32 / bf16 / int8 (stays in HBM — ``memory_space=ANY``;
                  scratch matches it, distances compute in f32)
  q       [B, D]  f32
  ids     [B, K]  i32 row ids into ``vectors`` (callers pre-clip to
                  [0, N); invalid slots are masked AFTER the kernel)
  scales  [N] f32 optional per-row decode scales (int8 codec)
  ->      dists [B, K] f32  (cosine/ip: 1 - <q, x>; l2: squared distance)

Grid / block layout
  grid = (B / block_q,): one step per query block. Per step the q tile
  [BQ, D] and ids tile [BQ, K] live in VMEM (BlockSpec); the database is
  never tiled in. scratch [2, WAVE, D] + 2 DMA semaphores implement the
  double buffer (scales add a [2, WAVE, 1] scratch + their own semaphore
  pair): the BQ*K row fetches are issued WAVE at a time, and wave i's
  distances compute while wave i+1's DMAs are in flight. ``wave`` is
  shrunk to divide block_q*K.

Fallback
  ``interpret=None`` resolves platform-aware (kernels.resolve_interpret):
  the Pallas interpreter off-TPU, the compiled kernel on TPU — callers no
  longer pass the flag. ``ops.gather_distance`` only selects the Pallas
  path on TPU (or REPRO_PALLAS=interpret); otherwise it runs the jnp
  oracle ``ref.gather_distance_ref`` — ``take`` + fused dot, same
  results. The HNSW search (core/hnsw.py) layers its own -1-padding mask
  on top either way.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import resolve_interpret


def _kernel(metric: str, wave: int, has_scales: bool, *refs):
    if has_scales:
        (ids_ref, q_ref, db_ref, scl_ref, out_ref,
         scratch, s_scratch, sems, s_sems) = refs
    else:
        ids_ref, q_ref, db_ref, out_ref, scratch, sems = refs
        scl_ref = s_scratch = s_sems = None
    bq, k = ids_ref.shape
    total = bq * k

    def dma(slot, w_idx):
        """Issue the DMAs for flat wave ``w_idx`` into scratch[slot]."""
        def issue(i, _):
            flat = w_idx * wave + i
            row = ids_ref[flat // k, flat % k]
            pltpu.make_async_copy(
                db_ref.at[pl.ds(row, 1)], scratch.at[slot, pl.ds(i, 1)],
                sems.at[slot]).start()
            if has_scales:
                pltpu.make_async_copy(
                    scl_ref.at[pl.ds(row, 1)],
                    s_scratch.at[slot, pl.ds(i, 1)],
                    s_sems.at[slot]).start()
            return 0
        jax.lax.fori_loop(0, wave, issue, 0)

    def wait(slot):
        def w(i, _):
            pltpu.make_async_copy(
                db_ref.at[pl.ds(0, 1)], scratch.at[slot, pl.ds(i, 1)],
                sems.at[slot]).wait()
            if has_scales:
                pltpu.make_async_copy(
                    scl_ref.at[pl.ds(0, 1)],
                    s_scratch.at[slot, pl.ds(i, 1)],
                    s_sems.at[slot]).wait()
            return 0
        jax.lax.fori_loop(0, wave, w, 0)

    total_waves = total // wave
    dma(0, 0)

    def step(w_idx, _):
        slot = w_idx % 2
        nxt = (w_idx + 1) % 2

        @pl.when(w_idx + 1 < total_waves)
        def _():
            dma(nxt, w_idx + 1)

        wait(slot)
        rows = scratch[slot]                                  # [wave, D]

        def one(i, _):
            flat = w_idx * wave + i
            b_i, k_i = flat // k, flat % k
            qv = q_ref[b_i, :].astype(jnp.float32)
            xv = rows[i, :].astype(jnp.float32)
            if has_scales:
                xv = xv * s_scratch[slot, i, 0]               # fused decode
            if metric in ("cosine", "ip"):
                dist = 1.0 - jnp.sum(qv * xv)
            else:
                dist = jnp.sum((qv - xv) ** 2)
            out_ref[b_i, k_i] = dist
            return 0

        jax.lax.fori_loop(0, wave, one, 0)
        return 0

    jax.lax.fori_loop(0, total_waves, step, 0)


@functools.partial(jax.jit, static_argnames=("metric", "block_q", "wave",
                                             "interpret"))
def _call(vectors, q, ids, scales, metric, block_q, wave, interpret):
    b, k = ids.shape
    d = q.shape[1]
    block_q = min(block_q, b)
    while b % block_q:
        block_q -= 1
    wave = min(wave, block_q * k)
    while (block_q * k) % wave:
        wave -= 1
    has_scales = scales is not None

    in_specs = [
        pl.BlockSpec((block_q, k), lambda i: (i, 0)),                # ids
        pl.BlockSpec((block_q, d), lambda i: (i, 0)),                # q
        pl.BlockSpec(memory_space=pl.ANY),                           # db
    ]
    args = [ids, q, vectors]
    scratch_shapes = [pltpu.VMEM((2, wave, d), vectors.dtype)]
    if has_scales:
        in_specs.append(pl.BlockSpec(memory_space=pl.ANY))           # scales
        args.append(scales.reshape(-1, 1).astype(jnp.float32))
        scratch_shapes.append(pltpu.VMEM((2, wave, 1), jnp.float32))
    scratch_shapes.append(pltpu.SemaphoreType.DMA((2,)))
    if has_scales:
        scratch_shapes.append(pltpu.SemaphoreType.DMA((2,)))

    grid = (b // block_q,)
    return pl.pallas_call(
        functools.partial(_kernel, metric, wave, has_scales),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_q, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, k), jnp.float32),
        scratch_shapes=scratch_shapes,
        interpret=interpret,
    )(*args)


def gather_distance_pallas(vectors: jax.Array, q: jax.Array, ids: jax.Array,
                           *, metric: str = "cosine",
                           scales: jax.Array | None = None,
                           block_q: int = 8, wave: int = 8,
                           interpret: bool | None = None) -> jax.Array:
    """vectors [N,D] (HBM, any codec dtype) + optional scales [N], q [B,D],
    ids [B,K] -> dists [B,K] f32. ``interpret=None`` resolves
    platform-aware."""
    return _call(vectors, q, ids, scales, metric, block_q, wave,
                 resolve_interpret(interpret))
