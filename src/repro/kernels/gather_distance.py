"""Fused gather(HBM)→VMEM + distance kernel — MeMemo's prefetch (C2) on TPU.

HNSW frontier expansion reads K graph-neighbor vectors per query and scores
them against the query. The browser version amortises IndexedDB transactions
by prefetching ``p`` neighbors per miss; here the analogue is wave-batched
async DMA: the database stays in HBM (``memory_space=ANY``), each wave issues
``WAVE`` row DMAs into a double-buffered VMEM scratch, and the distance for
wave ``i`` computes while wave ``i+1`` is in flight.

Shapes / dtypes
  vectors [N, D]  f32 (stays in HBM — ``memory_space=ANY``; any float
                  dtype, scratch matches it, distances compute in f32)
  q       [B, D]  f32
  ids     [B, K]  i32 row ids into ``vectors`` (callers pre-clip to
                  [0, N); invalid slots are masked AFTER the kernel)
  ->      dists [B, K] f32  (cosine/ip: 1 - <q, x>; l2: squared distance)

Grid / block layout
  grid = (B / block_q,): one step per query block. Per step the q tile
  [BQ, D] and ids tile [BQ, K] live in VMEM (BlockSpec); the database is
  never tiled in. scratch [2, WAVE, D] + 2 DMA semaphores implement the
  double buffer: the BQ*K row fetches are issued WAVE at a time, and wave
  i's distances compute while wave i+1's DMAs are in flight. ``wave`` is
  shrunk to divide block_q*K.

Fallback
  ``interpret=True`` runs this kernel under the Pallas interpreter (any
  backend; kernel tests on CPU). ``ops.gather_distance`` only selects the
  Pallas path on TPU (or REPRO_PALLAS=interpret); otherwise it runs the
  jnp oracle ``ref.gather_distance_ref`` — ``take`` + fused dot, same
  results. The HNSW search (core/hnsw.py) layers its own -1-padding mask
  on top either way.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(metric: str, wave: int, ids_ref, q_ref, db_ref, out_ref,
            scratch, sems):
    bq, k = ids_ref.shape
    d = q_ref.shape[1]
    n_waves = k // wave
    total = bq * k

    def dma(slot, w_idx):
        """Issue the DMAs for flat wave ``w_idx`` into scratch[slot]."""
        def issue(i, _):
            flat = w_idx * wave + i
            row = ids_ref[flat // k, flat % k]
            cp = pltpu.make_async_copy(
                db_ref.at[pl.ds(row, 1)], scratch.at[slot, pl.ds(i, 1)],
                sems.at[slot])
            cp.start()
            return 0
        jax.lax.fori_loop(0, wave, issue, 0)

    def wait(slot):
        def w(i, _):
            pltpu.make_async_copy(
                db_ref.at[pl.ds(0, 1)], scratch.at[slot, pl.ds(i, 1)],
                sems.at[slot]).wait()
            return 0
        jax.lax.fori_loop(0, wave, w, 0)

    total_waves = total // wave
    dma(0, 0)

    def step(w_idx, _):
        slot = w_idx % 2
        nxt = (w_idx + 1) % 2

        @pl.when(w_idx + 1 < total_waves)
        def _():
            dma(nxt, w_idx + 1)

        wait(slot)
        rows = scratch[slot]                                  # [wave, D]

        def one(i, _):
            flat = w_idx * wave + i
            b_i, k_i = flat // k, flat % k
            qv = q_ref[b_i, :].astype(jnp.float32)
            xv = rows[i, :].astype(jnp.float32)
            if metric in ("cosine", "ip"):
                dist = 1.0 - jnp.sum(qv * xv)
            else:
                dist = jnp.sum((qv - xv) ** 2)
            out_ref[b_i, k_i] = dist
            return 0

        jax.lax.fori_loop(0, wave, one, 0)
        return 0

    jax.lax.fori_loop(0, total_waves, step, 0)


@functools.partial(jax.jit, static_argnames=("metric", "block_q", "wave",
                                             "interpret"))
def gather_distance_pallas(vectors: jax.Array, q: jax.Array, ids: jax.Array,
                           *, metric: str = "cosine", block_q: int = 8,
                           wave: int = 8, interpret: bool = True) -> jax.Array:
    """vectors [N,D] (HBM), q [B,D], ids [B,K] -> dists [B,K] f32."""
    b, k = ids.shape
    d = q.shape[1]
    block_q = min(block_q, b)
    while b % block_q:
        block_q -= 1
    wave = min(wave, block_q * k)
    while (block_q * k) % wave:
        wave -= 1

    grid = (b // block_q,)
    return pl.pallas_call(
        functools.partial(_kernel, metric, wave),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_q, k), lambda i: (i, 0)),                # ids
            pl.BlockSpec((block_q, d), lambda i: (i, 0)),                # q
            pl.BlockSpec(memory_space=pl.ANY),                        # db
        ],
        out_specs=pl.BlockSpec((block_q, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, k), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((2, wave, d), vectors.dtype),
            pltpu.SemaphoreType.DMA((2,)),
        ],
        interpret=interpret,
    )(ids, q, vectors)
