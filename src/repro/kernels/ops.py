"""Public jit'd wrappers for the kernel layer, with backend dispatch.

Dispatch policy (env ``REPRO_PALLAS``):
  "auto" (default) — Pallas (compiled) on TPU; pure-jnp reference elsewhere
  "interpret"      — Pallas in interpret mode everywhere (kernel tests)
  "off"            — always the jnp reference

The jnp reference paths are the same oracles the kernel tests assert
against, so behaviour is identical either way.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref


def _mode() -> str:
    return os.environ.get("REPRO_PALLAS", "auto")


def _use_pallas() -> tuple[bool, bool]:
    """-> (use_pallas, interpret)."""
    m = _mode()
    if m == "off":
        return False, False
    if m == "interpret":
        return True, True
    on_tpu = jax.default_backend() == "tpu"
    return on_tpu, False


# ---------------------------------------------------------------------------
def gather_distance(vectors: jax.Array, q: jax.Array, ids: jax.Array,
                    *, metric: str = "cosine",
                    scales: jax.Array | None = None) -> jax.Array:
    """Fused gather+distance: vectors [N,D], q [B,D], ids [B,K] -> [B,K].

    ``vectors`` may be codec-encoded (f32 / bf16 / int8, DESIGN.md §9);
    ``scales`` [N] fuses the per-row decode into the distance."""
    use, interp = _use_pallas()
    if use:
        from repro.kernels.gather_distance import gather_distance_pallas
        return gather_distance_pallas(vectors, q, ids, metric=metric,
                                      scales=scales, interpret=interp)
    return _ref.gather_distance_ref(vectors, q, ids, metric=metric,
                                    scales=scales)


def beam_search(vectors: jax.Array, neighbors0: jax.Array, q: jax.Array,
                ep: jax.Array, ep_dist: jax.Array, *, ef: int,
                metric: str = "cosine", scales: jax.Array | None = None,
                expand_t: int = 4, max_iters: int | None = None
                ) -> tuple[jax.Array, jax.Array]:
    """Whole layer-0 ef-beam HNSW search in ONE launch (DESIGN.md §12):
    per-hop neighbor gather, fused codec-decode distance, and in-kernel
    bitonic beam merge, expanding the top ``expand_t`` frontier nodes
    per hop. vectors [N,D] (any codec dtype, ``scales`` [N] decodes),
    neighbors0 [N,2M] i32, q [B,D], ep/ep_dist [B] entry points ->
    (ids [B,ef], dists [B,ef]) ascending by (d, id), empty slots
    (-1, INF). The jnp fallback is the identical algorithm on the same
    helpers (``ref.beam_search_ref``)."""
    use, interp = _use_pallas()
    if use:
        from repro.kernels.beam_search import beam_search_pallas
        return beam_search_pallas(vectors, neighbors0, q, ep, ep_dist,
                                  ef=ef, metric=metric, scales=scales,
                                  expand_t=expand_t, max_iters=max_iters,
                                  interpret=interp)
    return _ref.beam_search_ref(vectors, neighbors0, q, ep, ep_dist,
                                ef=ef, metric=metric, scales=scales,
                                expand_t=expand_t, max_iters=max_iters)


@functools.partial(jax.jit, static_argnames=("m", "metric"))
def _select_neighbors_jit(vectors, q, cand_ids, *, m, metric, scales):
    return _ref.select_neighbors_ref(vectors, q, cand_ids, m=m,
                                     metric=metric, scales=scales)


def select_neighbors(vectors: jax.Array, q: jax.Array, cand_ids: jax.Array,
                     *, m: int, metric: str = "cosine",
                     scales: jax.Array | None = None
                     ) -> tuple[jax.Array, jax.Array]:
    """Batched HNSW neighbor-selection heuristic (Malkov Alg. 4 with
    pruned-candidate backfill, DESIGN.md §13): vectors [N,D] (any codec
    dtype, ``scales`` [N] decodes), q [B,D], cand_ids [B,C] i32 -1-pad
    -> (ids [B,m] i32 -1-pad, dists [B,m] f32 INF-pad), per row
    output-identical to the host ``select_heuristic_host`` oracle.

    jnp-only: the op is one [B,C,C] einsum + a C-step masked keep-scan,
    which XLA already fuses well at construction's C = efConstruction
    sizes — a hand-written Pallas lowering has nothing left to fuse, so
    every backend runs the reference (unlike the query-path ops above,
    where the win is cross-hop fusion)."""
    return _select_neighbors_jit(vectors, q, cand_ids, m=m, metric=metric,
                                 scales=scales)


def flat_topk(db: jax.Array, q: jax.Array, k: int,
              *, metric: str = "cosine",
              scales: jax.Array | None = None
              ) -> tuple[jax.Array, jax.Array]:
    """Exact k-NN: db [N,D], q [B,D] -> (dists [B,k], ids [B,k]).

    ``db`` may be codec-encoded (f32 / bf16 / int8, DESIGN.md §9);
    ``scales`` [N] fuses the per-row decode into the distance."""
    use, interp = _use_pallas()
    if use:
        from repro.kernels.distance_topk import distance_topk_pallas
        pd, pi = distance_topk_pallas(db, q, k, metric=metric,
                                      scales=scales, interpret=interp)
        neg, j = jax.lax.top_k(-pd, k)                 # tiny [B, T*k] merge
        return -neg, jnp.take_along_axis(pi, j, axis=1)
    return _ref.distance_topk_ref(db, q, k, metric=metric, scales=scales)


def embedding_bag(table: jax.Array, ids: jax.Array,
                  weights: jax.Array | None = None,
                  *, combine: str = "sum") -> jax.Array:
    """EmbeddingBag: table [R,E], ids [B,L] -> [B,E]."""
    use, interp = _use_pallas()
    if use:
        from repro.kernels.embedding_bag import embedding_bag_pallas
        return embedding_bag_pallas(table, ids, weights, combine=combine,
                                    interpret=interp)
    return _ref.embedding_bag_ref(table, ids, weights, combine=combine)


def flash_decode(q: jax.Array, k: jax.Array, v: jax.Array,
                 cur_len) -> jax.Array:
    """Decode attention: q [B,H,Dh], k/v [B,S,KVH,Dh] -> [B,H,Dh] f32.

    ``cur_len`` is a scalar or a per-sequence [B] vector of live prefix
    lengths — the serving hot loop (``models/transformer.decode_step``)
    passes [B] so one dispatch decodes continuous-batching slots at
    different depths (DESIGN.md §11)."""
    use, interp = _use_pallas()
    if use:
        from repro.kernels.flash_decode import flash_decode_pallas
        return flash_decode_pallas(q, k, v, cur_len, interpret=interp)
    return _ref.flash_decode_ref(q, k, v, jnp.asarray(cur_len, jnp.int32))
