"""Analytic per-device TPU-target cost model (the §Roofline memory term).

Why analytic: the dry run compiles for the *CPU* backend, whose HLO keeps
bf16<->f32 convert chains and fuses far less aggressively than Mosaic/XLA:TPU
— parsing its buffer traffic overstates TPU HBM bytes 10-50x (measured; see
EXPERIMENTS.md §Methodology). FLOPs parse exactly (dot shapes are identical
on both backends) and collectives parse exactly (SPMD inserts the same ops),
so those two terms stay HLO-derived; only the memory term uses this model.
Every formula below is the sum of actual tensor passes our implementation
makes — weights streamed per layer, flash-attention KV re-reads, activation
round trips, optimizer state traffic — all per device, per step.
"""
from __future__ import annotations

from repro.configs import get_config

BF16 = 2
F32 = 4


def _lm_bytes(arch, shape, chips: int, tp: int, tuning=None) -> float:
    m = arch.model
    dp = max(chips // tp, 1)
    N = m.param_count
    Na = m.active_param_count
    L, d, V = m.n_layers, m.d_model, m.vocab
    kvh, dh = m.n_kv_heads, m.dh
    t = tuning or {}

    if shape.kind == "train":
        B, S = shape["global_batch"], shape["seq_len"]
        tok_d = B * S / dp
        w_shard = N / tp
        # fwd + backward-dgrad + backward-wgrad weight passes (bf16 compute)
        weights = 3 * w_shard * BF16
        # remat: one extra forward's weight reads
        if m.remat:
            weights += w_shard * BF16
        grads = w_shard * F32 * 2                       # write + opt read
        opt = 6 * (N / (tp * dp)) * F32                 # ZeRO-1 m,v,p r/w
        # activations: ~14 d-wide tensor passes / layer / token (fwd+bwd)
        acts = L * tok_d * d * 14 * BF16 * (2 if m.remat else 1)
        # flash attention: kv re-read nq times per layer (fwd + bwd 2x)
        s_eff = min(S, m.sliding_window or S)
        nq = max(S // max(m.attn_block_q, 1), 1)
        kv_pass = (B / dp) * s_eff * kvh * dh * 2 * BF16
        attn = L * kv_pass * nq * 3
        # vocab head: logits write+read fwd, recompute in bwd
        chunk = t.get("chunked_loss", m.chunked_loss)
        logits = tok_d * (V / tp) * F32 * (2 if chunk else 4)
        return weights + grads + opt + acts + attn + logits

    if shape.kind == "prefill":
        B, S = shape["global_batch"], shape["seq_len"]
        tok_d = B * S / dp
        weights = (Na / tp) * BF16
        acts = L * tok_d * d * 10 * BF16
        s_eff = min(S, m.sliding_window or S)
        nq = max(S // max(m.attn_block_q, 1), 1)
        kv_pass = (B / dp) * s_eff * kvh * dh * 2 * BF16
        attn = L * kv_pass * nq
        cache_write = L * (B / dp) * (min(S, m.sliding_window or S) / 1) \
            * kvh * dh * 2 * BF16 / tp
        logits = (B / dp) * (V / tp) * F32
        return weights + acts + attn + cache_write + logits

    # decode: weights once + full cache read + tiny activations
    B, S = shape["global_batch"], shape["seq_len"]
    s_c = min(S, m.sliding_window or S)
    weights = (Na / tp) * BF16
    kv_item = 1 + 4.0 / dh if t.get("kv_quant") else BF16   # int8 + scales
    cache = L * (B / dp) * (s_c / tp) * kvh * dh * 2 * kv_item
    acts = L * (B / dp) * d * 14 * BF16
    logits = (B / dp) * (V / tp) * F32
    return weights + cache + acts + logits


def _gnn_bytes(arch, shape, chips: int) -> float:
    m = arch.model
    h = m.d_hidden
    d = shape["d_feat"]
    if shape.name == "molecule":
        g, n = shape["batch"], shape["n_nodes"]
        per = g * (n * n * F32 + n * (d + 2 * h) * F32 * 3)
        return per / chips * 3
    if shape.kind == "sampled_train":
        b = shape["batch_nodes"]
        f1, f2 = shape["fanout1"], shape["fanout2"]
        n_eff = b * (1 + f1 + f1 * f2)
        gather = n_eff * d * F32
        acts = b * (f1 + 1) * (d + h) * F32 * 4
        return (gather + acts) / chips * 3
    n, e = shape["n_nodes"], shape["n_edges"]
    msgs = e * (d + h) * F32          # layer-1 + layer-2 message passes
    nodes = n * (d + 4 * h) * F32
    return (msgs + nodes) / chips * 3


def _db_itemsize(tuning) -> int:
    return 2 if (tuning or {}).get("db_dtype", "float32") == "bfloat16" else 4


def _recsys_bytes(arch, shape, chips: int, tp: int, tuning=None) -> float:
    m = arch.model
    if shape.kind == "retrieval":
        n = shape["n_candidates"]
        return (n / chips) * m.embed_dim * _db_itemsize(tuning)
    B = shape["batch"]
    b_d = B / chips
    mult = 3 if shape.kind == "train" else 1
    if m.kind in ("fm", "wide_deep"):
        rows = b_d * m.n_sparse * m.embed_dim * F32
        mlp = 0.0
        dims = (m.n_sparse * m.embed_dim + m.n_dense,) + tuple(m.mlp_dims) + (1,)
        for a, b in zip(dims[:-1], dims[1:]):
            mlp += (a * b / tp) * F32 + b_d * b * F32
        if shape.kind == "train":                    # dense table-grad pass
            rows += (m.n_sparse * m.rows_per_field * m.embed_dim / chips) \
                * F32 * 2
        return (rows + mlp) * mult
    d, s = m.embed_dim, m.seq_len
    if m.kind == "bert4rec":
        acts = b_d * s * d * 14 * F32 * m.n_blocks
        logits = b_d * s * (m.n_items / tp) * F32
        emb = (m.n_items * d / tp) * F32
        return (acts + logits + emb) * mult
    acts = b_d * s * d * (6 + 2 * m.capsule_iters) * F32
    emb = b_d * s * d * F32
    return (acts + emb) * mult


def model_bytes(arch_id: str, shape_name: str, chips: int, tp: int = 16,
                tuning: dict | None = None) -> float:
    """Per-device HBM bytes per step on the TPU target."""
    arch = get_config(arch_id)
    shape = arch.shape(shape_name)
    if arch.family == "lm":
        return _lm_bytes(arch, shape, chips, tp, tuning)
    if arch.family == "gnn":
        return _gnn_bytes(arch, shape, chips)
    if arch.family == "recsys":
        return _recsys_bytes(arch, shape, chips, tp, tuning)
    # mememo retrieval
    return (shape["n_candidates"] / chips) * shape["dim"] * _db_itemsize(tuning)
