"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch llama3-8b --preset small \
        --steps 300 --batch 8 --seq 128 --ckpt-dir /tmp/run1

Presets: ``smoke`` (CPU seconds), ``small`` (~15M params, the "train a small
model for a few hundred steps" deliverable), ``full`` (the exact published
config — pod-scale; on CPU use only with --dry-run via launch/dryrun.py).
Any run is resumable: rerun the same command and it restores the newest
checkpoint (fault-tolerance path, see train/fault_tolerance.py).
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.data import synthetic
from repro.models import gnn as gnn_lib
from repro.models import recsys as rs
from repro.models import transformer as tf
from repro.train.checkpoint import CheckpointManager
from repro.train.optimizer import AdamWConfig, warmup_cosine
from repro.train.train_loop import make_train_step, fit
from repro.utils import logger, human_count
from repro.models.common import count_params


def small_lm(cfg):
    return dataclasses.replace(
        cfg, n_layers=4, d_model=256, n_heads=8,
        n_kv_heads=max(2, cfg.n_kv_heads // 4), d_ff=1024,
        vocab=min(cfg.vocab, 8192),
        moe=dataclasses.replace(cfg.moe, n_experts=8, top_k=2, d_ff=256)
        if cfg.moe else None,
        attn_block_q=64, attn_block_k=64)


def build(arch: str, preset: str, args):
    full = get_config(arch)
    if preset == "smoke":
        mcfg = get_smoke_config(arch)
    elif preset == "small" and full.family == "lm":
        mcfg = small_lm(full.model)
    else:
        mcfg = full.model
    key = jax.random.PRNGKey(args.seed)

    if full.family == "lm":
        params = tf.init_lm(key, mcfg)
        loss = lambda p, tokens, labels: tf.lm_loss(p, mcfg, tokens, labels,
                                                    dtype=jnp.float32)
        data = synthetic.lm_batches(mcfg.vocab, args.batch, args.seq + 1,
                                    seed=args.seed)
    elif full.family == "gnn":
        graph = synthetic.make_graph(2000, 8, 32, 7, seed=args.seed)
        params = gnn_lib.init_sage(key, mcfg, 32, 7)
        feats = jnp.asarray(graph.feats)
        src, dst = jnp.asarray(graph.edge_src), jnp.asarray(graph.edge_dst)
        labels = jnp.asarray(graph.labels)
        loss = lambda p, **_: gnn_lib.sage_full_loss(
            p, mcfg, feats, src, dst, labels, jnp.ones_like(labels, jnp.float32))
        data = iter(lambda: {"_": np.zeros(1)}, None)  # full-batch: no stream

        def gen():
            while True:
                yield {}
        data = gen()
    else:  # recsys
        params = rs.INIT[mcfg.kind](key, mcfg)
        if mcfg.kind in ("fm", "wide_deep"):
            fn = rs.fm_loss if mcfg.kind == "fm" else rs.wide_deep_loss
            loss = lambda p, sparse_ids, dense, labels: fn(
                p, mcfg, sparse_ids, dense, labels)
            data = synthetic.ctr_batches(mcfg.n_sparse, mcfg.rows_per_field,
                                         mcfg.n_dense, args.batch, seed=args.seed)
        elif mcfg.kind == "bert4rec":
            loss = lambda p, item_seq, labels, label_mask: rs.bert4rec_loss(
                p, mcfg, item_seq, labels, label_mask)
            data = synthetic.masked_item_batches(mcfg.n_items, mcfg.seq_len,
                                                 args.batch, seed=args.seed)
        else:
            loss = lambda p, behavior, behavior_mask, target, neg: rs.mind_loss(
                p, mcfg, behavior, behavior_mask, target, neg)
            data = synthetic.seq_rec_batches(mcfg.n_items, mcfg.seq_len,
                                             args.batch, seed=args.seed)
    return mcfg, params, loss, data


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--preset", default="smoke",
                    choices=["smoke", "small", "full"])
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    mcfg, params, loss_fn, data = build(args.arch, args.preset, args)
    n_params = count_params(params)
    logger.info(f"arch={args.arch} preset={args.preset} "
                f"params={human_count(n_params)}")

    opt_cfg = AdamWConfig(
        lr=warmup_cosine(args.lr, max(args.steps // 20, 5), args.steps))
    step_fn = make_train_step(loss_fn, opt_cfg, microbatches=args.microbatches)

    ckpt = None
    start, opt_state = 0, None
    if args.ckpt_dir:
        ckpt = CheckpointManager(args.ckpt_dir, keep=3, async_save=True)
        latest = ckpt.latest_step()
        if latest:
            from repro.train.optimizer import adamw_init
            template = {"params": params, "opt": adamw_init(params)}
            state, _ = ckpt.restore(template)
            params = jax.tree.map(jnp.asarray, state["params"])
            opt_state = jax.tree.map(jnp.asarray, state["opt"])
            start = latest
            logger.info(f"resumed from step {latest}")

    t0 = time.time()
    params, opt_state, hist = fit(
        params, step_fn, data, steps=args.steps, ckpt=ckpt,
        ckpt_every=args.ckpt_every, opt_state=opt_state, start_step=start)
    if hist:
        dt = time.time() - t0
        logger.info(f"done: loss {hist[0]['loss']:.4f} -> {hist[-1]['loss']:.4f} "
                    f"({len(hist)} steps, {dt:.0f}s, "
                    f"{len(hist)/dt:.2f} steps/s)")
    if ckpt:
        ckpt.wait()


if __name__ == "__main__":
    main()
