"""Production mesh construction.

The production target is a TPU v5e pod slice: 16x16 = 256 chips per pod,
2 pods = 512 chips for the multi-pod configuration.  Axis semantics:

  pod    -- crosses the data-center interconnect (DCI); only gradient
            all-reduces (data parallelism) travel this axis.
  data   -- intra-pod data parallelism (batch sharding, ZeRO-1 state shards,
            GNN edge parallelism, MoE token sharding).
  model  -- tensor/expert/table parallelism (Megatron TP, MoE EP, recsys
            embedding-row sharding, retrieval DB sharding, decode KV
            sequence splits).

NOTE: constructed via functions, never at import time, so importing this
module never touches jax device state (smoke tests must keep seeing the
single real CPU device).
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """The graded production mesh: (16,16) single pod / (2,16,16) multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devs = jax.devices()
    if len(devs) < n:
        raise RuntimeError(
            f"need {n} devices for the production mesh, found {len(devs)}; "
            "the dry run must set XLA_FLAGS=--xla_force_host_platform_"
            "device_count=512 before importing jax (see launch/dryrun.py)")
    return jax.make_mesh(shape, axes, devices=devs[:n])


def make_host_mesh(data: int = 1, model: int = 1) -> Mesh:
    """Small mesh over whatever devices actually exist (CPU tests)."""
    n = len(jax.devices())
    data = min(data, n)
    model = max(1, min(model, n // data))
    return jax.make_mesh((data, model), ("data", "model"))


def batch_axes(mesh: Mesh) -> tuple[str, ...]:
    """Mesh axes over which the global batch is sharded (DP axes)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def model_axis(mesh: Mesh) -> str | None:
    return "model" if "model" in mesh.axis_names else None


def dp_size(mesh: Mesh) -> int:
    s = 1
    for a in batch_axes(mesh):
        s *= mesh.shape[a]
    return s


def tp_size(mesh: Mesh) -> int:
    return mesh.shape.get("model", 1)
