"""Scan-aware cost analysis of optimized HLO text.

``compiled.cost_analysis()`` visits every while-loop body exactly ONCE, so
any scan-over-layers / blocked-attention program under-reports FLOPs, bytes
and collective traffic by the trip counts (validated empirically — see
EXPERIMENTS.md §Methodology). This module parses the optimized HLO into its
computation call graph and rolls costs up properly:

  * while: body x trip_count (trip = the integer constant in the loop's
    condition computation — exact for lax.scan/fori; data-dependent
    while_loops fall back to 1 and are flagged),
  * fusion/call: callee FLOPs roll up; callee *bytes* don't (fusion
    internals live in registers — only the fusion boundary touches memory),
  * conditional: max over branches,
  * collectives: wire bytes by op kind (all-reduce 2x ring, reduce-scatter
    counts its operand, gather/permute/all-to-all their result).

Outputs: flops, bytes accessed, collective bytes, per-kind collective
breakdown — the §Roofline inputs.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_ELEMWISE = {
    "add", "subtract", "multiply", "divide", "power", "maximum", "minimum",
    "tanh", "exponential", "log", "negate", "abs", "rsqrt", "sqrt", "sign",
    "floor", "ceil", "round-nearest-afz", "select", "compare", "and", "or",
    "xor", "not", "clamp", "atan2", "expm1", "log1p", "cosine", "sine",
    "logistic", "remainder", "shift-left", "shift-right-logical",
    "shift-right-arithmetic", "erf", "cbrt",
}

_COLLECTIVES = {"all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute"}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_DEF_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(\([^()]*\)|[a-z0-9]+\[[\d,]*\](?:\{[^}]*\})?)\s*"
    r"([a-z0-9\-]+)\((.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s+\((.*?)\)\s*->")
_PARAM_RE = re.compile(r"([\w\.\-]+):\s*((?:\([^)]*\))|[a-z0-9]+\[[\d,]*\])")


def _shape_bytes(type_str: str) -> int:
    """Total bytes of a (possibly tuple) HLO type string."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_elems(type_str: str) -> int:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return 0
    dims = m.group(2)
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n


def _shape_dims(type_str: str) -> list[int]:
    m = _SHAPE_RE.search(type_str)
    if not m or not m.group(2):
        return []
    return [int(d) for d in m.group(2).split(",")]


@dataclasses.dataclass
class Op:
    name: str
    kind: str
    result_type: str
    args: str          # raw remainder of the line (operands + attrs)


@dataclasses.dataclass
class Computation:
    name: str
    ops: list[Op]
    syms: dict[str, str]          # %name -> type string (params + defs)
    max_const: int = 0            # largest s32 constant (trip-count heuristic)
    param_order: list[str] = dataclasses.field(default_factory=list)
    defs: dict[str, "Op"] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_by_kind: dict = dataclasses.field(
        default_factory=lambda: defaultdict(float))
    dynamic_whiles: int = 0

    def scaled(self, k: float) -> "Cost":
        out = Cost(self.flops * k, self.bytes * k, self.coll_bytes * k,
                   defaultdict(float), self.dynamic_whiles)
        for kk, v in self.coll_by_kind.items():
            out.coll_by_kind[kk] = v * k
        return out

    def add(self, o: "Cost"):
        self.flops += o.flops
        self.bytes += o.bytes
        self.coll_bytes += o.coll_bytes
        self.dynamic_whiles += o.dynamic_whiles
        for kk, v in o.coll_by_kind.items():
            self.coll_by_kind[kk] += v


def parse_hlo(txt: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for raw in txt.splitlines():
        line = raw.rstrip()
        stripped = line.strip()
        if not stripped:
            continue
        header = _COMP_RE.match(line) if line and not line.startswith(" ") else None
        if header and stripped.endswith("{"):
            cur = Computation(header.group(1), [], {})
            comps[cur.name] = cur
            for pm in _PARAM_RE.finditer(header.group(2)):
                cur.syms["%" + pm.group(1)] = pm.group(2)
                cur.param_order.append("%" + pm.group(1))
            continue
        if stripped == "}":
            cur = None
            continue
        if cur is None:
            continue
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, rtype, kind, rest = m.groups()
        cur.syms["%" + name] = rtype
        op = Op(name, kind, rtype, rest)
        cur.ops.append(op)
        cur.defs["%" + name] = op
        if kind == "constant" and rtype.startswith("s32[]"):
            cm = re.match(r"(\d+)\)", rest)
            if cm:
                cur.max_const = max(cur.max_const, int(cm.group(1)))
    return comps


_CALL_RE = re.compile(r"calls=%?([\w\.\-]+)")
_TO_APPLY_RE = re.compile(r"to_apply=%?([\w\.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w\.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w\.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")


def _operand_types(op: Op, comp: Computation) -> list[str]:
    # operands appear before the first "), " attr boundary; just resolve all
    # %refs on the line that are known symbols (attrs reference computations,
    # which are not in syms)
    out = []
    args = op.args.split("),")[0]
    for m in _OPERAND_RE.finditer(args):
        ref = "%" + m.group(1)
        if ref in comp.syms:
            out.append(comp.syms[ref])
    return out


class HLOAnalyzer:
    def __init__(self, txt: str):
        self.comps = parse_hlo(txt)
        self._memo: dict[str, Cost] = {}
        entry = None
        for name in self.comps:
            pass
        # ENTRY computation: the one named main.* if present, else last
        mains = [n for n in self.comps if n.startswith("main")]
        self.entry = mains[0] if mains else list(self.comps)[-1]

    # ------------------------------------------------------------------
    def cost(self, comp_name: str | None = None) -> Cost:
        name = comp_name or self.entry
        if name in self._memo:
            return self._memo[name]
        comp = self.comps.get(name)
        total = Cost()
        if comp is None:
            return total
        self._memo[name] = total          # guards recursion
        for op in comp.ops:
            total.add(self._op_cost(op, comp))
        return total

    # ------------------------------------------------------------------
    def _op_cost(self, op: Op, comp: Computation) -> Cost:
        c = Cost()
        kind = op.kind
        if kind == "dot":
            operands = _operand_types(op, comp)
            k = 1
            cm = _CONTRACT_RE.search(op.args)
            if cm and operands:
                lhs_dims = _shape_dims(operands[0])
                for d in cm.group(1).split(","):
                    if d != "" and int(d) < len(lhs_dims):
                        k *= lhs_dims[int(d)]
            c.flops += 2.0 * _shape_elems(op.result_type) * k
            c.bytes += _shape_bytes(op.result_type) + sum(
                _shape_bytes(t) for t in operands)
        elif kind in ("fusion", "call", "custom-call", "map"):
            callee = _CALL_RE.search(op.args) or _TO_APPLY_RE.search(op.args)
            callee_comp = None
            if callee:
                callee_comp = self.comps.get(callee.group(1))
                sub = self.cost(callee.group(1))
                c.flops += sub.flops                # internals: flops only
                c.coll_bytes += sub.coll_bytes
                for kk, v in sub.coll_by_kind.items():
                    c.coll_by_kind[kk] += v
                c.dynamic_whiles += sub.dynamic_whiles
            c.bytes += self._fusion_bytes(op, comp, callee_comp)
        elif kind == "while":
            cond = _COND_RE.search(op.args)
            body = _BODY_RE.search(op.args)
            trip = 1
            dynamic = 0
            if cond and cond.group(1) in self.comps:
                tc = self.comps[cond.group(1)].max_const
                if tc > 0:
                    trip = tc
                else:
                    dynamic = 1
            if body:
                c.add(self.cost(body.group(1)).scaled(trip))
            if cond:
                cnd = self.cost(cond.group(1)).scaled(trip + 1)
                c.add(cnd)
            c.dynamic_whiles += dynamic
        elif kind == "conditional":
            br = _BRANCH_RE.search(op.args)
            if br:
                subs = [self.cost(b.strip().lstrip("%"))
                        for b in br.group(1).split(",")]
                if subs:
                    best = max(subs, key=lambda s: s.flops)
                    c.add(best)
        elif kind in _COLLECTIVES:
            operands = _operand_types(op, comp)
            out_b = _shape_bytes(op.result_type)
            in_b = sum(_shape_bytes(t) for t in operands)
            # TPU-equivalent wire dtype: the CPU backend upcasts bf16 dot
            # operands to f32 before partitioning, so collectives here often
            # move f32 where the TPU target would move bf16. Walk the
            # convert chain back to the source dtype and scale.
            scale = self._wire_scale(op, comp)
            wire = {"all-reduce": 2 * out_b, "all-gather": out_b,
                    "reduce-scatter": in_b, "all-to-all": out_b,
                    "collective-permute": out_b}[kind] * scale
            c.coll_bytes += wire
            c.coll_by_kind[kind] += wire
            c.bytes += (out_b + in_b) * scale
        elif kind in ("dynamic-update-slice",):
            operands = _operand_types(op, comp)
            upd = _shape_bytes(operands[1]) if len(operands) > 1 else 0
            c.bytes += 2 * upd                      # in-place on real HW
        elif kind in ("dynamic-slice", "slice", "gather"):
            # touches only the sliced/gathered rows, not the whole operand
            c.bytes += 2 * _shape_bytes(op.result_type)
        elif kind == "scatter":
            operands = _operand_types(op, comp)
            upd = _shape_bytes(operands[2]) if len(operands) > 2 else \
                _shape_bytes(op.result_type)
            c.bytes += 2 * upd                      # in-place accumulate
        elif kind in ("reduce", "reduce-window", "sort", "copy", "transpose",
                      "reshape", "broadcast", "concatenate", "pad", "convert",
                      "iota", "rng-bit-generator", "select-and-scatter"):
            operands = _operand_types(op, comp)
            if kind in ("reduce", "reduce-window", "sort"):
                c.flops += sum(_shape_elems(t) for t in operands)
            c.bytes += _shape_bytes(op.result_type) + sum(
                _shape_bytes(t) for t in operands)
        elif kind in _ELEMWISE:
            c.flops += _shape_elems(op.result_type)
            c.bytes += _shape_bytes(op.result_type) + sum(
                _shape_bytes(t) for t in _operand_types(op, comp))
        # parameters/constants/gte/tuple: free
        return c

    _CHAIN = ("convert", "copy", "bitcast", "reshape", "transpose",
              "get-tuple-element")

    def _wire_scale(self, op: Op, comp: Computation) -> float:
        """min(source_itemsize, current_itemsize) / current_itemsize over the
        collective's operands, walking back through dtype-conversion chains
        (and through pure-convert fusions)."""
        args = op.args.split("),")[0]
        refs = ["%" + m.group(1) for m in _OPERAND_RE.finditer(args)]
        cur_m = _SHAPE_RE.search(op.result_type)
        if not cur_m or cur_m.group(1) not in _DTYPE_BYTES:
            return 1.0
        cur_sz = _DTYPE_BYTES[cur_m.group(1)]
        best = cur_sz
        for ref in refs[:1]:          # first operand carries the payload
            src = self._trace_source_dtype(ref, comp, depth=8)
            if src is not None:
                best = min(best, src)
        return best / cur_sz if cur_sz else 1.0

    def _trace_source_dtype(self, ref: str, comp: Computation,
                            depth: int) -> int | None:
        if depth <= 0 or ref not in comp.defs:
            t = comp.syms.get(ref)
            if t:
                m = _SHAPE_RE.search(t)
                if m and m.group(1) in _DTYPE_BYTES:
                    return _DTYPE_BYTES[m.group(1)]
            return None
        op = comp.defs[ref]
        if op.kind in self._CHAIN:
            args = op.args.split("),")[0]
            rs = ["%" + m.group(1) for m in _OPERAND_RE.finditer(args)]
            if rs:
                return self._trace_source_dtype(rs[0], comp, depth - 1)
        if op.kind == "fusion":
            callee_m = _CALL_RE.search(op.args)
            callee = self.comps.get(callee_m.group(1)) if callee_m else None
            if callee is not None:
                kinds = {c.kind for c in callee.ops
                         if c.kind not in ("parameter", "constant")}
                if kinds <= set(self._CHAIN):      # pure convert fusion
                    args = op.args.split("),")[0]
                    rs = ["%" + m.group(1) for m in _OPERAND_RE.finditer(args)]
                    if rs:
                        return self._trace_source_dtype(rs[0], comp, depth - 1)
        m = _SHAPE_RE.search(op.result_type)
        if m and m.group(1) in _DTYPE_BYTES:
            return _DTYPE_BYTES[m.group(1)]
        return None

    def _fusion_bytes(self, op: Op, comp: Computation,
                      callee: Computation | None) -> float:
        """Fusion boundary traffic, per-parameter.

        A fused dynamic-slice of a parameter touches only the slice; a fused
        dynamic-update-slice writes only the update (XLA aliases the buffer
        in place); anything else reads its parameter wholesale. This mirrors
        the traffic real fusions generate — counting whole operands at the
        boundary overstated the decode step ~100x (stacked-layer weight /
        KV-cache slicing inside scan bodies).
        """
        result_b = _shape_bytes(op.result_type)
        operand_ts = _operand_types(op, comp)
        if callee is None or len(callee.param_order) != len(operand_ts):
            return result_b + sum(_shape_bytes(t) for t in operand_ts)

        # dtype-conversion chains are free on the TPU target (MXU consumes
        # bf16 and accumulates f32 natively); treat convert/bitcast/copy as
        # aliases of their source when attributing parameter usage.
        _ALIAS = ("convert", "bitcast", "copy", "reshape")
        alias: dict[str, str] = {}

        def resolve(r: str) -> str:
            seen = set()
            while r in alias and r not in seen:
                seen.add(r)
                r = alias[r]
            return r

        sliced_bytes = {p: 0.0 for p in callee.param_order}
        wholesale = {p: False for p in callee.param_order}
        dus_results: set[str] = set()
        pure_compute = 0        # ops that do real arithmetic
        last_op = None
        for cop in callee.ops:
            refs = ["%" + m.group(1)
                    for m in _OPERAND_RE.finditer(cop.args.split("),")[0])]
            if cop.kind in ("parameter", "constant"):
                continue
            last_op = cop
            if cop.kind in _ALIAS and refs:
                alias["%" + cop.name] = refs[0]
                continue
            rr = [resolve(r) for r in refs]
            if cop.kind in ("dynamic-slice", "slice", "gather"):
                rb = _shape_bytes(cop.result_type)
                alias["%" + cop.name] = rr[0] if rr else ""
                for r in rr:
                    if r in sliced_bytes:
                        sliced_bytes[r] += rb
                pure_compute += 1
            elif cop.kind in ("dynamic-update-slice", "scatter"):
                idx = 1 if cop.kind == "dynamic-update-slice" else 2
                dus_results.add("%" + cop.name)
                ops_in = _operand_types(cop, callee)
                upd = _shape_bytes(ops_in[idx]) if len(ops_in) > idx else 0
                for pos, r in enumerate(rr):
                    if r not in sliced_bytes:
                        continue
                    if pos == 0:
                        sliced_bytes[r] += upd      # in-place write
                    elif pos == idx:
                        sliced_bytes[r] += upd      # the update itself
                    else:
                        pass                        # indices: negligible
                pure_compute += 1
            else:
                for r in rr:
                    if r in sliced_bytes:
                        wholesale[r] = True
                pure_compute += 1

        if pure_compute == 0:      # pure convert/bitcast chain: free on TPU
            return 0.0

        total = 0.0
        for p, t in zip(callee.param_order, operand_ts):
            total += _shape_bytes(t) if wholesale[p] else sliced_bytes[p]
        root_src = resolve("%" + last_op.name) if last_op is not None else ""
        inplace_root = ("%" + (last_op.name if last_op else "")) in dus_results \
            or root_src in dus_results
        total += 0.0 if inplace_root else result_b
        return total


def analyze(txt: str) -> dict:
    a = HLOAnalyzer(txt)
    c = a.cost()
    return {
        "flops": c.flops,
        "bytes": c.bytes,
        "collective_bytes": c.coll_bytes,
        "collectives": dict(c.coll_by_kind),
        "dynamic_whiles": c.dynamic_whiles,
    }
