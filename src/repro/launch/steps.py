"""Dry-run cell builders: one lowered program per (arch x shape x mesh).

Every builder returns ``(fn, arg_specs, in_shardings)`` ready for
``jax.jit(fn, in_shardings=...).lower(*arg_specs)``. Inputs are
ShapeDtypeStructs — weak-type-correct, shardable, zero allocation.

Shape kinds -> lowered program (DESIGN.md §6):
  train / sampled_train  -> loss + grad + AdamW update (full train_step)
  prefill                -> prompt pass building the KV cache
  decode                 -> serve_step: one token against a seq_len cache
  serve                  -> recsys forward
  retrieval              -> sharded flat top-k (the paper's own workload)
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import ArchConfig, get_config
from repro.configs.base import ShapeSpec
from repro.distributed.sharding import axis_rules, named_sharding, shard
from repro.models import gnn as gnn_lib
from repro.models import recsys as rs
from repro.models import transformer as tf
from repro.models.transformer import lm_param_axes
from repro.train.optimizer import AdamWConfig, OptState, adamw_init, adamw_update, opt_state_axes

SDS = jax.ShapeDtypeStruct

OPT_CFG = AdamWConfig(lr=3e-4)


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------
def _shardings_for(tree_shapes: Any, tree_axes: Any, mesh: Mesh,
                   rules=None) -> Any:
    """ShapeDtypeStruct tree + logical axes tree -> NamedSharding tree."""
    with axis_rules(mesh, rules):
        return jax.tree.map(
            lambda s, a: named_sharding(s.shape, *a),
            tree_shapes, tree_axes,
            is_leaf=lambda x: isinstance(x, SDS))


def _replicated(mesh: Mesh):
    return NamedSharding(mesh, P())


def _is_axes(x):
    return isinstance(x, tuple) and all(isinstance(a, (str, type(None)))
                                        for a in x)


def _train_fn(loss_fn, grad_axes=None):
    """loss_fn(params, *batch) -> full train step (grad + AdamW).

    ``grad_axes``: logical axes tree for the grads (same as params). The
    constraint right after autodiff makes the partitioner emit a
    reduce-scatter instead of all-reduce + slice, so replicated full-size
    grad buffers never materialise (ZeRO-2-style grad sharding)."""
    def step(params, opt_state, *batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, *batch)
        if grad_axes is not None:
            grads = jax.tree.map(lambda g, a: shard(g, *a), grads, grad_axes,
                                 is_leaf=lambda x: _is_axes(x))
        params, opt_state, om = adamw_update(OPT_CFG, params, grads, opt_state)
        return params, opt_state, {"loss": loss, **om}
    return step


def _opt_shardings(param_shapes, param_axes, mesh, rules=None,
                   like_params: bool = False):
    """``like_params=True`` (FSDP): m/v mirror the param sharding — params
    are already fully sharded, and a different opt layout would force the
    partitioner to rematerialise full tensors in the update (measured:
    +25 GiB/dev). Default (TP): ZeRO-1 layers->data remap."""
    opt_shapes = jax.eval_shape(adamw_init, param_shapes)
    if like_params:
        mv_axes = {"m": param_axes, "v": param_axes}
    else:
        axes = opt_state_axes(param_axes)
        mv_axes = {"m": axes.m, "v": axes.v}
    sh = _shardings_for({"m": opt_shapes.m, "v": opt_shapes.v}, mv_axes, mesh,
                        rules)
    return opt_shapes, OptState(m=sh["m"], v=sh["v"], step=_replicated(mesh))


# ---------------------------------------------------------------------------
# LM cells
# ---------------------------------------------------------------------------
def _lm_overrides(mcfg, shape_kind: str, tuning: dict | None):
    """Per-cell implementation knobs (baseline unless tuning overrides)."""
    t = dict(tuning or {})
    if "moe_pad_experts" in t and mcfg.moe is not None:
        mcfg = dataclasses.replace(
            mcfg, moe=dataclasses.replace(
                mcfg.moe, pad_experts_to=int(t["moe_pad_experts"])))
    fields = {f.name for f in dataclasses.fields(mcfg)}
    upd = {k: v for k, v in t.items() if k in fields}
    return dataclasses.replace(mcfg, **upd) if upd else mcfg


def _rules(tuning: dict | None):
    """Logical->mesh rule overrides, e.g. FSDP: {"heads": ["data","model"]}."""
    r = (tuning or {}).get("rules")
    if not r:
        return None
    return {k: (tuple(v) if isinstance(v, list) else v) for k, v in r.items()}


def lm_cell(arch: ArchConfig, shape: ShapeSpec, mesh: Mesh,
            tuning: dict | None = None):
    mcfg = _lm_overrides(arch.model, shape.kind, tuning)
    B, S = shape["global_batch"], shape["seq_len"]
    impl = (tuning or {}).get("attn_impl", "masked")
    rules = _rules(tuning)
    # param storage dtype: f32 master (default) or bf16 + f32 opt state
    # (MaxText-style: makes every FSDP gather and grad all-reduce bf16)
    p_dtype = jnp.dtype((tuning or {}).get("param_dtype", "float32"))

    with axis_rules(mesh, rules):
        p_shapes = jax.eval_shape(
            functools.partial(tf.init_lm, cfg=mcfg, dtype=p_dtype),
            jax.random.PRNGKey(0))
        p_axes = lm_param_axes(mcfg)
        p_shard = _shardings_for(p_shapes, p_axes, mesh, rules)

        if shape.kind == "train":
            tok = SDS((B, S), jnp.int32)
            tok_sh = named_sharding((B, S), "batch", None)
            o_shapes, o_shard = _opt_shardings(
                p_shapes, p_axes, mesh, rules,
                like_params=bool((tuning or {}).get("opt_like_params")))

            def loss(p, tokens, labels):
                return tf.lm_loss(p, mcfg, tokens, labels, impl=impl)

            fn = _train_fn(loss, p_axes)
            return (fn, (p_shapes, o_shapes, tok, tok),
                    (p_shard, o_shard, tok_sh, tok_sh),
                    (p_shard, o_shard, None))

        if shape.kind == "prefill":
            # serving params in bf16
            pb_shapes = jax.eval_shape(
                functools.partial(tf.init_lm, cfg=mcfg, dtype=jnp.bfloat16),
                jax.random.PRNGKey(0))
            tok = SDS((B, S), jnp.int32)
            tok_sh = named_sharding((B, S), "batch", None)

            def fn(p, tokens):
                return tf.prefill(p, mcfg, tokens)

            cache_out = tf.KVCache(
                k=named_sharding((mcfg.n_layers, B, tf.cache_len(mcfg, S),
                                  mcfg.n_kv_heads, mcfg.dh),
                                 None, "batch", "kv_seq", None, None),
                v=named_sharding((mcfg.n_layers, B, tf.cache_len(mcfg, S),
                                  mcfg.n_kv_heads, mcfg.dh),
                                 None, "batch", "kv_seq", None, None),
                cur_len=_replicated(mesh))
            return (fn, (pb_shapes, tok), (p_shard, tok_sh),
                    (None, cache_out))

        if shape.kind == "decode":
            pb_shapes = jax.eval_shape(
                functools.partial(tf.init_lm, cfg=mcfg, dtype=jnp.bfloat16),
                jax.random.PRNGKey(0))
            Sc = tf.cache_len(mcfg, S)
            L, KVH, Dh = mcfg.n_layers, mcfg.n_kv_heads, mcfg.dh
            cache_shape = (L, B, Sc, KVH, Dh)
            pay = jnp.int8 if mcfg.kv_quant else jnp.bfloat16
            sc = SDS(cache_shape[:-1], jnp.float32) if mcfg.kv_quant else None
            sc_sh = (named_sharding(cache_shape[:-1], None, "batch",
                                    "kv_seq", None)
                     if mcfg.kv_quant else None)
            cache = tf.KVCache(
                k=SDS(cache_shape, pay),
                v=SDS(cache_shape, pay),
                cur_len=SDS((B,), jnp.int32),
                k_scale=sc, v_scale=sc)
            cache_sh = tf.KVCache(
                k=named_sharding(cache_shape, None, "batch", "kv_seq", None, None),
                v=named_sharding(cache_shape, None, "batch", "kv_seq", None, None),
                cur_len=_replicated(mesh),
                k_scale=sc_sh, v_scale=sc_sh)
            tok = SDS((B, 1), jnp.int32)
            tok_sh = named_sharding((B, 1), "batch", None)

            def fn(p, token, cache):
                return tf.decode_step(p, mcfg, token, cache)

            return (fn, (pb_shapes, tok, cache), (p_shard, tok_sh, cache_sh),
                    (None, cache_sh))

    raise ValueError(shape.kind)


# ---------------------------------------------------------------------------
# GNN cells
# ---------------------------------------------------------------------------
def gnn_cell(arch: ArchConfig, shape: ShapeSpec, mesh: Mesh,
             tuning: dict | None = None):
    mcfg = arch.model
    with axis_rules(mesh):
        if shape.name == "molecule":
            d_feat, n_classes = shape["d_feat"], shape["n_classes"]
        else:
            d_feat, n_classes = shape["d_feat"], shape["n_classes"]
        p_shapes = jax.eval_shape(
            functools.partial(gnn_lib.init_sage, cfg=mcfg, d_feat=d_feat,
                              n_classes=n_classes), jax.random.PRNGKey(0))
        p_axes = gnn_lib.sage_param_axes(mcfg)
        p_shard = _shardings_for(p_shapes, p_axes, mesh)
        o_shapes, o_shard = _opt_shardings(p_shapes, p_axes, mesh)

        if shape.kind == "train" and shape.name != "molecule":
            n, e = shape["n_nodes"], shape["n_edges"]
            n += (-n) % 256               # pad nodes: mesh-divisible sharding
            e += (-e) % 256               # pad edges (dummy-node self-loops)
            feats = SDS((n, d_feat), jnp.float32)
            edge = SDS((e,), jnp.int32)
            labels = SDS((n,), jnp.int32)
            mask = SDS((n,), jnp.float32)
            feats_sh = named_sharding((n, d_feat), "nodes", None)
            edge_sh = named_sharding((e,), "edges")
            lab_sh = named_sharding((n,), "nodes")

            def loss(p, feats, src, dst, labels, mask):
                return gnn_lib.sage_full_loss(p, mcfg, feats, src, dst,
                                              labels, mask)

            fn = _train_fn(loss)
            return (fn, (p_shapes, o_shapes, feats, edge, edge, labels, mask),
                    (p_shard, o_shard, feats_sh, edge_sh, edge_sh, lab_sh,
                     lab_sh),
                    (p_shard, o_shard, None))

        if shape.kind == "sampled_train":
            n, e, b = shape["n_nodes"], shape["n_edges"], shape["batch_nodes"]
            n += (-n) % 256               # pad nodes: mesh-divisible sharding
            f1, f2 = shape["fanout1"], shape["fanout2"]
            row_ptr = SDS((n + 1,), jnp.int32)
            col_idx = SDS((e,), jnp.int32)
            feats = SDS((n, d_feat), jnp.float32)
            seeds = SDS((b,), jnp.int32)
            labels = SDS((b,), jnp.int32)
            key = SDS((2,), jnp.uint32)
            feats_sh = named_sharding((n, d_feat), "nodes", None)
            col_sh = named_sharding((e,), "edges")
            b_sh = named_sharding((b,), "batch")

            def loss(p, row_ptr, col_idx, feats, seeds, labels, key):
                return gnn_lib.sampled_train_from_graph(
                    p, mcfg, row_ptr, col_idx, feats, seeds, labels,
                    key, (f1, f2))

            fn = _train_fn(loss)
            return (fn, (p_shapes, o_shapes, row_ptr, col_idx, feats, seeds,
                         labels, key),
                    (p_shard, o_shard, _replicated(mesh), col_sh, feats_sh,
                     b_sh, b_sh, _replicated(mesh)),
                    (p_shard, o_shard, None))

        # molecule: batched small graphs
        g, nn = shape["batch"], shape["n_nodes"]
        feats = SDS((g, nn, d_feat), jnp.float32)
        adj = SDS((g, nn, nn), jnp.float32)
        labels = SDS((g,), jnp.int32)
        f_sh = named_sharding((g, nn, d_feat), "batch", None, None)
        a_sh = named_sharding((g, nn, nn), "batch", None, None)
        l_sh = named_sharding((g,), "batch")

        def loss(p, feats, adj, labels):
            return gnn_lib.sage_molecule_loss(p, mcfg, feats, adj, labels)

        fn = _train_fn(loss)
        return (fn, (p_shapes, o_shapes, feats, adj, labels),
                (p_shard, o_shard, f_sh, a_sh, l_sh),
                (p_shard, o_shard, None))


# ---------------------------------------------------------------------------
# RecSys cells
# ---------------------------------------------------------------------------
def recsys_cell(arch: ArchConfig, shape: ShapeSpec, mesh: Mesh,
                tuning: dict | None = None):
    mcfg = arch.model
    kind = mcfg.kind
    with axis_rules(mesh):
        p_shapes = jax.eval_shape(
            functools.partial(rs.INIT[kind], cfg=mcfg), jax.random.PRNGKey(0))
        p_axes = rs.AXES[kind](mcfg)
        p_shard = _shardings_for(p_shapes, p_axes, mesh)

        if shape.kind == "retrieval":
            t = tuning or {}
            chips = int(mesh.devices.size)
            n_cand = shape["n_candidates"]
            n_cand += (-n_cand) % chips     # pad with sentinel rows
            dim = mcfg.embed_dim
            nq = shape["batch"] * max(mcfg.n_interests, 1)
            db = SDS((n_cand, dim), jnp.dtype(t.get("db_dtype", "float32")))
            q = SDS((nq, dim), jnp.float32)
            db_sh = NamedSharding(mesh, P(tuple(mesh.axis_names), None))
            q_sh = _replicated(mesh)
            from repro.core.distributed import sharded_flat_topk

            def fn(db, q):
                return sharded_flat_topk(mesh, db, q, 100, metric="ip",
                                         wire_bf16=bool(t.get("wire_bf16")))

            return fn, (db, q), (db_sh, q_sh), None

        B = shape["batch"]
        if kind in ("fm", "wide_deep"):
            F = mcfg.n_sparse
            ids = SDS((B, F), jnp.int32)
            dense = SDS((B, mcfg.n_dense), jnp.float32)
            labels = SDS((B,), jnp.int32)
            ids_sh = named_sharding((B, F), "batch", None)
            d_sh = named_sharding((B, mcfg.n_dense), "batch", None)
            l_sh = named_sharding((B,), "batch")
            fwd = rs.fm_forward if kind == "fm" else rs.wide_deep_forward
            lss = rs.fm_loss if kind == "fm" else rs.wide_deep_loss
            if shape.kind == "serve":
                def fn(p, ids, dense):
                    return fwd(p, mcfg, ids, dense)
                return (fn, (p_shapes, ids, dense), (p_shard, ids_sh, d_sh),
                        None)
            o_shapes, o_shard = _opt_shardings(p_shapes, p_axes, mesh)

            def loss(p, ids, dense, labels):
                return lss(p, mcfg, ids, dense, labels)

            fn = _train_fn(loss)
            return (fn, (p_shapes, o_shapes, ids, dense, labels),
                    (p_shard, o_shard, ids_sh, d_sh, l_sh),
                    (p_shard, o_shard, None))

        if kind == "bert4rec":
            S = mcfg.seq_len
            seq = SDS((B, S), jnp.int32)
            seq_sh = named_sharding((B, S), "batch", None)
            if shape.kind == "serve":
                def fn(p, seq):
                    return rs.bert4rec_user_embedding(p, mcfg, seq)
                return fn, (p_shapes, seq), (p_shard, seq_sh), None
            # fixed-count masked positions (20%): [B,M,V] logits, not [B,S,V]
            M = max(S // 5, 1)
            mpos = SDS((B, M), jnp.int32)
            labels = SDS((B, M), jnp.int32)
            m_sh = named_sharding((B, M), "batch", None)
            o_shapes, o_shard = _opt_shardings(p_shapes, p_axes, mesh)

            def loss(p, seq, mpos, labels):
                return rs.bert4rec_masked_loss(p, mcfg, seq, mpos, labels)

            fn = _train_fn(loss)
            return (fn, (p_shapes, o_shapes, seq, mpos, labels),
                    (p_shard, o_shard, seq_sh, m_sh, m_sh),
                    (p_shard, o_shard, None))

        # mind
        S = mcfg.seq_len
        beh = SDS((B, S), jnp.int32)
        bm = SDS((B, S), jnp.float32)
        beh_sh = named_sharding((B, S), "batch", None)
        if shape.kind == "serve":
            def fn(p, behavior, mask):
                return rs.mind_user_embedding(p, mcfg, behavior, mask)
            return (fn, (p_shapes, beh, bm), (p_shard, beh_sh, beh_sh), None)
        tgt = SDS((B,), jnp.int32)
        neg = SDS((B, 16), jnp.int32)
        o_shapes, o_shard = _opt_shardings(p_shapes, p_axes, mesh)

        def loss(p, behavior, mask, target, neg):
            return rs.mind_loss(p, mcfg, behavior, mask, target, neg)

        fn = _train_fn(loss)
        return (fn, (p_shapes, o_shapes, beh, bm, tgt, neg),
                (p_shard, o_shard, beh_sh, beh_sh,
                 named_sharding((B,), "batch"),
                 named_sharding((B, 16), "batch", None)),
                (p_shard, o_shard, None))


# ---------------------------------------------------------------------------
# MeMemo (the paper's own shapes)
# ---------------------------------------------------------------------------
def retrieval_cell(arch: ArchConfig, shape: ShapeSpec, mesh: Mesh,
                   tuning: dict | None = None):
    t = tuning or {}
    n, dim = shape["n_candidates"], shape["dim"]
    n += (-n) % int(mesh.devices.size)      # pad with sentinel rows
    b, k = shape["batch"], shape["k"]
    db_dtype = jnp.dtype(t.get("db_dtype", "float32"))
    wire_bf16 = bool(t.get("wire_bf16", False))
    db = SDS((n, dim), db_dtype)
    q = SDS((b, dim), jnp.float32)
    db_sh = NamedSharding(mesh, P(tuple(mesh.axis_names), None))
    from repro.core.distributed import sharded_flat_topk

    def fn(db, q):
        return sharded_flat_topk(mesh, db, q, k, wire_bf16=wire_bf16)

    return fn, (db, q), (db_sh, _replicated(mesh)), None


BUILDERS = {"lm": lm_cell, "gnn": gnn_cell, "recsys": recsys_cell,
            "retrieval": retrieval_cell}


def build_cell(arch_id: str, shape_name: str, mesh: Mesh,
               tuning: dict | None = None):
    arch = get_config(arch_id)
    shape = arch.shape(shape_name)
    builder = BUILDERS[arch.family]
    fn, specs, shardings, out_shardings = builder(arch, shape, mesh, tuning)

    rules = _rules(tuning)

    def wrapped(*args):
        with axis_rules(mesh, rules):
            return fn(*args)

    if out_shardings is None:
        return jax.jit(wrapped, in_shardings=shardings), specs
    return (jax.jit(wrapped, in_shardings=shardings,
                    out_shardings=out_shardings), specs)
