"""End-to-end serving driver: continuous-batching LM serving (optionally
with RAG augmentation, retrieval overlapped behind the decode loop).

    PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b \
        --requests 12 --max-new 16 [--rag]

RAG requests arrive closed-loop (a bounded window of outstanding
requests is kept topped up, like real traffic) and ride the engine's
tick state machine: late arrivals' ANN searches run behind the decode
dispatches of earlier requests (DESIGN.md §11) — the run reports
``overlap_ratio`` (fraction of retrieval ticks hidden behind decode)
and ``slot_occupancy`` alongside req/s.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.data.corpus import BUILTIN_CORPUS
from repro.models import transformer as tf
from repro.serve.engine import ServeEngine
from repro.serve.rag import RAGPipeline
from repro.utils import logger


def _power_of_two(v: str) -> int:
    n = int(v)
    if n < 1 or n & (n - 1):
        raise argparse.ArgumentTypeError(f"{v} is not a power of two")
    return n


def _serve_closed_loop(engine, queries, tenants, *, k, max_new):
    """Drive the engine closed-loop: keep up to 2*slots requests
    outstanding so retrieval for late arrivals overlaps decode ticks
    already running (an open-loop burst would retrieve everything on
    tick 1 with nothing to hide behind)."""
    window = 2 * engine.slots
    pend = list(zip(queries, tenants))
    reqs = []
    t0 = time.perf_counter()
    while pend or engine._work_pending():
        while pend and sum(not r.done for r in reqs) < window:
            q, t = pend.pop(0)
            reqs.append(engine.submit_rag(q, k=k, tenant=t,
                                          max_new_tokens=max_new))
        engine.step()
    dt = time.perf_counter() - t0
    engine.poll()
    return reqs, dt


def _log_engine_stats(engine):
    s = engine.stats.as_dict()
    logger.info(
        f"engine: {s['ticks']} ticks ({s['decode_ticks']} decode, "
        f"{s['prefills']} prefills), overlap_ratio "
        f"{s['overlap_ratio']:.2f} ({s['overlapped_ticks']}/"
        f"{s['retrieval_ticks']} retrieval ticks hidden behind decode), "
        f"slot_occupancy {s['slot_occupancy']:.2f}, "
        f"{s['re_retrievals']} epoch-guard re-retrievals")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--rag", action="store_true")
    ap.add_argument("--index", default="hnsw",
                    choices=("flat", "ivf", "hnsw", "tiered"),
                    help="VectorIndex backend for the RAG retriever")
    ap.add_argument("--index-dtype", default=None,
                    choices=("fp32", "bf16", "int8"),
                    help="row-storage codec (DESIGN.md §9): encoded "
                         "device blocks + snapshot pages (int8 ≈ 4x "
                         "smaller), asymmetric search with fp32 rerank. "
                         "Default: fp32 (or the stored codec on a warm "
                         "restore — a mismatch is rejected)")
    ap.add_argument("--beam-impl", default=None,
                    choices=("fused", "jnp"),
                    help="HNSW layer-0 beam implementation (DESIGN.md "
                         "§12): 'fused' runs the whole ef-beam as one "
                         "kernel launch; 'jnp' is the per-hop while_loop "
                         "reference. Default: fused")
    ap.add_argument("--retrieval-batch", type=_power_of_two, default=128,
                    help="RetrievalEngine bucket cap (power of two)")
    ap.add_argument("--retrieval-cache", type=int, default=1024,
                    help="RetrievalEngine LRU entries (0 disables)")
    ap.add_argument("--shards", type=int, default=None,
                    help="partition the index over N mesh shards "
                         "(DESIGN.md §8): CRUD routes by key hash, "
                         "queries fan out + merge. Default: single "
                         "device (or the stored shard count on a warm "
                         "restore). CPU simulation needs XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N")
    ap.add_argument("--store-dir", default=None,
                    help="durable IndexStore directory (DESIGN.md §7): "
                         "restarts restore the index warm — snapshot + "
                         "WAL replay — instead of re-embedding the corpus")
    ap.add_argument("--snapshot-every", type=int, default=0,
                    help="auto-snapshot the store every N mutations "
                         "(0: only the final snapshot on exit)")
    ap.add_argument("--tenants", type=int, default=0,
                    help="multi-tenant serving (DESIGN.md §10): front the "
                         "retriever with an IndexPool of N per-tenant "
                         "private corpora over one shared device arena. "
                         "Requests round-robin across tenants and still "
                         "coalesce into one retrieval dispatch per tick. "
                         "Implies a flat per-tenant index; --store-dir "
                         "becomes the pool root (per-tenant subdirs)")
    ap.add_argument("--max-resident", type=int, default=64,
                    help="with --tenants: LRU cap on arena-resident "
                         "tenants; the rest page to their store dirs")
    ap.add_argument("--sampler", default="greedy",
                    choices=("greedy", "temperature"),
                    help="token sampler; temperature draws fold (request, "
                         "position) into --seed, so output is independent "
                         "of the admission schedule")
    ap.add_argument("--temperature", type=float, default=1.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    params = tf.init_lm(jax.random.PRNGKey(args.seed), cfg)

    def build_engine(pipeline=None):
        return ServeEngine(params, cfg, pipeline=pipeline, slots=args.slots,
                           max_len=args.max_len, dtype=jnp.float32,
                           sampler=args.sampler,
                           temperature=args.temperature, seed=args.seed)

    if args.rag and args.tenants > 0:
        from repro.core import IndexPool
        from repro.data.corpus import HashingEncoder
        encoder = HashingEncoder()
        pool = IndexPool(args.store_dir, dim=encoder.dim,
                         n_shards=args.shards or 1,
                         dtype=args.index_dtype or "fp32",
                         max_resident=args.max_resident,
                         snapshot_every=args.snapshot_every or None)
        rag = RAGPipeline(encoder=encoder, index=pool,
                          retrieval_batch=args.retrieval_batch,
                          retrieval_cache=args.retrieval_cache)
        tids = [f"tenant{i}" for i in range(args.tenants)]
        for tid in tids:
            # each tenant holds a PRIVATE copy of the corpus — keys and
            # embeddings are namespaced, so identical texts never collide
            try:
                known = pool.size(tid)      # pages a durable tenant in
            except KeyError:
                known = 0
            if known:
                logger.info(f"{tid}: warm restore, {known} docs "
                            f"@ epoch {pool.epoch(tid)}")
                rag.register_texts(BUILTIN_CORPUS, tenant=tid)
            else:
                rag.add_documents(BUILTIN_CORPUS, tenant=tid)
        engine = build_engine(rag)
        queries = [["how does hnsw search work",
                    "why is on device retrieval private",
                    "what does efConstruction control"][i % 3]
                   for i in range(args.requests)]
        tenants = [tids[i % len(tids)] for i in range(args.requests)]
        reqs, dt = _serve_closed_loop(engine, queries, tenants, k=3,
                                      max_new=args.max_new)
        for i, r in enumerate(reqs):
            logger.info(f"req {i} [{r.tenant}]: retrieved "
                        f"{[d.key for d in r.docs]}")
        logger.info(f"RAG[pool x{args.tenants}]: {args.requests} requests "
                    f"in {dt:.1f}s ({args.requests / dt:.2f} req/s, "
                    f"overlapped continuous batching)")
        _log_engine_stats(engine)
        rs = rag.retriever.stats.as_dict()
        logger.info(
            f"retrieval: {rs['requests']} requests in {rs['searches']} "
            f"device dispatches across {len(set(tenants))} tenants "
            f"(cache hit rate {rs['hit_rate']:.2f})")
        ps = pool.pool_stats()
        logger.info(f"pool: {ps['tenants']} tenants, {ps['resident']} "
                    f"resident, {ps['arena_rows']} arena rows in "
                    f"{ps['slabs']} slabs ({ps['arena_bytes']} device "
                    f"bytes), {ps['evictions']} evictions")
        if args.store_dir:
            pool.flush()
            logger.info(f"pool flushed to {args.store_dir} "
                        f"(per-tenant snapshot + WAL; next start "
                        f"restores warm)")
        return

    if args.rag:
        store = None
        if args.store_dir:
            from repro.store import IndexStore
            store = IndexStore(args.store_dir,
                               snapshot_every=args.snapshot_every or None)
        rag = RAGPipeline(index_kind=args.index, index_store=store,
                          retrieval_batch=args.retrieval_batch,
                          retrieval_cache=args.retrieval_cache,
                          index_shards=args.shards,
                          index_dtype=args.index_dtype,
                          index_beam_impl=args.beam_impl)
        if rag.index.shard_count > 1:
            logger.info(f"index sharded over {rag.index.shard_count} "
                        f"devices (key-hash routing + fan-out search)")
        if rag.index.storage_dtype != "fp32":
            logger.info(f"index rows stored as {rag.index.storage_dtype} "
                        "(encoded device blocks + snapshot pages, "
                        "asymmetric search + fp32 rerank; DESIGN.md §9)")
        if rag.index.size:
            # warm restore: embeddings came back from the store (epoch
            # included — the retrieval cache keys on it); only the text
            # side-table needs repopulating
            logger.info(
                f"warm restore from {args.store_dir}: {rag.index.size} "
                f"docs @ mutation_epoch {rag.index.mutation_epoch}")
            rag.register_texts(BUILTIN_CORPUS)
        else:
            rag.add_documents(BUILTIN_CORPUS)
        engine = build_engine(rag)
        queries = [["how does hnsw search work",
                    "why is on device retrieval private",
                    "what does efConstruction control"][i % 3]
                   for i in range(args.requests)]
        reqs, dt = _serve_closed_loop(engine, queries,
                                      [None] * len(queries), k=3,
                                      max_new=args.max_new)
        for i, r in enumerate(reqs):
            logger.info(f"req {i}: retrieved {[d.key for d in r.docs]}")
        logger.info(f"RAG[{args.index}]: {args.requests} requests in {dt:.1f}s "
                    f"({args.requests / dt:.2f} req/s, overlapped "
                    f"continuous batching)")
        _log_engine_stats(engine)
        rs = rag.retriever.stats.as_dict()
        logger.info(
            f"retrieval: {rs['requests']} requests in {rs['searches']} device "
            f"dispatches ({rs['searched_queries']} searched + "
            f"{rs['padded_queries']} bucket pad, "
            f"cache hit rate {rs['hit_rate']:.2f})")
        if store is not None:
            path = store.snapshot(rag.index)
            logger.info(f"store snapshot: {path} "
                        f"(epoch {rag.index.mutation_epoch}; next start "
                        f"restores warm)")
        return

    engine = build_engine()
    rng = np.random.default_rng(args.seed)
    prompts = [rng.integers(0, cfg.vocab, size=rng.integers(4, 24))
               for _ in range(args.requests)]
    t0 = time.perf_counter()
    outs = engine.generate(prompts, max_new_tokens=args.max_new)
    dt = time.perf_counter() - t0
    logger.info(f"{args.requests} requests, {engine.tokens_out} tokens in "
                f"{dt:.1f}s -> {engine.tokens_out / dt:.1f} tok/s "
                f"({engine.ticks} engine ticks, {args.slots} slots)")
    assert all(len(o) == args.max_new for o in outs)


if __name__ == "__main__":
    main()
