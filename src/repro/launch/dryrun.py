import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh) cell
and extract the roofline terms from the compiled artifact.

    PYTHONPATH=src python -m repro.launch.dryrun --mesh pod --out results.json
    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b --shape train_4k

The XLA_FLAGS line above MUST run before any jax import: 512 host devices
stand in for the production pods (16x16 single pod, 2x16x16 multi-pod).
Everything lowered here uses ShapeDtypeStructs — no real allocation.

Roofline (TPU v5e targets): 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s ICI
per link. The parsed HLO is the per-device SPMD module, so all terms are
per-device already. FLOPs/bytes/collective-bytes come from the scan-aware
HLO analyzer (launch/hlo_analysis.py) because XLA's cost_analysis counts
loop bodies once (EXPERIMENTS.md §Methodology).
"""
import argparse      # noqa: E402
import dataclasses   # noqa: E402
import gc            # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402

from repro.configs import ALL_ARCHS, get_config          # noqa: E402
from repro.launch.hlo_analysis import analyze            # noqa: E402
from repro.launch.mesh import make_production_mesh, tp_size  # noqa: E402
from repro.launch.model_costs import model_bytes         # noqa: E402
from repro.launch.steps import build_cell                # noqa: E402
from repro.utils import human_bytes, logger              # noqa: E402

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # B/s / chip
ICI_BW = 50e9                # B/s / link (conservative: 1 link)
HBM_PER_CHIP = 16 * 1024 ** 3

# ---------------------------------------------------------------------------
# Tuned per-cell configurations — the outcome of the EXPERIMENTS.md §Perf
# hillclimbs. ``--preset tuned`` applies these; ``--preset baseline`` runs
# the paper-faithful/naive configuration for comparison.
# ---------------------------------------------------------------------------
_FSDP_RULES = {
    "heads": ["data", "model"], "mlp": ["data", "model"],
    "vocab": ["data", "model"], "kv_heads": ["data", "model"],
    "act_heads": None, "batch": ["data", "model"],
    "tokens": ["data", "model"],
}
_LM_TRAIN_DENSE = {
    "chunked_loss": 512, "opt_like_params": True, "param_dtype": "bfloat16",
    "attn_impl": "packed", "attn_block_k": 512, "rules": _FSDP_RULES,
}
_LM_TRAIN_MOE = {"chunked_loss": 512}      # grouped dispatch is code-default
_RETRIEVAL = {"db_dtype": "bfloat16", "wire_bf16": True}
_KVQ = {"kv_quant": True}                  # int8 KV cache (decode cells)

TUNED: dict = {
    ("llama3-8b", "train_4k"): _LM_TRAIN_DENSE,
    ("h2o-danube-3-4b", "train_4k"): _LM_TRAIN_DENSE,
    ("minitron-8b", "train_4k"): _LM_TRAIN_DENSE,
    ("olmoe-1b-7b", "train_4k"): _LM_TRAIN_MOE,
    ("granite-moe-3b-a800m", "train_4k"): {**_LM_TRAIN_MOE,
                                           "moe_pad_experts": 48,
                                           "vocab": 49408},   # pad 49155
    ("granite-moe-3b-a800m", "prefill_32k"): {"moe_pad_experts": 48},
    ("granite-moe-3b-a800m", "decode_32k"): {**_KVQ, "moe_pad_experts": 48},
    ("llama3-8b", "decode_32k"): _KVQ,
    ("h2o-danube-3-4b", "decode_32k"): _KVQ,
    ("h2o-danube-3-4b", "long_500k"): _KVQ,
    ("minitron-8b", "decode_32k"): _KVQ,
    ("olmoe-1b-7b", "decode_32k"): _KVQ,
    ("mememo", "query_1m"): _RETRIEVAL,
    ("mememo", "query_rt"): _RETRIEVAL,
    ("mind", "retrieval_cand"): _RETRIEVAL,
    ("wide-deep", "retrieval_cand"): _RETRIEVAL,
    ("bert4rec", "retrieval_cand"): _RETRIEVAL,
    ("fm", "retrieval_cand"): _RETRIEVAL,
}


# ---------------------------------------------------------------------------
def model_flops(arch_id: str, shape_name: str) -> float:
    """Analytic 'useful' FLOPs per step, whole job (all devices)."""
    arch = get_config(arch_id)
    shape = arch.shape(shape_name)
    m = arch.model
    if arch.family == "lm":
        n_act = m.active_param_count
        if shape.kind == "train":
            tokens = shape["global_batch"] * shape["seq_len"]
            return 6.0 * n_act * tokens
        if shape.kind == "prefill":
            tokens = shape["global_batch"] * shape["seq_len"]
            return 2.0 * n_act * tokens
        # decode: one token per sequence + attention over the cache
        b, s = shape["global_batch"], shape["seq_len"]
        s_eff = min(s, m.sliding_window or s)
        attn = 4.0 * b * s_eff * m.n_layers * m.n_kv_heads * m.dh
        return 2.0 * n_act * b + attn
    if arch.family == "gnn":
        h = m.d_hidden
        if shape.name == "molecule":
            e_eff = shape["batch"] * shape["n_edges"]
            n_eff = shape["batch"] * shape["n_nodes"]
        elif shape.kind == "sampled_train":
            b, f1, f2 = shape["batch_nodes"], shape["fanout1"], shape["fanout2"]
            n_eff = b * (1 + f1 + f1 * f2)
            e_eff = b * (f1 + f1 * f2)
        else:
            n_eff, e_eff = shape["n_nodes"], shape["n_edges"]
        d = shape["d_feat"]
        fwd = 2.0 * n_eff * (d * h + h * h) * 2 + 2.0 * e_eff * (d + h)
        return 3.0 * fwd if "train" in shape.kind else fwd
    if arch.family == "recsys":
        if shape.kind == "retrieval":
            nq = shape["batch"] * max(m.n_interests, 1)
            return 2.0 * nq * shape["n_candidates"] * m.embed_dim
        b = shape["batch"]
        if m.kind in ("fm", "wide_deep"):
            per = 2.0 * m.n_sparse * m.embed_dim
            for a, bdim in zip((m.n_sparse * m.embed_dim + m.n_dense,)
                               + tuple(m.mlp_dims), tuple(m.mlp_dims) + (1,)):
                per += 2.0 * a * bdim
        elif m.kind == "bert4rec":
            d, s = m.embed_dim, m.seq_len
            per_tok = (12 * d * d + 4 * d * s) * m.n_blocks
            per = s * per_tok
            if shape.kind == "train":       # M=S/5 masked-position logits
                per += (s // 5) * 2 * d * m.n_items
        else:  # mind
            d, s = m.embed_dim, m.seq_len
            per = 2 * s * d * d + m.capsule_iters * 4 * m.n_interests * s * d
        fwd = per * b
        return 3.0 * fwd if shape.kind == "train" else fwd
    # mememo retrieval
    return 2.0 * shape["batch"] * shape["n_candidates"] * shape["dim"]


# ---------------------------------------------------------------------------
def run_cell(arch_id: str, shape_name: str, mesh, mesh_name: str,
             tuning: dict | None = None) -> dict:
    chips = mesh.devices.size
    t0 = time.time()
    jitted, specs = build_cell(arch_id, shape_name, mesh, tuning)
    lowered = jitted.lower(*specs)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    hlo = analyze(compiled.as_text())
    ca = compiled.cost_analysis() or {}

    mf_total = model_flops(arch_id, shape_name)
    mf_dev = mf_total / chips
    mb_dev = model_bytes(arch_id, shape_name, chips, tp_size(mesh), tuning)
    t_comp = hlo["flops"] / PEAK_FLOPS
    t_mem = mb_dev / HBM_BW                     # analytic TPU-target bytes
    t_mem_hlo = hlo["bytes"] / HBM_BW           # CPU-HLO upper bound
    t_coll = hlo["collective_bytes"] / ICI_BW
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    bottleneck = max(terms, key=terms.get)
    step_time = max(terms.values())
    per_dev_bytes = (mem.argument_size_in_bytes + mem.temp_size_in_bytes
                     + mem.output_size_in_bytes - mem.alias_size_in_bytes)

    row = {
        "arch": arch_id, "shape": shape_name, "mesh": mesh_name,
        "chips": int(chips),
        "status": "ok",
        "compile_s": round(t_compile, 1), "lower_s": round(t_lower, 1),
        "hlo_flops_per_dev": hlo["flops"],
        "hlo_bytes_per_dev": hlo["bytes"],
        "model_bytes_per_dev": mb_dev,
        "coll_bytes_per_dev": hlo["collective_bytes"],
        "coll_by_kind": {k: round(v) for k, v in hlo["collectives"].items()},
        "dynamic_whiles": hlo["dynamic_whiles"],
        "xla_flops_raw": ca.get("flops", 0.0),
        "t_compute_s": t_comp, "t_memory_s": t_mem,
        "t_memory_hlo_s": t_mem_hlo, "t_collective_s": t_coll,
        "bottleneck": bottleneck,
        "roofline_fraction": (t_comp / step_time) if step_time > 0 else 0.0,
        "model_flops_per_dev": mf_dev,
        "useful_ratio": mf_dev / hlo["flops"] if hlo["flops"] else 0.0,
        "arg_bytes_per_dev": mem.argument_size_in_bytes,
        "temp_bytes_per_dev": mem.temp_size_in_bytes,
        "total_bytes_per_dev": int(per_dev_bytes),
        "fits_hbm": bool(per_dev_bytes <= HBM_PER_CHIP),
        "tuning": tuning or {},
    }
    del compiled, lowered, jitted
    gc.collect()
    return row


def iter_cells(archs, shapes):
    for arch_id in archs:
        arch = get_config(arch_id)
        for shape in arch.shapes:
            if shapes and shape.name not in shapes:
                continue
            if shape.kind == "build":
                continue            # host-side builder, not a lowered program
            yield arch_id, shape.name, (shape.name in arch.skip_shapes)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", action="append", default=None)
    ap.add_argument("--shape", action="append", default=None)
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod", "both"])
    ap.add_argument("--out", default=None)
    ap.add_argument("--tuning", default=None,
                    help="JSON dict of implementation overrides")
    ap.add_argument("--preset", default="baseline",
                    choices=["baseline", "tuned"])
    args = ap.parse_args()

    archs = args.arch or list(ALL_ARCHS)
    tuning = json.loads(args.tuning) if args.tuning else None
    meshes = []
    if args.mesh in ("pod", "both"):
        meshes.append(("pod_16x16", make_production_mesh(multi_pod=False)))
    if args.mesh in ("multipod", "both"):
        meshes.append(("multipod_2x16x16", make_production_mesh(multi_pod=True)))

    rows = []
    for mesh_name, mesh in meshes:
        for arch_id, shape_name, skipped in iter_cells(archs, args.shape):
            tag = f"{arch_id} x {shape_name} x {mesh_name}"
            if skipped:
                logger.info(f"SKIP  {tag} (mandated: full attention at 500k, "
                            "see DESIGN.md section 5)")
                rows.append({"arch": arch_id, "shape": shape_name,
                             "mesh": mesh_name, "status": "skipped_mandated"})
                continue
            cell_tuning = tuning
            if cell_tuning is None and args.preset == "tuned":
                cell_tuning = TUNED.get((arch_id, shape_name))
            try:
                row = run_cell(arch_id, shape_name, mesh, mesh_name,
                               cell_tuning)
                logger.info(
                    f"OK    {tag}: compile={row['compile_s']}s "
                    f"bottleneck={row['bottleneck']} "
                    f"t=({row['t_compute_s']:.2e},{row['t_memory_s']:.2e},"
                    f"{row['t_collective_s']:.2e})s "
                    f"mem/dev={human_bytes(row['total_bytes_per_dev'])} "
                    f"fits={row['fits_hbm']} useful={row['useful_ratio']:.2f}")
            except Exception as e:
                logger.info(f"FAIL  {tag}: {type(e).__name__}: {str(e)[:200]}")
                row = {"arch": arch_id, "shape": shape_name, "mesh": mesh_name,
                       "status": "failed", "error": f"{type(e).__name__}: {e}",
                       "traceback": traceback.format_exc()[-2000:]}
            rows.append(row)
            if args.out:           # incremental write (long runs)
                with open(args.out, "w") as f:
                    json.dump(rows, f, indent=1)

    ok = sum(1 for r in rows if r.get("status") == "ok")
    fail = sum(1 for r in rows if r.get("status") == "failed")
    skip = sum(1 for r in rows if r.get("status") == "skipped_mandated")
    logger.info(f"dry-run complete: {ok} ok, {fail} failed, {skip} skipped "
                f"(mandated)")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(rows, f, indent=1)
        logger.info(f"wrote {args.out}")
    return 1 if fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
