"""Fault tolerance for 1000+-node operation.

Mechanisms (all exercised in tests/test_fault_tolerance.py):

  * run_resilient — supervisor loop: any step failure (device loss,
    preemption, injected fault) triggers restore-from-latest-checkpoint and
    replay. The data pipeline is (seed, step)-deterministic, so replay is
    exact; with checkpoint-every-K the worst-case lost work is K steps.
  * StragglerWatchdog — rolling p95 step-time deadline; steps beyond
    ``factor * p95`` are flagged (at pod scale the action is re-scheduling
    the slow host's shard / firing the backup executor — here we record and
    expose them; the hook receives each event).
  * elastic re-mesh — checkpoints hold logical content only, so restore can
    target a *different* mesh (fewer/more hosts) via
    CheckpointManager.restore_sharded: lose a pod, shrink the mesh, resume.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.train.checkpoint import CheckpointManager
from repro.train.optimizer import OptState, adamw_init
from repro.utils import PyTree, logger


class InjectedFailure(RuntimeError):
    """Stands in for XlaRuntimeError/device-loss in tests."""


@dataclasses.dataclass
class StragglerEvent:
    step: int
    seconds: float
    p95: float


class StragglerWatchdog:
    def __init__(self, window: int = 50, factor: float = 3.0,
                 min_samples: int = 10, on_straggler: Callable | None = None):
        self.times: list[float] = []
        self.window = window
        self.factor = factor
        self.min_samples = min_samples
        self.events: list[StragglerEvent] = []
        self.on_straggler = on_straggler

    def observe(self, step: int, seconds: float) -> bool:
        flagged = False
        if len(self.times) >= self.min_samples:
            p95 = float(np.percentile(self.times[-self.window:], 95))
            if seconds > self.factor * p95:
                ev = StragglerEvent(step, seconds, p95)
                self.events.append(ev)
                logger.info(f"straggler: step {step} took {seconds*1e3:.0f}ms "
                            f"(p95 {p95*1e3:.0f}ms)")
                if self.on_straggler:
                    self.on_straggler(ev)
                flagged = True
        self.times.append(seconds)
        return flagged


def run_resilient(init_params: PyTree, train_step: Callable,
                  batch_fn: Callable[[int], dict], *, steps: int,
                  ckpt: CheckpointManager, ckpt_every: int = 20,
                  max_restarts: int = 5, watchdog: StragglerWatchdog | None = None,
                  fail_at: Iterator[int] | None = None
                  ) -> tuple[PyTree, OptState, dict]:
    """Supervised training: restart from the newest checkpoint on failure.

    ``batch_fn(step)`` must be deterministic in ``step`` (see data/synthetic).
    ``fail_at`` injects failures at the given global steps (testing).
    """
    # host snapshot: train_step donates its inputs, and restart-from-scratch
    # must survive the originals having been consumed
    init_host = jax.tree.map(np.asarray, init_params)
    fresh = lambda: jax.tree.map(jnp.asarray, init_host)
    params = fresh()
    opt_state = adamw_init(params)
    template = {"params": jax.tree.map(np.asarray, params),
                "opt": jax.tree.map(np.asarray, opt_state)}
    fail_steps = set(fail_at or [])
    restarts = 0
    losses = {}
    step = 0
    while step < steps:
        try:
            if step in fail_steps:
                fail_steps.discard(step)
                raise InjectedFailure(f"injected failure at step {step}")
            t0 = time.perf_counter()
            batch = batch_fn(step)
            params, opt_state, metrics = train_step(params, opt_state, batch)
            dt = time.perf_counter() - t0
            losses[step] = float(metrics["loss"])
            if watchdog is not None:
                watchdog.observe(step, dt)
            step += 1
            if ckpt_every and step % ckpt_every == 0:
                ckpt.save(step, {"params": params, "opt": opt_state})
        except (InjectedFailure, RuntimeError) as e:  # device loss, preemption
            restarts += 1
            if restarts > max_restarts:
                raise RuntimeError(f"exceeded {max_restarts} restarts") from e
            latest = ckpt.latest_step()
            if latest is None:
                logger.info(f"failure at step {step} ({e}); no checkpoint — "
                            "restarting from scratch")
                params = fresh()
                opt_state = adamw_init(params)
                step = 0
            else:
                logger.info(f"failure at step {step} ({e}); restoring step "
                            f"{latest}")
                state, _ = ckpt.restore(template)
                params, opt_state = state["params"], state["opt"]
                params = jax.tree.map(jnp.asarray, params)
                opt_state = jax.tree.map(jnp.asarray, opt_state)
                step = latest
    ckpt.save(steps, {"params": params, "opt": opt_state})
    return params, opt_state, {"losses": losses, "restarts": restarts,
                               "stragglers": watchdog.events if watchdog else []}
