"""Checkpointing: atomic, versioned, keep-last-k, async-capable, and
mesh-elastic (a checkpoint saved on one mesh restores onto any other).

Format: one ``step_<N>.npz`` per step holding the flattened param/opt pytree
(path-keyed), plus a JSON meta blob. Checkpoints store *logical* content
only — device layout is reapplied at restore time from the target mesh +
logical axis rules, which is what makes elastic re-meshing work (DESIGN.md
§4 fault tolerance). At real pod scale the same writer runs per-host on the
host-local shard (jax.experimental.multihost_utils); single-process here.
"""
from __future__ import annotations

import json
import os
import re
import threading
from typing import Any

import jax
import numpy as np

from repro.distributed.sharding import axis_rules, named_sharding
from repro.utils import PyTree, logger

_SEP = "/"


def _flatten(tree: PyTree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_path_str(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


def _unflatten(template: PyTree, flat: dict[str, np.ndarray]) -> PyTree:
    paths_leaves = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, tmpl in paths_leaves[0]:
        key = _SEP.join(_path_str(p) for p in path)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = flat[key]
        if tuple(arr.shape) != tuple(tmpl.shape):
            raise ValueError(f"shape mismatch for {key}: ckpt {arr.shape} "
                             f"vs template {tmpl.shape}")
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(paths_leaves[1], leaves)


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3, async_save: bool = False):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self._pending: threading.Thread | None = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------- save
    def save(self, step: int, state: PyTree, meta: dict | None = None) -> str:
        self.wait()
        flat = _flatten(state)          # snapshot on caller thread (consistent)
        flat = {k: np.array(v, copy=True) for k, v in flat.items()}
        if self.async_save:
            t = threading.Thread(target=self._write, args=(step, flat, meta))
            t.start()
            self._pending = t
            return self._path(step)
        return self._write(step, flat, meta)

    def _write(self, step: int, flat: dict, meta: dict | None) -> str:
        path = self._path(step)
        tmp = path + ".tmp.npz"
        payload = dict(flat)
        payload["__meta__"] = np.frombuffer(
            json.dumps({"step": step, **(meta or {})}).encode(), dtype=np.uint8)
        np.savez(tmp[:-4], **payload)
        os.replace(tmp, path)           # atomic publish
        self._gc()
        logger.info(f"checkpoint saved: {path}")
        return path

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _path(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:08d}.npz")

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep > 0 else []:
            try:
                os.remove(self._path(s))
            except OSError:
                pass

    # ---------------------------------------------------------- restore
    def all_steps(self) -> list[int]:
        out = []
        for f in os.listdir(self.dir):
            m = re.fullmatch(r"step_(\d+)\.npz", f)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, template: PyTree, step: int | None = None
                ) -> tuple[PyTree, dict]:
        self.wait()
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        z = np.load(self._path(step), allow_pickle=False)
        meta = json.loads(bytes(z["__meta__"]).decode())
        flat = {k: z[k] for k in z.files if k != "__meta__"}
        return _unflatten(template, flat), meta

    def restore_sharded(self, template: PyTree, axes: PyTree, mesh,
                        step: int | None = None) -> tuple[PyTree, dict]:
        """Elastic restore: place host arrays onto ``mesh`` per logical axes.
        The mesh may differ arbitrarily from the one that saved (ZeRO shards,
        TP degree, pod count) because only logical content was stored."""
        host, meta = self.restore(template, step)
        with axis_rules(mesh):
            def place(arr, ax):
                sh = named_sharding(arr.shape, *ax)
                return jax.device_put(arr, sh)
            placed = jax.tree.map(
                place, host, axes,
                is_leaf=lambda x: isinstance(x, np.ndarray))
        return placed, meta
