"""Pure-JAX optimizer library (no optax): AdamW + Adafactor-style second
moment option, global-norm clipping, LR schedules, ZeRO-1 state sharding.

State layout mirrors the param pytree: {"m": tree, "v": tree, "step": int}.
ZeRO-1: optimizer-state logical axes reuse the param axes with "layers"
remapped to the "zero" rule (-> data axis), so m/v shard across data
parallel ranks on top of the params' model-parallel sharding.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.utils import PyTree, tree_norm


# ---------------------------------------------------------------------------
# LR schedules
# ---------------------------------------------------------------------------
def warmup_cosine(peak_lr: float, warmup_steps: int, total_steps: int,
                  min_ratio: float = 0.1) -> Callable:
    def schedule(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * step / jnp.maximum(warmup_steps, 1)
        t = jnp.clip((step - warmup_steps)
                     / jnp.maximum(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = peak_lr * (min_ratio + (1 - min_ratio)
                         * 0.5 * (1 + jnp.cos(jnp.pi * t)))
        return jnp.where(step < warmup_steps, warm, cos)
    return schedule


def constant_lr(lr: float) -> Callable:
    return lambda step: jnp.asarray(lr, jnp.float32)


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: Callable | float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0

    def lr_at(self, step):
        return self.lr(step) if callable(self.lr) else jnp.asarray(self.lr)


class OptState(NamedTuple):
    m: PyTree
    v: PyTree
    step: jax.Array


def adamw_init(params: PyTree) -> OptState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return OptState(m=zeros, v=jax.tree.map(jnp.copy, zeros),
                    step=jnp.zeros((), jnp.int32))


def clip_by_global_norm(grads: PyTree, max_norm: float) -> tuple[PyTree, jax.Array]:
    g_norm = tree_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(g_norm, 1e-12))
    return jax.tree.map(lambda g: g * scale, grads), g_norm


def adamw_update(cfg: AdamWConfig, params: PyTree, grads: PyTree,
                 state: OptState) -> tuple[PyTree, OptState, dict]:
    grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    if cfg.grad_clip > 0:
        grads, g_norm = clip_by_global_norm(grads, cfg.grad_clip)
    else:
        g_norm = tree_norm(grads)
    step = state.step + 1
    lr = cfg.lr_at(step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mh = m / b1c
        vh = v / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if cfg.weight_decay > 0 and p.ndim >= 2:       # no decay on norms/bias
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state.m)
    flat_v = tdef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, OptState(new_m, new_v, step), {"grad_norm": g_norm, "lr": lr}


def opt_state_axes(param_axes: PyTree) -> Any:
    """Logical axes for (m, v): param axes with 'layers' -> 'zero' (ZeRO-1:
    the stacked-layer dim shards across the data axis)."""
    def remap(axes):
        return tuple("zero" if a == "layers" else a for a in axes)
    mapped = jax.tree.map(
        remap, param_axes,
        is_leaf=lambda x: isinstance(x, tuple)
        and all(isinstance(a, (str, type(None))) for a in x))
    return OptState(m=mapped, v=mapped, step=())
