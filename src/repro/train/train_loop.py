"""jit'd train-step factory + the training driver.

``make_train_step`` builds a donated, optionally microbatched (grad
accumulation) step:  (params, opt_state, batch) -> (params, opt_state,
metrics). Microbatching scans the batch's leading-dim splits, accumulating
f32 grads — this is also the compute/communication overlap lever: per-
microbatch reduce lets XLA's latency-hiding scheduler interleave the DP
all-reduce of microbatch i with the backward of i+1.

``fit`` is the fault-tolerant driver (checkpoint every K, straggler
watchdog, auto-restart) — see train/fault_tolerance.py.
"""
from __future__ import annotations

import time
from typing import Callable, Iterator

import jax
import jax.numpy as jnp

from repro.train.checkpoint import CheckpointManager
from repro.train.optimizer import AdamWConfig, OptState, adamw_init, adamw_update
from repro.utils import PyTree, logger, tree_zeros_like


def make_train_step(loss_fn: Callable, opt_cfg: AdamWConfig, *,
                    microbatches: int = 1, donate: bool = True) -> Callable:
    """loss_fn(params, **batch) -> scalar loss."""

    def step(params: PyTree, opt_state: OptState, batch: dict):
        if microbatches <= 1:
            loss, grads = jax.value_and_grad(
                lambda p: loss_fn(p, **batch))(params)
        else:
            def split(x):
                b = x.shape[0]
                assert b % microbatches == 0, (b, microbatches)
                return x.reshape(microbatches, b // microbatches, *x.shape[1:])
            micro = {k: split(v) for k, v in batch.items()}

            def micro_step(carry, mb):
                loss_acc, grad_acc = carry
                loss, grads = jax.value_and_grad(
                    lambda p: loss_fn(p, **mb))(params)
                grads = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32), grad_acc, grads)
                return (loss_acc + loss, grads), None

            init = (jnp.zeros((), jnp.float32), tree_zeros_like(
                jax.tree.map(lambda p: p.astype(jnp.float32), params)))
            (loss, grads), _ = jax.lax.scan(micro_step, init, micro)
            loss = loss / microbatches
            grads = jax.tree.map(lambda g: g / microbatches, grads)

        params, opt_state, om = adamw_update(opt_cfg, params, grads, opt_state)
        return params, opt_state, {"loss": loss, **om}

    return jax.jit(step, donate_argnums=(0, 1) if donate else ())


def init_train_state(params: PyTree) -> OptState:
    return adamw_init(params)


def fit(params: PyTree, train_step: Callable, batches: Iterator[dict], *,
        steps: int, ckpt: CheckpointManager | None = None,
        ckpt_every: int = 50, log_every: int = 10,
        opt_state: OptState | None = None, start_step: int = 0,
        on_step=None) -> tuple[PyTree, OptState, list[dict]]:
    """Plain single-controller loop (the fault-tolerant wrapper lives in
    fault_tolerance.run_resilient)."""
    opt_state = opt_state if opt_state is not None else adamw_init(params)
    history = []
    for i in range(start_step, steps):
        batch = next(batches)
        t0 = time.perf_counter()
        params, opt_state, metrics = train_step(params, opt_state, batch)
        loss = float(metrics["loss"])
        dt = time.perf_counter() - t0
        history.append({"step": i, "loss": loss, "sec": dt})
        if on_step is not None:
            on_step(i, params, opt_state, metrics)
        if log_every and i % log_every == 0:
            logger.info(f"step {i}: loss={loss:.4f} "
                        f"gnorm={float(metrics['grad_norm']):.3f} {dt*1e3:.0f}ms")
        if ckpt is not None and ckpt_every and (i + 1) % ckpt_every == 0:
            ckpt.save(i + 1, {"params": params, "opt": opt_state})
    return params, opt_state, history
