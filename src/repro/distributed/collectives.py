"""Collective building blocks: hierarchical top-k merge and compressed
all-reduce. All are shard_map-side functions (use inside `shard_map`).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def topk_merge_axis(dists: jax.Array, ids: jax.Array, k: int,
                    axis_name: str, wire_bf16: bool = False,
                    tie_break_ids: bool = False
                    ) -> tuple[jax.Array, jax.Array]:
    """Merge per-shard top-k over one mesh axis (log-depth building block).

    dists/ids [B, k] per shard -> merged [B, k] (replicated along the axis).
    Wire cost: k * axis_size values instead of the full candidate set.
    ``wire_bf16`` halves the distance payload on the wire (ordering is
    preserved to bf16 resolution; ids stay exact).

    ``tie_break_ids`` resolves equal distances toward the smallest id via
    a two-key sort — the same order a single-device ``top_k`` over the
    id-sorted candidate set produces, which is what keeps the sharded
    index's merge bit-compatible with the 1-shard path (DESIGN.md §8).
    (Ties that straddle a shard's LOCAL top-k boundary are still cut by
    shard-local order; with real-valued distances that requires > k
    exactly-tied duplicate rows in one shard.)
    """
    if wire_bf16 and dists.dtype == jnp.bfloat16:
        # ship raw u16 bits: a bitcast cannot be commuted above the gather
        # the way a convert can, so the wire really carries 2 bytes/value
        bits = jax.lax.bitcast_convert_type(dists, jnp.uint16)
        d_all = jax.lax.bitcast_convert_type(
            jax.lax.all_gather(bits, axis_name), jnp.bfloat16)
    else:
        d_all = jax.lax.all_gather(dists, axis_name)   # [S, B, k]
    i_all = jax.lax.all_gather(ids, axis_name)
    s = d_all.shape[0]
    b = dists.shape[0]
    d_flat = jnp.transpose(d_all, (1, 0, 2)).reshape(b, s * k)
    i_flat = jnp.transpose(i_all, (1, 0, 2)).reshape(b, s * k)
    if tie_break_ids:
        sd, si = jax.lax.sort((d_flat, i_flat), num_keys=2)
        return sd[:, :k], si[:, :k]
    neg, j = jax.lax.top_k(-d_flat, k)
    return -neg, jnp.take_along_axis(i_flat, j, axis=1)


def hierarchical_topk(dists: jax.Array, ids: jax.Array, k: int,
                      axis_names: tuple[str, ...],
                      wire_bf16: bool = False,
                      tie_break_ids: bool = False
                      ) -> tuple[jax.Array, jax.Array]:
    """Merge local top-k across every mesh axis, innermost (fastest) first:
    'model' -> 'data' -> 'pod' gives log-depth tree reduction whose traffic
    per hop is k*axis_size rather than sum of shard sizes. ``wire_bf16``
    runs the whole merge in bf16 (converting once before the first hop, so
    no convert sits above a gather for XLA to commute): half the distance
    payload on every hop; ids stay exact, ordering is bf16-resolution."""
    out_dtype = dists.dtype
    if wire_bf16:
        dists = dists.astype(jnp.bfloat16)
    for ax in axis_names:
        dists, ids = topk_merge_axis(dists, ids, k, ax, wire_bf16,
                                     tie_break_ids)
    return dists.astype(out_dtype), ids


def compressed_psum(x: jax.Array, axis_name: str) -> jax.Array:
    """int8 chunk-quantized all-reduce: reduce-scatter + all-gather with int8
    payloads — 4x wire-byte reduction vs f32 ring all-reduce. Per-shard
    scale factors travel as f32 scalars (negligible).
    """
    # axis size via the psum-of-ones idiom: works on every JAX that supports
    # shard_map (jax.lax.axis_size is not present in the installed version)
    s = jax.lax.psum(1, axis_name)
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % s
    flat = jnp.pad(flat, (0, pad))
    chunks = flat.reshape(s, -1)                       # [S, n/S]
    scale = jnp.max(jnp.abs(chunks), axis=1, keepdims=True) / 127.0 + 1e-20
    q = jnp.clip(jnp.round(chunks / scale), -127, 127).astype(jnp.int8)
    # reduce-scatter: all_to_all the int8 chunks, dequantise + sum locally
    q_t = jax.lax.all_to_all(q[:, None], axis_name, split_axis=0,
                             concat_axis=1)            # [1, S, n/S] int8
    scale_t = jax.lax.all_gather(scale, axis_name)     # [S, S, 1]
    my = jax.lax.axis_index(axis_name)
    sc = scale_t[:, my]                                # [S, 1] scales for my chunk
    part = jnp.sum(q_t[0].astype(jnp.float32) * sc, axis=0)   # [n/S] f32
    # all-gather the reduced chunks, int8-quantised again
    psc = jnp.max(jnp.abs(part)) / 127.0 + 1e-20
    pq = jnp.clip(jnp.round(part / psc), -127, 127).astype(jnp.int8)
    all_q = jax.lax.all_gather(pq, axis_name)          # [S, n/S] int8
    all_sc = jax.lax.all_gather(psc, axis_name)        # [S]
    out = (all_q.astype(jnp.float32) * all_sc[:, None]).reshape(-1)
    if pad:
        out = out[:-pad]
    return out.reshape(x.shape).astype(x.dtype)
