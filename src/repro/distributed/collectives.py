"""Collective building blocks: hierarchical top-k merge and compressed
all-reduce. All are shard_map-side functions (use inside `shard_map`).

Two merge strategies live here:

* all-gather oracle (``axis_size=None``) — gather [S, B, k] then one full
  sort/top_k.  O(S*k) wire bytes per shard, single round.  Kept as the
  parity reference: every tree-merge result must be bitwise identical to
  it under ``tie_break_ids``.
* ppermute tree reduction (``axis_size=S``) — ceil(log2 S) pairwise
  rounds over ``lax.ppermute``; each round exchanges exactly k candidates
  with a partner and keeps the k best of 2k via a two-key sort.  Wire
  bytes per shard per round are k, not S*k, so total traffic is
  k*ceil(log2 S) instead of k*S — the merge stays bandwidth-bound as the
  shard count grows (DESIGN.md §8).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _wire_exchange(dists: jax.Array, ids: jax.Array, axis_name: str,
                   perm: list[tuple[int, int]], wire_bf16: bool
                   ) -> tuple[jax.Array, jax.Array]:
    """One ppermute hop of (dists, ids).  When ``wire_bf16`` and the
    distances are already bf16, ship raw u16 bits: a bitcast cannot be
    commuted above the collective the way a convert can, so the wire
    really carries 2 bytes/value."""
    if wire_bf16 and dists.dtype == jnp.bfloat16:
        bits = jax.lax.bitcast_convert_type(dists, jnp.uint16)
        rd = jax.lax.bitcast_convert_type(
            jax.lax.ppermute(bits, axis_name, perm), jnp.bfloat16)
    else:
        rd = jax.lax.ppermute(dists, axis_name, perm)
    ri = jax.lax.ppermute(ids, axis_name, perm)
    return rd, ri


def _merge_pair(d1: jax.Array, i1: jax.Array, d2: jax.Array, i2: jax.Array,
                k: int, tie_break_ids: bool) -> tuple[jax.Array, jax.Array]:
    """Keep the k best of two per-shard candidate sets [B, k] each."""
    dd = jnp.concatenate([d1, d2], axis=1)
    ii = jnp.concatenate([i1, i2], axis=1)
    if tie_break_ids:
        sd, si = jax.lax.sort((dd, ii), num_keys=2)
        return sd[:, :k], si[:, :k]
    neg, j = jax.lax.top_k(-dd, k)
    return -neg, jnp.take_along_axis(ii, j, axis=1)


def _tree_merge_axis(dists: jax.Array, ids: jax.Array, k: int,
                     axis_name: str, axis_size: int, wire_bf16: bool,
                     tie_break_ids: bool) -> tuple[jax.Array, jax.Array]:
    """Recursive-doubling top-k merge over ``lax.ppermute``.

    Non-power-of-two sizes use the classic MPI scheme: with
    p = 2**floor(log2 S) and rem = S - p, the rem tail shards first fold
    their candidates into shards [0, rem); the butterfly then runs over
    the p-shard power-of-two subset (partner = rank XOR stride); finally
    shards [0, rem) send the finished result back to the tail so every
    shard exits replicated (the fan-out wrappers use out_specs=P(None)).

    Under a total order — (distance, id) with globally unique ids, i.e.
    ``tie_break_ids`` — every pairwise keep-k step discards only
    candidates that can never appear in the global top-k, so the result
    is bitwise identical to the all-gather-then-full-sort oracle
    regardless of the merge-tree shape.  Without tie-breaking, equal
    distances may resolve to different ids than the oracle.

    ppermute delivers zeros to shards no permutation entry targets; a
    zero distance would masquerade as a best-possible candidate, so every
    receive is masked to (+inf, -1) on shards outside the round's static
    receiver set before merging.
    """
    s = int(axis_size)
    if s <= 1:
        return dists, ids
    me = jax.lax.axis_index(axis_name)
    p = 1 << (s.bit_length() - 1)           # largest power of two <= s
    rem = s - p
    inf = jnp.asarray(jnp.inf, dists.dtype)

    def recv(d, i, perm, is_receiver):
        rd, ri = _wire_exchange(d, i, axis_name, perm, wire_bf16)
        rd = jnp.where(is_receiver, rd, inf)
        ri = jnp.where(is_receiver, ri, jnp.asarray(-1, ids.dtype))
        return rd, ri

    d, i = dists, ids
    if rem:
        # fold tail shards p+j into j (j < rem)
        rd, ri = recv(d, i, [(p + j, j) for j in range(rem)], me < rem)
        md, mi = _merge_pair(d, i, rd, ri, k, tie_break_ids)
        active = me < p
        d = jnp.where(active, md, d)
        i = jnp.where(active, mi, i)
    for r in range(p.bit_length() - 1):     # log2(p) butterfly rounds
        stride = 1 << r
        rd, ri = recv(d, i, [(a, a ^ stride) for a in range(p)], me < p)
        d, i = _merge_pair(d, i, rd, ri, k, tie_break_ids)
    if rem:
        # broadcast the finished result back to the tail shards
        rd, ri = recv(d, i, [(j, p + j) for j in range(rem)], me >= p)
        tail = me >= p
        d = jnp.where(tail, rd, d)
        i = jnp.where(tail, ri, i)
    return d, i


def topk_merge_axis(dists: jax.Array, ids: jax.Array, k: int,
                    axis_name: str, wire_bf16: bool = False,
                    tie_break_ids: bool = False,
                    axis_size: int | None = None
                    ) -> tuple[jax.Array, jax.Array]:
    """Merge per-shard top-k over one mesh axis (log-depth building block).

    dists/ids [B, k] per shard -> merged [B, k] (replicated along the axis).
    ``wire_bf16`` halves the distance payload on the wire (ordering is
    preserved to bf16 resolution; ids stay exact).

    ``axis_size`` selects the strategy: pass the static mesh-axis size to
    run the ppermute tree reduction (k wire values per shard per round,
    ceil(log2 S) rounds); leave it None for the single-round all-gather
    path (k*S wire values per shard), which doubles as the parity oracle
    for the tree.  The size must be static because the installed JAX has
    no ``jax.lax.axis_size`` and the permutation tables are Python-built.

    ``tie_break_ids`` resolves equal distances toward the smallest id via
    a two-key sort — the same order a single-device ``top_k`` over the
    id-sorted candidate set produces, which is what keeps the sharded
    index's merge bit-compatible with the 1-shard path (DESIGN.md §8).
    (Ties that straddle a shard's LOCAL top-k boundary are still cut by
    shard-local order; with real-valued distances that requires > k
    exactly-tied duplicate rows in one shard.)
    """
    if axis_size is not None:
        return _tree_merge_axis(dists, ids, k, axis_name, axis_size,
                                wire_bf16, tie_break_ids)
    if wire_bf16 and dists.dtype == jnp.bfloat16:
        # ship raw u16 bits: a bitcast cannot be commuted above the gather
        # the way a convert can, so the wire really carries 2 bytes/value
        bits = jax.lax.bitcast_convert_type(dists, jnp.uint16)
        d_all = jax.lax.bitcast_convert_type(
            jax.lax.all_gather(bits, axis_name), jnp.bfloat16)
    else:
        d_all = jax.lax.all_gather(dists, axis_name)   # [S, B, k]
    i_all = jax.lax.all_gather(ids, axis_name)
    s = d_all.shape[0]
    b = dists.shape[0]
    d_flat = jnp.transpose(d_all, (1, 0, 2)).reshape(b, s * k)
    i_flat = jnp.transpose(i_all, (1, 0, 2)).reshape(b, s * k)
    if tie_break_ids:
        sd, si = jax.lax.sort((d_flat, i_flat), num_keys=2)
        return sd[:, :k], si[:, :k]
    neg, j = jax.lax.top_k(-d_flat, k)
    return -neg, jnp.take_along_axis(i_flat, j, axis=1)


def hierarchical_topk(dists: jax.Array, ids: jax.Array, k: int,
                      axis_names: tuple[str, ...],
                      wire_bf16: bool = False,
                      tie_break_ids: bool = False,
                      axis_sizes: tuple[int, ...] | None = None
                      ) -> tuple[jax.Array, jax.Array]:
    """Merge local top-k across every mesh axis, innermost (fastest) first:
    'model' -> 'data' -> 'pod' gives log-depth tree reduction whose traffic
    per hop is k*axis_size rather than sum of shard sizes. ``wire_bf16``
    runs the whole merge in bf16 (converting once before the first hop, so
    no convert sits above a gather for XLA to commute): half the distance
    payload on every hop; ids stay exact, ordering is bf16-resolution.
    ``axis_sizes`` (parallel to ``axis_names``) switches each axis to the
    ppermute tree reduction; None keeps the all-gather oracle."""
    out_dtype = dists.dtype
    if wire_bf16:
        dists = dists.astype(jnp.bfloat16)
    for j, ax in enumerate(axis_names):
        size = axis_sizes[j] if axis_sizes is not None else None
        dists, ids = topk_merge_axis(dists, ids, k, ax, wire_bf16,
                                     tie_break_ids, axis_size=size)
    return dists.astype(out_dtype), ids


def compressed_psum(x: jax.Array, axis_name: str) -> jax.Array:
    """int8 chunk-quantized all-reduce: reduce-scatter + all-gather with int8
    payloads — 4x wire-byte reduction vs f32 ring all-reduce. Per-shard
    scale factors travel as f32 scalars (negligible).
    """
    # axis size via the psum-of-ones idiom: works on every JAX that supports
    # shard_map (jax.lax.axis_size is not present in the installed version)
    s = jax.lax.psum(1, axis_name)
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % s
    flat = jnp.pad(flat, (0, pad))
    chunks = flat.reshape(s, -1)                       # [S, n/S]
    scale = jnp.max(jnp.abs(chunks), axis=1, keepdims=True) / 127.0 + 1e-20
    q = jnp.clip(jnp.round(chunks / scale), -127, 127).astype(jnp.int8)
    # reduce-scatter: all_to_all the int8 chunks, dequantise + sum locally
    q_t = jax.lax.all_to_all(q[:, None], axis_name, split_axis=0,
                             concat_axis=1)            # [1, S, n/S] int8
    scale_t = jax.lax.all_gather(scale, axis_name)     # [S, S, 1]
    my = jax.lax.axis_index(axis_name)
    sc = scale_t[:, my]                                # [S, 1] scales for my chunk
    part = jnp.sum(q_t[0].astype(jnp.float32) * sc, axis=0)   # [n/S] f32
    # all-gather the reduced chunks, int8-quantised again
    psc = jnp.max(jnp.abs(part)) / 127.0 + 1e-20
    pq = jnp.clip(jnp.round(part / psc), -127, 127).astype(jnp.int8)
    all_q = jax.lax.all_gather(pq, axis_name)          # [S, n/S] int8
    all_sc = jax.lax.all_gather(psc, axis_name)        # [S]
    out = (all_q.astype(jnp.float32) * all_sc[:, None]).reshape(-1)
    if pad:
        out = out[:-pad]
    return out.reshape(x.shape).astype(x.dtype)
