"""Logical-axis sharding: one rules table maps model-semantic axis names to
physical mesh axes; every with_sharding_constraint in the framework goes
through here so a whole parallelism layout can be swapped by swapping rules.

This is the mechanism behind the per-arch partitioning described in
DESIGN.md section 4 (Megatron TP for LMs, EP for MoE/recsys tables, edge
parallelism for GNNs, DB-row sharding for retrieval).
"""
from __future__ import annotations

import contextlib
import threading
from typing import Any, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# ---------------------------------------------------------------------------
# Default logical rules.  Values: mesh axis name, tuple of axis names, or None.
# ---------------------------------------------------------------------------
DEFAULT_RULES: dict[str, Any] = {
    # activations
    "batch": ("pod", "data"),
    "seq": None,
    "act_embed": None,
    "act_heads": "model",
    "kv_seq": "model",        # decode-time KV cache sequence split (flash-decode)
    "qkv_embed": "model",
    # LM params (Megatron column->row)
    "embed": None,
    "heads": "model",
    "kv_heads": None,
    "head_dim": None,
    "mlp": "model",
    "vocab": "model",
    "layers": None,
    # MoE
    "dp_group": ("pod", "data"),
    "expert": "model",
    "expert_mlp": None,
    "capacity": "data",
    "tokens": ("pod", "data"),
    # recsys
    "table_rows": "model",
    "feature_dim": None,
    "fields": None,
    # gnn
    "edges": ("pod", "data"),
    "nodes": "model",
    "node_feat": None,
    # retrieval (the paper's workload)
    "db_rows": ("pod", "data", "model"),
    "db_dim": None,
    "queries": ("pod", "data"),
    # optimizer
    "zero": "data",
}


class _Ctx(threading.local):
    def __init__(self):
        self.mesh: Mesh | None = None
        self.rules: dict[str, Any] = dict(DEFAULT_RULES)


_CTX = _Ctx()


@contextlib.contextmanager
def axis_rules(mesh: Mesh | None, rules: dict[str, Any] | None = None):
    """Activate a mesh + logical rules for model code built inside the block."""
    prev_mesh, prev_rules = _CTX.mesh, _CTX.rules
    _CTX.mesh = mesh
    merged = dict(DEFAULT_RULES)
    if rules:
        merged.update(rules)
    _CTX.rules = merged
    try:
        yield
    finally:
        _CTX.mesh, _CTX.rules = prev_mesh, prev_rules


def current_mesh() -> Mesh | None:
    return _CTX.mesh


def _mesh_axes_for(logical: str | None, mesh: Mesh) -> tuple[str, ...]:
    if logical is None:
        return ()
    rule = _CTX.rules.get(logical, None)
    if rule is None:
        return ()
    axes = (rule,) if isinstance(rule, str) else tuple(rule)
    return tuple(a for a in axes if a in mesh.axis_names)


def spec_for(shape: Sequence[int], logical_axes: Sequence[str | None]) -> P:
    """PartitionSpec for `shape` given per-dim logical axis names.

    Drops mesh axes that do not evenly divide the corresponding dim, and
    never assigns the same mesh axis to two dims (first dim wins).
    """
    mesh = _CTX.mesh
    if mesh is None:
        return P()
    assert len(shape) == len(logical_axes), (shape, logical_axes)
    used: set[str] = set()
    out = []
    for dim, logical in zip(shape, logical_axes):
        axes = [a for a in _mesh_axes_for(logical, mesh) if a not in used]
        # keep the largest prefix of axes whose product divides dim
        keep: list[str] = []
        prod = 1
        for a in axes:
            if dim % (prod * mesh.shape[a]) == 0:
                keep.append(a)
                prod *= mesh.shape[a]
        used.update(keep)
        out.append(tuple(keep) if len(keep) > 1 else (keep[0] if keep else None))
    return P(*out)


def shard(x: jax.Array, *logical_axes: str | None) -> jax.Array:
    """with_sharding_constraint by logical axis names (no-op without a mesh)."""
    mesh = _CTX.mesh
    if mesh is None:
        return x
    spec = spec_for(x.shape, logical_axes)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def named_sharding(shape: Sequence[int], *logical_axes: str | None) -> NamedSharding | None:
    mesh = _CTX.mesh
    if mesh is None:
        return None
    return NamedSharding(mesh, spec_for(shape, logical_axes))


def param_sharding(tree_axes, tree_shapes) -> Any:
    """Map a pytree of logical-axes tuples + shapes to NamedShardings."""
    return jax.tree.map(
        lambda axes, shp: named_sharding(shp, *axes),
        tree_axes,
        tree_shapes,
        is_leaf=lambda x: isinstance(x, tuple) and all(isinstance(a, (str, type(None))) for a in x),
    )


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


def bytes_per_device(shape: Sequence[int], spec: P, mesh: Mesh, itemsize: int) -> int:
    per = int(np.prod(shape)) * itemsize
    for entry in spec:
        if entry is None:
            continue
        axes = (entry,) if isinstance(entry, str) else entry
        for a in axes:
            per //= mesh.shape[a]
    return per
