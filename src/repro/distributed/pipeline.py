"""Pipeline parallelism: GPipe-style microbatch pipeline over a mesh axis.

Stages are contiguous layer blocks whose stacked parameters are sharded over
the pipeline axis; activations hop stage->stage with ``ppermute`` inside a
``shard_map``. The schedule is the classic lock-step GPipe wavefront:
``n_micro + n_stages - 1`` ticks, each device computing (or idling through)
one microbatch per tick — bubbles are real and show up in the tick count,
exactly like on hardware.

This composes with the rest of the framework as the PP building block of
DESIGN.md §4 (e.g. "model" or a dedicated "pp" axis as the pipeline axis,
DP on the remaining axes).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map


def pipeline_apply(mesh: Mesh, axis: str, stage_fn: Callable,
                   stage_params, x_micro: jax.Array) -> jax.Array:
    """Run ``n_stages`` pipeline stages over ``n_micro`` microbatches.

    stage_fn(params_slice, x) -> y        (same shape as x)
    stage_params: pytree with leading dim n_stages (sharded over ``axis``)
    x_micro: [n_micro, mb, ...] (replicated along ``axis``)
    returns [n_micro, mb, ...] — the last stage's outputs.
    """
    n_stages = mesh.shape[axis]
    n_micro = x_micro.shape[0]
    perm_fwd = [(i, (i + 1) % n_stages) for i in range(n_stages)]

    p_specs = jax.tree.map(lambda _: P(axis), stage_params)

    def local(p_local, xs):
        # p_local leaves have leading dim 1 (this device's stage)
        p_stage = jax.tree.map(lambda a: a[0], p_local)
        stage_id = jax.lax.axis_index(axis)
        mb_shape = xs.shape[1:]

        def tick(carry, t):
            recv, outputs = carry
            m = t - stage_id                    # microbatch at this stage now
            valid = (m >= 0) & (m < n_micro)
            # stage 0 reads from the input stream; others from recv
            x0 = jax.lax.dynamic_index_in_dim(
                xs, jnp.clip(m, 0, n_micro - 1), 0, keepdims=False)
            x_in = jnp.where(stage_id == 0, x0, recv)
            y = stage_fn(p_stage, x_in)
            y = jnp.where(valid, y, jnp.zeros_like(y))
            # last stage writes its finished microbatch into the output
            write = valid & (stage_id == n_stages - 1)
            outputs = jax.lax.cond(
                write,
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, y, jnp.clip(m, 0, n_micro - 1), 0),
                lambda o: o, outputs)
            # hop the activation to the next stage
            nxt = jax.lax.ppermute(y, axis, perm_fwd)
            return (nxt, outputs), None

        init = (jnp.zeros(mb_shape, xs.dtype),
                jnp.zeros((n_micro,) + mb_shape, xs.dtype))
        (recv, outputs), _ = jax.lax.scan(
            tick, init, jnp.arange(n_micro + n_stages - 1))
        # only the last stage holds real outputs; gather + select them so the
        # result is replicated (out_specs P())
        return jax.lax.all_gather(outputs, axis)[n_stages - 1]

    fn = shard_map(local, mesh=mesh,
                   in_specs=(p_specs, P()),
                   out_specs=P(),
                   check_rep=False)
    return fn(stage_params, x_micro)


def pipeline_bubble_fraction(n_stages: int, n_micro: int) -> float:
    """GPipe bubble overhead: (S-1) / (M + S - 1)."""
    return (n_stages - 1) / (n_micro + n_stages - 1)
