"""Small shared utilities: pytree helpers, rng, precision policy, logging."""
from __future__ import annotations

import dataclasses
import logging
import time
from typing import Any, Callable, Iterator

import jax
import jax.numpy as jnp
import numpy as np

logger = logging.getLogger("repro")
if not logger.handlers:
    _h = logging.StreamHandler()
    _h.setFormatter(logging.Formatter("[%(asctime)s repro] %(message)s", "%H:%M:%S"))
    logger.addHandler(_h)
    logger.setLevel(logging.INFO)

PyTree = Any


# ---------------------------------------------------------------------------
# RNG helpers
# ---------------------------------------------------------------------------
def key_iter(seed: int) -> Iterator[jax.Array]:
    """Infinite stream of fresh PRNG keys."""
    key = jax.random.PRNGKey(seed)
    while True:
        key, sub = jax.random.split(key)
        yield sub


def split_dict(key: jax.Array, names: list[str]) -> dict[str, jax.Array]:
    keys = jax.random.split(key, len(names))
    return dict(zip(names, keys))


# ---------------------------------------------------------------------------
# Pytree helpers
# ---------------------------------------------------------------------------
def tree_size(tree: PyTree) -> int:
    """Total number of array elements in a pytree."""
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(tree))


def tree_bytes(tree: PyTree) -> int:
    return sum(int(np.prod(x.shape)) * x.dtype.itemsize for x in jax.tree.leaves(tree))


def tree_cast(tree: PyTree, dtype) -> PyTree:
    return jax.tree.map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x, tree
    )


def tree_zeros_like(tree: PyTree) -> PyTree:
    return jax.tree.map(jnp.zeros_like, tree)


def tree_norm(tree: PyTree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def tree_add(a: PyTree, b: PyTree) -> PyTree:
    return jax.tree.map(jnp.add, a, b)


def tree_scale(a: PyTree, s) -> PyTree:
    return jax.tree.map(lambda x: x * s, a)


# ---------------------------------------------------------------------------
# Precision policy
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Policy:
    """Mixed-precision policy: params stored / compute / output dtypes."""

    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.bfloat16
    output_dtype: Any = jnp.float32

    def cast_compute(self, tree: PyTree) -> PyTree:
        return tree_cast(tree, self.compute_dtype)


DEFAULT_POLICY = Policy()
FULL_PRECISION = Policy(jnp.float32, jnp.float32, jnp.float32)


# ---------------------------------------------------------------------------
# Timing
# ---------------------------------------------------------------------------
class Timer:
    def __init__(self):
        self.t0 = time.perf_counter()

    def __call__(self) -> float:
        return time.perf_counter() - self.t0


def timed(fn: Callable, *args, n: int = 3, warmup: int = 1, **kw):
    """Best-of-n wall clock for a blocking fn; returns (seconds, last_result)."""
    out = None
    for _ in range(warmup):
        out = fn(*args, **kw)
        jax.block_until_ready(out)
    best = float("inf")
    for _ in range(n):
        t = Timer()
        out = fn(*args, **kw)
        jax.block_until_ready(out)
        best = min(best, t())
    return best, out


def human_bytes(n: float) -> str:
    for unit in ["B", "KiB", "MiB", "GiB", "TiB"]:
        if abs(n) < 1024:
            return f"{n:.2f} {unit}"
        n /= 1024
    return f"{n:.2f} PiB"


def human_count(n: float) -> str:
    for unit in ["", "K", "M", "B", "T"]:
        if abs(n) < 1000:
            return f"{n:.2f}{unit}"
        n /= 1000
    return f"{n:.2f}Q"
