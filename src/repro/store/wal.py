"""Write-ahead mutation log (DESIGN.md §7).

Every ``insert``/``update``/``delete``/``bulk_insert`` against a
store-attached ``VectorIndex`` appends one record here *before* the
mutation touches index state, so a crash between snapshots replays the
tail exactly — MeMemo persists every mutation to IndexedDB before
acknowledging it; this file is that durability contract for the
jax_pallas reproduction.

File layout (binary, append-only):

    RWAL\\x01                                  file magic + format version
    [u32 payload_len][u32 crc32][payload]      one frame per record
    ...

A record payload is a JSON header line (op, epoch-before-apply, op
metadata, array specs) followed by the raw bytes of its arrays in spec
order — vectors travel uncompressed, which is what makes the
secure-delete byte-absence property (DESIGN.md §7) testable against this
file. The header's ``epoch`` is the index's ``mutation_epoch`` *before*
the op applied: replay skips records already covered by a snapshot by
comparing it with the restored epoch.

Torn tails: a crash mid-append leaves a frame with a short payload or a
CRC mismatch. Readers stop at the first bad frame (everything before it
is intact by construction); ``repair()`` truncates the file back to the
last valid frame so the log can keep growing after a crash.
"""
from __future__ import annotations

import json
import os
import struct
import zlib
from typing import Iterator

import numpy as np

FILE_MAGIC = b"RWAL\x01"            # 4 magic bytes + 1 format-version byte
_FRAME = struct.Struct("<II")       # payload_len, crc32(payload)


class WalCorruption(RuntimeError):
    """Structural damage the reader cannot safely skip (bad file magic,
    unknown op). Torn tails are NOT corruption — they are expected crash
    debris and handled by ``repair()``."""


class WriteAheadLog:
    def __init__(self, path: str, *, fsync: bool = False):
        self.path = path
        self.fsync = fsync
        self._fh = None             # lazily-opened append handle

    # ------------------------------------------------------------- append
    def _open_append(self):
        if self._fh is None:
            fresh = (not os.path.exists(self.path)
                     or os.path.getsize(self.path) == 0)
            self._fh = open(self.path, "ab")
            if fresh:
                self._fh.write(FILE_MAGIC)
                self._fh.flush()
        return self._fh

    @staticmethod
    def encode(op: str, epoch: int, meta: dict | None,
               arrays: dict | None) -> bytes:
        specs, blobs = [], []
        for name, arr in (arrays or {}).items():
            a = np.ascontiguousarray(arr)
            specs.append({"name": name, "dtype": str(a.dtype),
                          "shape": list(a.shape)})
            blobs.append(a.tobytes())
        header = {"op": op, "epoch": int(epoch), "meta": meta or {},
                  "arrays": specs}
        # json escapes control characters, so the header line contains no
        # raw newline and the b"\n" separator below is unambiguous
        return json.dumps(header).encode() + b"\n" + b"".join(blobs)

    def append(self, op: str, *, epoch: int, meta: dict | None = None,
               arrays: dict | None = None) -> None:
        """Durably append one record. Called BEFORE the mutation applies."""
        payload = self.encode(op, epoch, meta, arrays)
        fh = self._open_append()
        fh.write(_FRAME.pack(len(payload), zlib.crc32(payload)))
        fh.write(payload)
        fh.flush()
        if self.fsync:
            os.fsync(fh.fileno())

    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None

    # -------------------------------------------------------------- read
    @staticmethod
    def _decode(payload: bytes) -> tuple[dict, dict[str, np.ndarray]]:
        nl = payload.index(b"\n")
        header = json.loads(payload[:nl].decode())
        arrays: dict[str, np.ndarray] = {}
        off = nl + 1
        for spec in header["arrays"]:
            dt = np.dtype(spec["dtype"])
            n = int(np.prod(spec["shape"], dtype=np.int64)) * dt.itemsize
            arrays[spec["name"]] = np.frombuffer(
                payload[off:off + n], dtype=dt).reshape(spec["shape"]).copy()
            off += n
        return header, arrays

    def _scan(self) -> Iterator[tuple[dict, dict, int]]:
        """Yield (header, arrays, end_offset) for every intact frame,
        stopping silently at the first torn one."""
        if not os.path.exists(self.path):
            return
        with open(self.path, "rb") as f:
            head = f.read(len(FILE_MAGIC))
            if len(head) < len(FILE_MAGIC):
                return                      # torn first write: no records
            if head != FILE_MAGIC:
                raise WalCorruption(
                    f"{self.path}: bad WAL magic {head!r}")
            off = len(FILE_MAGIC)
            while True:
                frame = f.read(_FRAME.size)
                if len(frame) < _FRAME.size:
                    return                  # clean EOF or torn frame header
                plen, crc = _FRAME.unpack(frame)
                payload = f.read(plen)
                if len(payload) < plen or zlib.crc32(payload) != crc:
                    return                  # torn / damaged tail record
                header, arrays = self._decode(payload)
                off += _FRAME.size + plen
                yield header, arrays, off

    def records(self) -> Iterator[tuple[dict, dict[str, np.ndarray]]]:
        """Replay iterator over intact records, oldest first."""
        for header, arrays, _ in self._scan():
            yield header, arrays

    def valid_length(self) -> int:
        """Byte offset just past the last intact frame."""
        if not os.path.exists(self.path):
            return 0
        off = (len(FILE_MAGIC)
               if os.path.getsize(self.path) >= len(FILE_MAGIC) else 0)
        for _, _, end in self._scan():
            off = end
        return off

    # ------------------------------------------------------------ repair
    def repair(self) -> bool:
        """Truncate a torn tail left by a crash mid-append. Returns True
        if any bytes were cut. Safe to call on a healthy log (no-op)."""
        if not os.path.exists(self.path):
            return False
        self.close()
        good = self.valid_length()
        if good < os.path.getsize(self.path):
            with open(self.path, "r+b") as f:
                f.truncate(good)
            return True
        return False

    def reset(self) -> None:
        """Empty the log (after a snapshot made its records redundant, or
        during compaction). Truncation removes the old record bytes from
        the file — part of the secure-delete story (DESIGN.md §7)."""
        self.close()
        with open(self.path, "wb") as f:
            f.write(FILE_MAGIC)
            f.flush()
            if self.fsync:
                os.fsync(f.fileno())

    @property
    def size_bytes(self) -> int:
        return os.path.getsize(self.path) if os.path.exists(self.path) else 0
