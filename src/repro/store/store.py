"""``IndexStore`` — the durable home of one ``VectorIndex`` (DESIGN.md §7).

MeMemo's IndexedDB layer is what lets the browser restart with the user's
private index intact; this is its jax_pallas analog. One store directory
owns one index:

    store/
      config.json          index kind + construction params (written once)
      wal.log              write-ahead mutation log (store/wal.py)
      snap_<epoch>/        chunked snapshots (store/snapshot.py), newest wins

Lifecycle:

    store = IndexStore("store/", snapshot_every=1000)
    idx = make_index("hnsw", store=store)     # cold: attach; warm: restore
    idx.insert/update/delete(...)             # WAL-logged before applying
    store.snapshot(idx)                       # durable point; truncates WAL
    ...crash...
    idx = make_index("hnsw", store=IndexStore("store/"))   # snapshot + WAL
                                              # replay == the live index,
                                              # bit for bit, same epoch

Invariants (tests/test_store.py):
  * every mutation record lands in the WAL before index state changes;
  * restore = latest snapshot + replay of WAL records whose
    ``epoch`` (mutation_epoch before the op) >= the snapshot's epoch —
    so a crash between "snapshot written" and "WAL truncated" replays
    idempotently (stale records are skipped by epoch);
  * ``compact()`` physically rewrites the store so tombstoned vectors'
    bytes appear in NO file under the directory — deletion is physical,
    not a tombstone bit (the privacy property).
"""
from __future__ import annotations

import json
import os
import shutil

from repro.store import snapshot as snapmod
from repro.store.wal import WalCorruption, WriteAheadLog

CONFIG_NAME = "config.json"
WAL_NAME = "wal.log"
SNAP_PREFIX = "snap_"
FORMAT_VERSION = 1


class IndexStore:
    """Durability orchestrator for one ``VectorIndex``.

    Parameters
    ----------
    root:           store directory (created if absent).
    snapshot_every: auto-snapshot after this many mutations (None = only
                    explicit ``snapshot()`` calls; the WAL still makes
                    every mutation durable in between).
    keep:           snapshots retained by routine GC (compaction always
                    purges down to one).
    fsync:          fsync the WAL after every append (power-loss
                    durability; off by default — process-crash durability
                    only needs the flush).
    page_bytes:     snapshot page size (store/snapshot.py).
    """

    def __init__(self, root: str, *, snapshot_every: int | None = None,
                 keep: int = 2, fsync: bool = False,
                 page_bytes: int = 4 << 20):
        self.root = str(root)
        os.makedirs(self.root, exist_ok=True)
        if snapshot_every is not None and snapshot_every < 1:
            raise ValueError("snapshot_every must be >= 1")
        self.snapshot_every = snapshot_every
        self.keep = max(int(keep), 1)
        self.page_bytes = page_bytes
        self.wal = WriteAheadLog(os.path.join(self.root, WAL_NAME),
                                 fsync=fsync)
        self._since_snapshot = 0

    # ----------------------------------------------------------- listing
    def _config_path(self) -> str:
        return os.path.join(self.root, CONFIG_NAME)

    def has_state(self) -> bool:
        """True once an index has ever been attached here — the signal
        ``make_index(store=...)`` uses to restore instead of create."""
        return os.path.exists(self._config_path())

    def snapshots(self) -> list[str]:
        """Published snapshot directory names, oldest -> newest (the
        zero-padded epoch in the name makes lexical order epoch order)."""
        out = []
        for d in sorted(os.listdir(self.root)):
            if (d.startswith(SNAP_PREFIX) and not d.endswith(".tmp")
                    and os.path.exists(os.path.join(
                        self.root, d, snapmod.MANIFEST_NAME))):
                out.append(d)
        return out

    # ------------------------------------------------------------ attach
    def attach(self, index) -> None:
        """Bind ``index`` to this store: future mutations are WAL-logged.
        Writes ``config.json`` on first attach; later attaches validate
        the stored kind."""
        cfgp = self._config_path()
        if not os.path.exists(cfgp):
            cfg = {"format_version": FORMAT_VERSION, "kind": index.kind,
                   "params": index.config_dict()}
            tmp = cfgp + ".tmp"
            with open(tmp, "w") as f:
                json.dump(cfg, f, indent=1)
            os.replace(tmp, cfgp)
        else:
            with open(cfgp) as f:
                stored = json.load(f)
            if stored["kind"] != index.kind:
                raise ValueError(
                    f"store at {self.root} holds a {stored['kind']!r} "
                    f"index; cannot attach a {index.kind!r}")
        index._store = self
        self._since_snapshot = 0

    # --------------------------------------------------------------- WAL
    def wal_append(self, op: str, *, epoch: int, meta: dict | None = None,
                   arrays: dict | None = None) -> None:
        self.wal.append(op, epoch=epoch, meta=meta, arrays=arrays)

    def notify_mutation(self, index) -> None:
        """Called by the index after every applied mutation; drives the
        ``snapshot_every`` policy."""
        self._since_snapshot += 1
        if (self.snapshot_every is not None
                and self._since_snapshot >= self.snapshot_every):
            self.snapshot(index)

    # ---------------------------------------------------------- snapshot
    def snapshot(self, index) -> str | None:
        """Write a durable snapshot of ``index`` and truncate the WAL
        (its records are now redundant). Crash-ordering: the snapshot is
        published (atomic rename) BEFORE the WAL is cut, and replay skips
        records the snapshot already covers — so dying between the two
        steps is harmless."""
        if index._row_count() == 0 and index.mutation_epoch == 0:
            return None                       # nothing ever happened
        epoch = index.mutation_epoch
        path = os.path.join(self.root, f"{SNAP_PREFIX}{epoch:012d}")
        if os.path.exists(path):
            # a snapshot at this epoch is already durable. Do NOT touch
            # the WAL: it may hold derived.* records (IVF centroid
            # training) logged SINCE that snapshot without bumping the
            # epoch — resetting would silently lose them and break the
            # bit-for-bit restore invariant. GC (old snapshots + crash
            # debris) is WAL-independent and still runs.
            self._gc()
            self._since_snapshot = 0
            return path
        arrays, meta = index.state_dict()
        snapmod.write_snapshot(
            path, kind=index.kind, config=index.config_dict(),
            epoch=epoch, arrays=arrays, meta=meta,
            page_bytes=self.page_bytes)
        self.wal.reset()
        self._gc()
        self._since_snapshot = 0
        return path

    def _gc(self) -> None:
        snaps = self.snapshots()
        for d in snaps[:-self.keep]:
            shutil.rmtree(os.path.join(self.root, d), ignore_errors=True)
        for d in os.listdir(self.root):       # crash debris from mid-write
            if d.startswith(SNAP_PREFIX) and d.endswith(".tmp"):
                shutil.rmtree(os.path.join(self.root, d),
                              ignore_errors=True)

    # ----------------------------------------------------------- restore
    def load_index(self, expect_kind: str | None = None,
                   n_shards: int | None = None,
                   expect_dtype: str | None = None):
        """Warm restore: latest snapshot + WAL replay, then attach.

        The result is bit-for-bit equal to the index that was live when
        the last WAL record landed — including ``mutation_epoch``, so
        epoch-keyed consumers (the RetrievalEngine LRU, DESIGN.md §6)
        keep their invalidation semantics across restarts.

        ``n_shards`` overrides the stored shard count — RESHARDING on
        restore (DESIGN.md §8): backends serialize canonical (placement-
        independent) state, so a snapshot taken at 8 shards restores onto
        1 and vice versa. Without an override, a stored shard count that
        exceeds this process's device count is clamped (with a log line)
        instead of bricking the store — shard count is an execution
        resource, not data.

        ``expect_dtype`` is DIFFERENT: the storage dtype (DESIGN.md §9)
        determines the stored bytes themselves (encoded pages cannot be
        transcoded), so a mismatch with the stored codec is rejected with
        an error rather than overridden."""
        import jax

        from repro.core.index import make_index
        from repro.utils import logger

        cfgp = self._config_path()
        if not os.path.exists(cfgp):
            raise FileNotFoundError(
                f"store at {self.root} has no {CONFIG_NAME}; "
                "nothing to restore")
        with open(cfgp) as f:
            cfg = json.load(f)
        if expect_kind is not None and cfg["kind"] != expect_kind:
            raise ValueError(
                f"store at {self.root} holds a {cfg['kind']!r} index, "
                f"not {expect_kind!r}")
        params = dict(cfg["params"])
        stored_dtype = params.get("dtype", "fp32")
        if expect_dtype is not None and expect_dtype != stored_dtype:
            raise ValueError(
                f"store at {self.root} holds a {stored_dtype!r}-encoded "
                f"index; cannot restore it as dtype={expect_dtype!r} — "
                "storage dtype is part of the stored bytes (encoded "
                "snapshot pages cannot be transcoded). Omit dtype= to "
                f"keep {stored_dtype!r}, or re-ingest the corpus into a "
                "fresh store.")
        if n_shards is not None:
            params["n_shards"] = int(n_shards)
        elif params.get("n_shards", 1) > len(jax.devices()):
            logger.info(
                f"store at {self.root}: stored n_shards="
                f"{params['n_shards']} exceeds {len(jax.devices())} "
                "available device(s); resharding on restore")
            params["n_shards"] = len(jax.devices())
        idx = make_index(cfg["kind"], **params)

        snaps = self.snapshots()
        if snaps:
            manifest, arrays = snapmod.read_snapshot(
                os.path.join(self.root, snaps[-1]))
            idx.restore_state(arrays, manifest["meta"])
            if idx.mutation_epoch != manifest["epoch"]:
                raise WalCorruption(
                    f"snapshot {snaps[-1]} meta epoch "
                    f"{manifest['epoch']} != restored index epoch "
                    f"{idx.mutation_epoch}")

        self.wal.repair()                     # cut any torn tail record
        for header, arrays in self.wal.records():
            ep = int(header["epoch"])
            if ep < idx.mutation_epoch:
                continue                      # already inside the snapshot
            if ep > idx.mutation_epoch:
                raise WalCorruption(
                    f"WAL gap: record epoch {ep} is ahead of index epoch "
                    f"{idx.mutation_epoch}")
            try:
                self._apply(idx, header, arrays)
            except WalCorruption:
                raise
            except Exception:
                # records land BEFORE the impl applies, so an op that
                # raised live (e.g. a dim-mismatched insert the caller
                # caught) left exactly this record behind with no state
                # change — the deterministic impl raises identically
                # here and the op stays skipped. The epoch-gap check on
                # the FOLLOWING records still fails loudly if the op had
                # actually applied live (true divergence).
                continue
        self.attach(idx)
        return idx

    @staticmethod
    def _apply(idx, header: dict, arrays: dict) -> None:
        """Re-run one logged mutation through the SAME implementation path
        the live op took (the ``*_impl`` layer — below validation and
        below WAL logging, so replay never re-logs)."""
        op, meta = header["op"], header["meta"]
        if op == "insert":
            idx._insert_impl(meta["key"], arrays["vec"])
        elif op == "bulk_insert":
            idx._bulk_insert_impl(list(meta["keys"]), arrays["vec"])
        elif op == "update":
            idx._update_impl(meta["key"], arrays["vec"])
        elif op == "delete":
            idx._delete_impl(meta["key"])
        elif op.startswith("derived."):
            idx._apply_derived(op, meta, arrays)
        else:
            raise WalCorruption(f"unknown WAL op {op!r}")

    # --------------------------------------------------------- compaction
    def compact(self, index) -> None:
        """Secure-delete compaction (DESIGN.md §7): physically rewrite the
        store so tombstoned vectors exist in NO file underneath it.

        1. ``index.compact()`` drops dead rows from the in-memory index
           (HNSW rebuilds its graph over live rows) and bumps the epoch —
           epoch-keyed caches over this index invalidate themselves.
        2. A fresh snapshot of the compacted state is published
           (``on_compact``, which ``index.compact()`` itself triggers on
           an attached index — calling either entry point is safe).
        3. The WAL is truncated (old records held the deleted vectors'
           insert payloads) and EVERY other snapshot is purged.

        If the process dies mid-way the store stays consistent (restore
        uses whatever snapshot is newest + the WAL), but files written
        before the crash may still hold deleted bytes — compaction only
        guarantees physical erasure once it returns."""
        if index._store is not self:
            self.attach(index)
        index.compact()                       # template -> on_compact(self)

    def on_compact(self, index) -> None:
        """Post-compaction hook invoked by ``VectorIndex.compact`` on an
        attached index: compaction is not WAL-logged (its epoch bumps
        would otherwise be an unreplayable gap), so the compacted state
        must become durable HERE, atomically with the old files' purge."""
        self.snapshot(index)                  # fresh epoch: writes + resets
        keep = f"{SNAP_PREFIX}{index.mutation_epoch:012d}"
        for d in os.listdir(self.root):
            if d.startswith(SNAP_PREFIX) and d != keep:
                shutil.rmtree(os.path.join(self.root, d),
                              ignore_errors=True)
