# Durable on-device index store — the jax_pallas analog of MeMemo's
# IndexedDB layer (DESIGN.md §7): write-ahead log + chunked snapshots +
# secure-delete compaction, fronted by ``IndexStore``.
from repro.store.snapshot import read_snapshot, write_snapshot
from repro.store.store import IndexStore
from repro.store.wal import WalCorruption, WriteAheadLog

__all__ = ["IndexStore", "WriteAheadLog", "WalCorruption",
           "read_snapshot", "write_snapshot"]
